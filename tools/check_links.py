#!/usr/bin/env python3
"""Fail if a markdown file links to a repo path that doesn't exist.

Usage: python tools/check_links.py README.md docs/architecture.md ...

Checks inline markdown links ``[text](target)``. External targets
(``http(s)://``, ``mailto:``) and pure in-page anchors (``#...``) are
skipped; relative targets resolve against the markdown file's directory and
must exist (an optional ``#fragment`` suffix is ignored).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check(md_path: Path) -> list[str]:
    errors = []
    for n, line in enumerate(md_path.read_text().splitlines(), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md_path.parent / rel).exists():
                errors.append(f"{md_path}:{n}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    errors = []
    for name in argv:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check(p))
    for e in errors:
        print(e, file=sys.stderr)
    if not errors:
        print(f"link check OK ({len(argv)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
