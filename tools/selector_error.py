#!/usr/bin/env python3
"""Aggregate selector-report JSONL into tracked accuracy metrics.

Usage::

    PYTHONPATH=src python -m repro.launch.schedsweep \
        --selector-report --ep 4 --report-out selector_report.jsonl
    python tools/selector_error.py selector_report.jsonl \
        [--min-argmin-rate 0.5] [--max-mean-regret 0.10] [--json out.json]

Each input line is one (scenario, direction, candidate) row from
``repro.launch.schedsweep.selector_report``. Absolute predictions are
structural lower bounds, so the tracked metrics are *ordering* metrics:

* ``argmin_match_rate`` — fraction of scenarios where the selector's pick
  is the simulated optimum over the priced candidates;
* ``mean_regret`` / ``max_regret`` — simulated cost of the pick relative
  to the simulated optimum (0.0 when the pick is the optimum);
* ``pairwise_ordering_accuracy`` — fraction of within-scenario candidate
  pairs whose predicted ordering matches the simulated ordering (ties in
  either ordering are skipped);
* ``underprediction_ratio`` (context) — median simulated/predicted ratio,
  the calibration headroom the ROADMAP selector-calibration item fits.

Gates are off unless requested; CI passes thresholds so a selector
regression fails the build instead of silently drifting.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path


def load_rows(paths: list[str]) -> list[dict]:
    rows = []
    for name in paths:
        p = Path(name)
        if not p.exists():
            raise FileNotFoundError(f"{name}: no such report")
        for n, line in enumerate(p.read_text().splitlines(), 1):
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{name}:{n}: bad JSONL row: {e}") from None
    return rows


def aggregate(rows: list[dict]) -> dict:
    """Selector accuracy metrics over one or more JSONL reports."""
    scenarios: dict[tuple, list[dict]] = {}
    for r in rows:
        scenarios.setdefault((r["plan"], r["direction"], r["ep"],
                              r["rows"], r["d_model"], r["d_ff"]),
                             []).append(r)
    matches, regrets, ratios = [], [], []
    pair_ok = pair_all = 0
    for cands in scenarios.values():
        picked = [c for c in cands if c["picked"]]
        if picked:
            matches.append(any(c["sim_best"] for c in picked))
            regrets.extend(c["regret"] for c in picked
                           if c.get("regret") is not None)
        ratios.extend(c["simulated_us"] / c["predicted_us"]
                      for c in cands if c["predicted_us"] > 0)
        for i, a in enumerate(cands):
            for b in cands[i + 1:]:
                dp = a["predicted_us"] - b["predicted_us"]
                ds = a["simulated_us"] - b["simulated_us"]
                if dp == 0 or ds == 0:
                    continue
                pair_all += 1
                pair_ok += (dp > 0) == (ds > 0)
    return {
        "rows": len(rows),
        "scenarios": len(scenarios),
        "argmin_match_rate": (sum(matches) / len(matches)
                              if matches else None),
        "mean_regret": statistics.mean(regrets) if regrets else None,
        "max_regret": max(regrets) if regrets else None,
        "pairwise_ordering_accuracy": (pair_ok / pair_all
                                       if pair_all else None),
        "underprediction_ratio_median": (statistics.median(ratios)
                                         if ratios else None),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="selector-report JSONL -> tracked accuracy metrics")
    ap.add_argument("reports", nargs="+", metavar="REPORT.jsonl")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the metrics dict as JSON")
    ap.add_argument("--min-argmin-rate", type=float, default=None,
                    help="fail if argmin_match_rate drops below this")
    ap.add_argument("--max-mean-regret", type=float, default=None,
                    help="fail if mean_regret exceeds this")
    args = ap.parse_args(argv)

    metrics = aggregate(load_rows(args.reports))
    for k, v in metrics.items():
        print(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=1)

    failures = []
    if (args.min_argmin_rate is not None
            and (metrics["argmin_match_rate"] or 0.0) < args.min_argmin_rate):
        failures.append(f"argmin_match_rate {metrics['argmin_match_rate']} "
                        f"< {args.min_argmin_rate}")
    if (args.max_mean_regret is not None
            and (metrics["mean_regret"] or 0.0) > args.max_mean_regret):
        failures.append(f"mean_regret {metrics['mean_regret']} "
                        f"> {args.max_mean_regret}")
    for msg in failures:
        print(f"selector accuracy gate failed: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
