"""recurrentgemma-2b — 26L d=2560 10H (MQA kv=1) d_ff=7680 vocab=256000,
RG-LRU + local attention 1:2 pattern (R,R,A), window 2048, GeGLU.
[arXiv:2402.19427]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        head_dim=256, d_ff=7680, vocab=256000, act="geglu",
        norm="rmsnorm", rope_theta=10000.0, sliding_window=2048,
        hybrid_pattern=("rglru", "rglru", "local_attn"),
        lru_width=2560, embed_scale=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rgemma-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
        head_dim=16, d_ff=128, vocab=128, act="geglu", norm="rmsnorm",
        sliding_window=16,
        hybrid_pattern=("rglru", "rglru", "local_attn"),
        lru_width=64, embed_scale=True, tie_embeddings=True,
        vocab_pad=16, remat=False,
    )
