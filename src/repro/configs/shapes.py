"""Assigned input shapes × skip rules, and ShapeDtypeStruct input specs.

Shapes (assignment):
    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (inference prefill)
    decode_32k   seq 32768,  global_batch 128   (one-token decode, 32k cache)
    long_500k    seq 524288, global_batch 1     (long-context decode)

Skip rules (DESIGN.md §4):
    * long_500k only for sub-quadratic archs (mamba2, recurrentgemma);
    * decode shapes skipped for encoder-only archs (hubert);
    * hubert prefill_32k = a 32k-frame encoder forward.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC = {"mamba2-1.3b", "recurrentgemma-2b"}
ENCODER_ONLY = {"hubert-xlarge"}


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    if shape == "long_500k" and cfg.name not in SUBQUADRATIC:
        return "long_500k needs sub-quadratic attention (full-attention arch)"
    if cfg.name in ENCODER_ONLY and SHAPES[shape].kind == "decode":
        return "encoder-only arch has no decode step"
    return None


def cells(cfg: ModelConfig) -> list[str]:
    return [s for s in SHAPES if skip_reason(cfg, s) is None]


def input_specs(cfg: ModelConfig, shape: str, *, batch_override=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns a dict matching what ``train_step`` / ``prefill_step`` /
    ``decode_step`` expect. No device allocation.
    """
    sp = SHAPES[shape]
    B = batch_override or sp.global_batch
    S = sp.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if cfg.family == "audio":
        feats = jax.ShapeDtypeStruct((B, S, cfg.feat_in), f)
        if sp.kind == "train":
            return {"features": feats,
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        return {"features": feats}

    if sp.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif sp.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token; the cache spec is built separately
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    if cfg.family == "vlm" and sp.kind != "decode":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), f)
    return batch


def cache_specs(cfg: ModelConfig, shape: str, *, batch_override=None):
    """ShapeDtypeStructs for the decode cache at this cell's seq_len."""
    from repro.models.model import init_cache
    sp = SHAPES[shape]
    B = batch_override or sp.global_batch
    return jax.eval_shape(lambda: init_cache(cfg, B, sp.seq_len))
