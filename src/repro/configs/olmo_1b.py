"""olmo-1b — 16L d=2048 16H (kv=16) d_ff=8192 vocab=50304, non-parametric
LayerNorm. [arXiv:2402.00838]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304, act="swiglu", norm="nonparam_ln",
        rope_theta=10000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, act="swiglu", norm="nonparam_ln",
        tie_embeddings=True, vocab_pad=16, remat=False,
    )
