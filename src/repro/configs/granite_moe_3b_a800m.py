"""granite-moe-3b-a800m — 32L d=1536 24H (GQA kv=8) expert_ff=512 vocab=49155,
MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-*; assignment header is
authoritative: 40e top-8.] Experts padded 40→48 so E % 16 == 0 on the
production mesh (router never selects padding)."""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155, act="swiglu", norm="rmsnorm",
        rope_theta=10000.0,
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512,
                      n_padding_experts=8),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=128, act="swiglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=5, top_k=2, d_expert=32,
                      n_padding_experts=1),
        vocab_pad=16, remat=False,
    )
