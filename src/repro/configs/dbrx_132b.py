"""dbrx-132b — 40L d=6144 48H (GQA kv=8) expert_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab=100352, act="swiglu", norm="layernorm",
        rope_theta=500000.0,
        moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, act="swiglu", norm="layernorm",
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=96),
        vocab_pad=16, remat=False,
    )
