"""mamba2-1.3b — 48L d=2048 attn-free SSD, ssm_state=128 vocab=50280.
[arXiv:2405.21060]"""

from repro.models.model import ModelConfig
from repro.models.ssm import SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm",
        n_layers=48, d_model=2048, vocab=50280, norm="rmsnorm",
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=128, norm="rmsnorm",
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8),
        tie_embeddings=True, vocab_pad=16, remat=False,
    )
