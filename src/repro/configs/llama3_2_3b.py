"""llama3.2-3b — 28L d=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-3B]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256, act="swiglu", norm="rmsnorm",
        rope_theta=500000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, act="swiglu", norm="rmsnorm",
        vocab_pad=16, remat=False,
    )
