"""gemma-2b — 18L d=2048 8H (MQA kv=1) d_ff=16384 head_dim=256
vocab=256000, GeGLU, sqrt(d) embed scaling. [arXiv:2403.08295]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        head_dim=256, d_ff=16384, vocab=256000, act="geglu",
        norm="rmsnorm", rope_theta=10000.0, embed_scale=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        head_dim=32, d_ff=128, vocab=128, act="geglu", norm="rmsnorm",
        embed_scale=True, tie_embeddings=True, vocab_pad=16, remat=False,
    )
