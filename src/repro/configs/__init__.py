"""Architecture config registry: ``get_config(arch_id)`` / ``ARCHS``."""

from __future__ import annotations

from importlib import import_module

ARCHS = [
    "granite-moe-3b-a800m",
    "dbrx-132b",
    "olmo-1b",
    "llama3_2-3b",
    "qwen2-1_5b",
    "gemma-2b",
    "recurrentgemma-2b",
    "hubert-xlarge",
    "mamba2-1_3b",
    "internvl2-26b",
]

_ALIASES = {
    "llama3.2-3b": "llama3_2-3b",
    "qwen2-1.5b": "qwen2-1_5b",
    "mamba2-1.3b": "mamba2-1_3b",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get_config(arch: str, **overrides):
    mod = import_module(
        f"repro.configs.{canonical(arch).replace('-', '_')}")
    cfg = mod.config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch: str):
    mod = import_module(
        f"repro.configs.{canonical(arch).replace('-', '_')}")
    return mod.smoke_config()
