"""hubert-xlarge — 48L d=1280 16H d_ff=5120 vocab=504 (cluster targets),
encoder-only (non-causal), GELU MLP, LayerNorm, stub frame frontend.
[arXiv:2106.07447]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab=504, act="gelu", norm="layernorm",
        rope_theta=0.0, causal=False, feat_in=512, vocab_pad=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=32, act="gelu", norm="layernorm",
        rope_theta=0.0, causal=False, feat_in=16, vocab_pad=8,
        remat=False,
    )
