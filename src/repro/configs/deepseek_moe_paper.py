"""The paper's DeepSeek-V3-style MoE-FFN evaluation module (§5.2):
hidden 7168, expert intermediate 2048, top-8, 8 local experts per rank;
EP in {4, 8, 16} → 32/64/128 experts. Used by the module benchmarks
(Table 3 / Fig 7-8), not a dry-run architecture cell."""

from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig


def config(ep: int = 8, n_layers: int = 4) -> ModelConfig:
    return ModelConfig(
        name=f"deepseek-moe-ep{ep}", family="moe",
        n_layers=n_layers, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=2048, vocab=129280, act="swiglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=8 * ep, top_k=8, d_expert=2048),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=128, act="swiglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32),
        vocab_pad=16, remat=False,
    )
