"""internvl2-26b — InternLM2 backbone: 48L d=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553; InternViT frontend is a stub providing
precomputed patch embeddings. [arXiv:2404.16821]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92553, act="swiglu", norm="rmsnorm",
        rope_theta=1000000.0, n_patches=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, act="swiglu", norm="rmsnorm",
        n_patches=8, vocab_pad=16, remat=False,
    )
