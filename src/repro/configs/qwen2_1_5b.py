"""qwen2-1.5b — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, QKV bias.
[arXiv:2407.10671]"""

from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, act="swiglu", norm="rmsnorm",
        qkv_bias=True, rope_theta=1000000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, act="swiglu", norm="rmsnorm",
        qkv_bias=True, tie_embeddings=True, vocab_pad=16, remat=False,
    )
