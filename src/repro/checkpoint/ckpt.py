"""Sharded checkpointing: npz shards + manifest, atomic, elastic-restorable.

Layout:
    <dir>/step_000042/
        manifest.json        # tree structure, shapes, dtypes, checksums
        shard_00000.npz      # flat {leaf_key: array} chunks
        ...
        _COMPLETE            # written last — incomplete dirs are ignored
    <dir>/latest             # text file with the newest complete step dir

Design points for 1000+ node runs:
* params are saved as *logical* (unsharded) arrays keyed by tree path, so a
  checkpoint written on one mesh restores onto any other mesh/topology —
  elastic rescaling is a pure resharding problem handled by ``device_put``
  with the new sharding rules (tested: save on 8 devices, load on 4).
* atomic: temp dir + rename, `_COMPLETE` sentinel, per-leaf CRC32 checks.
* restore is lazy-per-leaf so host memory stays bounded.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib

import jax
import ml_dtypes
import numpy as np

_SHARD_LEAVES = 64  # leaves per npz shard

# Fault-injection seam for the atomicity tests: every state-changing file
# operation of save() announces itself through this hook, so a harness can
# SIGKILL the writer between any two operations and assert latest_step_dir
# never resolves to the partial checkpoint. Production never installs one.
_file_hook = None


def set_file_fault_hook(hook) -> None:
    """Install (``None`` clears) the ``save()`` file-op callback.

    ``hook(op)`` runs immediately *before* each file-mutating operation:
    ``mkdir_tmp``, ``write_shard``, ``write_manifest``, ``write_complete``,
    ``rename_final``, ``write_latest``, ``replace_latest``. The hook may
    raise or kill the process — the atomicity contract is that no prefix of
    these operations leaves a state ``latest_step_dir`` would resolve to.
    """
    global _file_hook
    _file_hook = hook


def _file_op(op: str) -> None:
    if _file_hook is not None:
        _file_hook(op)

# npz cannot store bfloat16 — persist the exact bit pattern as uint16 and
# reinterpret on restore (recorded via the manifest's dtype field).
_BITCAST = {"bfloat16": np.uint16}


def _to_storable(a: np.ndarray) -> np.ndarray:
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16)
    return a


def _from_storable(a: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return a.view(ml_dtypes.bfloat16)
    return a


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    _file_op("mkdir_tmp")
    os.makedirs(tmp)

    flat = _flatten(tree)
    keys = sorted(flat)
    manifest = {"step": step, "extra": extra or {}, "leaves": {},
                "shards": []}
    for si in range(0, len(keys), _SHARD_LEAVES):
        shard_keys = keys[si:si + _SHARD_LEAVES]
        shard_name = f"shard_{si // _SHARD_LEAVES:05d}.npz"
        arrays = {}
        for k in shard_keys:
            a = flat[k]
            stored = _to_storable(a)
            arrays[k.replace("/", "__")] = stored
            manifest["leaves"][k] = {
                "shape": list(a.shape), "dtype": str(a.dtype),
                "shard": shard_name,
                "crc32": zlib.crc32(np.ascontiguousarray(stored).tobytes()),
            }
        _file_op("write_shard")
        np.savez(os.path.join(tmp, shard_name), **arrays)
        manifest["shards"].append(shard_name)
    _file_op("write_manifest")
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    _file_op("write_complete")
    with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    _file_op("rename_final")
    os.rename(tmp, final)
    _file_op("write_latest")
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(os.path.basename(final))
    _file_op("replace_latest")
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    return final


def latest_step_dir(ckpt_dir: str) -> str | None:
    ptr = os.path.join(ckpt_dir, "latest")
    if os.path.exists(ptr):
        cand = os.path.join(ckpt_dir, open(ptr).read().strip())
        if os.path.exists(os.path.join(cand, "_COMPLETE")):
            return cand
    # Fallback: newest complete dir (covers a crashed `latest` update).
    if not os.path.isdir(ckpt_dir):
        return None
    dirs = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                  and os.path.exists(os.path.join(ckpt_dir, d, "_COMPLETE")))
    return os.path.join(ckpt_dir, dirs[-1]) if dirs else None


def restore(step_dir: str, tree_like, shardings=None, *,
            verify: bool = True):
    """Restore into the structure of ``tree_like`` (shapes may be sharded
    onto a different mesh via ``shardings`` — elastic rescale)."""
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)

    cache: dict[str, np.lib.npyio.NpzFile] = {}

    def load_leaf(key: str):
        info = manifest["leaves"][key]
        shard = info["shard"]
        if shard not in cache:
            cache[shard] = np.load(os.path.join(step_dir, shard))
        a = cache[shard][key.replace("/", "__")]
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(a).tobytes())
            if crc != info["crc32"]:
                raise IOError(f"checksum mismatch for {key} in {step_dir}")
        return _from_storable(a, info["dtype"])

    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(paths))
    out = []
    for (path, leaf), sh in zip(paths, shard_flat):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        a = load_leaf(key)
        if sh is not None:
            out.append(jax.device_put(a, sh))
        else:
            out.append(jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    dirs = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                  and os.path.exists(os.path.join(ckpt_dir, d, "_COMPLETE")))
    for d in dirs[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
