"""SwiGLU + Add pair — the §6.1 microbenchmark workload, two ways.

``serial``      — two pallas_calls: SwiGLU writes its full output to HBM,
                  Add reads it back (the kernel-by-kernel baseline).
``interleaved`` — one pallas_call whose tile applies SwiGLU and Add before
                  anything leaves VMEM (the statically-scheduled tile
                  interleaving of the paper, with the reuse window moved
                  from L2 into VMEM).

Shapes follow the paper: SwiGLU input [M, 4096], Add operand [M, 2048].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import swiglu_add_ref, swiglu_ref  # noqa: F401


def _swiglu_kernel(h_ref, o_ref):
    h = h_ref[...]
    f = h.shape[-1] // 2
    a, b = h[:, :f], h[:, f:]
    af = a.astype(jnp.float32)
    o_ref[...] = (af * jax.nn.sigmoid(af) * b.astype(jnp.float32)
                  ).astype(o_ref.dtype)


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _swiglu_add_kernel(h_ref, y_ref, o_ref):
    h = h_ref[...]
    f = h.shape[-1] // 2
    a, b = h[:, :f], h[:, f:]
    af = a.astype(jnp.float32)
    g = af * jax.nn.sigmoid(af) * b.astype(jnp.float32)
    o_ref[...] = (g + y_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def swiglu_add_serial(h, y, *, bm: int = 256, interpret: bool = False):
    """Two kernels with an HBM round-trip between them."""
    M, F2 = h.shape
    F = F2 // 2
    bm = min(bm, M)
    assert M % bm == 0
    g = pl.pallas_call(
        _swiglu_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, F2), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, F), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, F), h.dtype),
        interpret=interpret,
    )(h)
    return pl.pallas_call(
        _add_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, F), lambda i: (i, 0)),
                  pl.BlockSpec((bm, F), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, F), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, F), h.dtype),
        interpret=interpret,
    )(g, y)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def swiglu_add_interleaved(h, y, *, bm: int = 256, interpret: bool = False):
    """One fused tile program — the intermediate stays in VMEM."""
    M, F2 = h.shape
    F = F2 // 2
    bm = min(bm, M)
    assert M % bm == 0
    return pl.pallas_call(
        _swiglu_add_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, F2), lambda i: (i, 0)),
                  pl.BlockSpec((bm, F), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, F), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, F), h.dtype),
        interpret=interpret,
    )(h, y)
