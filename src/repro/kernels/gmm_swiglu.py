"""Fused GMM1 + SwiGLU Pallas kernel — the VMEM-resident producer/consumer.

This is the TPU adaptation of the paper's L2-reuse insight (§2.1, §4.4,
§6.1): on Ascend, a GMM tile's output lands in the shared L2 and the SwiGLU
tile reads it back at >4× HBM bandwidth; on TPU we go one step further and
never let the intermediate leave VMEM at all — the gate/up matmul results
are consumed by the SwiGLU activation inside the same tile program.

Layout trick: ``w_in`` is viewed as [E, K, 2, F] so one N-tile loads the
gate *and* up column slices for the same F-range in a single block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``pref`` (hardware-aligned when
    possible — callers pass multiples of 128)."""
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


from .ref import gmm_swiglu_ref  # noqa: F401


def _gmm_swiglu_kernel(x_ref, w_ref, o_ref):
    # x_ref: [1, bm, K]; w_ref: [1, K, 2, bn]; o_ref: [1, bm, bn]
    x = x_ref[0]
    wg = w_ref[0, :, 0, :]
    wu = w_ref[0, :, 1, :]
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # SwiGLU on the VMEM-resident accumulators (never round-trips to HBM).
    o_ref[0, :, :] = (g * jax.nn.sigmoid(g) * u).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def gmm_swiglu(x, w_in, *, bm: int = 128, bn: int = 128,
               interpret: bool = False):
    """x: [E, C, K]; w_in: [E, K, 2F] (gate ‖ up) → [E, C, F]."""
    E, C, K = x.shape
    F = w_in.shape[-1] // 2
    bm = _pick_block(C, bm)
    bn = _pick_block(F, bn)
    # View the fused gate/up projection as [E, K, 2, F].
    w4 = w_in.reshape(E, K, 2, F)
    vmem = (bm * K + 2 * K * bn + 3 * bm * bn) * x.dtype.itemsize
    assert vmem < 100 * 2**20, f"tile working set {vmem} exceeds VMEM budget"

    grid = (E, C // bm, F // bn)
    return pl.pallas_call(
        _gmm_swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, K), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, K, 2, bn), lambda e, i, j: (e, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        interpret=interpret,
    )(x, w4)
