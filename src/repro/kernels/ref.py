"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x, w):
    """Grouped GEMM. x: [E, C, K]; w: [E, K, N] → [E, C, N]."""
    return jnp.einsum("eck,ekn->ecn", x, w)


def gmm_swiglu_ref(x, w_in):
    """Fused GMM1 + SwiGLU. x: [E, C, K]; w_in: [E, K, 2F] → [E, C, F]."""
    h = jnp.einsum("eck,ekf->ecf", x, w_in)
    f = h.shape[-1] // 2
    return jax.nn.silu(h[..., :f]) * h[..., f:]


def swiglu_ref(h):
    """h: [M, 2F] → [M, F]."""
    f = h.shape[-1] // 2
    return jax.nn.silu(h[..., :f]) * h[..., f:]


def swiglu_add_ref(h, y):
    """SwiGLU followed by residual Add: [M, 2F], [M, F] → [M, F]."""
    return swiglu_ref(h) + y


def moe_ffn_ref(x, w_in, w_down):
    """Full expert FFN: x: [E, C, D] → [E, C, D]."""
    g = gmm_swiglu_ref(x, w_in)
    return jnp.einsum("ecf,efd->ecd", g, w_down)
