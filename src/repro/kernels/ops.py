"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) so the same
call sites run the kernel bodies in Python for correctness validation and
compile to real Mosaic kernels on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .gmm import gmm
from .gmm_swiglu import gmm_swiglu
from .swiglu_add import swiglu_add_interleaved, swiglu_add_serial


def _interp() -> bool:
    return jax.default_backend() == "cpu"


def grouped_gemm(x, w, *, bm: int = 128, bn: int = 128):
    """[E, C, K] × [E, K, N] → [E, C, N] (expert-block tiles, full K)."""
    return gmm(x, w, bm=bm, bn=bn, interpret=_interp())


def fused_gmm_swiglu(x, w_in, *, bm: int = 128, bn: int = 128):
    """[E, C, K] × [E, K, 2F] → [E, C, F], SwiGLU fused in VMEM."""
    return gmm_swiglu(x, w_in, bm=bm, bn=bn, interpret=_interp())


def moe_expert_ffn(x, w_in, w_down, act: str = "swiglu", *, bm: int = 128,
                   trainable: bool = False):
    """Full expert FFN via the fused kernels — drop-in ``gmm_fn`` for
    ``models.moe.moe_grouped``. Falls back to einsum for non-swiglu acts.

    ``trainable=True`` routes through the custom-VJP variant whose backward
    is also Pallas (flash-style recompute, fp32 accumulators)."""
    if act != "swiglu":
        from repro.models.moe import expert_ffn
        return expert_ffn(w_in, w_down, x, act)
    if trainable:
        from .gmm_swiglu_bwd import gmm_swiglu_trainable
        g = gmm_swiglu_trainable(x, w_in.astype(x.dtype), _interp())
    else:
        g = fused_gmm_swiglu(x, w_in.astype(x.dtype), bm=bm)
    return grouped_gemm(g, w_down.astype(x.dtype), bm=bm)


def swiglu_add(h, y, *, mode: str = "interleaved", bm: int = 256):
    fn = (swiglu_add_interleaved if mode == "interleaved"
          else swiglu_add_serial)
    return fn(h, y, bm=bm, interpret=_interp())
