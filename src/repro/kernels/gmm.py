"""Grouped GEMM Pallas kernel — expert-block tiles, full-K reduction.

The paper's GMM decomposition constraint (§4.2): task-level parallelism only
along token/expert-block dimensions; the K reduction stays intact so the
accumulation structure and expert-local layout survive. On TPU that maps to
a grid over (expert, M-tile, N-tile) with K kept whole inside the tile —
each tile is one MXU-aligned matmul with both operands VMEM-resident.

Block shapes default to MXU-friendly multiples of 128; ``bm × K`` and
``K × bn`` must fit VMEM (~128 MB), checked at call time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``pref`` (hardware-aligned when
    possible — callers pass multiples of 128)."""
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


from .ref import gmm_ref  # noqa: F401  (oracle lives alongside)


def _gmm_kernel(x_ref, w_ref, o_ref):
    # x_ref: [1, bm, K]; w_ref: [1, K, bn]; o_ref: [1, bm, bn]
    x = x_ref[0]
    w = w_ref[0]
    o_ref[0, :, :] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def gmm(x, w, *, bm: int = 128, bn: int = 128, interpret: bool = False):
    """x: [E, C, K] expert-grouped tokens; w: [E, K, N] → [E, C, N]."""
    E, C, K = x.shape
    _, _, N = w.shape
    bm = _pick_block(C, bm)
    bn = _pick_block(N, bn)
    vmem = (bm * K + K * bn + bm * bn) * x.dtype.itemsize
    assert vmem < 100 * 2**20, f"tile working set {vmem} exceeds VMEM budget"

    grid = (E, C // bm, N // bn)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, K), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, K, bn), lambda e, i, j: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), x.dtype),
        interpret=interpret,
    )(x, w)
