"""Backward Pallas kernels for the fused GMM+SwiGLU (custom VJP).

Flash-style: the forward saves only (x, w_in); both backward kernels
recompute the gate/up activations tile-by-tile in VMEM instead of
round-tripping the [E, C, 2F] intermediate through HBM — the same
producer/consumer-residency insight as the forward, applied to training.

    dx  = dg·wgᵀ + du·wuᵀ   (accumulated over F tiles, grid-revisited)
    dwg = xᵀ·dg, dwu = xᵀ·du (accumulated over M tiles)
with dg = dout ⊙ u ⊙ silu'(g), du = dout ⊙ silu(g).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _silu_grads(x, wg, wu, dout):
    """Recompute tile activations and return (dg, du) in fp32."""
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    sig = jax.nn.sigmoid(g)
    silu = g * sig
    dsilu = sig * (1.0 + g * (1.0 - sig))
    do = dout.astype(jnp.float32)
    return do * u * dsilu, do * silu


def _dx_kernel(x_ref, w_ref, do_ref, dx_ref):
    # grid (E, M, F): dx block [1, bm, K] accumulates over the F dimension.
    f = pl.program_id(2)
    x = x_ref[0]
    wg = w_ref[0, :, 0, :]
    wu = w_ref[0, :, 1, :]
    dg, du = _silu_grads(x, wg, wu, do_ref[0])
    part = (jax.lax.dot_general(dg, wg, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
            + jax.lax.dot_general(du, wu, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32))

    @pl.when(f == 0)
    def _init():
        dx_ref[0] = part.astype(dx_ref.dtype)

    @pl.when(f > 0)
    def _acc():
        dx_ref[0] = (dx_ref[0].astype(jnp.float32)
                     + part).astype(dx_ref.dtype)


def _dw_kernel(x_ref, w_ref, do_ref, dw_ref):
    # grid (E, F, M): dw block [1, K, 2, bf] accumulates over the M dim.
    m = pl.program_id(2)
    x = x_ref[0]
    wg = w_ref[0, :, 0, :]
    wu = w_ref[0, :, 1, :]
    dg, du = _silu_grads(x, wg, wu, do_ref[0])
    dwg = jax.lax.dot_general(x, dg, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dwu = jax.lax.dot_general(x, du, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    @pl.when(m == 0)
    def _init():
        dw_ref[0, :, 0, :] = dwg.astype(dw_ref.dtype)
        dw_ref[0, :, 1, :] = dwu.astype(dw_ref.dtype)

    @pl.when(m > 0)
    def _acc():
        dw_ref[0, :, 0, :] = (dw_ref[0, :, 0, :].astype(jnp.float32)
                              + dwg).astype(dw_ref.dtype)
        dw_ref[0, :, 1, :] = (dw_ref[0, :, 1, :].astype(jnp.float32)
                              + dwu).astype(dw_ref.dtype)


def _pick(dim, pref):
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit,
                   static_argnames=("bm", "bf", "interpret"))
def gmm_swiglu_bwd(x, w4, dout, *, bm=128, bf=128, interpret=False):
    """x: [E,C,K]; w4: [E,K,2,F]; dout: [E,C,F] → (dx, dw4)."""
    E, C, K = x.shape
    F = w4.shape[-1]
    bm = _pick(C, bm)
    bf = _pick(F, bf)

    dx = pl.pallas_call(
        _dx_kernel,
        grid=(E, C // bm, F // bf),
        in_specs=[
            pl.BlockSpec((1, bm, K), lambda e, i, f: (e, i, 0)),
            pl.BlockSpec((1, K, 2, bf), lambda e, i, f: (e, 0, 0, f)),
            pl.BlockSpec((1, bm, bf), lambda e, i, f: (e, i, f)),
        ],
        out_specs=pl.BlockSpec((1, bm, K), lambda e, i, f: (e, i, 0)),
        # fp32 accumulator output (cast to the primal dtype by the caller)
        # — grid-revisited blocks must not round-trip through bf16.
        out_shape=jax.ShapeDtypeStruct((E, C, K), jnp.float32),
        interpret=interpret,
    )(x, w4, dout)

    dw4 = pl.pallas_call(
        _dw_kernel,
        grid=(E, F // bf, C // bm),
        in_specs=[
            pl.BlockSpec((1, bm, K), lambda e, f, m: (e, m, 0)),
            pl.BlockSpec((1, K, 2, bf), lambda e, f, m: (e, 0, 0, f)),
            pl.BlockSpec((1, bm, bf), lambda e, f, m: (e, m, f)),
        ],
        out_specs=pl.BlockSpec((1, K, 2, bf), lambda e, f, m: (e, 0, 0, f)),
        out_shape=jax.ShapeDtypeStruct((E, K, 2, F), jnp.float32),
        interpret=interpret,
    )(x, w4, dout)
    return dx, dw4


# ---------------------------------------------------------------------------
# custom_vjp wrapper: fully-Pallas fused GMM+SwiGLU for training.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def gmm_swiglu_trainable(x, w_in, interpret=False):
    from .gmm_swiglu import gmm_swiglu
    return gmm_swiglu(x, w_in, interpret=interpret)


def _fwd(x, w_in, interpret):
    from .gmm_swiglu import gmm_swiglu
    return gmm_swiglu(x, w_in, interpret=interpret), (x, w_in)


def _bwd(interpret, res, dout):
    x, w_in = res
    E, K = x.shape[0], x.shape[2]
    F = w_in.shape[-1] // 2
    w4 = w_in.reshape(E, K, 2, F)
    dx, dw4 = gmm_swiglu_bwd(x, w4, dout, interpret=interpret)
    return dx.astype(x.dtype), dw4.reshape(E, K, 2 * F).astype(w_in.dtype)


gmm_swiglu_trainable.defvjp(_fwd, _bwd)
