"""Fault-tolerant training driver.

Production loop responsibilities, all testable on CPU:

* **checkpoint/restart** — periodic atomic checkpoints; on start, auto-resume
  from the newest complete one (crash-as-restart semantics). Data order is
  counter-based (``SyntheticStream``), so a restart replays the exact batch
  sequence with no state beyond the step number.
* **straggler mitigation** — per-step wall-time watchdog with an EWMA
  baseline; steps slower than ``straggler_factor ×`` EWMA are logged and
  counted. When the step metrics carry ``rank_time_us`` (the dropless step
  does), a per-rank EWMA accumulates alongside — the observed-time vector
  :meth:`RunState.cost_model` normalizes into ``CostModel(rank_bias=)`` so a
  persistently slow rank becomes the *compile-time* critical rank that
  ``critical_rank_first`` / ``autoselect`` schedule around.
* **fault injection** — ``inject_fault(step)`` raising mid-run simulates a
  node loss; the driver checkpoints at boundaries, so recovery loses at most
  ``ckpt_every - 1`` steps. Run history (``metrics_log``/``stragglers``)
  rides the checkpoint manifest, so a resumed run's merged log spans the
  crash instead of silently dropping pre-crash entries.
* **elastic rescale** — restore() maps logical checkpoints onto any mesh;
  with an :class:`ElasticContext` the *plan world* participates too: live
  :class:`~repro.core.routing.RoutingPlan`\\ s persisted in the manifest are
  remapped onto the surviving ranks (``core.elastic.remap_plan``) and the
  SSC cache is re-keyed — not flushed — for the new mesh size
  (``SSCCache.rekey_for_mesh``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as CK


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 2.5
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class ElasticContext:
    """Mesh-aware restore context: what the elastic rescale path needs.

    ``ep`` is the mesh size of *this* run. Live plans the caller registers
    in ``plans`` (name → RoutingPlan) are persisted with every checkpoint;
    on a resume whose manifest recorded a different mesh size they come
    back **remapped** onto the current mesh (survivors keep their rows,
    experts re-chunk in global order — see ``core/elastic.py``), ready to
    compile through the normal ``plan_from_routing`` → SSC path.

    ``dead_ranks`` names which old-mesh ranks were lost (shrink only);
    when ``None`` a shrink defaults to dropping the tail ranks — the
    conventional contraction of a torn-down trailing host. ``cache`` is an
    ``SSCCache`` (or anything with ``rekey_for_mesh``) to re-key on rescale.
    """

    ep: int
    cache: Optional[object] = None
    plans: dict = dataclasses.field(default_factory=dict)
    dead_ranks: Optional[tuple] = None


@dataclasses.dataclass
class RunState:
    step: int
    params: object
    opt_state: object
    metrics_log: list
    stragglers: list
    resumed_from: Optional[int] = None
    # Per-rank step-time EWMA (None until a step reports "rank_time_us").
    rank_time_ewma: Optional[list] = None
    # One record per rescale the restore path performed.
    elastic_events: list = dataclasses.field(default_factory=list)

    def cost_model(self, base=None):
        """Observed-time-biased CostModel (straggler feedback loop).

        With no per-rank observations yet this is just ``base`` (or the
        compile-time default); otherwise the EWMA vector normalizes into
        ``CostModel(rank_bias=)`` via ``core.elastic.observed_cost_model``.
        """
        from repro.core.elastic import observed_cost_model
        return observed_cost_model(self.rank_time_ewma, base)


def _run_extra(elastic: Optional[ElasticContext], metrics_log: list,
               stragglers: list, rank_ewma: Optional[list]) -> dict:
    """JSON-safe manifest ``extra``: run history + the elastic plan world."""
    extra: dict = {
        "metrics_log": metrics_log,
        "stragglers": [list(s) for s in stragglers],
    }
    if rank_ewma is not None:
        extra["rank_time_ewma"] = [float(x) for x in rank_ewma]
    if elastic is not None:
        extra["ep"] = elastic.ep
        extra["plans"] = {
            name: np.asarray(p.counts, dtype=np.int64).tolist()
            for name, p in elastic.plans.items()}
    return extra


def _elastic_restore(elastic: ElasticContext, prev_ep: int, extra: dict,
                     rank_ewma: Optional[list], start_step: int,
                     events: list) -> Optional[list]:
    """Remap the persisted plan world from ``prev_ep`` onto ``elastic.ep``.

    Mutates ``elastic.plans`` in place (remapped plans replace whatever the
    caller registered under the same names), re-keys ``elastic.cache``, and
    returns the survivor-restricted per-rank EWMA vector.
    """
    from repro.core.elastic import remap_plan, surviving_ranks
    from repro.core.routing import RoutingPlan

    if elastic.ep < prev_ep:
        dead = (tuple(int(r) for r in elastic.dead_ranks)
                if elastic.dead_ranks is not None
                else tuple(range(elastic.ep, prev_ep)))
        survivors = surviving_ranks(prev_ep, dead)
        if len(survivors) != elastic.ep:
            raise ValueError(
                f"dead_ranks={dead} leaves {len(survivors)} survivors of "
                f"the checkpoint's {prev_ep}-rank mesh, but this run has "
                f"ep={elastic.ep}")
        kw = {"dead_ranks": dead}
    else:
        survivors = tuple(range(prev_ep))
        kw = {"new_ep": elastic.ep}

    for name, counts in (extra.get("plans") or {}).items():
        old = RoutingPlan.from_counts(np.asarray(counts, dtype=np.int64))
        elastic.plans[name] = remap_plan(old, **kw)

    if rank_ewma is not None and len(rank_ewma) == prev_ep:
        kept = [float(rank_ewma[r]) for r in survivors]
        # Re-admitted ranks start at the survivors' mean — unbiased until
        # they report their own times.
        fill = float(np.mean(kept)) if kept else 0.0
        rank_ewma = kept + [fill] * (elastic.ep - len(kept))

    rekey = None
    if elastic.cache is not None:
        rekey = elastic.cache.rekey_for_mesh(elastic.ep)
    events.append({"step": start_step, "from_ep": prev_ep,
                   "to_ep": elastic.ep, "survivors": list(survivors),
                   "plans": sorted(elastic.plans), "cache": rekey})
    return rank_ewma


def train_loop(*, step_fn, params, opt_state, stream, mesh, batch_sharding,
               n_steps: int, ft: FTConfig,
               inject_fault: Optional[Callable[[int], None]] = None,
               log_every: int = 10,
               elastic: Optional[ElasticContext] = None) -> RunState:
    """Run (or resume) ``n_steps`` of training with FT behaviours."""
    start_step = 0
    resumed_from = None
    metrics_log: list = []
    stragglers: list = []
    rank_ewma: Optional[list] = None
    elastic_events: list = []
    latest = CK.latest_step_dir(ft.ckpt_dir)
    if latest is not None:
        (params, opt_state), manifest = CK.restore(
            latest, (params, opt_state))
        start_step = manifest["step"]
        resumed_from = start_step
        extra = manifest.get("extra") or {}
        # Merged run history: pre-crash entries come back from the manifest
        # so the resumed log spans the crash (entries are logged with the
        # post-increment step, hence always <= the checkpoint's step).
        metrics_log = [m for m in extra.get("metrics_log", [])
                       if m.get("step", 0) <= start_step]
        stragglers = [tuple(s) for s in extra.get("stragglers", [])]
        rank_ewma = extra.get("rank_time_ewma")
        prev_ep = extra.get("ep")
        if elastic is not None and prev_ep and prev_ep != elastic.ep:
            rank_ewma = _elastic_restore(elastic, prev_ep, extra, rank_ewma,
                                         start_step, elastic_events)

    ewma = None
    step = start_step
    while step < n_steps:
        if inject_fault is not None:
            inject_fault(step)  # may raise — simulating a node loss
        batch = stream.sharded_batch(step, mesh, batch_sharding)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0

        if ewma is None:
            ewma = dt
        elif dt > ft.straggler_factor * ewma:
            stragglers.append((step, dt, ewma))
        ewma = (1 - ft.ewma_alpha) * ewma + ft.ewma_alpha * dt

        rt = metrics.get("rank_time_us")
        if rt is not None:
            rt = [float(x) for x in np.ravel(np.asarray(rt))]
            if rank_ewma is None or len(rank_ewma) != len(rt):
                rank_ewma = rt
            else:
                a = ft.ewma_alpha
                rank_ewma = [(1 - a) * e + a * x
                             for e, x in zip(rank_ewma, rt)]

        step += 1
        if step % log_every == 0 or step == n_steps:
            metrics_log.append(
                {"step": step,
                 "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]),
                 "step_time_s": dt})
        if step % ft.ckpt_every == 0 or step == n_steps:
            CK.save(ft.ckpt_dir, step, (params, opt_state),
                    extra=_run_extra(elastic, metrics_log, stragglers,
                                     rank_ewma))
            CK.gc_old(ft.ckpt_dir, keep=ft.keep)

    return RunState(step=step, params=params, opt_state=opt_state,
                    metrics_log=metrics_log, stragglers=stragglers,
                    resumed_from=resumed_from, rank_time_ewma=rank_ewma,
                    elastic_events=elastic_events)
