"""Fault-tolerant training driver.

Production loop responsibilities, all testable on CPU:

* **checkpoint/restart** — periodic atomic checkpoints; on start, auto-resume
  from the newest complete one (crash-as-restart semantics). Data order is
  counter-based (``SyntheticStream``), so a restart replays the exact batch
  sequence with no state beyond the step number.
* **straggler mitigation** — per-step wall-time watchdog with an EWMA
  baseline; steps slower than ``straggler_factor ×`` EWMA are logged and
  counted. On real clusters the hook triggers rank exclusion / re-admission
  at the next checkpoint boundary; here the policy is exercised through
  fault injection in tests.
* **fault injection** — ``inject_fault(step)`` raising mid-run simulates a
  node loss; the driver checkpoints at boundaries, so recovery loses at most
  ``ckpt_every - 1`` steps.
* **elastic rescale** — restore() maps logical checkpoints onto any mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as CK


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 2.5
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class RunState:
    step: int
    params: object
    opt_state: object
    metrics_log: list
    stragglers: list
    resumed_from: Optional[int] = None


def train_loop(*, step_fn, params, opt_state, stream, mesh, batch_sharding,
               n_steps: int, ft: FTConfig,
               inject_fault: Optional[Callable[[int], None]] = None,
               log_every: int = 10) -> RunState:
    """Run (or resume) ``n_steps`` of training with FT behaviours."""
    start_step = 0
    resumed_from = None
    latest = CK.latest_step_dir(ft.ckpt_dir)
    if latest is not None:
        (params, opt_state), manifest = CK.restore(
            latest, (params, opt_state))
        start_step = manifest["step"]
        resumed_from = start_step

    ewma = None
    metrics_log: list = []
    stragglers: list = []
    step = start_step
    while step < n_steps:
        if inject_fault is not None:
            inject_fault(step)  # may raise — simulating a node loss
        batch = stream.sharded_batch(step, mesh, batch_sharding)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0

        if ewma is None:
            ewma = dt
        elif dt > ft.straggler_factor * ewma:
            stragglers.append((step, dt, ewma))
        ewma = (1 - ft.ewma_alpha) * ewma + ft.ewma_alpha * dt

        step += 1
        if step % log_every == 0 or step == n_steps:
            metrics_log.append(
                {"step": step,
                 "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]),
                 "step_time_s": dt})
        if step % ft.ckpt_every == 0 or step == n_steps:
            CK.save(ft.ckpt_dir, step, (params, opt_state))
            CK.gc_old(ft.ckpt_dir, keep=ft.keep)

    return RunState(step=step, params=params, opt_state=opt_state,
                    metrics_log=metrics_log, stragglers=stragglers,
                    resumed_from=resumed_from)
