"""Expert-parallel MoE execution under shard_map.

Two modes, both numerically identical to the single-device paths (tests
assert it on a multi-device CPU mesh):

* ``baseline``       — collective AllToAll dispatch, full-barrier semantics:
  the conventional host-driven path the paper profiles in §2.3.
* ``hyperparallel``  — the paper's design mapped to JAX/TPU: the AllToAll is
  decomposed into per-destination chunks moved by ``ppermute`` in a
  RATR-rotated ring (source rank r starts at destination r+k at step k),
  with each arriving chunk's expert FFN issued immediately. Data dependence
  is chunk-local, so XLA's latency-hiding scheduler overlaps the
  collective-permute of step k+1 with the GMM of step k — the tile-level
  one-sided pipeline of §4.1/§4.4, with ppermute's send/recv semantics
  standing in for put_mem_signal's remote-write + event counter.

Routing uses per-(destination, expert) fixed capacity so all comm shapes are
static. Every device routes its local tokens with the replicated router;
combine applies top-k weights back at the source — exactly the paper's
Dispatch→…→Combine boundary.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.moe import MoEConfig, router_topk
from repro.models.layers import glu_act


@dataclasses.dataclass(frozen=True)
class EPConfig:
    mode: str = "hyperparallel"     # baseline | hyperparallel
    axis: str = "model"
    capacity_factor: float = 1.25
    use_pallas: bool = False        # fused gmm kernels inside the shard
    # EP-over-DP (paper's dp=32/ep=32 layout): tokens are batch-sharded over
    # every mesh axis incl. the EP axis; the a2a still runs over `axis`.
    dp_batch: bool = False


def _pair_capacity(t_loc: int, mc: MoEConfig, ep: int,
                   cap_factor: float) -> int:
    """Tokens per (destination rank, local expert) pair from one device."""
    per_slot = t_loc * mc.top_k / mc.e_total
    return max(8, int(np.ceil(per_slot * cap_factor / 8)) * 8)


def plan_from_dispatch(top_i, mc: MoEConfig, ep: int, C: int):
    """RoutingPlan for the rows ``_dispatch_buffers`` actually materialises.

    ``top_i``: per-source-rank expert choices [ep, T_loc, k]. Capacity here
    is *per (source device, global expert)* — the slot semantics of
    ``_dispatch_buffers`` — so ``counts[s, d, e] = min(#choices, C)``. The
    returned plan describes the useful (non-padding) rows of the EP path's
    fixed-capacity send buffers, letting the same batch be compiled by the
    scheduling stack and profiled for skew.
    """
    from repro.core.routing import RoutingPlan

    ti = np.asarray(top_i)
    if ti.ndim != 3 or ti.shape[0] != ep:
        raise ValueError(f"expected [ep, T_loc, k] choices, got {ti.shape}")
    if mc.e_total % ep:
        raise ValueError(f"e_total={mc.e_total} not divisible by ep={ep}")
    e_loc = mc.e_total // ep
    counts = np.zeros((ep, ep, e_loc), dtype=np.int64)
    for s in range(ep):
        hist = np.bincount(ti[s].reshape(-1), minlength=mc.e_total)
        counts[s] = np.minimum(hist, C).reshape(ep, e_loc)
    return RoutingPlan.from_counts(counts)


def ring_chunk_caps(plan, ep: int, topology=None, bucket=None,
                    inter_bucket=None) -> tuple:
    """Per-ring-step row caps from a :class:`RoutingPlan`.

    ``caps[k]`` is the largest per-(dst, expert) row count any source rank
    moves at ring distance ``k`` (source ``s`` → destination ``(s + k) %
    ep``). The hyperparallel ring uses these to slice each step's ppermute
    chunk to plan size instead of the full fixed capacity — and a step whose
    cap is 0 carries only padding for *every* rank, so it is skipped
    entirely (no ppermute pair, no FFN). Caps are an upper bound per SPMD
    step: all ranks must move the same shape, so the straggler source sets
    the cap.

    With a :class:`repro.core.hardware.Topology`, each step's cap can be
    quantized per *link class*: ring step ``k`` is an **inter-node** step
    when any source's hop at distance ``k`` crosses a node boundary (one
    straggler crossing makes the whole SPMD step pay NIC rates). Intra-node
    steps quantize their caps with ``bucket``, inter-node steps with the
    (typically coarser) ``inter_bucket`` — fewer distinct cap rungs on the
    slow axis means fewer retraces of exactly the steps where a retrace
    stalls the NIC pipeline longest. Both accept anything
    ``BucketSpec.from_any`` does; ``None`` leaves that class's caps exact.
    Quantization only rounds caps *up* (rungs are upper bounds), so a
    bucketed cap never drops rows a plan-sized chunk would have carried,
    and zero caps stay zero — step skipping survives bucketing.
    """
    if plan.ep != ep:
        raise ValueError(f"plan ep={plan.ep} != mesh ep={ep}")
    c = np.asarray(plan.counts, dtype=np.int64)       # [src, dst, e_loc]
    caps = []
    for k in range(ep):
        dst = (np.arange(ep) + k) % ep
        caps.append(int(c[np.arange(ep), dst].max()))
    if bucket is None and inter_bucket is None:
        return tuple(caps)
    if inter_bucket is not None and topology is None:
        raise ValueError(
            "inter_bucket needs a topology to tell inter-node ring steps "
            "from intra-node ones")
    from repro.core.buckets import BucketSpec

    def quantize(cap: int, b) -> int:
        if b is None or cap == 0:
            return cap
        return int(BucketSpec.from_any(b).quantize(np.array([cap]))[0])

    out = []
    for k, cap in enumerate(caps):
        inter = topology is not None and any(
            not topology.same_node(s, (s + k) % ep) for s in range(ep))
        b = inter_bucket if (inter and inter_bucket is not None) else bucket
        out.append(quantize(cap, b))
    return tuple(out)


def _expert_ffn_local(w_in, w_down, x, act, use_pallas):
    if use_pallas:
        from repro.kernels.ops import moe_expert_ffn
        return moe_expert_ffn(x, w_in, w_down, act)
    h = jnp.einsum("ecd,edf->ecf", x, w_in.astype(x.dtype))
    h = glu_act(h, act)
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))


def _dispatch_buffers(x2d, router, mc: MoEConfig, ep: int, C: int):
    """Local routing + scatter into the per-(dst, expert) send buffer.

    Returns (send [ep, e_loc, C, d], top_p, top_i, slot) where slot is the
    position within the (dst, expert) capacity bucket (C = dropped).
    """
    T, d = x2d.shape
    e_loc = mc.e_total // ep
    top_p, top_i = router_topk(router, x2d, mc)
    flat_e = top_i.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, mc.e_total, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C
    slot = jnp.where(keep, slot, C)
    top_p = top_p * keep.reshape(top_p.shape)

    send = jnp.zeros((mc.e_total, C + 1, d), x2d.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], top_i.shape)
    send = send.at[flat_e, slot.reshape(-1)].add(
        x2d[tok_idx.reshape(-1)])
    send = send[:, :C].reshape(ep, e_loc, C, d)
    return send, top_p, top_i, slot.reshape(top_i.shape)


def _combine(back, top_p, top_i, slot, T, d, ep, e_loc, C, dtype):
    """back: [ep(dst), e_loc, C, d] results at their send slots → [T, d]."""
    flat = jnp.concatenate(
        [back.reshape(ep * e_loc * C, d),
         jnp.zeros((1, d), back.dtype)], axis=0)
    # global flat index of (expert_global, slot): expert-major like send.
    gather_idx = jnp.where(
        slot < C, top_i * C + slot, ep * e_loc * C)     # [T, k]
    y = jnp.einsum("tkd,tk->td", flat[gather_idx],
                   top_p.astype(back.dtype))
    return y.astype(dtype)


def make_moe_ep(mesh, epc: EPConfig, act: str = "swiglu", plan=None,
                bucket=None, topology=None, inter_bucket=None):
    """Returns moe_impl(params, x, mc) running EP over the model axis.

    ``plan``: an optional host-known :class:`RoutingPlan` (e.g. from
    ``plan_from_dispatch`` on this batch's routing, or a bucketed plan
    covering it). In ``hyperparallel`` mode the ring then moves *plan-sized*
    ppermute chunks — each step's chunk is sliced to the largest row count
    any source actually sends at that ring distance — and ring steps that
    would carry only padding for every rank are skipped outright (the
    ROADMAP "ragged EP path"). Chunk caps are static Python ints, so a new
    plan triggers a retrace. ``bucket`` (a
    :class:`repro.core.buckets.BucketSpec` or anything
    ``BucketSpec.from_any`` accepts) quantizes the plan's counts before the
    caps are derived, so jittered per-batch plans collapse onto a small set
    of cap tuples and the retrace count stays bounded by the policy's rung
    ladder instead of growing with every batch — the same trade the SSC
    cache makes, applied to jit traces. Buckets only ever round counts
    *up*, so a bucketed plan never undercounts the routing it was derived
    from. If the (possibly bucketed) plan undercounts the real routing —
    e.g. a stale plan reused across batches — overflow rows degrade to
    capacity-style drops (their result rows stay zero); they are never
    mis-gathered.

    ``topology`` (a :class:`repro.core.hardware.Topology`) switches cap
    quantization to per link class: ring steps whose hop crosses a node
    boundary for any source quantize with ``inter_bucket`` instead of
    ``bucket`` (see :func:`ring_chunk_caps`) — a coarser inter-node ladder
    bounds retraces of the NIC-bound steps separately from the cheap
    intra-node ones.
    """
    ep = mesh.shape[epc.axis]
    dp = tuple(a for a in mesh.axis_names if a != epc.axis)
    if (bucket is not None or inter_bucket is not None) and plan is None:
        raise ValueError(
            "make_moe_ep(bucket=.../inter_bucket=...) quantizes a routing "
            "plan's ring caps — pass plan= as well (without one the "
            "fixed-capacity path runs and the bucket would be silently "
            "ignored)")
    if topology is not None and plan is not None:
        # Per-link-class cap quantization: intra-node steps use ``bucket``,
        # inter-node steps the (coarser) ``inter_bucket``.
        ring_caps = ring_chunk_caps(plan, ep, topology=topology,
                                    bucket=bucket,
                                    inter_bucket=inter_bucket)
    else:
        if bucket is not None:
            from repro.core.buckets import BucketSpec
            plan = BucketSpec.from_any(bucket).apply(plan)
        ring_caps = ring_chunk_caps(plan, ep) if plan is not None else None

    def moe_impl(params, x, mc: MoEConfig):
        B, S, d = x.shape
        e_loc = mc.e_total // ep

        if epc.dp_batch and B % (ep * max(1, np.prod(
                [mesh.shape[a] for a in dp]))) == 0:
            x_spec = P(tuple(mesh.axis_names), None, None)
        else:
            x_spec = P(dp if B > 1 else None,
                       epc.axis if S % ep == 0 and S > 1 else None, None)

        @partial(jax.shard_map, mesh=mesh,
                 in_specs=(P(None, None), P(epc.axis, None, None),
                           P(epc.axis, None, None), x_spec),
                 out_specs=x_spec, check_vma=False)
        def run(router, w_in, w_down, x_loc):
            b, s, _ = x_loc.shape
            T = b * s
            x2d = x_loc.reshape(T, d)
            C = _pair_capacity(T, mc, ep, epc.capacity_factor)
            send, top_p, top_i, slot = _dispatch_buffers(
                x2d, router, mc, ep, C)

            if epc.mode == "baseline":
                recv = jax.lax.all_to_all(send, epc.axis, split_axis=0,
                                          concat_axis=0, tiled=True)
                xin = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep * C, d)
                y = _expert_ffn_local(w_in, w_down, xin, act,
                                      epc.use_pallas)
                y = y.reshape(e_loc, ep, C, d).transpose(1, 0, 2, 3)
                back = jax.lax.all_to_all(y, epc.axis, split_axis=0,
                                          concat_axis=0, tiled=True)
            else:
                back = _hyperparallel_ring(
                    send, w_in, w_down, act, ep, epc)

            y = _combine(back, top_p, top_i, slot, T, d, ep, e_loc, C,
                         x_loc.dtype)
            return y.reshape(b, s, d)

        return run(params["router"], params["w_in"], params["w_down"], x)

    def _hyperparallel_ring(send, w_in, w_down, act, ep, epc):
        """RATR ring: step k moves the chunk for destination (r+k) and the
        FFN for the chunk that just arrived runs immediately; results ride
        the reverse ring back to their source. Step 0 is the rank-local
        chunk (an HBM copy, not link traffic — same as the simulator).

        With ``ring_caps`` (a routing plan is known), each step's chunk is
        sliced to ``min(C, caps[k])`` rows per (dst, expert) slot — tokens
        always occupy the head of each slot, so the sliced rows are exactly
        the routed ones — and all-padding steps (cap 0) are skipped.
        """
        r = jax.lax.axis_index(epc.axis)
        e_loc, C, d = send.shape[1], send.shape[2], send.shape[3]
        back = jnp.zeros_like(send)

        def step_cap(k):
            return C if ring_caps is None else min(C, ring_caps[k])

        # k = 0: local chunk.
        c0 = step_cap(0)
        if c0 > 0:
            chunk0 = jnp.take(send, r, axis=0)[:, :c0]   # dyn [e_loc,c0,d]
            y0 = _expert_ffn_local(w_in, w_down, chunk0, act, epc.use_pallas)
            back = jax.lax.dynamic_update_slice(back, y0[None], (r, 0, 0, 0))

        for k in range(1, ep):
            ck = step_cap(k)
            if ck == 0:
                continue        # every rank's step-k chunk is pure padding
            perm_fwd = [(i, (i + k) % ep) for i in range(ep)]
            perm_bwd = [(i, (i - k) % ep) for i in range(ep)]
            # RATR: source r's step-k chunk targets destination (r+k).
            chunk = jnp.take(send, (r + k) % ep, axis=0)[:, :ck]
            arrived = jax.lax.ppermute(chunk, epc.axis, perm_fwd)
            y = _expert_ffn_local(w_in, w_down, arrived, act,
                                  epc.use_pallas)
            returned = jax.lax.ppermute(y, epc.axis, perm_bwd)
            back = jax.lax.dynamic_update_slice(
                back, returned[None], ((r + k) % ep, 0, 0, 0))
        return back

    return moe_impl
