"""Per-architecture sharding rules: param / batch / cache PartitionSpecs.

Policy (DESIGN.md §5):

* ``data`` (+ ``pod``) axes — batch/data parallelism. ``pod`` is the outer
  DP axis so cross-pod traffic is gradient all-reduce only.
* ``model`` axis — tensor parallelism for dense stacks (output-dim sharding
  with divisibility fallbacks), expert parallelism for MoE stacks (expert
  dim sharding; experts are padded so E % model == 0).
* Large archs (> ``FSDP_THRESHOLD`` params) additionally shard the weight's
  other dim over ``data`` (ZeRO-3 style; XLA inserts the all-gathers).
* Activations: residual stream is sequence-sharded over ``model`` between
  blocks (Megatron sequence parallelism) via an ambient constraint context.
* Decode KV caches: batch over dp, sequence over ``model`` (flash-decoding
  style — softmax reductions over the sharded dim become all-reduces).

Optimizer state (fp32 m/v) inherits the param specs leaf-for-leaf.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes
from repro.models.model import ModelConfig

FSDP_THRESHOLD = 10e9


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class ShardingRules:
    """``mode``:

    * ``tp_sp`` — tensor parallel over 'model' + sequence-parallel
      activations (the initial Megatron-style mapping; the paper-faithful
      baseline recorded in EXPERIMENTS.md §Perf).
    * ``zero1`` — pure data parallelism over all mesh axes: params
      replicated, optimizer state sharded (ZeRO-1), batch over
      (pod, data, model). The right mapping for ≲3B dense archs on a
      256-chip pod — the only remaining collective is the gradient
      all-reduce.
    * ``ep_dp`` — zero1 for the dense trunk, experts sharded over 'model'
      (EP spans DP ranks: the paper's own dp=32/ep=32 production layout).
    """

    def __init__(self, cfg: ModelConfig, mesh, fsdp: bool | None = None,
                 mode: str = "tp_sp"):
        self.cfg = cfg
        self.mesh = mesh
        self.mode = mode
        self.dp = dp_axes(mesh)
        self.model_n = mesh.shape.get("model", 1)
        self.fsdp = (cfg.param_count() > FSDP_THRESHOLD
                     if fsdp is None else fsdp)
        self.data_n = mesh.shape.get("data", 1)
        self.all_axes = tuple(mesh.axis_names)

    # -- helpers -----------------------------------------------------------
    def _m(self, dim: int):
        """'model' if divisible else None."""
        return "model" if _div(dim, self.model_n) else None

    def _f(self, dim: int):
        """FSDP ('data') if enabled and divisible else None."""
        return "data" if (self.fsdp and _div(dim, self.data_n)) else None

    # -- parameter rules ----------------------------------------------------
    def param_spec(self, path: tuple[str, ...], shape: tuple[int, ...]) -> P:
        keys = [getattr(k, "key", getattr(k, "idx", k)) for k in path]
        name = keys[-1] if keys else ""
        stacked = any(k in ("blocks", "super") for k in keys)
        lead = (None,) if stacked else ()
        if self.mode in ("zero1", "ep_dp"):
            body = self._param_spec_dp(name, shape[len(lead):])
        else:
            body = self._param_spec_body(name, shape[len(lead):])
        return P(*(lead + body))

    def _param_spec_dp(self, name: str, s: tuple[int, ...]) -> tuple:
        """DP modes: replicate everything except MoE experts in ep_dp."""
        if (self.mode == "ep_dp" and name in ("w_in", "w_down")
                and len(s) == 3):
            return (self._m(s[0]), None, None)   # experts over 'model'
        return (None,) * len(s)

    def opt_state_spec(self, path, shape) -> P:
        """ZeRO-1: moments/master sharded over as many axes as divide."""
        if self.mode not in ("zero1", "ep_dp"):
            return self.param_spec(path, shape)
        base = list(self.param_spec(path, shape))
        used = {a for a in base if a}
        free = [a for a in self.all_axes if a not in used]
        # shard the largest unsharded dim over the free axes (greedy).
        dims = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in dims:
            if base[i] is not None:
                continue
            take = []
            rem = shape[i]
            for a in free:
                n = self.mesh.shape[a]
                if rem % n == 0:
                    take.append(a)
                    rem //= n
            if take:
                base[i] = tuple(take)
                break
        return P(*base)

    def opt_state_shardings(self, params_shape):
        def spec(path, leaf):
            return NamedSharding(self.mesh,
                                 self.opt_state_spec(path, leaf.shape))
        return jax.tree_util.tree_map_with_path(spec, params_shape)

    def _param_spec_body(self, name: str, s: tuple[int, ...]) -> tuple:
        cfg = self.cfg
        if name == "embed":
            return (self._m(s[0]), None)
        if name == "unembed":
            return (None, self._m(s[1]))
        if name in ("wq", "wk", "wv"):
            return (self._f(s[0]), self._m(s[1]))
        if name == "wo":
            return (self._m(s[0]), self._f(s[1]))
        if name in ("bq", "bk", "bv"):
            return (self._m(s[0]),)
        if name == "w_in" and len(s) == 3:    # MoE experts [E, d, 2f]
            return (self._m(s[0]), self._f(s[1]), None)
        if name == "w_down" and len(s) == 3:  # [E, f, d]
            return (self._m(s[0]), None, self._f(s[2]))
        if name == "w_in":
            return (self._f(s[0]), self._m(s[1]))
        if name == "w_down":
            return (self._m(s[0]), self._f(s[1]))
        if name == "router":
            return (None, None)
        if name == "in_proj":                 # ssm [d, zxbcdt]
            return (self._f(s[0]), self._m(s[1]))
        if name in ("conv_w", "conv_b"):
            return (None,) * (len(s) - 1) + (self._m(s[-1]),)
        if name == "out_proj":
            return (self._m(s[0]), self._f(s[1]))
        if name == "norm_w" and len(s) == 1 and s[0] != cfg.d_model:
            return (self._m(s[0]),)
        if name in ("in_x", "in_y"):          # rglru [d, w]
            return (self._f(s[0]), self._m(s[1]))
        if name in ("gate_a", "gate_x"):      # [w, w]
            return (None, self._m(s[1]))
        if name in ("gate_a_b", "gate_x_b", "lam"):
            return (self._m(s[0]),)
        if name == "out" and len(s) == 2:     # rglru out [w, d]
            return (self._m(s[0]), self._f(s[1]))
        if name == "feat_proj":
            return (None, None)
        # norms, scalars, A_log, D, dt_bias, ln*: replicate
        return (None,) * len(s)

    def param_shardings(self, params_shape):
        """Pytree of NamedShardings matching a params (shape) tree."""
        def spec(path, leaf):
            return NamedSharding(self.mesh,
                                 self.param_spec(path, leaf.shape))
        return jax.tree_util.tree_map_with_path(spec, params_shape)

    # -- batch rules ---------------------------------------------------------
    def _batch_axis(self, B: int):
        """Shard batch over as many (mode-appropriate) axes as divide it."""
        pool = (self.all_axes if self.mode in ("zero1", "ep_dp")
                else self.dp)
        axes = []
        rem = B
        for a in pool:
            n = self.mesh.shape[a]
            if rem % n == 0:
                axes.append(a)
                rem //= n
        return tuple(axes) if axes else None

    def batch_spec(self, batch_shapes: dict) -> dict:
        out = {}
        for k, v in batch_shapes.items():
            B = v.shape[0]
            ba = self._batch_axis(B)
            if k in ("tokens", "labels"):
                seq_m = ("model" if self.mode == "tp_sp"
                         and len(v.shape) > 1
                         and _div(v.shape[1], self.model_n)
                         and v.shape[1] > 1 else None)
                out[k] = P(ba, seq_m) if len(v.shape) == 2 else P(ba)
            elif k == "features":
                seq_m = (self._m(v.shape[1]) if self.mode == "tp_sp"
                         else None)
                out[k] = P(ba, seq_m, None)
            elif k == "patches":
                out[k] = P(ba, None, None)
            else:
                out[k] = P(*([ba] + [None] * (len(v.shape) - 1)))
        return out

    def batch_shardings(self, batch_shapes: dict) -> dict:
        return {k: NamedSharding(self.mesh, s)
                for k, s in self.batch_spec(batch_shapes).items()}

    # -- activation constraint (sequence parallelism) -------------------------
    def act_spec(self, B: int) -> P:
        return P(self._batch_axis(B), "model", None)

    # -- cache rules -----------------------------------------------------------
    def cache_spec(self, path, shape) -> P:
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = keys[-1] if keys else ""
        stacked = len(shape) > 0
        if name in ("k", "v"):
            # [L, B, S, K, hd] (stacked) or [B, S, K, hd]
            lead = (None,) if len(shape) == 5 else ()
            B, S = shape[len(lead)], shape[len(lead) + 1]
            return P(*(lead + (self._batch_axis(B),
                               "model" if _div(S, self.model_n) else None,
                               None, None)))
        if name == "len":
            return P(*((None,) * len(shape)))
        if name == "ssm":
            lead = (None,) if len(shape) == 5 else ()
            B, H = shape[len(lead)], shape[len(lead) + 1]
            return P(*(lead + (self._batch_axis(B), self._m(H), None, None)))
        if name == "conv":
            lead = (None,) if len(shape) == 4 else ()
            B = shape[len(lead)]
            C = shape[-1]
            return P(*(lead + (self._batch_axis(B), None, self._m(C))))
        if name == "h":
            lead = (None,) if len(shape) == 3 else ()
            B, W = shape[len(lead)], shape[len(lead) + 1]
            return P(*(lead + (self._batch_axis(B), self._m(W))))
        return P(*((None,) * len(shape)))

    def cache_shardings(self, cache_shape):
        def spec(path, leaf):
            return NamedSharding(self.mesh, self.cache_spec(path, leaf.shape))
        return jax.tree_util.tree_map_with_path(spec, cache_shape)


# Re-exported ambient context (defined dependency-free in ctx.py).
from repro.parallel.ctx import (  # noqa: E402,F401
    activation_sharding, constrain_activation)
