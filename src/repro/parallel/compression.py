"""Gradient/activation compression for the bandwidth-poor cross-node axis.

``make_pod_compressed_psum``-style transforms plug into the optimizer's
``grad_transform`` hook. Two schemes:

* ``bf16``  — cast gradients to bf16 before the (XLA-inserted) cross-pod
  all-reduce and back; halves pod-link bytes, negligible quality impact.
* ``int8``  — per-tensor scale symmetric int8 with error feedback: the
  quantization residual is carried in an explicit state tree and re-added
  next step, so compression error does not accumulate (1-bit-Adam style).

On the intra-pod axes gradients stay full precision — the hierarchy follows
the bandwidth hierarchy, as the paper's RATR does for EP links.

The same int8 transform compresses the *aggregated inter-node hop* of
two-level hierarchical dispatch (``ScheduleConfig(xnode_compress="int8")``):
``int8_wire_bytes`` is what the cost model prices on the slow link and
``int8_roundtrip_np`` is the numpy model of the payload the executor
delivers (quantized at the leader, dequantized at the destination). These
helpers are numpy-only — the jax dependency stays inside the optimizer-path
functions so the jax-free compile stack (``core/``) can import this module.
"""

from __future__ import annotations

import numpy as np

# Wire overhead of one compressed message: the fp32 scale, padded to a row
# multiple on real transports — 8 bytes models scale + header.
INT8_SCALE_BYTES = 8


def int8_wire_bytes(nbytes: int, dtype_bytes: int = 2) -> int:
    """Bytes on the wire for an int8-compressed message of ``nbytes``
    full-precision payload (one int8 per element + per-message scale)."""
    return nbytes // max(1, dtype_bytes) + INT8_SCALE_BYTES


def int8_roundtrip_np(x: np.ndarray) -> np.ndarray:
    """Symmetric per-message int8 quantize→dequantize (numpy).

    Models what the inter-node hop delivers under ``xnode_compress="int8"``.
    Mirrors ``int8_ef_compress``'s scalar math: per-message max-abs scale,
    round-to-nearest, clip to ±127.
    """
    x32 = x.astype(np.float32)
    amax = float(np.max(np.abs(x32))) if x32.size else 0.0
    scale = max(amax, 1e-12) / 127.0
    q = np.clip(np.round(x32 / scale), -127, 127).astype(np.int8)
    return (q.astype(np.float32) * scale).astype(x.dtype)


def bf16_compress(grads):
    """Round-trip through bf16 (halves cross-pod reduce bytes)."""
    import jax
    import jax.numpy as jnp
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


def int8_ef_init(params):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def int8_ef_compress(grads, error_state):
    """Symmetric per-tensor int8 with error feedback.

    Returns (decompressed grads, new error state). The quantize→dequantize
    round-trip models what crosses the pod link; the residual is carried.
    """
    import jax
    import jax.numpy as jnp

    def q_deq(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return (q.astype(jnp.float32) * scale).astype(g.dtype)

    # Two maps, not one returning tuples (tuple nodes exist in param trees).
    deq = jax.tree.map(q_deq, grads, error_state)
    err = jax.tree.map(
        lambda g, e, d: g.astype(jnp.float32) + e - d.astype(jnp.float32),
        grads, error_state, deq)
    return deq, err
