"""Gradient compression for the bandwidth-poor cross-pod axis.

``make_pod_compressed_psum``-style transforms plug into the optimizer's
``grad_transform`` hook. Two schemes:

* ``bf16``  — cast gradients to bf16 before the (XLA-inserted) cross-pod
  all-reduce and back; halves pod-link bytes, negligible quality impact.
* ``int8``  — per-tensor scale symmetric int8 with error feedback: the
  quantization residual is carried in an explicit state tree and re-added
  next step, so compression error does not accumulate (1-bit-Adam style).

On the intra-pod axes gradients stay full precision — the hierarchy follows
the bandwidth hierarchy, as the paper's RATR does for EP links.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bf16_compress(grads):
    """Round-trip through bf16 (halves cross-pod reduce bytes)."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


def int8_ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def int8_ef_compress(grads, error_state):
    """Symmetric per-tensor int8 with error feedback.

    Returns (decompressed grads, new error state). The quantize→dequantize
    round-trip models what crosses the pod link; the residual is carried.
    """
    def q_deq(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return (q.astype(jnp.float32) * scale).astype(g.dtype)

    # Two maps, not one returning tuples (tuple nodes exist in param trees).
    deq = jax.tree.map(q_deq, grads, error_state)
    err = jax.tree.map(
        lambda g, e, d: g.astype(jnp.float32) + e - d.astype(jnp.float32),
        grads, error_state, deq)
    return deq, err
