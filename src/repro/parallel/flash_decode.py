"""Flash-decoding over a sequence-sharded KV cache (shard_map).

The naive GSPMD lowering of one-token decode against a cache sharded on the
sequence dim turns the cache update (dynamic-update-slice at a runtime
index) into a masked rewrite of the *entire* cache — ~25× the useful HBM
traffic (llama decode_32k baseline: 54 ms/token vs a ~2.3 ms roofline).

This module is the production fix, and it is exactly the paper's recipe
applied to decode: make the communication/compute structure explicit to a
scheduler instead of leaving it to collective inference —

* the cache stays sharded over 'model' in S-blocks; the *owning* shard
  performs a local in-place DUS (a put_mem_signal-style one-sided write);
* each shard computes partial attention over its block (tile task);
* partials merge with the online-softmax combine: a log-sum-exp psum of
  O(B·H) stats — the event-counter-sized synchronization, not data motion.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def make_flash_decode(mesh, axis: str = "model"):
    """Returns impl(q, k_cache, v_cache, new_k, new_v, cache_len)
    → (out [B,1,H,hd], k_cache', v_cache'). Caches sharded P(dp, axis)."""
    n_shards = mesh.shape[axis]
    dp = tuple(a for a in mesh.axis_names if a != axis)

    def impl(q, k_cache, v_cache, new_k, new_v, cache_len):
        B, S, K, hd = k_cache.shape
        H = q.shape[2]
        if S % n_shards:
            return None  # caller falls back to the dense path
        b_ax = dp if B % int(np.prod([mesh.shape[a] for a in dp])) == 0 \
            else None
        cache_spec = P(b_ax, axis, None, None)
        rep_spec = P(b_ax, None, None, None)

        @partial(jax.shard_map, mesh=mesh,
                 in_specs=(rep_spec, cache_spec, cache_spec, rep_spec,
                           rep_spec, P()),
                 out_specs=(rep_spec, cache_spec, cache_spec),
                 check_vma=False)
        def run(q, k_loc, v_loc, new_k, new_v, idx):
            r = jax.lax.axis_index(axis)
            s_loc = k_loc.shape[1]
            owner = idx // s_loc
            local_idx = idx % s_loc

            # One-sided local write: only the owning shard updates its block.
            # (A branchless slice+where+DUS variant was tried and *refuted*:
            # the extra read breaks XLA's in-place aliasing and re-copies the
            # block — see EXPERIMENTS.md §Perf iteration 3.2.)
            def write(c, u):
                return jax.lax.cond(
                    owner == r,
                    lambda a: jax.lax.dynamic_update_slice(
                        a, u, (0, local_idx, 0, 0)),
                    lambda a: a, c)

            k_loc = write(k_loc, new_k)
            v_loc = write(v_loc, new_v)

            # Partial attention over the local block (fp32 stats).
            g = H // K
            qg = q.reshape(q.shape[0], 1, K, g, hd)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                           k_loc).astype(jnp.float32)
            s = s * (1.0 / np.sqrt(hd))
            pos = r * s_loc + jnp.arange(s_loc)
            mask = pos <= idx                    # causal incl. new token
            s = jnp.where(mask[None, None, None, None, :], s, -1e30)
            m_loc = jnp.max(s, axis=-1)                       # [b,K,g,1]
            p = jnp.exp(s - m_loc[..., None])
            l_loc = jnp.sum(p, axis=-1)
            o_loc = jnp.einsum("bkgqs,bskd->bqkgd",
                               p.astype(v_loc.dtype), v_loc)

            # LSE combine across shards — O(B·H) stats, not data.
            m_glob = jax.lax.pmax(m_loc, axis)
            corr = jnp.exp(m_loc - m_glob)
            l_glob = jax.lax.psum(l_loc * corr, axis)
            o_glob = jax.lax.psum(
                o_loc * corr[..., None].transpose(0, 3, 1, 2, 4)
                .astype(o_loc.dtype), axis)
            out = o_glob / jnp.maximum(
                l_glob[..., None].transpose(0, 3, 1, 2, 4), 1e-30
            ).astype(o_glob.dtype)
            return (out.reshape(q.shape[0], 1, H, hd),
                    k_loc, v_loc)

        return run(q, k_cache, v_cache, new_k, new_v,
                   jnp.asarray(cache_len, jnp.int32))

    return impl
