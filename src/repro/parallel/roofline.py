"""Roofline-term extraction from compiled XLA artifacts.

Per the grading spec (TPU v5e targets):

    compute   = HLO_FLOPs            / (chips × 197 TFLOP/s)
    memory    = HLO_bytes_accessed   / (chips × 819 GB/s)
    collective= collective_op_bytes  / (chips × 50 GB/s/link)

``compiled.cost_analysis()`` is per-device after SPMD partitioning (verified
empirically), so the per-chip terms divide by one chip's peak directly.
Collective bytes are parsed from the optimized HLO text — result-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (sync or async-start form), which is the per-device
operand/result traffic the spec asks to sum. A ring-model "wire bytes"
estimate is reported alongside for interpretation.
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.hardware import V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def wire_bytes(self, n_shards: dict | None = None) -> float:
        """Ring-model per-device wire traffic estimate."""
        out = 0.0
        for kind, b in self.bytes_by_kind.items():
            if kind == "all-reduce":
                out += 2.0 * b          # reduce-scatter + all-gather phases
            else:
                out += float(b)
        return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    by_kind: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0) + b
    return CollectiveStats(counts=counts, bytes_by_kind=by_kind)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    model_flops_global: float
    arg_bytes: float
    temp_bytes: float
    coll_counts: dict
    # Minimal achievable HBM traffic (params + caches + optimizer state for
    # train), global across chips — the memory-side "useful work" analogue
    # of 6ND. Dominant for decode where flops are negligible.
    model_bytes_global: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / V5E.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / V5E.hbm_gbps

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / V5E.ici_link_gbps

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / max(1.0, hlo_global)

    @property
    def useful_bytes_ratio(self) -> float:
        hlo_global = self.bytes_per_device * self.chips
        return self.model_bytes_global / max(1.0, hlo_global)

    @property
    def roofline_frac(self) -> float:
        """max(useful-compute, useful-bandwidth) time / dominant bound:
        how close the step is to the best achievable on either roofline.
        The compute side dominates for train/prefill; the bandwidth side is
        the meaningful one for decode (flops are negligible there)."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful_c = (self.model_flops_global / self.chips
                      / V5E.peak_flops_bf16)
        t_useful_m = (self.model_bytes_global / self.chips / V5E.hbm_gbps)
        return max(t_useful_c, t_useful_m) / max(bound, 1e-12)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_global,
            "hlo_flops_per_dev": self.flops_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "useful_bytes_ratio": self.useful_bytes_ratio,
            "roofline_frac": self.roofline_frac,
            "hbm_args_gb": self.arg_bytes / 2**30,
            "hbm_temp_gb": self.temp_bytes / 2**30,
            "collectives": self.coll_counts,
        }


def extract(arch, shape, mesh_name, chips, compiled, model_flops,
            model_bytes: float = 0.0) -> Roofline:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    colls = parse_collectives(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        collective_bytes=float(colls.total_bytes),
        model_flops_global=float(model_flops),
        model_bytes_global=float(model_bytes),
        arg_bytes=float(getattr(ma, "argument_size_in_bytes", 0)),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0)),
        coll_counts=colls.counts,
    )
