"""Ambient activation-sharding context — dependency-free so both the model
code and the sharding rules can import it without cycles."""

from __future__ import annotations

import contextlib
import threading

import jax

_CTX = threading.local()


@contextlib.contextmanager
def activation_sharding(spec):
    """Set the residual-stream PartitionSpec for traces under this context."""
    prev = getattr(_CTX, "spec", None)
    _CTX.spec = spec
    try:
        yield
    finally:
        _CTX.spec = prev


def constrain_activation(x):
    spec = getattr(_CTX, "spec", None)
    if spec is None:
        return x
    if x.ndim != len(spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@contextlib.contextmanager
def head_sharding(spec):
    """PartitionSpec for [B, S, H, hd] attention tensors (TP over heads)."""
    prev = getattr(_CTX, "head_spec", None)
    _CTX.head_spec = spec
    try:
        yield
    finally:
        _CTX.head_spec = prev


def constrain_heads(x, n_heads_axis=2):
    spec = getattr(_CTX, "head_spec", None)
    if spec is None or x.ndim != len(spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@contextlib.contextmanager
def flash_decode_context(impl):
    """Ambient sharded one-token-decode attention override."""
    prev = getattr(_CTX, "flash_decode", None)
    _CTX.flash_decode = impl
    try:
        yield
    finally:
        _CTX.flash_decode = prev


def current_flash_decode():
    return getattr(_CTX, "flash_decode", None)


@contextlib.contextmanager
def moe_impl_context(impl):
    """Ambient MoE execution override (EP path injection, same pattern)."""
    prev = getattr(_CTX, "moe_impl", None)
    _CTX.moe_impl = impl
    try:
        yield
    finally:
        _CTX.moe_impl = prev


def current_moe_impl():
    return getattr(_CTX, "moe_impl", None)
