"""Schedule-pipeline sweep + selector accuracy report (jax-free).

The hillclimb driver's ``--sched-sweep`` lived inline in
``launch/hillclimb.py``; it moved here so the tier-1 regression gate
(``tests/test_autoselect.py``) can run fixture-sized sweeps without
importing jax or mutating ``XLA_FLAGS`` (hillclimb forces a 512-device host
platform at import, which would leak into every later test in the process).
``launch/hillclimb.py`` re-exports everything, so the CLI is unchanged:

    PYTHONPATH=src python -m repro.launch.hillclimb --sched-sweep [--ep 8]
    PYTHONPATH=src python -m repro.launch.hillclimb --sched-sweep \
        --selector-report

Two entry points:

* :func:`sched_sweep` — the hypothesis → change → measure table: every
  ``SCHED_PIPELINES`` entry (the canonical registry now lives in
  ``core/passes.py``) plus an ``auto`` row (the cost-model-guided selector,
  ``core/autoselect.py``) × routing scenarios × directions, through the
  discrete-event simulator. The ``auto`` row records what the selector
  resolved to (``resolved``/``resolved_m_split``) and its compile-time
  prediction (``predicted_us``) next to the simulated makespan.
* :func:`selector_report` — the selector's accuracy table: per scenario it
  simulates *every* candidate the selector priced and reports predicted vs
  simulated makespan plus whether the selector's argmin matched the
  simulator's.
"""

from __future__ import annotations

import json

from repro.core.autoselect import select
from repro.core.odg import (ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.passes import SCHED_PIPELINES
from repro.core.routing import hotspot_plan, skewed_plan
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_unified

_BUILDERS = {"forward": build_moe_ffn_forward,
             "backward": build_moe_ffn_backward}


def sweep_scenarios(ep: int, e_loc: int, rows: int):
    """The routing-scenario matrix: (name, plan-or-None) pairs."""
    # Background traffic must fit each source's token budget at any --ep.
    bg = max(0, min(16, ep * e_loc * rows // (ep * e_loc - 1) - (ep - 1)))
    return [
        ("balanced", None),
        ("skewed", skewed_plan(ep, e_loc, rows, 1.0)),
        ("hotspot", hotspot_plan(ep, e_loc, rows)),
        ("hotspot_bg", hotspot_plan(ep, e_loc, rows, background=bg)),
    ]


def _scenario_cfg(plan, ep: int, e_loc: int, rows: int, d_model: int,
                  d_ff: int, gmm_m_split: int) -> ScheduleConfig:
    return ScheduleConfig(ep=ep, e_loc=e_loc, rows=rows, d_model=d_model,
                          d_ff=d_ff, gmm_m_split=gmm_m_split,
                          gmm_split_mode="source_aligned", plan=plan)


def sched_sweep(ep: int = 8, out: str | None = None, *, e_loc: int = 8,
                rows: int = 128, d_model: int = 2048, d_ff: int = 512,
                gmm_m_split: int | None = None, include_auto: bool = True,
                quiet: bool = False) -> list[dict]:
    """Hillclimb over schedule pass pipelines on skewed routing scenarios.

    Sizing keywords exist so the tier-1 regression gate can run a
    fixture-sized sweep in seconds; the CLI default reproduces the full
    ep=8 table. Returns one row dict per (scenario, direction, pipeline),
    with an extra ``auto`` row per (scenario, direction) when
    ``include_auto`` — ``vs_naive`` > 1 means faster than naive.
    """
    m_split = gmm_m_split if gmm_m_split is not None else 8 * ep
    rows_out: list[dict] = []
    for plan_name, plan in sweep_scenarios(ep, e_loc, rows):
        cfg = _scenario_cfg(plan, ep, e_loc, rows, d_model, d_ff, m_split)
        for direction, builder in _BUILDERS.items():
            base_us = None
            fixed_res: dict[str, object] = {}
            entries = list(SCHED_PIPELINES.items())
            if include_auto:
                entries.append(("auto", "auto"))
            for tag, pipeline in entries:
                row = {"plan": plan_name, "direction": direction,
                       "pipeline": tag}
                if tag == "auto":
                    choice = select(cfg.routing, cfg, direction=direction)
                    row.update(resolved=choice.tag,
                               resolved_spec=choice.pipeline.spec(),
                               resolved_m_split=choice.cfg.gmm_m_split,
                               predicted_us=choice.predicted_us)
                    if choice.cfg == cfg and choice.tag in fixed_res:
                        # Un-retiled resolution to a fixed entry: the
                        # schedule is byte-identical to one already
                        # measured — skip the duplicate ~1s compile+sim.
                        res = fixed_res[choice.tag]
                    else:
                        res = simulate_unified(compile_schedule(
                            _BUILDERS[direction](choice.cfg),
                            pipeline=choice.pipeline))
                else:
                    res = simulate_unified(
                        compile_schedule(builder(cfg), pipeline=pipeline))
                    fixed_res[tag] = res
                if base_us is None:
                    base_us = res.makespan_us
                row.update(makespan_us=res.makespan_us,
                           vs_naive=base_us / res.makespan_us,
                           straggler=res.straggler_ratio,
                           mac_ratio=res.mac_ratio)
                rows_out.append(row)
                if not quiet:
                    extra = (f" ← {row['resolved']}" if tag == "auto" else "")
                    print(f"[sched {plan_name}/{direction}] {tag:12s} "
                          f"makespan={res.makespan_us:9.1f}us "
                          f"x{row['vs_naive']:.3f} vs naive "
                          f"straggler={res.straggler_ratio:.2f} "
                          f"mac={res.mac_ratio:.3f}{extra}")
    if out:
        with open(out, "w") as f:
            json.dump(rows_out, f, indent=1)
    return rows_out


def selector_report(ep: int = 8, out: str | None = None, *, e_loc: int = 8,
                    rows: int = 128, d_model: int = 2048, d_ff: int = 512,
                    gmm_m_split: int | None = None,
                    report_out: str | None = None,
                    quiet: bool = False) -> list[dict]:
    """Predicted-vs-simulated makespan for every candidate the selector
    priced — the selector's accuracy table.

    Absolute predictions are structural lower bounds (queue/startup
    chaining is not modeled), so the interesting columns are the per-
    scenario *ordering*: ``picked`` flags the selector's argmin,
    ``sim_best`` the simulator's, and ``regret`` what the pick costs
    relative to the simulated optimum over the priced candidates.

    ``report_out`` appends-nothing/overwrites a JSONL file — one
    predicted-vs-simulated row per line, each stamped with the sweep's
    sizing — the accumulating dataset the ROADMAP "selector calibration"
    item fits the pass-effect constants from (``out`` remains the
    one-shot JSON dump).
    """
    m_split = gmm_m_split if gmm_m_split is not None else 8 * ep
    sizing = {"ep": ep, "e_loc": e_loc, "rows": rows, "d_model": d_model,
              "d_ff": d_ff, "gmm_m_split": m_split}
    rows_out: list[dict] = []
    for plan_name, plan in sweep_scenarios(ep, e_loc, rows):
        cfg = _scenario_cfg(plan, ep, e_loc, rows, d_model, d_ff, m_split)
        for direction in _BUILDERS:
            choice = select(cfg.routing, cfg, direction=direction)
            sims = {}
            for cand in choice.scores:
                sched = compile_schedule(_BUILDERS[direction](cand.cfg),
                                         pipeline=cand.pipeline)
                sims[cand.tag] = simulate_unified(sched).makespan_us
            sim_best = min(sims, key=sims.get)
            for cand in choice.scores:
                picked = cand.tag == choice.tag
                rows_out.append({
                    "plan": plan_name, "direction": direction,
                    "candidate": cand.tag,
                    "pipeline": cand.pipeline.spec(),
                    "cand_m_split": cand.cfg.gmm_m_split,
                    "predicted_us": cand.predicted_us,
                    "simulated_us": sims[cand.tag],
                    "picked": picked,
                    "sim_best": cand.tag == sim_best,
                    "regret": (sims[choice.tag] / sims[sim_best] - 1.0
                               if picked else None),
                    **sizing,
                })
                if not quiet:
                    mark = ("←pick" if picked else "") + \
                           ("*best" if cand.tag == sim_best else "")
                    print(f"[selector {plan_name}/{direction}] "
                          f"{cand.tag:16s} predicted={cand.predicted_us:8.1f}"
                          f"us simulated={sims[cand.tag]:8.1f}us {mark}")
            if not quiet:
                regret = sims[choice.tag] / sims[sim_best] - 1.0
                print(f"[selector {plan_name}/{direction}] regret of pick: "
                      f"{regret:+.2%}")
    if out:
        with open(out, "w") as f:
            json.dump(rows_out, f, indent=1)
    if report_out:
        with open(report_out, "w") as f:
            for row in rows_out:
                f.write(json.dumps(row) + "\n")
    return rows_out


def main(argv=None):
    """Jax-free CLI twin of ``repro.launch.hillclimb --sched-sweep``."""
    import argparse
    ap = argparse.ArgumentParser(
        description="schedule-pipeline sweep / selector accuracy report "
                    "(no jax import, no forced XLA platform)")
    ap.add_argument("--sched-sweep", action="store_true",
                    help="run the SCHED_PIPELINES (+auto) sweep table")
    ap.add_argument("--selector-report", action="store_true",
                    help="dump predicted-vs-simulated makespan for every "
                         "candidate the selector priced")
    ap.add_argument("--ep", type=int, default=8)
    ap.add_argument("--out", default=None,
                    help="write the table as one JSON document")
    ap.add_argument("--report-out", default=None, metavar="PATH",
                    help="with --selector-report: write one predicted-vs-"
                         "simulated row per line as JSONL (the selector-"
                         "calibration dataset)")
    args = ap.parse_args(argv)
    if args.report_out and not args.selector_report:
        ap.error("--report-out requires --selector-report")
    if args.selector_report:
        selector_report(ep=args.ep, out=args.out,
                        report_out=args.report_out)
    elif args.sched_sweep:
        sched_sweep(ep=args.ep, out=args.out)
    else:
        ap.error("nothing to do: pass --sched-sweep or --selector-report")


if __name__ == "__main__":
    main()
