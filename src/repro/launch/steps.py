"""jit-able train / prefill / decode steps wired to sharding rules + EP.

``make_steps(cfg, mesh, …)`` returns closures whose in/out shardings come
from ``ShardingRules``; the MoE EP path and the sequence-parallel activation
constraint are installed via the ambient contexts at *trace* time, keeping
the model code mesh-agnostic (the paper's low-intrusion integration).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.optim import adamw
from repro.parallel.ctx import (activation_sharding, flash_decode_context,
                                head_sharding, moe_impl_context)
from repro.parallel.ep import EPConfig, make_moe_ep
from repro.parallel.sharding import ShardingRules


@dataclasses.dataclass
class StepFns:
    train_step: object
    prefill_step: object
    decode_step: object
    rules: ShardingRules
    ep_cfg: Optional[EPConfig]
    # Set when the dropless data-dependent path is active: holds the
    # process-level SSC cache handle (``dropless.cache.info()`` /
    # ``step_stats()`` for recompile-rate monitoring).
    dropless: Optional[object] = None


def make_steps(cfg, mesh, *, opt: Optional[adamw.OptConfig] = None,
               ep: Optional[EPConfig] = None,
               seq_parallel: bool = True,
               accum_steps: int = 0,
               fsdp: Optional[bool] = None,
               mode: str = "tp_sp",
               dropless=None,
               grad_transform=None) -> StepFns:
    """Build the jit-able step closures.

    ``dropless``: a :class:`repro.launch.dropless.DroplessConfig` switches
    the *training* MoE path from fixed-capacity execution to dropless,
    data-dependent schedule compilation — each batch's actual router output
    becomes a RoutingPlan whose (shape-bucketed) schedule is fetched from the
    process-level SSC cache and executed plan-sized. Serving steps keep the
    fixed-capacity/EP implementation (static shapes for decode).
    """
    rules = ShardingRules(cfg, mesh, fsdp=fsdp, mode=mode)
    if mode == "ep_dp" and ep is not None:
        ep = dataclasses.replace(ep, dp_batch=True)
    moe_impl = (make_moe_ep(mesh, ep, cfg.act)
                if (ep is not None and cfg.family == "moe") else None)
    dropless_moe = None
    if dropless is not None and cfg.family == "moe":
        from repro.launch.dropless import make_moe_dropless
        dropless_moe = make_moe_dropless(cfg, dropless)
    train_moe_impl = dropless_moe.impl if dropless_moe else moe_impl
    opt = opt or adamw.OptConfig()
    if accum_steps == 0:
        # Default policy: microbatch the big archs so train activations fit
        # HBM (grad accumulation is the standard production lever here).
        n_params = cfg.param_count()
        accum_steps = 8 if n_params > 100e9 else (4 if n_params > 10e9 else 1)

    import contextlib

    def _ctx(B, S):
        if rules.mode != "tp_sp":
            return contextlib.ExitStack()   # DP modes: no SP/TP constraints
        sp = (rules.act_spec(B) if seq_parallel and S > 1
              and S % rules.model_n == 0 else None)
        hs = None
        if cfg.n_heads and cfg.n_heads % rules.model_n == 0 and S > 1:
            hs = P(rules._batch_axis(B), None, "model", None)
        stack = contextlib.ExitStack()
        stack.enter_context(activation_sharding(sp))
        stack.enter_context(head_sharding(hs))
        return stack

    # ---- training ----------------------------------------------------------
    def train_step(params, opt_state, batch):
        B, S = batch["labels"].shape

        def loss_of(p, b):
            with _ctx(b["labels"].shape[0], S), \
                    moe_impl_context(train_moe_impl):
                return M.loss_fn(cfg, p, b)

        if accum_steps > 1 and B % accum_steps == 0:
            mb = jax.tree.map(
                lambda a: a.reshape((accum_steps, B // accum_steps)
                                    + a.shape[1:]), batch)
            lv, grads = adamw.accumulate_grads(
                lambda p, b: jax.value_and_grad(loss_of)(p, b), params, mb)
        else:
            lv, grads = jax.value_and_grad(loss_of)(params, batch)
        # Pin gradient shardings to the parameter shardings so the
        # backward-scan accumulators don't materialize unsharded (matters
        # for FSDP expert weights: 21 GB/device without this).
        grads = jax.tree_util.tree_map_with_path(
            lambda path, g: jax.lax.with_sharding_constraint(
                g, rules.param_spec(path, g.shape)), grads)
        params2, opt_state2, metrics = adamw.apply_updates(
            params, grads, opt_state, opt, grad_transform=grad_transform)
        metrics["loss"] = lv
        # Surface per-step SSC cache deltas (recompiles this step, hit
        # count, occupancy). Host-side counters only exist eagerly; under
        # jit read ``fns.dropless.cache.info()`` from the training loop.
        if dropless_moe is not None and not isinstance(lv, jax.core.Tracer):
            for k, v in dropless_moe.step_stats().items():
                metrics[f"ssc_{k}"] = v
        return params2, opt_state2, metrics

    # ---- serving -----------------------------------------------------------
    def prefill_step(params, batch, max_len: int):
        tokens = batch.get("tokens", batch.get("features"))
        B, S = tokens.shape[0], tokens.shape[1]
        with _ctx(B, S), moe_impl_context(moe_impl):
            if cfg.family == "audio":
                return M.forward(cfg, params, batch), None
            return M.prefill(cfg, params, batch, max_len)

    # Flash-decoding: sharded one-token attention for seq-sharded caches.
    fd_impl = None
    if rules.model_n > 1 and cfg.n_heads:
        from repro.parallel.flash_decode import make_flash_decode
        fd_impl = make_flash_decode(mesh, "model")

    def decode_step(params, token, cache):
        with moe_impl_context(moe_impl), flash_decode_context(fd_impl):
            return M.decode_step(cfg, params, token, cache)

    return StepFns(train_step=train_step, prefill_step=prefill_step,
                   decode_step=decode_step, rules=rules, ep_cfg=ep,
                   dropless=dropless_moe)


# ---------------------------------------------------------------------------
# Sharding-annotated jit wrappers (used by the launcher and the dry-run).
# ---------------------------------------------------------------------------


def jit_train_step(fns: StepFns, params_shape, batch_shapes):
    rules = fns.rules
    ps = rules.param_shardings(params_shape)
    # ZeRO-1 modes shard the optimizer state even where params replicate.
    oss = rules.opt_state_shardings(params_shape)         if hasattr(rules, "opt_state_shardings") else ps
    os_ = {"m": oss, "v": oss, "master": oss,
           "step": NamedSharding(rules.mesh, P())}
    bs = rules.batch_shardings(batch_shapes)
    return jax.jit(
        fns.train_step,
        in_shardings=(ps, os_, bs),
        out_shardings=(ps, os_, None),
        donate_argnums=(0, 1))


def jit_prefill_step(fns: StepFns, params_shape, batch_shapes,
                     max_len: int):
    rules = fns.rules
    ps = rules.param_shardings(params_shape)
    bs = rules.batch_shardings(batch_shapes)
    return jax.jit(partial(fns.prefill_step, max_len=max_len),
                   in_shardings=(ps, bs), out_shardings=None)


def jit_decode_step(fns: StepFns, params_shape, token_shape, cache_shape):
    rules = fns.rules
    ps = rules.param_shardings(params_shape)
    ts = NamedSharding(rules.mesh,
                       rules.batch_spec({"tokens": token_shape})["tokens"])
    cs = rules.cache_shardings(cache_shape)
    return jax.jit(fns.decode_step,
                   in_shardings=(ps, ts, cs),
                   out_shardings=(None, cs),
                   donate_argnums=(2,))
