"""Production mesh construction.

``make_production_mesh`` is a *function* (not a module-level constant) so
importing this module never touches JAX device state — required because the
dry-run pins ``xla_force_host_platform_device_count`` before first init.
"""

from __future__ import annotations

import jax


def _axis_types_kw(n: int) -> dict:
    """axis_types kwarg where supported (jax ≥ 0.5); empty dict otherwise."""
    at = getattr(jax.sharding, "AxisType", None)
    return {} if at is None else {"axis_types": (at.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for multi-device CPU tests (needs forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kw(2))


def mesh_context(mesh):
    """Context manager that makes ``mesh`` ambient for PartitionSpec-based
    ``with_sharding_constraint`` calls: ``jax.set_mesh`` on jax ≥ 0.5,
    falling back to the ``Mesh`` object itself (a context manager) on 0.4.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def dp_axes(mesh) -> tuple[str, ...]:
    """The pure data-parallel axes of a mesh (pod is outer DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
