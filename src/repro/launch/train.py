"""Production training launcher: mesh + sharded steps + data + FT loop.

On a TPU pod this is the entrypoint a scheduler (re)starts on every host;
on this CPU container it runs the same code path end-to-end on a small
forced-host mesh (that is what --force-devices does), exercising sharded
data feeding, EP execution, ZeRO-1 state, checkpoint/restart, and the
straggler watchdog.

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-moe-3b-a800m --smoke --force-devices 8 \
        --mesh 2x4 --mode ep_dp --steps 20
"""

from __future__ import annotations

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--mesh", default="2x4",
                    help="dataxmodel (or podxdataxmodel)")
    ap.add_argument("--mode", default="tp_sp",
                    choices=["tp_sp", "zero1", "ep_dp"])
    ap.add_argument("--ep-mode", default="hyperparallel",
                    choices=["hyperparallel", "baseline"])
    ap.add_argument("--dropless", action="store_true",
                    help="compile/reuse schedules from each batch's actual "
                         "router output (capacity=None) instead of running "
                         "the fixed-capacity path")
    ap.add_argument("--dropless-ep", type=int, default=0,
                    help="EP group size of the compiled dropless fragment "
                         "(0 = the mesh's model-axis size)")
    ap.add_argument("--dropless-bucket", default="16", metavar="SPEC",
                    help="shape-bucket policy for plan row counts: a "
                         "linear bucket size int ('16'; '1' = exact plans, "
                         "recompile on every routing change), "
                         "'geometric:B[xG]' (power-of-G rungs from base "
                         "B), or 'ladder:E1,E2,...' (explicit rungs, e.g. "
                         "fitted by repro.launch.replay); see "
                         "repro.core.buckets.BucketSpec")
    ap.add_argument("--sched", default=None, metavar="PIPELINE",
                    help="schedule-pass pipeline for the dropless path: "
                         "'auto' (cost-model-guided selection per batch "
                         "plan), a named core.passes.SCHED_PIPELINES entry "
                         "(e.g. 'ratr+crit'), or a comma-separated pass "
                         "list; default keeps the DroplessConfig default")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--force-devices", type=int, default=0,
                    help="force N host devices (CPU testing only)")
    args = ap.parse_args(argv)

    if args.force_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.force_devices}")

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import DataConfig, SyntheticStream
    from repro.ft.runner import FTConfig, train_loop
    from repro.launch import steps as St
    from repro.models import model as M
    from repro.optim import adamw
    from repro.parallel.ep import EPConfig

    from repro.core.passes import pipeline_arg as resolve_sched_arg
    from repro.launch.mesh import _axis_types_kw, mesh_context

    dims = [int(x) for x in args.mesh.split("x")]
    names = (("pod", "data", "model") if len(dims) == 3
             else ("data", "model"))
    mesh = jax.make_mesh(tuple(dims), names, **_axis_types_kw(len(dims)))

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "moe":
        # Pad experts so E % model-axis == 0 (router never selects padding).
        import dataclasses
        model_n = mesh.shape.get("model", 1)
        e_tot = cfg.moe.e_total
        extra = (-e_tot) % model_n
        if extra:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe,
                n_padding_experts=cfg.moe.n_padding_experts + extra))
    oc = adamw.OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                         total_steps=args.steps)
    ep = (EPConfig(mode=args.ep_mode, capacity_factor=4.0)
          if cfg.family == "moe" else None)
    sched_pipeline = None
    if args.sched is not None:
        # Validate eagerly: an unknown pass name must fail fast, and a
        # --sched that cannot take effect must say so instead of silently
        # training with defaults.
        try:
            sched_pipeline = resolve_sched_arg(args.sched)
        except KeyError as e:
            ap.error(str(e))
        if not args.dropless:
            ap.error("--sched only applies to the dropless scheduling path; "
                     "add --dropless")
        if cfg.family != "moe":
            ap.error(f"--sched requires a MoE arch (got {args.arch!r}: "
                     f"family={cfg.family!r})")
    dropless = None
    if args.dropless and cfg.family == "moe":
        from repro.core.buckets import BucketSpec
        from repro.launch.dropless import DroplessConfig
        try:
            bucket = BucketSpec.parse(args.dropless_bucket)
        except ValueError as e:
            ap.error(str(e))
        kw = {}
        if sched_pipeline is not None:
            kw["pipeline"] = sched_pipeline
        dropless = DroplessConfig(
            ep=args.dropless_ep or mesh.shape.get("model", 1),
            bucket=bucket, **kw)
        print(f"dropless shape buckets: {bucket}")
        if sched_pipeline is not None:
            print(f"dropless schedule pipeline: {dropless.pipeline!r}")
    fns = St.make_steps(cfg, mesh, opt=oc, ep=ep, mode=args.mode,
                        dropless=dropless)

    params = adamw.cast_params(M.init_params(cfg, jax.random.PRNGKey(0)),
                               cfg.compute_dtype)
    opt_state = adamw.init_opt_state(params)
    params_shape = jax.eval_shape(lambda: params)
    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct(
            (args.global_batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct(
            (args.global_batch, args.seq), jnp.int32)}
    with mesh_context(mesh):
        step = St.jit_train_step(fns, params_shape, batch_shapes)
        ps = fns.rules.param_shardings(params_shape)
        oss = fns.rules.opt_state_shardings(params_shape)
        params = jax.device_put(params, ps)
        opt_state = {
            "m": jax.device_put(opt_state["m"], oss),
            "v": jax.device_put(opt_state["v"], oss),
            "master": jax.device_put(opt_state["master"], oss),
            "step": jax.device_put(
                opt_state["step"],
                jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))}

        stream = SyntheticStream(DataConfig(
            vocab=cfg.vocab, seq_len=args.seq,
            global_batch=args.global_batch))

        class _Stream:
            def sharded_batch(self, s, mesh_, sharding):
                return stream.sharded_batch(
                    s, mesh, fns.rules.batch_shardings(batch_shapes))

        run = train_loop(
            step_fn=step, params=params, opt_state=opt_state,
            stream=_Stream(), mesh=mesh, batch_sharding=None,
            n_steps=args.steps,
            ft=FTConfig(ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every), log_every=5)

    if run.resumed_from is not None:
        print(f"resumed from step {run.resumed_from}")
    for m in run.metrics_log:
        print(f"step {m['step']:4d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f} {m['step_time_s']*1e3:.0f}ms")
    if run.stragglers:
        print("stragglers:", run.stragglers)
    if fns.dropless is not None:
        info = fns.dropless.cache.info()
        total = max(1, info["hits"] + info["misses"])
        print(f"dropless SSC cache: {info['entries']} entries "
              f"({info['bytes'] / 1024:.0f} KiB), "
              f"hit rate {info['hits'] / total:.1%} "
              f"({info['misses']} compiles, {info['evictions']} evictions)")
    return run


if __name__ == "__main__":
    main()
