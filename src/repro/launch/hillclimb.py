import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower a cell under different optimization
configurations and report the three roofline terms for each step of the
hypothesis → change → measure loop. Results feed EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell granite_train
"""

import argparse      # noqa: E402
import json          # noqa: E402

import jax           # noqa: E402

from repro.configs import get_config                       # noqa: E402
from repro.configs.shapes import SHAPES, input_specs       # noqa: E402
from repro.launch import steps as St                       # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.dryrun import (_costs_of, _trips,        # noqa: E402
                                 _with_trips, model_bytes, model_flops)
from repro.models import model as M                        # noqa: E402
from repro.optim import adamw                              # noqa: E402
from repro.parallel import roofline as R                   # noqa: E402
from repro.parallel.ep import EPConfig                     # noqa: E402


def compile_variant(cfg, shape_name, mesh, *, mode="tp_sp",
                    ep_mode="hyperparallel", accum=None, fsdp=None,
                    seq_parallel=True, policy_cfg=None, cap_factor=1.25):
    policy = policy_cfg or cfg
    sp = SHAPES[shape_name]
    ep = (EPConfig(mode=ep_mode, capacity_factor=cap_factor)
          if cfg.family == "moe" else None)
    n_params = policy.param_count()
    if accum is None:
        accum = 1 if policy_cfg is not None else (
            8 if n_params > 100e9 else (4 if n_params > 10e9 else 1))
    if fsdp is None:
        fsdp = n_params > 10e9
    fns = St.make_steps(cfg, mesh, ep=ep, seq_parallel=seq_parallel,
                        accum_steps=accum, fsdp=fsdp, mode=mode)
    params_shape = jax.eval_shape(
        lambda: adamw.cast_params(M.init_params(cfg, jax.random.PRNGKey(0)),
                                  cfg.compute_dtype))
    batch = input_specs(cfg, shape_name)
    with jax.set_mesh(mesh):
        if sp.kind == "train":
            opt_shape = jax.eval_shape(adamw.init_opt_state, params_shape)
            step = St.jit_train_step(fns, params_shape, batch)
            return step.lower(params_shape, opt_shape, batch).compile()
        if sp.kind == "prefill":
            step = St.jit_prefill_step(fns, params_shape, batch, sp.seq_len)
            return step.lower(params_shape, batch).compile()
        cache_shape = jax.eval_shape(
            lambda: M.init_cache(cfg, sp.global_batch, sp.seq_len))
        step = St.jit_decode_step(fns, params_shape, batch["tokens"],
                                  cache_shape)
        return step.lower(params_shape, batch["tokens"],
                          cache_shape).compile()


def measure(arch, shape_name, tag, **kw):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=False)
    compiled = compile_variant(cfg, shape_name, mesh, **kw)
    # extrapolate scan-body costs exactly like the dry-run
    c2 = compile_variant(_with_trips(cfg, 2), shape_name, mesh,
                         policy_cfg=cfg, **kw)
    c3 = compile_variant(_with_trips(cfg, 3), shape_name, mesh,
                         policy_cfg=cfg, **kw)
    v2, v3 = _costs_of(c2), _costs_of(c3)
    trips = _trips(cfg)
    fl, by, cb = (v2[i] + (v3[i] - v2[i]) * (trips - 2) for i in range(3))
    rf = R.extract(arch, shape_name, "16x16", 256, compiled,
                   model_flops(cfg, shape_name),
                   model_bytes(cfg, shape_name))
    rf.flops_per_device, rf.bytes_per_device, rf.collective_bytes = fl, by, cb
    ma = compiled.memory_analysis()
    row = rf.row()
    row.update(tag=tag, args_gb=ma.argument_size_in_bytes / 2**30,
               temp_gb=ma.temp_size_in_bytes / 2**30)
    print(f"[{tag}] compute={rf.t_compute*1e3:8.1f}ms "
          f"memory={rf.t_memory*1e3:8.1f}ms "
          f"collective={rf.t_collective*1e3:8.1f}ms "
          f"→ {rf.bottleneck}-bound frac={rf.roofline_frac:.3f} "
          f"(args={row['args_gb']:.1f}G temp={row['temp_gb']:.1f}G)")
    return row


CELLS = {
    "granite_train": ("granite-moe-3b-a800m", "train_4k"),
    "hubert_train": ("hubert-xlarge", "train_4k"),
    "llama_decode": ("llama3.2-3b", "decode_32k"),
}

# The schedule-level variant space (named pass pipelines) and the sweep /
# selector-report implementations live jax-free in core/passes.py and
# launch/schedsweep.py; re-exported here for back-compat — any newly
# registered pass joins sweep, selector and docs by adding one
# core.passes.SCHED_PIPELINES entry.
from repro.core.passes import SCHED_PIPELINES                   # noqa: E402,F401
from repro.launch.schedsweep import (sched_sweep,               # noqa: E402,F401
                                     selector_report)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS))
    ap.add_argument("--variants", default="baseline,opt")
    ap.add_argument("--sched-sweep", action="store_true",
                    help="sweep SCHED_PIPELINES (+ the auto selector) "
                         "through the simulator instead of lowering a "
                         "jax cell")
    ap.add_argument("--selector-report", action="store_true",
                    help="with --sched-sweep: dump the selector accuracy "
                         "table (predicted vs simulated makespan for every "
                         "priced candidate) instead of the pipeline table")
    ap.add_argument("--ep", type=int, default=8)
    ap.add_argument("--out", default=None)
    ap.add_argument("--report-out", default=None, metavar="PATH",
                    help="with --selector-report: write predicted-vs-"
                         "simulated rows as JSONL (selector-calibration "
                         "dataset)")
    args = ap.parse_args()
    if args.sched_sweep or args.selector_report or args.report_out:
        # One sweep CLI surface: delegate flags and cross-flag validation
        # to the jax-free twin so the two entrypoints cannot diverge.
        from repro.launch.schedsweep import main as sweep_main
        argv = ["--ep", str(args.ep)]
        argv += ["--selector-report"] if args.selector_report else \
            (["--sched-sweep"] if args.sched_sweep else [])
        if args.out:
            argv += ["--out", args.out]
        if args.report_out:
            argv += ["--report-out", args.report_out]
        sweep_main(argv)
        return
    if args.cell is None:
        ap.error("--cell is required unless --sched-sweep is given")
    arch, shape = CELLS[args.cell]
    rows = []
    for v in args.variants.split(","):
        if v == "baseline":
            rows.append(measure(arch, shape, "baseline(tp_sp)"))
        elif v == "zero1":
            rows.append(measure(arch, shape, "zero1", mode="zero1"))
        elif v == "zero1_noremat":
            import dataclasses as dc
            cfg2 = dc.replace(get_config(arch), remat=False)
            mesh = make_production_mesh(multi_pod=False)
            compiled = compile_variant(cfg2, shape, mesh, mode="zero1")
            c2 = compile_variant(_with_trips(cfg2, 2), shape, mesh,
                                 mode="zero1", policy_cfg=cfg2)
            c3 = compile_variant(_with_trips(cfg2, 3), shape, mesh,
                                 mode="zero1", policy_cfg=cfg2)
            v2, v3 = _costs_of(c2), _costs_of(c3)
            trips = _trips(cfg2)
            fl, by, cb = (v2[i] + (v3[i] - v2[i]) * (trips - 2)
                          for i in range(3))
            rf = R.extract(arch, shape, "16x16", 256, compiled,
                           model_flops(cfg2, shape),
                           model_bytes(cfg2, shape))
            rf.flops_per_device, rf.bytes_per_device = fl, by
            rf.collective_bytes = cb
            ma = compiled.memory_analysis()
            print(f"[zero1_noremat] compute={rf.t_compute*1e3:8.1f}ms "
                  f"memory={rf.t_memory*1e3:8.1f}ms "
                  f"collective={rf.t_collective*1e3:8.1f}ms "
                  f"→ {rf.bottleneck}-bound frac={rf.roofline_frac:.3f} "
                  f"(temp={ma.temp_size_in_bytes/2**30:.1f}G)")
            rows.append({**rf.row(), "tag": "zero1_noremat"})
        elif v == "ep_dp":
            rows.append(measure(arch, shape, "ep_dp", mode="ep_dp"))
        elif v == "ep_dp_savemoe":
            import dataclasses as dc
            globals()["get_config_orig"] = get_config
            cfg2 = dc.replace(get_config(arch), remat_policy="save_moe")
            mesh = make_production_mesh(multi_pod=False)
            compiled = compile_variant(cfg2, shape, mesh, mode="ep_dp")
            c2 = compile_variant(_with_trips(cfg2, 2), shape, mesh,
                                 mode="ep_dp", policy_cfg=cfg2)
            c3 = compile_variant(_with_trips(cfg2, 3), shape, mesh,
                                 mode="ep_dp", policy_cfg=cfg2)
            v2, v3 = _costs_of(c2), _costs_of(c3)
            trips = _trips(cfg2)
            fl, by, cb = (v2[i] + (v3[i] - v2[i]) * (trips - 2)
                          for i in range(3))
            rf = R.extract(arch, shape, "16x16", 256, compiled,
                           model_flops(cfg2, shape),
                           model_bytes(cfg2, shape))
            rf.flops_per_device, rf.bytes_per_device = fl, by
            rf.collective_bytes = cb
            ma = compiled.memory_analysis()
            print(f"[ep_dp_savemoe] compute={rf.t_compute*1e3:8.1f}ms "
                  f"memory={rf.t_memory*1e3:8.1f}ms "
                  f"collective={rf.t_collective*1e3:8.1f}ms "
                  f"→ {rf.bottleneck}-bound frac={rf.roofline_frac:.3f} "
                  f"(temp={ma.temp_size_in_bytes/2**30:.1f}G)")
            rows.append({**rf.row(), "tag": "ep_dp_savemoe"})
        elif v == "ep_dp_baselinea2a":
            rows.append(measure(arch, shape, "ep_dp+a2a", mode="ep_dp",
                                ep_mode="baseline"))
        elif v == "flashdecode_off":
            import repro.launch.steps as Sx
            import repro.parallel.flash_decode as FD
            orig = FD.make_flash_decode
            FD.make_flash_decode = lambda mesh, axis="model": (
                lambda *a, **k: None)
            try:
                rows.append(measure(arch, shape, "decode_dense_gspmd"))
            finally:
                FD.make_flash_decode = orig
        elif v == "nosp":
            rows.append(measure(arch, shape, "tp_nosp",
                                seq_parallel=False))
        elif v == "opt":
            mode = "ep_dp" if "moe" in arch or "granite" in arch else "zero1"
            rows.append(measure(arch, shape, f"opt({mode})", mode=mode))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)


if __name__ == "__main__":
    main()
