"""Decode-trace replay: bucket policies under serving-shaped traffic.

The ROADMAP's "serving traffic" gap: the dropless/SSC reuse numbers all come
from training-shaped batches (fixed token count, i.i.d. jitter), but the
traffic that decides a serving deployment is *decode* traffic — bursty batch
sizes as slots fill and drain, Zipf-skewed expert demand, slowly rotating
hotspots. This harness replays such traces through the real plan-compilation
path (``plan_from_routing`` → bucketed :class:`RoutingPlan` → ``SSCCache`` →
``compile_schedule``) and prices every step's schedule with
``simulate_unified``, reporting per bucket policy:

* ``hit_rate`` / ``recompile_rate`` — SSC cache behaviour over the trace;
* ``pad_ratio`` — bucketed plan rows / routed rows (the policy's cost);
* ``ep_retraces`` — distinct ``ring_chunk_caps`` tuples, i.e. how many
  times ``make_moe_ep(plan=..., bucket=...)`` would retrace under jit: an
  exact plan retraces nearly every batch, a laddered one is bounded by the
  policy's rung combinations;
* ``p50_us`` / ``p99_us`` — simulated step latency (padding inflates it,
  which is the other side of the padding-vs-reuse trade).

It is also the *producer* of the plan populations
:func:`repro.core.buckets.fit_ladder` learns from: ``fitted:B`` policies
fit a B-rung ladder on a fitting trace before replaying.

Traces are either synthesized (``--profile uniform|zipf|hotspot|bursty``)
or recorded: the JSONL format is one object per decode step,
``{"step": i, "top_i": [[e, e], ...]}`` with ``top_i`` the step's [T, k]
expert choices — exactly what a router tap in a serving loop would log.
Each step object may additionally carry ``"t_us"``, the step's arrival
timestamp in µs (monotone non-decreasing); absent ⇒ fixed cadence.
Arrivals drive arrival-time-accurate SLO measurement: with ``--slo-us``
set, replay runs a busy-server model (a step starts at
``max(arrival, previous completion)``) and reports response-time
percentiles and the SLO miss rate next to the raw step latencies.

Policies are static :class:`~repro.core.buckets.BucketSpec` forms,
``fitted:B[xL]`` (offline ladder fit on held-out data), or ``online[:B[xL]]``
— a :class:`~repro.launch.online.OnlineTuner` starting cold and refitting
on the replayed traffic itself (no held-out fit; the self-tuning serving
path under test).

    PYTHONPATH=src python -m repro.launch.replay --profile bursty \
        --steps 64 --policies exact,linear:16,geometric:8,fitted:6
    PYTHONPATH=src python -m repro.launch.replay --trace-in decode.jsonl \
        --experts 8 --ep 4 --policies linear:16,fitted:8 --report-out r.jsonl
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.buckets import BucketSpec, fit_ladder
from repro.core.odg import ScheduleConfig
from repro.core.simulator import simulate_unified
from repro.core.ssc import SSCCache

PROFILES = ("uniform", "zipf", "hotspot", "bursty")


# ---------------------------------------------------------------------------
# Trace synthesis + the recorded-trace JSONL format.
# ---------------------------------------------------------------------------

def _expert_probs(profile: str, e: int, hot: int = 0) -> np.ndarray:
    if profile == "uniform":
        p = np.ones(e)
    elif profile == "zipf":
        p = np.arange(1, e + 1, dtype=np.float64) ** -1.2
    elif profile == "hotspot":
        p = np.full(e, 0.3 / max(1, e - 1))
        p[hot % e] = 0.7
    elif profile == "bursty":
        # Mild skew plus a rotating hot expert (hot prompt prefixes).
        p = np.full(e, 0.7 / max(1, e - 1))
        p[hot % e] = 0.3
    else:
        raise ValueError(f"unknown profile {profile!r}; choices: {PROFILES}")
    return p / p.sum()


def _gumbel_topk(rng: np.random.Generator, probs: np.ndarray, t: int,
                 k: int) -> np.ndarray:
    """[t, k] distinct expert choices per token (Gumbel top-k)."""
    g = rng.gumbel(size=(t, probs.shape[0]))
    pert = np.log(probs)[None, :] + g
    return np.argsort(-pert, axis=1)[:, :k]


def synth_trace(profile: str, steps: int, *, ep: int = 4, e_loc: int = 2,
                t_loc: int = 64, top_k: int = 2, seed: int = 0,
                churn: float = 0.12) -> list[np.ndarray]:
    """Synthesize a decode trace: one [T_t, k] top-k choice array per step.

    Successive decode batches are *correlated* — continuous batching swaps
    only the slots that finished or arrived, the rest keep decoding — so
    every profile churns a ``churn`` fraction of token choices per step
    instead of resampling the whole batch (uncorrelated jitter wildly
    overstates recompile pressure). ``uniform``/``zipf``/``hotspot`` hold
    the batch at ``ep * t_loc`` tokens and churn only the routing.
    ``bursty`` is the hard serving case: the active token count follows a
    burst-arrival/drain envelope (slots fill on a burst, drain
    geometrically) and the hot expert rotates slowly — batch-size *and*
    routing jitter at once. Token counts stay multiples of ``ep``.
    """
    rng = np.random.default_rng(seed)
    e = ep * e_loc
    base_t = ep * t_loc
    trace: list[np.ndarray] = []

    def draw(t: int, probs: np.ndarray) -> np.ndarray:
        return _gumbel_topk(rng, probs, t, top_k)

    # The resident token pool: churn re-routes a fraction of it per step;
    # bursty replays an active prefix whose length follows the envelope.
    pool = draw(base_t, _expert_probs(profile, e, hot=0))
    env = 0.6
    for step in range(steps):
        probs = _expert_probs(profile, e,
                              hot=step // 8 if profile == "bursty" else 0)
        n = max(1, int(round(churn * base_t)))
        idx = rng.choice(base_t, size=n, replace=False)
        pool = pool.copy()
        pool[idx] = draw(n, probs)
        if profile == "bursty":
            if rng.random() < 0.2:
                env = rng.uniform(0.5, 1.0)          # burst: slots fill
            else:
                env = max(0.2, env * rng.uniform(0.8, 0.95))   # drain
            t = max(ep, int(round(base_t * env / ep)) * ep)
        else:
            t = base_t
        trace.append(pool[:t].copy())
    return trace


def synth_arrival_us(trace: Sequence[np.ndarray], *,
                     mean_gap_us: float = 500.0,
                     seed: int = 0) -> np.ndarray:
    """Per-step arrival timestamps consistent with a trace's batch sizes.

    A bigger offered batch means the inter-arrival gap that accumulated it
    was shorter, so gaps scale inversely with each step's token count
    around ``mean_gap_us`` (± jitter) — bursty traces get clustered
    arrivals, fixed-size traces an almost-fixed cadence. Monotone
    non-decreasing µs, deterministic per seed.
    """
    rng = np.random.default_rng(seed)
    tokens = np.asarray([np.asarray(t).reshape(-1, np.asarray(t).shape[-1])
                         .shape[0] for t in trace], dtype=np.float64)
    gaps = mean_gap_us * (tokens.mean() / np.maximum(tokens, 1.0))
    gaps *= rng.uniform(0.8, 1.2, size=gaps.shape)
    return np.cumsum(gaps)


def save_trace_jsonl(path: str, trace: Sequence[np.ndarray],
                     arrival_us: Optional[Sequence[float]] = None) -> None:
    """Write the recorded-trace JSONL; ``arrival_us`` (optional, one per
    step) adds the backward-compatible ``"t_us"`` timestamp field."""
    if arrival_us is not None and len(arrival_us) != len(trace):
        raise ValueError(
            f"arrival_us has {len(arrival_us)} entries for "
            f"{len(trace)} steps")
    with open(path, "w") as f:
        for i, top_i in enumerate(trace):
            obj = {"step": i, "top_i": np.asarray(top_i).tolist()}
            if arrival_us is not None:
                obj["t_us"] = float(arrival_us[i])
            f.write(json.dumps(obj) + "\n")


def load_trace_jsonl(path: str, with_arrivals: bool = False):
    """Load a recorded trace; default return is the plain step list.

    ``with_arrivals=True`` returns ``(trace, arrival_us)`` where
    ``arrival_us`` is a float64 array when *every* step carries ``t_us``
    and ``None`` otherwise (absent ⇒ fixed cadence, the legacy format).
    """
    trace, arrivals = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            trace.append(np.asarray(obj["top_i"], dtype=np.int64))
            arrivals.append(obj.get("t_us"))
    if not trace:
        raise ValueError(f"{path}: empty trace")
    if not with_arrivals:
        return trace
    arr = (np.asarray(arrivals, dtype=np.float64)
           if all(a is not None for a in arrivals) else None)
    return trace, arr


# ---------------------------------------------------------------------------
# Policy resolution (incl. fitting ladders from a trace).
# ---------------------------------------------------------------------------

def exact_plans(trace: Sequence[np.ndarray], mc, ep: int) -> list:
    """The unbucketed per-step RoutingPlans — fit_ladder's population."""
    from repro.models.moe import plan_from_routing
    return [plan_from_routing(ti, mc, ep, capacity=None).plan
            for ti in trace]


def resolve_policies(specs: Sequence[str], fit_trace, mc,
                     ep: int) -> dict:
    """Map CLI policy names to specs (or online tuners).

    ``fitted:B`` fits a B-rung ladder on ``fit_trace`` (use a *different*
    seed/segment than the replayed trace, or the fit is evaluated
    in-sample); ``fitted:BxL`` additionally sets the fit's
    ``split_penalty`` to L (0 = padding-optimal, larger = reuse-favoring).
    ``online[:B[xL]]`` builds an :class:`~repro.launch.online.OnlineTuner`
    with that ladder budget / split penalty, *warm-started* from the same
    offline fit ``fitted:B`` would deploy (the realistic rollout: ship the
    deploy-time ladder, let the tuner take over) — comparing ``online:B``
    against ``fitted:B`` on one trace therefore isolates exactly the value
    of online refitting.
    """
    from .online import OnlineConfig, OnlineTuner
    plans = None
    out: dict = {}
    for s in specs:
        s = s.strip()
        if not s:
            continue
        if s.startswith("fitted") or s.startswith("online"):
            params = s.partition(":")[2] or "6"
            b, _, lam = params.partition("x")
            if plans is None:
                plans = exact_plans(fit_trace, mc, ep)
            seed_spec = fit_ladder(plans, int(b),
                                   split_penalty=float(lam) if lam else 0.5)
            if s.startswith("online"):
                oc = OnlineConfig(budget=int(b), **(
                    {"split_penalty": float(lam)} if lam else {}))
                out[s] = OnlineTuner(initial=seed_spec, oc=oc)
            else:
                out[s] = seed_spec
        else:
            out[s] = BucketSpec.parse(s)
    if not out:
        raise ValueError("no bucket policies given")
    return out


# ---------------------------------------------------------------------------
# The replay loop.
# ---------------------------------------------------------------------------

def replay_trace(trace: Sequence[np.ndarray], mc, ep: int,
                 policies: dict, *,
                 d_model: int = 64, d_ff: Optional[int] = None,
                 pipeline: Sequence = ("ratr",),
                 directions: Sequence[str] = ("forward",),
                 gmm_m_split: int = 1, simulate: bool = True,
                 max_entries: int = 1024, quiet: bool = True,
                 arrival_us: Optional[Sequence[float]] = None,
                 slo_us: Optional[float] = None) -> list[dict]:
    """Replay one trace under each bucket policy; one result row per policy.

    Every step builds the policy's bucketed plan, fetches (or compiles) its
    schedule(s) from a fresh per-policy ``SSCCache``, tracks the EP-ring
    cap signature, and — with ``simulate`` — prices the step's schedule on
    the simulator (memoized per distinct plan, so the wall cost scales with
    *distinct* schedules, exactly like the real system's compile cost).
    Decode replay prices ``("forward",)``; pass both directions for
    training-shaped traces.

    A policy value may be an :class:`~repro.launch.online.OnlineTuner`
    instead of a static spec: each step's exact routing counts are fed to
    ``observe`` and the step is quantized with whatever spec the tuner
    currently holds (its result row adds ``swaps``/``refits``).

    ``arrival_us`` (with ``simulate``) adds arrival-time-accurate serving
    latency under a busy-server model — step *i* starts at
    ``max(arrival_us[i], completion[i-1])`` and its response time spans
    arrival → completion — reported as ``p50_resp_us``/``p99_resp_us``
    plus ``slo_miss_rate`` when ``slo_us`` is set.
    """
    from repro.models.moe import plan_from_routing, routed_counts
    from repro.parallel.ep import ring_chunk_caps

    from .online import OnlineTuner

    d_ff = d_ff if d_ff is not None else mc.d_expert
    if arrival_us is not None and len(arrival_us) != len(trace):
        raise ValueError(f"arrival_us has {len(arrival_us)} entries for "
                         f"{len(trace)} steps")
    rows_out = []
    for name, pol in policies.items():
        tuner = pol if isinstance(pol, OnlineTuner) else None
        spec = None if tuner else pol
        cache = SSCCache(max_entries=max_entries)
        if tuner is not None:
            tuner.bind(cache=cache, d_model=d_model, d_ff=d_ff)
        sims: dict[tuple, float] = {}
        lat_us: list[float] = []
        fetch_s: list[float] = []
        ring_sigs: set[tuple] = set()
        for top_i in trace:
            t0 = time.perf_counter()
            if tuner is not None:
                spec = tuner.observe(routed_counts(top_i, mc, ep))
            bridge = plan_from_routing(top_i, mc, ep, capacity=None,
                                       bucket=spec)
            plan = bridge.plan
            cache.record_rows(int(bridge.send_row.size), plan.total_rows)
            ring_sigs.add(ring_chunk_caps(plan, ep))
            cfg = ScheduleConfig(ep=ep, e_loc=plan.e_loc, rows=0,
                                 d_model=d_model, d_ff=d_ff,
                                 gmm_m_split=gmm_m_split,
                                 gmm_split_mode="source_aligned",
                                 plan=plan, bucket=spec.key())
            step_us = 0.0
            scheds = {direction: cache.get_or_compile(
                cfg, direction, pipeline=list(pipeline))
                for direction in directions}
            # Timed span = plan build + fetch-or-compile only; simulator
            # pricing below is measurement, not per-step scheduling cost.
            fetch_s.append(time.perf_counter() - t0)
            if simulate:
                for direction, sched in scheds.items():
                    sk = (plan.counts, direction)
                    if sk not in sims:
                        sims[sk] = simulate_unified(sched).makespan_us
                    step_us += sims[sk]
            lat_us.append(step_us)
        info = cache.info()
        total = info["hits"] + info["misses"]
        row = {
            "policy": name,
            "spec": str(spec),
            "steps": len(trace),
            "hit_rate": info["hits"] / total if total else 0.0,
            "recompile_rate": info["misses"] / total if total else 0.0,
            "compiles": info["misses"],
            "pad_ratio": info["pad_ratio"],
            "ep_retraces": len(ring_sigs),
            "fetch_us_mean": 1e6 * float(np.mean(fetch_s)),
        }
        if tuner is not None:
            row["swaps"] = len(tuner.swaps)
            row["refits"] = tuner.refits
        if simulate:
            row["p50_us"] = float(np.percentile(lat_us, 50))
            row["p99_us"] = float(np.percentile(lat_us, 99))
            if arrival_us is not None:
                resp, end = [], 0.0
                for arr, us in zip(arrival_us, lat_us):
                    end = max(float(arr), end) + us
                    resp.append(end - float(arr))
                row["p50_resp_us"] = float(np.percentile(resp, 50))
                row["p99_resp_us"] = float(np.percentile(resp, 99))
                if slo_us is not None:
                    row["slo_miss_rate"] = float(
                        (np.asarray(resp) > slo_us).mean())
        rows_out.append(row)
        if not quiet:
            sim = (f" p50={row['p50_us']:8.1f}us p99={row['p99_us']:8.1f}us"
                   if simulate else "")
            if "p99_resp_us" in row:
                sim += f" p99resp={row['p99_resp_us']:8.1f}us"
            swaps = f" swaps={row['swaps']}" if tuner is not None else ""
            print(f"[replay {name:14s}] hit={row['hit_rate']:.2f} "
                  f"pad={row['pad_ratio']:.2f}x "
                  f"retraces={row['ep_retraces']:3d}/{len(trace)} "
                  f"compiles={row['compiles']:3d}{sim}{swaps} ({spec})")
    return rows_out


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="replay decode traces through plan compilation + the "
                    "simulator, comparing bucket policies")
    ap.add_argument("--profile", default="bursty", choices=PROFILES,
                    help="synthetic trace profile (ignored with --trace-in)")
    ap.add_argument("--trace-in", default=None, metavar="JSONL",
                    help="recorded decode trace (one {'top_i': [[e,..],..]} "
                         "object per step) instead of a synthetic profile")
    ap.add_argument("--trace-out", default=None, metavar="JSONL",
                    help="record the replayed trace in the JSONL format")
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--ep", type=int, default=4)
    ap.add_argument("--experts", type=int, default=8,
                    help="total experts (e_loc = experts / ep)")
    ap.add_argument("--t-loc", type=int, default=64,
                    help="peak tokens per source rank")
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--d-ff", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--churn", type=float, default=0.12,
                    help="fraction of token choices re-routed per step "
                         "(continuous-batching slot turnover)")
    ap.add_argument("--policies", default="exact,linear:16,geometric:8,"
                                          "fitted:6",
                    help="comma-separated bucket policies; 'fitted:B[xL]' "
                         "fits a B-rung ladder (split_penalty L) on held-"
                         "out data: a seed+1 trace for synthetic profiles, "
                         "or the first half of --trace-in (all policies "
                         "then replay only the second half)")
    ap.add_argument("--directions", default="forward",
                    help="comma-separated schedule directions to fetch "
                         "(decode = forward; training traces: "
                         "forward,backward)")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip the simulator (cache/retrace stats only)")
    ap.add_argument("--arrival-gap-us", type=float, default=0.0,
                    help="synthesize per-step arrival timestamps at this "
                         "mean inter-step gap (0 = off); recorded traces "
                         "with t_us fields carry their own arrivals")
    ap.add_argument("--slo-us", type=float, default=None,
                    help="response-time SLO bound (µs); with arrivals, "
                         "rows gain slo_miss_rate")
    ap.add_argument("--report-out", default=None, metavar="JSONL",
                    help="write one result row per policy as JSONL")
    args = ap.parse_args(argv)

    from repro.models.moe import MoEConfig
    if args.experts % args.ep:
        ap.error(f"--experts {args.experts} not divisible by --ep {args.ep}")
    e_loc = args.experts // args.ep
    mc = MoEConfig(n_experts=args.experts, top_k=args.top_k,
                   d_expert=args.d_ff)

    wants_fit = any(s.strip().startswith("fitted")
                    for s in args.policies.split(","))
    arrivals = None
    if args.trace_in:
        trace, arrivals = load_trace_jsonl(args.trace_in,
                                           with_arrivals=True)
        if wants_fit:
            # A recorded trace has no second seed to draw from: fit on the
            # first half and replay *only* the held-out second half (for
            # every policy, so rows stay comparable) — otherwise fitted
            # hit/pad rows would be partly in-sample and look better than
            # they generalize.
            if len(trace) < 2:
                ap.error("--trace-in with a fitted policy needs >= 2 steps "
                         "(fit half + held-out half)")
            split = len(trace) // 2
            fit_trace, trace = trace[:split], trace[split:]
            if arrivals is not None:
                arrivals = arrivals[split:]
            print(f"fitted policies: fit on steps [0, {split}), replaying "
                  f"held-out steps [{split}, {split + len(trace)})")
        else:
            fit_trace = trace
    else:
        trace = synth_trace(args.profile, args.steps, ep=args.ep,
                            e_loc=e_loc, t_loc=args.t_loc,
                            top_k=args.top_k, seed=args.seed,
                            churn=args.churn)
        fit_trace = synth_trace(args.profile, args.steps, ep=args.ep,
                                e_loc=e_loc, t_loc=args.t_loc,
                                top_k=args.top_k, seed=args.seed + 1,
                                churn=args.churn)
    if arrivals is None and args.arrival_gap_us > 0:
        arrivals = synth_arrival_us(trace, mean_gap_us=args.arrival_gap_us,
                                    seed=args.seed)
    if args.trace_out:
        save_trace_jsonl(args.trace_out, trace, arrival_us=arrivals)

    policies = resolve_policies(args.policies.split(","), fit_trace, mc,
                                args.ep)
    rows = replay_trace(
        trace, mc, args.ep, policies, d_model=args.d_model, d_ff=args.d_ff,
        directions=tuple(d for d in args.directions.split(",") if d),
        simulate=not args.no_sim, quiet=False,
        arrival_us=arrivals, slo_us=args.slo_us)
    if args.report_out:
        with open(args.report_out, "w") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
    return rows


if __name__ == "__main__":
    main()
