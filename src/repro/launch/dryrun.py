import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — JAX locks the device count at first
init, and the dry-run needs 512 placeholder host devices for the production
meshes. Everything else (smoke tests, benches) sees the real device count.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, get_config                     # noqa: E402
from repro.configs.shapes import (SHAPES, cells, input_specs,   # noqa: E402
                                  skip_reason)
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch import steps as St                            # noqa: E402
from repro.models import model as M                             # noqa: E402
from repro.optim import adamw                                   # noqa: E402
from repro.parallel.ep import EPConfig                          # noqa: E402
from repro.parallel import roofline as R                        # noqa: E402


def model_flops(cfg, shape_name: str) -> float:
    sp = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = sp.global_batch * (sp.seq_len if sp.kind != "decode" else 1)
    mult = 6 if sp.kind == "train" else 2
    return mult * n_active * tokens


def model_bytes(cfg, shape_name: str) -> float:
    """Minimal achievable HBM traffic per step (global): parameter reads,
    optimizer-state read+write for train, full cache read for decode."""
    sp = SHAPES[shape_name]
    n = cfg.param_count()
    if sp.kind == "train":
        # bf16 params read (fwd+bwd ≈ 2 passes) + grads rw + m/v/master rw.
        return n * (2 * 2 + 2 * 4 + 2 * 3 * 4)
    total = 2.0 * n
    if sp.kind == "decode":
        cache_shape = jax.eval_shape(
            lambda: M.init_cache(cfg, sp.global_batch, sp.seq_len))
        total += sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(cache_shape))
    return total


def _compile_step(cfg, shape_name: str, mesh, ep_mode: str,
                  seq_parallel: bool, policy_cfg=None, mode: str = "tp_sp"):
    """policy_cfg pins FSDP decisions to the *real* config when compiling
    reduced-trip-count probe variants — and switches them to accum=1:
    the grad-accumulation loop is also a while op whose body HloCostAnalysis
    counts once, and accum=1 at full batch is the same math (compile-only,
    so the probe's activation memory is irrelevant)."""
    policy = policy_cfg or cfg
    is_probe = policy_cfg is not None
    sp = SHAPES[shape_name]
    ep = (EPConfig(mode=ep_mode) if cfg.family == "moe" else None)
    n_params = policy.param_count()
    accum = 1 if is_probe else (
        8 if n_params > 100e9 else (4 if n_params > 10e9 else 1))
    fns = St.make_steps(cfg, mesh, ep=ep, seq_parallel=seq_parallel,
                        accum_steps=accum, fsdp=n_params > 10e9, mode=mode)
    # Step-boundary params are the bf16 compute copies; the fp32 masters
    # live inside the optimizer state (mixed precision done properly).
    params_shape = jax.eval_shape(
        lambda: adamw.cast_params(M.init_params(cfg, jax.random.PRNGKey(0)),
                                  cfg.compute_dtype))
    batch = input_specs(cfg, shape_name)
    with jax.set_mesh(mesh):
        if sp.kind == "train":
            opt_shape = jax.eval_shape(adamw.init_opt_state, params_shape)
            step = St.jit_train_step(fns, params_shape, batch)
            lowered = step.lower(params_shape, opt_shape, batch)
        elif sp.kind == "prefill":
            step = St.jit_prefill_step(fns, params_shape, batch, sp.seq_len)
            lowered = step.lower(params_shape, batch)
        else:  # decode
            cache_shape = jax.eval_shape(
                lambda: M.init_cache(cfg, sp.global_batch, sp.seq_len))
            step = St.jit_decode_step(fns, params_shape,
                                      batch["tokens"], cache_shape)
            lowered = step.lower(params_shape, batch["tokens"], cache_shape)
        return lowered.compile()


def _trips(cfg) -> int:
    """Scan trip count of the layer stack."""
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.hybrid_pattern)
    return cfg.n_layers


def _with_trips(cfg, trips: int):
    if cfg.family == "hybrid":
        pat = len(cfg.hybrid_pattern)
        tail = cfg.n_layers % pat
        return dataclasses.replace(cfg, n_layers=trips * pat + tail,
                                   scan_layers=False)
    return dataclasses.replace(cfg, n_layers=trips, scan_layers=False)


def _costs_of(compiled):
    ca = compiled.cost_analysis() or {}
    colls = R.parse_collectives(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(colls.total_bytes))


def extrapolated_costs(cfg, shape_name: str, mesh, ep_mode: str,
                       seq_parallel: bool, mode: str = "tp_sp"):
    """XLA's HloCostAnalysis visits while (scan) bodies once — regardless of
    trip count — so scanned stacks under-report flops / bytes / collective
    bytes. Compile small *unrolled* variants (2 and 3 trips, scan_layers off
    so every layer is materialized in the HLO) and evaluate the affine model
    ``cost(L) = a + b·L`` at the real trip count."""
    c2 = _compile_step(_with_trips(cfg, 2), shape_name, mesh, ep_mode,
                       seq_parallel, policy_cfg=cfg, mode=mode)
    c3 = _compile_step(_with_trips(cfg, 3), shape_name, mesh, ep_mode,
                       seq_parallel, policy_cfg=cfg, mode=mode)
    v2, v3 = _costs_of(c2), _costs_of(c3)
    trips = _trips(cfg)
    return tuple(v2[i] + (v3[i] - v2[i]) * (trips - 2) for i in range(3))


def lower_cell(cfg, shape_name: str, mesh, *, ep_mode: str = "hyperparallel",
               seq_parallel: bool = True, verbose: bool = True,
               extrapolate: bool = True, mode: str = "tp_sp"):
    """Lower + compile one cell; returns (Roofline, compile_seconds)."""
    t0 = time.time()
    compiled = _compile_step(cfg, shape_name, mesh, ep_mode, seq_parallel,
                             mode=mode)
    dt = time.time() - t0

    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rf = R.extract(cfg.name, shape_name, mesh_name, chips, compiled,
                   model_flops(cfg, shape_name),
                   model_bytes(cfg, shape_name))
    if extrapolate:
        fl, by, cb = extrapolated_costs(cfg, shape_name, mesh, ep_mode,
                                        seq_parallel, mode=mode)
        rf.flops_per_device = fl
        rf.bytes_per_device = by
        rf.collective_bytes = cb
    if verbose:
        ma = compiled.memory_analysis()
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB")
        print(f"  cost_analysis: flops/dev={rf.flops_per_device:.3e} "
              f"bytes/dev={rf.bytes_per_device:.3e}")
        print(f"  collectives: {rf.coll_counts} "
              f"bytes/dev={rf.collective_bytes:.3e}")
        print(f"  roofline: compute={rf.t_compute*1e3:.2f}ms "
              f"memory={rf.t_memory*1e3:.2f}ms "
              f"collective={rf.t_collective*1e3:.2f}ms "
              f"→ {rf.bottleneck}-bound, frac={rf.roofline_frac:.3f}")
    return rf, dt


def run_all(archs, shapes, *, multi_pod_only=False, single_pod_only=False,
            ep_mode="hyperparallel", mode="tp_sp", out=None):
    meshes = []
    if not multi_pod_only:
        meshes.append(("1x16x16", make_production_mesh(multi_pod=False)))
    if not single_pod_only:
        meshes.append(("2x16x16", make_production_mesh(multi_pod=True)))

    rows, failures = [], []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            why = skip_reason(cfg, shape_name)
            if why:
                print(f"SKIP {arch} × {shape_name}: {why}")
                continue
            for mesh_name, mesh in meshes:
                print(f"RUN  {arch} × {shape_name} × {mesh_name}")
                try:
                    rf, dt = lower_cell(cfg, shape_name, mesh,
                                        ep_mode=ep_mode, mode=mode)
                    rows.append({**rf.row(), "compile_s": round(dt, 1)})
                    print(f"  OK in {dt:.1f}s")
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, mesh_name, str(e)))
                    print(f"  FAIL: {e}")
                    traceback.print_exc(limit=3)
    if out:
        with open(out, "w") as f:
            json.dump({"rows": rows,
                       "failures": [list(f_) for f_ in failures]}, f,
                      indent=1, default=str)
        print(f"wrote {out}")
    print(f"\n{len(rows)} cells compiled, {len(failures)} failures")
    for f_ in failures:
        print("FAILED:", *f_[:3])
    return rows, failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--ep-mode", default="hyperparallel",
                    choices=["hyperparallel", "baseline"])
    ap.add_argument("--mode", default="tp_sp",
                    choices=["tp_sp", "zero1", "ep_dp"],
                    help="sharding-rule mode (see DESIGN.md §5)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    run_all(archs, shapes, multi_pod_only=args.multi_pod_only,
            single_pod_only=args.single_pod_only,
            ep_mode=args.ep_mode, mode=args.mode, out=args.out)


if __name__ == "__main__":
    main()
