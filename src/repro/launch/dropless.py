"""Dropless, data-dependent MoE execution for the training step.

The fixed-capacity paths (``moe_grouped``, ``parallel/ep.py``) pad every
(destination, expert) pair to a static capacity and *drop* overflow tokens so
all shapes stay static under jit. This module is the opposite trade, and the
paper's core claim wired into training: each batch's **actual** router output
is turned into a :class:`~repro.core.routing.RoutingPlan` with
``plan_from_routing(capacity=None)`` (no token is ever dropped), the plan's
schedule is fetched from — or compiled into — a process-level
:class:`~repro.core.ssc.SSCCache`, and the **plan-sized** tile taskflow is
executed instead of the fixed-capacity one.

Because a fresh imbalanced plan would recompile every step, plans are
*shape-bucketed* first (``bucket``: a ``repro.core.buckets.BucketSpec``
quantizing per-cell counts up to policy buckets — linear, geometric, or a
fitted ladder — padding rows stay zero) so that batch-to-batch routing
jitter maps to a stable cache key; ``bench_dropless`` measures the
recompile-rate and padded-row difference between exact and bucketed keys
per policy, and the per-step ``ssc_pad_ratio`` metric reports what the
active policy costs.

Integration is the same pluggable ``moe_impl(params, x, mc)`` seam the EP
path uses: the router (and therefore the gradient into router weights) runs
in JAX, while the schedulable Dispatch→GMM→SwiGLU→GMM→Combine fragment runs
through ``jax.pure_callback`` on the schedule executor, with a
``jax.custom_vjp`` whose backward executes the backward-direction schedule —
so ``train_step`` trains *through* compiled schedules, forward and backward.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.odg import ScheduleConfig
from repro.core.ssc import SSCCache


@dataclasses.dataclass(frozen=True)
class DroplessConfig:
    """Configuration of the dropless data-dependent training path.

    ``ep`` is the size of the *compiled* EP group: tokens are split
    contiguously over ``ep`` virtual source ranks and experts over ``ep``
    expert shards, matching the fragment the scheduling stack compiles.
    ``bucket`` quantizes per-cell plan counts into shape buckets: a
    :class:`repro.core.buckets.BucketSpec` or anything
    ``BucketSpec.from_any`` accepts (``"geometric:8"``, a fitted ladder,
    an int). ``bucket_rows`` is the deprecated linear-bucket int shim —
    ``DroplessConfig(bucket_rows=r)`` and
    ``DroplessConfig(bucket=BucketSpec.linear(r))`` produce SSC-key
    identical schedules (``bucket`` wins when both are given; 1 = exact
    plans, every distinct routing compiles its own SSC).
    ``pipeline`` is a schedule-pass
    pipeline spec applied to both directions (direction-gated passes such as
    ``gmm_interleave`` no-op on forward) — or the string ``"auto"``, which
    resolves per batch-plan and per direction through the cost-model-guided
    selector (``core/autoselect.py``) inside ``SSCCache``: every batch gets
    the predicted-best pipeline (and ``gmm_m_split`` budget) for its actual
    routing, and bucketed plans memoize both the selection and the schedule.
    """

    ep: int = 1
    bucket_rows: int = 16            # deprecated: use bucket=
    bucket: object = None            # BucketSpec | int | str | key tuple
    gmm_m_split: int = 1
    gmm_split_mode: str = "source_aligned"
    pipeline: tuple | str = ("ratr", "gmm_interleave")
    cache_entries: int = 64

    def bucket_spec(self):
        """The effective :class:`~repro.core.buckets.BucketSpec` —
        ``bucket`` when given, else the legacy linear ``bucket_rows``."""
        from repro.core.buckets import BucketSpec, normalize_bucket
        if self.bucket is not None:
            return normalize_bucket(self.bucket)
        return BucketSpec.linear(self.bucket_rows)

    def __post_init__(self):
        # Fail at construction, not at the first train step inside a jitted
        # pure_callback: the only valid string is "auto" (SCHED_PIPELINES
        # names like "ratr+crit" go through core.passes.pipeline_arg — the
        # --sched CLI does), bare pass names must be registered, and the
        # bucket spec must parse.
        self.bucket_spec()
        from repro.core.passes import get_pass
        if isinstance(self.pipeline, str):
            if self.pipeline != "auto":
                raise ValueError(
                    f"pipeline={self.pipeline!r}: the only string spec is "
                    f'"auto"; for a named pipeline use '
                    f"core.passes.pipeline_arg({self.pipeline!r}) or a "
                    f"pass-name tuple")
            return
        for item in self.pipeline:
            if isinstance(item, str):
                get_pass(item)          # fail fast on unknown names

    def pipeline_spec(self):
        """The ``pipeline=`` argument for ``SSCCache``: ``"auto"`` or a
        list spec."""
        return self.pipeline if isinstance(self.pipeline, str) \
            else list(self.pipeline)


_PROCESS_CACHE: Optional[SSCCache] = None


def get_process_cache(max_entries: int = 64) -> SSCCache:
    """The process-level SSC cache shared by every dropless step fn.

    The cache keeps the *largest* bound ever requested: a later consumer
    asking for more headroom grows it (entries are never proactively
    evicted by a smaller request).
    """
    global _PROCESS_CACHE
    if _PROCESS_CACHE is None:
        _PROCESS_CACHE = SSCCache(max_entries=max_entries)
    elif max_entries > _PROCESS_CACHE.max_entries:
        _PROCESS_CACHE.max_entries = max_entries
    return _PROCESS_CACHE


class DroplessMoE:
    """A dropless ``moe_impl`` plus its schedule cache handle."""

    def __init__(self, dc: DroplessConfig, act: str = "swiglu",
                 cache: Optional[SSCCache] = None):
        if act != "swiglu":
            raise ValueError(
                f"dropless schedules execute the SwiGLU fragment; act={act!r}")
        self.dc = dc
        self.cache = cache if cache is not None else get_process_cache(
            dc.cache_entries)
        self.impl = _make_impl(dc, self.cache)
        info = self.cache.info()
        self._snapshot = (info["hits"], info["misses"], info["evictions"],
                          info["exact_rows"], info["padded_rows"])

    def rescale(self, new_ep: Optional[int] = None,
                dead_ranks=None) -> "DroplessMoE":
        """A fresh impl on the surviving mesh, sharing this handle's cache.

        The elastic-rescale entry point of the dropless path: pass the new
        mesh size directly or the lost ranks (``new_ep`` defaults to the
        survivor count). The shared ``SSCCache`` is **re-keyed** for the
        new mesh — old-mesh entries stay resident (they hit again should
        the mesh grow back) but bear the LRU pressure first, and the new
        handle's per-batch plans compile through the normal
        ``plan_from_routing`` → SSC path with ``ep``-tagged bucket keys, so
        the two mesh populations never alias. Remapped plans
        (``core.elastic.remap_plan``) execute bit-for-bit like plans built
        natively on the small mesh, so no schedule state needs migrating.
        """
        if new_ep is None:
            if dead_ranks is None:
                raise ValueError("pass new_ep= and/or dead_ranks=")
            from repro.core.elastic import surviving_ranks
            new_ep = len(surviving_ranks(self.dc.ep, dead_ranks))
        new_ep = int(new_ep)
        if new_ep < 1:
            raise ValueError(f"new_ep must be >= 1, got {new_ep}")
        self.cache.rekey_for_mesh(new_ep)
        return DroplessMoE(dataclasses.replace(self.dc, ep=new_ep),
                           cache=self.cache)

    def step_stats(self) -> dict:
        """Cache counter deltas since this handle's previous call.

        The snapshot lives on the handle, not the (possibly shared) cache,
        so independent consumers — two models on one process cache, or a
        monitoring loop calling ``cache.step_stats()`` — don't zero each
        other's per-step numbers. With a shared cache the deltas still
        aggregate *all* activity between this handle's calls; give each
        model its own ``SSCCache`` when per-model attribution matters.
        """
        info = self.cache.info()
        cur = (info["hits"], info["misses"], info["evictions"],
               info["exact_rows"], info["padded_rows"])
        last = self._snapshot
        self._snapshot = cur
        d_exact, d_pad = cur[3] - last[3], cur[4] - last[4]
        return {"hits": cur[0] - last[0], "misses": cur[1] - last[1],
                "evictions": cur[2] - last[2], "entries": info["entries"],
                "pad_ratio": d_pad / d_exact if d_exact else 1.0}


def make_moe_dropless(model_cfg, dc: DroplessConfig,
                      cache: Optional[SSCCache] = None) -> DroplessMoE:
    """Build the dropless MoE impl for a model config (validates shapes)."""
    mc = model_cfg.moe
    if mc is None:
        raise ValueError("dropless MoE requires a MoE model config")
    if mc.e_total % dc.ep:
        raise ValueError(f"e_total={mc.e_total} not divisible by "
                         f"dropless ep={dc.ep}")
    return DroplessMoE(dc, act=model_cfg.act, cache=cache)


# ---------------------------------------------------------------------------
# The schedulable fragment as a custom-vjp JAX function backed by callbacks.
# ---------------------------------------------------------------------------


def _schedule_cfg(dc: DroplessConfig, plan, d_model: int,
                  d_ff: int) -> ScheduleConfig:
    return ScheduleConfig(ep=dc.ep, e_loc=plan.e_loc, rows=0,
                          d_model=d_model, d_ff=d_ff,
                          gmm_m_split=dc.gmm_m_split,
                          gmm_split_mode=dc.gmm_split_mode, plan=plan,
                          bucket=dc.bucket_spec().key())


def _bridge_of(dc: DroplessConfig, top_i, mc, cache: Optional[SSCCache] = None):
    from repro.models.moe import plan_from_routing
    bridge = plan_from_routing(top_i, mc, dc.ep, capacity=None,
                               bucket=dc.bucket_spec())
    if cache is not None:
        # Dropless keeps every choice, so the exact row count is the full
        # [ep, T_loc, k] choice grid; the bucketed plan's total is what the
        # executor actually allocates/streams.
        cache.record_rows(int(bridge.send_row.size),
                          bridge.plan.total_rows)
    return bridge


def _exec_forward(dc: DroplessConfig, cache: SSCCache, mc,
                  xt, top_p, top_i, w1, w2):
    """Host side: plan → cached schedule → executor → combined tokens.

    ``w1``/``w2`` are the per-rank expert weights ``[ep, e_loc, d, 2f]`` /
    ``[ep, e_loc, f, d]``. Returns ``y [T, d]`` float32.
    """
    from repro.core import executor as ex
    from repro.models.moe import bridge_combine, bridge_dispatch

    xt = np.asarray(xt, dtype=np.float32)
    top_p = np.asarray(top_p, dtype=np.float32)
    top_i = np.asarray(top_i)
    T, d = xt.shape
    f = mc.d_expert

    bridge = _bridge_of(dc, top_i, mc, cache)
    plan = bridge.plan
    cfg = _schedule_cfg(dc, plan, d, f)
    sched = cache.get_or_compile(cfg, "forward",
                                 pipeline=dc.pipeline_spec())

    x_src = bridge_dispatch(bridge, xt.reshape(dc.ep, T // dc.ep, d))
    st = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
    ex.execute(sched, st, rng=np.random.default_rng(0))
    y_ret = [st.get("y_ret", r) if plan.send_rows(r)
             else np.zeros((0, d), np.float32) for r in range(dc.ep)]
    y = bridge_combine(bridge, y_ret, top_p)
    return y.reshape(T, d)


def _make_impl(dc: DroplessConfig, cache: SSCCache, live=None):
    """Build ``moe_impl(params, x, mc)`` executing plan-sized schedules.

    ``live`` is the online-tuning seam (``launch/online.py``): a host-side
    callable ``live(top_i, mc, direction) -> DroplessConfig`` invoked from
    inside the pure_callback host functions on every step. The host fns run
    per step even under a single jit trace, and the returned config may only
    differ in fields that don't change traced shapes (the bucket spec, the
    pipeline) — so the tuner can observe live routing and hot-swap the
    quantization policy without retracing. ``None`` (the default, and the
    whole training path) pins the construction-time ``dc``.
    """

    def moe_impl(params, x, mc):
        from repro.models.moe import router_topk

        B, S, d = x.shape
        T = B * S
        if T % dc.ep:
            raise ValueError(f"T={T} tokens not divisible by dropless "
                             f"ep={dc.ep}")
        xt = x.reshape(T, d)
        top_p, top_i = router_topk(params["router"], xt, mc)

        f = mc.d_expert

        # ---- host callbacks ------------------------------------------------
        def fwd_host(xt_h, top_p_h, top_i_h, w_in_h, w_down_h):
            dcc = live(np.asarray(top_i_h), mc, "forward") if live else dc
            w1 = np.asarray(w_in_h, np.float32).reshape(
                dcc.ep, mc.e_total // dcc.ep, d, 2 * f)
            w2 = np.asarray(w_down_h, np.float32).reshape(
                dcc.ep, mc.e_total // dcc.ep, f, d)
            return _exec_forward(dcc, cache, mc, xt_h, top_p_h, top_i_h,
                                 w1, w2)

        def bwd_host(xt_h, top_p_h, top_i_h, w_in_h, w_down_h, g_h):
            from repro.core import executor as ex
            from repro.models.moe import bridge_dispatch

            dcc = live(np.asarray(top_i_h), mc, "backward") if live else dc
            xt_h = np.asarray(xt_h, np.float32)
            top_p_h = np.asarray(top_p_h, np.float32)
            top_i_h = np.asarray(top_i_h)
            g = np.asarray(g_h, np.float32)
            e_loc = mc.e_total // dcc.ep
            w1 = np.asarray(w_in_h, np.float32).reshape(dcc.ep, e_loc, d,
                                                        2 * f)
            w2 = np.asarray(w_down_h, np.float32).reshape(dcc.ep, e_loc, f, d)

            bridge = _bridge_of(dcc, top_i_h, mc)
            plan = bridge.plan
            cfg = _schedule_cfg(dcc, plan, d, f)
            t_loc = T // dcc.ep
            rows = bridge.send_row                        # [ep, t_loc, k]
            g3 = g.reshape(dcc.ep, t_loc, d)
            tp3 = top_p_h.reshape(dcc.ep, t_loc, mc.top_k)

            # Recompute the saved activations the backward schedule consumes.
            x_src = bridge_dispatch(bridge, xt_h.reshape(dcc.ep, t_loc, d))
            fwd = ex.reference_forward_plan(cfg, x_src, w1, w2)

            # Per-row cotangent entering the fragment: dy[row] = p · g_token.
            dy = [np.zeros((plan.send_rows(s), d), np.float32)
                  for s in range(dcc.ep)]
            for s in range(dcc.ep):
                r = rows[s].reshape(-1)
                valid = r >= 0
                contrib = (tp3[s][:, :, None] * g3[s][:, None, :]).reshape(
                    -1, d)
                np.add.at(dy[s], r[valid], contrib[valid])

            sched = cache.get_or_compile(cfg, "backward",
                                         pipeline=dcc.pipeline_spec())
            st = ex.ExecutorState(cfg)
            ex.load_backward_state_plan(cfg, st, fwd, w1, w2, dy)
            ex.execute(sched, st, rng=np.random.default_rng(0))

            dxt = np.zeros((dcc.ep, t_loc, d), np.float32)
            dtp = np.zeros((dcc.ep, t_loc, mc.top_k), np.float32)
            for s in range(dcc.ep):
                if not plan.send_rows(s):
                    continue
                dx_ret = st.get("dx_ret", s)
                y_ret = fwd["y_ret"][s]
                for j in range(mc.top_k):
                    r = rows[s, :, j]
                    valid = r >= 0
                    dxt[s, valid] += dx_ret[r[valid]]
                    dtp[s, valid, j] = np.einsum(
                        "td,td->t", g3[s, valid], y_ret[r[valid]])
            dw1 = np.stack([st.get("dW1", r) if plan.recv_rows(r)
                            else np.zeros((e_loc, d, 2 * f), np.float32)
                            for r in range(dcc.ep)])
            dw2 = np.stack([st.get("dW2", r) if plan.recv_rows(r)
                            else np.zeros((e_loc, f, d), np.float32)
                            for r in range(dcc.ep)])
            return (dxt.reshape(T, d), dtp.reshape(T, mc.top_k),
                    dw1.reshape(mc.e_total, d, 2 * f),
                    dw2.reshape(mc.e_total, f, d))

        # ---- custom-vjp fragment ------------------------------------------
        @jax.custom_vjp
        def fragment(xt, top_p, top_i, w_in, w_down):
            return jax.pure_callback(
                fwd_host, jax.ShapeDtypeStruct((T, d), jnp.float32),
                xt, top_p, top_i, w_in, w_down)

        def fragment_fwd(xt, top_p, top_i, w_in, w_down):
            y = fragment(xt, top_p, top_i, w_in, w_down)
            return y, (xt, top_p, top_i, w_in, w_down)

        def fragment_bwd(res, g):
            xt, top_p, top_i, w_in, w_down = res
            dxt, dtp, dw1, dw2 = jax.pure_callback(
                bwd_host,
                (jax.ShapeDtypeStruct((T, d), jnp.float32),
                 jax.ShapeDtypeStruct((T, mc.top_k), jnp.float32),
                 jax.ShapeDtypeStruct(w_in.shape, jnp.float32),
                 jax.ShapeDtypeStruct(w_down.shape, jnp.float32)),
                xt, top_p, top_i, w_in, w_down, g)
            return (dxt.astype(xt.dtype), dtp.astype(top_p.dtype),
                    np.zeros(top_i.shape, dtype=jax.dtypes.float0),
                    dw1.astype(w_in.dtype), dw2.astype(w_down.dtype))

        fragment.defvjp(fragment_fwd, fragment_bwd)

        y = fragment(xt, top_p, top_i, params["w_in"], params["w_down"])
        return y.astype(x.dtype).reshape(B, S, d)

    return moe_impl


# ---------------------------------------------------------------------------
# Fused K-layer block: one multi-fragment taskflow per direction.
# ---------------------------------------------------------------------------


class FusedDroplessMoE:
    """K >= 2 consecutive dropless MoE layers as one fused taskflow.

    Fragment boundary contract (parallel routers): *every* layer's router
    is evaluated on the block input ``x``, so all K routing plans — and
    therefore the complete multi-fragment taskflow, boundary tiles
    included — are known before the first dispatch launches. Each
    inter-layer token remap (layer j's combine-weighted gather composed
    with layer j+1's send-buffer scatter) is exactly rank-local, so it
    runs as LayerBoundary tiles *inside* the taskflow and layer j+1's
    dispatch traffic overlaps layer j's combine tail.

    ``fuse=False`` keeps identical parallel-router semantics but executes
    the K per-layer schedules back to back with host bridge ops in
    between — the bit-exact sequential twin the fused path is tested
    against (fwd and bwd). ``fuse="auto"`` decides per batch through
    ``core.autoselect.select_fused``, which prices the in-taskflow
    boundary remap against the host-bridge round-trip the sequential twin
    pays per junction.
    """

    def __init__(self, dc: DroplessConfig, act: str = "swiglu",
                 cache: Optional[SSCCache] = None, fuse=True):
        if act != "swiglu":
            raise ValueError(
                f"dropless schedules execute the SwiGLU fragment; act={act!r}")
        if not (isinstance(fuse, bool) or fuse == "auto"):
            raise ValueError(f'fuse must be True, False or "auto", '
                             f"got {fuse!r}")
        self.dc = dc
        self.fuse = fuse
        self.cache = cache if cache is not None else get_process_cache(
            dc.cache_entries)
        self.impl = _make_fused_impl(dc, self.cache, fuse)


def _make_fused_impl(dc: DroplessConfig, cache: SSCCache, fuse):
    """Build ``block_impl(params, x, mc)`` for a fused K-layer block.

    ``params`` is a sequence of K >= 2 per-layer dicts, each with
    ``router`` / ``w_in`` / ``w_down``. Layer arrays travel through the
    custom-vjp fragment as tuples (pytree leaves), so one fragment
    signature serves every K.
    """

    def block_impl(params, x, mc):
        from repro.models.moe import router_topk

        params = list(params)
        K = len(params)
        if K < 2:
            raise ValueError(f"FusedDroplessMoE needs >= 2 layers, got {K}")
        B, S, d = x.shape
        T = B * S
        if T % dc.ep:
            raise ValueError(f"T={T} tokens not divisible by dropless "
                             f"ep={dc.ep}")
        xt = x.reshape(T, d)
        # Parallel-router contract: every plan derives from the block input.
        tps, tis = zip(*[router_topk(p["router"], xt, mc) for p in params])

        f = mc.d_expert
        e_loc = mc.e_total // dc.ep
        t_loc = T // dc.ep
        k = mc.top_k

        def _shape_w(w_in_h, w_down_h):
            w1 = np.asarray(w_in_h, np.float32).reshape(
                dc.ep, e_loc, d, 2 * f)
            w2 = np.asarray(w_down_h, np.float32).reshape(
                dc.ep, e_loc, f, d)
            return w1, w2

        def _do_fuse(cfgs, direction):
            if isinstance(fuse, bool):
                return fuse
            from repro.core.autoselect import select_fused
            return select_fused(tuple(cfgs), direction=direction).fuse

        def _dy_of(plan, rows, tp3, g3):
            # Per-row cotangent entering a backward fragment — statement
            # for statement the single-layer bwd_host build, so fused and
            # sequential stay bit-identical.
            dy = [np.zeros((plan.send_rows(s), d), np.float32)
                  for s in range(dc.ep)]
            for s in range(dc.ep):
                r = rows[s].reshape(-1)
                valid = r >= 0
                contrib = (tp3[s][:, :, None] * g3[s][:, None, :]).reshape(
                    -1, d)
                np.add.at(dy[s], r[valid], contrib[valid])
            return dy

        def _token_grads(bridge, dx_ret, y_ret, g3, tp3):
            # (dx_tokens, dtop_p) of one layer from its dx_ret buffers —
            # same j-loop accumulation order as the single-layer bwd_host.
            dx_tok = np.zeros((dc.ep, t_loc, d), np.float32)
            dtp = np.zeros((dc.ep, t_loc, k), np.float32)
            for s in range(dc.ep):
                if not bridge.plan.send_rows(s):
                    continue
                for j in range(k):
                    r = bridge.send_row[s, :, j]
                    valid = r >= 0
                    dx_tok[s, valid] += dx_ret[s][r[valid]]
                    dtp[s, valid, j] = np.einsum(
                        "td,td->t", g3[s, valid], y_ret[s][r[valid]])
            return dx_tok, dtp

        def _ret_bufs(st, tensor, plan):
            return [st.get(tensor, r) if plan.send_rows(r)
                    else np.zeros((0, d), np.float32) for r in range(dc.ep)]

        def _dw_of(st_l, suffix, plan):
            dw1 = np.stack([st_l.get(f"dW1{suffix}", r) if plan.recv_rows(r)
                            else np.zeros((e_loc, d, 2 * f), np.float32)
                            for r in range(dc.ep)])
            dw2 = np.stack([st_l.get(f"dW2{suffix}", r) if plan.recv_rows(r)
                            else np.zeros((e_loc, f, d), np.float32)
                            for r in range(dc.ep)])
            return (dw1.reshape(mc.e_total, d, 2 * f),
                    dw2.reshape(mc.e_total, f, d))

        # ---- host callbacks ------------------------------------------------
        def fwd_host(xt_h, tps_h, tis_h, wins_h, wdns_h):
            from repro.core import executor as ex
            from repro.core import fusion as fu
            from repro.models.moe import (bridge_combine, bridge_dispatch,
                                          fused_boundary_forward)

            xt_h = np.asarray(xt_h, np.float32)
            tps_h = [np.asarray(t, np.float32) for t in tps_h]
            ws = [_shape_w(wi, wd) for wi, wd in zip(wins_h, wdns_h)]
            bs = [_bridge_of(dc, ti, mc, cache) for ti in tis_h]
            cfgs = [_schedule_cfg(dc, b.plan, d, f) for b in bs]

            x_src = bridge_dispatch(bs[0], xt_h.reshape(dc.ep, t_loc, d))
            if _do_fuse(cfgs, "forward"):
                fs = cache.get_or_compile_fused(
                    cfgs, "forward", pipeline=dc.pipeline_spec())
                st = ex.ExecutorState(cfgs[0], fragment_cfgs=cfgs)
                fu.load_fused_forward_state(fs, cfgs, st, x_src,
                                            [w1 for w1, _ in ws],
                                            [w2 for _, w2 in ws])
                st.boundary_fns = {
                    (j, r): fn
                    for j in range(K - 1)
                    for r, fn in fused_boundary_forward(
                        bs[j], bs[j + 1], tps_h[j], d).items()}
                ex.execute(fs, st, rng=np.random.default_rng(0))
                y_ret = _ret_bufs(st, f"y_ret#L{K - 1}", bs[-1].plan)
            else:
                cur = x_src
                for j in range(K):
                    sj = cache.get_or_compile(cfgs[j], "forward",
                                              pipeline=dc.pipeline_spec())
                    stj = ex.ExecutorState(cfgs[j])
                    ex.load_forward_state_plan(cfgs[j], stj, cur,
                                               ws[j][0], ws[j][1])
                    ex.execute(sj, stj, rng=np.random.default_rng(0))
                    y_ret = _ret_bufs(stj, "y_ret", bs[j].plan)
                    if j < K - 1:
                        yj = bridge_combine(bs[j], y_ret, tps_h[j])
                        cur = bridge_dispatch(bs[j + 1], yj)
            y = bridge_combine(bs[-1], y_ret, tps_h[-1])
            return y.reshape(T, d)

        def bwd_host(xt_h, tps_h, tis_h, wins_h, wdns_h, g_h):
            from repro.core import executor as ex
            from repro.core import fusion as fu
            from repro.models.moe import (bridge_combine, bridge_dispatch,
                                          fused_boundary_backward)

            xt_h = np.asarray(xt_h, np.float32)
            tps_h = [np.asarray(t, np.float32) for t in tps_h]
            g = np.asarray(g_h, np.float32)
            ws = [_shape_w(wi, wd) for wi, wd in zip(wins_h, wdns_h)]
            bs = [_bridge_of(dc, ti, mc) for ti in tis_h]
            cfgs = [_schedule_cfg(dc, b.plan, d, f) for b in bs]
            g3 = g.reshape(dc.ep, t_loc, d)
            tp3s = [t.reshape(dc.ep, t_loc, k) for t in tps_h]

            # Recompute every layer's saved activations.
            fwds = []
            cur = bridge_dispatch(bs[0], xt_h.reshape(dc.ep, t_loc, d))
            for j in range(K):
                fwds.append(ex.reference_forward_plan(cfgs[j], cur,
                                                      ws[j][0], ws[j][1]))
                if j < K - 1:
                    yj = bridge_combine(bs[j], fwds[j]["y_ret"], tps_h[j])
                    cur = bridge_dispatch(bs[j + 1], yj)
            dy_top = _dy_of(bs[-1].plan, bs[-1].send_row, tp3s[-1], g3)

            dtps = [None] * K
            dws = [None] * K
            if _do_fuse(cfgs, "backward"):
                fs = cache.get_or_compile_fused(
                    cfgs, "backward", pipeline=dc.pipeline_spec())
                exec_cfgs = cfgs[::-1]       # top layer's gradient first
                st = ex.ExecutorState(cfgs[-1], fragment_cfgs=exec_cfgs)
                fu.load_fused_backward_state(
                    fs, exec_cfgs, st, dy_top, fwds[::-1],
                    [w1 for w1, _ in ws][::-1], [w2 for _, w2 in ws][::-1])
                # Execution junction e sits between execution positions e
                # and e+1 (layers K-1-e and K-2-e) — the physical junction
                # p = K-2-e, whose remap transposes the forward boundary.
                st.boundary_fns = {}
                for e in range(K - 1):
                    p = K - 2 - e
                    for r, fn in fused_boundary_backward(
                            bs[p], bs[p + 1], tps_h[p], d).items():
                        st.boundary_fns[(e, r)] = fn
                ex.execute(fs, st, rng=np.random.default_rng(0))
                g_up = g3
                for layer in range(K - 1, -1, -1):
                    dx_tok, dtps[layer] = _token_grads(
                        bs[layer],
                        _ret_bufs(st, f"dx_ret#L{layer}", bs[layer].plan),
                        fwds[layer]["y_ret"], g_up, tp3s[layer])
                    g_up = dx_tok
                    dws[layer] = _dw_of(st, f"#L{layer}", bs[layer].plan)
            else:
                g_up = g3
                dy = dy_top
                for layer in range(K - 1, -1, -1):
                    sj = cache.get_or_compile(cfgs[layer], "backward",
                                              pipeline=dc.pipeline_spec())
                    stj = ex.ExecutorState(cfgs[layer])
                    ex.load_backward_state_plan(cfgs[layer], stj,
                                                fwds[layer], ws[layer][0],
                                                ws[layer][1], dy)
                    ex.execute(sj, stj, rng=np.random.default_rng(0))
                    dx_tok, dtps[layer] = _token_grads(
                        bs[layer], _ret_bufs(stj, "dx_ret", bs[layer].plan),
                        fwds[layer]["y_ret"], g_up, tp3s[layer])
                    g_up = dx_tok
                    dws[layer] = _dw_of(stj, "", bs[layer].plan)
                    if layer > 0:
                        dy = _dy_of(bs[layer - 1].plan,
                                    bs[layer - 1].send_row,
                                    tp3s[layer - 1], dx_tok)

            return (g_up.reshape(T, d),
                    tuple(dt.reshape(T, k) for dt in dtps),
                    tuple(dw1 for dw1, _ in dws),
                    tuple(dw2 for _, dw2 in dws))

        # ---- custom-vjp fused fragment ------------------------------------
        @jax.custom_vjp
        def fragment(xt, tps, tis, w_ins, w_downs):
            return jax.pure_callback(
                fwd_host, jax.ShapeDtypeStruct((T, d), jnp.float32),
                xt, tps, tis, w_ins, w_downs)

        def fragment_fwd(xt, tps, tis, w_ins, w_downs):
            y = fragment(xt, tps, tis, w_ins, w_downs)
            return y, (xt, tps, tis, w_ins, w_downs)

        def fragment_bwd(res, g):
            xt, tps, tis, w_ins, w_downs = res
            dxt, dtps, dw1s, dw2s = jax.pure_callback(
                bwd_host,
                (jax.ShapeDtypeStruct((T, d), jnp.float32),
                 tuple(jax.ShapeDtypeStruct((T, k), jnp.float32)
                       for _ in range(K)),
                 tuple(jax.ShapeDtypeStruct(w.shape, jnp.float32)
                       for w in w_ins),
                 tuple(jax.ShapeDtypeStruct(w.shape, jnp.float32)
                       for w in w_downs)),
                xt, tps, tis, w_ins, w_downs, g)
            f0 = lambda t: np.zeros(t.shape, dtype=jax.dtypes.float0)
            return (dxt.astype(xt.dtype),
                    tuple(dt.astype(tp.dtype)
                          for dt, tp in zip(dtps, tps)),
                    tuple(f0(ti) for ti in tis),
                    tuple(dw.astype(w.dtype)
                          for dw, w in zip(dw1s, w_ins)),
                    tuple(dw.astype(w.dtype)
                          for dw, w in zip(dw2s, w_downs)))

        fragment.defvjp(fragment_fwd, fragment_bwd)

        y = fragment(xt, tuple(tps), tuple(tis),
                     tuple(p["w_in"] for p in params),
                     tuple(p["w_down"] for p in params))
        return y.astype(x.dtype).reshape(B, S, d)

    return block_impl
