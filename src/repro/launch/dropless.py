"""Dropless, data-dependent MoE execution for the training step.

The fixed-capacity paths (``moe_grouped``, ``parallel/ep.py``) pad every
(destination, expert) pair to a static capacity and *drop* overflow tokens so
all shapes stay static under jit. This module is the opposite trade, and the
paper's core claim wired into training: each batch's **actual** router output
is turned into a :class:`~repro.core.routing.RoutingPlan` with
``plan_from_routing(capacity=None)`` (no token is ever dropped), the plan's
schedule is fetched from — or compiled into — a process-level
:class:`~repro.core.ssc.SSCCache`, and the **plan-sized** tile taskflow is
executed instead of the fixed-capacity one.

Because a fresh imbalanced plan would recompile every step, plans are
*shape-bucketed* first (``bucket``: a ``repro.core.buckets.BucketSpec``
quantizing per-cell counts up to policy buckets — linear, geometric, or a
fitted ladder — padding rows stay zero) so that batch-to-batch routing
jitter maps to a stable cache key; ``bench_dropless`` measures the
recompile-rate and padded-row difference between exact and bucketed keys
per policy, and the per-step ``ssc_pad_ratio`` metric reports what the
active policy costs.

Integration is the same pluggable ``moe_impl(params, x, mc)`` seam the EP
path uses: the router (and therefore the gradient into router weights) runs
in JAX, while the schedulable Dispatch→GMM→SwiGLU→GMM→Combine fragment runs
through ``jax.pure_callback`` on the schedule executor, with a
``jax.custom_vjp`` whose backward executes the backward-direction schedule —
so ``train_step`` trains *through* compiled schedules, forward and backward.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.odg import ScheduleConfig
from repro.core.ssc import SSCCache


@dataclasses.dataclass(frozen=True)
class DroplessConfig:
    """Configuration of the dropless data-dependent training path.

    ``ep`` is the size of the *compiled* EP group: tokens are split
    contiguously over ``ep`` virtual source ranks and experts over ``ep``
    expert shards, matching the fragment the scheduling stack compiles.
    ``bucket`` quantizes per-cell plan counts into shape buckets: a
    :class:`repro.core.buckets.BucketSpec` or anything
    ``BucketSpec.from_any`` accepts (``"geometric:8"``, a fitted ladder,
    an int). ``bucket_rows`` is the deprecated linear-bucket int shim —
    ``DroplessConfig(bucket_rows=r)`` and
    ``DroplessConfig(bucket=BucketSpec.linear(r))`` produce SSC-key
    identical schedules (``bucket`` wins when both are given; 1 = exact
    plans, every distinct routing compiles its own SSC).
    ``pipeline`` is a schedule-pass
    pipeline spec applied to both directions (direction-gated passes such as
    ``gmm_interleave`` no-op on forward) — or the string ``"auto"``, which
    resolves per batch-plan and per direction through the cost-model-guided
    selector (``core/autoselect.py``) inside ``SSCCache``: every batch gets
    the predicted-best pipeline (and ``gmm_m_split`` budget) for its actual
    routing, and bucketed plans memoize both the selection and the schedule.
    """

    ep: int = 1
    bucket_rows: int = 16            # deprecated: use bucket=
    bucket: object = None            # BucketSpec | int | str | key tuple
    gmm_m_split: int = 1
    gmm_split_mode: str = "source_aligned"
    pipeline: tuple | str = ("ratr", "gmm_interleave")
    cache_entries: int = 64

    def bucket_spec(self):
        """The effective :class:`~repro.core.buckets.BucketSpec` —
        ``bucket`` when given, else the legacy linear ``bucket_rows``."""
        from repro.core.buckets import BucketSpec, normalize_bucket
        if self.bucket is not None:
            return normalize_bucket(self.bucket)
        return BucketSpec.linear(self.bucket_rows)

    def __post_init__(self):
        # Fail at construction, not at the first train step inside a jitted
        # pure_callback: the only valid string is "auto" (SCHED_PIPELINES
        # names like "ratr+crit" go through core.passes.pipeline_arg — the
        # --sched CLI does), bare pass names must be registered, and the
        # bucket spec must parse.
        self.bucket_spec()
        from repro.core.passes import get_pass
        if isinstance(self.pipeline, str):
            if self.pipeline != "auto":
                raise ValueError(
                    f"pipeline={self.pipeline!r}: the only string spec is "
                    f'"auto"; for a named pipeline use '
                    f"core.passes.pipeline_arg({self.pipeline!r}) or a "
                    f"pass-name tuple")
            return
        for item in self.pipeline:
            if isinstance(item, str):
                get_pass(item)          # fail fast on unknown names

    def pipeline_spec(self):
        """The ``pipeline=`` argument for ``SSCCache``: ``"auto"`` or a
        list spec."""
        return self.pipeline if isinstance(self.pipeline, str) \
            else list(self.pipeline)


_PROCESS_CACHE: Optional[SSCCache] = None


def get_process_cache(max_entries: int = 64) -> SSCCache:
    """The process-level SSC cache shared by every dropless step fn.

    The cache keeps the *largest* bound ever requested: a later consumer
    asking for more headroom grows it (entries are never proactively
    evicted by a smaller request).
    """
    global _PROCESS_CACHE
    if _PROCESS_CACHE is None:
        _PROCESS_CACHE = SSCCache(max_entries=max_entries)
    elif max_entries > _PROCESS_CACHE.max_entries:
        _PROCESS_CACHE.max_entries = max_entries
    return _PROCESS_CACHE


class DroplessMoE:
    """A dropless ``moe_impl`` plus its schedule cache handle."""

    def __init__(self, dc: DroplessConfig, act: str = "swiglu",
                 cache: Optional[SSCCache] = None):
        if act != "swiglu":
            raise ValueError(
                f"dropless schedules execute the SwiGLU fragment; act={act!r}")
        self.dc = dc
        self.cache = cache if cache is not None else get_process_cache(
            dc.cache_entries)
        self.impl = _make_impl(dc, self.cache)
        info = self.cache.info()
        self._snapshot = (info["hits"], info["misses"], info["evictions"],
                          info["exact_rows"], info["padded_rows"])

    def rescale(self, new_ep: Optional[int] = None,
                dead_ranks=None) -> "DroplessMoE":
        """A fresh impl on the surviving mesh, sharing this handle's cache.

        The elastic-rescale entry point of the dropless path: pass the new
        mesh size directly or the lost ranks (``new_ep`` defaults to the
        survivor count). The shared ``SSCCache`` is **re-keyed** for the
        new mesh — old-mesh entries stay resident (they hit again should
        the mesh grow back) but bear the LRU pressure first, and the new
        handle's per-batch plans compile through the normal
        ``plan_from_routing`` → SSC path with ``ep``-tagged bucket keys, so
        the two mesh populations never alias. Remapped plans
        (``core.elastic.remap_plan``) execute bit-for-bit like plans built
        natively on the small mesh, so no schedule state needs migrating.
        """
        if new_ep is None:
            if dead_ranks is None:
                raise ValueError("pass new_ep= and/or dead_ranks=")
            from repro.core.elastic import surviving_ranks
            new_ep = len(surviving_ranks(self.dc.ep, dead_ranks))
        new_ep = int(new_ep)
        if new_ep < 1:
            raise ValueError(f"new_ep must be >= 1, got {new_ep}")
        self.cache.rekey_for_mesh(new_ep)
        return DroplessMoE(dataclasses.replace(self.dc, ep=new_ep),
                           cache=self.cache)

    def step_stats(self) -> dict:
        """Cache counter deltas since this handle's previous call.

        The snapshot lives on the handle, not the (possibly shared) cache,
        so independent consumers — two models on one process cache, or a
        monitoring loop calling ``cache.step_stats()`` — don't zero each
        other's per-step numbers. With a shared cache the deltas still
        aggregate *all* activity between this handle's calls; give each
        model its own ``SSCCache`` when per-model attribution matters.
        """
        info = self.cache.info()
        cur = (info["hits"], info["misses"], info["evictions"],
               info["exact_rows"], info["padded_rows"])
        last = self._snapshot
        self._snapshot = cur
        d_exact, d_pad = cur[3] - last[3], cur[4] - last[4]
        return {"hits": cur[0] - last[0], "misses": cur[1] - last[1],
                "evictions": cur[2] - last[2], "entries": info["entries"],
                "pad_ratio": d_pad / d_exact if d_exact else 1.0}


def make_moe_dropless(model_cfg, dc: DroplessConfig,
                      cache: Optional[SSCCache] = None) -> DroplessMoE:
    """Build the dropless MoE impl for a model config (validates shapes)."""
    mc = model_cfg.moe
    if mc is None:
        raise ValueError("dropless MoE requires a MoE model config")
    if mc.e_total % dc.ep:
        raise ValueError(f"e_total={mc.e_total} not divisible by "
                         f"dropless ep={dc.ep}")
    return DroplessMoE(dc, act=model_cfg.act, cache=cache)


# ---------------------------------------------------------------------------
# The schedulable fragment as a custom-vjp JAX function backed by callbacks.
# ---------------------------------------------------------------------------


def _schedule_cfg(dc: DroplessConfig, plan, d_model: int,
                  d_ff: int) -> ScheduleConfig:
    return ScheduleConfig(ep=dc.ep, e_loc=plan.e_loc, rows=0,
                          d_model=d_model, d_ff=d_ff,
                          gmm_m_split=dc.gmm_m_split,
                          gmm_split_mode=dc.gmm_split_mode, plan=plan,
                          bucket=dc.bucket_spec().key())


def _bridge_of(dc: DroplessConfig, top_i, mc, cache: Optional[SSCCache] = None):
    from repro.models.moe import plan_from_routing
    bridge = plan_from_routing(top_i, mc, dc.ep, capacity=None,
                               bucket=dc.bucket_spec())
    if cache is not None:
        # Dropless keeps every choice, so the exact row count is the full
        # [ep, T_loc, k] choice grid; the bucketed plan's total is what the
        # executor actually allocates/streams.
        cache.record_rows(int(bridge.send_row.size),
                          bridge.plan.total_rows)
    return bridge


def _exec_forward(dc: DroplessConfig, cache: SSCCache, mc,
                  xt, top_p, top_i, w1, w2):
    """Host side: plan → cached schedule → executor → combined tokens.

    ``w1``/``w2`` are the per-rank expert weights ``[ep, e_loc, d, 2f]`` /
    ``[ep, e_loc, f, d]``. Returns ``y [T, d]`` float32.
    """
    from repro.core import executor as ex
    from repro.models.moe import bridge_combine, bridge_dispatch

    xt = np.asarray(xt, dtype=np.float32)
    top_p = np.asarray(top_p, dtype=np.float32)
    top_i = np.asarray(top_i)
    T, d = xt.shape
    f = mc.d_expert

    bridge = _bridge_of(dc, top_i, mc, cache)
    plan = bridge.plan
    cfg = _schedule_cfg(dc, plan, d, f)
    sched = cache.get_or_compile(cfg, "forward",
                                 pipeline=dc.pipeline_spec())

    x_src = bridge_dispatch(bridge, xt.reshape(dc.ep, T // dc.ep, d))
    st = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
    ex.execute(sched, st, rng=np.random.default_rng(0))
    y_ret = [st.get("y_ret", r) if plan.send_rows(r)
             else np.zeros((0, d), np.float32) for r in range(dc.ep)]
    y = bridge_combine(bridge, y_ret, top_p)
    return y.reshape(T, d)


def _make_impl(dc: DroplessConfig, cache: SSCCache, live=None):
    """Build ``moe_impl(params, x, mc)`` executing plan-sized schedules.

    ``live`` is the online-tuning seam (``launch/online.py``): a host-side
    callable ``live(top_i, mc, direction) -> DroplessConfig`` invoked from
    inside the pure_callback host functions on every step. The host fns run
    per step even under a single jit trace, and the returned config may only
    differ in fields that don't change traced shapes (the bucket spec, the
    pipeline) — so the tuner can observe live routing and hot-swap the
    quantization policy without retracing. ``None`` (the default, and the
    whole training path) pins the construction-time ``dc``.
    """

    def moe_impl(params, x, mc):
        from repro.models.moe import router_topk

        B, S, d = x.shape
        T = B * S
        if T % dc.ep:
            raise ValueError(f"T={T} tokens not divisible by dropless "
                             f"ep={dc.ep}")
        xt = x.reshape(T, d)
        top_p, top_i = router_topk(params["router"], xt, mc)

        f = mc.d_expert

        # ---- host callbacks ------------------------------------------------
        def fwd_host(xt_h, top_p_h, top_i_h, w_in_h, w_down_h):
            dcc = live(np.asarray(top_i_h), mc, "forward") if live else dc
            w1 = np.asarray(w_in_h, np.float32).reshape(
                dcc.ep, mc.e_total // dcc.ep, d, 2 * f)
            w2 = np.asarray(w_down_h, np.float32).reshape(
                dcc.ep, mc.e_total // dcc.ep, f, d)
            return _exec_forward(dcc, cache, mc, xt_h, top_p_h, top_i_h,
                                 w1, w2)

        def bwd_host(xt_h, top_p_h, top_i_h, w_in_h, w_down_h, g_h):
            from repro.core import executor as ex
            from repro.models.moe import bridge_dispatch

            dcc = live(np.asarray(top_i_h), mc, "backward") if live else dc
            xt_h = np.asarray(xt_h, np.float32)
            top_p_h = np.asarray(top_p_h, np.float32)
            top_i_h = np.asarray(top_i_h)
            g = np.asarray(g_h, np.float32)
            e_loc = mc.e_total // dcc.ep
            w1 = np.asarray(w_in_h, np.float32).reshape(dcc.ep, e_loc, d,
                                                        2 * f)
            w2 = np.asarray(w_down_h, np.float32).reshape(dcc.ep, e_loc, f, d)

            bridge = _bridge_of(dcc, top_i_h, mc)
            plan = bridge.plan
            cfg = _schedule_cfg(dcc, plan, d, f)
            t_loc = T // dcc.ep
            rows = bridge.send_row                        # [ep, t_loc, k]
            g3 = g.reshape(dcc.ep, t_loc, d)
            tp3 = top_p_h.reshape(dcc.ep, t_loc, mc.top_k)

            # Recompute the saved activations the backward schedule consumes.
            x_src = bridge_dispatch(bridge, xt_h.reshape(dcc.ep, t_loc, d))
            fwd = ex.reference_forward_plan(cfg, x_src, w1, w2)

            # Per-row cotangent entering the fragment: dy[row] = p · g_token.
            dy = [np.zeros((plan.send_rows(s), d), np.float32)
                  for s in range(dcc.ep)]
            for s in range(dcc.ep):
                r = rows[s].reshape(-1)
                valid = r >= 0
                contrib = (tp3[s][:, :, None] * g3[s][:, None, :]).reshape(
                    -1, d)
                np.add.at(dy[s], r[valid], contrib[valid])

            sched = cache.get_or_compile(cfg, "backward",
                                         pipeline=dcc.pipeline_spec())
            st = ex.ExecutorState(cfg)
            ex.load_backward_state_plan(cfg, st, fwd, w1, w2, dy)
            ex.execute(sched, st, rng=np.random.default_rng(0))

            dxt = np.zeros((dcc.ep, t_loc, d), np.float32)
            dtp = np.zeros((dcc.ep, t_loc, mc.top_k), np.float32)
            for s in range(dcc.ep):
                if not plan.send_rows(s):
                    continue
                dx_ret = st.get("dx_ret", s)
                y_ret = fwd["y_ret"][s]
                for j in range(mc.top_k):
                    r = rows[s, :, j]
                    valid = r >= 0
                    dxt[s, valid] += dx_ret[r[valid]]
                    dtp[s, valid, j] = np.einsum(
                        "td,td->t", g3[s, valid], y_ret[r[valid]])
            dw1 = np.stack([st.get("dW1", r) if plan.recv_rows(r)
                            else np.zeros((e_loc, d, 2 * f), np.float32)
                            for r in range(dcc.ep)])
            dw2 = np.stack([st.get("dW2", r) if plan.recv_rows(r)
                            else np.zeros((e_loc, f, d), np.float32)
                            for r in range(dcc.ep)])
            return (dxt.reshape(T, d), dtp.reshape(T, mc.top_k),
                    dw1.reshape(mc.e_total, d, 2 * f),
                    dw2.reshape(mc.e_total, f, d))

        # ---- custom-vjp fragment ------------------------------------------
        @jax.custom_vjp
        def fragment(xt, top_p, top_i, w_in, w_down):
            return jax.pure_callback(
                fwd_host, jax.ShapeDtypeStruct((T, d), jnp.float32),
                xt, top_p, top_i, w_in, w_down)

        def fragment_fwd(xt, top_p, top_i, w_in, w_down):
            y = fragment(xt, top_p, top_i, w_in, w_down)
            return y, (xt, top_p, top_i, w_in, w_down)

        def fragment_bwd(res, g):
            xt, top_p, top_i, w_in, w_down = res
            dxt, dtp, dw1, dw2 = jax.pure_callback(
                bwd_host,
                (jax.ShapeDtypeStruct((T, d), jnp.float32),
                 jax.ShapeDtypeStruct((T, mc.top_k), jnp.float32),
                 jax.ShapeDtypeStruct(w_in.shape, jnp.float32),
                 jax.ShapeDtypeStruct(w_down.shape, jnp.float32)),
                xt, top_p, top_i, w_in, w_down, g)
            return (dxt.astype(xt.dtype), dtp.astype(top_p.dtype),
                    np.zeros(top_i.shape, dtype=jax.dtypes.float0),
                    dw1.astype(w_in.dtype), dw2.astype(w_down.dtype))

        fragment.defvjp(fragment_fwd, fragment_bwd)

        y = fragment(xt, top_p, top_i, params["w_in"], params["w_down"])
        return y.astype(x.dtype).reshape(B, S, d)

    return moe_impl


# ---------------------------------------------------------------------------
# Fused two-layer block: one multi-fragment taskflow per direction.
# ---------------------------------------------------------------------------


class FusedDroplessMoE:
    """Two consecutive dropless MoE layers as one fused taskflow.

    Fragment boundary contract (parallel routers): *both* layers' routers
    are evaluated on the block input ``x``, so both routing plans — and
    therefore the complete multi-fragment taskflow, boundary tiles
    included — are known before the first dispatch launches. The
    inter-layer token remap (layer 0's combine-weighted gather composed
    with layer 1's send-buffer scatter) is exactly rank-local, so it runs
    as LayerBoundary tiles *inside* the taskflow and layer 1's dispatch
    traffic overlaps layer 0's combine tail.

    ``fuse=False`` keeps identical parallel-router semantics but executes
    the two per-layer schedules back to back with host bridge ops in
    between — the bit-exact sequential twin the fused path is tested
    against (fwd and bwd).
    """

    def __init__(self, dc: DroplessConfig, act: str = "swiglu",
                 cache: Optional[SSCCache] = None, fuse: bool = True):
        if act != "swiglu":
            raise ValueError(
                f"dropless schedules execute the SwiGLU fragment; act={act!r}")
        self.dc = dc
        self.fuse = fuse
        self.cache = cache if cache is not None else get_process_cache(
            dc.cache_entries)
        self.impl = _make_fused_impl(dc, self.cache, fuse)


def _make_fused_impl(dc: DroplessConfig, cache: SSCCache, fuse: bool):
    """Build ``block_impl(params, x, mc)`` for a fused two-layer block.

    ``params`` is a two-element sequence of per-layer dicts, each with
    ``router`` / ``w_in`` / ``w_down``.
    """

    def block_impl(params, x, mc):
        from repro.models.moe import router_topk

        p_lo, p_hi = params
        B, S, d = x.shape
        T = B * S
        if T % dc.ep:
            raise ValueError(f"T={T} tokens not divisible by dropless "
                             f"ep={dc.ep}")
        xt = x.reshape(T, d)
        # Parallel-router contract: both plans derive from the block input.
        tp0, ti0 = router_topk(p_lo["router"], xt, mc)
        tp1, ti1 = router_topk(p_hi["router"], xt, mc)

        f = mc.d_expert
        e_loc = mc.e_total // dc.ep
        t_loc = T // dc.ep
        k = mc.top_k

        def _shape_w(w_in_h, w_down_h):
            w1 = np.asarray(w_in_h, np.float32).reshape(
                dc.ep, e_loc, d, 2 * f)
            w2 = np.asarray(w_down_h, np.float32).reshape(
                dc.ep, e_loc, f, d)
            return w1, w2

        def _dy_of(plan, rows, tp3, g3):
            # Per-row cotangent entering a backward fragment — statement
            # for statement the single-layer bwd_host build, so fused and
            # sequential stay bit-identical.
            dy = [np.zeros((plan.send_rows(s), d), np.float32)
                  for s in range(dc.ep)]
            for s in range(dc.ep):
                r = rows[s].reshape(-1)
                valid = r >= 0
                contrib = (tp3[s][:, :, None] * g3[s][:, None, :]).reshape(
                    -1, d)
                np.add.at(dy[s], r[valid], contrib[valid])
            return dy

        def _token_grads(bridge, dx_ret, y_ret, g3, tp3):
            # (dx_tokens, dtop_p) of one layer from its dx_ret buffers —
            # same j-loop accumulation order as the single-layer bwd_host.
            dx_tok = np.zeros((dc.ep, t_loc, d), np.float32)
            dtp = np.zeros((dc.ep, t_loc, k), np.float32)
            for s in range(dc.ep):
                if not bridge.plan.send_rows(s):
                    continue
                for j in range(k):
                    r = bridge.send_row[s, :, j]
                    valid = r >= 0
                    dx_tok[s, valid] += dx_ret[s][r[valid]]
                    dtp[s, valid, j] = np.einsum(
                        "td,td->t", g3[s, valid], y_ret[s][r[valid]])
            return dx_tok, dtp

        def _ret_bufs(st, tensor, plan):
            return [st.get(tensor, r) if plan.send_rows(r)
                    else np.zeros((0, d), np.float32) for r in range(dc.ep)]

        # ---- host callbacks ------------------------------------------------
        def fwd_host(xt_h, tp0_h, ti0_h, tp1_h, ti1_h,
                     win0, wdn0, win1, wdn1):
            from repro.core import executor as ex
            from repro.core import fusion as fu
            from repro.models.moe import (bridge_combine, bridge_dispatch,
                                          fused_boundary_forward)

            xt_h = np.asarray(xt_h, np.float32)
            tp0_h = np.asarray(tp0_h, np.float32)
            tp1_h = np.asarray(tp1_h, np.float32)
            w10, w20 = _shape_w(win0, wdn0)
            w11, w21 = _shape_w(win1, wdn1)
            b0 = _bridge_of(dc, ti0_h, mc, cache)
            b1 = _bridge_of(dc, ti1_h, mc, cache)
            cfg0 = _schedule_cfg(dc, b0.plan, d, f)
            cfg1 = _schedule_cfg(dc, b1.plan, d, f)

            x_src = bridge_dispatch(b0, xt_h.reshape(dc.ep, t_loc, d))
            if fuse:
                fs = cache.get_or_compile_fused(
                    [cfg0, cfg1], "forward", pipeline=dc.pipeline_spec())
                st = ex.ExecutorState(cfg0, fragment_cfgs=[cfg0, cfg1])
                fu.load_fused_forward_state(fs, [cfg0, cfg1], st, x_src,
                                            [w10, w11], [w20, w21])
                st.boundary_fns = {
                    (0, r): fn for r, fn in fused_boundary_forward(
                        b0, b1, tp0_h, d).items()}
                ex.execute(fs, st, rng=np.random.default_rng(0))
                y_ret1 = _ret_bufs(st, "y_ret#L1", b1.plan)
            else:
                s0 = cache.get_or_compile(cfg0, "forward",
                                          pipeline=dc.pipeline_spec())
                st0 = ex.ExecutorState(cfg0)
                ex.load_forward_state_plan(cfg0, st0, x_src, w10, w20)
                ex.execute(s0, st0, rng=np.random.default_rng(0))
                y0 = bridge_combine(b0, _ret_bufs(st0, "y_ret", b0.plan),
                                    tp0_h)
                s1 = cache.get_or_compile(cfg1, "forward",
                                          pipeline=dc.pipeline_spec())
                st1 = ex.ExecutorState(cfg1)
                ex.load_forward_state_plan(cfg1, st1,
                                           bridge_dispatch(b1, y0), w11, w21)
                ex.execute(s1, st1, rng=np.random.default_rng(0))
                y_ret1 = _ret_bufs(st1, "y_ret", b1.plan)
            y = bridge_combine(b1, y_ret1, tp1_h)
            return y.reshape(T, d)

        def bwd_host(xt_h, tp0_h, ti0_h, tp1_h, ti1_h,
                     win0, wdn0, win1, wdn1, g_h):
            from repro.core import executor as ex
            from repro.core import fusion as fu
            from repro.models.moe import (bridge_combine, bridge_dispatch,
                                          fused_boundary_backward)

            xt_h = np.asarray(xt_h, np.float32)
            tp0_h = np.asarray(tp0_h, np.float32)
            tp1_h = np.asarray(tp1_h, np.float32)
            g = np.asarray(g_h, np.float32)
            w10, w20 = _shape_w(win0, wdn0)
            w11, w21 = _shape_w(win1, wdn1)
            b0 = _bridge_of(dc, ti0_h, mc)
            b1 = _bridge_of(dc, ti1_h, mc)
            cfg0 = _schedule_cfg(dc, b0.plan, d, f)
            cfg1 = _schedule_cfg(dc, b1.plan, d, f)
            g3 = g.reshape(dc.ep, t_loc, d)
            tp03 = tp0_h.reshape(dc.ep, t_loc, k)
            tp13 = tp1_h.reshape(dc.ep, t_loc, k)

            # Recompute both layers' saved activations.
            x_src0 = bridge_dispatch(b0, xt_h.reshape(dc.ep, t_loc, d))
            fwd0 = ex.reference_forward_plan(cfg0, x_src0, w10, w20)
            y0 = bridge_combine(b0, fwd0["y_ret"], tp0_h)
            fwd1 = ex.reference_forward_plan(cfg1, bridge_dispatch(b1, y0),
                                             w11, w21)
            dy1 = _dy_of(b1.plan, b1.send_row, tp13, g3)

            if fuse:
                fs = cache.get_or_compile_fused(
                    [cfg0, cfg1], "backward", pipeline=dc.pipeline_spec())
                st = ex.ExecutorState(cfg1, fragment_cfgs=[cfg1, cfg0])
                fu.load_fused_backward_state(fs, [cfg1, cfg0], st, dy1,
                                             [fwd1, fwd0], [w11, w10],
                                             [w21, w20])
                st.boundary_fns = {
                    (0, r): fn for r, fn in fused_boundary_backward(
                        b0, b1, tp0_h, d).items()}
                ex.execute(fs, st, rng=np.random.default_rng(0))
                dx1_tok, dtp1 = _token_grads(
                    b1, _ret_bufs(st, "dx_ret#L1", b1.plan),
                    fwd1["y_ret"], g3, tp13)
                dx0_tok, dtp0 = _token_grads(
                    b0, _ret_bufs(st, "dx_ret#L0", b0.plan),
                    fwd0["y_ret"], dx1_tok, tp03)
                sts = {0: st, 1: st}
                suff = {0: "#L0", 1: "#L1"}
            else:
                s1 = cache.get_or_compile(cfg1, "backward",
                                          pipeline=dc.pipeline_spec())
                st1 = ex.ExecutorState(cfg1)
                ex.load_backward_state_plan(cfg1, st1, fwd1, w11, w21, dy1)
                ex.execute(s1, st1, rng=np.random.default_rng(0))
                dx1_tok, dtp1 = _token_grads(
                    b1, _ret_bufs(st1, "dx_ret", b1.plan),
                    fwd1["y_ret"], g3, tp13)
                dy0 = _dy_of(b0.plan, b0.send_row, tp03, dx1_tok)
                s0 = cache.get_or_compile(cfg0, "backward",
                                          pipeline=dc.pipeline_spec())
                st0 = ex.ExecutorState(cfg0)
                ex.load_backward_state_plan(cfg0, st0, fwd0, w10, w20, dy0)
                ex.execute(s0, st0, rng=np.random.default_rng(0))
                dx0_tok, dtp0 = _token_grads(
                    b0, _ret_bufs(st0, "dx_ret", b0.plan),
                    fwd0["y_ret"], dx1_tok, tp03)
                sts = {0: st0, 1: st1}
                suff = {0: "", 1: ""}

            def _dw(layer, plan):
                st_l = sts[layer]
                s = suff[layer]
                dw1 = np.stack([st_l.get(f"dW1{s}", r) if plan.recv_rows(r)
                                else np.zeros((e_loc, d, 2 * f), np.float32)
                                for r in range(dc.ep)])
                dw2 = np.stack([st_l.get(f"dW2{s}", r) if plan.recv_rows(r)
                                else np.zeros((e_loc, f, d), np.float32)
                                for r in range(dc.ep)])
                return (dw1.reshape(mc.e_total, d, 2 * f),
                        dw2.reshape(mc.e_total, f, d))

            dw1_0, dw2_0 = _dw(0, b0.plan)
            dw1_1, dw2_1 = _dw(1, b1.plan)
            return (dx0_tok.reshape(T, d), dtp0.reshape(T, k),
                    dtp1.reshape(T, k), dw1_0, dw2_0, dw1_1, dw2_1)

        # ---- custom-vjp fused fragment ------------------------------------
        @jax.custom_vjp
        def fragment(xt, tp0, ti0, tp1, ti1, w_in0, w_down0, w_in1, w_down1):
            return jax.pure_callback(
                fwd_host, jax.ShapeDtypeStruct((T, d), jnp.float32),
                xt, tp0, ti0, tp1, ti1, w_in0, w_down0, w_in1, w_down1)

        def fragment_fwd(xt, tp0, ti0, tp1, ti1,
                         w_in0, w_down0, w_in1, w_down1):
            y = fragment(xt, tp0, ti0, tp1, ti1,
                         w_in0, w_down0, w_in1, w_down1)
            return y, (xt, tp0, ti0, tp1, ti1,
                       w_in0, w_down0, w_in1, w_down1)

        def fragment_bwd(res, g):
            xt, tp0, ti0, tp1, ti1, w_in0, w_down0, w_in1, w_down1 = res
            out = jax.pure_callback(
                bwd_host,
                (jax.ShapeDtypeStruct((T, d), jnp.float32),
                 jax.ShapeDtypeStruct((T, k), jnp.float32),
                 jax.ShapeDtypeStruct((T, k), jnp.float32),
                 jax.ShapeDtypeStruct(w_in0.shape, jnp.float32),
                 jax.ShapeDtypeStruct(w_down0.shape, jnp.float32),
                 jax.ShapeDtypeStruct(w_in1.shape, jnp.float32),
                 jax.ShapeDtypeStruct(w_down1.shape, jnp.float32)),
                xt, tp0, ti0, tp1, ti1, w_in0, w_down0, w_in1, w_down1, g)
            dxt, dtp0, dtp1, dw1_0, dw2_0, dw1_1, dw2_1 = out
            f0 = lambda t: np.zeros(t.shape, dtype=jax.dtypes.float0)
            return (dxt.astype(xt.dtype), dtp0.astype(tp0.dtype), f0(ti0),
                    dtp1.astype(tp1.dtype), f0(ti1),
                    dw1_0.astype(w_in0.dtype), dw2_0.astype(w_down0.dtype),
                    dw1_1.astype(w_in1.dtype), dw2_1.astype(w_down1.dtype))

        fragment.defvjp(fragment_fwd, fragment_bwd)

        y = fragment(xt, tp0, ti0, tp1, ti1,
                     p_lo["w_in"], p_lo["w_down"],
                     p_hi["w_in"], p_hi["w_down"])
        return y.astype(x.dtype).reshape(B, S, d)

    return block_impl
