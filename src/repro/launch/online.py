"""Self-tuning SLO-aware serving: online bucket/selector refitting.

Closes the serving loop the replay harness (``launch/replay.py``) only
measures offline: the compile-time wins of plan-keyed SSC caching hold up
under *live* traffic only if the quantization ladder and the pipeline
selector track the traffic they serve. This module owns that loop:

* :class:`OnlineTuner` — maintains a rolling population of exact routing
  count matrices from served batches, periodically refits the
  :class:`~repro.core.buckets.BucketSpec` ladder (``fit_ladder``) and
  re-prices the pipeline selector, and **hot-swaps** the spec only when
  the candidate's predicted padding + recompile cost beats the incumbent
  under a hysteresis margin. Swaps re-key — never flush — the SSC cache
  (:meth:`~repro.core.ssc.SSCCache.rekey_for_bucket`) and are
  bit-transparent to served tokens: quantization only pads plan cells,
  and padding rows are provably inert (zeros propagate through
  GMM/SwiGLU and are never gathered by Combine).
* :class:`OnlineMoE` — the serving twin of ``launch/dropless.DroplessMoE``:
  the same custom-vjp/pure_callback executor impl, but built with the
  ``live=`` hook so every host-side step observes its exact routing into
  the tuner and executes under the tuner's *current* spec.
* :class:`AdmissionConfig` / :func:`replay_admission` /
  :func:`size_slots` / :func:`size_capacity_factor` — replay-driven
  batch-size and capacity-factor sizing plus a queue-depth +
  predicted-step-latency admission gate with load shedding, simulated at
  the token level against the replay profiles (the ``bursty`` chaos case).

Everything here except :class:`OnlineMoE` is jax-free — the tuner, the
sizing, and the admission simulation run on count matrices and the
compile-time cost model only, so they price decisions without running
anything (the same resource-modeling shape as the auto-selector).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.autoselect import AutoChoice, predict_plan_us, select
from repro.core.buckets import BucketSpec, fit_ladder
from repro.core.costmodel import CostModel
from repro.core.odg import ScheduleConfig
from repro.core.routing import RoutingPlan


# ---------------------------------------------------------------------------
# Rolling-population plan derivation.
# ---------------------------------------------------------------------------


def population_plan(counts_pop: Sequence[np.ndarray],
                    total_rows: Optional[int] = None) -> RoutingPlan:
    """Representative :class:`RoutingPlan` of a plan population.

    Per-cell mean over the population, rounded up (so the profile keeps
    every expert the population ever touched — sparsity of the *union*,
    skew of the mean). ``total_rows`` rescales the mean to a target row
    count before rounding — the decode-profile case, where the population
    was observed at serving batch size B but the schedule being sized runs
    at ``n_slots * top_k`` rows.
    """
    mats = [np.asarray(c, dtype=np.int64) for c in counts_pop]
    if not mats:
        raise ValueError("population_plan needs a non-empty population")
    mean = np.mean(np.stack(mats), axis=0)
    if total_rows is not None:
        s = float(mean.sum())
        if s > 0:
            mean = mean * (float(total_rows) / s)
    c = np.ceil(mean).astype(np.int64)
    if c.sum() == 0:
        raise ValueError("population_plan: population routes zero rows")
    return RoutingPlan.from_counts(c)


# ---------------------------------------------------------------------------
# The online tuner.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    """Knobs of the refit/swap loop (see :class:`OnlineTuner`)."""

    window: int = 32          # rolling population size (served batches)
    refit_every: int = 8      # observations between refit attempts
    min_window: int = 8       # no refit before this many observations
    budget: int = 6           # fit_ladder edge budget
    # Online refits favor reuse a notch harder than the offline default
    # (0.5): a live candidate pays its own compiles, so flip-prone tight
    # ladders must not even be proposed.
    split_penalty: float = 1.0
    # Swap only when the candidate's predicted window cost undercuts the
    # incumbent's by this fraction — the anti-thrash margin. 0 = greedy.
    hysteresis: float = 0.1
    # The swap criterion is priced in *row-equivalents* (padding rows are
    # the natural unit; a padded row is dispatched and multiplied like a
    # real one). One fresh schedule compile+fetch then costs
    # ``compile_step_ratio`` steps' worth of mean window rows — the
    # scale-free form of "a compile costs a couple of served steps"
    # (bench_dropless: SSC fetch ~2.5 ms vs a served step's ~ms). Setting
    # ``row_us`` *and* ``compile_us`` (µs) overrides the ratio with an
    # absolute measured pair.
    compile_step_ratio: float = 1.0
    row_us: Optional[float] = None
    compile_us: Optional[float] = None

    def __post_init__(self):
        if self.window < 1 or self.refit_every < 1 or self.min_window < 1:
            raise ValueError("window/refit_every/min_window must be >= 1")
        if not 0.0 <= self.hysteresis < 1.0:
            raise ValueError(
                f"hysteresis must be in [0, 1), got {self.hysteresis}")


class OnlineTuner:
    """Online bucket-ladder refitting with hysteresis-gated hot swaps.

    ``observe(counts)`` feeds one served batch's exact ``[ep, ep, e_loc]``
    routing counts (``models.moe.routed_counts``) into the rolling window
    and returns the spec the batch should be quantized with. Every
    ``refit_every`` observations (once ``min_window`` is reached) the tuner
    fits a candidate ladder on the window and prices both specs over it in
    row-equivalents::

        cost(spec) = padded_rows(window) + compiles(spec) * compile_rows

    where ``compile_rows`` prices one fresh compile (see
    :class:`OnlineConfig`) and ``compiles`` is asymmetric, exactly the
    asymmetry a hot swap faces: the *incumbent* served the window, so its
    window keys are warm — it only pays its ongoing key-novelty rate
    (distinct keys appearing in the window's second half that its first
    half never produced, scaled to the full window); the *challenger*
    pays its cold fill (every distinct key its quantization of the window
    produces) plus the same novelty rate. An ``exact`` incumbent under
    churn is thereby correctly charged per new routing, while a coarse
    warm incumbent is nearly free to keep. The swap fires only when
    ``cand < (1 - hysteresis) * incumbent``; each swap re-keys the SSC
    cache (never flushes — the old policy's blobs stay bit-correct and the
    ladder may swap back) and re-prices the pipeline selector against the
    window's population profile. Decisions are pure functions of the
    observation sequence — two tuners fed the same window agree.
    """

    def __init__(self, initial="geometric:8",
                 oc: Optional[OnlineConfig] = None, *,
                 cache=None, cost: Optional[CostModel] = None,
                 d_model: int = 64, d_ff: int = 32):
        self.spec = BucketSpec.from_any(initial)
        self.oc = oc if oc is not None else OnlineConfig()
        self.cache = cache
        self.cost = cost if cost is not None else CostModel(l2=False)
        self.d_model = int(d_model)
        self.d_ff = int(d_ff)
        self.window: collections.deque = collections.deque(
            maxlen=self.oc.window)
        self.steps = 0
        self.refits = 0
        self.swaps: list[dict] = []
        self.choice: Optional[AutoChoice] = None   # last selector re-pricing

    def bind(self, *, cache=None, cost: Optional[CostModel] = None,
             d_model: Optional[int] = None,
             d_ff: Optional[int] = None) -> "OnlineTuner":
        """Late-bind serving context (cache, cost model, layer sizing) —
        the replay/serve loops construct tuners before either is known."""
        if cache is not None:
            self.cache = cache
        if cost is not None:
            self.cost = cost
        if d_model is not None:
            self.d_model = int(d_model)
        if d_ff is not None:
            self.d_ff = int(d_ff)
        return self

    # -- the observation loop ------------------------------------------------

    def observe(self, counts) -> BucketSpec:
        """Feed one batch's exact routing counts; returns the active spec."""
        self.window.append(np.asarray(counts, dtype=np.int64))
        self.steps += 1
        if (self.steps % self.oc.refit_every == 0
                and len(self.window) >= self.oc.min_window):
            self.maybe_refit()
        return self.spec

    # -- refit / swap machinery ----------------------------------------------

    def _compile_rows(self) -> float:
        """Row-equivalent price of one fresh schedule compile."""
        oc = self.oc
        if oc.row_us is not None and oc.compile_us is not None:
            return oc.compile_us / oc.row_us
        mean_rows = float(np.mean([int(c.sum()) for c in self.window]))
        return oc.compile_step_ratio * mean_rows

    def policy_cost(self, spec: BucketSpec, *, warm: bool) -> float:
        """Predicted window cost of ``spec`` in row-equivalents.

        ``warm`` is the incumbent's position: its window keys were
        compiled while serving the window, so it pays only its ongoing
        key-novelty rate; a cold challenger pays its full cold fill plus
        the same novelty rate (see class docstring).
        """
        pad = 0
        keys: list[bytes] = []
        for c in self.window:
            q = spec.quantize(c)
            pad += int(q.sum() - c.sum())
            keys.append(q.tobytes())
        half = len(keys) // 2
        novel = len(set(keys[half:]) - set(keys[:half])) * 2
        fresh = novel if warm else len(set(keys)) + novel
        return pad + fresh * self._compile_rows()

    def maybe_refit(self) -> bool:
        """Fit a candidate ladder on the window; swap iff it clears the
        hysteresis margin. Returns whether a swap happened."""
        self.refits += 1
        cand = fit_ladder(list(self.window), self.oc.budget,
                          self.oc.split_penalty)
        if cand.key() == self.spec.key():
            self._reprice()
            return False
        inc_cost = self.policy_cost(self.spec, warm=True)
        cand_cost = self.policy_cost(cand, warm=False)
        if cand_cost < (1.0 - self.oc.hysteresis) * inc_cost:
            self.swap_to(cand, inc_cost=inc_cost, cand_cost=cand_cost)
            return True
        self._reprice()
        return False

    def swap_to(self, spec, **evidence) -> None:
        """Hot-swap the active spec (also the forced-swap test seam).

        Bit-transparent by construction: the spec only changes how plan
        cells pad, and padding rows are inert in the executor. The SSC
        cache re-keys (MRU-boosts the new policy's resident population —
        never flushes) so the swap costs at most fresh compiles, not
        correctness.
        """
        spec = BucketSpec.from_any(spec)
        event = {"step": self.steps, "from": str(self.spec),
                 "to": str(spec), **evidence}
        self.spec = spec
        if self.cache is not None:
            event["rekey"] = self.cache.rekey_for_bucket(spec)
        self.swaps.append(event)
        self._reprice()

    def _reprice(self) -> None:
        """Re-price the pipeline selector on the window's profile."""
        if not self.window:
            return
        plan = population_plan(self.window)
        cfg = ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                             d_model=self.d_model, d_ff=self.d_ff,
                             gmm_split_mode="source_aligned", plan=plan)
        self.choice = select(plan, cfg, self.cost, direction="forward")

    # -- consumers -----------------------------------------------------------

    def decode_plan(self, total_rows: Optional[int] = None) -> RoutingPlan:
        """Decode-profile plan derived from the rolling population."""
        return population_plan(self.window, total_rows=total_rows)

    def summary(self) -> dict:
        return {"steps": self.steps, "refits": self.refits,
                "swaps": len(self.swaps), "spec": str(self.spec),
                "selector": self.choice.tag if self.choice else None}


# ---------------------------------------------------------------------------
# Live-swapping dropless MoE (the serving executor).
# ---------------------------------------------------------------------------


class OnlineMoE:
    """Dropless MoE whose bucket spec hot-swaps under the online tuner.

    Same executor impl as ``DroplessMoE`` (plan-sized schedules inside the
    jitted step via ``pure_callback``), built with the ``live=`` hook: each
    forward host call observes the batch's exact routing into the tuner and
    executes under whatever spec the tuner currently holds. Only the bucket
    spec may change across swaps — mesh size, tiling, and pipeline are
    pinned at construction, so no retrace ever happens.
    """

    def __init__(self, dc, tuner: OnlineTuner, act: str = "swiglu",
                 cache=None):
        from .dropless import _make_impl, get_process_cache
        if act != "swiglu":
            raise ValueError(
                f"dropless schedules execute the SwiGLU fragment; act={act!r}")
        self.cache = cache if cache is not None else get_process_cache(
            dc.cache_entries)
        self.tuner = tuner.bind(cache=self.cache)
        self._dc = dataclasses.replace(dc, bucket=self.tuner.spec)
        self.impl = _make_impl(self._dc, self.cache, live=self._live)

    @property
    def dc(self):
        """The *current* dropless config (bucket tracks the tuner)."""
        return self._dc

    def _live(self, top_i, mc, direction):
        from repro.models.moe import routed_counts
        if direction == "forward":
            spec = self.tuner.observe(
                routed_counts(top_i, mc, self._dc.ep))
        else:
            spec = self.tuner.spec
        if spec.key() != self._dc.bucket_spec().key():
            self._dc = dataclasses.replace(self._dc, bucket=spec)
        return self._dc

    def swap_to(self, spec) -> None:
        """Force a hot swap (chaos tests; normal swaps come from refits)."""
        self.tuner.swap_to(spec, forced=True)

    def step_stats(self) -> dict:
        return self.cache.step_stats()


# ---------------------------------------------------------------------------
# Replay-driven sizing + admission control with load shedding.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Queue-depth + predicted-step-latency admission gate.

    ``slo_us`` bounds the *predicted* per-step latency
    (:func:`~repro.core.autoselect.predict_plan_us` units — the gate and
    any SLO assertion must share the predictor). ``max_queue`` bounds
    deferred tokens; arrivals beyond it are shed (reported, never silently
    dropped) when ``shed`` is on, and wait unboundedly otherwise.
    """

    slo_us: float
    max_queue: int = 64
    shed: bool = True

    def __post_init__(self):
        if self.slo_us <= 0:
            raise ValueError(f"slo_us must be > 0, got {self.slo_us}")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")


def size_slots(counts_pop: Sequence[np.ndarray], mc, ep: int,
               slo_us: float, *, d_model: int = 64, d_ff: int = 32,
               max_slots: int = 256, cost: Optional[CostModel] = None,
               pipeline=("ratr",)) -> int:
    """Largest per-step token budget whose predicted latency fits the SLO.

    Walks batch sizes in ``ep``-token chunks, pricing the population
    profile rescaled to each size; returns the largest size still under
    ``slo_us`` (at least ``ep`` — the server must make progress). This is
    the replay-driven batch-size sizing the admission gate enforces live.
    """
    best = ep
    for n in range(ep, max_slots + 1, ep):
        plan = population_plan(counts_pop, total_rows=n * mc.top_k)
        if predict_plan_us(plan, d_model, d_ff, cost=cost,
                           pipeline=pipeline) <= slo_us:
            best = n
        else:
            break
    return best


def size_capacity_factor(counts_pop: Sequence[np.ndarray], *,
                         quantile: float = 0.99,
                         headroom: float = 1.05) -> float:
    """Capacity factor covering the population's per-expert load quantile.

    For each observed batch, each expert's load relative to the uniform
    share (``rows_e * E / total_rows``); the returned factor is the
    ``quantile`` of that distribution times ``headroom`` — the smallest
    ``MoEConfig.capacity_factor`` that would keep drop rates at
    ``1 - quantile`` under capacity-ful serving of this traffic.
    """
    loads = []
    for c in counts_pop:
        c = np.asarray(c, dtype=np.int64)
        per_e = c.sum(axis=0).reshape(-1)
        total = int(per_e.sum())
        if total:
            loads.append(per_e * (per_e.size / total))
    if not loads:
        raise ValueError("size_capacity_factor needs a non-empty population")
    return float(np.quantile(np.concatenate(loads), quantile) * headroom)


def replay_admission(trace: Sequence[np.ndarray], mc, ep: int, *,
                     d_model: int = 64, d_ff: int = 32,
                     n_slots: Optional[int] = None,
                     admission: Optional[AdmissionConfig] = None,
                     cost: Optional[CostModel] = None,
                     pipeline=("ratr",)) -> dict:
    """Token-level serving simulation of the admission gate on a trace.

    Each trace step offers a batch of routed tokens (``[T, k]`` or
    ``[ep, t_loc, k]`` top-k choices). Offered tokens enter a FIFO queue;
    per step the server admits queued tokens in ``ep``-token chunks while
    the admitted set stays within ``n_slots`` tokens *and* its actual
    routing prices under ``admission.slo_us`` (the first chunk is always
    admitted — progress guarantee). With shedding on, the residual queue
    is clamped to ``max_queue`` and the newest overflow is shed — counted,
    never silently dropped. ``admission=None`` is the unbounded baseline:
    every queued token is admitted immediately.

    Returns per-step predicted latencies and their p50/p99, ``max_active``
    (peak admitted tokens — never exceeds ``n_slots`` under a gate),
    ``shed``/``served``/``deferred`` token counts, and ``slo_miss_rate``
    when a gate is set. Deterministic; latency is predictor-priced (see
    :class:`AdmissionConfig`).
    """
    queue: list[np.ndarray] = []
    step_us: list[float] = []
    shed = served = 0
    max_active = 0
    cap = None
    if admission is not None:
        cap = n_slots if n_slots is not None else 0
        if cap <= 0:
            raise ValueError("admission control needs n_slots > 0")
        cap -= cap % ep
        cap = max(ep, cap)
        max_queue = admission.max_queue - (admission.max_queue % ep)

    def price(tokens: list[np.ndarray]) -> float:
        ti = np.stack(tokens)                      # [T, k], T % ep == 0
        from repro.models.moe import routed_counts
        plan = RoutingPlan.from_counts(routed_counts(ti, mc, ep))
        return predict_plan_us(plan, d_model, d_ff, cost=cost,
                               pipeline=pipeline)

    for top_i in trace:
        ti = np.asarray(top_i)
        queue.extend(ti.reshape(-1, ti.shape[-1]))
        if not queue:
            continue
        if admission is None:
            admit = queue
            queue = []
            us = price(admit)
        else:
            admit = queue[:ep]
            us = price(admit)
            while len(admit) + ep <= min(cap, len(queue)):
                cand = queue[:len(admit) + ep]
                cand_us = price(cand)
                if cand_us > admission.slo_us:
                    break
                admit, us = cand, cand_us
            queue = queue[len(admit):]
            if admission.shed and len(queue) > max_queue:
                shed += len(queue) - max_queue
                queue = queue[:max_queue]
        served += len(admit)
        max_active = max(max_active, len(admit))
        step_us.append(us)

    lat = np.asarray(step_us, dtype=np.float64)
    out = {
        "steps": len(step_us),
        "served": served,
        "shed": shed,
        "deferred": len(queue),
        "max_active": max_active,
        "p50_us": float(np.percentile(lat, 50)) if lat.size else 0.0,
        "p99_us": float(np.percentile(lat, 99)) if lat.size else 0.0,
    }
    if admission is not None:
        out["slo_miss_rate"] = (float((lat > admission.slo_us).mean())
                                if lat.size else 0.0)
    return out
