"""Serving driver with continuous batching.

Production shape: a request queue feeds fixed-slot batched decoding —
finished sequences immediately release their slot to the next request
(prefill into the slot, decode continues for everyone else). Per-slot
cache state lives in one batched cache pytree; slot refill uses masked
scatter so everything stays jit-compiled at a fixed batch size.

CPU-scale demo:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 12 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def resolve_decode_sched(cfg, sched: str, n_slots: int):
    """Size the decode-traffic MoE fragment's schedule for this server.

    Decode batches are small and Zipf-skewed (a few hot experts dominate
    short-request traffic), so the schedule that serves them best is a
    routing-profile question — exactly what the cost-model-guided selector
    answers. For MoE archs this compiles the decode-profile fragment with
    ``--sched`` (``"auto"`` resolves through ``core/autoselect``), runs it
    through the simulator, and reports the resolution; non-MoE archs have
    no schedulable fragment and skip. Returns the report dict (or None).
    """
    if cfg.family != "moe":
        print(f"--sched {sched}: {cfg.name!r} has no MoE fragment; "
              f"scheduling stack not engaged")
        return None
    from repro.core.autoselect import select
    from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
    from repro.core.passes import Pipeline, pipeline_arg
    from repro.core.routing import skewed_plan
    from repro.core.scheduler import compile_schedule
    from repro.core.simulator import simulate_unified

    mc = cfg.moe
    ep = next(e for e in (4, 2, 1) if mc.e_total % e == 0)
    e_loc = mc.e_total // ep
    # Zipf-skewed decode profile sized to a busy step: every slot decodes
    # one token routed top_k ways, batched over a scheduling window.
    rows = max(1, n_slots * mc.top_k)
    plan = skewed_plan(ep, e_loc, rows, 1.0)
    scfg = ScheduleConfig(ep=ep, e_loc=e_loc, rows=0, d_model=cfg.d_model,
                          d_ff=mc.d_expert, gmm_m_split=2 * ep,
                          gmm_split_mode="source_aligned", plan=plan)
    req = pipeline_arg(sched)
    if req == "auto":
        choice = select(plan, scfg, direction="forward")
        pipe, scfg, tag = choice.pipeline, choice.cfg, choice.tag
        predicted = choice.predicted_us
    else:
        pipe, tag, predicted = Pipeline.of(*req), sched, None
    res = simulate_unified(compile_schedule(build_moe_ffn_forward(scfg),
                                            pipeline=pipe))
    pred = f" predicted={predicted:.1f}us" if predicted is not None else ""
    print(f"decode schedule [{tag}] pipeline={pipe.names()} "
          f"ep={ep} rows/cell={rows} simulated={res.makespan_us:.1f}us"
          f"{pred} straggler={res.straggler_ratio:.2f}")
    return {"tag": tag, "pipeline": pipe.spec(),
            "makespan_us": res.makespan_us, "predicted_us": predicted}


class ContinuousBatcher:
    """Fixed-slot continuous batching over a batched KV cache."""

    def __init__(self, cfg, params, n_slots: int, max_len: int):
        from repro.models import model as M
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.M = M
        self.cache = M.init_cache(cfg, n_slots, max_len,
                                  per_slot_len=True)
        self.active = np.zeros(n_slots, bool)
        self.req_id = [-1] * n_slots
        self.generated: dict[int, list[int]] = {}
        self.budget = np.zeros(n_slots, np.int32)
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)

        self._decode = jax.jit(
            lambda p, t, c: M.decode_step(cfg, p, t, c))
        # Slot prefill: run the prompt through with batch=1 and scatter the
        # resulting cache slice into the batched cache at `slot`.
        self._prefill1 = jax.jit(
            lambda p, toks: M.prefill(cfg, p, {"tokens": toks},
                                      max_len=max_len))

    def _scatter_slot(self, slot: int, cache1):
        """Write a batch-1 prefill cache into slot ``slot``.

        Dispatch on the *batch-1 marker* of cache1, never on absolute sizes
        (L == n_slots is a real collision otherwise): stacked leaves are
        [L, 1, …] → batch at axis 1; unstacked are [1, …] → axis 0;
        per-slot len leaves are one dim short of their target."""
        def upd(c, c1):
            if c.ndim == 0 or c1.ndim == 0:
                return c1 if c.ndim == 0 else c
            if c.ndim == c1.ndim + 1:
                # per-slot len [L, B] ← scalar-len prefill [L]
                return c.at[:, slot].set(c1)
            if c1.ndim >= 2 and c1.shape[1] == 1 \
                    and c.shape[0] == c1.shape[0]:
                return c.at[:, slot].set(c1[:, 0])   # stacked [L, B, ...]
            if c1.shape[0] == 1:
                return c.at[slot].set(c1[0])         # unstacked [B, ...]
            raise ValueError(f"unrecognized cache leaf {c.shape}/{c1.shape}")
        self.cache = jax.tree.map(upd, self.cache, cache1)

    def admit(self, rid: int, prompt: np.ndarray, max_new: int) -> bool:
        free = np.where(~self.active)[0]
        if not len(free):
            return False
        slot = int(free[0])
        logits, cache1 = self._prefill1(
            self.params, jnp.asarray(prompt[None, :], jnp.int32))
        self._scatter_slot(slot, cache1)
        tok = int(jnp.argmax(logits[0]))
        self.generated[rid] = [tok]
        self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
        self.active[slot] = True
        self.req_id[slot] = rid
        self.budget[slot] = max_new - 1
        return True

    def step(self) -> list[int]:
        """One batched decode step for every active slot; returns finished
        request ids."""
        if not self.active.any():
            return []
        logits, self.cache = self._decode(self.params, self.cur_tok,
                                          self.cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.cur_tok = nxt[:, None]
        done = []
        for s in range(self.n_slots):
            if not self.active[s]:
                continue
            self.generated[self.req_id[s]].append(int(nxt[s]))
            self.budget[s] -= 1
            if self.budget[s] <= 0:
                done.append(self.req_id[s])
                self.active[s] = False
                self.req_id[s] = -1
        return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--sched", default=None, metavar="PIPELINE",
                    help="size the decode-traffic MoE fragment's schedule "
                         "before serving: 'auto' (cost-model-guided "
                         "selection), a core.passes.SCHED_PIPELINES name, "
                         "or a comma-separated pass list")
    args = ap.parse_args()

    if args.sched:
        # Validate eagerly, for every arch: an unknown pipeline/pass name
        # must be an argparse error, not a traceback (or a silent no-op on
        # non-MoE archs).
        from repro.core.passes import pipeline_arg
        try:
            pipeline_arg(args.sched)
        except KeyError as e:
            ap.error(str(e))

    from repro.configs import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config(args.arch)
    if args.sched:
        resolve_decode_sched(cfg, args.sched, args.slots)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, args.prompt_len)
               for i in range(args.requests)}

    b = ContinuousBatcher(cfg, params,
                          n_slots=args.slots,
                          max_len=args.prompt_len + args.max_new + 1)
    pending = list(range(args.requests))
    finished = []
    t0 = time.perf_counter()
    steps = 0
    while pending or b.active.any():
        while pending and b.admit(pending[0], prompts[pending[0]],
                                  args.max_new):
            pending.pop(0)
        finished += b.step()
        steps += 1
        if steps > 10000:
            raise RuntimeError("serving loop did not converge")
    dt = time.perf_counter() - t0
    total_toks = sum(len(v) for v in b.generated.values())
    print(f"served {args.requests} requests / {total_toks} tokens in "
          f"{dt:.1f}s over {steps} batched steps "
          f"({args.slots} slots, continuous batching)")
    assert sorted(finished) == sorted(prompts), "all requests must finish"
    for rid in list(prompts)[:2]:
        print(f"  req{rid}: …{prompts[rid][-4:].tolist()} → "
              f"{b.generated[rid][:10]}…")
    return b


if __name__ == "__main__":
    main()
