"""Serving driver with continuous batching.

Production shape: a request queue feeds fixed-slot batched decoding —
finished sequences immediately release their slot to the next request
(prefill into the slot, decode continues for everyone else). Per-slot
cache state lives in one batched cache pytree; slot refill uses masked
scatter so everything stays jit-compiled at a fixed batch size.

CPU-scale demo:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 12 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

# Serving jits the whole decode step *around* the dropless pure_callback
# executor (``--online-refit``); under async CPU dispatch the callback's
# device-to-host operand transfer can deadlock against the in-flight
# executable. The knob binds at CPU-client creation, so it is pinned at
# import — effective for the CLI and for any consumer that imports this
# module before touching jax (tests pin it in conftest.py).
jax.config.update("jax_cpu_enable_async_dispatch", False)


def decode_population(mc, ep: int, n_tokens: int, *, profile: str = "zipf",
                      steps: int = 16, seed: int = 0) -> list[np.ndarray]:
    """Synthesized decode-traffic routing-count population.

    The cold-start stand-in for a live rolling window: a short correlated
    Zipf decode trace (``launch/replay.synth_trace``) sized to this
    server's per-step token budget, reduced to exact ``[ep, ep, e_loc]``
    count matrices. Sizing, admission pricing, and the decode schedule all
    consume populations of this shape — once the server runs, the online
    tuner's window (real traffic, same shape) replaces it.
    """
    from repro.launch.replay import synth_trace
    from repro.models.moe import routed_counts
    t_loc = max(1, n_tokens // ep)
    trace = synth_trace(profile, steps, ep=ep, e_loc=mc.e_total // ep,
                        t_loc=t_loc, top_k=mc.top_k, seed=seed)
    return [routed_counts(ti, mc, ep) for ti in trace]


def resolve_decode_sched(cfg, sched: str, n_slots: int, plan=None):
    """Size the decode-traffic MoE fragment's schedule for this server.

    Decode batches are small and skewed (a few hot experts dominate
    short-request traffic), so the schedule that serves them best is a
    routing-profile question — exactly what the cost-model-guided selector
    answers. For MoE archs this compiles the decode-profile fragment with
    ``--sched`` (``"auto"`` resolves through ``core/autoselect``), runs it
    through the simulator, and reports the resolution; non-MoE archs have
    no schedulable fragment and skip. Returns the report dict (or None).

    ``plan`` is the decode profile to size against — pass the online
    tuner's ``decode_plan(rows)`` to re-resolve from the *live* rolling
    population. By default the profile is replay-derived: the population
    mean of a synthesized Zipf decode trace at this server's token budget
    (:func:`decode_population`), not an analytic skew guess.
    """
    if cfg.family != "moe":
        print(f"--sched {sched}: {cfg.name!r} has no MoE fragment; "
              f"scheduling stack not engaged")
        return None
    from repro.core.autoselect import select
    from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
    from repro.core.passes import Pipeline, pipeline_arg
    from repro.core.scheduler import compile_schedule
    from repro.core.simulator import simulate_unified
    from repro.launch.online import population_plan

    mc = cfg.moe
    # Decode profile sized to a busy step: every slot decodes one token
    # routed top_k ways, batched over a scheduling window.
    rows = max(1, n_slots * mc.top_k)
    if plan is None:
        ep = next(e for e in (4, 2, 1) if mc.e_total % e == 0)
        plan = population_plan(decode_population(mc, ep, max(ep, n_slots)),
                               total_rows=rows)
    ep, e_loc = plan.ep, plan.e_loc
    scfg = ScheduleConfig(ep=ep, e_loc=e_loc, rows=0, d_model=cfg.d_model,
                          d_ff=mc.d_expert, gmm_m_split=2 * ep,
                          gmm_split_mode="source_aligned", plan=plan)
    req = pipeline_arg(sched)
    if req == "auto":
        choice = select(plan, scfg, direction="forward")
        pipe, scfg, tag = choice.pipeline, choice.cfg, choice.tag
        predicted = choice.predicted_us
    else:
        pipe, tag, predicted = Pipeline.of(*req), sched, None
    res = simulate_unified(compile_schedule(build_moe_ffn_forward(scfg),
                                            pipeline=pipe))
    pred = f" predicted={predicted:.1f}us" if predicted is not None else ""
    print(f"decode schedule [{tag}] pipeline={pipe.names()} "
          f"ep={ep} rows/cell={rows} simulated={res.makespan_us:.1f}us"
          f"{pred} straggler={res.straggler_ratio:.2f}")
    return {"tag": tag, "pipeline": pipe.spec(),
            "makespan_us": res.makespan_us, "predicted_us": predicted}


class ContinuousBatcher:
    """Fixed-slot continuous batching over a batched KV cache.

    ``moe_impl`` threads a pluggable MoE executor into the jitted
    prefill/decode steps — pass ``OnlineMoE(...).impl`` to serve through
    plan-sized compiled schedules with live bucket refitting (the impl's
    ``pure_callback`` host fns run per step under the single jit trace, so
    hot swaps never retrace; decode batch ``n_slots`` and the prompt
    length must be divisible by the impl's ``ep``). ``admission`` arms the
    :meth:`offer` gate: queue-depth shedding plus a predicted-step-latency
    check priced on the ``decode_counts`` population
    (:func:`~repro.core.autoselect.predict_plan_us` units — the gate and
    any SLO assertion must share the predictor).
    """

    def __init__(self, cfg, params, n_slots: int, max_len: int, *,
                 moe_impl=None, admission=None, decode_counts=None,
                 cost=None):
        from repro.models import model as M
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.M = M
        self.cache = M.init_cache(cfg, n_slots, max_len,
                                  per_slot_len=True)
        self.active = np.zeros(n_slots, bool)
        self.req_id = [-1] * n_slots
        self.generated: dict[int, list[int]] = {}
        self.budget = np.zeros(n_slots, np.int32)
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.admission = admission
        self.decode_counts = decode_counts
        self.cost = cost
        self.shed: list[int] = []        # shed request ids — reported
        self.deferred = 0                # defer verdicts (retried later)
        self.instant_done: list[int] = []

        self._decode = jax.jit(
            lambda p, t, c: M.decode_step(cfg, p, t, c, moe_impl=moe_impl))
        # Slot prefill: run the prompt through with batch=1 and scatter the
        # resulting cache slice into the batched cache at `slot`.
        self._prefill1 = jax.jit(
            lambda p, toks: M.prefill(cfg, p, {"tokens": toks},
                                      max_len=max_len, moe_impl=moe_impl))

    def _scatter_slot(self, slot: int, cache1):
        """Write a batch-1 prefill cache into slot ``slot``.

        Dispatch on the *batch-1 marker* of cache1, never on absolute sizes
        (L == n_slots is a real collision otherwise): stacked leaves are
        [L, 1, …] → batch at axis 1; unstacked are [1, …] → axis 0;
        per-slot len leaves are one dim short of their target."""
        def upd(c, c1):
            if c.ndim == 0 or c1.ndim == 0:
                return c1 if c.ndim == 0 else c
            if c.ndim == c1.ndim + 1:
                # per-slot len [L, B] ← scalar-len prefill [L]
                return c.at[:, slot].set(c1)
            if c1.ndim >= 2 and c1.shape[1] == 1 \
                    and c.shape[0] == c1.shape[0]:
                return c.at[:, slot].set(c1[:, 0])   # stacked [L, B, ...]
            if c1.shape[0] == 1:
                return c.at[slot].set(c1[0])         # unstacked [B, ...]
            raise ValueError(f"unrecognized cache leaf {c.shape}/{c1.shape}")
        self.cache = jax.tree.map(upd, self.cache, cache1)

    def _predict_step_us(self, n_active: int) -> float:
        """Predicted decode-step latency at ``n_active`` busy slots,
        priced on the decode-population profile rescaled to that size."""
        if self.decode_counts is None or self.cfg.family != "moe":
            return 0.0
        from repro.core.autoselect import predict_plan_us
        from repro.launch.online import population_plan
        mc = self.cfg.moe
        plan = population_plan(self.decode_counts,
                               total_rows=max(1, n_active) * mc.top_k)
        return predict_plan_us(plan, self.cfg.d_model, mc.d_expert,
                               cost=self.cost)

    def admit(self, rid: int, prompt: np.ndarray, max_new: int) -> bool:
        if max_new > 1 and self.active.all():
            return False
        logits, cache1 = self._prefill1(
            self.params, jnp.asarray(prompt[None, :], jnp.int32))
        tok = int(jnp.argmax(logits[0]))
        self.generated[rid] = [tok]
        if max_new <= 1:
            # Prefill already produced the whole response: finish without
            # occupying a slot. (Routing through a slot would set the
            # budget to 0, which the decode loop treats as "decode once
            # more" — over-generating by a token.)
            self.instant_done.append(rid)
            return True
        slot = int(np.where(~self.active)[0][0])
        self._scatter_slot(slot, cache1)
        self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
        self.active[slot] = True
        self.req_id[slot] = rid
        self.budget[slot] = max_new - 1
        return True

    def offer(self, rid: int, prompt: np.ndarray, max_new: int,
              queue_depth: int = 0) -> str:
        """Admission-gated :meth:`admit`: ``'admit' | 'defer' | 'shed'``.

        With no :class:`~repro.launch.online.AdmissionConfig` this is
        plain admit-or-defer (slot availability only). With one, requests
        past ``max_queue`` queued behind this offer are shed — recorded in
        ``self.shed``, never silently dropped — and a request whose
        admission would push the predicted decode-step latency past
        ``slo_us`` is deferred (unless the server is idle: the first
        request always gets in, the progress guarantee). Deferred requests
        stay the caller's to retry; shed ones are final.
        """
        adm = self.admission
        if adm is None:
            if self.admit(rid, prompt, max_new):
                return "admit"
            self.deferred += 1
            return "defer"
        if adm.shed and queue_depth > adm.max_queue:
            self.shed.append(rid)
            return "shed"
        n_active = int(self.active.sum())
        if (max_new > 1 and n_active >= 1
                and self._predict_step_us(n_active + 1) > adm.slo_us):
            self.deferred += 1
            return "defer"
        if self.admit(rid, prompt, max_new):
            return "admit"
        self.deferred += 1
        return "defer"

    def step(self) -> list[int]:
        """One batched decode step for every active slot; returns finished
        request ids."""
        done0, self.instant_done = self.instant_done, []
        if not self.active.any():
            return done0
        logits, self.cache = self._decode(self.params, self.cur_tok,
                                          self.cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.cur_tok = nxt[:, None]
        done = done0
        for s in range(self.n_slots):
            if not self.active[s]:
                continue
            self.generated[self.req_id[s]].append(int(nxt[s]))
            self.budget[s] -= 1
            if self.budget[s] <= 0:
                done.append(self.req_id[s])
                self.active[s] = False
                self.req_id[s] = -1
        return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--sched", default=None, metavar="PIPELINE",
                    help="size the decode-traffic MoE fragment's schedule "
                         "before serving: 'auto' (cost-model-guided "
                         "selection), a core.passes.SCHED_PIPELINES name, "
                         "or a comma-separated pass list")
    ap.add_argument("--online-refit", action="store_true",
                    help="serve the MoE fragment through plan-sized "
                         "compiled schedules with an OnlineTuner "
                         "observing live routing and hot-swapping the "
                         "bucket ladder (MoE archs only)")
    ap.add_argument("--slo-us", type=float, default=0.0,
                    help="arm admission control: defer admissions whose "
                         "predicted decode-step latency (cost-model "
                         "units) exceeds this, shed past --max-queue")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="queue depth beyond which offers are shed "
                         "(with --slo-us)")
    args = ap.parse_args()

    if args.sched:
        # Validate eagerly, for every arch: an unknown pipeline/pass name
        # must be an argparse error, not a traceback (or a silent no-op on
        # non-MoE archs).
        from repro.core.passes import pipeline_arg
        try:
            pipeline_arg(args.sched)
        except KeyError as e:
            ap.error(str(e))

    from repro.configs import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config(args.arch)
    if args.sched:
        resolve_decode_sched(cfg, args.sched, args.slots)

    online = moe_impl = None
    decode_counts = None
    n_slots = args.slots
    admission = None
    if cfg.family == "moe":
        from repro.launch.online import (AdmissionConfig, size_slots,
                                         size_capacity_factor)
        mc = cfg.moe
        ep = next(e for e in (4, 2, 1)
                  if mc.e_total % e == 0 and args.slots % e == 0
                  and args.prompt_len % e == 0)
        decode_counts = decode_population(mc, ep, args.slots)
        if args.slo_us > 0:
            admission = AdmissionConfig(slo_us=args.slo_us,
                                        max_queue=args.max_queue)
            sized = size_slots(decode_counts, mc, ep, args.slo_us)
            n_slots = max(ep, min(args.slots, sized))
            cf = size_capacity_factor(decode_counts)
            print(f"admission: slo={args.slo_us:.1f}us sized slots="
                  f"{sized} -> serving {n_slots}/{args.slots}, "
                  f"p99 capacity factor={cf:.2f}")
        if args.online_refit:
            from repro.core.buckets import fit_ladder
            from repro.launch.dropless import DroplessConfig
            from repro.launch.online import OnlineMoE, OnlineTuner
            if n_slots % ep or args.prompt_len % ep:
                ap.error(f"--online-refit needs slots and prompt-len "
                         f"divisible by ep={ep}")
            tuner = OnlineTuner(initial=fit_ladder(decode_counts, 6, 1.0),
                                d_model=cfg.d_model, d_ff=mc.d_expert)
            online = OnlineMoE(DroplessConfig(ep=ep, bucket=tuner.spec,
                                              pipeline=("ratr",)), tuner)
            moe_impl = online.impl
            print(f"online refit: ep={ep} seed spec={tuner.spec}")
    elif args.online_refit:
        print(f"--online-refit: {cfg.name!r} has no MoE fragment; skipped")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, args.prompt_len)
               for i in range(args.requests)}

    b = ContinuousBatcher(cfg, params,
                          n_slots=n_slots,
                          max_len=args.prompt_len + args.max_new + 1,
                          moe_impl=moe_impl, admission=admission,
                          decode_counts=decode_counts)
    pending = list(range(args.requests))
    finished = []
    t0 = time.perf_counter()
    steps = 0
    while pending or b.active.any() or b.instant_done:
        while pending:
            verdict = b.offer(pending[0], prompts[pending[0]],
                              args.max_new, queue_depth=len(pending))
            if verdict == "defer":
                break
            pending.pop(0)         # admitted or shed — either way consumed
        finished += b.step()
        steps += 1
        if steps > 10000:
            raise RuntimeError("serving loop did not converge")
    dt = time.perf_counter() - t0
    total_toks = sum(len(v) for v in b.generated.values())
    shed = f", {len(b.shed)} shed" if b.shed else ""
    print(f"served {len(finished)} requests / {total_toks} tokens in "
          f"{dt:.1f}s over {steps} batched steps "
          f"({n_slots} slots, continuous batching{shed})")
    assert sorted(finished + b.shed) == sorted(prompts), \
        "every request must finish or be reported shed"
    for rid in list(prompts)[:2]:
        if rid in b.generated:
            print(f"  req{rid}: …{prompts[rid][-4:].tolist()} → "
                  f"{b.generated[rid][:10]}…")
    if online is not None:
        s = online.tuner.summary()
        print(f"online tuner: steps={s['steps']} refits={s['refits']} "
              f"swaps={s['swaps']} spec={s['spec']} "
              f"selector={s['selector']}")
        if args.sched:
            # Re-resolve the decode schedule from the *live* rolling
            # population the server just observed.
            rows = max(1, n_slots * cfg.moe.top_k)
            resolve_decode_sched(cfg, args.sched, n_slots,
                                 plan=online.tuner.decode_plan(rows))
    return b


if __name__ == "__main__":
    main()
