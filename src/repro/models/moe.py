"""Mixture-of-Experts FFN: router + expert execution paths.

Three execution paths, all numerically equivalent (tests assert it):

* ``moe_dense_ref`` — one-hot einsum over all experts; the oracle.
* ``moe_grouped``  — capacity-based dispatch/combine with sorted token
  buffers feeding a grouped GEMM (optionally the Pallas kernel); this is the
  single-device analogue of the paper's Dispatch→GMM→SwiGLU→GMM→Combine.
* EP-sharded execution lives in ``repro/parallel/ep.py`` (shard_map): the
  ``baseline`` mode uses a collective AllToAll, the ``hyperparallel`` mode
  the RATR chunked-ppermute schedule mirroring the paper's one-sided tasks.

``plan_from_routing`` bridges this layer to the scheduling stack: it turns a
batch's actual (imbalanced) top-k assignment into a compilable
``repro.core.routing.RoutingPlan``, so compiled schedules are verified
against ``moe_grouped`` on real router output, not just balanced grids.

Routing uses fixed expert capacity so shapes stay static under jit:
``capacity = ceil(tokens · top_k / E · capacity_factor)``; overflow tokens
are dropped (standard practice; the dense ref applies the same mask).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import glu_act


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width (branch width)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Experts padded up so E % ep == 0 (router never selects padding).
    n_padding_experts: int = 0

    @property
    def e_total(self) -> int:
        return self.n_experts + self.n_padding_experts


def init_moe(key, d_model: int, mc: MoEConfig, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    E = mc.e_total
    std = d_model ** -0.5
    return {
        "router": jax.random.normal(k1, (d_model, E), jnp.float32) * std,
        "w_in": jax.random.normal(k2, (E, d_model, 2 * mc.d_expert), dtype)
        * std,
        "w_down": jax.random.normal(k3, (E, mc.d_expert, d_model), dtype)
        * mc.d_expert ** -0.5,
    }


def router_topk(p_router, x, mc: MoEConfig):
    """Top-k routing with renormalized softmax probs.

    x: [T, d] → (probs [T, k], idx [T, k]).  Padding experts are masked out.
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p_router)
    if mc.n_padding_experts:
        pad_mask = jnp.arange(mc.e_total) >= mc.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, mc.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_i


def load_balance_loss(p_router, x, mc: MoEConfig):
    """Switch-style auxiliary load-balancing loss + router z-loss.

    aux = E · Σ_e f_e · P_e  (f: token fraction routed to e via top-1,
    P: mean router prob) — minimized at uniform routing; z-loss keeps
    router logits bounded. Returns (aux, z)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p_router)
    if mc.n_padding_experts:
        pad = jnp.arange(mc.e_total) >= mc.n_experts
        logits = jnp.where(pad[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, mc.e_total, dtype=jnp.float32),
                 axis=0)
    P = jnp.mean(probs, axis=0)
    aux = mc.n_experts * jnp.sum(f * P)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return aux, z


def capacity(tokens: int, mc: MoEConfig, ep: int = 1) -> int:
    """Per-expert capacity, rounded up to a multiple of ``ep`` so EP
    all-to-all chunks stay uniform."""
    c = int(np.ceil(tokens * mc.top_k / mc.e_total * mc.capacity_factor))
    return max(ep, ((c + ep - 1) // ep) * ep)


def expert_ffn(w_in, w_down, x, act: str = "swiglu"):
    """x: [E, C, d] per-expert batches → [E, C, d]."""
    h = jnp.einsum("ecd,edf->ecf", x, w_in.astype(x.dtype))
    h = glu_act(h, act)
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))


def make_dispatch(top_p, top_i, T: int, E: int, C: int):
    """Position-in-expert assignment under fixed capacity.

    Returns (combine_w [T,k], slot [T,k] in [0, C) or C for dropped).
    """
    k = top_i.shape[1]
    flat_e = top_i.reshape(-1)                                  # [T*k]
    # position of each (token, choice) within its expert, in token order
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                        # running idx
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C
    return (top_p * keep.reshape(T, k)), flat_e.reshape(T, k), \
        jnp.where(keep, slot, C).reshape(T, k)


def moe_dense_ref(params, x, mc: MoEConfig, act: str = "swiglu",
                  cap: Optional[int] = None):
    """One-hot dense-einsum oracle (same capacity-drop mask, no scatter)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E = mc.e_total
    C = cap or capacity(T, mc)
    top_p, top_i, slot = _routed(params, xt, mc, C)
    # dispatch_mask[t, k, e, c]: token t's k-th choice occupies (e, c).
    e_oh = jax.nn.one_hot(top_i, E, dtype=xt.dtype)          # [T,k,E]
    c_oh = jax.nn.one_hot(slot, C, dtype=xt.dtype)           # [T,k,C] (C drops)
    disp_mask = jnp.einsum("tke,tkc->tec", e_oh, c_oh)
    disp = jnp.einsum("tec,td->ecd", disp_mask, xt)
    out_e = expert_ffn(params["w_in"], params["w_down"], disp, act)
    comb = jnp.einsum("tke,tkc,tk->tec", e_oh, c_oh, top_p.astype(xt.dtype))
    y = jnp.einsum("tec,ecd->td", comb, out_e)
    return y.reshape(B, S, d)


def _routed(params, xt, mc: MoEConfig, C: int):
    top_p, top_i = router_topk(params["router"], xt, mc)
    top_p, top_i, slot = make_dispatch(top_p, top_i, xt.shape[0],
                                       mc.e_total, C)
    return top_p, top_i, slot


# ---------------------------------------------------------------------------
# RoutingPlan bridge — real router output → compilable schedule input.
#
# This is the seam between the model layer (capacity-based top-k routing)
# and the scheduling stack (repro.core): the bridge turns a batch's actual
# (imbalanced) expert assignment into a RoutingPlan plus the row bookkeeping
# needed to scatter tokens into the plan's send-buffer layout and to apply
# top-k combine weights to the executor's returned rows. Tokens are split
# contiguously over EP source ranks, so a token's global order equals
# (src-major, local order) — exactly the slot order `moe_grouped` produces,
# which is what makes a compiled schedule comparable bit-for-bit against the
# grouped reference.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RoutingBridge:
    """A RoutingPlan plus token↔row maps for one routed batch."""

    plan: "object"              # repro.core.routing.RoutingPlan
    # Row index into source rank s's send buffer for choice (s, t, k);
    # -1 where the choice was dropped by capacity.
    send_row: np.ndarray        # int64 [ep, T_loc, k]

    @property
    def ep(self) -> int:
        return self.send_row.shape[0]


def _cumcount(keys: np.ndarray) -> np.ndarray:
    """Occurrence index of each element within its key group, in order.

    Vectorized (stable argsort + group starts): this runs once per routed
    batch on [T*k] choices, so no per-choice Python loop.
    """
    n = keys.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    order = np.argsort(keys, kind="stable")
    sorted_keys = keys[order]
    starts = np.r_[0, np.flatnonzero(np.diff(sorted_keys)) + 1]
    group_start = np.repeat(starts, np.diff(np.r_[starts, n]))
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n, dtype=np.int64) - group_start
    return rank


def bucket_counts(counts: np.ndarray, bucket_rows=1) -> np.ndarray:
    """Quantize per-(src, dst, expert) row counts into shape buckets.

    ``bucket_rows`` is any :func:`repro.core.buckets.BucketSpec.from_any`
    argument: the legacy linear bucket-size int, a :class:`BucketSpec`
    (``linear`` / ``geometric`` / fitted ``ladder``), or a parsed spec
    string like ``"geometric:8"``. Nonzero cells round *up* to their policy
    bucket (the padding rows stay zero-filled in the send buffers, so
    execution is unchanged); empty cells stay empty so plan sparsity — and
    therefore the task graph's nonzero-cell structure — is preserved. Two
    batches whose counts land in the same buckets produce identical plans
    and therefore share one SSC cache entry: this is the shape-bucketing
    layer that keeps the dropless cache hit rate high under batch-to-batch
    routing jitter.
    """
    from repro.core.buckets import BucketSpec
    spec = BucketSpec.from_any(bucket_rows)
    if spec.is_exact:
        return counts
    return spec.quantize(counts)


def routed_counts(top_i, mc: MoEConfig, ep: int) -> np.ndarray:
    """Exact per-(src, dst, expert) row counts of one batch's routing.

    The dropless-counts histogram of :func:`plan_from_routing` without
    building the bridge — what the online tuner's rolling plan population
    stores per served batch (``launch/online.py``). ``top_i`` as in
    :func:`plan_from_routing`; returns int64 ``[ep, ep, e_loc]``.
    """
    ti = np.asarray(top_i)
    if ti.ndim == 2:
        T, k = ti.shape
        if T % ep:
            raise ValueError(f"T={T} tokens not divisible by ep={ep}")
        ti = ti.reshape(ep, T // ep, k)
    if ti.shape[0] != ep:
        raise ValueError(f"leading dim {ti.shape[0]} != ep={ep}")
    if mc.e_total % ep:
        raise ValueError(f"e_total={mc.e_total} not divisible by ep={ep}")
    e_loc = mc.e_total // ep
    _, t_loc, k = ti.shape
    flat = ti.reshape(-1).astype(np.int64)
    src_idx = np.repeat(np.arange(ep, dtype=np.int64), t_loc * k)
    counts = np.zeros((ep, ep, e_loc), dtype=np.int64)
    np.add.at(counts, (src_idx, flat // e_loc, flat % e_loc), 1)
    return counts


def plan_from_routing(top_i, mc: MoEConfig, ep: int,
                      capacity: Optional[int] = None,
                      bucket_rows: int = 1, bucket=None) -> RoutingBridge:
    """Turn real router output into a compilable :class:`RoutingBridge`.

    ``top_i``: expert indices [T, k] (tokens split contiguously over ``ep``
    source ranks; T % ep == 0) or already per-rank [ep, T_loc, k].
    ``capacity``: per-(global expert) token cap applied in global token
    order, matching ``make_dispatch``; ``None`` = dropless.
    ``bucket``: a :class:`repro.core.buckets.BucketSpec` (or anything
    ``BucketSpec.from_any`` accepts) quantizing each cell's row count up to
    its shape bucket; ``bucket_rows`` is the legacy linear-bucket int shim
    (``bucket`` wins when both are given). The actual rows occupy the head
    of each padded cell and the tail rows stay zero, so a schedule compiled
    for the bucketed plan computes the same result as the exact one.
    """
    from repro.core.buckets import normalize_bucket
    from repro.core.routing import RoutingPlan

    spec = normalize_bucket(bucket, bucket_rows)

    ti = np.asarray(top_i)
    if ti.ndim == 2:
        T, k = ti.shape
        if T % ep:
            raise ValueError(f"T={T} tokens not divisible by ep={ep}")
        ti = ti.reshape(ep, T // ep, k)
    if ti.shape[0] != ep:
        raise ValueError(f"leading dim {ti.shape[0]} != ep={ep}")
    _, t_loc, k = ti.shape
    if mc.e_total % ep:
        raise ValueError(f"e_total={mc.e_total} not divisible by ep={ep}")
    e_loc = mc.e_total // ep

    flat = ti.reshape(-1).astype(np.int64)      # global (src-major) order
    src_idx = np.repeat(np.arange(ep, dtype=np.int64), t_loc * k)
    d_idx = flat // e_loc
    e_idx = flat % e_loc

    # Position of each choice within its global expert, in global order —
    # the same cumulative count `make_dispatch` computes.
    slot = _cumcount(flat)
    keep = (slot < capacity) if capacity is not None else np.ones(
        flat.shape[0], dtype=bool)

    counts = np.zeros((ep, ep, e_loc), dtype=np.int64)
    np.add.at(counts, (src_idx[keep], d_idx[keep], e_idx[keep]), 1)
    plan = RoutingPlan.from_counts(bucket_counts(counts, spec))

    # Row within the (src, dst, expert) send cell = occurrence index among
    # the *kept* choices of that cell, in local order.
    send_row = np.full(flat.shape[0], -1, dtype=np.int64)
    kept = np.nonzero(keep)[0]
    cell = (src_idx[kept] * ep + d_idx[kept]) * e_loc + e_idx[kept]
    send_row[kept] = (plan.send_offsets.reshape(-1)[cell]
                      + _cumcount(cell))
    return RoutingBridge(plan=plan,
                         send_row=send_row.reshape(ep, t_loc, k))


def bridge_dispatch(bridge: RoutingBridge, x) -> list:
    """Scatter tokens [ep, T_loc, d] into per-rank plan send buffers."""
    x = np.asarray(x, dtype=np.float32)
    k = bridge.send_row.shape[2]
    bufs = []
    for s in range(bridge.ep):
        buf = np.zeros((bridge.plan.send_rows(s), x.shape[-1]),
                       dtype=np.float32)
        rows = bridge.send_row[s].reshape(-1)
        valid = rows >= 0
        buf[rows[valid]] = np.repeat(x[s], k, axis=0)[valid]
        bufs.append(buf)
    return bufs


def bridge_combine(bridge: RoutingBridge, y_ret: list, top_p) -> np.ndarray:
    """Weight-and-gather executor return buffers back to [ep, T_loc, d].

    Applies the same per-choice accumulation ``moe_grouped`` performs;
    dropped choices contribute zero.
    """
    top_p = np.asarray(top_p, dtype=np.float32).reshape(
        bridge.send_row.shape)
    ep, t_loc, k = bridge.send_row.shape
    d = y_ret[0].shape[-1] if y_ret else 0
    y = np.zeros((ep, t_loc, d), dtype=np.float32)
    for s in range(ep):
        for j in range(k):
            rows = bridge.send_row[s, :, j]
            valid = rows >= 0
            if valid.any():
                y[s, valid] += (top_p[s, valid, j, None]
                                * y_ret[s][rows[valid]])
    return y


def fused_boundary_forward(bridge_out: RoutingBridge,
                           bridge_in: RoutingBridge,
                           top_p_out, d_model: int) -> dict:
    """Per-rank remap fns for one forward junction of a fused schedule.

    The junction composes layer i's combine-weighted gather (the rank-r
    slice of :func:`bridge_combine` under ``bridge_out``/``top_p_out``)
    with layer i+1's send-buffer scatter (the rank-r slice of
    :func:`bridge_dispatch` under ``bridge_in``). Both ops are exactly
    rank-local — a token's returned rows and its next-layer send rows live
    on its own source rank — so the per-rank restriction is *bitwise*
    identical to running the full ops sequentially; the loops below mirror
    them statement for statement to keep it that way.

    Returns ``{rank: fn}`` with the executor's LayerBoundary contract
    ``fn(full_y_ret_or_None, lo, hi) -> [hi - lo, d_model]``; the full
    remap is memoized per rank, so tile granularity costs nothing.
    """
    tp = np.asarray(top_p_out, dtype=np.float32).reshape(
        bridge_out.send_row.shape)
    ep, t_loc, k_out = bridge_out.send_row.shape
    k_in = bridge_in.send_row.shape[2]
    fns = {}
    for r in range(ep):
        def fn(data, lo, hi, r=r, _memo={}):
            if "buf" not in _memo:
                y = np.zeros((t_loc, d_model), dtype=np.float32)
                for j in range(k_out):
                    rows = bridge_out.send_row[r, :, j]
                    valid = rows >= 0
                    if valid.any():
                        y[valid] += tp[r, valid, j, None] * data[rows[valid]]
                buf = np.zeros((bridge_in.plan.send_rows(r), d_model),
                               dtype=np.float32)
                rows = bridge_in.send_row[r].reshape(-1)
                valid = rows >= 0
                buf[rows[valid]] = np.repeat(y, k_in, axis=0)[valid]
                _memo["buf"] = buf
            return _memo["buf"][lo:hi]
        fns[r] = fn
    return fns


def fused_boundary_backward(bridge_out: RoutingBridge,
                            bridge_in: RoutingBridge,
                            top_p_out, d_model: int) -> dict:
    """Backward twin of :func:`fused_boundary_forward`.

    Maps ``dx_ret`` of layer i+1's backward fragment (gradient w.r.t. that
    layer's send buffer) to ``dy_src`` of layer i's (gradient w.r.t. its
    return buffer): gather-sum the dispatched copies back to tokens
    (dispatch transpose), then scatter the combine weights' products into
    the upstream send layout (combine transpose). Rank-local for the same
    reason as the forward; mirrors the dropless backward host's
    accumulation statements bit for bit.
    """
    tp = np.asarray(top_p_out, dtype=np.float32).reshape(
        bridge_out.send_row.shape)
    ep, t_loc, k_out = bridge_out.send_row.shape
    k_in = bridge_in.send_row.shape[2]
    fns = {}
    for r in range(ep):
        def fn(data, lo, hi, r=r, _memo={}):
            if "buf" not in _memo:
                dx_tok = np.zeros((t_loc, d_model), dtype=np.float32)
                for j in range(k_in):
                    rows = bridge_in.send_row[r, :, j]
                    valid = rows >= 0
                    if valid.any():
                        dx_tok[valid] += data[rows[valid]]
                dy = np.zeros((bridge_out.plan.send_rows(r), d_model),
                              dtype=np.float32)
                rows = bridge_out.send_row[r].reshape(-1)
                valid = rows >= 0
                contrib = (tp[r][:, :, None] * dx_tok[:, None, :]).reshape(
                    -1, d_model)
                np.add.at(dy, rows[valid], contrib[valid])
                _memo["buf"] = dy
            return _memo["buf"][lo:hi]
        fns[r] = fn
    return fns


def moe_grouped(params, x, mc: MoEConfig, act: str = "swiglu",
                cap: Optional[int] = None, gmm_fn=None):
    """Sorted/capacity dispatch → grouped FFN → weighted combine.

    ``gmm_fn(x_sorted, group_sizes, w_in, w_down)`` may override the expert
    FFN with the Pallas grouped-GEMM kernel; defaults to the einsum path.
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E = mc.e_total
    C = cap or capacity(T, mc)
    top_p, top_i, slot = _routed(params, xt, mc, C)

    # Dispatch: scatter tokens into [E, C, d] expert buffers.
    disp = jnp.zeros((E, C + 1, d), x.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], top_i.shape)
    disp = disp.at[top_i.reshape(-1), slot.reshape(-1)].add(
        xt[tok_idx.reshape(-1)])
    disp = disp[:, :C]

    if gmm_fn is not None:
        out_e = gmm_fn(disp, params["w_in"], params["w_down"], act)
    else:
        out_e = expert_ffn(params["w_in"], params["w_down"], disp, act)

    # Combine: gather back with routing weights.
    out_e = jnp.concatenate([out_e, jnp.zeros_like(out_e[:, :1])], axis=1)
    y = jnp.zeros((T, d), x.dtype)
    for j in range(mc.top_k):
        y = y + (out_e[top_i[:, j], slot[:, j]]
                 * top_p[:, j][:, None].astype(x.dtype))
    return y.reshape(B, S, d)
