"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = σ(W_a x_t + b_a)            gate
    i_t = σ(W_x x_t + b_x)            input gate
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over the linear recurrence
(log-depth); decode is the single-step update. The full Griffin recurrent
block wraps the RG-LRU with a temporal conv and a GeGLU-style output gate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

C_RGLRU = 8.0


def init_rglru(key, d_model: int, lru_width: int, conv_width: int = 4,
               dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    std = d_model ** -0.5
    w = lru_width
    return {
        "in_x": jax.random.normal(ks[0], (d_model, w), dtype) * std,
        "in_y": jax.random.normal(ks[1], (d_model, w), dtype) * std,
        "conv_w": jax.random.normal(ks[2], (conv_width, w), dtype) * 0.1,
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": jax.random.normal(ks[3], (w, w), jnp.float32) * w ** -0.5,
        "gate_a_b": jnp.zeros((w,), jnp.float32),
        "gate_x": jax.random.normal(ks[4], (w, w), jnp.float32) * w ** -0.5,
        "gate_x_b": jnp.zeros((w,), jnp.float32),
        # Λ init so a^c spans (0.9, 0.999) — Griffin's stable range.
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / C_RGLRU)),
        "out": jax.random.normal(ks[5], (w, d_model), dtype) * w ** -0.5,
    }


def _rglru_core(x, p, h0=None):
    """x: [B, L, W] → (h: [B, L, W], h_last). Linear recurrence scan."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xf, p["gate_a"])
                       + p["gate_a_b"])
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xf, p["gate_x"])
                       + p["gate_x_b"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * (i * xf)

    if x.shape[1] == 1 and h0 is not None:
        h = a[:, 0] * h0 + gated[:, 0]
        return h[:, None].astype(x.dtype), h

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_in, b_in = a, gated
    if h0 is not None:
        b_in = b_in.at[:, 0].add(a[:, 0] * h0)
    A, Bv = jax.lax.associative_scan(op, (a_in, b_in), axis=1)
    return Bv.astype(x.dtype), Bv[:, -1]


def rglru_block(p, x, state=None, conv_width: int = 4):
    """Full Griffin recurrent block. x: [B, L, d] → (y, new_state)."""
    from .ssm import _causal_conv
    dt = x.dtype
    branch = jnp.einsum("bld,dw->blw", x, p["in_x"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, p["in_y"].astype(dt)),
                       approximate=True)
    conv_state = state["conv"] if state is not None else None
    branch, conv_tail = _causal_conv(branch, p["conv_w"].astype(dt),
                                     p["conv_b"].astype(dt), conv_state)
    h0 = state["h"] if state is not None else None
    h, h_last = _rglru_core(branch, p, h0)
    y = jnp.einsum("blw,wd->bld", h * gate, p["out"].astype(dt))
    new_state = ({"conv": conv_tail, "h": h_last}
                 if state is not None else None)
    return y, new_state


def rglru_reference(x, p, h0=None):
    """Sequential-scan oracle for tests."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xf, p["gate_a"])
                       + p["gate_a_b"])
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xf, p["gate_x"])
                       + p["gate_x_b"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * (i * xf)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    h0 = jnp.zeros_like(a[:, 0]) if h0 is None else h0
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                    jnp.moveaxis(gated, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
