"""Core transformer layer primitives (pure JAX, shard-friendly).

Everything is a pure function over explicit parameter pytrees — no module
framework. Conventions:

* activations ``[batch, seq, d_model]``; attention heads ``[B, S, H, hd]``;
* parameters are created in ``init_*`` fns (fp32 masters; cast at use);
* attention is *always* computed blockwise over KV (online softmax), so the
  full ``S×S`` score matrix never materializes — required for the 32k prefill
  cells to fit HBM and the production answer anyway;
* all einsums keep named dims stable so GSPMD can propagate shardings.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(dt)


def nonparam_ln(x, eps=1e-5):
    """OLMo-style non-parametric LayerNorm (no scale, no bias)."""
    return layer_norm(x, None, None, eps)


def apply_norm(kind: str, x, p, name: str):
    if kind == "rmsnorm":
        return rms_norm(x, p[name])
    if kind == "layernorm":
        return layer_norm(x, p[name], p.get(name + "_b"))
    if kind == "nonparam_ln":
        return nonparam_ln(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """cos/sin tables [..., head_dim/2] for given integer positions."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, S, H, hd]; cos/sin: [B, S, hd/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — online softmax over KV blocks.
# ---------------------------------------------------------------------------


def _attn_block(q, k, v, mask, scale):
    """One KV block: returns (scores_max, exp_sum, weighted_v) in fp32."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)                                  # [B,H,Q]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                                  # noqa: E741
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return m, l, o.astype(jnp.float32)


def blockwise_attention(q, k, v, *, causal: bool, q_offset,
                        sliding_window: int = 0, block: int = 1024,
                        scale: Optional[float] = None):
    """Online-softmax attention, O(S·block) memory.

    q: [B, Sq, H, hd]; k/v: [B, Sk, K, hd] with K | H (GQA: kv repeated).
    ``q_offset`` is the absolute position of q[0] relative to k[0] (decode:
    cache_len; self-attn: 0). ``sliding_window`` masks keys older than W.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    nb = max(1, (Sk + block - 1) // block)
    pad = nb * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block, H, hd)
    vb = v.reshape(B, nb, block, H, hd)

    q_pos = q_offset + jnp.arange(Sq)                        # [Sq]

    def body(carry, blk):
        m_acc, l_acc, o_acc, i = carry
        kb_i, vb_i = blk
        k_pos = i * block + jnp.arange(block)                # [block]
        mask = jnp.ones((Sq, block), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if sliding_window:
            mask &= q_pos[:, None] - k_pos[None, :] < sliding_window
        mask &= (k_pos < Sk)[None, :]
        m, l, o = _attn_block(q, kb_i, vb_i, mask[None, None], scale)  # noqa: E741
        m_new = jnp.maximum(m_acc, m)
        c_old = jnp.exp(m_acc - m_new)
        c_new = jnp.exp(m - m_new)
        l_new = l_acc * c_old + l * c_new
        o_new = (o_acc * c_old[..., None].transpose(0, 2, 1, 3)
                 + o * c_new[..., None].transpose(0, 2, 1, 3))
        return (m_new, l_new, o_new, i + 1), None

    m0 = jnp.full((B, H, Sq), -1e30, dtype=jnp.float32)
    l0 = jnp.zeros((B, H, Sq), dtype=jnp.float32)
    o0 = jnp.zeros((B, Sq, H, hd), dtype=jnp.float32)
    # checkpoint per KV block: the backward recomputes each block's scores
    # instead of saving [nb, B, H, Sq, block] fp32 probs — this is what
    # makes the attention actually flash-like in memory on the bwd pass.
    (m, l, o, _), _ = jax.lax.scan(      # noqa: E741
        jax.checkpoint(body), (m0, l0, o0, jnp.int32(0)),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
    denom = l.transpose(0, 2, 1)[..., None]                  # [B,Sq,H,1]
    return (o / jnp.maximum(denom, 1e-30)).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     sliding_window: int = 0,
                     scale: Optional[float] = None):
    """Single-token attention against a (possibly sharded) KV cache.

    q: [B, 1, H, hd]; caches: [B, S, K, hd]. Softmax reductions over the
    cache S dim are plain jnp reductions, so a sequence-sharded cache
    resolves to GSPMD all-reduces — the flash-decoding pattern.
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, 1, K, H // K, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache)
    s = s.astype(jnp.float32) * scale
    pos = jnp.arange(S)
    lens = jnp.asarray(cache_len)
    if lens.ndim == 0:                                       # uniform batch
        lens = jnp.full((B,), lens)
    mask = pos[None, :] < lens[:, None]                      # [B, S]
    if sliding_window:
        mask &= pos[None, :] >= lens[:, None] - sliding_window
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, hd)


def _ring_decode_attention(q, k_cache, v_cache, n_tokens, W):
    """Decode attention over a ring-buffer window cache of W slots.

    Slot ``i`` holds the newest token with ``pos ≡ i (mod W)`` — all slots
    are within the window by construction; only not-yet-written slots are
    masked (n_tokens < W). Keys were rotated at absolute positions already.
    """
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    qg = q.reshape(B, 1, K, H // K, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    s = s * (1.0 / np.sqrt(hd))
    lens = jnp.asarray(n_tokens)
    if lens.ndim == 0:
        lens = jnp.full((B,), lens)
    mask = jnp.arange(W)[None, :] < lens[:, None]
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# Attention layer (GQA/MQA, optional bias, RoPE, KV cache)
# ---------------------------------------------------------------------------


def init_attention(key, d_model, n_heads, n_kv_heads, head_dim,
                   qkv_bias=False, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d_model ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d_model, n_heads * head_dim), dtype) * std,
        "wk": jax.random.normal(k2, (d_model, n_kv_heads * head_dim), dtype) * std,
        "wv": jax.random.normal(k3, (d_model, n_kv_heads * head_dim), dtype) * std,
        "wo": jax.random.normal(k4, (n_heads * head_dim, d_model), dtype) * std,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def attention(p, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
              causal=True, sliding_window=0, block=1024,
              cache=None, positions=None):
    """Returns (out, new_cache). ``cache`` = dict(k, v, len) for decode."""
    B, S, _ = x.shape
    compute_dtype = x.dtype

    def proj(w, b, n):
        y = jnp.einsum("bsd,de->bse", x, w.astype(compute_dtype))
        if b is not None:
            y = y + b.astype(compute_dtype)
        return y.reshape(B, S, n, head_dim)

    from repro.parallel.ctx import constrain_heads
    # Head-shard the attention tensors (SP→TP reshard at the block entry;
    # no-op without an active head_sharding context or on smoke tests).
    q = constrain_heads(proj(p["wq"], p.get("bq"), n_heads))
    k = proj(p["wk"], p.get("bk"), n_kv_heads)
    v = proj(p["wv"], p.get("bv"), n_kv_heads)

    if positions is None:
        if cache is not None:
            # cache["len"]: scalar (uniform batched serving) or [B]
            # (continuous batching with per-slot positions).
            lens = jnp.asarray(cache["len"])
            if lens.ndim == 0:
                positions = jnp.broadcast_to(
                    (lens + jnp.arange(S))[None, :], (B, S))
            else:
                positions = lens[:, None] + jnp.arange(S)[None, :]
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if rope_theta:
        cos, sin = rope_tables(positions, head_dim, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        if S == 1:
            # Sharded one-token decode (flash-decoding) when the launcher
            # installed an impl: local cache write + LSE-combined partials.
            from repro.parallel.ctx import current_flash_decode
            fd = current_flash_decode()
            if fd is not None and not sliding_window:
                res = fd(q, cache["k"], cache["v"], k, v, cache["len"])
                if res is not None:
                    o, kc, vc = res
                    new_cache = {"k": kc, "v": vc, "len": cache["len"] + 1}
                    out = jnp.einsum(
                        "bse,ed->bsd", o.reshape(B, S, n_heads * head_dim),
                        p["wo"].astype(compute_dtype))
                    return out, new_cache
            # Decode: scatter this token's K/V at the write index. Scalar
            # len → one DUS (sharding-friendly); per-slot [B] len → vmapped
            # per-slot writes (continuous batching). Ring buffer for
            # sliding-window caches: wrap so the cache stays O(window).
            W = cache["k"].shape[1]
            lens = jnp.asarray(cache["len"])
            wrap = sliding_window and W <= sliding_window
            if lens.ndim == 0:
                idx = lens % W if wrap else lens
                kc = jax.lax.dynamic_update_slice(cache["k"], k,
                                                  (0, idx, 0, 0))
                vc = jax.lax.dynamic_update_slice(cache["v"], v,
                                                  (0, idx, 0, 0))
            else:
                idx = lens % W if wrap else lens
                kc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                    c, u, (i, 0, 0)))(cache["k"], k, idx)
                vc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                    c, u, (i, 0, 0)))(cache["v"], v, idx)
            new_cache = {"k": kc, "v": vc, "len": cache["len"] + 1}
            if sliding_window and W <= sliding_window:
                o = _ring_decode_attention(q, kc, vc, cache["len"] + 1, W)
            else:
                o = decode_attention(q, kc, vc, cache["len"] + 1,
                                     sliding_window=sliding_window)
        else:
            # Prefill into an empty cache. Window (ring) caches smaller than
            # the prompt keep the last W keys, aligned to ring slots.
            W = cache["k"].shape[1]
            if W < S:
                # slot(p) = p % W: element j of the last-W slice holds
                # position S-W+j and belongs at slot (j + S) % W.
                roll = S % W
                kc = jnp.roll(k[:, -W:], roll, axis=1)
                vc = jnp.roll(v[:, -W:], roll, axis=1)
            elif W > S:
                kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
            else:
                kc, vc = k, v
            new_cache = {"k": kc, "v": vc, "len": cache["len"] + S}
            o = blockwise_attention(q, k, v, causal=causal, q_offset=0,
                                    sliding_window=sliding_window,
                                    block=block)
    else:
        o = blockwise_attention(q, k, v, causal=causal, q_offset=0,
                                sliding_window=sliding_window, block=block)

    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, n_heads * head_dim),
                     p["wo"].astype(compute_dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU fused-gate, or plain GELU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, act, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    std = d_model ** -0.5
    if act in ("swiglu", "geglu"):
        return {"w_in": jax.random.normal(k1, (d_model, 2 * d_ff), dtype) * std,
                "w_down": jax.random.normal(k2, (d_ff, d_model), dtype)
                * d_ff ** -0.5}
    return {"w_in": jax.random.normal(k1, (d_model, d_ff), dtype) * std,
            "w_down": jax.random.normal(k2, (d_ff, d_model), dtype)
            * d_ff ** -0.5}


def glu_act(h, act: str):
    f = h.shape[-1] // 2
    a, b = h[..., :f], h[..., f:]
    if act == "swiglu":
        return jax.nn.silu(a) * b
    if act == "geglu":
        return jax.nn.gelu(a, approximate=True) * b
    raise ValueError(act)


def mlp(p, x, act: str):
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dt))
    if act in ("swiglu", "geglu"):
        h = glu_act(h, act)
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
