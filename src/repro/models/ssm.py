"""Mamba2 — State Space Duality (SSD), chunked training scan + decode step.

Implements the chunked SSD algorithm of arXiv:2405.21060 §6: within a chunk
the output is a masked (causal, decay-weighted) attention-like matmul; chunk
boundary states are carried by a linear recurrence. This keeps everything
MXU-shaped matmuls (the TPU-friendly form) with O(L·Q) memory.

Decode maintains the recurrent state  S ∈ [B, H, P, N]:
    S_t = a_t · S_t-1 + dt·B_tᵀ ⊗ x_t ;   y_t = C_t · S_t + D ⊙ x_t.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128           # N
    head_dim: int = 64           # P
    expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128             # SSD chunk length Q

    def n_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim


def init_ssm(key, d_model: int, sc: SSMConfig, dtype=jnp.float32):
    H = sc.n_heads(d_model)
    d_in = sc.expand * d_model
    N = sc.d_state
    ks = jax.random.split(key, 6)
    std = d_model ** -0.5
    # in_proj produces [z (gate), x, B, C, dt] fused.
    zxbcdt = d_in + d_in + N + N + H
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, zxbcdt), dtype) * std,
        "conv_w": jax.random.normal(ks[1], (sc.conv_width, d_in + 2 * N),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((d_in + 2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (d_in, d_model), dtype)
        * d_in ** -0.5,
        "norm_w": jnp.zeros((d_in,), dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d. x: [B, L, C]; w: [W, C]. Returns (y, tail)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    tail = xp[:, -(W - 1):]
    return y + b[None, None, :], tail


def _ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD scan.

    x: [b, L, H, P]; dt: [b, L, H]; A: [H] (negative rates);
    B, C: [b, L, N] (single group); D: [H]. Returns y: [b, L, H, P].
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    Q = chunk
    nc = L // Q
    assert L % Q == 0, "sequence length must be a multiple of the SSD chunk"

    la = (dt * A[None, None, :]).reshape(b, nc, Q, H)   # log decay per step
    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, N)
    Cc = C.reshape(b, nc, Q, N)

    cs = jnp.cumsum(la, axis=2)                         # [b,nc,Q,H]
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # [b,nc,Q(i),Q(j),H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # Mask *before* exp: the non-causal entries are positive and would
    # overflow, poisoning gradients through the where.
    decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))

    # Intra-chunk (the "attention-like" quadratic term).
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)      # [b,nc,Q,Q]
    M = scores[..., None] * decay                       # [b,nc,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", M, dtc, xc)

    # Chunk states: S_c = Σ_j exp(cs_end - cs_j) dt_j B_j x_jᵀ.
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)       # [b,nc,Q,H]
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                     Bc, dtc * decay_to_end, xc)        # [b,nc,H,N,P]

    # Inter-chunk recurrence over chunk states.
    a_chunk = jnp.exp(cs[:, :, -1, :])                  # [b,nc,H]

    def step(S_prev, inp):
        a_k, S_k = inp
        S_new = S_prev * a_k[..., None, None] + S_k
        return S_new, S_prev

    S0 = jnp.zeros((b, H, N, P), x.dtype)
    S_final, S_prevs = jax.lax.scan(
        step, S0, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(S_c, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)               # [b,nc,H,N,P]

    decay_from_start = jnp.exp(cs)                      # [b,nc,Q,H]
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cc, decay_from_start, S_prevs)

    y = (y_intra + y_inter).reshape(b, L, H, P)
    return y + x * D[None, None, :, None], S_final


def ssm_forward(p, x, sc: SSMConfig, state=None):
    """Full Mamba2 mixer. x: [B, L, d_model] → (y, new_state).

    ``state`` = dict(conv [B, W-1, d_conv], ssm [B, H, N, P]) for decode.
    """
    Bsz, L, d_model = x.shape
    H = sc.n_heads(d_model)
    P, N = sc.head_dim, sc.d_state
    d_in = sc.expand * d_model
    dt_f = x.dtype

    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dt_f))
    z, xs, B_, C_, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"].astype(dt_f),
                                       p["conv_b"].astype(dt_f), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs, B_, C_ = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    xh = xs.reshape(Bsz, L, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])     # [B,L,H]
    A = -jnp.exp(p["A_log"])                                # [H] negative

    new_state = None
    if state is not None and L == 1:
        # Recurrent decode step.
        a = jnp.exp(dt[:, 0] * A[None, :])                  # [B,H]
        dBx = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0].astype(dt_f),
                         B_[:, 0], xh[:, 0])
        S = state["ssm"] * a[..., None, None].astype(dt_f) + dBx
        y = jnp.einsum("bn,bhnp->bhp", C_[:, 0], S)
        y = y + xh[:, 0] * p["D"].astype(dt_f)[None, :, None]
        y = y[:, None]                                      # [B,1,H,P]
        new_state = {"conv": conv_tail, "ssm": S}
    else:
        y, S_final = _ssd_chunked(xh, dt.astype(dt_f), A.astype(dt_f), B_,
                                  C_, p["D"].astype(dt_f), min(sc.chunk, L))
        if state is not None:
            # Prefill: hand the final recurrent + conv state to decode.
            new_state = {"conv": conv_tail, "ssm": S_final}

    y = y.reshape(Bsz, L, d_in)
    # Gated RMSNorm (Mamba2's norm-before-out-proj).
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(dt_f)
    y = y * (1.0 + p["norm_w"].astype(dt_f))[None, None, :]
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(dt_f))
    return out, new_state


def ssd_reference(x, dt, A, B, C, D):
    """O(L²)-free sequential reference for tests: plain recurrence."""
    b, L, H, P = x.shape
    N = B.shape[-1]

    def step(S, inp):
        x_t, dt_t, B_t, C_t = inp
        a = jnp.exp(dt_t * A)                               # [b,H]
        S = S * a[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhnp", dt_t, B_t, x_t)
        y = jnp.einsum("bn,bhnp->bhp", C_t, S)
        return S, y

    S0 = jnp.zeros((b, H, N, P), x.dtype)
    _, ys = jax.lax.scan(step, S0, (jnp.moveaxis(x, 1, 0),
                                    jnp.moveaxis(dt, 1, 0),
                                    jnp.moveaxis(B, 1, 0),
                                    jnp.moveaxis(C, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)
    return y + x * D[None, None, :, None]
