"""Composable model builder: ModelConfig → init / forward / prefill / decode.

One config dataclass covers all ten assigned architecture families:
dense & MoE decoders, encoder-only (audio), VLM backbones, Mamba2 SSD, and
the RecurrentGemma hybrid. Homogeneous stacks scan over stacked per-layer
parameters (compact HLO, remat-friendly); the hybrid stack scans over
(pattern)-superblocks with an unrolled tail.

The MoE block takes a pluggable ``moe_impl`` so the distributed launcher can
inject the EP-sharded execution path (see ``repro/parallel/ep.py``) without
touching model code — the paper's "low code intrusion" integration point.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.ctx import constrain_activation, current_moe_impl

from . import layers as L
from .moe import MoEConfig, init_moe, moe_grouped
from .rglru import init_rglru, rglru_block
from .ssm import SSMConfig, init_ssm, ssm_forward


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0             # 0 → d_model // n_heads
    act: str = "swiglu"           # swiglu | geglu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparam_ln
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    sliding_window: int = 0
    embed_scale: bool = False     # gemma-style sqrt(d) embedding scaling
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_pattern: tuple = ()    # e.g. ("rglru", "rglru", "local_attn")
    lru_width: int = 0
    feat_in: int = 0              # audio frontend feature width (stub)
    n_patches: int = 0            # vlm patch-prefix length (stub)
    vocab_pad: int = 256
    dtype: str = "bfloat16"
    remat: bool = True
    # 'full' recomputes everything; 'save_moe' checkpoints each block's MoE
    # output so the backward never re-runs dispatch/FFN/combine (saves one
    # full EP round-trip of collectives per layer at ~12MB/layer/device).
    remat_policy: str = "full"
    scan_layers: bool = True      # False → unrolled python loop (cost probes)
    attn_block: int = 1024        # KV block for blockwise attention

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def padded_vocab(self) -> int:
        return int(np.ceil(self.vocab / self.vocab_pad) * self.vocab_pad)

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def layer_types(self) -> list[str]:
        if self.family in ("dense", "audio", "vlm"):
            return ["attn"] * self.n_layers
        if self.family == "moe":
            return ["attn_moe"] * self.n_layers
        if self.family == "ssm":
            return ["ssm"] * self.n_layers
        if self.family == "hybrid":
            pat = list(self.hybrid_pattern)
            out = []
            while len(out) < self.n_layers:
                out.extend(pat)
            return out[:self.n_layers]
        raise ValueError(self.family)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, f, V = self.d_model, self.d_ff, self.padded_vocab
        n = V * d  # embed
        if not self.tie_embeddings:
            n += d * V
        for t in self.layer_types():
            if t in ("attn", "attn_moe", "local_attn"):
                n += d * (self.n_heads + 2 * self.n_kv_heads) * self.hd
                n += self.n_heads * self.hd * d
            if t == "attn":
                n += (3 if self.act in ("swiglu", "geglu") else 2) * d * f
            if t == "local_attn" or t == "rglru":
                n += (3 if self.act in ("swiglu", "geglu") else 2) * d * f
            if t == "attn_moe":
                m = self.moe
                n += d * m.e_total + m.e_total * 3 * d * m.d_expert
            if t == "ssm":
                s = self.ssm
                d_in = s.expand * d
                H = s.n_heads(d)
                n += d * (2 * d_in + 2 * s.d_state + H) + d_in * d
            if t == "rglru":
                w = self.lru_width or d
                n += 2 * d * w + 2 * w * w + w * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        full = self.param_count()
        expert_p = self.n_layers * m.e_total * 3 * self.d_model * m.d_expert
        active_e = self.n_layers * m.top_k * 3 * self.d_model * m.d_expert
        return full - expert_p + active_e


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_norms(cfg, p, key):
    if cfg.norm == "nonparam_ln":
        return p
    p["ln1"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.norm == "layernorm":
        p["ln1_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ln2_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def _init_block(cfg: ModelConfig, btype: str, key):
    dt = jnp.float32
    ks = jax.random.split(key, 4)
    p: dict = {}
    if btype in ("attn", "attn_moe", "local_attn"):
        p["attn"] = L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.hd, cfg.qkv_bias, dt)
    if btype in ("attn", "local_attn"):
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
    if btype == "attn_moe":
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe, dt)
    if btype == "ssm":
        p["ssm"] = init_ssm(ks[0], cfg.d_model, cfg.ssm, dt)
    if btype == "rglru":
        p["rglru"] = init_rglru(ks[0], cfg.d_model,
                                cfg.lru_width or cfg.d_model, 4, dt)
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return _init_norms(cfg, p, ks[3])


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    V, d = cfg.padded_vocab, cfg.d_model
    params: dict = {
        "embed": jax.random.normal(ks[0], (V, d), jnp.float32) * d ** -0.5,
    }
    if cfg.family == "audio":
        params["feat_proj"] = jax.random.normal(
            ks[3], (cfg.feat_in, d), jnp.float32) * cfg.feat_in ** -0.5
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            ks[1], (d, V), jnp.float32) * d ** -0.5
    if cfg.norm != "nonparam_ln":
        params["ln_f"] = jnp.zeros((d,), jnp.float32)
        if cfg.norm == "layernorm":
            params["ln_f_b"] = jnp.zeros((d,), jnp.float32)

    types = cfg.layer_types()
    lkeys = jax.random.split(ks[2], cfg.n_layers)
    if cfg.family == "hybrid":
        pat = len(cfg.hybrid_pattern)
        n_super = cfg.n_layers // pat
        super_blocks = []
        for pos in range(pat):
            idxs = [g * pat + pos for g in range(n_super)]
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_init_block(cfg, types[i], lkeys[i]) for i in idxs])
            super_blocks.append(stacked)
        params["super"] = tuple(super_blocks)
        params["tail"] = [
            _init_block(cfg, types[i], lkeys[i])
            for i in range(n_super * pat, cfg.n_layers)]
    else:
        params["blocks"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_block(cfg, types[i], lkeys[i])
              for i in range(cfg.n_layers)])
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _norm(cfg, p, x, which):
    return L.apply_norm(cfg.norm, x, p, which)


def block_apply(cfg: ModelConfig, btype: str, p, x, cache=None,
                moe_impl: Optional[Callable] = None):
    """One residual block. Returns (x, new_cache)."""
    new_cache = None
    if btype in ("attn", "attn_moe", "local_attn"):
        window = cfg.sliding_window if btype == "local_attn" else (
            cfg.sliding_window if cfg.family != "hybrid" else 0)
        a, new_cache = L.attention(
            p["attn"], _norm(cfg, p, x, "ln1"),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, causal=cfg.causal,
            sliding_window=window, block=cfg.attn_block, cache=cache)
        x = x + a
        h = _norm(cfg, p, x, "ln2")
        if btype == "attn_moe":
            impl = (moe_impl or current_moe_impl()
                    or partial(moe_grouped, act=cfg.act))
            moe_out = impl(p["moe"], h, cfg.moe)
            if cfg.remat_policy == "save_moe":
                from jax.ad_checkpoint import checkpoint_name
                moe_out = checkpoint_name(moe_out, "moe_out")
            x = x + moe_out
        else:
            x = x + L.mlp(p["mlp"], h, cfg.act)
    elif btype == "ssm":
        y, new_cache = ssm_forward(p["ssm"], _norm(cfg, p, x, "ln1"),
                                   cfg.ssm, cache)
        x = x + y
    elif btype == "rglru":
        y, new_cache = rglru_block(p["rglru"], _norm(cfg, p, x, "ln1"), cache)
        x = x + y
        x = x + L.mlp(p["mlp"], _norm(cfg, p, x, "ln2"), cfg.act)
    else:
        raise ValueError(btype)
    return x, new_cache


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    dt = cfg.compute_dtype
    if cfg.family == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["features"].astype(dt),
                       params["feat_proj"].astype(dt))
    else:
        x = params["embed"].astype(dt)[batch["tokens"]]
        if cfg.family == "vlm" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(dt), x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    return x


def _run_stack(cfg: ModelConfig, params, x, caches=None,
               moe_impl=None):
    """Apply all layers. caches: stacked pytree or None."""
    types = cfg.layer_types()

    if cfg.family == "hybrid":
        pat = len(cfg.hybrid_pattern)
        n_super = cfg.n_layers // pat

        def super_body(carry, inp):
            x = carry
            ps, cs = inp
            new_cs = []
            for pos in range(pat):
                x, nc = block_apply(cfg, cfg.hybrid_pattern[pos], ps[pos], x,
                                    None if cs is None else cs[pos], moe_impl)
                new_cs.append(nc)
            return x, (tuple(new_cs) if cs is not None else None)

        body = jax.checkpoint(super_body) if cfg.remat else super_body
        sup_caches = None if caches is None else caches["super"]
        if cfg.scan_layers:
            x, new_sup = jax.lax.scan(
                body, x, (params["super"], sup_caches))
        else:
            ncs = []
            for i in range(n_super):
                ps = jax.tree.map(lambda a: a[i], params["super"])
                cs = (None if sup_caches is None
                      else jax.tree.map(lambda a: a[i], sup_caches))
                x, nc = body(x, (ps, cs))
                ncs.append(nc)
            new_sup = (None if sup_caches is None
                       else jax.tree.map(lambda *a: jnp.stack(a), *ncs))
        new_tail = []
        for i, bp in enumerate(params["tail"]):
            btype = types[n_super * pat + i]
            c = None if caches is None else caches["tail"][i]
            x, nc = block_apply(cfg, btype, bp, x, c, moe_impl)
            new_tail.append(nc)
        new_caches = (None if caches is None
                      else {"super": new_sup, "tail": new_tail})
        return x, new_caches

    btype = types[0]

    def body(x, inp):
        ps, cs = inp
        x, nc = block_apply(cfg, btype, ps, x, cs, moe_impl)
        # Sequence-parallel residual stream between blocks (no-op unless an
        # activation_sharding context is active — keeps model mesh-agnostic).
        return constrain_activation(x), nc

    if cfg.remat and cfg.remat_policy == "save_moe":
        fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names(
                "moe_out"))
    elif cfg.remat:
        fn = jax.checkpoint(body)
    else:
        fn = body
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(fn, x, (params["blocks"], caches))
        return x, new_caches
    # Unrolled loop (used by the dry-run cost probes: XLA's HloCostAnalysis
    # counts while bodies once, so scanned stacks under-report flops).
    ncs = []
    for i in range(cfg.n_layers):
        ps = jax.tree.map(lambda a: a[i], params["blocks"])
        cs = (None if caches is None
              else jax.tree.map(lambda a: a[i], caches))
        x, nc = fn(x, (ps, cs))
        ncs.append(nc)
    new_caches = (None if caches is None
                  else jax.tree.map(lambda *a: jnp.stack(a), *ncs))
    return x, new_caches


def forward(cfg: ModelConfig, params, batch, moe_impl=None):
    """Full forward → logits [B, S, Vp] (VLM: token region only)."""
    x = final_hidden(cfg, params, batch, moe_impl)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    return jnp.einsum("bsd,dv->bsv", x, unembed.astype(x.dtype))


def _ce_chunk(cfg: ModelConfig, x, labels, unembed):
    """CE over one sequence chunk; logits exist only inside this fn."""
    logits = jnp.einsum("bsd,dv->bsv", x,
                        unembed.astype(x.dtype)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - picked) * mask), jnp.sum(mask)


def loss_fn(cfg: ModelConfig, params, batch, moe_impl=None,
            ce_chunk: int = 512):
    """Next-token (or frame-label) cross entropy, fp32, vocab-pad masked.

    The unembedding + logsumexp run in sequence chunks under jax.checkpoint
    so the full [B, S, V] logits tensor never materializes — required to fit
    the 100k+-vocab archs in HBM at train_4k."""
    x = final_hidden(cfg, params, batch, moe_impl)
    labels = batch["labels"]
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    B, S, _ = x.shape
    n = max(1, S // max(1, min(ce_chunk, S)))
    while S % n:
        n -= 1
    xs = x.reshape(B, n, S // n, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, S // n).transpose(1, 0, 2)

    def chunk_body(carry, inp):
        x_c, l_c = inp
        nll_c, cnt_c = _ce_chunk(cfg, x_c, l_c, unembed)
        return (carry[0] + nll_c, carry[1] + cnt_c), None

    (nll, cnt), _ = jax.lax.scan(jax.checkpoint(chunk_body),
                                 (0.0, 0.0), (xs, ls))
    return nll / jnp.maximum(cnt, 1.0)


def final_hidden(cfg: ModelConfig, params, batch, moe_impl=None):
    """Forward to the final (pre-unembedding) hidden states."""
    x = constrain_activation(embed_inputs(cfg, params, batch))
    x, _ = _run_stack(cfg, params, x, None, moe_impl)
    if cfg.norm != "nonparam_ln":
        x = L.apply_norm(cfg.norm, x, params, "ln_f")
    else:
        x = L.nonparam_ln(x)
    if cfg.family == "vlm" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]
    return x


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _block_cache(cfg: ModelConfig, btype: str, B: int, max_len: int,
                 per_slot_len: bool = False):
    dt = cfg.compute_dtype
    zlen = (jnp.zeros((B,), jnp.int32) if per_slot_len else jnp.int32(0))
    if btype in ("attn", "attn_moe"):
        shp = (B, max_len, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt),
                "len": zlen}
    if btype == "local_attn":
        W = min(max_len, cfg.sliding_window or max_len)
        shp = (B, W, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt),
                "len": zlen}
    if btype == "ssm":
        s = cfg.ssm
        H = s.n_heads(cfg.d_model)
        d_in = s.expand * cfg.d_model
        return {"conv": jnp.zeros((B, s.conv_width - 1,
                                   d_in + 2 * s.d_state), dt),
                "ssm": jnp.zeros((B, H, s.d_state, s.head_dim), dt)}
    if btype == "rglru":
        w = cfg.lru_width or cfg.d_model
        return {"conv": jnp.zeros((B, 3, w), dt),
                "h": jnp.zeros((B, w), jnp.float32)}
    raise ValueError(btype)


def init_cache(cfg: ModelConfig, B: int, max_len: int,
               per_slot_len: bool = False):
    types = cfg.layer_types()
    if cfg.family == "hybrid":
        pat = len(cfg.hybrid_pattern)
        n_super = cfg.n_layers // pat
        sup = tuple(
            jax.tree.map(lambda x: jnp.stack([x] * n_super),
                         _block_cache(cfg, cfg.hybrid_pattern[pos], B,
                                      max_len, per_slot_len))
            for pos in range(pat))
        tail = [_block_cache(cfg, types[n_super * pat + i], B, max_len,
                             per_slot_len)
                for i in range(cfg.n_layers - n_super * pat)]
        return {"super": sup, "tail": tail}
    one = _block_cache(cfg, types[0], B, max_len, per_slot_len)
    return jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), one)


def decode_step(cfg: ModelConfig, params, token, cache, moe_impl=None):
    """token: [B, 1] → (logits [B, 1, Vp], new_cache)."""
    x = params["embed"].astype(cfg.compute_dtype)[token]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    x, new_cache = _run_stack(cfg, params, x, cache, moe_impl)
    if cfg.norm != "nonparam_ln":
        x = L.apply_norm(cfg.norm, x, params, "ln_f")
    else:
        x = L.nonparam_ln(x)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(x.dtype))
    return logits, new_cache


def prefill(cfg: ModelConfig, params, batch, max_len: int, moe_impl=None):
    """Run the prompt through the stack, filling caches.

    Returns (last-token logits [B, Vp], cache). For encoder-only families
    there is no cache; call ``forward`` instead.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    x = embed_inputs(cfg, params, batch)
    x, new_cache = _run_stack(cfg, params, x, cache, moe_impl)
    if cfg.norm != "nonparam_ln":
        x = L.apply_norm(cfg.norm, x, params, "ln_f")
    else:
        x = L.nonparam_ln(x)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], unembed.astype(x.dtype))
    return logits, new_cache