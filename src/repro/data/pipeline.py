"""Deterministic synthetic data pipeline, sharded per host.

Production shape without external deps: an infinite, seekable stream of
token batches derived from a counter-based PRNG (stateless — any step's
batch can be regenerated exactly, which is what makes checkpoint/restart
and elastic rescaling deterministic). Each host materializes only its
addressable shard; ``jax.make_array_from_callback`` assembles the global
array so no host ever holds the global batch.

The synthetic distribution is a Zipf-ish LM-like marginal with short-range
structure (repeated n-grams) so losses move during integration tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234


class SyntheticStream:
    def __init__(self, dc: DataConfig):
        self.dc = dc

    def _tokens(self, step: int, row_lo: int, row_hi: int) -> np.ndarray:
        """Rows [row_lo, row_hi) of the global batch at ``step``."""
        dc = self.dc
        rows = []
        for r in range(row_lo, row_hi):
            rng = np.random.default_rng(
                np.uint64(dc.seed) + np.uint64(step) * np.uint64(1 << 20)
                + np.uint64(r))
            # Zipf-ish marginal, clipped to vocab.
            z = rng.zipf(1.3, size=dc.seq_len + 1).astype(np.int64)
            toks = (z % (dc.vocab - 1)) + 1
            # short-range structure: repeat a motif at a random offset
            m_len = int(rng.integers(4, 16))
            motif = toks[:m_len]
            off = int(rng.integers(0, dc.seq_len - m_len))
            toks[off:off + m_len] = motif
            rows.append(toks)
        return np.stack(rows)

    def global_batch_np(self, step: int):
        t = self._tokens(step, 0, self.dc.global_batch)
        return {"tokens": t[:, :-1].astype(np.int32),
                "labels": t[:, 1:].astype(np.int32)}

    def sharded_batch(self, step: int, mesh, batch_sharding) -> dict:
        """Global jax.Arrays built shard-by-shard (per-host addressable)."""
        dc = self.dc
        out = {}
        for name in ("tokens", "labels"):
            sharding = batch_sharding[name]
            shape = (dc.global_batch, dc.seq_len)

            def cb(index, name=name):
                rs = index[0]
                lo = rs.start or 0
                hi = rs.stop if rs.stop is not None else dc.global_batch
                t = self._tokens(step, lo, hi)
                col = index[1] if len(index) > 1 else slice(None)
                if name == "tokens":
                    return t[:, :-1][:, col].astype(np.int32)
                return t[:, 1:][:, col].astype(np.int32)

            out[name] = jax.make_array_from_callback(shape, sharding, cb)
        return out
