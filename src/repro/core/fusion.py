"""Cross-layer schedule fusion — multi-fragment taskflows.

A compiled :class:`~repro.core.scheduler.Schedule` covers one MoE-FFN
fragment (one layer, one direction). Executing layers back-to-back turns
every layer boundary into a hard barrier: combine fully drains before the
next dispatch starts — exactly the serialization the paper attacks *inside*
a layer. This module stitches K per-layer schedules into one statically
scheduled :class:`FusedSchedule` whose cross-fragment dependency edges
follow actual tile dataflow, so layer N+1's dispatch communication issues
per-rank as soon as that rank's boundary remap is ready, overlapping layer
N's combine and GMM tails on the other ranks.

Mechanics:

* Every fragment's tasks are cloned with tensors renamed ``{t}#L{i}`` and
  op names prefixed ``L{i}/`` (``i`` is the *layer* index, so backward
  fusion — which executes fragments in reversed layer order — keeps
  layer-faithful names). ``meta["fragment"]`` records the execution
  position, which is how passes, the cost model, and the simulator
  declare fragment scope.
* Between consecutive fragments, per-rank ``LayerBoundary`` VTQ tasks model
  the inter-layer token remap (layer-i combine-weighted sum composed with
  layer-i+1 routing). The remap is exactly rank-local — a token's combine
  rows and its next-layer send rows both live on its own source rank — so
  per-rank boundary tasks are an exact conservative dependency model, not
  an approximation. Tiles group *whole* downstream dispatch cells (never
  splitting a cell) so each tile triggers exactly one event and the
  scheduler's single-trigger invariant holds by construction.
* Dependencies and events are re-derived over the full task list with the
  same ``_derive_dependencies`` / ``_allocate_events`` machinery the
  per-fragment compiler uses; queue order concatenates each fragment's
  (already pass-optimized) queues with the boundary tiles in between, so a
  sequential fragment-by-fragment execution always exists and the fused
  schedule is deadlock-free by construction (and re-verified by
  ``validate_schedule``).

The numerical boundary remap itself is *not* part of the schedulable
fragment (it owns the top-k weighting, like Combine's accumulation); the
executor calls a per-(junction, rank) ``boundary_fn`` — see
``models/moe.py`` for the dropless implementation and ``core/executor.py``
for the handler contract.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional, Sequence

from .odg import VTQ, ScheduleConfig, build_moe_ffn_backward, \
    build_moe_ffn_forward
from .scheduler import Schedule, ScheduleError, _allocate_events, \
    _derive_dependencies, compile_schedule, validate_schedule
from .tasks import NO_EVENT, Range, TaskDescriptor

# Max LayerBoundary tiles per (junction, rank). Tiling matters for cost
# fidelity: one monolithic boundary task per rank would serialize the whole
# junction on a single AIV unit in the simulator (~10x the real fused
# makespan); ~64 whole-cell groups price like the vector op it models while
# keeping the task count small.
DEFAULT_BOUNDARY_SPLIT = 64

# Tensor pair bridged at each junction, per direction: upstream fragment's
# terminal send-layout output -> downstream fragment's send-layout input.
_BRIDGE_BASES = {"forward": ("y_ret", "x_src"),
                 "backward": ("dx_ret", "dy_src")}


@dataclasses.dataclass(frozen=True)
class Fragment:
    """One per-layer schedule's slice of the fused task list."""

    index: int                  # execution position (0 runs first)
    label: str                  # layer label, e.g. "L0" — tensor/op suffix
    tid_lo: int                 # half-open tid range of the cloned tasks
    tid_hi: int
    # LayerBoundary tiles feeding this fragment (empty for fragment 0).
    boundary_tids: tuple[int, ...] = ()

    @property
    def n_tasks(self) -> int:
        return self.tid_hi - self.tid_lo


@dataclasses.dataclass
class FusedSchedule(Schedule):
    """A multi-fragment taskflow; ``tasks``/``events``/``queues`` span all
    fragments, ``fragments`` records the per-layer slices."""

    fragments: tuple = ()       # tuple[Fragment, ...] in execution order

    @property
    def n_fragments(self) -> int:
        return len(self.fragments)

    def fragment_tids(self, index: int) -> list[int]:
        f = self.fragments[index]
        return list(range(f.tid_lo, f.tid_hi))


def _rename(rng: Range, label: str) -> Range:
    return Range(f"{rng.tensor}#{label}", rng.rank, rng.lo, rng.hi)


def _clone_task(td: TaskDescriptor, label: str, frag: int,
                extra_meta: Optional[dict] = None) -> TaskDescriptor:
    """Fragment-scoped copy: renamed tensors/ops, event fields reset.

    ``_allocate_events`` only assigns ``trigger_event`` to tasks that end up
    producers, so stale event ids from the source schedule must be cleared
    here, not merely overwritten later. ``extra_meta`` tags every cloned
    task (the PP interleaver stamps ``pp_stage``/``pp_microbatch``).
    """
    return dataclasses.replace(
        td,
        inputs=[_rename(r, label) for r in td.inputs],
        outputs=[_rename(r, label) for r in td.outputs],
        op_name=f"{label}/{td.op_name}",
        meta={**td.meta, "fragment": frag, **(extra_meta or {})},
        dependent_event=NO_EVENT,
        trigger_event=NO_EVENT,
        dependent_threshold=0,
        tid=-1)


def _boundary_tasks(up_label: str, dn_label: str, frag: int,
                    src_base: str, dst_base: str,
                    up_cfg: ScheduleConfig, dn_cfg: ScheduleConfig,
                    boundary_split: int, *, kind: str = "layer",
                    junction: Optional[int] = None,
                    extra_meta: Optional[dict] = None
                    ) -> list[TaskDescriptor]:
    """Per-rank boundary tiles for one junction (Layer- or StageBoundary).

    Tiles cover whole cells of the *downstream* plan's send layout, grouped
    into at most ``boundary_split`` chunks per rank. Whole-cell grouping is
    what keeps event allocation legal: every downstream dispatch cell is
    covered by exactly one tile, so each tile triggers exactly one event
    (the dispatch tasks it feeds share it as their sole producer).

    ``kind="layer"`` emits the rank-local token-remap VTQ tile (priced as
    AIV vector work); ``kind="stage"`` emits the pipeline-parallel twin —
    the same tiling and dedup invariants, but the tile carries the
    *activation payload* across the stage link (``comm_bytes`` set, priced
    on the topology's inter-node link class by the cost model). ``junction``
    is the id the executor's ``boundary_fns`` are keyed by (defaults to the
    layer-fusion convention ``frag - 1``).
    """
    up_plan, dn_plan = up_cfg.routing, dn_cfg.routing
    in_row_b = up_cfg.d_model * up_cfg.dtype_bytes
    out_row_b = dn_cfg.d_model * dn_cfg.dtype_bytes
    if junction is None:
        junction = frag - 1
    task_type = "LayerBoundary" if kind == "layer" else "StageBoundary"
    op_kind = "Boundary" if kind == "layer" else "StageBoundary"
    comm_kind = "boundary" if kind == "layer" else "stage"
    tds: list[TaskDescriptor] = []
    for r in range(dn_cfg.ep):
        cells = dn_plan.send_cells(r)        # (dst, e, count), contiguous
        if not cells:
            continue                         # rank sends nothing next layer
        total = sum(c for (_, _, c) in cells)
        target = -(-total // max(1, boundary_split))
        in_rows = up_plan.send_rows(r)
        # The remap consumes the rank's *entire* upstream return buffer
        # (combine-weighted sums mix every returned copy of a token), so
        # each tile reads the full range; zero upstream rows still yield a
        # valid tile — the remap of an all-zero combine.
        reads = ([Range(f"{src_base}#{up_label}", r, 0, in_rows)]
                 if in_rows > 0 else [])
        groups: list[tuple[int, int]] = []
        lo = acc = 0
        hi = 0
        for (_, _, c) in cells:
            hi += c
            acc += c
            if acc >= target:
                groups.append((lo, hi))
                lo, acc = hi, 0
        if acc > 0:
            groups.append((lo, hi))
        for i, (g_lo, g_hi) in enumerate(groups):
            chunk = g_hi - g_lo
            tds.append(TaskDescriptor(
                task_type=task_type, queue_type=VTQ,
                inputs=list(reads),
                outputs=[Range(f"{dst_base}#{dn_label}", r, g_lo, g_hi)],
                task_index=i, task_split_num=len(groups),
                task_split_value=chunk,
                read_bytes=chunk * in_row_b,
                write_bytes=chunk * out_row_b,
                comm_bytes=(chunk * out_row_b if kind == "stage" else 0),
                src_rank=(r if kind == "stage" else -1),
                dst_rank=(r if kind == "stage" else -1),
                op_name=f"{dn_label}/{op_kind}@{r}",
                op_type=f"{kind}_boundary", rank=r,
                meta={"fragment": frag, "boundary": junction,
                      "comm_kind": comm_kind, **(extra_meta or {})}))
    return tds


def _split_multirank_writer(td: TaskDescriptor) -> list[TaskDescriptor]:
    """Re-tile one comm task whose outputs land on several ranks into one
    copy per output range.

    The combine fill's fallback path (``core/tasks.py``: split propagation
    pinned ``task_num`` to 1) emits a single task returning rows to every
    source rank for highly concentrated plans. Unfused that is legal — the
    return buffer is terminal — but a fused junction *consumes* it on each
    rank, and one producer cannot trigger per-rank events. The fallback's
    outputs are ordered to match its sequential input layout, so block-wise
    re-tiling is an exact (bit-identical) refinement of the copy.
    """
    if td.task_type != "put_mem_signal" or len(td.inputs) != 1:
        raise ScheduleError(
            f"cannot re-tile multi-rank bridge writer {td.op_name}"
            f"#{td.task_index} ({td.task_type}) for fusion")
    i0 = td.inputs[0]
    rows = i0.hi - i0.lo
    row_b = td.read_bytes // rows if rows else 0
    parts = []
    off = i0.lo
    for idx, o in enumerate(td.outputs):
        c = o.hi - o.lo
        parts.append(dataclasses.replace(
            td,
            inputs=[Range(i0.tensor, i0.rank, off, off + c)],
            outputs=[o],
            task_index=idx, task_split_num=len(td.outputs),
            task_split_value=c,
            comm_bytes=c * row_b, read_bytes=c * row_b,
            write_bytes=c * row_b, dst_rank=o.rank,
            meta={**td.meta, "bridge_split": True}))
        off += c
    return parts


def _fragment_view(s: Schedule, bridge_src: Optional[str]):
    """One input schedule's (tasks, queues) as fused — with every
    multi-rank writer of the bridge tensor re-tiled per rank. Queue lists
    hold fragment-local task positions; ``bridge_src=None`` (no downstream
    junction) passes the schedule through verbatim."""
    if bridge_src is None:
        return list(s.tasks), {q: list(t) for q, t in s.queues.items()}
    expansion: dict[int, list[int]] = {}
    tasks: list[TaskDescriptor] = []
    for td in s.tasks:
        if (len(td.outputs) > 1
                and len({o.rank for o in td.outputs}) > 1
                and any(o.tensor == bridge_src for o in td.outputs)):
            parts = _split_multirank_writer(td)
        else:
            parts = [td]
        expansion[td.tid] = list(range(len(tasks), len(tasks) + len(parts)))
        tasks.extend(parts)
    queues = {q: [p for t in tids for p in expansion[t]]
              for q, tids in s.queues.items()}
    return tasks, queues


def fuse_schedules(scheds: Sequence[Schedule],
                   cfgs: Sequence[ScheduleConfig], *,
                   labels: Optional[Sequence[str]] = None,
                   fused_pipeline=("fuse_boundary",),
                   boundary_split: int = DEFAULT_BOUNDARY_SPLIT
                   ) -> FusedSchedule:
    """Stitch per-layer schedules (in *execution* order) into one taskflow.

    ``scheds``/``cfgs``/``labels`` are aligned and ordered by execution:
    layer order for forward, reversed layer order for backward. Each input
    schedule's queue order — including any per-fragment pass effects — is
    preserved verbatim inside its fragment; ``fused_pipeline`` names the
    fragment-spanning passes run on the stitched schedule afterwards.
    """
    from .passes import resolve_pipeline

    if not scheds:
        raise ValueError("fuse_schedules needs at least one schedule")
    if len(scheds) != len(cfgs):
        raise ValueError(f"{len(scheds)} schedules but {len(cfgs)} configs")
    direction = scheds[0].direction
    ep = scheds[0].ep
    for s in scheds:
        if s.direction != direction:
            raise ScheduleError(
                f"cannot fuse mixed directions {direction!r}/{s.direction!r}")
        if s.ep != ep:
            raise ScheduleError(f"cannot fuse ep={ep} with ep={s.ep}")
    src_base, dst_base = _BRIDGE_BASES[direction]
    K = len(scheds)
    if labels is None:
        labels = ([f"L{j}" for j in range(K)] if direction == "forward"
                  else [f"L{K - 1 - j}" for j in range(K)])
    labels = list(labels)
    if len(set(labels)) != K:
        raise ValueError(f"fragment labels must be unique, got {labels}")

    tasks: list[TaskDescriptor] = []
    fragments: list[Fragment] = []
    bases: list[int] = []
    boundary_tids: list[tuple[int, ...]] = []
    views = [_fragment_view(s, src_base if j < K - 1 else None)
             for j, s in enumerate(scheds)]
    for j, (cfg, (ftasks, _)) in enumerate(zip(cfgs, views)):
        btids: list[int] = []
        if j > 0:
            for td in _boundary_tasks(labels[j - 1], labels[j], j,
                                      src_base, dst_base,
                                      cfgs[j - 1], cfg, boundary_split):
                td.tid = len(tasks)
                btids.append(td.tid)
                tasks.append(td)
        boundary_tids.append(tuple(btids))
        bases.append(len(tasks))
        for td in ftasks:                    # fragment-local position order
            c = _clone_task(td, labels[j], j)
            c.tid = len(tasks)
            tasks.append(c)
        fragments.append(Fragment(index=j, label=labels[j],
                                  tid_lo=bases[j], tid_hi=len(tasks),
                                  boundary_tids=tuple(btids)))

    deps = _derive_dependencies(tasks)
    events = _allocate_events(tasks, deps)

    queues: dict[tuple[int, str], list[int]] = defaultdict(list)
    for j, (_, fqueues) in enumerate(views):
        for tid in boundary_tids[j]:
            queues[(tasks[tid].rank, VTQ)].append(tid)
        for (rank, qt) in sorted(fqueues):
            queues[(rank, qt)].extend(bases[j] + t for t in fqueues[(rank, qt)])

    fused_pipe = resolve_pipeline(fused_pipeline)
    fs = FusedSchedule(
        direction=direction, ep=ep, tasks=tasks, events=events,
        queues=dict(queues),
        opts={"pipeline": fused_pipe.spec(),
              "fragment_pipelines": [list(s.opts.get("pipeline", []))
                                     for s in scheds],
              "fragment_labels": labels,
              "boundary_split": boundary_split},
        fragments=tuple(fragments))

    fused_pipe.run(fs, cfgs[0])
    validate_schedule(fs)
    return fs


def compile_fused(cfgs: Sequence[ScheduleConfig], direction: str, *,
                  pipeline=None, pipelines=None,
                  fused_pipeline=("fuse_boundary",),
                  boundary_split: int = DEFAULT_BOUNDARY_SPLIT
                  ) -> FusedSchedule:
    """Compile K per-layer configs (in *layer* order) into a FusedSchedule.

    Backward fusion executes fragments in reversed layer order (layer K-1's
    upstream gradient arrives first) while labels stay layer-faithful, so
    ``dW1#L0`` in a fused backward schedule is layer 0's gradient no matter
    where its fragment sits in the taskflow.

    ``pipelines`` gives one per-layer pass pipeline each (layer order);
    ``pipeline`` applies one to every layer. ``pipeline="auto"`` resolves
    per layer against that layer's plan, exactly like the unfused path.
    """
    if direction not in _BRIDGE_BASES:
        raise ValueError(f"direction must be forward|backward, "
                         f"got {direction!r}")
    K = len(cfgs)
    if K == 0:
        raise ValueError("compile_fused needs at least one config")
    if pipelines is None:
        pipelines = [pipeline] * K
    if len(pipelines) != K:
        raise ValueError(f"{K} configs but {len(pipelines)} pipelines")
    builder = (build_moe_ffn_forward if direction == "forward"
               else build_moe_ffn_backward)
    scheds = [compile_schedule(builder(cfg), pipeline=p)
              for cfg, p in zip(cfgs, pipelines)]
    order = list(range(K)) if direction == "forward" else list(range(K))[::-1]
    return fuse_schedules([scheds[i] for i in order],
                          [cfgs[i] for i in order],
                          labels=[f"L{i}" for i in order],
                          fused_pipeline=fused_pipeline,
                          boundary_split=boundary_split)


# ---------------------------------------------------------------------------
# Pipeline-parallel fusion — stages × microbatches as fragments.
# ---------------------------------------------------------------------------

def pp_cell_order(n_stages: int, n_microbatches: int,
                  direction: str) -> list[tuple[int, int]]:
    """Wave-ordered (stage, microbatch) cells — the 1F1B interleave
    restricted to one direction.

    Cell (s, m) sits in wave ``depth(s) + m`` where ``depth`` is the
    stage's pipeline depth in this direction (``s`` forward, ``S-1-s``
    backward); within a wave, shallower stages come first. Microbatches
    within a stage therefore always execute in order, and adjacent cells
    of one wave are exactly the pairs 1F1B runs concurrently — stage s's
    EP dispatch/combine of microbatch m lands in the queue gaps where
    stage s would otherwise idle on m±1.
    """
    cells = []
    for s in range(n_stages):
        depth = s if direction == "forward" else n_stages - 1 - s
        for m in range(n_microbatches):
            cells.append((depth + m, depth, s, m))
    cells.sort()
    return [(s, m) for (_, _, s, m) in cells]


def fuse_pp_schedules(scheds: Sequence[Schedule],
                      cfgs: Sequence[ScheduleConfig],
                      n_microbatches: int, *,
                      fused_pipeline=("pp_interleave",),
                      boundary_split: int = DEFAULT_BOUNDARY_SPLIT
                      ) -> FusedSchedule:
    """Stitch per-*stage* schedules into one PP-fused taskflow.

    ``scheds``/``cfgs`` are per pipeline stage, in stage order; each stage
    is replicated once per microbatch, yielding ``S × M`` fragments in
    :func:`pp_cell_order`. Consecutive stages of the *same* microbatch are
    bridged with ``StageBoundary`` tiles that carry the activation payload
    over the stage link (physical junction ``m*(S-1) + min(s_up, s_dn)``,
    identical for forward and backward so one ``boundary_fns`` convention
    serves both). Every task is stamped ``pp_stage``/``pp_microbatch``,
    which is what the simulator's ``stage_barrier`` reference, per-cell
    phase accounting, and the ``pp_interleave`` pass key on.
    """
    from .passes import resolve_pipeline

    if not scheds:
        raise ValueError("fuse_pp_schedules needs at least one stage")
    if len(scheds) != len(cfgs):
        raise ValueError(f"{len(scheds)} schedules but {len(cfgs)} configs")
    if n_microbatches < 1:
        raise ValueError(f"n_microbatches must be >= 1, "
                         f"got {n_microbatches}")
    direction = scheds[0].direction
    ep = scheds[0].ep
    for s in scheds:
        if s.direction != direction:
            raise ScheduleError(
                f"cannot fuse mixed directions {direction!r}/{s.direction!r}")
        if s.ep != ep:
            raise ScheduleError(f"cannot fuse ep={ep} with ep={s.ep}")
    src_base, dst_base = _BRIDGE_BASES[direction]
    S, M = len(scheds), n_microbatches
    order = pp_cell_order(S, M, direction)

    # A stage has a downstream junction when any stage follows it in this
    # direction's dataflow; those stages' bridge writers are re-tiled once.
    def has_downstream(s: int) -> bool:
        return s < S - 1 if direction == "forward" else s > 0

    views = {s: _fragment_view(sch, src_base if has_downstream(s) else None)
             for s, sch in enumerate(scheds)}

    tasks: list[TaskDescriptor] = []
    fragments: list[Fragment] = []
    bases: list[int] = []
    boundary_tids: list[tuple[int, ...]] = []
    for frag, (s, m) in enumerate(order):
        lab = f"S{s}M{m}"
        cell_meta = {"pp_stage": s, "pp_microbatch": m}
        btids: list[int] = []
        s_up = s - 1 if direction == "forward" else s + 1
        if 0 <= s_up < S:
            junction = m * (S - 1) + min(s, s_up)
            for td in _boundary_tasks(f"S{s_up}M{m}", lab, frag,
                                      src_base, dst_base,
                                      cfgs[s_up], cfgs[s], boundary_split,
                                      kind="stage", junction=junction,
                                      extra_meta=cell_meta):
                td.tid = len(tasks)
                btids.append(td.tid)
                tasks.append(td)
        boundary_tids.append(tuple(btids))
        bases.append(len(tasks))
        for td in views[s][0]:               # fragment-local position order
            c = _clone_task(td, lab, frag, extra_meta=cell_meta)
            c.tid = len(tasks)
            tasks.append(c)
        fragments.append(Fragment(index=frag, label=lab,
                                  tid_lo=bases[frag], tid_hi=len(tasks),
                                  boundary_tids=tuple(btids)))

    deps = _derive_dependencies(tasks)
    events = _allocate_events(tasks, deps)

    queues: dict[tuple[int, str], list[int]] = defaultdict(list)
    for frag, (s, m) in enumerate(order):
        for tid in boundary_tids[frag]:
            queues[(tasks[tid].rank, VTQ)].append(tid)
        fqueues = views[s][1]
        for (rank, qt) in sorted(fqueues):
            queues[(rank, qt)].extend(bases[frag] + t
                                      for t in fqueues[(rank, qt)])

    fused_pipe = resolve_pipeline(fused_pipeline)
    fs = FusedSchedule(
        direction=direction, ep=ep, tasks=tasks, events=events,
        queues=dict(queues),
        opts={"pipeline": fused_pipe.spec(),
              "fragment_pipelines": [list(scheds[s].opts.get("pipeline", []))
                                     for (s, _) in order],
              "fragment_labels": [f.label for f in fragments],
              "boundary_split": boundary_split,
              "pp": {"n_stages": S, "n_microbatches": M,
                     "order": [[s, m] for (s, m) in order]}},
        fragments=tuple(fragments))

    fused_pipe.run(fs, cfgs[0])
    validate_schedule(fs)
    return fs


def compile_pp_fused(cfgs: Sequence[ScheduleConfig], n_microbatches: int,
                     n_stages: Optional[int] = None, *,
                     direction: str = "forward",
                     pipeline=None, pipelines=None,
                     fused_pipeline=("pp_interleave",),
                     boundary_split: int = DEFAULT_BOUNDARY_SPLIT
                     ) -> FusedSchedule:
    """Compile per-stage configs (stage order) into a PP-fused schedule.

    ``cfgs`` gives one config per pipeline stage; a single config is
    replicated to ``n_stages`` (uniform pipeline). Per-stage schedules are
    compiled once (``pipeline="auto"`` resolves per stage, like the unfused
    path) and cloned per microbatch by :func:`fuse_pp_schedules`.
    """
    if direction not in _BRIDGE_BASES:
        raise ValueError(f"direction must be forward|backward, "
                         f"got {direction!r}")
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("compile_pp_fused needs at least one config")
    if n_stages is None:
        n_stages = len(cfgs)
    if len(cfgs) == 1 and n_stages > 1:
        cfgs = cfgs * n_stages
    if len(cfgs) != n_stages:
        raise ValueError(f"{len(cfgs)} configs but n_stages={n_stages}")
    if pipelines is None:
        pipelines = [pipeline] * n_stages
    if len(pipelines) != n_stages:
        raise ValueError(f"{n_stages} stages but {len(pipelines)} pipelines")
    builder = (build_moe_ffn_forward if direction == "forward"
               else build_moe_ffn_backward)
    scheds = [compile_schedule(builder(cfg), pipeline=p)
              for cfg, p in zip(cfgs, pipelines)]
    return fuse_pp_schedules(scheds, cfgs, n_microbatches,
                             fused_pipeline=fused_pipeline,
                             boundary_split=boundary_split)


def pp_fragment_cfgs(fs: FusedSchedule, cfgs) -> list:
    """Per-fragment config list (execution order) for
    ``ExecutorState(fragment_cfgs=...)``: ``cfgs`` is per stage."""
    return [cfgs[s] for (s, _) in fs.opts["pp"]["order"]]


def load_pp_forward_state(fs: FusedSchedule, cfgs, st,
                          x_srcs, w1s, w2s) -> None:
    """``cfgs``/``w1s``/``w2s`` per *stage* (stage order); ``x_srcs[m]`` is
    microbatch m's per-rank input list for stage 0."""
    pp = fs.opts["pp"]
    for (s, _), frag in zip(pp["order"], fs.fragments):
        for r in range(cfgs[s].ep):
            st.set_weight(f"W1#{frag.label}", r, w1s[s][r])
            st.set_weight(f"W2#{frag.label}", r, w2s[s][r])
    for m in range(pp["n_microbatches"]):
        for r in range(cfgs[0].ep):
            st.set_buffer(f"x_src#S0M{m}", r, x_srcs[m][r])


def load_pp_backward_state(fs: FusedSchedule, cfgs, st,
                           dys, fwds, w1s, w2s) -> None:
    """Backward twin: ``dys[m]`` is microbatch m's upstream gradient at the
    last stage; ``fwds[m][s]`` the saved forward dict of cell (s, m)."""
    pp = fs.opts["pp"]
    S = pp["n_stages"]
    for (s, m), frag in zip(pp["order"], fs.fragments):
        lab = frag.label
        for r in range(cfgs[s].ep):
            st.set_weight(f"W1#{lab}", r, w1s[s][r])
            st.set_weight(f"W2#{lab}", r, w2s[s][r])
            st.set_buffer(f"g_saved#{lab}", r, fwds[m][s]["g"][r])
            st.set_buffer(f"h_saved#{lab}", r, fwds[m][s]["h"][r])
            st.set_buffer(f"x_recv_saved#{lab}", r,
                          fwds[m][s]["x_recv"][r])
    for m in range(pp["n_microbatches"]):
        for r in range(cfgs[-1].ep):
            st.set_buffer(f"dy_src#S{S - 1}M{m}", r, dys[m][r])


# ---------------------------------------------------------------------------
# Executor state loaders — fragment-suffixed twins of the *_plan loaders.
# ---------------------------------------------------------------------------

def load_fused_forward_state(fs: FusedSchedule, cfgs, st,
                             x_src, w1s, w2s) -> None:
    """``cfgs``/``w1s``/``w2s`` in execution order (aligned with
    ``fs.fragments``); ``x_src`` is fragment 0's per-rank input list."""
    labels = [f.label for f in fs.fragments]
    for j, (cfg, lab) in enumerate(zip(cfgs, labels)):
        for r in range(cfg.ep):
            st.set_weight(f"W1#{lab}", r, w1s[j][r])
            st.set_weight(f"W2#{lab}", r, w2s[j][r])
    for r in range(cfgs[0].ep):
        st.set_buffer(f"x_src#{labels[0]}", r, x_src[r])


def load_fused_backward_state(fs: FusedSchedule, cfgs, st,
                              dy, fwds, w1s, w2s) -> None:
    """Backward twin: everything in *execution* order (reversed layer
    order), so ``dy`` is the last layer's upstream gradient and ``fwds[j]``
    the saved forward dict of the fragment at execution position j."""
    labels = [f.label for f in fs.fragments]
    for j, (cfg, lab) in enumerate(zip(cfgs, labels)):
        for r in range(cfg.ep):
            st.set_weight(f"W1#{lab}", r, w1s[j][r])
            st.set_weight(f"W2#{lab}", r, w2s[j][r])
            st.set_buffer(f"g_saved#{lab}", r, fwds[j]["g"][r])
            st.set_buffer(f"h_saved#{lab}", r, fwds[j]["h"][r])
            st.set_buffer(f"x_recv_saved#{lab}", r, fwds[j]["x_recv"][r])
    for r in range(cfgs[0].ep):
        st.set_buffer(f"dy_src#{labels[0]}", r, dy[r])
