"""Execution-order optimizations over legal topological orders (§4.5).

Both passes permute *mutually independent* tasks only — ODG edges, tile
ranges, and event semantics are untouched, and ``validate_schedule`` re-proves
legality after reordering.

* **RATR (rank-aware task reordering)** — rotate each source rank's
  communication-task order so rank *r* starts sending to destination
  ``(r+1) mod ep`` and walks the ring. Destroys the destination-rank hotspot
  of the naive order (every rank sending to rank 0 first) and balances link
  usage over time (Fig. 6).

* **Cache-guided GMM interleaving** — in the backward graph the two GMM
  branches hanging off a shared input (act_grad/w2_grad consume dispatched
  dY; gate_grad/w1_grad consume dSwiGLU) are topologically independent.
  Interleaving their tiles by expert shortens the reuse distance of the
  shared activations in L2/VMEM instead of streaming one branch end-to-end.

Both passes operate on ragged tile sets from imbalanced RoutingPlans: RATR
sorts whatever comm tasks a rank actually emits (empty cells simply don't
appear in its ring walk), and GMM interleaving keys on (expert, m) metadata
that survives variable-extent tiling.
"""

from __future__ import annotations

from collections import defaultdict

from .odg import ScheduleConfig, CTQ, VTQ


def apply_reorderings(sched, cfg: ScheduleConfig, *, ratr: bool,
                      gmm_interleave: bool,
                      chain_interleave: bool = False) -> None:
    if ratr:
        _apply_ratr(sched, cfg)
    if gmm_interleave and sched.direction == "backward":
        _apply_gmm_interleave(sched, cfg)
    if chain_interleave:
        _apply_chain_interleave(sched)


def _apply_chain_interleave(sched, lag: int = 50) -> None:
    """Place consumer tiles a small *lag* behind their aligned producers
    (§6.1).

    For 1:1-aligned elementwise chains the VTQ order becomes
    [p0 … p_{lag-1}, c0, p_lag, c1, …]: close enough that the producer's
    tile is still L2-resident when the consumer reads it, but far enough
    that in-order-fetching workers never block on a not-yet-ready consumer
    (lag ≈ worker-pool width). Op-major order instead streams the whole
    intermediate through the cache before any consumer runs."""
    for key, q in list(sched.queues.items()):
        by_op: dict[str, list[int]] = {}
        order: list[str] = []
        for tid in q:
            op = sched.tasks[tid].op_name
            if op not in by_op:
                order.append(op)
            by_op.setdefault(op, []).append(tid)
        if len(order) < 2:
            continue
        counts = {len(v) for v in by_op.values()}
        if len(counts) != 1:
            continue            # not 1:1 aligned — leave as-is
        n = counts.pop()
        streams = [by_op[op] for op in order]
        k = len(streams)
        new_q: list[int] = []
        emitted = [0] * k
        while len(new_q) < n * k:
            # Emit from the deepest stream whose predecessor is ≥ lag ahead
            # (or finished); otherwise advance the head stream.
            for si in range(k - 1, -1, -1):
                if emitted[si] >= n:
                    continue
                if si == 0 or emitted[si - 1] >= min(n, emitted[si] + lag):
                    new_q.append(streams[si][emitted[si]])
                    emitted[si] += 1
                    break
        sched.queues[key] = new_q


def ratr_order(rank: int, ep: int) -> list[int]:
    """Destination visit order for a source rank under RATR."""
    return [(rank + 1 + i) % ep for i in range(ep)]


def _apply_ratr(sched, cfg: ScheduleConfig) -> None:
    for (rank, qtype), q in sched.queues.items():
        if qtype != VTQ:
            continue
        ring_pos = {d: i for i, d in enumerate(ratr_order(rank, cfg.ep))}
        # Reorder each comm operator's contiguous task block independently so
        # relative order against non-comm VTQ tasks is preserved.
        new_q: list[int] = []
        block: list[int] = []
        block_op = None

        def flush():
            nonlocal block, block_op
            if block:
                block.sort(key=lambda tid: (
                    ring_pos[sched.tasks[tid].dst_rank],
                    sched.tasks[tid].meta.get("expert", 0)))
                new_q.extend(block)
                block, block_op = [], None

        for tid in q:
            td = sched.tasks[tid]
            is_comm = (td.task_type == "put_mem_signal"
                       and td.dst_rank >= 0)
            if is_comm and (block_op in (None, td.op_name)):
                block.append(tid)
                block_op = td.op_name
            else:
                flush()
                if is_comm:
                    block.append(tid)
                    block_op = td.op_name
                else:
                    new_q.append(tid)
        flush()
        sched.queues[(rank, qtype)] = new_q


def _apply_gmm_interleave(sched, cfg: ScheduleConfig) -> None:
    """Interleave independent backward GMM branch pairs by expert."""
    for (rank, qtype), q in sched.queues.items():
        if qtype != CTQ:
            continue
        # Group consecutive CTQ ops by their shared-input branch tag.
        by_branch: dict[str, list[int]] = defaultdict(list)
        order: list[str] = []
        for tid in q:
            br = sched.tasks[tid].meta.get("branch", f"_solo{tid}")
            if br not in by_branch:
                order.append(br)
            by_branch[br].append(tid)

        new_q: list[int] = []
        for br in order:
            tids = by_branch[br]
            ops = []
            for tid in tids:
                op = sched.tasks[tid].op_name
                if op not in ops:
                    ops.append(op)
            if br.startswith("_solo") or len(ops) < 2:
                new_q.extend(tids)
                continue
            # Interleave: same (expert, m) tiles of the branch's ops adjacent.
            keyed = sorted(tids, key=lambda tid: (
                sched.tasks[tid].meta.get("expert", 0),
                sched.tasks[tid].meta.get("m", 0),
                ops.index(sched.tasks[tid].op_name)))
            new_q.extend(keyed)
        sched.queues[(rank, qtype)] = new_q
