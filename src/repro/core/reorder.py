"""Reordering pass bodies over legal topological orders (§4.5).

This module holds the *implementations* of the queue-reordering schedule
passes; their registration, naming, and composition live in
``core/passes.py`` (the pass pipeline ``compile_schedule`` executes between
task generation and validation). Every function here permutes *mutually
independent* tasks only — ODG edges, tile ranges, and event semantics are
untouched, and ``validate_schedule`` re-proves legality after the pipeline
runs.

* **RATR (rank-aware task reordering)** — rotate each source rank's
  communication-task order so rank *r* starts sending to destination
  ``(r+1) mod ep`` and walks the ring. Destroys the destination-rank hotspot
  of the naive order (every rank sending to rank 0 first) and balances link
  usage over time (Fig. 6).

* **Cache-guided GMM interleaving** — in the backward graph the two GMM
  branches hanging off a shared input (act_grad/w2_grad consume dispatched
  dY; gate_grad/w1_grad consume dSwiGLU) are topologically independent.
  Interleaving their tiles by expert shortens the reuse distance of the
  shared activations in L2/VMEM instead of streaming one branch end-to-end.

* **Chain interleaving** — place consumer tiles a small lag behind their
  1:1-aligned producers so the producer tile is still L2-resident (§6.1).

* **Critical-rank-first** — hoist comm tasks that feed the compile-time
  critical rank (``CostModel.critical_rank``, the static analogue of the
  simulator's ``straggler_ratio``) to the front of each producer queue's
  comm blocks, so the straggler's dependencies arrive as early as possible.

All passes operate on ragged tile sets from imbalanced RoutingPlans: comm
reorderings sort whatever comm tasks a rank actually emits (empty cells
simply don't appear), and GMM interleaving keys on (expert, m) metadata that
survives variable-extent tiling.
"""

from __future__ import annotations

from collections import defaultdict

from .odg import ScheduleConfig, CTQ, VTQ


def reorder_comm_blocks(sched, q: list[int], sort_key) -> list[int]:
    """Sort each contiguous same-op block of comm tasks in queue ``q``.

    Comm tasks inside one operator's block are mutually independent (they
    write disjoint remote ranges), so any permutation is legal; relative
    order against non-comm tasks and across blocks is preserved. The sort is
    stable, so passes compose: a later pass's partial key refines, rather
    than destroys, an earlier pass's order.
    """
    new_q: list[int] = []
    block: list[int] = []
    block_op = None

    def flush():
        nonlocal block, block_op
        if block:
            block.sort(key=sort_key)
            new_q.extend(block)
            block, block_op = [], None

    for tid in q:
        td = sched.tasks[tid]
        is_comm = (td.task_type == "put_mem_signal" and td.dst_rank >= 0)
        if is_comm and (block_op in (None, td.op_name)):
            block.append(tid)
            block_op = td.op_name
        else:
            flush()
            if is_comm:
                block.append(tid)
                block_op = td.op_name
            else:
                new_q.append(tid)
    flush()
    return new_q


def ratr_order(rank: int, ep: int) -> list[int]:
    """Destination visit order for a source rank under RATR."""
    return [(rank + 1 + i) % ep for i in range(ep)]


def apply_ratr(sched, cfg: ScheduleConfig) -> None:
    """Ring-rotate each rank's comm blocks; fragment- and node-aware.

    On multi-fragment schedules the ring start additionally rotates by the
    task's fragment index, so consecutive layers at the same source rank
    begin their walks at *different* destinations — without this, a fused
    schedule re-creates the transient hotspot RATR removes, once per layer
    boundary. Single-fragment schedules (fragment 0 everywhere) reorder
    byte-identically to the original RATR.

    With a :class:`~repro.core.hardware.Topology` the ring rotates over
    *nodes* first: rank r walks remote nodes starting at the next node on
    the node ring, visiting same-node destinations last. Cross-node puts
    are the scarce resource (the NIC), so every source starts pushing onto
    a *different* node's ingress while the cheap intra-node copies fill
    the tail; within one destination node, the rank-level ring still
    staggers ingress ports. Without a topology the key degenerates to the
    original rank ring (n_nodes=1 ⇒ node term constant).
    """
    ep = cfg.ep
    topo = getattr(cfg, "topology", None)
    nodes = topo.n_nodes(ep) if topo is not None else 1
    node_of = (topo.node_of if topo is not None else (lambda r: 0))
    for (rank, qtype), q in sched.queues.items():
        if qtype != VTQ:
            continue

        def key(tid, rank=rank):
            td = sched.tasks[tid]
            frag = td.meta.get("fragment", 0)
            return ((node_of(td.dst_rank) - node_of(rank) - 1 - frag)
                    % nodes if nodes > 1 else 0,
                    (td.dst_rank - rank - 1 - frag) % ep,
                    td.meta.get("expert", 0))

        sched.queues[(rank, qtype)] = reorder_comm_blocks(sched, q, key)


def apply_hier_dispatch(sched, cfg: ScheduleConfig) -> None:
    """Order two-level dispatch stage puts by node-ring distance.

    Within each comm block, hierarchical stage tasks (the intra-node
    ``gather`` puts and the aggregated ``xnode`` puts emitted by
    ``dispatch_mode="hier"``) are hoisted ahead of ordinary puts and
    walked over destination *nodes* ring-wise from the sender's own node —
    the node-level analogue of RATR: gathers that feed the most distant
    leader's aggregation issue first, so the slow inter-node messages can
    start as early as their staging rows land. Tasks without a ``stage``
    tag sort under one constant key, so the stable sort leaves flat
    schedules byte-identical (the pass is a registered no-op there).
    """
    topo = getattr(cfg, "topology", None)
    if topo is None:
        return
    nodes = topo.n_nodes(cfg.ep)
    for (rank, qtype), q in sched.queues.items():
        if qtype != VTQ:
            continue
        my_node = topo.node_of(rank)

        def key(tid, my_node=my_node):
            td = sched.tasks[tid]
            if td.meta.get("stage") not in ("gather", "xnode"):
                return (1, 0)
            return (0, (td.meta.get("dst_node", 0) - my_node - 1) % nodes)

        sched.queues[(rank, qtype)] = reorder_comm_blocks(sched, q, key)


def apply_gmm_interleave(sched, cfg: ScheduleConfig) -> None:
    """Interleave independent backward GMM branch pairs by expert."""
    for (rank, qtype), q in sched.queues.items():
        if qtype != CTQ:
            continue
        # Group consecutive CTQ ops by their shared-input branch tag.
        by_branch: dict[str, list[int]] = defaultdict(list)
        order: list[str] = []
        for tid in q:
            br = sched.tasks[tid].meta.get("branch", f"_solo{tid}")
            if br not in by_branch:
                order.append(br)
            by_branch[br].append(tid)

        new_q: list[int] = []
        for br in order:
            tids = by_branch[br]
            ops = []
            for tid in tids:
                op = sched.tasks[tid].op_name
                if op not in ops:
                    ops.append(op)
            if br.startswith("_solo") or len(ops) < 2:
                new_q.extend(tids)
                continue
            # Interleave: same (expert, m) tiles of the branch's ops adjacent.
            keyed = sorted(tids, key=lambda tid: (
                sched.tasks[tid].meta.get("expert", 0),
                sched.tasks[tid].meta.get("m", 0),
                ops.index(sched.tasks[tid].op_name)))
            new_q.extend(keyed)
        sched.queues[(rank, qtype)] = new_q


def _interleave_aligned_queue(sched, key, lag: int) -> bool:
    """Lag-interleave one queue's op streams if they are 1:1 aligned.

    Produces [p0 … p_{lag-1}, c0, p_lag, c1, …] per op pair: each consumer
    tile sits ``lag`` entries behind its producer. Returns False (queue
    untouched) when the queue has < 2 ops or its op streams differ in
    length.
    """
    q = sched.queues.get(key, [])
    by_op: dict[str, list[int]] = {}
    order: list[str] = []
    for tid in q:
        op = sched.tasks[tid].op_name
        if op not in by_op:
            order.append(op)
        by_op.setdefault(op, []).append(tid)
    if len(order) < 2:
        return False
    counts = {len(v) for v in by_op.values()}
    if len(counts) != 1:
        return False            # not 1:1 aligned — leave as-is
    n = counts.pop()
    streams = [by_op[op] for op in order]
    k = len(streams)
    new_q: list[int] = []
    emitted = [0] * k
    while len(new_q) < n * k:
        # Emit from the deepest stream whose predecessor is ≥ lag ahead
        # (or finished); otherwise advance the head stream.
        for si in range(k - 1, -1, -1):
            if emitted[si] >= n:
                continue
            if si == 0 or emitted[si - 1] >= min(n, emitted[si] + lag):
                new_q.append(streams[si][emitted[si]])
                emitted[si] += 1
                break
    sched.queues[key] = new_q
    return True


def apply_chain_interleave(sched, lag: int = 50) -> None:
    """Place consumer tiles a small *lag* behind their aligned producers
    (§6.1).

    For 1:1-aligned elementwise chains the queue order becomes
    [p0 … p_{lag-1}, c0, p_lag, c1, …]: close enough that the producer's
    tile is still L2-resident when the consumer reads it, but far enough
    that in-order-fetching workers never block on a not-yet-ready consumer
    (lag ≈ worker-pool width). Op-major order instead streams the whole
    intermediate through the cache before any consumer runs."""
    for key in list(sched.queues):
        _interleave_aligned_queue(sched, key, lag)


def apply_critical_rank_first(sched, cfg: ScheduleConfig, *,
                              threshold: float | None = None,
                              lag: int = 0) -> None:
    """Prioritize the compile-time critical rank (§4.5 extension).

    The cost model prices every CTQ tile at compile time; when the
    most-loaded rank's cube time exceeds ``threshold`` × the EP-group mean,
    two reorderings fire:

    1. *Dependency-feeding hoist* — each rank's VTQ comm blocks are stably
       re-sorted so transfers destined to the critical rank go first: on
       producer peers this feeds the straggler's dependency events as early
       as the links allow, and on the critical rank itself its rank-local
       dispatch copy moves ahead of sends to non-critical peers. Composes
       with RATR: a stable partition keeps the anti-hotspot ring order
       among non-critical destinations.

    2. *Starved-chain interleave* — when the critical rank's cube work is
       concentrated in one dominant expert (the remaining CTQ tiles cannot
       even fill the AIC pool), op-major order leaves its workers parked on
       the dominant chain while downstream tiles sit deep in the queue.
       If the rank's CTQ is a 1:1-aligned op chain, interleave it with a
       lag of twice the AIC pool width — deep enough that by the time an
       in-order worker fetches a consumer tile, its producer (2×pool
       entries ahead) has usually retired, so the interleave never parks
       workers that op-major order would have kept busy (on chains shorter
       than the lag it degenerates to op-major — a no-op). With enough
       sibling-expert work to keep the pool busy the interleave is skipped
       entirely — parking workers on not-yet-ready consumers would then
       *cost* throughput.
    """
    from .costmodel import CostModel
    from .passes import CRIT_STRAGGLER_THRESHOLD
    if threshold is None:
        threshold = CRIT_STRAGGLER_THRESHOLD
    cost = CostModel(l2=False)
    if len({td.meta.get("fragment", 0) for td in sched.tasks}) > 1:
        # Fragment scope: each fused fragment carries its own routing plan,
        # so the straggler is per-fragment — hoist each fragment's combine/
        # dispatch blocks toward *that fragment's* critical rank. The
        # starved-chain interleave is skipped here: a fused CTQ mixes
        # fragments, so the 1:1-aligned single-chain precondition it relies
        # on never holds across the mix.
        crit_by_frag = {f: c for f, (ratio, c)
                        in cost.fragment_critical_ranks(sched).items()
                        if c >= 0 and ratio > threshold}
        if not crit_by_frag:
            return

        def fkey(tid):
            td = sched.tasks[tid]
            c = crit_by_frag.get(td.meta.get("fragment", 0))
            return 0 if (c is not None and td.dst_rank == c) else 1

        for (rank, qtype), q in sched.queues.items():
            if qtype != VTQ:
                continue
            sched.queues[(rank, qtype)] = reorder_comm_blocks(sched, q, fkey)
        return
    ratio, crit = cost.critical_rank(sched)
    if crit < 0 or ratio <= threshold:
        return
    for (rank, qtype), q in sched.queues.items():
        if qtype != VTQ:
            continue
        sched.queues[(rank, qtype)] = reorder_comm_blocks(
            sched, q,
            lambda tid: 0 if sched.tasks[tid].dst_rank == crit else 1)

    ctq = sched.queues.get((crit, CTQ))
    if not ctq:
        return
    # Dominant-expert concentration: tiles outside the costliest expert.
    by_expert: dict[int, float] = defaultdict(float)
    for tid in ctq:
        td = sched.tasks[tid]
        by_expert[td.meta.get("expert", -1)] += cost.task_us(td)
    dominant = max(by_expert, key=by_expert.get)
    other_tiles = sum(1 for tid in ctq
                      if sched.tasks[tid].meta.get("expert", -1) != dominant)
    if other_tiles >= cost.hw.num_aic:
        return
    _interleave_aligned_queue(sched, (crit, CTQ),
                              lag=lag or 2 * cost.hw.num_aic)


def apply_fuse_boundary(sched, cfg: ScheduleConfig) -> None:
    """Interleave fragment-boundary comm into the neighbor's AIC shadow.

    In a fused schedule, fragment f's combine tiles are the producers that
    gate fragment f+1's dispatch (through the per-rank LayerBoundary
    remap): the sooner all combines *into* rank r complete, the sooner r's
    boundary fires and its next-layer dispatch issues — overlapping the
    other ranks' still-running GMM and combine tails. Within each combine
    block, stably hoist tiles returning to the ranks with the most
    downstream dispatch traffic (they sit deepest on the next fragment's
    critical path). Dispatch blocks and the last fragment's combines see a
    constant key, so the stable sort leaves them — and any single-fragment
    schedule — untouched.
    """
    dn_dispatch = defaultdict(float)     # (fragment, src rank) -> bytes
    for td in sched.tasks:
        if (td.task_type == "put_mem_signal"
                and td.meta.get("comm_kind") == "dispatch"):
            dn_dispatch[(td.meta.get("fragment", 0), td.rank)] += \
                td.comm_bytes
    if not dn_dispatch:
        return

    def key(tid):
        td = sched.tasks[tid]
        if td.meta.get("comm_kind") != "combine":
            return (0.0,)
        frag = td.meta.get("fragment", 0)
        return (-dn_dispatch.get((frag + 1, td.dst_rank), 0.0),)

    for (rank, qtype), q in sched.queues.items():
        if qtype != VTQ:
            continue
        sched.queues[(rank, qtype)] = reorder_comm_blocks(sched, q, key)


def apply_pp_interleave(sched, cfg: ScheduleConfig) -> None:
    """PP-aware twin of :func:`apply_fuse_boundary` for stage-fused
    schedules.

    In a PP-fused taskflow the consumer of cell (s, m)'s combine traffic is
    the *same-microbatch next-stage* cell — (s+1, m) forward, (s-1, m)
    backward — not the next execution position (which under the 1F1B wave
    order is usually another microbatch of a different stage). Resolve the
    true downstream cell through ``pp_stage``/``pp_microbatch`` metadata
    and stably hoist, within each combine block, the tiles returning to
    ranks with the heaviest downstream dispatch: those feed the
    StageBoundary handoff that gates the next stage. Like
    ``fuse_boundary``, this only reorders *within* contiguous comm blocks
    — it can never hoist a task ahead of a same-queue producer, so the
    head-blocking validation order stays legal. No-op without PP metadata.
    """
    dn_dispatch = defaultdict(float)     # ((stage, microbatch), rank) -> B
    for td in sched.tasks:
        if (td.task_type == "put_mem_signal"
                and td.meta.get("comm_kind") == "dispatch"
                and "pp_stage" in td.meta):
            cell = (td.meta["pp_stage"], td.meta.get("pp_microbatch", 0))
            dn_dispatch[(cell, td.rank)] += td.comm_bytes
    if not dn_dispatch:
        return
    step = 1 if sched.direction == "forward" else -1

    def key(tid):
        td = sched.tasks[tid]
        if (td.meta.get("comm_kind") != "combine"
                or "pp_stage" not in td.meta):
            return (0.0,)
        dn_cell = (td.meta["pp_stage"] + step,
                   td.meta.get("pp_microbatch", 0))
        return (-dn_dispatch.get((dn_cell, td.dst_rank), 0.0),)

    for (rank, qtype), q in sched.queues.items():
        if qtype != VTQ:
            continue
        sched.queues[(rank, qtype)] = reorder_comm_blocks(sched, q, key)


def apply_reorderings(sched, cfg: ScheduleConfig, *, ratr: bool,
                      gmm_interleave: bool,
                      chain_interleave: bool = False) -> None:
    """Back-compat shim for the pre-pipeline boolean-flag API."""
    from .passes import pipeline_from_flags
    pipeline_from_flags(ratr=ratr, gmm_interleave=gmm_interleave,
                        chain_interleave=chain_interleave).run(sched, cfg)
