"""Numerical executor for compiled schedules.

Replays an SSC taskflow *with real numbers* over an in-process model of the
EP group: every buffer is a ``[rows, width]`` array per (tensor, rank), comm
tasks perform one-sided writes into the destination rank's buffer, and tasks
run in an arbitrary legal order chosen by the event counters — exactly the
runtime protocol of §4.4, minus the hardware.

This is the correctness backbone of the reproduction: for any schedule the
executor's outputs must match the monolithic jnp reference (forward) and
``jax.vjp`` of it (backward), bit-for-bit in fp32. Because execution order is
event-driven (and can be randomized), passing tests prove the *event wiring*
preserves the original MoE-FFN semantics under out-of-order completion.
Under an imbalanced :class:`~repro.core.routing.RoutingPlan` the per-rank
buffers are ragged; the ``*_plan`` reference/loader variants below work with
per-rank lists and exercise skewed, sparse, and hotspot routing.

Note: Combine here is a pure one-sided copy back to the source rank — the
top-k weighting/accumulation lives in ``models/moe.py`` outside the
schedulable fragment, matching the paper's Dispatch-to-Combine boundary.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Callable, Optional

import numpy as np

from .odg import ScheduleConfig
from .scheduler import Schedule, ScheduleError
from .tasks import NO_EVENT, TaskDescriptor


def _sigmoid(x: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-x))


def _silu(x: np.ndarray) -> np.ndarray:
    return x * _sigmoid(x)


def swiglu_np(h: np.ndarray) -> np.ndarray:
    f = h.shape[-1] // 2
    return _silu(h[..., :f]) * h[..., f:]


def swiglu_grad_np(dg: np.ndarray, h: np.ndarray) -> np.ndarray:
    f = h.shape[-1] // 2
    a, b = h[..., :f], h[..., f:]
    s = _sigmoid(a)
    silu_a = a * s
    dsilu = s * (1.0 + a * (1.0 - s))
    da = dg * b * dsilu
    db = dg * silu_a
    return np.concatenate([da, db], axis=-1)


class ExecutorState:
    """All (tensor, rank) buffers of one EP group, host-side."""

    def __init__(self, cfg: ScheduleConfig,
                 fragment_cfgs: Optional[list[ScheduleConfig]] = None):
        self.cfg = cfg
        self.buffers: dict[tuple[str, int], np.ndarray] = {}
        self.weights: dict[tuple[str, int], np.ndarray] = {}
        # (tensor, rank) -> total rows, precomputed from the schedule's write
        # set so lazily-created buffers get their full extent up front.
        self.rows_map: dict[tuple[str, int], int] = {}
        # Multi-fragment schedules: per-fragment configs in execution order
        # (each fragment's tasks must resolve routing against *its* plan).
        self.fragment_cfgs = fragment_cfgs
        # (junction index, rank) -> fn(full_input|None, lo, hi) -> [hi-lo, w]
        # numerical remap for LayerBoundary tasks; identity when absent.
        self.boundary_fns: dict[tuple[int, int], Callable] = {}

    def cfg_of(self, td: TaskDescriptor) -> ScheduleConfig:
        """The config governing this task's routing extents."""
        if self.fragment_cfgs is not None:
            return self.fragment_cfgs[td.meta.get("fragment", 0)]
        return self.cfg

    def set_buffer(self, name: str, rank: int, arr: np.ndarray) -> None:
        self.buffers[(name, rank)] = np.asarray(arr, dtype=np.float32)

    def set_weight(self, name: str, rank: int, arr: np.ndarray) -> None:
        """Weights are [e_loc, K, N] per rank."""
        self.weights[(name, rank)] = np.asarray(arr, dtype=np.float32)

    def ensure(self, name: str, rank: int, rows: int, width: int) -> np.ndarray:
        """Lazily create a buffer, sized strictly from the schedule's
        precomputed ``rows_map`` (never guessed from a same-named peer,
        which breaks once per-rank row counts differ under skew)."""
        key = (name, rank)
        if key not in self.buffers:
            rows = max(rows, self.rows_map.get(key, 0))
            self.buffers[key] = np.zeros((rows, width), dtype=np.float32)
        return self.buffers[key]

    def get(self, name: str, rank: int) -> np.ndarray:
        if (name, rank) in self.buffers:
            return self.buffers[(name, rank)]
        return self.weights[(name, rank)]


# ---------------------------------------------------------------------------
# Task handlers — bridge TDs to "operator bodies" (§4.4's handler layer).
# ---------------------------------------------------------------------------

def _h_put_mem_signal(td: TaskDescriptor, st: ExecutorState) -> None:
    src = td.inputs[0]
    data = st.get(src.tensor, src.rank)[src.lo:src.hi]
    if td.meta.get("compress") == "int8":
        # Compressed inter-node hop: the destination receives the
        # quantize→dequantize round-trip of the payload, exactly what the
        # int8 wire format delivers (see parallel/compression.py).
        from repro.parallel.compression import int8_roundtrip_np
        data = int8_roundtrip_np(data)
    off = 0
    for out in td.outputs:
        buf = st.ensure(out.tensor, out.rank, out.hi, data.shape[1])
        n = out.hi - out.lo
        buf[out.lo:out.hi] = data[off:off + n]
        off += n


def _h_gmm(td: TaskDescriptor, st: ExecutorState) -> None:
    a_rng, w_rng = td.inputs
    a = st.get(a_rng.tensor, a_rng.rank)[a_rng.lo:a_rng.hi]
    w_all = st.get(w_rng.tensor, w_rng.rank)
    transpose = td.meta.get("which") in ("act_grad", "gate_grad")
    if td.meta.get("fallback"):
        # Unsplit task: block-diagonal GMM over the plan's expert blocks
        # (ragged extents; empty experts contribute no rows).
        cfg = st.cfg_of(td)
        plan = cfg.routing
        r = td.rank
        outs = []
        for e in range(cfg.e_loc):
            rows_e = plan.expert_rows(r, e)
            if rows_e == 0:
                continue
            lo = plan.expert_offset(r, e)
            w = w_all[e].T if transpose else w_all[e]
            outs.append(a[lo:lo + rows_e] @ w)
        out = np.concatenate(outs, axis=0)
    else:
        w = w_all[w_rng.lo]
        if transpose:
            w = w.T        # activation-gradient GMMs multiply by Wᵀ
        out = a @ w
    o = td.outputs[0]
    buf = st.ensure(o.tensor, o.rank, o.hi, out.shape[1])
    if buf.shape[0] < o.hi:
        raise ScheduleError(f"output buffer too small for {td.op_name}")
    buf[o.lo:o.hi] = out


def _h_gmm_wgrad(td: TaskDescriptor, st: ExecutorState) -> None:
    g_rng, act_rng = td.inputs   # [grad rows, saved activation rows]
    grad = st.get(g_rng.tensor, g_rng.rank)[g_rng.lo:g_rng.hi]
    act = st.get(act_rng.tensor, act_rng.rank)[act_rng.lo:act_rng.hi]
    key = (td.outputs[0].tensor, td.outputs[0].rank)
    e_loc = st.cfg_of(td).e_loc
    if td.meta.get("fallback"):
        cfg = st.cfg_of(td)
        plan = cfg.routing
        r = td.rank
        for e in range(cfg.e_loc):
            rows_e = plan.expert_rows(r, e)
            if rows_e == 0:
                continue      # no routed rows → zero gradient contribution
            lo = plan.expert_offset(r, e)
            dW = act[lo:lo + rows_e].T @ grad[lo:lo + rows_e]
            if key not in st.buffers:
                st.buffers[key] = np.zeros(
                    (cfg.e_loc, dW.shape[0], dW.shape[1]),
                    dtype=np.float32)
            st.buffers[key][e] += dW
        return
    dW = act.T @ grad
    o = td.outputs[0]
    if key not in st.buffers:
        st.buffers[key] = np.zeros(
            (e_loc, dW.shape[0], dW.shape[1]), dtype=np.float32)
    st.buffers[key][o.lo] += dW      # m-chunks of one expert accumulate


def _h_swiglu(td: TaskDescriptor, st: ExecutorState) -> None:
    i = td.inputs[0]
    h = st.get(i.tensor, i.rank)[i.lo:i.hi]
    out = swiglu_np(h)
    o = td.outputs[0]
    buf = st.ensure(o.tensor, o.rank, o.hi, out.shape[1])
    buf[o.lo:o.hi] = out


def _h_swiglu_grad(td: TaskDescriptor, st: ExecutorState) -> None:
    dg_rng, h_rng = td.inputs
    dg = st.get(dg_rng.tensor, dg_rng.rank)[dg_rng.lo:dg_rng.hi]
    h = st.get(h_rng.tensor, h_rng.rank)[h_rng.lo:h_rng.hi]
    out = swiglu_grad_np(dg, h)
    o = td.outputs[0]
    buf = st.ensure(o.tensor, o.rank, o.hi, out.shape[1])
    buf[o.lo:o.hi] = out


def _h_layer_boundary(td: TaskDescriptor, st: ExecutorState) -> None:
    """Inter-layer token remap tile of a fused multi-fragment schedule.

    The numerical remap (upstream combine-weighted sum composed with the
    downstream layer's routing) lives outside the schedulable fragment;
    ``st.boundary_fns[(junction, rank)]`` supplies it with the contract
    ``fn(full_input_or_None, lo, hi) -> [hi - lo, width]`` where the row
    range addresses the downstream send buffer. Without a registered fn the
    tile is an identity row copy (legal only when the upstream return
    buffer covers the downstream send rows — e.g. both layers share a
    plan), which is what the pure-schedule tests exercise.
    """
    if td.inputs:
        i = td.inputs[0]
        data = st.get(i.tensor, i.rank)[i.lo:i.hi]
    else:
        data = None              # rank returned no rows upstream
    o = td.outputs[0]
    fn = st.boundary_fns.get((td.meta.get("boundary", 0), td.rank))
    if fn is None:
        if data is None or data.shape[0] < o.hi:
            raise ScheduleError(
                f"{td.op_name}: identity boundary needs {o.hi} upstream "
                f"rows, have {0 if data is None else data.shape[0]}; "
                f"register a boundary_fn for mismatched plans")
        out = data[o.lo:o.hi]
    else:
        out = np.asarray(fn(data, o.lo, o.hi), dtype=np.float32)
    if out.shape[0] != o.hi - o.lo:
        raise ScheduleError(
            f"{td.op_name}: boundary fn returned {out.shape[0]} rows "
            f"for range [{o.lo}, {o.hi})")
    buf = st.ensure(o.tensor, o.rank, o.hi, out.shape[1])
    buf[o.lo:o.hi] = out


HANDLERS: dict[str, Callable[[TaskDescriptor, ExecutorState], None]] = {
    "put_mem_signal": _h_put_mem_signal,
    "GMM": _h_gmm,
    "GMMWGrad": _h_gmm_wgrad,
    "SwiGLU": _h_swiglu,
    "SwiGLUGrad": _h_swiglu_grad,
    "LayerBoundary": _h_layer_boundary,
    # The PP stage handoff computes the same junction remap (upstream
    # combine composed with downstream routing) — only its *scheduling*
    # and pricing differ (activation payload over the stage link), so it
    # shares the handler; junctions key ``boundary_fns`` the same way.
    "StageBoundary": _h_layer_boundary,
}


def execute(sched: Schedule, st: ExecutorState,
            rng: Optional[np.random.Generator] = None,
            record_order: Optional[list[int]] = None) -> None:
    """Run the taskflow under event-counter gating.

    Among all currently-runnable queue heads, picks uniformly at random when
    ``rng`` is given (adversarial order), else round-robin — results must be
    identical either way, which is what the tests assert.
    """
    for td in sched.tasks:
        for w in td.outputs:
            key = (w.tensor, w.rank)
            st.rows_map[key] = max(st.rows_map.get(key, 0), w.hi)
    cursors = {k: 0 for k in sched.queues}
    counters: dict[int, int] = defaultdict(int)
    done = 0
    keys = sorted(sched.queues.keys())
    while done < sched.n_tasks:
        ready = []
        for key in keys:
            q = sched.queues[key]
            c = cursors[key]
            if c >= len(q):
                continue
            td = sched.tasks[q[c]]
            if (td.dependent_event == NO_EVENT
                    or counters[td.dependent_event] >= td.dependent_threshold):
                ready.append(key)
        if not ready:
            raise ScheduleError(f"runtime deadlock at {done}/{sched.n_tasks}")
        if rng is not None:
            chosen = [ready[rng.integers(len(ready))]]
        else:
            chosen = ready
        for key in chosen:
            q = sched.queues[key]
            td = sched.tasks[q[cursors[key]]]
            HANDLERS[td.task_type](td, st)
            if td.trigger_event != NO_EVENT:
                counters[td.trigger_event] += 1
            cursors[key] += 1
            done += 1
            if record_order is not None:
                record_order.append(td.tid)


# ---------------------------------------------------------------------------
# Monolithic references (what a kernel-by-kernel framework computes).
# ---------------------------------------------------------------------------

def make_inputs(cfg: ScheduleConfig, seed: int = 0):
    """Balanced-routing fragment inputs: x_src per rank, W1/W2 per rank."""
    rng = np.random.default_rng(seed)
    d, f = cfg.d_model, cfg.d_ff
    x_src = rng.standard_normal(
        (cfg.ep, cfg.ep * cfg.e_loc * cfg.rows, d)).astype(np.float32)
    # Scale before the float32 cast — dividing after it would promote back
    # to float64 (NumPy 2 scalar promotion) and break the fp32 bit-exact
    # executor-vs-reference contract.
    w1 = (rng.standard_normal((cfg.ep, cfg.e_loc, d, 2 * f))
          / np.sqrt(d)).astype(np.float32)
    w2 = (rng.standard_normal((cfg.ep, cfg.e_loc, f, d))
          / np.sqrt(f)).astype(np.float32)
    return x_src, w1, w2


def reference_forward(cfg: ScheduleConfig, x_src, w1, w2):
    """Monolithic Dispatch→GMM1→SwiGLU→GMM2→Combine, all ranks at once."""
    ep, el, R = cfg.ep, cfg.e_loc, cfg.rows
    d, f = cfg.d_model, cfg.d_ff
    # Dispatch: x_src[s] grouped by (dst, e) → x_recv[r] grouped by (e, src).
    blocks = x_src.reshape(ep, ep, el, R, d)          # [src, dst, e, R, d]
    x_recv = np.transpose(blocks, (1, 2, 0, 3, 4))    # [dst, e, src, R, d]
    x_flat = x_recv.reshape(ep, el, ep * R, d)
    h = np.einsum("repd,redf->repf", x_flat.reshape(ep, el, ep * R, d), w1)
    g = swiglu_np(h)
    y = np.einsum("repf,refd->repd", g, w2)
    # Combine: y[r] grouped by (e, src) → y_ret[s] grouped by (dst=r, e).
    y_blocks = y.reshape(ep, el, ep, R, d)            # [dst, e, src, R, d]
    y_ret = np.transpose(y_blocks, (2, 0, 1, 3, 4))   # [src, dst, e, R, d]
    return {
        "x_recv": x_flat.reshape(ep, el * ep * R, d),
        "h": h.reshape(ep, el * ep * R, 2 * f),
        "g": g.reshape(ep, el * ep * R, f),
        "y": y.reshape(ep, el * ep * R, d),
        "y_ret": y_ret.reshape(ep, ep * el * R, d),
    }


def reference_backward(cfg: ScheduleConfig, x_src, w1, w2, dy):
    """Reference gradients via jax.vjp on the monolithic fragment."""
    import jax
    import jax.numpy as jnp

    def frag(x_src, w1, w2):
        ep, el, R = cfg.ep, cfg.e_loc, cfg.rows
        d, f = cfg.d_model, cfg.d_ff
        blocks = x_src.reshape(ep, ep, el, R, d)
        x_recv = jnp.transpose(blocks, (1, 2, 0, 3, 4)).reshape(
            ep, el, ep * R, d)
        h = jnp.einsum("repd,redf->repf", x_recv, w1)
        a, b = h[..., :f], h[..., f:]
        g = jax.nn.silu(a) * b
        y = jnp.einsum("repf,refd->repd", g, w2)
        y_blocks = y.reshape(ep, el, ep, R, d)
        return jnp.transpose(y_blocks, (2, 0, 1, 3, 4)).reshape(
            ep, ep * el * R, d)

    _, vjp = jax.vjp(frag, jnp.asarray(x_src), jnp.asarray(w1),
                     jnp.asarray(w2))
    dx, dw1, dw2 = vjp(jnp.asarray(dy))
    return np.asarray(dx), np.asarray(dw1), np.asarray(dw2)


def load_forward_state(cfg: ScheduleConfig, st: ExecutorState,
                       x_src, w1, w2) -> None:
    for r in range(cfg.ep):
        st.set_buffer("x_src", r, x_src[r])
        st.set_weight("W1", r, w1[r])
        st.set_weight("W2", r, w2[r])


def load_backward_state(cfg: ScheduleConfig, st: ExecutorState,
                        fwd: dict, w1, w2, dy) -> None:
    for r in range(cfg.ep):
        st.set_buffer("dy_src", r, dy[r])
        st.set_weight("W1", r, w1[r])
        st.set_weight("W2", r, w2[r])
        st.set_buffer("g_saved", r, fwd["g"][r])
        st.set_buffer("h_saved", r, fwd["h"][r])
        st.set_buffer("x_recv_saved", r, fwd["x_recv"][r])


# ---------------------------------------------------------------------------
# Ragged (plan-aware) references — imbalanced routing.
#
# Per-rank buffers have *different* row counts under a RoutingPlan, so the
# ragged references work with lists of [rows_r, width] arrays instead of one
# stacked array. The forward reference uses one matmul per expert block —
# the same BLAS calls the executor's gmm_m_split=1 tiles issue — so
# executor output is bit-identical, not merely close.
# ---------------------------------------------------------------------------

def make_inputs_plan(cfg: ScheduleConfig, seed: int = 0):
    """Ragged fragment inputs: per-rank x_src list, W1/W2 per rank."""
    plan = cfg.routing
    rng = np.random.default_rng(seed)
    d, f = cfg.d_model, cfg.d_ff
    x_src = [rng.standard_normal((plan.send_rows(r), d)).astype(np.float32)
             for r in range(cfg.ep)]
    # Scale *before* the float32 cast: a float64 scalar divide after the cast
    # would silently promote back to float64 and break bit-exact comparison
    # against the executor's float32 buffers.
    w1 = (rng.standard_normal((cfg.ep, cfg.e_loc, d, 2 * f))
          / np.sqrt(d)).astype(np.float32)
    w2 = (rng.standard_normal((cfg.ep, cfg.e_loc, f, d))
          / np.sqrt(f)).astype(np.float32)
    return x_src, w1, w2


def _dispatch_np(plan, src_bufs: list, width: int) -> list:
    """(dst, expert)-major send layout → (expert, src)-major recv layout."""
    recv = []
    for r in range(plan.ep):
        buf = np.zeros((plan.recv_rows(r), width), dtype=np.float32)
        for (e, s, c) in plan.recv_layout_cells(r):
            lo = plan.recv_offset(r, e, s)
            s_lo = plan.send_offset(s, r, e)
            buf[lo:lo + c] = src_bufs[s][s_lo:s_lo + c]
        recv.append(buf)
    return recv


def _combine_np(plan, y_bufs: list, width: int) -> list:
    """(expert, src)-major recv layout → send layout on each source rank."""
    ret = []
    for s in range(plan.ep):
        buf = np.zeros((plan.send_rows(s), width), dtype=np.float32)
        for (d, e, c) in plan.send_cells(s):
            lo = plan.send_offset(s, d, e)
            y_lo = plan.recv_offset(d, e, s)
            buf[lo:lo + c] = y_bufs[d][y_lo:y_lo + c]
        ret.append(buf)
    return ret


def reference_forward_plan(cfg: ScheduleConfig, x_src, w1, w2) -> dict:
    """Ragged Dispatch→GMM1→SwiGLU→GMM2→Combine; all values per-rank lists."""
    plan = cfg.routing
    d, f = cfg.d_model, cfg.d_ff
    x_recv = _dispatch_np(plan, x_src, d)
    h, g, y = [], [], []
    for r in range(cfg.ep):
        h_r = np.zeros((plan.recv_rows(r), 2 * f), dtype=np.float32)
        g_r = np.zeros((plan.recv_rows(r), f), dtype=np.float32)
        y_r = np.zeros((plan.recv_rows(r), d), dtype=np.float32)
        for e in range(cfg.e_loc):
            rows_e = plan.expert_rows(r, e)
            if rows_e == 0:
                continue
            lo = plan.expert_offset(r, e)
            h_r[lo:lo + rows_e] = x_recv[r][lo:lo + rows_e] @ w1[r, e]
            g_r[lo:lo + rows_e] = swiglu_np(h_r[lo:lo + rows_e])
            y_r[lo:lo + rows_e] = g_r[lo:lo + rows_e] @ w2[r, e]
        h.append(h_r)
        g.append(g_r)
        y.append(y_r)
    y_ret = _combine_np(plan, y, d)
    return {"x_recv": x_recv, "h": h, "g": g, "y": y, "y_ret": y_ret}


def reference_backward_plan(cfg: ScheduleConfig, fwd: dict, w1, w2, dy):
    """Manual ragged backward mirroring the executor's per-expert matmuls.

    Returns (dx_ret list, dW1 [ep, e_loc, d, 2f], dW2 [ep, e_loc, f, d]).
    Bit-identical to the executor at gmm_m_split=1 by construction; use
    ``reference_backward_plan_jax`` for an independent autodiff oracle.
    """
    plan = cfg.routing
    d, f = cfg.d_model, cfg.d_ff
    dy_recv = _dispatch_np(plan, dy, d)
    dW1 = np.zeros_like(w1)
    dW2 = np.zeros_like(w2)
    dx_disp = []
    for r in range(cfg.ep):
        dx_r = np.zeros((plan.recv_rows(r), d), dtype=np.float32)
        for e in range(cfg.e_loc):
            rows_e = plan.expert_rows(r, e)
            if rows_e == 0:
                continue
            lo = plan.expert_offset(r, e)
            sl = slice(lo, lo + rows_e)
            dg = dy_recv[r][sl] @ w2[r, e].T
            dW2[r, e] = fwd["g"][r][sl].T @ dy_recv[r][sl]
            dh = swiglu_grad_np(dg, fwd["h"][r][sl])
            dx_r[sl] = dh @ w1[r, e].T
            dW1[r, e] = fwd["x_recv"][r][sl].T @ dh
        dx_disp.append(dx_r)
    dx_ret = _combine_np(plan, dx_disp, d)
    return dx_ret, dW1, dW2


def reference_backward_plan_jax(cfg: ScheduleConfig, x_src, w1, w2, dy):
    """Independent oracle: jax.vjp over the ragged monolithic fragment."""
    import jax
    import jax.numpy as jnp

    plan = cfg.routing
    d, f = cfg.d_model, cfg.d_ff

    def frag(x_src_t, w1, w2):
        x_recv = []
        for r in range(cfg.ep):
            blocks = [x_src_t[s][plan.send_offset(s, r, e):
                                 plan.send_offset(s, r, e) + c]
                      for (e, s, c) in plan.recv_layout_cells(r)]
            x_recv.append(jnp.concatenate(blocks, axis=0) if blocks
                          else jnp.zeros((0, d), jnp.float32))
        ys = []
        for r in range(cfg.ep):
            parts = []
            for e in range(cfg.e_loc):
                rows_e = plan.expert_rows(r, e)
                if rows_e == 0:
                    continue
                lo = plan.expert_offset(r, e)
                h = x_recv[r][lo:lo + rows_e] @ w1[r, e]
                a, b = h[:, :f], h[:, f:]
                g = jax.nn.silu(a) * b
                parts.append(g @ w2[r, e])
            ys.append(jnp.concatenate(parts, axis=0) if parts
                      else jnp.zeros((0, d), jnp.float32))
        y_ret = []
        for s in range(cfg.ep):
            blocks = [ys[dd][plan.recv_offset(dd, e, s):
                             plan.recv_offset(dd, e, s) + c]
                      for (dd, e, c) in plan.send_cells(s)]
            y_ret.append(jnp.concatenate(blocks, axis=0) if blocks
                         else jnp.zeros((0, d), jnp.float32))
        return tuple(y_ret)

    _, vjp = jax.vjp(frag, tuple(jnp.asarray(x) for x in x_src),
                     jnp.asarray(w1), jnp.asarray(w2))
    dx, dw1, dw2 = vjp(tuple(jnp.asarray(g) for g in dy))
    return [np.asarray(x) for x in dx], np.asarray(dw1), np.asarray(dw2)


def load_forward_state_plan(cfg: ScheduleConfig, st: ExecutorState,
                            x_src, w1, w2) -> None:
    for r in range(cfg.ep):
        st.set_buffer("x_src", r, x_src[r])
        st.set_weight("W1", r, w1[r])
        st.set_weight("W2", r, w2[r])


def load_backward_state_plan(cfg: ScheduleConfig, st: ExecutorState,
                             fwd: dict, w1, w2, dy) -> None:
    for r in range(cfg.ep):
        st.set_buffer("dy_src", r, dy[r])
        st.set_weight("W1", r, w1[r])
        st.set_weight("W2", r, w2[r])
        st.set_buffer("g_saved", r, fwd["g"][r])
        st.set_buffer("h_saved", r, fwd["h"][r])
        st.set_buffer("x_recv_saved", r, fwd["x_recv"][r])
