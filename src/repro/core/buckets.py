"""BucketSpec — first-class plan quantization policies (paper §5.1).

The SSC reuse story hinges on mapping data-dependent routing onto a small
set of stable shape buckets: two batches whose per-(src, dst, expert) row
counts quantize to the same values produce identical
:class:`~repro.core.routing.RoutingPlan`\\ s and therefore share one
compiled schedule (one SSC cache entry, one jit trace of the ragged EP
ring). Until this module, that quantization was a single scalar
(``bucket_rows``, linear round-up) threaded ad-hoc through the dropless
path; serving traffic and the ragged EP path got none at all.

A :class:`BucketSpec` is a serializable, hashable quantization policy over
nonzero cell counts. Three policies:

* ``linear(rows)`` — round each nonzero count up to the next multiple of
  ``rows``. The legacy ``bucket_rows`` behaviour; ``linear(1)`` is the
  exact (identity) spec. Constant absolute padding per cell, so tiny cells
  pay a large *relative* padding cost (a 1-row cell pads to ``rows``) and
  large cells outgrow the bucket under jitter.
* ``geometric(base, growth=2)`` — round up to the next rung of the ladder
  ``base, base·g, base·g², …`` (power-of-two style for ``g = 2``). Bucket
  width grows with cell size, which is the right match for multiplicative
  jitter: a cell whose count fluctuates by a few percent stays on one rung
  no matter how hot it is, while cold cells pad only to ``base``.
* ``ladder(edges)`` — an explicit sorted rung list; counts round up to the
  smallest edge ≥ count, and counts above the top edge round up to the
  next *multiple* of the top edge (coverage never fails, growth stays
  bounded). Ladders are what :func:`fit_ladder` learns from an observed
  plan population: the edges minimizing total padded rows for a given rung
  budget — the per-profile bucket ladder the ROADMAP asked for.

Invariants every policy keeps (property-tested in ``tests/test_buckets.py``):

* **coverage** — ``quantize(c) >= c`` for every cell; a schedule compiled
  for the bucketed plan always has room for the exact rows;
* **sparsity** — zero cells stay zero, so the task graph's nonzero-cell
  structure (and the EP ring's skipped steps) is preserved;
* **idempotence** — ``quantize(quantize(c)) == quantize(c)``: bucketed
  plans are fixed points, so re-bucketing a cached plan never forks keys;
* **monotonicity** — ``c1 <= c2`` implies ``quantize(c1) <= quantize(c2)``.

A spec ``B`` *coarsens* a spec ``A`` when ``B(A(c)) == B(c)`` for every
count — ``B``'s buckets are unions of ``A``'s. Coarsening can only merge
cache keys, never split them, so a coarser spec's hit rate on a fixed
trace is never lower (also property-tested). ``geometric(b)`` coarsens
``linear(b)``, and ``linear(k·r)`` coarsens ``linear(r)``.

Serialization: :meth:`BucketSpec.key` is the canonical hashable tuple that
rides the SSC cache key and ``Schedule.opts``/blob;
:meth:`BucketSpec.from_any` accepts a spec, a legacy ``bucket_rows`` int,
a CLI string (``"geometric:8"``), or a serialized key, so every layer can
take whichever form its caller holds.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Union

import numpy as np

_POLICIES = ("linear", "geometric", "ladder")


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """A quantization policy over nonzero plan-cell row counts."""

    policy: str = "linear"
    rows: int = 1                      # linear: bucket multiple
    base: int = 8                      # geometric: first rung
    growth: float = 2.0                # geometric: rung ratio
    edges: tuple = ()                  # ladder: sorted rung values
    # Mesh-size tag: bucket ladders are per-mesh-size populations (a plan's
    # cell shape is [ep, ep, e_loc], so a ladder fit at ep=8 says nothing
    # about ep=7 cells). ``None`` = untagged; untagged specs key/print
    # byte-identically to the pre-tag format, so resident cache keys and
    # serialized blobs stay valid. ``SSCCache.rekey_for_mesh`` migrates
    # entries between mesh populations by rewriting this tag.
    ep: Optional[int] = None

    # -- constructors --------------------------------------------------------
    @classmethod
    def linear(cls, rows: int) -> "BucketSpec":
        """Round nonzero counts up to a multiple of ``rows`` (legacy
        ``bucket_rows``); ``rows <= 1`` is the exact/identity spec."""
        return cls(policy="linear", rows=max(1, int(rows)))

    @classmethod
    def geometric(cls, base: int, growth: float = 2.0) -> "BucketSpec":
        """Round nonzero counts up to ``base * growth**k`` rungs."""
        if base < 1:
            raise ValueError(f"geometric base must be >= 1, got {base}")
        if growth <= 1.0:
            raise ValueError(f"geometric growth must be > 1, got {growth}")
        return cls(policy="geometric", base=int(base), growth=float(growth))

    @classmethod
    def ladder(cls, edges: Sequence[int]) -> "BucketSpec":
        """Explicit rung list; counts above the top edge round up to a
        multiple of it."""
        e = tuple(sorted({int(x) for x in edges if int(x) > 0}))
        if not e:
            raise ValueError("ladder needs at least one positive edge")
        return cls(policy="ladder", edges=e)

    @classmethod
    def exact(cls) -> "BucketSpec":
        return cls.linear(1)

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown bucket policy {self.policy!r}; "
                             f"choices: {_POLICIES}")
        if self.ep is not None and int(self.ep) < 1:
            raise ValueError(f"bucket ep tag must be >= 1, got {self.ep}")

    def for_mesh(self, ep: Optional[int]) -> "BucketSpec":
        """This policy tagged to the ``ep``-rank mesh population
        (``None`` untags). Quantization is unchanged — the tag only
        separates cache-key populations per mesh size."""
        ep = int(ep) if ep is not None else None
        if ep == self.ep:
            return self
        return dataclasses.replace(self, ep=ep)

    # -- identity / serialization -------------------------------------------
    def key(self) -> tuple:
        """Canonical hashable identity (rides the SSC cache key and blob).

        ``linear(rows)`` keys as ``("linear", rows)`` — by construction the
        same tuple whether it came from the legacy ``bucket_rows`` int shim
        or an explicit spec, which is the key-identity contract the
        dropless shim test pins. A mesh tag appends ``("ep", n)``:
        ``linear(16).for_mesh(4)`` keys as ``("linear", 16, ("ep", 4))``,
        while untagged specs keep the pre-tag byte-identical form.
        """
        if self.policy == "linear":
            k = ("linear", self.rows)
        elif self.policy == "geometric":
            k = ("geometric", self.base, self.growth)
        else:
            k = ("ladder", self.edges)
        if self.ep is not None:
            k = k + (("ep", self.ep),)
        return k

    def spec(self) -> list:
        """msgpack/JSON-safe form of :meth:`key` (tuples become lists)."""
        k = self.key()
        return [list(x) if isinstance(x, tuple) else x for x in k]

    @property
    def is_exact(self) -> bool:
        return self.policy == "linear" and self.rows <= 1

    def __str__(self) -> str:
        if self.policy == "linear":
            s = f"linear:{self.rows}"
        elif self.policy == "geometric":
            g = (f"x{self.growth:g}" if self.growth != 2.0 else "")
            s = f"geometric:{self.base}{g}"
        else:
            s = "ladder:" + ",".join(str(e) for e in self.edges)
        return s + (f"@ep{self.ep}" if self.ep is not None else "")

    @classmethod
    def parse(cls, text: str) -> "BucketSpec":
        """Parse the CLI form: ``"16"`` (legacy linear), ``"exact"``,
        ``"linear:16"``, ``"geometric:8"``, ``"geometric:8x1.5"``,
        ``"ladder:4,8,32"``; any form takes an ``@epN`` mesh-tag suffix
        (``"linear:16@ep4"``)."""
        t = text.strip().lower()
        if "@" in t:
            t, _, tag = t.rpartition("@")
            if not tag.startswith("ep") or not tag[2:].isdigit():
                raise ValueError(
                    f"bucket spec {text!r}: mesh tag must be '@epN'")
            return cls.parse(t).for_mesh(int(tag[2:]))
        if t in ("exact", "none", "1"):
            return cls.exact()
        if ":" not in t:
            try:
                return cls.linear(int(t))
            except ValueError:
                raise ValueError(
                    f"bucket spec {text!r}: expected an int (legacy "
                    f"bucket_rows) or policy:params "
                    f"(linear:R | geometric:B[xG] | ladder:E1,E2,...)")
        policy, _, params = t.partition(":")
        if policy == "linear":
            return cls.linear(int(params))
        if policy == "geometric":
            if "x" in params:
                b, _, g = params.partition("x")
                return cls.geometric(int(b), float(g))
            return cls.geometric(int(params))
        if policy == "ladder":
            return cls.ladder([int(x) for x in params.split(",") if x])
        raise ValueError(f"unknown bucket policy {policy!r} in {text!r}; "
                         f"choices: {_POLICIES}")

    @classmethod
    def from_any(cls, obj: Union["BucketSpec", int, str, Sequence, None],
                 ) -> "BucketSpec":
        """Normalize any accepted bucket argument to a spec.

        ``None`` and ints are the legacy ``bucket_rows`` shim
        (``None``/``<=1`` = exact); strings go through :meth:`parse`;
        tuples/lists are serialized :meth:`key`/:meth:`spec` forms.
        """
        if obj is None:
            return cls.exact()
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, (int, np.integer)):
            return cls.linear(int(obj))
        if isinstance(obj, str):
            return cls.parse(obj)
        if isinstance(obj, (tuple, list)) and obj \
                and isinstance(obj[0], str):
            ep = None
            if (len(obj) > 1 and isinstance(obj[-1], (tuple, list))
                    and len(obj[-1]) == 2 and obj[-1][0] == "ep"):
                ep = int(obj[-1][1])
                obj = obj[:-1]
            policy = obj[0]
            spec = None
            if policy == "linear":
                spec = cls.linear(obj[1])
            elif policy == "geometric":
                spec = cls.geometric(obj[1], obj[2] if len(obj) > 2 else 2.0)
            elif policy == "ladder":
                spec = cls.ladder(obj[1])
            if spec is not None:
                return spec.for_mesh(ep) if ep is not None else spec
        raise TypeError(f"cannot interpret {obj!r} as a BucketSpec")

    # -- quantization --------------------------------------------------------
    def _rungs_through(self, top: int) -> np.ndarray:
        """Geometric rung values covering counts up to ``top``."""
        rungs = [self.base]
        while rungs[-1] < top:
            nxt = int(np.ceil(rungs[-1] * self.growth))
            rungs.append(max(nxt, rungs[-1] + 1))
        return np.asarray(rungs, dtype=np.int64)

    def quantize(self, counts) -> np.ndarray:
        """Quantize a count array cell-wise: nonzero counts round *up* to
        their policy bucket, zeros stay zero (sparsity preserved)."""
        c = np.asarray(counts, dtype=np.int64)
        if self.is_exact or c.size == 0:
            return c.copy() if c is counts else c
        top = int(c.max()) if c.size else 0
        if self.policy == "linear":
            q = -(-c // self.rows) * self.rows
        elif self.policy == "geometric":
            rungs = self._rungs_through(max(top, self.base))
            idx = np.searchsorted(rungs, c, side="left")
            q = rungs[np.minimum(idx, len(rungs) - 1)]
        else:
            edges = np.asarray(self.edges, dtype=np.int64)
            idx = np.searchsorted(edges, c, side="left")
            inside = idx < len(edges)
            q = np.where(inside, edges[np.minimum(idx, len(edges) - 1)], 0)
            # Above the top edge: next multiple of the top edge, so
            # coverage holds for any future count the fit never saw.
            e_top = int(edges[-1])
            q = np.where(inside, q, -(-c // e_top) * e_top)
        return np.where(c > 0, q, 0)

    def apply(self, plan):
        """Bucketed :class:`~repro.core.routing.RoutingPlan` of ``plan``.

        The returned plan covers ``plan`` cell-wise with identical
        sparsity; exact specs return ``plan`` unchanged (same object, so
        cached identity survives).
        """
        from .routing import RoutingPlan
        if self.is_exact:
            return plan
        q = self.quantize(np.asarray(plan.counts, dtype=np.int64))
        if (q == np.asarray(plan.counts)).all():
            return plan
        return RoutingPlan.from_counts(q)

    def pad_ratio(self, counts) -> float:
        """Padded rows / exact rows for one count matrix (1.0 = no pad)."""
        c = np.asarray(counts, dtype=np.int64)
        total = int(c.sum())
        return float(self.quantize(c).sum()) / total if total else 1.0

    def pad_rows(self, counts) -> int:
        """Absolute padded-row overhead for one count matrix (>= 0).

        The padding term of the online tuner's swap criterion
        (``launch/online.py``) — additive across a window where
        :meth:`pad_ratio` is not."""
        c = np.asarray(counts, dtype=np.int64)
        return int(self.quantize(c).sum() - c.sum())


def coarsens(coarse: BucketSpec, fine: BucketSpec,
             counts: Iterable[int]) -> bool:
    """Check ``coarse``'s buckets are unions of ``fine``'s on ``counts``.

    When true, ``fine(c1) == fine(c2)`` implies ``coarse(c1) ==
    coarse(c2)`` for every pair in ``counts`` — coarsening merges cache
    keys, never splits them, so the coarse spec's hit rate on a trace over
    these counts is never lower than the fine spec's.
    """
    c = np.asarray(list(counts), dtype=np.int64)
    return bool((coarse.quantize(fine.quantize(c))
                 == coarse.quantize(c)).all())


# ---------------------------------------------------------------------------
# Ladder fitting — learn a per-profile rung list from observed plans.
# ---------------------------------------------------------------------------

def _cell_intervals(plans) -> tuple[np.ndarray, list[tuple[int, int]], int]:
    """(stacked counts, per-cell observed nonzero [min, max] ranges,
    n_plans) over a same-shape plan population."""
    mats = []
    for p in plans:
        counts = getattr(p, "counts", None)
        if counts is None:
            counts = getattr(getattr(p, "plan", None), "counts", p)
        mats.append(np.asarray(counts, dtype=np.int64))
    stacked = np.stack(mats)                        # [n_plans, ...cells]
    flat = stacked.reshape(stacked.shape[0], -1)
    ivals = []
    for c in range(flat.shape[1]):
        col = flat[:, c][flat[:, c] > 0]
        if col.size:
            ivals.append((int(col.min()), int(col.max())))
    return flat, ivals, stacked.shape[0]


def fit_ladder(plans, budget: int, split_penalty: float = 0.5) -> BucketSpec:
    """Fit an explicit bucket ladder from an observed plan population.

    Chooses at most ``budget`` edges (a subset of the observed distinct
    nonzero cell counts, always including the maximum) by exact DP over two
    costs the ladder trades between:

    * **padding** — total padded rows when every observed count rounds up
      to its next edge (the classic 1-D quantization objective);
    * **key-flip risk** — a plan's cache key only repeats when *every*
      cell lands on the same rung, so an edge placed inside some cell's
      observed count range [min, max] lets that cell hop rungs under
      jitter and forks the key. Each such straddled interval charges
      ``split_penalty`` × the population's mean per-cell rows, pushing
      edges into the gaps *between* cell ranges.

    ``split_penalty=0`` is padding-optimal in-sample (``budget >= n``
    distinct counts then reproduces the population itself — the exact-keys
    regime); larger values buy reuse with padding, degenerating to one
    rung per merged band of overlapping cell ranges. The replay harness
    (``launch/replay.py``) produces the plan populations this learns from,
    per traffic profile; fit on one trace segment and evaluate on another
    (``bench_dropless`` fits on a held-out seed).

    All plans must share one ``[ep, ep, e_loc]`` cell shape — cell
    identity across the population is what defines the flip risk.
    """
    if budget < 1:
        raise ValueError(f"ladder budget must be >= 1, got {budget}")
    if split_penalty < 0:
        raise ValueError(
            f"split_penalty must be >= 0, got {split_penalty}")
    flat, ivals, n_plans = _cell_intervals(plans)
    pool = flat[flat > 0]
    if pool.size == 0:
        raise ValueError("fit_ladder: no nonzero cell counts in the plans")
    vals, freq = np.unique(pool, return_counts=True)
    n = len(vals)
    if budget >= n and split_penalty == 0:
        return BucketSpec.ladder(vals.tolist())

    # Straddle census: intervals an edge between vals[j] and vals[j+1]
    # would cut (the cell takes values on both sides of the boundary).
    straddles = np.zeros(n, dtype=np.int64)
    for lo, hi in ivals:
        straddles += ((vals >= lo) & (vals < hi))
    mean_cell_rows = float(pool.sum()) / max(1, len(ivals)) / max(1, n_plans)
    # Penalty is in padded-row units: one straddled cell ≈ re-padding that
    # cell's mean rows once per plan in the population.
    boundary_cost = split_penalty * straddles * mean_cell_rows * n_plans

    csum_f = np.concatenate([[0], np.cumsum(freq)])
    csum_fv = np.concatenate([[0], np.cumsum(freq * vals)])

    def seg_pad(i: int, j: int) -> int:
        # sum_{t=i..j} freq[t] * (vals[j] - vals[t])
        return int(vals[j]) * int(csum_f[j + 1] - csum_f[i]) \
            - int(csum_fv[j + 1] - csum_fv[i])

    def pen(j: int) -> float:
        return 0.0 if j == n - 1 else float(boundary_cost[j])

    INF = float("inf")
    kmax = min(budget, n)
    # dp[k][j] = min cost covering v[0..j] with k edges, last edge v[j].
    dp = [[INF] * n for _ in range(kmax + 1)]
    back = [[-1] * n for _ in range(kmax + 1)]
    for j in range(n):
        dp[1][j] = seg_pad(0, j) + pen(j)
    for k in range(2, kmax + 1):
        for j in range(k - 1, n):
            best, arg = INF, -1
            for i in range(k - 2, j):
                cand = dp[k - 1][i] + seg_pad(i + 1, j) + pen(j)
                if cand < best:
                    best, arg = cand, i
            dp[k][j], back[k][j] = best, arg
    # Fewer edges than the budget may cost less once boundaries are priced.
    k = min(range(1, kmax + 1), key=lambda kk: dp[kk][n - 1])
    edges = [int(vals[n - 1])]
    j = n - 1
    while k > 1 and back[k][j] >= 0:
        j = back[k][j]
        edges.append(int(vals[j]))
        k -= 1
    return BucketSpec.ladder(edges)


def normalize_bucket(bucket, bucket_rows: Optional[int] = None) -> BucketSpec:
    """Resolve the (new-style ``bucket``, legacy ``bucket_rows``) pair every
    threaded-through signature accepts: ``bucket`` wins when given, else the
    legacy int (``None`` → exact)."""
    if bucket is not None:
        return BucketSpec.from_any(bucket)
    return BucketSpec.from_any(bucket_rows)
