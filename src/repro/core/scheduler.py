"""Static scheduling + event-driven synchronization (§4.3).

Compiles the TD collection generated from an ODG into per-rank CTQ/VTQ
taskflows augmented with threshold event counters:

1. *Dependency derivation* — a consumer depends on every producer whose write
   range overlaps one of its read ranges (true tile-level data readiness,
   not operator barriers).
2. *Event allocation* — consumers sharing an identical producer set share one
   event (paper: "multiple downstream tasks may wait on the same event");
   the event threshold equals the producer count (paper: "multiple upstream
   tasks may contribute to the same event counter"). Each producer triggers
   exactly one event — the single ``trigger_event`` field of Table 1. Split
   propagation guarantees aligned boundaries, which is what makes the
   single-trigger invariant hold; the scheduler *verifies* it and raises on
   violation instead of silently emitting an illegal plan.
3. *Queue construction* — per (rank, CTQ/VTQ) task order; workers consume
   in order and wait on dependent events, so the combined (queue ∪ event)
   order must be deadlock-free.
4. *Pass pipeline* — an ordered, serializable list of registered schedule
   passes (``core/passes.py``: RATR, cache-guided GMM interleaving, chain
   interleaving, critical-rank-first, …) permutes mutually independent
   queue entries; ``Schedule.opts`` records the pipeline spec, and
   ``validate_schedule`` then proves the final (queue ∪ event) combination
   deadlock-free by symbolic execution of the counters.

All stages are extent-agnostic: dependency derivation works on the exact
(possibly ragged) tile ranges the plan-driven FillConfigs emit, so
imbalanced RoutingPlans — variable cell sizes, empty cells, whole ranks
with zero tasks — compile through the same path as the balanced grid.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

from .odg import ODG, CTQ, VTQ
from .split import propagate_splits
from .tasks import NO_EVENT, Range, TaskDescriptor, fill_tasks


@dataclasses.dataclass
class Event:
    eid: int
    threshold: int
    home_rank: int
    producers: tuple[int, ...]   # tids that trigger this event


@dataclasses.dataclass
class Schedule:
    """The full compiled taskflow for one EP group (all ranks)."""

    direction: str
    ep: int
    tasks: list[TaskDescriptor]                    # indexed by tid
    events: dict[int, Event]
    queues: dict[tuple[int, str], list[int]]       # (rank, CTQ|VTQ) -> [tid]
    opts: dict = dataclasses.field(default_factory=dict)

    def queue(self, rank: int, qtype: str) -> list[int]:
        return self.queues.get((rank, qtype), [])

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)


class ScheduleError(RuntimeError):
    pass


def _derive_dependencies(tasks: list[TaskDescriptor]) -> list[set[int]]:
    """Producer tid set per task, from tile-range overlap."""
    writers: dict[tuple[str, int], list[tuple[Range, int]]] = defaultdict(list)
    for td in tasks:
        for w in td.outputs:
            writers[(w.tensor, w.rank)].append((w, td.tid))
    deps: list[set[int]] = []
    for td in tasks:
        producers: set[int] = set()
        for rd in td.inputs:
            for (w, tid) in writers.get((rd.tensor, rd.rank), ()):  # noqa: B905
                if tid != td.tid and w.overlaps(rd):
                    producers.add(tid)
        deps.append(producers)
    return deps


def _allocate_events(tasks: list[TaskDescriptor], deps: list[set[int]],
                     allow_multi_trigger: bool = False) -> dict[int, Event]:
    """Dedup producer sets into shared threshold events (§4.3)."""
    events: dict[int, Event] = {}
    group_to_eid: dict[frozenset, int] = {}
    producer_trigger: dict[int, int] = {}

    for td, producers in zip(tasks, deps):
        if not producers:
            td.dependent_event = NO_EVENT
            td.dependent_threshold = 0
            continue
        key = frozenset(producers)
        eid = group_to_eid.get(key)
        if eid is None:
            eid = len(events)
            events[eid] = Event(eid=eid, threshold=len(producers),
                                home_rank=td.rank,
                                producers=tuple(sorted(producers)))
            group_to_eid[key] = eid
            for p in producers:
                if p in producer_trigger and producer_trigger[p] != eid:
                    if not allow_multi_trigger:
                        raise ScheduleError(
                            f"single-trigger invariant violated: task "
                            f"{tasks[p].op_name}#{tasks[p].task_index} would "
                            f"trigger events {producer_trigger[p]} and {eid}. "
                            f"Tile boundaries are misaligned — split "
                            f"propagation should have prevented this.")
                producer_trigger[p] = eid
        else:
            # All consumers of this event must live where the counter lives.
            if events[eid].home_rank != td.rank:
                raise ScheduleError(
                    f"event {eid} consumers span ranks "
                    f"{events[eid].home_rank} and {td.rank}")
        td.dependent_event = eid
        td.dependent_threshold = events[eid].threshold

    for p, eid in producer_trigger.items():
        tasks[p].trigger_event = eid
    return events


def compile_schedule(g: ODG, *, pipeline=None, ratr: bool = False,
                     gmm_interleave: bool = False,
                     chain_interleave: bool = False,
                     allow_multi_trigger: bool = False) -> Schedule:
    """ODG → validated per-rank CTQ/VTQ taskflow (the SSC payload).

    ``pipeline`` names the ordered schedule passes to run between queue
    construction and validation — a :class:`~repro.core.passes.Pipeline`, a
    list of pass names, or a serialized spec. The legacy boolean kwargs
    (``ratr=`` / ``gmm_interleave=`` / ``chain_interleave=``) are shimmed
    onto the equivalent canonical pipeline and compile byte-identical SSC
    blobs; they are mutually exclusive with ``pipeline``.

    ``pipeline="auto"`` resolves through the cost-model-guided selector
    (``core/autoselect.py``) against this graph's config and direction; the
    *resolved* spec — never the literal ``"auto"`` — is what lands in
    ``Schedule.opts`` (and hence the SSC blob). The tiling is pinned here
    because the ODG's task set is already built; callers who want the
    selector's ``gmm_m_split`` budget grid resolve before building the
    graph (``SSCCache.get_or_compile`` does).
    """
    from .passes import resolve_pipeline
    from .autoselect import auto_pipeline, is_auto
    if is_auto(pipeline):
        pipe, _ = auto_pipeline(None, g.cfg, direction=g.direction,
                                allow_retile=False)
    else:
        pipe = resolve_pipeline(pipeline, ratr=ratr,
                                gmm_interleave=gmm_interleave,
                                chain_interleave=chain_interleave)

    propagate_splits(g)

    tasks: list[TaskDescriptor] = []
    for op in g.topological():
        tds = fill_tasks(g, op)
        for td in tds:
            td.tid = len(tasks)
            tasks.append(td)

    deps = _derive_dependencies(tasks)
    events = _allocate_events(tasks, deps,
                              allow_multi_trigger=allow_multi_trigger)

    queues: dict[tuple[int, str], list[int]] = defaultdict(list)
    for td in tasks:
        queues[(td.rank, td.queue_type)].append(td.tid)

    sched = Schedule(direction=g.direction, ep=g.cfg.ep, tasks=tasks,
                     events=events, queues=dict(queues),
                     opts={"pipeline": pipe.spec()})

    pipe.run(sched, g.cfg)

    validate_schedule(sched)
    return sched


# ---------------------------------------------------------------------------
# Deadlock-freedom / legality validation by symbolic counter execution.
# ---------------------------------------------------------------------------

def validate_schedule(s: Schedule) -> None:
    """Prove the (queue order ∪ event) combination admits full execution.

    Workers consume queues in order and block on dependent events, so a legal
    schedule must let some queue head run at every step until all tasks
    complete. This is exactly the runtime protocol of §4.4, executed
    symbolically.
    """
    cursors = {k: 0 for k in s.queues}
    counters: dict[int, int] = defaultdict(int)
    done = 0
    total = s.n_tasks
    # Tasks must each sit in exactly one queue.
    enqueued = sum(len(q) for q in s.queues.values())
    if enqueued != total:
        raise ScheduleError(f"{total} tasks but {enqueued} queue entries")

    progressed = True
    while done < total:
        if not progressed:
            stuck = {k: (s.tasks[s.queues[k][c]].op_name
                         if c < len(s.queues[k]) else "<drained>")
                     for k, c in cursors.items()}
            raise ScheduleError(f"deadlock: no queue head is ready; "
                                f"completed {done}/{total}; heads={stuck}")
        progressed = False
        for key, q in s.queues.items():
            while cursors[key] < len(q):
                td = s.tasks[q[cursors[key]]]
                if (td.dependent_event != NO_EVENT
                        and counters[td.dependent_event]
                        < td.dependent_threshold):
                    break
                # run it
                if td.trigger_event != NO_EVENT:
                    counters[td.trigger_event] += 1
                cursors[key] += 1
                done += 1
                progressed = True


def execution_order(s: Schedule) -> list[int]:
    """One legal global completion order (round-robin over queue heads)."""
    cursors = {k: 0 for k in s.queues}
    counters: dict[int, int] = defaultdict(int)
    order: list[int] = []
    keys = sorted(s.queues.keys())
    while len(order) < s.n_tasks:
        progressed = False
        for key in keys:
            q = s.queues[key]
            if cursors[key] >= len(q):
                continue
            td = s.tasks[q[cursors[key]]]
            if (td.dependent_event != NO_EVENT
                    and counters[td.dependent_event] < td.dependent_threshold):
                continue
            if td.trigger_event != NO_EVENT:
                counters[td.trigger_event] += 1
            cursors[key] += 1
            order.append(td.tid)
            progressed = True
        if not progressed:
            raise ScheduleError("deadlock during execution_order")
    return order
