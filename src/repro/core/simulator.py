"""Discrete-event model of the unified AIC/AIV runtime (§4.4) on Ascend A3.

The container has no Ascend (or TPU) hardware, so the paper's latency tables
are reproduced *structurally*: the simulator executes real compiled schedules
(the same ``Schedule`` objects the executor validates numerically) against a
hardware model built from the paper's constants (``hardware.AscendA3``).

Two execution modes:

* ``simulate_unified`` — the HyperParallel-MoE runtime: per-rank AIC/AIV
  worker pools fetch CTQ/VTQ entries in order, block on dependent event
  counters, drive one-sided transfers over per-rank egress/ingress links,
  and share an LRU-modelled L2 between producer and consumer tiles.
* ``simulate_baseline`` — the conventional operator-by-operator path:
  per-op full-device kernels with launch gaps, host-synchronized collective
  AllToAll, and strict AIC/AIV alternation.

Per-tile GMM efficiency is identical in both modes — the baseline's low
observed MAC ratio *emerges* from idle alternation, it is not assumed.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict, defaultdict

from .costmodel import CostModel
from .hardware import AscendA3
from .odg import CTQ, VTQ
from .scheduler import Schedule, ScheduleError
from .tasks import NO_EVENT, TaskDescriptor


@dataclasses.dataclass
class SimResult:
    makespan_us: float
    busy_us: dict            # (rank, pool) -> busy time
    mac_ratio: float         # cube busy / (makespan * n_pools) across ranks
    exposed_comm_us: float   # time when comm is in flight but no cube busy
    l2_hits: int
    l2_lookups: int
    timeline: list           # (start, end, rank, pool, op_name)
    # Skew diagnostics (imbalanced RoutingPlans): how much longer the most
    # loaded rank's cube stays busy than the average rank's — the straggler
    # a load-imbalanced MoE batch creates even with perfect overlap.
    straggler_ratio: float = 1.0     # max / mean per-rank cube busy time
    critical_rank: int = -1          # rank with the largest cube busy time
    # Paper headline metrics: busy time per phase kind (dispatch / gmm /
    # vector / combine, plus boundary for fused schedules) and the explicit
    # dispatch-to-combine span — first dispatch byte in flight to last
    # combine byte landed.
    phase_us: dict = dataclasses.field(default_factory=dict)
    dispatch_to_combine_us: float = 0.0
    # Multi-fragment schedules: execution-position index -> wall-clock span
    # of that fragment's tasks. Overlap shows up as spans summing to more
    # than the makespan.
    fragment_makespan_us: dict = dataclasses.field(default_factory=dict)
    # PP-fused schedules (tasks stamped pp_stage/pp_microbatch):
    # per-(stage, microbatch) wall-clock span and per-phase busy breakdown.
    # Bubble absorption shows up as a cell's "stage"/"dispatch" phase time
    # overlapping the neighbouring cells' spans.
    stage_span_us: dict = dataclasses.field(default_factory=dict)
    stage_phase_us: dict = dataclasses.field(default_factory=dict)
    # Per-link-class transfer busy time: {"local"/"link"} flat, or
    # {"local"/"intra"/"inter"} when the cost model carries a Topology —
    # where the comm time actually lives in a hierarchical cluster.
    link_us: dict = dataclasses.field(default_factory=dict)

    @property
    def l2_hit_rate(self) -> float:
        return self.l2_hits / max(1, self.l2_lookups)


def _phase_of(td: TaskDescriptor) -> str:
    """Phase kind for the per-phase breakdown (comm kinds from TD meta)."""
    if td.task_type == "put_mem_signal":
        return td.meta.get("comm_kind", "dispatch")
    if td.task_type == "LayerBoundary":
        return "boundary"
    if td.task_type == "StageBoundary":
        return "stage"
    return "gmm" if td.queue_type == CTQ else "vector"


class _L2:
    """Per-rank LRU of recently-touched tile ranges (byte-weighted)."""

    def __init__(self, capacity: int):
        self.cap = capacity
        self.entries: OrderedDict[tuple, int] = OrderedDict()
        self.used = 0

    def touch(self, key: tuple, nbytes: int) -> None:
        if key in self.entries:
            self.used -= self.entries.pop(key)
        self.entries[key] = nbytes
        self.used += nbytes
        while self.used > self.cap and self.entries:
            _, b = self.entries.popitem(last=False)
            self.used -= b

    def hit(self, key: tuple) -> bool:
        if key in self.entries:
            self.entries.move_to_end(key)
            return True
        return False


def _task_duration_us(td: TaskDescriptor, cost: CostModel, l2: _L2,
                      count_l2) -> float:
    """Execution time of one tile task on its unit (excl. queue overhead).

    The timing formula itself lives in :class:`CostModel` (shared with the
    compile-time passes); this wrapper only owns the simulator's L2 *state*
    — which input tiles hit, what the miss allocates — and hands the
    resulting hit fraction to the model.
    """
    if td.task_type in ("put_mem_signal", "StageBoundary"):
        # Link-bound tasks: no L2 term — a StageBoundary tile streams the
        # activation payload over the stage link, not from HBM.
        return cost.task_us(td)
    total_rows = sum(r.hi - r.lo for r in td.inputs) or 1
    hit_b = miss_b = 0.0
    for rng in td.inputs:
        key = (rng.tensor, rng.rank, rng.lo, rng.hi)
        rows = rng.hi - rng.lo
        if l2.hit(key):
            hit_b += rows
            count_l2(True)
        else:
            miss_b += rows
            count_l2(False)
            # read-miss allocates in L2 (streams evict older residents).
            l2.touch(key, int(td.read_bytes * rows / total_rows))
    frac = hit_b / max(1.0, hit_b + miss_b)
    return cost.task_us(td, frac)


def _touch_outputs(td: TaskDescriptor, l2s: dict[int, _L2]) -> None:
    for rng in td.outputs:
        l2s[rng.rank].touch((rng.tensor, rng.rank, rng.lo, rng.hi),
                            int(td.write_bytes / max(1, len(td.outputs))))


def simulate_unified(s: Schedule, hw: AscendA3 = AscendA3(), *,
                     dispatch_overhead_us: float | None = None,
                     serialize_dispatch: bool = False,
                     workers_per_pool: dict | None = None,
                     cost: CostModel | None = None,
                     fragment_barrier: bool = False,
                     stage_barrier: bool = False) -> SimResult:
    """Event-driven simulation of the single-launch unified runtime.

    ``serialize_dispatch`` models an *online dynamic* scheduler: task
    dispatch decisions go through one device-side scheduler, so per-task
    overheads serialize on the critical path (§6.2). The static path's
    dispatch is per-worker queue consumption and overlaps freely.
    ``cost`` overrides the per-task duration model (default: the shared
    ``CostModel`` on ``hw`` with L2 residency effects on).
    ``fragment_barrier`` serializes multi-fragment taskflows: fragment
    ``j`` may not start until every task of fragments ``< j`` has
    finished. This is the back-to-back per-layer reference a fused
    schedule is measured against — identical tasks and costs, with the
    cross-fragment overlap switched off.
    ``stage_barrier`` is the pipeline-parallel analogue: cell (s, m) of a
    PP-fused schedule may not start until its feeding cell (same
    microbatch, previous stage in this direction's dataflow) and its
    stage predecessor (same stage, previous microbatch) have fully
    drained. That is a synchronous pipeline — still pipelined across
    stages, but with no intra-cell work absorbed into neighbours' bubbles
    — the fair reference PP fusion is measured against. On schedules
    without pp_stage metadata it degrades to ``fragment_barrier``.
    """
    if fragment_barrier and stage_barrier:
        raise ValueError("fragment_barrier and stage_barrier are "
                         "mutually exclusive references")
    cost = cost or CostModel(hw=hw)
    oh = (hw.static_dispatch_us if dispatch_overhead_us is None
          else dispatch_overhead_us)
    pools = workers_per_pool or {CTQ: hw.num_aic, VTQ: hw.num_aiv}
    sched_clock: dict[int, float] = defaultdict(float)  # per-rank clock

    ranks = sorted({r for (r, _) in s.queues})
    l2s = {r: _L2(hw.l2_bytes) for r in ranks}
    l2_stats = [0, 0]

    def count_l2(hit: bool):
        l2_stats[0] += int(hit)
        l2_stats[1] += 1

    cursors = {k: 0 for k in s.queues}
    idle = {k: pools[k[1]] for k in s.queues}
    counters: dict[int, int] = defaultdict(int)
    waiters: dict[int, list[int]] = defaultdict(list)   # eid -> [tid]
    # Link clocks are per (rank, link class): with a Topology the intra-node
    # bus and the inter-node NIC are independent resources, so intra traffic
    # never queues behind an inter-node transfer (and vice versa).
    egress_free: dict = defaultdict(float)
    ingress_free: dict = defaultdict(float)
    link_busy: dict = defaultdict(float)
    busy: dict = defaultdict(float)
    timeline: list = []
    heap: list = []       # (time, seq, kind, payload)
    seq = 0
    done = 0
    now = 0.0
    comm_busy_intervals: list[tuple[float, float]] = []
    cube_busy_intervals: list[tuple[float, float]] = []
    phase_busy: dict = defaultdict(float)
    frag_span: dict = {}
    stage_span: dict = {}
    stage_phase: dict = defaultdict(lambda: defaultdict(float))
    d2c = [None, None]        # [first dispatch begin, last combine end]

    def frag_of(td):
        return td.meta.get("fragment", 0)

    frag_total: dict[int, int] = defaultdict(int)
    frag_done: dict[int, int] = defaultdict(int)
    barrier_waiters: dict[int, list[int]] = defaultdict(list)
    if fragment_barrier or stage_barrier:
        for td in s.tasks:
            frag_total[frag_of(td)] += 1
    open_frag = min(frag_total, default=0)
    # stage_barrier prerequisite graph: fragment -> fragments that must
    # fully drain first (feeding cell + same-stage predecessor microbatch).
    frag_prereq: dict[int, tuple[int, ...]] = {}
    stage_waiters: dict[int, list[int]] = defaultdict(list)
    if stage_barrier:
        frag_cell: dict[int, tuple[int, int]] = {}
        for td in s.tasks:
            f = frag_of(td)
            if f not in frag_cell and "pp_stage" in td.meta:
                frag_cell[f] = (td.meta["pp_stage"],
                                td.meta.get("pp_microbatch", 0))
        if frag_cell:
            cell_frag = {c: f for f, c in frag_cell.items()}
            step = 1 if s.direction == "forward" else -1
            for f, (st_, m) in frag_cell.items():
                frag_prereq[f] = tuple(
                    cell_frag[c] for c in ((st_, m - 1), (st_ - step, m))
                    if c in cell_frag)
        else:
            frag_prereq = {f: ((f - 1,) if f - 1 in frag_total else ())
                           for f in frag_total}

    def cell_ready(f):
        return all(frag_done[p] >= frag_total[p]
                   for p in frag_prereq.get(f, ()))

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, payload))
        seq += 1

    def dispatch_at(t, rank):
        """Time the dispatch decision lands (serialized for dynamic)."""
        if serialize_dispatch:
            begin = max(t, sched_clock[rank])
            sched_clock[rank] = begin + oh
            return begin + oh
        return t + oh

    def admit(tid, t):
        """Event gate for a fetched TD (past any fragment barrier)."""
        td = s.tasks[tid]
        if (td.dependent_event == NO_EVENT
                or counters[td.dependent_event]
                >= td.dependent_threshold):
            push(dispatch_at(t, td.rank), "start", tid)
        else:
            waiters[td.dependent_event].append(tid)

    def try_fetch(key, t):
        """Idle workers grab next TDs in order (§4.4 queue protocol)."""
        q = s.queues[key]
        while idle[key] > 0 and cursors[key] < len(q):
            tid = q[cursors[key]]
            cursors[key] += 1
            idle[key] -= 1
            td = s.tasks[tid]
            if fragment_barrier and frag_of(td) > open_frag:
                barrier_waiters[frag_of(td)].append(tid)
            elif stage_barrier and not cell_ready(frag_of(td)):
                stage_waiters[frag_of(td)].append(tid)
            else:
                admit(tid, t)

    def start_task(tid, t):
        td = s.tasks[tid]
        dur = _task_duration_us(td, cost, l2s[td.rank], count_l2)
        begin = t
        if (td.task_type == "put_mem_signal" and td.dst_rank >= 0
                and td.dst_rank != td.src_rank):
            # Work-conserving fluid link model: the transfer queues ``dur``
            # of work on the source egress and destination ingress clocks
            # independently and completes when both have drained it. This
            # avoids artificial convoy holes from joint interval booking
            # while still capturing per-link serialization (the RATR
            # hotspot effect shows up as an inflated ingress clock).
            cls = cost.link_class_of(td)
            e0 = max(egress_free[(td.src_rank, cls)], t) + dur
            i0 = max(ingress_free[(td.dst_rank, cls)], t) + dur
            egress_free[(td.src_rank, cls)] = e0
            ingress_free[(td.dst_rank, cls)] = i0
            begin = max(e0, i0) - dur
            comm_busy_intervals.append((begin, begin + dur))
            link_busy[cls] += dur
        elif td.task_type == "put_mem_signal":
            link_busy[cost.link_class_of(td)] += dur
        elif td.task_type == "StageBoundary":
            # The activation handoff rides the stage link's egress from
            # this rank, sharing the wire with EP cross-node traffic of the
            # same class — PP fusion only wins when the bubble has room for
            # both.
            cls = cost.link_class_of(td)
            e0 = max(egress_free[(td.rank, cls)], t) + dur
            egress_free[(td.rank, cls)] = e0
            begin = e0 - dur
            comm_busy_intervals.append((begin, begin + dur))
            link_busy[cls] += dur
        end = begin + dur
        key = (td.rank, td.queue_type)
        busy[key] += dur
        if td.queue_type == CTQ:
            cube_busy_intervals.append((begin, end))
        ph = _phase_of(td)
        phase_busy[ph] += dur
        if ph == "dispatch":
            d2c[0] = begin if d2c[0] is None else min(d2c[0], begin)
        elif ph == "combine":
            d2c[1] = end if d2c[1] is None else max(d2c[1], end)
        fr = td.meta.get("fragment")
        if fr is not None:
            lo, hi = frag_span.get(fr, (begin, end))
            frag_span[fr] = (min(lo, begin), max(hi, end))
        ps = td.meta.get("pp_stage")
        if ps is not None:
            cell = (ps, td.meta.get("pp_microbatch", 0))
            lo, hi = stage_span.get(cell, (begin, end))
            stage_span[cell] = (min(lo, begin), max(hi, end))
            stage_phase[cell][ph] += dur
        timeline.append((begin, end, td.rank, td.queue_type, td.op_name))
        push(end, "finish", tid)

    for key in s.queues:
        try_fetch(key, 0.0)

    while heap:
        now, _, kind, tid = heapq.heappop(heap)
        td = s.tasks[tid]
        if kind == "start":
            start_task(tid, now)
        else:  # finish
            _touch_outputs(td, l2s)
            done += 1
            key = (td.rank, td.queue_type)
            idle[key] += 1
            if fragment_barrier:
                f = frag_of(td)
                frag_done[f] += 1
                while (open_frag in frag_total
                       and frag_done[open_frag] >= frag_total[open_frag]):
                    open_frag += 1
                    for w in barrier_waiters.pop(open_frag, []):
                        admit(w, now)
            elif stage_barrier:
                f = frag_of(td)
                frag_done[f] += 1
                if frag_done[f] >= frag_total[f]:
                    for wf in [w for w in stage_waiters if cell_ready(w)]:
                        for w in stage_waiters.pop(wf):
                            admit(w, now)
            if td.trigger_event != NO_EVENT:
                eid = td.trigger_event
                counters[eid] += 1
                thr = s.events[eid].threshold
                if counters[eid] >= thr and waiters[eid]:
                    for w in waiters.pop(eid):
                        push(dispatch_at(now, s.tasks[w].rank), "start", w)
            try_fetch(key, now)

    if done != s.n_tasks:
        raise ScheduleError(f"simulator deadlock: {done}/{s.n_tasks}")

    makespan = max((e for (_, e, *_ ) in timeline), default=0.0)
    n_cube_pools = len([k for k in s.queues if k[1] == CTQ])
    cube_busy = sum(v for k, v in busy.items() if k[1] == CTQ)
    mac_ratio = (cube_busy / (makespan * max(1, n_cube_pools) * hw.num_aic)
                 if makespan else 0.0)
    exposed = _exposed_time(comm_busy_intervals, cube_busy_intervals)
    # Straggler is over the whole EP group: a rank with zero tasks (fully
    # starved by the plan) must drag the mean down, not vanish from it.
    straggler, crit = _straggler(busy, range(s.ep))
    d2c_us = (d2c[1] - d2c[0]
              if d2c[0] is not None and d2c[1] is not None else makespan)
    return SimResult(makespan_us=makespan, busy_us=dict(busy),
                     mac_ratio=mac_ratio, exposed_comm_us=exposed,
                     l2_hits=l2_stats[0], l2_lookups=l2_stats[1],
                     timeline=timeline, straggler_ratio=straggler,
                     critical_rank=crit, phase_us=dict(phase_busy),
                     dispatch_to_combine_us=d2c_us,
                     fragment_makespan_us={f: hi - lo for f, (lo, hi)
                                           in sorted(frag_span.items())},
                     stage_span_us={c: hi - lo for c, (lo, hi)
                                    in sorted(stage_span.items())},
                     stage_phase_us={c: dict(v) for c, v
                                     in sorted(stage_phase.items())},
                     link_us=dict(link_busy))


def _straggler(busy: dict, ranks) -> tuple[float, int]:
    """(max/mean per-rank cube busy, most-loaded rank) over the EP group."""
    per_rank = {r: busy.get((r, CTQ), 0.0) for r in ranks}
    if not per_rank:
        return 1.0, -1
    mean = sum(per_rank.values()) / len(per_rank)
    crit = max(per_rank, key=per_rank.get)
    return (per_rank[crit] / mean if mean > 0 else 1.0), crit


def _merge(intervals):
    out = []
    for s0, e0 in sorted(intervals):
        if out and s0 <= out[-1][1]:
            out[-1][1] = max(out[-1][1], e0)
        else:
            out.append([s0, e0])
    return out


def _exposed_time(comm, cube) -> float:
    """Comm-in-flight time not covered by any cube activity."""
    comm_m, cube_m = _merge(comm), _merge(cube)
    exposed = 0.0
    j = 0
    for cs, ce in comm_m:
        t = cs
        while t < ce:
            while j < len(cube_m) and cube_m[j][1] <= t:
                j += 1
            if j >= len(cube_m) or cube_m[j][0] >= ce:
                exposed += ce - t
                break
            if cube_m[j][0] > t:
                exposed += cube_m[j][0] - t
            t = cube_m[j][1]
    return exposed


def simulate_baseline(s: Schedule, hw: AscendA3 = AscendA3(), *,
                      cost: CostModel | None = None) -> SimResult:
    """Operator-by-operator execution with collective comm (§2.3 profile).

    Ops run as full-device kernels in topological order; AllToAll is a
    host-synchronized collective across the whole EP group; AIC and AIV
    alternate (a kernel owns the device). GMM tiles use the *same* per-tile
    efficiency (the shared ``CostModel``) as the unified mode.
    """
    cost = cost or CostModel(hw=hw)
    # Group tasks by operator in schedule (≙ topological) order.
    op_order: list[str] = []
    op_tasks: dict[str, list[TaskDescriptor]] = defaultdict(list)
    for td in s.tasks:
        if td.op_name not in op_tasks:
            op_order.append(td.op_name)
        op_tasks[td.op_name].append(td)

    # Collapse per-rank op instances into phases by op kind (Dispatch@0..N
    # form one collective phase; GMM1@0..N one kernel phase, etc.).
    phase_order: list[str] = []
    phases: dict[str, list[TaskDescriptor]] = defaultdict(list)
    for name in op_order:
        kind = name.split("@")[0]
        if kind not in phases:
            phase_order.append(kind)
        phases[kind].extend(op_tasks[name])

    ranks = sorted({r for (r, _) in s.queues})
    l2s = {r: _L2(hw.l2_bytes) for r in ranks}
    l2_stats = [0, 0]

    def count_l2(hit):
        l2_stats[0] += int(hit)
        l2_stats[1] += 1

    now = 0.0
    busy: dict = defaultdict(float)
    timeline = []
    comm_iv, cube_iv = [], []
    phase_busy: dict = defaultdict(float)
    d2c = [None, None]
    for kind in phase_order:
        tds = phases[kind]
        ph = _phase_of(tds[0])
        is_comm = tds[0].task_type == "put_mem_signal"
        if is_comm:
            # Host-synchronized collective AllToAllV. Unlike one-sided
            # put_mem_signal (which scatters directly into the remote
            # layout), A2AV needs contiguous send buffers: an AIV pack pass
            # before the collective and an unpack pass after it, both on the
            # critical path. Link time is bounded by the busiest rank.
            per_rank_bytes = defaultdict(float)
            total_rank_bytes = defaultdict(float)
            for td in tds:
                total_rank_bytes[td.src_rank] += td.comm_bytes
                if td.dst_rank != td.src_rank:
                    per_rank_bytes[td.src_rank] += td.comm_bytes
            link_t = (max(per_rank_bytes.values(), default=0.0)
                      / (hw.link_gbps * 1e3))
            pack_bytes = max(total_rank_bytes.values(), default=0.0)
            # pack on source + unpack on destination: streaming copies that
            # ride the L2 (read bw ≈ l2_read_x_hbm × HBM), one pass each.
            l2_bw = hw.l2_read_x_hbm * hw.hbm_gbps * 1e3
            pack_t = 2 * (2 * pack_bytes) / l2_bw
            dur = pack_t + link_t + hw.collective_host_us
            timeline.append((now, now + dur, -1, "COLL", kind))
            comm_iv.append((now + pack_t / 2, now + pack_t / 2 + link_t))
            phase_busy[ph] += dur
            if ph == "dispatch":
                d2c[0] = now if d2c[0] is None else min(d2c[0], now)
            elif ph == "combine":
                d2c[1] = (now + dur if d2c[1] is None
                          else max(d2c[1], now + dur))
            now += dur + hw.kernel_launch_us
            continue
        # Full-device kernel phase. Production operators balance their own
        # internal tiling across the pool, so the phase is work-conserving:
        # duration = total unit-time / pool width (not our tile packing).
        pool_n = hw.num_aic if tds[0].queue_type == CTQ else hw.num_aiv
        phase_end = now
        for r in ranks:
            mine = [td for td in tds if td.rank == r]
            work = 0.0
            for td in mine:
                dur = _task_duration_us(td, cost, l2s[r], count_l2)
                work += dur
                busy[(r, td.queue_type)] += dur
                _touch_outputs(td, l2s)
            rank_end = now + work / pool_n
            if mine and mine[0].queue_type == CTQ:
                cube_iv.append((now, rank_end))
            phase_end = max(phase_end, rank_end)
        timeline.append((now, phase_end, -1, tds[0].queue_type, kind))
        phase_busy[ph] += phase_end - now
        now = phase_end + hw.kernel_launch_us

    makespan = now - hw.kernel_launch_us
    cube_busy = sum(v for k, v in busy.items() if k[1] == CTQ)
    mac_ratio = cube_busy / (makespan * len(ranks) * hw.num_aic)
    straggler, crit = _straggler(busy, range(s.ep))
    d2c_us = (d2c[1] - d2c[0]
              if d2c[0] is not None and d2c[1] is not None else makespan)
    return SimResult(makespan_us=makespan, busy_us=dict(busy),
                     mac_ratio=mac_ratio,
                     exposed_comm_us=_exposed_time(comm_iv, cube_iv),
                     l2_hits=l2_stats[0], l2_lookups=l2_stats[1],
                     timeline=timeline, straggler_ratio=straggler,
                     critical_rank=crit, phase_us=dict(phase_busy),
                     dispatch_to_combine_us=d2c_us)
