"""Split propagation over the ODG — a faithful port of Algorithm 1 (§4.2).

Split labels (``split_dim``, ``split_num``) live on tensors shared by
producer outputs and consumer inputs. The traversal walks the graph in
topological order; an operator generates partitioned tile tasks only when
every required input already carries the expected partition label, and
otherwise *falls back to one unsplit task* — preserving semantic correctness
at the cost of parallelism, exactly as the paper specifies.

Task counts are *plan-aware*: a node's ``task_num_fn`` takes (config,
operator), so the count reflects the nonzero cells of that rank's
:class:`~repro.core.routing.RoutingPlan` rather than a fixed ``ep × e_loc``
grid. A rank with no routed rows legally gets zero tasks. Under
``gmm_split_mode="source_aligned"`` the counts come from source-cell-aligned
chunk grouping (``RoutingPlan.gmm_tiles``), which keeps the propagated
boundaries legal for arbitrarily imbalanced plans.
"""

from __future__ import annotations

from .odg import ODG, OperatorNode


def propagate_splits(g: ODG) -> None:
    """Run Algorithm 1 in place: fills ``op.task_num`` and tensor labels."""
    c = g.cfg

    # Lines 1-4: initialise split labels on every tensor.
    for t in g.tensors.values():
        t.split_dim = -1
        t.split_num = 1

    # Lines 5-25: topological traversal applying each node's SplitSpec.
    for op in g.topological():
        s = op.split_spec

        checked = s.split_inputs
        if checked is None:
            # Partitioning origin (e.g. Dispatch).
            n = s.task_num_fn(c, op)
        else:
            required = [(i, d) for (i, d) in checked
                        if i not in s.ignore_inputs]
            if all(op.inputs[i].split_dim == d for (i, d) in required):
                n = s.task_num_fn(c, op)
            else:
                n = 1  # fallback to one unsplit task

        op.task_num = n

        for j, y in enumerate(op.outputs):
            d = s.split_output_dims[j]
            if (n > 1 or s.always_label) and d >= 0:
                y.split_dim = d
                y.split_num = n          # visible to downstream inputs
            else:
                y.split_dim = -1
                y.split_num = n


def split_report(g: ODG) -> list[tuple[str, int]]:
    """(op name, task_num) for every operator — handy for tests/logging."""
    return [(op.name, op.task_num) for op in g.ops]
