"""Compile-time per-task cost model — one timing formula for the whole stack.

The discrete-event simulator (``core/simulator.py``) and the schedule-pass
pipeline (``core/passes.py``) both need to price a :class:`TaskDescriptor`:
the simulator to advance its clocks, the passes to make placement and
ordering decisions *at compile time* (Hexa-MoE-style: heterogeneity-aware
cost estimates drive decisions before any simulation runs). Keeping one
``CostModel`` here is what guarantees the two never disagree — the simulator
owns the L2 *state* (which tiles are resident) but delegates every duration
to :meth:`CostModel.task_us`.

The L2-residency term is optional: passes that run before any execution
order exists have no residency information, so they price tasks with
``CostModel(l2=False)`` — the HBM-streaming lower bound. The simulator keeps
``l2=True`` and supplies the hit fraction it observes.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

from .hardware import AscendA3, Topology
from .odg import CTQ


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Prices one tile task on its execution unit (excl. queue overhead)."""

    hw: AscendA3 = AscendA3()
    # Model operand L2 residency. When False, the ``l2_hit_frac`` argument is
    # ignored and every input streams from HBM — the deterministic estimate
    # compile-time passes use.
    l2: bool = True
    # Optional cluster topology: remote transfers are then priced per link
    # class (intra-node vs inter-node bandwidth and latency) instead of the
    # flat ``hw.link_gbps`` / ``hw.hop_latency_us``.
    topology: Optional[Topology] = None
    # Observed per-rank slowdown factors (mean ≈ 1.0), fed back from the
    # training loop's straggler watchdog (``ft.runner`` records per-rank
    # step-time EWMAs; ``core.elastic.observed_cost_model`` normalizes them
    # into this tuple). Every task executing on rank ``r`` is priced
    # ``rank_bias[r]×`` slower, so a persistently slow rank becomes the
    # compile-time critical rank that ``critical_rank_first`` and
    # ``autoselect`` schedule around. A tuple (not a list) so the model
    # stays frozen/hashable — it is part of the selector's memo key.
    rank_bias: Optional[tuple] = None

    def _bias(self, rank: int) -> float:
        if self.rank_bias is None or not 0 <= rank < len(self.rank_bias):
            return 1.0
        return self.rank_bias[rank]

    def link_class_of(self, td) -> str:
        """Link class of a put task: local / intra / inter, or the flat
        ``"link"`` when no topology is attached (incl. multi-dst fallback
        tasks, whose destinations are unknown). ``StageBoundary`` tiles
        always ride the pipeline-stage link — the topology's inter-node
        class — regardless of rank indices (the downstream stage is a
        different device that happens to share the EP rank index)."""
        if td.task_type == "StageBoundary":
            return "inter" if self.topology is not None else "link"
        if td.dst_rank == td.src_rank:
            return "local"
        if self.topology is None or td.dst_rank < 0:
            return "link"
        return self.topology.link_class(td.src_rank, td.dst_rank)

    def task_us(self, td, l2_hit_frac: float = 0.0) -> float:
        """Execution time of one TD in microseconds.

        ``l2_hit_frac`` is the row-weighted fraction of the task's inputs
        resident in L2 (supplied by the simulator's LRU model; 0.0 for
        compile-time estimates). With ``rank_bias`` set, the result scales
        by the executing rank's observed slowdown factor.
        """
        return self._bias(td.rank) * self._task_us_unbiased(td, l2_hit_frac)

    def _task_us_unbiased(self, td, l2_hit_frac: float = 0.0) -> float:
        hw = self.hw
        frac = l2_hit_frac if self.l2 else 0.0
        if td.task_type == "put_mem_signal":
            t = 0.0
            if td.meta.get("compress"):
                # Quantize at the sender + dequantize at the receiver:
                # two L2-resident streaming passes over the full-precision
                # payload. ``comm_bytes`` already reflects the wire size.
                t += ((td.read_bytes + td.write_bytes)
                      / (hw.l2_read_x_hbm * hw.hbm_gbps * 1e3))
            cls = self.link_class_of(td)
            if cls == "local":
                # Rank-local "transfer" is an HBM copy, not link traffic.
                return t + td.comm_bytes / (hw.hbm_gbps * 1e3)
            if cls == "link":
                return (t + hw.hop_latency_us
                        + td.comm_bytes / (hw.link_gbps * 1e3))
            topo = self.topology
            return (t + topo.latency_us(cls)
                    + td.comm_bytes / (topo.bw_gbps(cls) * 1e3))
        if td.task_type == "StageBoundary":
            # PP activation handoff: the payload crosses the stage link.
            # No L2 term — the tile is link-bound, not bandwidth-from-HBM
            # bound, and no ``local`` case: the downstream stage is always
            # a different device.
            cls = self.link_class_of(td)
            if cls == "link":
                return (hw.hop_latency_us
                        + td.comm_bytes / (hw.link_gbps * 1e3))
            topo = self.topology
            return (topo.latency_us(cls)
                    + td.comm_bytes / (topo.bw_gbps(cls) * 1e3))
        if td.queue_type == CTQ:
            # Per-tile GMM efficiency depends on operand L2 residency — the
            # mechanism cache-guided interleaving exploits (§4.5).
            eff_util = (hw.aic_eff_hbm
                        + (hw.aic_eff_l2 - hw.aic_eff_hbm) * frac)
            eff = hw.aic_tflops_bf16 * 1e12 * eff_util
            return td.flops / eff * 1e6
        # Vector task: read bandwidth depends on L2 residency of inputs.
        rb = td.read_bytes
        hit_bytes = rb * frac
        miss_bytes = rb - hit_bytes
        eff_bytes = (miss_bytes + hit_bytes / hw.l2_read_x_hbm
                     + td.write_bytes)
        return eff_bytes / (hw.aiv_gbps * 1e3)

    # -- schedule-level aggregates (compile-time skew diagnostics) -----------

    def rank_cube_us(self, sched) -> dict[int, float]:
        """Total estimated CTQ (cube) time per rank over the full EP group.

        Every rank of ``sched.ep`` appears, including ranks the plan starved
        of work — they must drag the mean down, exactly as the simulator's
        ``straggler_ratio`` counts them.
        """
        loads: dict[int, float] = defaultdict(float)
        for td in sched.tasks:
            if td.queue_type == CTQ:
                loads[td.rank] += self.task_us(td)
        return {r: loads.get(r, 0.0) for r in range(sched.ep)}

    def critical_rank(self, sched) -> tuple[float, int]:
        """(max/mean cube load, most-loaded rank) — the compile-time analogue
        of ``SimResult.straggler_ratio``/``critical_rank``."""
        loads = self.rank_cube_us(sched)
        if not loads:
            return 1.0, -1
        mean = sum(loads.values()) / len(loads)
        crit = max(loads, key=loads.get)
        return (loads[crit] / mean if mean > 0 else 1.0), crit

    # -- multi-fragment aggregates (fused schedules, core/fusion.py) ---------

    def fragment_rank_cube_us(self, sched) -> dict[int, dict[int, float]]:
        """Per-fragment cube load: {fragment index: {rank: us}}.

        Fragments are identified by ``meta["fragment"]`` (0 for every task
        of an unfused schedule, so this degenerates to one entry equal to
        :meth:`rank_cube_us`).
        """
        loads: dict[int, dict[int, float]] = defaultdict(
            lambda: defaultdict(float))
        frags: set[int] = set()
        for td in sched.tasks:
            f = td.meta.get("fragment", 0)
            frags.add(f)
            if td.queue_type == CTQ:
                loads[f][td.rank] += self.task_us(td)
        return {f: {r: loads[f].get(r, 0.0) for r in range(sched.ep)}
                for f in sorted(frags)}

    def pp_bubble_us(self, sched) -> float:
        """Compile-time 1F1B bubble estimate of a PP-fused schedule.

        The warm-up + cool-down idle of a synchronous pipeline is
        ``(n_stages - 1)`` slots of the bottleneck cell's pool-bound time —
        exactly the gap StageBoundary handoffs and EP dispatch/combine can
        be absorbed into. Cells are identified by ``pp_stage`` /
        ``pp_microbatch`` task metadata; returns 0.0 for schedules without
        it. Pool-bound: a cell's cube work spreads over ``num_aic`` cores
        and its vector work over ``num_aiv``, so the slot time is the
        slower pool, not the serial task sum.
        """
        cells: dict[tuple[int, int], list[float]] = defaultdict(
            lambda: [0.0, 0.0])
        for td in sched.tasks:
            s = td.meta.get("pp_stage")
            if s is None:
                continue
            c = cells[(s, td.meta.get("pp_microbatch", 0))]
            if td.queue_type == CTQ:
                c[0] += self.task_us(td)
            elif td.task_type not in ("put_mem_signal", "StageBoundary"):
                c[1] += self.task_us(td)
        if not cells:
            return 0.0
        hw = self.hw
        n_stages = len({s for (s, _) in cells})
        slot = max(max(cube / hw.num_aic, vec / hw.num_aiv)
                   for cube, vec in cells.values())
        return (n_stages - 1) * slot

    def fragment_critical_ranks(self, sched) -> dict[int, tuple[float, int]]:
        """Per-fragment (straggler ratio, critical rank) — each fused
        fragment carries its own plan, so its straggler is its own."""
        out: dict[int, tuple[float, int]] = {}
        for f, loads in self.fragment_rank_cube_us(sched).items():
            if not loads:
                out[f] = (1.0, -1)
                continue
            mean = sum(loads.values()) / len(loads)
            crit = max(loads, key=loads.get)
            out[f] = ((loads[crit] / mean if mean > 0 else 1.0), crit)
        return out
