"""Static Schedule Configuration (SSC) — the compilation/runtime boundary.

SSC is the serialized execution plan a rank's unified runtime consumes:
CTQ/VTQ task sequences, TD metadata, dependency events, and thresholds
(§3, §5.1). For a fixed shape bucket, EP size, and rank the SSC is compiled
once and reused across training steps; each step supplies only fresh tensor
pointers and zeroed event-counter state.

We serialize with msgpack (binary, runtime) and expose a JSON debug dump.
The blob records the schedule-pass pipeline spec that produced it
(``Schedule.opts["pipeline"]``), so a deserialized schedule knows exactly
which passes shaped its queues. An in-process :class:`SSCCache` keyed by
shape bucket × pipeline mirrors the paper's "reuse SSC for stable shapes or
shape buckets" behaviour (Table 2), with LRU eviction bounding it.

Cache keying and bucketing semantics
------------------------------------

:meth:`SSCCache.key` identifies a compiled schedule by::

    (ep, e_loc, d_model, d_ff, dtype_bytes,
     gmm_m_split, gmm_split_mode,
     cfg.routing.counts,          # the full per-(src, dst, expert) matrix
     cfg.bucket @ ep,             # BucketSpec.key() tagged for_mesh(ep), or None
     cfg.topology.key(),          # cluster link shape (or None = flat links)
     cfg.dispatch_mode, cfg.xnode_compress,
     direction, pipeline.key())

Three properties follow:

* **Resolved-``auto`` keying.** ``pipeline="auto"`` resolves through the
  cost-model-guided selector (``core/autoselect.py``) *before* keying: the
  key is built from the resolved pipeline spec and the (possibly re-tiled)
  resolved config, never the literal ``"auto"``. An ``"auto"`` request and
  the equivalent explicit request share one entry, and every cached blob
  stays addressable by the spec that actually compiled it.

* **Effective-routing keying.** The key uses ``cfg.routing`` — the plan
  that actually drives extents — so a ``ScheduleConfig(rows=r)`` balanced
  grid and an explicit ``RoutingPlan.balanced(ep, e_loc, r)`` share one
  entry, while any genuinely different per-cell count matrix compiles (and
  caches) a fresh SSC. Legacy boolean kwargs (``ratr=`` …) and the
  equivalent ``pipeline=`` spec normalize to the same canonical pipeline
  and share one entry.

* **Bucketed-plan keys.** The dropless training path never inserts exact
  per-batch plans directly:
  ``models.moe.plan_from_routing(bucket=BucketSpec...)`` quantizes each
  nonzero cell count up to its policy bucket — ``linear(rows)`` (the legacy
  ``bucket_rows`` int shim, key-identical by construction),
  ``geometric(base)``, or a fitted ``ladder(edges)`` (see
  ``repro.core.buckets``) — *before* the plan reaches the cache, so every
  batch whose counts land in the same buckets maps to the same
  ``cfg.routing.counts`` tuple — one key, one compile. ``cfg.bucket``
  carries the spec's canonical ``key()`` tuple into the cache key (so two
  policies that happen to map one batch to the same counts still never
  alias) and ``get_or_compile`` records it in ``Schedule.opts["bucket"]``
  / the blob for provenance. Padding rows are zero-filled in the
  executor's send buffers and provably do not change results (zeros
  propagate through GMM/SwiGLU and are never gathered by Combine). Exact
  plans (``bucket_rows=1`` / ``BucketSpec.exact()``) key every distinct
  routing as a miss — the recompile-rate baseline ``bench_dropless``
  measures.

``info()`` reports cumulative ``hits``/``misses``/``evictions`` plus
occupancy; ``step_stats()`` returns the *deltas* since its previous call —
the per-training-step recompile counters the dropless step surfaces in its
metrics dict. Consumers that bucket plans additionally report the rows
they padded (``record_rows``): ``info()``/``step_stats()`` then carry a
cumulative / per-step ``pad_ratio`` (bucketed plan rows / exact routed
rows, 1.0 = no padding), so bucket policies are comparable straight from
the ``ssc_*`` train metrics next to the hit/miss counters they trade
against.
"""

from __future__ import annotations

import dataclasses
import json
from collections import OrderedDict
from typing import Optional

import msgpack

from .odg import ScheduleConfig
from .passes import resolve_pipeline
from .scheduler import Event, Schedule
from .tasks import Range, TaskDescriptor


def _td_to_dict(td: TaskDescriptor) -> dict:
    d = dataclasses.asdict(td)
    d["inputs"] = [dataclasses.asdict(r) for r in td.inputs]
    d["outputs"] = [dataclasses.asdict(r) for r in td.outputs]
    return d


def _td_from_dict(d: dict) -> TaskDescriptor:
    d = dict(d)
    d["inputs"] = [Range(**r) for r in d["inputs"]]
    d["outputs"] = [Range(**r) for r in d["outputs"]]
    return TaskDescriptor(**d)


def schedule_to_ssc(s: Schedule) -> bytes:
    """Serialize a full (all-rank) schedule.

    Multi-fragment schedules (``core/fusion.FusedSchedule``) additionally
    carry their fragment table; the payload stays version 1 — readers
    without fusion support would still decode a valid plain Schedule.
    """
    payload = {
        "version": 1,
        "direction": s.direction,
        "ep": s.ep,
        "opts": s.opts,
        "tasks": [_td_to_dict(td) for td in s.tasks],
        "events": {str(e.eid): {"threshold": e.threshold,
                                "home_rank": e.home_rank,
                                "producers": list(e.producers)}
                   for e in s.events.values()},
        "queues": [{"rank": r, "qtype": q, "tids": tids}
                   for (r, q), tids in sorted(s.queues.items())],
    }
    fragments = getattr(s, "fragments", None)
    if fragments:
        payload["fragments"] = [
            {"index": f.index, "label": f.label, "tid_lo": f.tid_lo,
             "tid_hi": f.tid_hi, "boundary_tids": list(f.boundary_tids)}
            for f in fragments]
    return msgpack.packb(payload, use_bin_type=True)


def ssc_to_schedule(blob: bytes) -> Schedule:
    p = msgpack.unpackb(blob, raw=False)
    tasks = [_td_from_dict(d) for d in p["tasks"]]
    events = {int(k): Event(eid=int(k), threshold=v["threshold"],
                            home_rank=v["home_rank"],
                            producers=tuple(v["producers"]))
              for k, v in p["events"].items()}
    queues = {(e["rank"], e["qtype"]): list(e["tids"]) for e in p["queues"]}
    if p.get("fragments"):
        from .fusion import Fragment, FusedSchedule   # lazy: avoid cycle
        frags = tuple(Fragment(index=f["index"], label=f["label"],
                               tid_lo=f["tid_lo"], tid_hi=f["tid_hi"],
                               boundary_tids=tuple(f["boundary_tids"]))
                      for f in p["fragments"])
        return FusedSchedule(direction=p["direction"], ep=p["ep"],
                             tasks=tasks, events=events, queues=queues,
                             opts=p.get("opts", {}), fragments=frags)
    return Schedule(direction=p["direction"], ep=p["ep"], tasks=tasks,
                    events=events, queues=queues, opts=p.get("opts", {}))


def rank_view(s: Schedule, rank: int) -> dict:
    """The per-rank slice a device runtime would receive (debug/JSON)."""
    tids = set(s.queue(rank, "CTQ")) | set(s.queue(rank, "VTQ"))
    return {
        "rank": rank,
        "ctq": [_td_to_dict(s.tasks[t]) for t in s.queue(rank, "CTQ")],
        "vtq": [_td_to_dict(s.tasks[t]) for t in s.queue(rank, "VTQ")],
        "events": {e.eid: e.threshold for e in s.events.values()
                   if e.home_rank == rank
                   or any(p in tids for p in e.producers)},
    }


def dump_json(s: Schedule, path: str) -> None:
    with open(path, "w") as f:
        json.dump([rank_view(s, r) for r in range(s.ep)], f, indent=1)


class SSCCache:
    """LRU cache of compiled SSCs keyed by shape bucket + pass pipeline
    (paper §5.1).

    ``max_entries`` bounds the cache — the dropless per-batch-plan direction
    compiles one SSC per distinct RoutingPlan, so unbounded growth is a
    production blocker. Least-recently-used blobs are evicted; ``info()``
    reports occupancy and hit/miss/eviction counters.

    Schedules are requested either with ``pipeline=`` (a Pipeline, a pass
    name list, or a serialized spec) or with the legacy boolean kwargs
    (``ratr=`` …); both normalize to the same canonical pipeline and share
    one cache entry.
    """

    def __init__(self, max_entries: int = 64):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self._cache: OrderedDict[tuple, bytes] = OrderedDict()
        # Fragment count per cached blob (parallel to _cache, which stays a
        # plain key -> bytes map — debug consumers index it directly).
        self._frags: dict[tuple, int] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # Elastic bookkeeping: rekey_for_mesh calls survived, and the mesh
        # size whose entries currently get LRU priority (None = never
        # rescaled — a fixed-mesh run).
        self.rekeyed = 0
        self.active_ep: Optional[int] = None
        # Online-tuning bookkeeping: the bucket-spec key whose entries
        # currently get LRU priority (None = no hot-swap ever happened).
        # Stored untagged (no ("ep", n) suffix) — a swap applies to every
        # mesh size's population of that policy.
        self.active_bucket: Optional[tuple] = None
        # Padded-vs-exact row accounting (reported by bucketing consumers
        # via record_rows; the cache only ever sees bucketed plans, so it
        # cannot derive the exact rows itself).
        self.exact_rows = 0
        self.padded_rows = 0
        self._step_snapshot = (0, 0, 0, 0, 0)

    @staticmethod
    def _resolve(cfg: ScheduleConfig, direction: str, pipeline,
                 opts: dict) -> tuple[ScheduleConfig, "object"]:
        """Normalize (config, pipeline) — including ``pipeline="auto"``.

        ``"auto"`` resolves through the cost-model-guided selector with the
        full ``gmm_m_split`` budget grid: the returned config may carry a
        re-tiled ``gmm_m_split``/``gmm_split_mode``, and the returned
        pipeline is the resolved spec. Resolution is deterministic and
        memoized, so an ``"auto"`` request and the equivalent explicit
        request produce the same key — one cache entry (cache-hit parity).
        """
        from .autoselect import auto_pipeline, is_auto
        if is_auto(pipeline):
            pipe, cfg = auto_pipeline(None, cfg, direction=direction)
            return cfg, pipe
        return cfg, resolve_pipeline(pipeline, **opts)

    @staticmethod
    def key(cfg: ScheduleConfig, direction: str, pipeline=None,
            **opts) -> tuple:
        # Key on the effective routing (cfg.routing), so an explicit
        # balanced plan and the equivalent scalar-rows config share one
        # entry; a fresh imbalanced router output compiles a fresh SSC.
        # ``pipeline="auto"`` is keyed by its *resolved* (config, spec) —
        # cached schedules stay byte-addressable by what actually compiled.
        cfg, pipe = SSCCache._resolve(cfg, direction, pipeline, opts)
        # Topology key + dispatch mode + compression: two-level dispatch
        # emits a different task structure (and the aggregation threshold
        # depends on the link parameters), so schedules compiled under
        # different cluster shapes must never alias.
        topo = cfg.topology.key() if cfg.topology is not None else None
        bucket = cfg.bucket
        if bucket is not None:
            # Bucket ladders are per-mesh-size populations (plan cells are
            # [ep, ep, e_loc]); the key carries the spec tagged to this
            # config's mesh so rekey_for_mesh can migrate populations
            # without guessing which mesh an entry belonged to.
            from .buckets import BucketSpec
            bucket = BucketSpec.from_any(bucket).for_mesh(cfg.ep).key()
        return (cfg.ep, cfg.e_loc, cfg.d_model, cfg.d_ff, cfg.dtype_bytes,
                cfg.gmm_m_split, cfg.gmm_split_mode, cfg.routing.counts,
                bucket, topo, cfg.dispatch_mode, cfg.xnode_compress,
                direction, pipe.key())

    def get_or_compile(self, cfg: ScheduleConfig, direction: str,
                       pipeline=None, **opts) -> Schedule:
        from .odg import build_moe_ffn_backward, build_moe_ffn_forward
        from .scheduler import compile_schedule
        cfg, pipe = self._resolve(cfg, direction, pipeline, opts)
        k = self.key(cfg, direction, pipeline=pipe)
        blob = self._cache.get(k)
        if blob is None:
            self.misses += 1
            builder = (build_moe_ffn_forward if direction == "forward"
                       else build_moe_ffn_backward)
            sched = compile_schedule(builder(cfg), pipeline=pipe)
            if cfg.bucket is not None:
                # Provenance: the blob records which quantization policy
                # shaped its plan, next to the pipeline spec that shaped
                # its queues (msgpack-safe list form of BucketSpec.key()).
                from .buckets import BucketSpec
                sched.opts["bucket"] = BucketSpec.from_any(cfg.bucket).spec()
            blob = schedule_to_ssc(sched)
            self._insert(k, blob, fragments=1)
        else:
            self.hits += 1
            self._cache.move_to_end(k)
        return ssc_to_schedule(blob)

    def _insert(self, k: tuple, blob: bytes, fragments: int) -> None:
        self._cache[k] = blob
        self._frags[k] = fragments
        while len(self._cache) > self.max_entries:
            ek, _ = self._cache.popitem(last=False)
            self._frags.pop(ek, None)
            self.evictions += 1

    # -- elastic re-keying (core/elastic.py rescale path) --------------------

    @staticmethod
    def _key_ep(k: tuple) -> int:
        """Mesh size a resident key was compiled for (fused keys carry it
        in their per-layer key tuple)."""
        if k and k[0] == "fused":
            layers = k[4]
            return layers[0][0] if layers else -1
        return k[0]

    @staticmethod
    def _tag_bucket(k: tuple) -> tuple:
        """One plain key with a legacy untagged bucket field retagged to
        the key's own mesh size (no-op for tagged or bucket-less keys)."""
        b = k[8]
        if b is None or (isinstance(b[-1], tuple) and len(b[-1]) == 2
                         and b[-1][0] == "ep"):
            return k
        return k[:8] + (b + (("ep", k[0]),),) + k[9:]

    @classmethod
    def _retag_key(cls, k: tuple) -> tuple:
        if k and k[0] == "fused":
            return (k[:4] + (tuple(cls._tag_bucket(lk) for lk in k[4]),)
                    + k[5:])
        return cls._tag_bucket(k)

    def rekey_for_mesh(self, new_ep: int) -> dict:
        """Re-key — never flush — the resident population for a new mesh.

        Rank loss does not invalidate compiled schedules: an old-mesh blob
        stays bit-correct should the mesh grow back, and the new mesh's
        population fills through the normal ``get_or_compile`` path (whose
        keys lead with ``cfg.ep`` and carry ``ep``-tagged bucket specs, so
        mesh populations never alias). This method (1) retags any legacy
        untagged bucket fields in resident keys with their own mesh size,
        (2) boosts the ``new_ep`` population to the MRU end — stale-mesh
        entries bear the LRU eviction pressure first — and (3) records
        ``active_ep`` so ``info()`` reports occupancy per mesh.

        Returns ``{"entries", "active", "stale", "retagged"}`` counts.
        """
        if new_ep < 1:
            raise ValueError(f"new_ep must be >= 1, got {new_ep}")
        retagged = 0
        items = []
        for k, blob in list(self._cache.items()):
            nk = self._retag_key(k)
            if nk != k:
                retagged += 1
                self._frags[nk] = self._frags.pop(k, 1)
            items.append((nk, blob))
        self._cache = OrderedDict(items)
        # MRU-boost the new mesh's entries in their existing relative order.
        for k in [k for k in self._cache if self._key_ep(k) == new_ep]:
            self._cache.move_to_end(k)
        self.active_ep = int(new_ep)
        self.rekeyed += 1
        active = sum(1 for k in self._cache if self._key_ep(k) == new_ep)
        return {"entries": len(self._cache), "active": active,
                "stale": len(self._cache) - active, "retagged": retagged}

    # -- online bucket hot-swap (launch/online.py serving path) --------------

    @staticmethod
    def _untag_bucket_key(b) -> Optional[tuple]:
        """A key's bucket field with any trailing ``("ep", n)`` tag removed
        (the canonical policy identity, mesh-size independent)."""
        if b is None:
            return None
        b = tuple(b)
        if b and isinstance(b[-1], tuple) and len(b[-1]) == 2 \
                and b[-1][0] == "ep":
            return b[:-1]
        return b

    @classmethod
    def _key_bucket(cls, k: tuple) -> Optional[tuple]:
        """Untagged bucket policy a resident key was quantized with (fused
        keys report their first layer's — layers share a policy today)."""
        if k and k[0] == "fused":
            layers = k[4]
            return cls._untag_bucket_key(layers[0][8]) if layers else None
        return cls._untag_bucket_key(k[8])

    def rekey_for_bucket(self, spec) -> dict:
        """Hot-swap the active bucket policy — re-key, never flush.

        The serving-path twin of :meth:`rekey_for_mesh`: when the online
        tuner (``launch/online.py``) swaps the serving ``BucketSpec``, the
        incumbent policy's compiled schedules stay bit-correct (quantization
        only shapes plan *counts*; padding rows are provably inert) and the
        ladder may swap back, so nothing is invalidated. This method
        (1) boosts the new policy's resident entries to the MRU end —
        stale-policy entries bear the LRU eviction pressure first — and
        (2) records ``active_bucket`` so ``info()`` reports occupancy per
        policy. The new policy's population then fills through the normal
        ``get_or_compile`` path (``cfg.bucket`` is part of the key, so
        policies never alias even when two specs quantize one batch to the
        same counts).

        Returns ``{"entries", "active", "stale"}`` counts.
        """
        from .buckets import BucketSpec
        bk = self._untag_bucket_key(BucketSpec.from_any(spec).key())
        for k in [k for k in self._cache if self._key_bucket(k) == bk]:
            self._cache.move_to_end(k)
        self.active_bucket = bk
        self.rekeyed += 1
        active = sum(1 for k in self._cache if self._key_bucket(k) == bk)
        return {"entries": len(self._cache), "active": active,
                "stale": len(self._cache) - active}

    def get_or_compile_fused(self, cfgs, direction: str, pipeline=None,
                             pipelines=None,
                             fused_pipeline=("fuse_boundary",),
                             boundary_split: Optional[int] = None,
                             **opts) -> Schedule:
        """Fused multi-layer twin of :meth:`get_or_compile`.

        ``cfgs`` are the per-layer configs in *layer* order; the cache key
        is the tuple of the per-layer keys (each resolved exactly as the
        unfused path resolves it, so per-layer ``pipeline="auto"`` works)
        plus the fused pipeline, boundary tiling, and the fusion shape
        tuple ``(boundary kind, n_stages, n_microbatches)`` — layer fusion
        is ``("layer", K, 1)``, keeping it disjoint from PP-fused blobs of
        the same plans. One multi-fragment blob per distinct plan tuple;
        ``info()`` reports its fragment count next to its byte size.
        """
        from .fusion import DEFAULT_BOUNDARY_SPLIT, compile_fused
        if boundary_split is None:
            boundary_split = DEFAULT_BOUNDARY_SPLIT
        if pipelines is None:
            pipelines = [pipeline] * len(cfgs)
        resolved = [self._resolve(c, direction, p, opts)
                    for c, p in zip(cfgs, pipelines)]
        fp = resolve_pipeline(fused_pipeline)
        k = ("fused", direction, fp.key(), boundary_split,
             tuple(self.key(c, direction, pipeline=p)
                   for (c, p) in resolved),
             ("layer", len(cfgs), 1))
        blob = self._cache.get(k)
        if blob is None:
            self.misses += 1
            fs = compile_fused([c for (c, _) in resolved], direction,
                               pipelines=[p for (_, p) in resolved],
                               fused_pipeline=fp,
                               boundary_split=boundary_split)
            blob = schedule_to_ssc(fs)
            self._insert(k, blob, fragments=len(cfgs))
        else:
            self.hits += 1
            self._cache.move_to_end(k)
        return ssc_to_schedule(blob)

    def get_or_compile_pp_fused(self, cfgs, n_microbatches: int,
                                direction: str, pipeline=None,
                                pipelines=None,
                                fused_pipeline=("pp_interleave",),
                                boundary_split: Optional[int] = None,
                                **opts) -> Schedule:
        """PP-fused twin: ``cfgs`` per *stage* (stage order), replicated
        across ``n_microbatches`` by ``compile_pp_fused``. Keys share the
        fused namespace with :meth:`get_or_compile_fused` but carry
        ``("stage", n_stages, n_microbatches)``, so the same stage plans
        at different microbatch counts (or vs layer fusion) never alias.
        """
        from .fusion import DEFAULT_BOUNDARY_SPLIT, compile_pp_fused
        if boundary_split is None:
            boundary_split = DEFAULT_BOUNDARY_SPLIT
        if pipelines is None:
            pipelines = [pipeline] * len(cfgs)
        resolved = [self._resolve(c, direction, p, opts)
                    for c, p in zip(cfgs, pipelines)]
        fp = resolve_pipeline(fused_pipeline)
        k = ("fused", direction, fp.key(), boundary_split,
             tuple(self.key(c, direction, pipeline=p)
                   for (c, p) in resolved),
             ("stage", len(cfgs), int(n_microbatches)))
        blob = self._cache.get(k)
        if blob is None:
            self.misses += 1
            fs = compile_pp_fused([c for (c, _) in resolved],
                                  n_microbatches, direction=direction,
                                  pipelines=[p for (_, p) in resolved],
                                  fused_pipeline=fp,
                                  boundary_split=boundary_split)
            blob = schedule_to_ssc(fs)
            self._insert(k, blob,
                         fragments=len(cfgs) * int(n_microbatches))
        else:
            self.hits += 1
            self._cache.move_to_end(k)
        return ssc_to_schedule(blob)

    def record_rows(self, exact_rows: int, padded_rows: int) -> None:
        """Accumulate one bucketed plan's padded-vs-exact row accounting.

        Called by consumers that quantize plans before keying (the dropless
        bridge, the replay harness): ``exact_rows`` is the batch's routed
        row count, ``padded_rows`` the bucketed plan's total rows. The
        cumulative ratio surfaces in ``info()``/``step_stats()`` so bucket
        policies are comparable straight from the ``ssc_*`` train metrics.
        """
        if padded_rows < exact_rows:
            raise ValueError(
                f"padded_rows={padded_rows} < exact_rows={exact_rows}: "
                f"bucketed plans must cover the exact plan")
        self.exact_rows += int(exact_rows)
        self.padded_rows += int(padded_rows)

    @staticmethod
    def _pad_ratio(padded: int, exact: int) -> float:
        return padded / exact if exact else 1.0

    def info(self) -> dict:
        """Occupancy + counter snapshot (for logs and capacity planning).

        ``per_entry`` itemizes each resident blob's byte size and fragment
        count (LRU order, oldest first) — multi-fragment blobs are several
        times a per-layer blob, so capacity planning needs to see them.
        """
        return {
            "entries": len(self._cache),
            "max_entries": self.max_entries,
            "bytes": sum(len(b) for b in self._cache.values()),
            "per_entry": [{"bytes": len(b),
                           "fragments": self._frags.get(k, 1)}
                          for k, b in self._cache.items()],
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "rekeyed": self.rekeyed,
            "active_ep": self.active_ep,
            "active_bucket": self.active_bucket,
            "by_ep": dict(sorted(
                (ep, sum(1 for k in self._cache if self._key_ep(k) == ep))
                for ep in {self._key_ep(k) for k in self._cache})),
            "by_bucket": {
                str(b): n for b, n in sorted(
                    ((b, sum(1 for k in self._cache
                             if self._key_bucket(k) == b))
                     for b in {self._key_bucket(k) for k in self._cache}),
                    key=lambda kv: str(kv[0]))},
            "exact_rows": self.exact_rows,
            "padded_rows": self.padded_rows,
            "pad_ratio": self._pad_ratio(self.padded_rows, self.exact_rows),
        }

    def step_stats(self) -> dict:
        """Hit/miss/eviction *deltas* since the previous call, + occupancy.

        The dropless training step calls this once per executed step to
        surface per-step recompile counts in its metrics dict; ``misses``
        is the number of schedules compiled during the step (0 on a fully
        cache-served step). ``pad_ratio`` is the padded-vs-exact row ratio
        of the plans recorded *during the step* (1.0 when none were).
        """
        cur = (self.hits, self.misses, self.evictions,
               self.exact_rows, self.padded_rows)
        last = self._step_snapshot
        self._step_snapshot = cur
        return {
            "hits": cur[0] - last[0],
            "misses": cur[1] - last[1],
            "evictions": cur[2] - last[2],
            "entries": len(self._cache),
            "pad_ratio": self._pad_ratio(cur[4] - last[4], cur[3] - last[3]),
        }
