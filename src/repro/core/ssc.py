"""Static Schedule Configuration (SSC) — the compilation/runtime boundary.

SSC is the serialized execution plan a rank's unified runtime consumes:
CTQ/VTQ task sequences, TD metadata, dependency events, and thresholds
(§3, §5.1). For a fixed shape bucket, EP size, and rank the SSC is compiled
once and reused across training steps; each step supplies only fresh tensor
pointers and zeroed event-counter state.

We serialize with msgpack (binary, runtime) and expose a JSON debug dump.
An in-process :class:`SSCCache` keyed by shape bucket mirrors the paper's
"reuse SSC for stable shapes or shape buckets" behaviour (Table 2).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import msgpack

from .odg import ScheduleConfig
from .scheduler import Event, Schedule
from .tasks import Range, TaskDescriptor


def _td_to_dict(td: TaskDescriptor) -> dict:
    d = dataclasses.asdict(td)
    d["inputs"] = [dataclasses.asdict(r) for r in td.inputs]
    d["outputs"] = [dataclasses.asdict(r) for r in td.outputs]
    return d


def _td_from_dict(d: dict) -> TaskDescriptor:
    d = dict(d)
    d["inputs"] = [Range(**r) for r in d["inputs"]]
    d["outputs"] = [Range(**r) for r in d["outputs"]]
    return TaskDescriptor(**d)


def schedule_to_ssc(s: Schedule) -> bytes:
    """Serialize a full (all-rank) schedule."""
    payload = {
        "version": 1,
        "direction": s.direction,
        "ep": s.ep,
        "opts": s.opts,
        "tasks": [_td_to_dict(td) for td in s.tasks],
        "events": {str(e.eid): {"threshold": e.threshold,
                                "home_rank": e.home_rank,
                                "producers": list(e.producers)}
                   for e in s.events.values()},
        "queues": [{"rank": r, "qtype": q, "tids": tids}
                   for (r, q), tids in sorted(s.queues.items())],
    }
    return msgpack.packb(payload, use_bin_type=True)


def ssc_to_schedule(blob: bytes) -> Schedule:
    p = msgpack.unpackb(blob, raw=False)
    tasks = [_td_from_dict(d) for d in p["tasks"]]
    events = {int(k): Event(eid=int(k), threshold=v["threshold"],
                            home_rank=v["home_rank"],
                            producers=tuple(v["producers"]))
              for k, v in p["events"].items()}
    queues = {(e["rank"], e["qtype"]): list(e["tids"]) for e in p["queues"]}
    return Schedule(direction=p["direction"], ep=p["ep"], tasks=tasks,
                    events=events, queues=queues, opts=p.get("opts", {}))


def rank_view(s: Schedule, rank: int) -> dict:
    """The per-rank slice a device runtime would receive (debug/JSON)."""
    tids = set(s.queue(rank, "CTQ")) | set(s.queue(rank, "VTQ"))
    return {
        "rank": rank,
        "ctq": [_td_to_dict(s.tasks[t]) for t in s.queue(rank, "CTQ")],
        "vtq": [_td_to_dict(s.tasks[t]) for t in s.queue(rank, "VTQ")],
        "events": {e.eid: e.threshold for e in s.events.values()
                   if e.home_rank == rank
                   or any(p in tids for p in e.producers)},
    }


def dump_json(s: Schedule, path: str) -> None:
    with open(path, "w") as f:
        json.dump([rank_view(s, r) for r in range(s.ep)], f, indent=1)


class SSCCache:
    """Shape-bucket keyed cache of compiled SSCs (paper §5.1)."""

    def __init__(self):
        self._cache: dict[tuple, bytes] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(cfg: ScheduleConfig, direction: str, **opts) -> tuple:
        # Key on the effective routing (cfg.routing), so an explicit
        # balanced plan and the equivalent scalar-rows config share one
        # entry; a fresh imbalanced router output compiles a fresh SSC.
        return (cfg.ep, cfg.e_loc, cfg.d_model, cfg.d_ff, cfg.dtype_bytes,
                cfg.gmm_m_split, cfg.routing.counts, direction,
                tuple(sorted(opts.items())))

    def get_or_compile(self, cfg: ScheduleConfig, direction: str,
                       **opts) -> Schedule:
        from .odg import build_moe_ffn_backward, build_moe_ffn_forward
        from .scheduler import compile_schedule
        k = self.key(cfg, direction, **opts)
        blob = self._cache.get(k)
        if blob is None:
            self.misses += 1
            builder = (build_moe_ffn_forward if direction == "forward"
                       else build_moe_ffn_backward)
            sched = compile_schedule(builder(cfg), **opts)
            blob = schedule_to_ssc(sched)
            self._cache[k] = blob
        else:
            self.hits += 1
        return ssc_to_schedule(blob)
