"""Operator Dependency Graph (ODG) — HyperParallel-MoE's scheduling IR (§4.2).

The ODG describes the operator-level dataflow of a schedulable MoE-FFN
fragment. Nodes are :class:`OperatorNode`s; edges are tensor dependencies
expressed through shared :class:`TensorRef` objects. Each node carries a
:class:`SplitSpec` describing its *legal* tiling strategy:

* ``split_inputs`` — which input tensors must already carry a compatible
  partition (``None`` marks a partitioning *origin*, e.g. Dispatch);
* ``split_output_dims`` — along which dimension each output's partition
  keeps propagating downstream (``-1`` = stop propagating);
* ``task_num_fn`` — how many tile tasks to generate for a given shape /
  parallel configuration (plan-aware: counts come from the nonzero cells of
  the operator's :class:`~repro.core.routing.RoutingPlan`, not a fixed grid).

``build_moe_ffn_forward`` / ``build_moe_ffn_backward`` construct the exact
graphs of Fig. 2(a)/(b) for one EP group. Tensor extents are driven by
``ScheduleConfig.routing`` — a :class:`RoutingPlan` whose per-(src, dst,
expert) row counts may be arbitrarily imbalanced (skewed, sparse, hotspot);
the balanced plan reproduces the paper's controlled Table-3 setting and the
seed's schedules exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from .hardware import Topology
from .routing import HierDispatch, RoutingPlan, balanced_plan

# Resource classes (paper: AIC = cube/matrix, AIV = vector/comm/data-movement).
CUBE = "cube"
VECTOR = "vector"

# Queue names.
CTQ = "CTQ"
VTQ = "VTQ"

RESOURCE_TO_QUEUE = {CUBE: CTQ, VECTOR: VTQ}


@dataclasses.dataclass
class TensorRef:
    """A logical tensor in the ODG.

    ``rows``/``row_bytes`` define the canonical *row layout* used for tile
    range bookkeeping: every tile task reads/writes a contiguous row range of
    some tensor. ``split_dim``/``split_num`` are the partition labels written
    and consumed by split propagation (Algorithm 1); by convention the row
    dimension is dim 0, so a row-partitioned tensor has ``split_dim == 0``.
    """

    name: str
    rows: int
    row_bytes: int
    dtype: str = "bf16"
    # Partition labels (mutated by split propagation).
    split_dim: int = -1
    split_num: int = 1
    # True for tensors produced outside this fragment (weights, saved acts).
    external: bool = False

    @property
    def nbytes(self) -> int:
        return self.rows * self.row_bytes


@dataclasses.dataclass(frozen=True)
class SplitSpec:
    """Legal tiling strategy for one operator (§4.2)."""

    # ((input_index, required_split_dim), ...) or None for partition origins.
    split_inputs: Optional[tuple[tuple[int, int], ...]]
    # Per output: dimension along which the partition propagates (-1 = stop).
    split_output_dims: tuple[int, ...]
    # (config, operator) → number of tile tasks; plan-aware fns use the
    # operator's rank to count its nonzero routing cells.
    task_num_fn: Callable[["ScheduleConfig", "OperatorNode"], int]
    # Input indices excluded from split checking (e.g. Combine's offset/size
    # metadata tensors — paper §4.2 example).
    ignore_inputs: tuple[int, ...] = ()
    # Label outputs row-partitioned even when this op emits ≤1 tasks. Set
    # for Dispatch: its *receive* buffer is written in exact per-cell ranges
    # by every source rank's tasks, so downstream tiling is legal no matter
    # how few cells this particular sender has (hotspot / zero-send ranks).
    always_label: bool = False


@dataclasses.dataclass
class OperatorNode:
    """One operator instance in the ODG (per EP rank for rank-local ops)."""

    name: str
    op_type: str                 # dispatch | gmm | swiglu | combine | ...
    resource: str                # CUBE or VECTOR
    rank: int                    # EP rank that *executes* this operator
    inputs: list[TensorRef]
    outputs: list[TensorRef]
    split_spec: SplitSpec
    meta: dict = dataclasses.field(default_factory=dict)
    # Filled in by split propagation.
    task_num: int = 1

    @property
    def queue(self) -> str:
        return RESOURCE_TO_QUEUE[self.resource]


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Shape + parallel configuration C handed to split propagation.

    ``rows`` describes the balanced-routing special case (the controlled
    setting of the paper's Table 3): every (src rank, dst rank, local expert)
    triple carries the same token count. Supplying ``plan`` instead drives
    the whole stack from a per-cell :class:`RoutingPlan` — imbalanced,
    sparse, or hotspot routing as produced by a real router (see
    ``models.moe.plan_from_routing``). ``d_model``/``d_ff`` in elements;
    dtype_bytes for bf16=2.
    """

    ep: int                      # EP group size
    e_loc: int                   # local experts per rank
    rows: int                    # tokens per (src, dst, expert) triple
    d_model: int
    d_ff: int
    dtype_bytes: int = 2
    # Extra row-wise splits per expert GMM tile (1 = one tile per expert,
    # the paper's "tile covers a complete expert width" default). Under a
    # plan, each expert block is cut into ≤ gmm_m_split ragged chunks.
    gmm_m_split: int = 1
    # How gmm_m_split chunk boundaries are placed inside an expert block:
    # "even" (seed behaviour — equal chunks, only legal when boundaries
    # happen to align with dispatch cells) or "source_aligned" (boundaries
    # restricted to source-cell edges, legal for arbitrary imbalanced
    # plans). See RoutingPlan.gmm_tiles.
    gmm_split_mode: str = "even"
    # Imbalanced routing plan; None means the balanced grid from ``rows``.
    plan: Optional[RoutingPlan] = None
    # Quantization provenance of ``plan``: the canonical key tuple of the
    # repro.core.buckets.BucketSpec the plan's counts were quantized with
    # (None = unbucketed/exact). Part of the SSC cache key, so schedules
    # compiled under different bucket policies never alias even when two
    # policies happen to map one batch to the same counts; recorded in
    # Schedule.opts / the SSC blob for provenance. Any BucketSpec /
    # int / str / spec form normalizes to the key tuple at construction.
    bucket: Optional[tuple] = None
    # Cluster link topology (core/hardware.Topology). None = every link
    # equal (the flat-interconnect assumption of the seed). Setting it
    # makes link classes visible to the cost model, autoselect, and the
    # node-aware passes even when dispatch stays flat.
    topology: Optional[Topology] = None
    # "flat" — one put per nonzero (dst, expert) cell (seed behaviour);
    # "hier" — two-level dispatch: same-node cells stay flat, cross-node
    # cells are gathered at a node-leader rank and take the inter-node
    # hop as one aggregated message per (leader, dst, expert) group.
    # Requires ``topology`` and ``gmm_split_mode="source_aligned"``.
    dispatch_mode: str = "flat"
    # Compress the aggregated inter-node hop only: None or "int8"
    # (symmetric per-message quantization; see parallel/compression.py).
    xnode_compress: Optional[str] = None

    def __post_init__(self):
        if self.gmm_split_mode not in ("even", "source_aligned"):
            raise ValueError(
                f"gmm_split_mode must be 'even' or 'source_aligned', "
                f"got {self.gmm_split_mode!r}")
        if self.bucket is not None:
            from .buckets import BucketSpec
            object.__setattr__(self, "bucket",
                               BucketSpec.from_any(self.bucket).key())
        if self.plan is not None and (self.plan.ep != self.ep
                                      or self.plan.e_loc != self.e_loc):
            raise ValueError(
                f"plan shape ({self.plan.ep}, {self.plan.e_loc}) does not "
                f"match config (ep={self.ep}, e_loc={self.e_loc})")
        if self.dispatch_mode not in ("flat", "hier"):
            raise ValueError(
                f"dispatch_mode must be 'flat' or 'hier', "
                f"got {self.dispatch_mode!r}")
        if self.xnode_compress not in (None, "int8"):
            raise ValueError(
                f"xnode_compress must be None or 'int8', "
                f"got {self.xnode_compress!r}")
        if self.topology is not None and self.ep % self.topology.ranks_per_node:
            raise ValueError(
                f"ep={self.ep} is not a multiple of "
                f"topology.ranks_per_node={self.topology.ranks_per_node}")
        if self.dispatch_mode == "hier":
            if self.topology is None:
                raise ValueError("dispatch_mode='hier' requires a topology")
            if self.gmm_split_mode != "source_aligned":
                raise ValueError(
                    "dispatch_mode='hier' requires "
                    "gmm_split_mode='source_aligned' (tile boundaries must "
                    "respect aggregated inter-node message atoms)")
        if self.xnode_compress is not None and self.dispatch_mode != "hier":
            raise ValueError(
                "xnode_compress only applies to dispatch_mode='hier'")

    @property
    def hier(self) -> Optional[HierDispatch]:
        """Two-level dispatch geometry, or None under flat dispatch."""
        if self.dispatch_mode != "hier":
            return None
        return HierDispatch(self.routing, self.topology.ranks_per_node,
                            agg_rows=self.tile_agg_rows)

    @property
    def tile_atom_nodes(self) -> Optional[int]:
        """Node size for GMM/vector tile atoms (hier mode only): tiles may
        not split the landing zone of an aggregated inter-node message."""
        if self.dispatch_mode != "hier":
            return None
        return self.topology.ranks_per_node

    @property
    def tile_agg_rows(self) -> Optional[float]:
        """Aggregation threshold in rows (hier mode only): the row count
        whose inter-node transfer time equals one inter-node hop latency.
        A remote-node group aggregates iff its total rows stay within
        ``(n_cells - 1)`` times this — the hop latency saved covers the
        per-cell pipelining given up (see ``routing.aggregate_group``)."""
        if self.dispatch_mode != "hier":
            return None
        t = self.topology
        return (t.inter_hop_us * t.inter_gbps * 1e3
                / (self.d_model * self.dtype_bytes))

    @property
    def routing(self) -> RoutingPlan:
        """The routing plan driving all extents (balanced if none given)."""
        if self.plan is not None:
            return self.plan
        return balanced_plan(self.ep, self.e_loc, self.rows)

    @property
    def rows_per_expert(self) -> int:
        """Balanced-grid rows per local expert (from all ep source ranks).

        Only meaningful without a plan; plan-aware code paths use
        ``routing.expert_rows(rank, e)`` instead.
        """
        return self.ep * self.rows

    @property
    def recv_rows(self) -> int:
        """Balanced-grid rows in a rank's dispatch-receive buffer."""
        return self.e_loc * self.rows_per_expert


class ODG:
    """A directed acyclic operator graph over one EP group."""

    def __init__(self, cfg: ScheduleConfig, direction: str):
        self.cfg = cfg
        self.direction = direction          # "forward" | "backward"
        self.tensors: dict[str, TensorRef] = {}
        self.ops: list[OperatorNode] = []

    # -- construction -----------------------------------------------------
    def tensor(self, name: str, rows: int, row_bytes: int, **kw) -> TensorRef:
        if name in self.tensors:
            return self.tensors[name]
        t = TensorRef(name=name, rows=rows, row_bytes=row_bytes, **kw)
        self.tensors[name] = t
        return t

    def add_op(self, op: OperatorNode) -> OperatorNode:
        self.ops.append(op)
        return op

    # -- queries -----------------------------------------------------------
    def topological(self) -> list[OperatorNode]:
        """Ops in topological order.

        Construction order is already topological for the builders below, but
        we verify: every non-external input must have been produced by an
        earlier op (or be external).
        """
        produced: set[str] = set()
        for op in self.ops:
            for t in op.inputs:
                if not t.external and t.name not in produced:
                    raise ValueError(
                        f"ODG not topologically ordered: {op.name} reads "
                        f"{t.name} before it is produced")
            for t in op.outputs:
                produced.add(t.name)
        return list(self.ops)

    def validate_acyclic(self) -> None:
        self.topological()


# ---------------------------------------------------------------------------
# SplitSpecs for the MoE-FFN operators (paper §4.2).
# ---------------------------------------------------------------------------

def _dispatch_tasks(c: ScheduleConfig, op: "OperatorNode") -> int:
    # One put_mem_signal task per *nonzero* (dst rank, local expert) cell of
    # this source rank's plan (balanced: ep * e_loc).
    return c.routing.n_send_cells(op.rank)


def _dispatch_x_tasks(c: ScheduleConfig, op: "OperatorNode") -> int:
    # One aggregated inter-node put per (leader, dst rank, expert) group
    # homed at this leader rank (hier dispatch only).
    return c.hier.n_stage_groups(op.rank)


def _gmm_tasks(c: ScheduleConfig, op: "OperatorNode") -> int:
    # Task-level parallelism only along expert blocks (× optional row split);
    # the K reduction dimension stays intact (§4.2). Empty experts produce
    # no tiles; ragged blocks produce a ragged last chunk.
    return c.routing.n_gmm_tiles(op.rank, c.gmm_m_split, c.gmm_split_mode,
                                 c.tile_atom_nodes, c.tile_agg_rows)


def _vector_tasks(c: ScheduleConfig, op: "OperatorNode") -> int:
    # AIV-side elementwise ops align with GMM row partitions.
    return c.routing.n_gmm_tiles(op.rank, c.gmm_m_split, c.gmm_split_mode,
                                 c.tile_atom_nodes, c.tile_agg_rows)


def _combine_tasks(c: ScheduleConfig, op: "OperatorNode") -> int:
    # One put_mem_signal task per nonzero (source rank, local expert) cell
    # returned by this rank (balanced: ep * e_loc).
    return c.routing.n_combine_cells(op.rank)


DISPATCH_SPEC = SplitSpec(split_inputs=None, split_output_dims=(0,),
                          task_num_fn=_dispatch_tasks, always_label=True)
# Hier dispatch declares the staging buffer as a second output.
HIER_DISPATCH_SPEC = SplitSpec(split_inputs=None, split_output_dims=(0, 0),
                               task_num_fn=_dispatch_tasks, always_label=True)
# The aggregated inter-node hop is its own partitioning origin: one task
# per (leader, dst, expert) staging group.
DISPATCH_X_SPEC = SplitSpec(split_inputs=None, split_output_dims=(0,),
                            task_num_fn=_dispatch_x_tasks, always_label=True)
GMM_SPEC = SplitSpec(split_inputs=((0, 0),), split_output_dims=(0,),
                     task_num_fn=_gmm_tasks)
SWIGLU_SPEC = SplitSpec(split_inputs=((0, 0),), split_output_dims=(0,),
                        task_num_fn=_vector_tasks)
# Combine inherits row partitioning from its *data* input (input 0) and
# ignores routing-metadata inputs during split checking (§4.2).
COMBINE_SPEC = SplitSpec(split_inputs=((0, 0),), split_output_dims=(0,),
                         task_num_fn=_combine_tasks, ignore_inputs=(1,))
# Weight-gradient GMMs terminate propagation (outputs are weight blocks).
GMM_WGRAD_SPEC = SplitSpec(split_inputs=((0, 0),), split_output_dims=(-1,),
                           task_num_fn=_gmm_tasks)


# ---------------------------------------------------------------------------
# Graph builders — Fig. 2(a) forward and Fig. 2(b) backward.
# ---------------------------------------------------------------------------

def build_moe_ffn_forward(cfg: ScheduleConfig) -> ODG:
    """Dispatch → GMM1 → SwiGLU → GMM2 → Combine, per EP rank."""
    g = ODG(cfg, "forward")
    db = cfg.dtype_bytes
    d, f = cfg.d_model, cfg.d_ff
    plan = cfg.routing

    hier = cfg.hier
    for r in range(cfg.ep):
        # Source-side routed tokens, grouped by (dst rank, expert).
        x_src = g.tensor(f"x_src@{r}", plan.send_rows(r), d * db,
                         external=True)
        # Receive buffer, grouped by (expert, src rank) — expert-major so each
        # expert's rows are contiguous for the GMM.
        x_recv = g.tensor(f"x_recv@{r}", plan.recv_rows(r), d * db)
        outputs, spec = [x_recv], DISPATCH_SPEC
        if hier is not None:
            # Node-leader staging buffer for this rank's homed groups.
            outputs.append(g.tensor(f"x_recv_stg@{r}", hier.stage_rows(r),
                                    d * db))
            spec = HIER_DISPATCH_SPEC
        g.add_op(OperatorNode(
            name=f"Dispatch@{r}", op_type="dispatch", resource=VECTOR, rank=r,
            inputs=[x_src], outputs=outputs, split_spec=spec))

    if hier is not None:
        for r in range(cfg.ep):
            if hier.n_stage_groups(r) == 0:
                continue
            g.add_op(OperatorNode(
                name=f"DispatchX@{r}", op_type="dispatch_xnode",
                resource=VECTOR, rank=r,
                inputs=[g.tensors[f"x_recv_stg@{r}"]],
                outputs=[g.tensors[f"x_recv@{r}"]],
                split_spec=DISPATCH_X_SPEC))

    for r in range(cfg.ep):
        x_recv = g.tensors[f"x_recv@{r}"]
        w1 = g.tensor(f"W1@{r}", cfg.e_loc, d * 2 * f * db, external=True)
        h = g.tensor(f"h@{r}", plan.recv_rows(r), 2 * f * db)
        g.add_op(OperatorNode(
            name=f"GMM1@{r}", op_type="gmm", resource=CUBE, rank=r,
            inputs=[x_recv, w1], outputs=[h], split_spec=GMM_SPEC,
            meta={"which": "gmm1"}))

        act = g.tensor(f"g@{r}", plan.recv_rows(r), f * db)
        g.add_op(OperatorNode(
            name=f"SwiGLU@{r}", op_type="swiglu", resource=VECTOR, rank=r,
            inputs=[h], outputs=[act], split_spec=SWIGLU_SPEC,
            meta={"plan_tiling": "expert"}))

        w2 = g.tensor(f"W2@{r}", cfg.e_loc, f * d * db, external=True)
        y = g.tensor(f"y@{r}", plan.recv_rows(r), d * db)
        g.add_op(OperatorNode(
            name=f"GMM2@{r}", op_type="gmm", resource=CUBE, rank=r,
            inputs=[act, w2], outputs=[y], split_spec=GMM_SPEC,
            meta={"which": "gmm2"}))

    for r in range(cfg.ep):
        y = g.tensors[f"y@{r}"]
        meta_t = g.tensor(f"route_meta@{r}", cfg.ep * cfg.e_loc, 8,
                          external=True)
        y_ret = g.tensor(f"y_ret@{r}", plan.send_rows(r), d * db)
        g.add_op(OperatorNode(
            name=f"Combine@{r}", op_type="combine", resource=VECTOR, rank=r,
            inputs=[y, meta_t], outputs=[y_ret], split_spec=COMBINE_SPEC))

    g.validate_acyclic()
    return g


def build_moe_ffn_backward(cfg: ScheduleConfig) -> ODG:
    """The 7-node backward graph of Fig. 2(b).

    DispatchB → {GMM_act_grad, GMM_w2_grad} → SwiGLU_grad →
    {GMM_gate_grad, GMM_w1_grad} → CombineB.
    ``GMM_act_grad``/``GMM_w2_grad`` independently consume the dispatched
    upstream gradient; ``GMM_gate_grad``/``GMM_w1_grad`` independently consume
    the SwiGLU gradient — the freedom exploited by cache-guided interleaving.
    """
    g = ODG(cfg, "backward")
    db = cfg.dtype_bytes
    d, f = cfg.d_model, cfg.d_ff
    plan = cfg.routing

    hier = cfg.hier
    for r in range(cfg.ep):
        dy_src = g.tensor(f"dy_src@{r}", plan.send_rows(r),
                          d * db, external=True)
        dy_recv = g.tensor(f"dy_recv@{r}", plan.recv_rows(r), d * db)
        outputs, spec = [dy_recv], DISPATCH_SPEC
        if hier is not None:
            outputs.append(g.tensor(f"dy_recv_stg@{r}", hier.stage_rows(r),
                                    d * db))
            spec = HIER_DISPATCH_SPEC
        g.add_op(OperatorNode(
            name=f"DispatchB@{r}", op_type="dispatch", resource=VECTOR,
            rank=r, inputs=[dy_src], outputs=outputs,
            split_spec=spec))

    if hier is not None:
        for r in range(cfg.ep):
            if hier.n_stage_groups(r) == 0:
                continue
            g.add_op(OperatorNode(
                name=f"DispatchBX@{r}", op_type="dispatch_xnode",
                resource=VECTOR, rank=r,
                inputs=[g.tensors[f"dy_recv_stg@{r}"]],
                outputs=[g.tensors[f"dy_recv@{r}"]],
                split_spec=DISPATCH_X_SPEC))

    for r in range(cfg.ep):
        dy_recv = g.tensors[f"dy_recv@{r}"]
        w2 = g.tensor(f"W2@{r}", cfg.e_loc, f * d * db, external=True)
        g_saved = g.tensor(f"g_saved@{r}", plan.recv_rows(r), f * db,
                           external=True)
        dg = g.tensor(f"dg@{r}", plan.recv_rows(r), f * db)
        g.add_op(OperatorNode(
            name=f"GMM_act_grad@{r}", op_type="gmm", resource=CUBE, rank=r,
            inputs=[dy_recv, w2], outputs=[dg], split_spec=GMM_SPEC,
            meta={"which": "act_grad", "branch": "dy"}))
        dW2 = g.tensor(f"dW2@{r}", cfg.e_loc, f * d * 4)  # fp32 wgrad
        g.add_op(OperatorNode(
            name=f"GMM_w2_grad@{r}", op_type="gmm_wgrad", resource=CUBE,
            rank=r, inputs=[dy_recv, g_saved], outputs=[dW2],
            split_spec=GMM_WGRAD_SPEC,
            meta={"which": "w2_grad", "branch": "dy"}))

        h_saved = g.tensor(f"h_saved@{r}", plan.recv_rows(r), 2 * f * db,
                           external=True)
        dh = g.tensor(f"dh@{r}", plan.recv_rows(r), 2 * f * db)
        g.add_op(OperatorNode(
            name=f"SwiGLU_grad@{r}", op_type="swiglu_grad", resource=VECTOR,
            rank=r, inputs=[dg, h_saved], outputs=[dh],
            split_spec=SWIGLU_SPEC, meta={"plan_tiling": "expert"}))

        w1 = g.tensor(f"W1@{r}", cfg.e_loc, d * 2 * f * db, external=True)
        dx_disp = g.tensor(f"dx_disp@{r}", plan.recv_rows(r), d * db)
        g.add_op(OperatorNode(
            name=f"GMM_gate_grad@{r}", op_type="gmm", resource=CUBE, rank=r,
            inputs=[dh, w1], outputs=[dx_disp], split_spec=GMM_SPEC,
            meta={"which": "gate_grad", "branch": "dh"}))
        x_saved = g.tensor(f"x_recv_saved@{r}", plan.recv_rows(r), d * db,
                           external=True)
        dW1 = g.tensor(f"dW1@{r}", cfg.e_loc, d * 2 * f * 4)
        g.add_op(OperatorNode(
            name=f"GMM_w1_grad@{r}", op_type="gmm_wgrad", resource=CUBE,
            rank=r, inputs=[dh, x_saved], outputs=[dW1],
            split_spec=GMM_WGRAD_SPEC,
            meta={"which": "w1_grad", "branch": "dh"}))

    for r in range(cfg.ep):
        dx_disp = g.tensors[f"dx_disp@{r}"]
        meta_t = g.tensor(f"route_meta@{r}", cfg.ep * cfg.e_loc, 8,
                          external=True)
        dx_ret = g.tensor(f"dx_ret@{r}", plan.send_rows(r), d * db)
        g.add_op(OperatorNode(
            name=f"CombineB@{r}", op_type="combine", resource=VECTOR, rank=r,
            inputs=[dx_disp, meta_t], outputs=[dx_ret],
            split_spec=COMBINE_SPEC))

    g.validate_acyclic()
    return g
