"""Schedule-compilation pass pipeline — §4.5's optimizations as compiler
passes over a shared task abstraction.

The seed reproduction hardcoded each execution-order optimization as a
boolean kwarg threaded through ``compile_schedule``, ``SSCCache.key`` and
every caller; adding an optimization meant widening every signature.
FlowMoE frames this as a *scheduling-pass* problem: each optimization is a
named, parameterized transform over the compiled ``Schedule``, and a
:class:`Pipeline` — an ordered, serializable list of pass specs — is the
single object that travels through compilation, the SSC cache key, the SSC
blob itself, and the hillclimb variant space.

Contract for a registered pass (the ``SchedulePass`` protocol):

* signature ``fn(sched, cfg, **params)``, mutating ``sched.queues`` in
  place;
* it may only permute mutually independent tasks — events, tile ranges and
  task membership are frozen (``validate_schedule`` re-proves legality
  after the whole pipeline runs);
* ``params`` must be msgpack-serializable scalars so the spec round-trips
  through the SSC blob byte-identically.

Back-compat: the seed's ``ratr=`` / ``gmm_interleave=`` /
``chain_interleave=`` kwargs are shimmed through
:func:`pipeline_from_flags`, which maps them onto the equivalent canonical
pipeline — compiling with the old flags and with the equivalent pipeline
spec produces byte-identical SSC blobs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, Union, runtime_checkable

from .odg import ScheduleConfig


@runtime_checkable
class SchedulePass(Protocol):
    """A registered schedule transform: ``fn(sched, cfg, **params)``."""

    def __call__(self, sched, cfg: ScheduleConfig, **params) -> None: ...


_PASS_REGISTRY: dict[str, Callable] = {}


def register_pass(name: str):
    """Register a :class:`SchedulePass` implementation under ``name``."""
    def deco(fn):
        if name in _PASS_REGISTRY:
            raise ValueError(f"schedule pass {name!r} already registered")
        _PASS_REGISTRY[name] = fn
        return fn
    return deco


def get_pass(name: str) -> Callable:
    try:
        return _PASS_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown schedule pass {name!r}; registered passes: "
                       f"{registered_passes()}") from None


def registered_passes() -> tuple[str, ...]:
    return tuple(sorted(_PASS_REGISTRY))


@dataclasses.dataclass(frozen=True)
class PassSpec:
    """One named pass plus its (sorted, hashable) parameter overrides."""

    name: str
    params: tuple = ()          # sorted (key, value) pairs

    @classmethod
    def of(cls, name: str, **params) -> "PassSpec":
        get_pass(name)          # fail fast on unknown names
        return cls(name=name, params=tuple(sorted(params.items())))

    def spec(self) -> list:
        """msgpack/JSON-friendly form: ``[name, {param: value}]``."""
        return [self.name, {k: v for k, v in self.params}]

    def run(self, sched, cfg: ScheduleConfig) -> None:
        get_pass(self.name)(sched, cfg, **dict(self.params))


PassLike = Union[str, tuple, list, PassSpec]


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """Ordered, serializable pass list — the `opts` of a compiled Schedule."""

    passes: tuple[PassSpec, ...] = ()

    @classmethod
    def of(cls, *items: PassLike) -> "Pipeline":
        """Build from pass names, ``[name, params]`` pairs, or PassSpecs."""
        specs = []
        for it in items:
            if isinstance(it, PassSpec):
                specs.append(it)
            elif isinstance(it, str):
                specs.append(PassSpec.of(it))
            elif isinstance(it, (tuple, list)) and len(it) == 2:
                specs.append(PassSpec.of(it[0], **dict(it[1])))
            else:
                raise TypeError(f"cannot interpret {it!r} as a pass spec")
        return cls(passes=tuple(specs))

    @classmethod
    def from_spec(cls, spec) -> "Pipeline":
        """Inverse of :meth:`spec` (e.g. from a deserialized SSC blob)."""
        return cls.of(*spec)

    def spec(self) -> list:
        return [p.spec() for p in self.passes]

    def key(self) -> tuple:
        """Hashable identity for SSC-cache keys."""
        return tuple((p.name, p.params) for p in self.passes)

    def names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.passes)

    def run(self, sched, cfg: ScheduleConfig) -> None:
        for p in self.passes:
            p.run(sched, cfg)

    def __bool__(self) -> bool:
        return bool(self.passes)


EMPTY_PIPELINE = Pipeline()


# The canonical named-pipeline table: the variant space the hillclimb sweep
# (``repro.launch.hillclimb --sched-sweep``), the cost-model-guided selector
# (``core/autoselect.py``) and the docs all enumerate. One registry — a newly
# registered pass joins sweep, selector and docs by adding one entry here.
# Values are serializable pipeline specs (resolvable via ``Pipeline.of``).
SCHED_PIPELINES: dict[str, tuple[str, ...]] = {
    "naive": (),
    "ratr": ("ratr",),
    "ratr+gmm_il": ("ratr", "gmm_interleave"),
    "ratr+crit": ("ratr", "critical_rank_first"),
    "all": ("ratr", "gmm_interleave", "critical_rank_first"),
}


def pipeline_arg(spec: str):
    """Map a CLI ``--sched`` string onto a pipeline request.

    ``"auto"`` stays the literal auto-selection request (resolved by
    ``compile_schedule`` / ``SSCCache`` against the actual plan); a
    ``SCHED_PIPELINES`` name maps to its registered spec; anything else is
    a comma-separated pass-name list, validated against the registry.
    """
    if spec == "auto":
        return "auto"
    if spec in SCHED_PIPELINES:
        return SCHED_PIPELINES[spec]
    names = tuple(s.strip() for s in spec.split(",") if s.strip())
    for n in names:
        get_pass(n)                 # fail fast on unknown names
    return names


def pipeline_from_flags(*, ratr: bool = False, gmm_interleave: bool = False,
                        chain_interleave: bool = False) -> Pipeline:
    """Map the seed's boolean kwargs onto the canonical equivalent pipeline.

    The order matches the seed's ``apply_reorderings`` application order, so
    flag-compiled and pipeline-compiled schedules are byte-identical.
    """
    names = []
    if ratr:
        names.append("ratr")
    if gmm_interleave:
        names.append("gmm_interleave")
    if chain_interleave:
        names.append("chain_interleave")
    return Pipeline.of(*names)


def resolve_pipeline(pipeline=None, *, ratr: bool = False,
                     gmm_interleave: bool = False,
                     chain_interleave: bool = False) -> Pipeline:
    """Normalize a pipeline argument or legacy boolean flags to a Pipeline."""
    if pipeline is not None:
        if ratr or gmm_interleave or chain_interleave:
            raise ValueError(
                "pass either pipeline= or the legacy boolean flags, not both")
        if isinstance(pipeline, Pipeline):
            return pipeline
        if isinstance(pipeline, str):      # a single bare pass name
            if pipeline == "auto":
                raise ValueError(
                    'pipeline="auto" must be resolved against a '
                    "ScheduleConfig first (core/autoselect.auto_pipeline); "
                    "compile_schedule and SSCCache do this for you")
            return Pipeline.of(pipeline)
        return Pipeline.of(*pipeline)
    return pipeline_from_flags(ratr=ratr, gmm_interleave=gmm_interleave,
                               chain_interleave=chain_interleave)


# ---------------------------------------------------------------------------
# Built-in passes (§4.5 reorderings + the straggler-aware extension).
# Implementations live in core/reorder.py; these wrappers own registration
# and any direction gating.
# ---------------------------------------------------------------------------

# ``critical_rank_first`` fires above this compile-time straggler ratio.
# One definition, three consumers: the pass wrapper below, the
# implementation default (core/reorder.py), and the auto-selector's
# fires/no-op gating (core/autoselect.py) — if they diverged, selection
# would price a pass effect the real pass never applies.
CRIT_STRAGGLER_THRESHOLD = 1.05

@register_pass("ratr")
def _pass_ratr(sched, cfg: ScheduleConfig) -> None:
    from .reorder import apply_ratr
    apply_ratr(sched, cfg)


@register_pass("gmm_interleave")
def _pass_gmm_interleave(sched, cfg: ScheduleConfig) -> None:
    from .reorder import apply_gmm_interleave
    if sched.direction == "backward":   # branch pairs only exist backward
        apply_gmm_interleave(sched, cfg)


@register_pass("chain_interleave")
def _pass_chain_interleave(sched, cfg: ScheduleConfig, *,
                           lag: int = 50) -> None:
    from .reorder import apply_chain_interleave
    apply_chain_interleave(sched, lag=lag)


@register_pass("critical_rank_first")
def _pass_critical_rank_first(sched, cfg: ScheduleConfig, *,
                              threshold: float = CRIT_STRAGGLER_THRESHOLD,
                              lag: int = 0) -> None:
    from .reorder import apply_critical_rank_first
    apply_critical_rank_first(sched, cfg, threshold=threshold, lag=lag)


@register_pass("hier_dispatch")
def _pass_hier_dispatch(sched, cfg: ScheduleConfig) -> None:
    """Node-ring ordering for two-level dispatch stage puts. Stable no-op
    on flat schedules (no ``stage``-tagged tasks) and without a topology,
    so it composes freely into any pipeline."""
    from .reorder import apply_hier_dispatch
    apply_hier_dispatch(sched, cfg)


@register_pass("fuse_boundary")
def _pass_fuse_boundary(sched, cfg: ScheduleConfig) -> None:
    """Fragment-spanning pass for fused schedules (core/fusion.py): hoist
    each fragment's combine tiles toward the destination ranks with the
    most next-fragment dispatch traffic. No-op on single-fragment
    schedules."""
    from .reorder import apply_fuse_boundary
    apply_fuse_boundary(sched, cfg)


@register_pass("pp_interleave")
def _pass_pp_interleave(sched, cfg: ScheduleConfig) -> None:
    """Cell-spanning pass for PP-fused schedules (compile_pp_fused): hoist
    each (stage, microbatch) cell's combine tiles toward the ranks with
    the heaviest *same-microbatch next-stage* dispatch traffic — the 1F1B
    analogue of ``fuse_boundary``, which would mis-resolve the downstream
    cell under the wave order. No-op without pp_stage metadata."""
    from .reorder import apply_pp_interleave
    apply_pp_interleave(sched, cfg)
