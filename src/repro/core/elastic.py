"""Elastic plan remapping — the compiled-schedule stack under rank loss.

The statically scheduled taskflows assume a fixed EP group, but production
MoE training is defined by rank loss and rescale (Pangu Ultra MoE and the
TeleChat3-MoE training reports both treat fault recovery as first-order).
This module makes the *plan world* participate in the FT story the runner
(``ft/runner.py``) already has: when the mesh changes, live
:class:`~repro.core.routing.RoutingPlan`\\ s are **remapped** onto the
surviving ranks instead of thrown away, the ``SSCCache`` is **re-keyed**
(never flushed — see :meth:`repro.core.ssc.SSCCache.rekey_for_mesh`), and
observed per-rank step times feed back into
``CostModel(rank_bias=)`` so a persistently slow rank becomes the
compile-time critical rank ``critical_rank_first`` / ``autoselect`` already
know how to schedule around.

Remap semantics (what makes the bit-for-bit guarantee possible)
---------------------------------------------------------------

``remap_plan(plan, dead_ranks=...)`` shrinks an ``[ep, ep, e_loc]`` plan
onto the ``S`` survivors:

* **sources** — a dead rank's data shard is gone for the step, so its rows
  are dropped; every surviving source keeps its rows exactly (*row
  conservation*: ``new.send_rows(i) == old.send_rows(survivors[i])``).
* **experts** — experts are identified by their *global* index
  ``g = dst * e_loc + e`` and re-chunked contiguously over the survivors
  (``e_loc' = ep * e_loc / S``, requires divisibility):
  ``new[s'][d'][e'] = old[survivors[s']][g // e_loc][g % e_loc]`` with
  ``g = d' * e_loc' + e'``. This preserves global expert order, which is
  exactly how expert weights re-chunk under a pure reshape
  (:func:`rechunk_expert_array`) and exactly what
  ``models.moe.plan_from_routing`` produces on the shrunken mesh for the
  same token→expert assignment — so a remapped plan equals a plan built
  natively on the small mesh, cell for cell.
* **send-buffer invariance** — a source's send buffer is (dst, expert)-
  destination-major, i.e. ordered by ascending global expert ``g``; the
  re-chunk preserves that order, so a surviving source's send buffer (and
  therefore every per-row executor output) is *bit-identical* across the
  remap. Offset validity and the single-trigger tiling invariants hold
  because the result is an ordinary ``RoutingPlan`` (offsets are derived,
  ``source_aligned`` tiling is legal for arbitrary plans).

Growth (``new_ep > ep``) is supported symmetrically: re-admitted ranks
join as zero-row sources and the expert axis re-chunks finer.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from .costmodel import CostModel
from .routing import RoutingPlan

# Observed-time bias is clipped to this band: a wedged rank's 100x blowup
# should mark it critical, not blow up every priced candidate.
BIAS_FLOOR = 0.25
BIAS_CEIL = 8.0


def surviving_ranks(ep: int, dead_ranks: Iterable[int]) -> tuple[int, ...]:
    """Sorted ranks of the old mesh that survive ``dead_ranks``."""
    dead = {int(r) for r in dead_ranks}
    bad = [r for r in dead if r < 0 or r >= ep]
    if bad:
        raise ValueError(f"dead ranks {sorted(bad)} outside mesh of {ep}")
    survivors = tuple(r for r in range(ep) if r not in dead)
    if not survivors:
        raise ValueError(f"all {ep} ranks dead — nothing to remap onto")
    return survivors


def remap_plan(plan: RoutingPlan, dead_ranks: Optional[Iterable[int]] = None,
               new_ep: Optional[int] = None) -> RoutingPlan:
    """Redistribute a live plan's cells onto the surviving mesh.

    Exactly one of ``dead_ranks`` (explicit rank loss; survivors keep their
    old order) or ``new_ep`` (rescale; shrink = tail ranks dead, grow =
    fresh zero-row sources appended) must be given. Experts of lost ranks
    are reassigned deterministically by re-chunking the global expert axis
    over the survivors — see the module docstring for the invariants.

    Raises ``ValueError`` when the total expert count does not divide over
    the new mesh size.
    """
    if (dead_ranks is None) == (new_ep is None):
        raise ValueError("pass exactly one of dead_ranks= or new_ep=")
    ep, e_loc = plan.ep, plan.e_loc
    e_total = ep * e_loc
    if dead_ranks is not None:
        survivors = surviving_ranks(ep, dead_ranks)
        s_new = len(survivors)
    else:
        s_new = int(new_ep)
        if s_new < 1:
            raise ValueError(f"new_ep must be >= 1, got {new_ep}")
        survivors = tuple(range(min(s_new, ep)))
    if e_total % s_new:
        ok = [s for s in range(1, e_total + 1) if e_total % s == 0]
        raise ValueError(
            f"cannot remap {e_total} experts onto {s_new} ranks "
            f"(not divisible); valid mesh sizes: {ok}")
    e_loc2 = e_total // s_new

    c = np.asarray(plan.counts, dtype=np.int64)
    # (dst, e) flattens to the global expert axis in ascending-g order —
    # the same order the send buffer lays rows out in, so surviving
    # sources' buffers are bit-identical after the re-chunk below.
    flat = c.reshape(ep, e_total)[list(survivors)]
    if len(survivors) < s_new:                      # growth: empty sources
        pad = np.zeros((s_new - len(survivors), e_total), dtype=np.int64)
        flat = np.concatenate([flat, pad], axis=0)
    return RoutingPlan.from_counts(flat.reshape(s_new, s_new, e_loc2))


def rechunk_expert_array(arr, new_ep: int,
                         e_total: Optional[int] = None) -> np.ndarray:
    """Re-chunk an expert-major array onto a new mesh size.

    ``arr`` is either logical ``[e_total, ...]`` or per-rank
    ``[ep, e_loc, ...]`` (pass ``e_total=`` to disambiguate when both
    divide); the result is ``[new_ep, e_total // new_ep, ...]`` with global
    expert order preserved — the weight-side twin of :func:`remap_plan`'s
    expert re-chunk, a pure reshape (no copy of expert contents, so
    remapped weights are bit-identical per expert).
    """
    a = np.asarray(arr)
    if e_total is not None:
        if a.shape[0] != e_total:
            a = a.reshape(e_total, *a.shape[2:])
    # Per-rank [ep, e_loc, ...] is resolved first — when dim 0 is a mesh
    # size it generally does not divide by new_ep, while [ep * e_loc] does.
    elif a.ndim >= 2 and a.shape[0] % new_ep != 0 \
            and (a.shape[0] * a.shape[1]) % new_ep == 0:
        a = a.reshape(a.shape[0] * a.shape[1], *a.shape[2:])
    flat = a
    if flat.shape[0] % new_ep:
        raise ValueError(
            f"{flat.shape[0]} experts cannot re-chunk onto {new_ep} ranks")
    e_total = flat.shape[0]
    return flat.reshape(new_ep, e_total // new_ep, *flat.shape[1:])


def check_remap(old: RoutingPlan, new: RoutingPlan,
                survivors: Sequence[int]) -> dict:
    """Invariant report for one remap (tests and the fault harness).

    Keys are booleans: ``row_conservation`` (surviving sources keep their
    totals), ``cells_preserved`` (per-(source, global expert) counts
    unchanged), ``offsets_valid`` (send/recv offset tables consistent with
    the counts), ``no_dead_cells`` (total rows equals the survivors' rows —
    nothing is addressed outside the new mesh by construction of the
    ``[S, S, e_loc']`` shape).
    """
    survivors = list(survivors)
    oc = np.asarray(old.counts, dtype=np.int64)
    nc = np.asarray(new.counts, dtype=np.int64)
    e_total = old.ep * old.e_loc
    old_flat = oc.reshape(old.ep, e_total)[survivors]
    new_flat = nc.reshape(new.ep, new.ep * new.e_loc)[:len(survivors)]
    report = {
        "row_conservation": all(
            new.send_rows(i) == old.send_rows(r)
            for i, r in enumerate(survivors)),
        "cells_preserved": bool((old_flat == new_flat).all()),
        "no_dead_cells": int(nc.sum()) == int(old_flat.sum()),
        "offsets_valid": _offsets_valid(new),
    }
    report["ok"] = all(report.values())
    return report


def _offsets_valid(plan: RoutingPlan) -> bool:
    """Send/recv offset tables are monotone prefix sums of the counts."""
    c = np.asarray(plan.counts, dtype=np.int64)
    for s in range(plan.ep):
        run = 0
        for d in range(plan.ep):
            for e in range(plan.e_loc):
                if plan.send_offset(s, d, e) != run:
                    return False
                run += int(c[s, d, e])
        if run != plan.send_rows(s):
            return False
    for d in range(plan.ep):
        run = 0
        for e in range(plan.e_loc):
            if plan.expert_offset(d, e) != run:
                return False
            for s in range(plan.ep):
                if plan.recv_offset(d, e, s) != run:
                    return False
                run += int(c[s, d, e])
        if run != plan.recv_rows(d):
            return False
    return True


# ---------------------------------------------------------------------------
# Observed-time feedback: straggler wall times → compile-time cost bias.
# ---------------------------------------------------------------------------

def rank_bias_from_times(times, floor: float = BIAS_FLOOR,
                         ceil: float = BIAS_CEIL) -> tuple[float, ...]:
    """Mean-normalized per-rank slowdown factors from observed step times.

    ``times`` is any per-rank sequence of observed wall times (the EWMA
    ``ft.runner.train_loop`` accumulates from ``rank_time_us`` step
    metrics). The result is clipped to ``[floor, ceil]`` and normalized to
    mean 1.0 *before* clipping, so a healthy mesh prices exactly as an
    unbiased model while a wedged rank cannot blow up every candidate.
    """
    t = np.asarray(list(times), dtype=np.float64)
    if t.size == 0:
        raise ValueError("rank_bias_from_times: empty time vector")
    if (t < 0).any():
        raise ValueError(f"negative observed times: {t.tolist()}")
    mean = t.mean()
    if mean <= 0:
        return tuple(1.0 for _ in range(t.size))
    bias = np.clip(t / mean, floor, ceil)
    return tuple(float(b) for b in bias)


def observed_cost_model(rank_times, base: Optional[CostModel] = None,
                        ) -> CostModel:
    """A :class:`CostModel` biased by observed per-rank step times.

    ``rank_times`` of None (no feedback yet) returns ``base`` unchanged.
    The biased model stays frozen/hashable, so it flows through the
    memoized ``autoselect`` selector — a persistently slow rank becomes the
    compile-time critical rank and ``critical_rank_first`` fires for it.
    """
    import dataclasses
    base = base if base is not None else CostModel(l2=False)
    if rank_times is None:
        return base
    return dataclasses.replace(base,
                               rank_bias=rank_bias_from_times(rank_times))
