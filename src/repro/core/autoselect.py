"""Cost-model-guided pipeline auto-selection.

The paper's thesis is that the tile-level taskflow is priced *at compile
time* — the ``--sched-sweep`` table showed that which pass pipeline wins
depends on the routing profile (``critical_rank_first`` on concentrated
hotspots, branch interleaving on skewed backward graphs, plain RATR on the
balanced grid), but until now a human read that table and hardcoded the
pick. This module closes the loop Piper-style: ``auto_pipeline`` enumerates
the canonical candidate space (``core.passes.SCHED_PIPELINES`` plus a small
``gmm_m_split`` budget grid), prices every candidate with the *same*
:class:`~repro.core.costmodel.CostModel` the passes and simulator share, and
returns the predicted-best ``(Pipeline, ScheduleConfig)`` — no simulator run,
no schedule compile.

Pricing never generates the real task set (dependency derivation on a dense
plan costs ~1s; selection must stay O(ms) so the dropless path can afford it
per batch). Instead a *synthetic* cube task set is built straight from the
``RoutingPlan`` — one ``TaskDescriptor`` per (rank, expert, GMM op) with the
exact flop/byte formulas of ``core/tasks.py`` — and handed to
``CostModel.rank_cube_us`` / ``critical_rank``, the static straggler
analysis the ``critical_rank_first`` pass itself consumes. Plan-profile
features (skew ratio, sparsity, hotspot concentration) prune the grid:
re-tiling candidates are only generated for starved-hotspot plans, and
pass effects that are gated no-ops (``gmm_interleave`` forward,
``critical_rank_first`` below its straggler threshold) are priced as such.

Resolution points (the literal string ``"auto"`` never escapes them):

* ``compile_schedule(odg, pipeline="auto")`` — resolves the pipeline with
  the tiling pinned (the ODG's task set is already built);
* ``SSCCache.key`` / ``SSCCache.get_or_compile`` — resolve pipeline *and*
  tiling, so cached schedules are keyed by the resolved spec and an
  ``"auto"`` request cache-hits the equivalent explicit request;
* ``launch/hillclimb.py --sched-sweep`` — the ``auto`` row and the
  ``--selector-report`` predicted-vs-simulated accuracy table.

Selection is deterministic (equal plans resolve to equal specs — an SSC
cache invariant) and memoized on the hashable ``ScheduleConfig``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from .costmodel import CostModel
from .odg import CTQ, ScheduleConfig
from .passes import CRIT_STRAGGLER_THRESHOLD, SCHED_PIPELINES, Pipeline
from .routing import RoutingPlan
from .tasks import TaskDescriptor

AUTO = "auto"
# A (rank, expert) block holding more than this fraction of all routed rows
# marks a concentrated hotspot (RATR's ring rotation stops mattering: all
# traffic converges on one destination anyway).
_CONC_HOTSPOT = 0.5
# Expert-level imbalance below which backward branch interleaving is priced
# as a small loss (tiny uniform blocks: interleaving only stretches the
# producer→consumer reuse distance the chain order already had).
_IL_SKEW_MIN = 1.25
# Calibrated effect sizes (fractions of the critical rank's cube-pool time),
# fitted against the ep=8 sweep (launch/hillclimb.py --sched-sweep) and
# re-checked at fixture scale by tests/test_autoselect.py.
_IL_GAIN = 0.06          # backward branch interleave, imbalanced plans
_IL_LOSS = 0.02          # backward branch interleave, balanced plans
_CRIT_CHAIN_GAIN = 0.25  # starved-chain interleave on the critical rank
_CRIT_HOIST_LOSS = 0.25  # peer-latency trade of the comm hoist (graded skew)
# Observed rank bias at which the comm hoist flips to a win: when the
# critical rank is critical because it is *measured* slow (not because its
# plan cells are heavy), its peers finish early anyway — hoisting the
# straggler's comm ahead of their compute costs the peers slack they have.
_BIAS_CRIT = 1.5


@dataclasses.dataclass(frozen=True)
class PlanFeatures:
    """The plan profile that prunes the candidate grid."""

    skew: float          # max/mean recv rows over ranks (straggler potential)
    expert_skew: float   # max/mean rows over (rank, expert) slots
    sparsity: float      # fraction of empty (src, dst, expert) cells
    conc: float          # largest (rank, expert) block / total routed rows
    hot_rows: int        # rows of that largest block
    total_rows: int

    @property
    def balanced(self) -> bool:
        return self.expert_skew <= _IL_SKEW_MIN

    @property
    def hotspot(self) -> bool:
        return self.conc >= _CONC_HOTSPOT


def plan_features(plan: RoutingPlan) -> PlanFeatures:
    c = np.asarray(plan.counts, dtype=np.int64)
    total = int(c.sum())
    blocks = c.sum(axis=0)                       # [dst rank, expert] rows
    hot = int(blocks.max()) if blocks.size else 0
    return PlanFeatures(
        skew=plan.rank_imbalance(),
        expert_skew=plan.expert_imbalance(),
        sparsity=float((c == 0).mean()),
        conc=hot / total if total else 0.0,
        hot_rows=hot,
        total_rows=total,
    )


class _TaskSetView:
    """Duck-typed stand-in for a Schedule: just ``tasks`` + ``ep``.

    ``CostModel.rank_cube_us`` / ``critical_rank`` only touch these two
    attributes, so the selector can run the same static straggler analysis
    the ``critical_rank_first`` pass uses — on a synthetic task set built
    straight from the plan, without compiling a schedule.
    """

    def __init__(self, tasks: list[TaskDescriptor], ep: int):
        self.tasks = tasks
        self.ep = ep


# Cube flops per routed row for each GMM op of the two graphs, as emitted by
# core/tasks.py (`2 * rows * K * N` with K/N in elements): forward runs
# GMM1 [d → 2f] + GMM2 [f → d]; backward runs act_grad [d → f] +
# w2_grad [d × f] + gate_grad [2f → d] + w1_grad [2f × d].
def _gmm_ops(direction: str, d: int, f: int) -> list[tuple[str, float]]:
    if direction == "forward":
        return [("gmm1", 2.0 * d * 2 * f), ("gmm2", 2.0 * f * d)]
    return [("act_grad", 2.0 * d * f), ("w2_grad", 2.0 * d * f),
            ("gate_grad", 2.0 * 2 * f * d), ("w1_grad", 2.0 * 2 * f * d)]


def cube_taskset(plan: RoutingPlan, cfg: ScheduleConfig,
                 direction: str) -> _TaskSetView:
    """Synthetic per-(rank, expert, op) CTQ task set mirroring tasks.py.

    Tiling does not change a rank's cube-time *sum* (``task_us`` is linear
    in flops at fixed residency), so one task per expert block prices
    ``rank_cube_us`` exactly while staying O(ep * e_loc) objects.
    """
    d, f = cfg.d_model, cfg.d_ff
    ops = _gmm_ops(direction, d, f)
    tds: list[TaskDescriptor] = []
    for r in range(plan.ep):
        for e in range(plan.e_loc):
            rows = plan.expert_rows(r, e)
            if rows == 0:
                continue
            for which, flops_per_row in ops:
                tds.append(TaskDescriptor(
                    task_type="GMM", queue_type=CTQ, rank=r,
                    flops=flops_per_row * rows,
                    meta={"expert": e, "which": which}))
    return _TaskSetView(tds, plan.ep)


def _comm_vec_us(plan: RoutingPlan, cfg: ScheduleConfig, direction: str,
                 cost: CostModel) -> tuple[np.ndarray, np.ndarray]:
    """Per-rank (link_us, vector_us) static estimates.

    ``link_us`` prices each rank's total off-rank row traffic — dispatch
    rows in plus combine/return rows out, which are row-for-row symmetric
    in both graphs, so one combined per-rank link term covers ingress and
    egress alike. Vector time prices the SwiGLU/SwiGLU-grad tile stream on
    the AIV pool's aggregate bandwidth.
    """
    hw = cost.hw
    d, f, db = cfg.d_model, cfg.d_ff, cfg.dtype_bytes
    c = np.asarray(plan.counts, dtype=np.float64)
    recv = c.sum(axis=(0, 2))                    # rows landing on each rank
    sent = c.sum(axis=(1, 2))                    # rows leaving each source
    local = np.diag(c.sum(axis=2)).copy()        # rank-local rows
    row_b = d * db
    link_bw = hw.link_gbps * 1e3                 # bytes / us
    link = ((recv - local) + (sent - local)) * row_b / link_bw
    # SwiGLU (fwd: read 2f, write f) / SwiGLU_grad (bwd: read f + 2f saved,
    # write 2f) rows per rank on the AIV pool.
    if direction == "forward":
        bytes_per_row = (2 * f + f) * db
    else:
        bytes_per_row = (f + 2 * f + 2 * f) * db
    vec = recv * bytes_per_row / (hw.aiv_gbps * 1e3)
    return link, vec


def _comm_topo_us(plan: RoutingPlan, cfg: ScheduleConfig,
                  cost: CostModel) -> np.ndarray:
    """Per-rank comm time under a Topology: the busiest link class.

    Walks the exact message set the candidate's dispatch mode emits —
    per-cell puts, plus gather/aggregated-xnode messages from the same
    :class:`~repro.core.routing.HierDispatch` geometry ``tasks.py`` fills
    from — and prices each on its link class (per-message hop latency +
    bytes over the class bandwidth; local stays HBM-bound). Egress and
    ingress accumulate separately per (rank, class) — mirroring the
    simulator's clocks — and a rank's bound is its worst single clock:
    the NIC and the intra-node bus are independent resources.
    """
    from repro.parallel.compression import int8_wire_bytes

    topo, hw = cfg.topology, cost.hw
    hier = cfg.hier
    row_b = cfg.d_model * cfg.dtype_bytes
    ep = plan.ep
    eg: dict[tuple[int, str], float] = {}
    ing: dict[tuple[int, str], float] = {}

    def put(a: int, b: int, nbytes: float, extra: float = 0.0) -> None:
        cls = topo.link_class(a, b)
        if cls == "local":
            t = nbytes / (hw.hbm_gbps * 1e3)
        else:
            t = topo.latency_us(cls) + nbytes / (topo.bw_gbps(cls) * 1e3)
        t += extra
        eg[(a, cls)] = eg.get((a, cls), 0.0) + t
        ing[(b, cls)] = ing.get((b, cls), 0.0) + t

    c = np.asarray(plan.counts, dtype=np.int64)
    for s in range(ep):
        for d in range(ep):
            for e in range(plan.e_loc):
                cnt = int(c[s, d, e])
                if cnt == 0:
                    continue
                put(d, s, cnt * row_b)          # combine return, always flat
                if (hier is not None
                        and not hier.same_node(s, d)
                        and hier.aggregated(hier.node_of(s), d, e)):
                    put(s, hier.leader(hier.node_of(s), d, e), cnt * row_b)
                else:
                    put(s, d, cnt * row_b)
    if hier is not None:
        for leader in range(ep):
            for (d, e, _srcs, total) in hier.stage_groups(leader):
                nb = total * row_b
                wire, qdq = nb, 0.0
                if cfg.xnode_compress == "int8":
                    wire = int8_wire_bytes(nb, cfg.dtype_bytes)
                    qdq = 2 * nb / (hw.l2_read_x_hbm * hw.hbm_gbps * 1e3)
                put(leader, d, wire, extra=qdq)

    link = np.zeros(ep)
    for (r, _cls), t in eg.items():
        link[r] = max(link[r], t)
    for (r, _cls), t in ing.items():
        link[r] = max(link[r], t)
    return link


def _crit_tiles(plan: RoutingPlan, cfg: ScheduleConfig,
                rank: int) -> tuple[int, int, int]:
    """(dominant-expert tile count, other-expert tile count, max tile rows)
    for ``rank`` under the candidate tiling — the exact quantities the
    ``critical_rank_first`` starved-chain gate checks at compile time."""
    tiles = plan.gmm_tiles(rank, cfg.gmm_m_split, cfg.gmm_split_mode,
                           cfg.tile_atom_nodes, cfg.tile_agg_rows)
    if not tiles:
        return 0, 0, 0
    rows_by_e: dict[int, int] = {}
    count_by_e: dict[int, int] = {}
    max_rows = 0
    for (e, _m, lo, hi) in tiles:
        rows_by_e[e] = rows_by_e.get(e, 0) + (hi - lo)
        count_by_e[e] = count_by_e.get(e, 0) + 1
        max_rows = max(max_rows, hi - lo)
    dom = max(rows_by_e, key=rows_by_e.get)
    n_dom = count_by_e[dom]
    n_other = sum(v for e, v in count_by_e.items() if e != dom)
    return n_dom, n_other, max_rows


@dataclasses.dataclass(frozen=True)
class CandidateScore:
    """One priced candidate of the selection grid."""

    tag: str                     # SCHED_PIPELINES name (+ ":m<split>" suffix)
    pipeline: Pipeline
    cfg: ScheduleConfig
    predicted_us: float


@dataclasses.dataclass(frozen=True)
class AutoChoice:
    """Full selector output: the pick plus its evidence."""

    pipeline: Pipeline
    cfg: ScheduleConfig
    predicted_us: float
    features: PlanFeatures
    scores: tuple[CandidateScore, ...]   # every priced candidate, best first

    @property
    def tag(self) -> str:
        return self.scores[0].tag if self.scores else "naive"


@dataclasses.dataclass(frozen=True)
class _PriceContext:
    """Everything about a (config, direction) that pipelines cannot change.

    Built once per candidate tiling and shared across the per-pipeline
    pricing loop — the synthetic task set, the per-rank cube/comm/vector
    aggregates and the critical-rank tile census are all independent of
    pass order (passes only permute queues).
    """

    feats: PlanFeatures
    crit_us: float           # critical rank's total cube time
    ratio: float             # compile-time straggler ratio
    crit: int                # critical rank id
    base_us: float           # max over ranks of the pool/link bounds
    link_max: float          # busiest rank's off-rank comm time
    link_mean: float
    drain_us: float          # largest-tile serialization tail
    n_dom: int               # dominant-expert tile count on the crit rank
    n_other: int             # other-expert tile count on the crit rank


def _price_context(cfg: ScheduleConfig, direction: str,
                   cost: CostModel) -> _PriceContext:
    hw = cost.hw
    plan = cfg.routing
    feats = plan_features(plan)
    view = cube_taskset(plan, cfg, direction)
    cube = cost.rank_cube_us(view)
    ratio, crit = cost.critical_rank(view)
    link, vec = _comm_vec_us(plan, cfg, direction, cost)
    if cfg.topology is not None:
        # Per-link-class pricing replaces the flat uniform-link estimate:
        # the candidate's real message set (incl. two-level dispatch
        # aggregation and compression) on heterogeneous links.
        link = _comm_topo_us(plan, cfg, cost)
    per_rank = [max(cube[r] / hw.num_aic, vec[r] / hw.num_aiv,
                    float(link[r]))
                for r in range(plan.ep)]
    # Largest-tile drain on the critical rank: one AIC core owns one tile,
    # so the last tile of the dominant chain serializes after the pool
    # drains — the term the gmm_m_split budget grid trades against.
    n_dom, n_other, max_tile_rows = _crit_tiles(plan, cfg, max(crit, 0))
    flops_row = max(f for _, f in _gmm_ops(direction, cfg.d_model, cfg.d_ff))
    drain = cost.task_us(TaskDescriptor(
        task_type="GMM", queue_type=CTQ, rank=max(crit, 0),
        flops=flops_row * max_tile_rows))
    return _PriceContext(
        feats=feats, crit_us=cube.get(crit, 0.0), ratio=ratio, crit=crit,
        base_us=max(per_rank) if per_rank else 0.0,
        link_max=float(link.max()) if link.size else 0.0,
        link_mean=float(link.mean()) if link.size else 0.0,
        drain_us=drain, n_dom=n_dom, n_other=n_other)


def predict_makespan_us(cfg: ScheduleConfig, direction: str,
                        pipeline_names, cost: Optional[CostModel] = None,
                        ctx: Optional[_PriceContext] = None) -> float:
    """Static makespan estimate of one (tiling, pipeline) candidate.

    Structural lower-bound terms (cube pool, vector pool, per-rank links,
    largest-tile drain) from the cost model, plus per-pass adjustments whose
    *gating* replicates each pass's own compile-time conditions. Absolute
    values undershoot the simulator (no queue/startup chaining is modeled);
    candidate *ordering* is what selection consumes, and the
    ``--selector-report`` table tracks the residual accuracy.

    ``ctx`` shares the pipeline-independent aggregates across a candidate
    loop (the selector prices every ``SCHED_PIPELINES`` entry against one
    :func:`_price_context` per tiling).
    """
    cost = cost or CostModel(l2=False)
    hw = cost.hw
    if ctx is None:
        ctx = _price_context(cfg, direction, cost)
    feats = ctx.feats
    names = tuple(pipeline_names)
    t = ctx.base_us + ctx.drain_us

    crit_cube_pool = ctx.crit_us / hw.num_aic
    fires = ctx.ratio > CRIT_STRAGGLER_THRESHOLD and ctx.crit >= 0
    starved = (fires and ctx.n_other < hw.num_aic
               and ctx.n_dom > 2 * hw.num_aic)
    il_active = ("gmm_interleave" in names and direction == "backward"
                 and feats.total_rows > 0)

    if "ratr" not in names and not feats.hotspot:
        # Naive dst-major order convoys every source onto rank 0's ingress
        # first; under a concentrated hotspot all traffic converges anyway.
        t += ctx.link_max / max(1, cfg.ep)

    if il_active:
        if feats.balanced:
            t += _IL_LOSS * crit_cube_pool
        else:
            t -= _IL_GAIN * crit_cube_pool

    biased = (cost.rank_bias is not None and ctx.crit >= 0
              and ctx.crit < len(cost.rank_bias)
              and cost.rank_bias[ctx.crit] >= _BIAS_CRIT)

    if "critical_rank_first" in names and fires:
        if il_active:
            # The branch interleave already owns the critical rank's CTQ
            # order; stacking the starved-chain interleave on top re-sorts
            # it away from the branch-paired order (sweep: "all" trails
            # "ratr+gmm_il" backward under concentrated hotspots).
            t += _IL_LOSS * crit_cube_pool
        elif starved:
            # Lag-interleaving the dominant chain overlaps its consumer op
            # with the tail of the producer chain (lag = 2 * pool width).
            t -= (_CRIT_CHAIN_GAIN * crit_cube_pool
                  * max(0.0, 1.0 - 2 * hw.num_aic / max(1, ctx.n_dom)))
        elif biased:
            # Observed-slow critical rank: peers have measured slack, so
            # hoisting the straggler's comm ahead of peer compute is free —
            # the peer-latency trade that costs on plan-driven skew wins.
            t -= _CRIT_HOIST_LOSS * ctx.link_mean
        elif not feats.hotspot:
            # Comm hoist trades peer latency for straggler latency; on
            # graded skew the peers' loss wins (sweep: skewed scenarios).
            t += _CRIT_HOIST_LOSS * ctx.link_mean

    return max(t, 0.0)


def _candidate_cfgs(cfg: ScheduleConfig, starved: bool,
                    allow_retile: bool) -> list[ScheduleConfig]:
    """The gmm_m_split / gmm_split_mode budget grid, feature-pruned.

    Re-tiling is only worth pricing when a starved hotspot chain exists
    (finer tiles shrink the last-tile drain *and* give the starved-chain
    interleave room); everywhere else the caller's tiling is kept, so
    selection prices |SCHED_PIPELINES| candidates, not a cross product.
    """
    cfgs = [cfg]
    if allow_retile and starved:
        m2 = min(2 * max(1, cfg.gmm_m_split), 4 * 64)
        if m2 > cfg.gmm_m_split:
            # source_aligned boundaries are legal for arbitrary plans; a
            # starved hotspot is by construction imbalanced, so never force
            # "even".
            cfgs.append(dataclasses.replace(cfg, gmm_m_split=m2,
                                            gmm_split_mode="source_aligned"))
    return cfgs


def _dispatch_variants(cfgs: list[ScheduleConfig],
                       allow_retile: bool) -> list[ScheduleConfig]:
    """Expand the grid with two-level-dispatch variants when a Topology is
    present.

    Hier changes the task *structure* (staging tensors, xnode ops, node-atom
    tiling), so it only enumerates under ``allow_retile`` — the SSC path,
    which rebuilds the ODG from the returned config. Variants are skipped
    when the plan's cross-node groups all stay on the direct path (the
    aggregation threshold says flat is optimal — the candidates would price
    identically and only add tie noise). The compressed variant rides the
    same geometry with int8 inter-node wire bytes.
    """
    out = list(cfgs)
    if not allow_retile:
        return out
    for base in cfgs:
        if base.topology is None or base.dispatch_mode != "flat":
            continue
        h = dataclasses.replace(base, dispatch_mode="hier",
                                gmm_split_mode="source_aligned")
        if not any(h.hier.n_stage_groups(r) for r in range(h.ep)):
            continue
        out.append(h)
        out.append(dataclasses.replace(h, xnode_compress="int8"))
    return out


@functools.lru_cache(maxsize=512)
def _select(cfg: ScheduleConfig, direction: str, allow_retile: bool,
            cost: CostModel) -> AutoChoice:
    hw = cost.hw

    # Starved-chain probe at the caller's tiling decides whether the
    # budget grid is worth enumerating at all; its context is reused to
    # price the un-retiled candidates (pipelines can't change it).
    base_ctx = _price_context(cfg, direction, cost)
    feats = base_ctx.feats
    fires = base_ctx.ratio > CRIT_STRAGGLER_THRESHOLD and base_ctx.crit >= 0
    starved = fires and base_ctx.n_other < hw.num_aic and feats.hotspot

    scores: list[CandidateScore] = []
    grid = _dispatch_variants(_candidate_cfgs(cfg, starved, allow_retile),
                              allow_retile)
    for cand_cfg in grid:
        ctx = (_price_context(cand_cfg, direction, cost)
               if cand_cfg != cfg else base_ctx)
        hier_cand = cand_cfg.dispatch_mode == "hier"
        for tag, spec in SCHED_PIPELINES.items():
            names = tuple(spec)
            if not fires and "critical_rank_first" in names:
                # The pass is a gated no-op below the straggler threshold;
                # pricing it would only duplicate its crit-less twin.
                continue
            label = tag
            if cand_cfg.gmm_m_split != cfg.gmm_m_split:
                label += f":m{cand_cfg.gmm_m_split}"
            if hier_cand:
                names = names + ("hier_dispatch",)
                label += (":hier+c" if cand_cfg.xnode_compress else ":hier")
            scores.append(CandidateScore(
                tag=label, pipeline=Pipeline.of(*names), cfg=cand_cfg,
                predicted_us=predict_makespan_us(cand_cfg, direction, names,
                                                 cost, ctx=ctx)))
    # Deterministic pick: predicted cost, then registry order (stable sort
    # keeps the enumeration order for ties).
    scores.sort(key=lambda s: s.predicted_us)
    best = scores[0]
    return AutoChoice(pipeline=best.pipeline, cfg=best.cfg,
                      predicted_us=best.predicted_us, features=feats,
                      scores=tuple(scores))


def select(plan: Optional[RoutingPlan], cfg: ScheduleConfig,
           cost_model: Optional[CostModel] = None, *,
           direction: str = "forward",
           allow_retile: bool = True) -> AutoChoice:
    """Full selector output (choice + per-candidate score table).

    ``plan`` overrides ``cfg``'s routing when given (the dropless path holds
    plans, not configs). ``cost_model`` defaults to the compile-time
    ``l2=False`` model the passes themselves use; a supplied model is
    normalized to ``l2=False`` (no execution order exists yet, so there is
    no residency to price).
    """
    if plan is not None and plan != cfg.routing:
        cfg = dataclasses.replace(cfg, plan=plan)
    cost = cost_model if cost_model is not None else CostModel(l2=False)
    if cost.l2:
        cost = dataclasses.replace(cost, l2=False)
    if direction not in ("forward", "backward"):
        raise ValueError(f"unknown direction {direction!r}")
    return _select(cfg, direction, allow_retile, cost)


def auto_pipeline(plan: Optional[RoutingPlan], cfg: ScheduleConfig,
                  cost_model: Optional[CostModel] = None, *,
                  direction: str = "forward",
                  allow_retile: bool = True,
                  ) -> tuple[Pipeline, ScheduleConfig]:
    """Resolve ``pipeline="auto"``: the predicted-best (Pipeline, config).

    Deterministic for equal plans, memoized on the hashable config.
    ``allow_retile=False`` pins the tiling (used by ``compile_schedule``,
    whose ODG task set is already built); the SSC cache resolves with the
    full budget grid.
    """
    choice = select(plan, cfg, cost_model, direction=direction,
                    allow_retile=allow_retile)
    return choice.pipeline, choice.cfg


@functools.lru_cache(maxsize=4096)
def _plan_us(cfg: ScheduleConfig, direction: str, names: tuple,
             cost: CostModel) -> float:
    return predict_makespan_us(cfg, direction, names, cost)


def predict_plan_us(plan: RoutingPlan, d_model: int, d_ff: int, *,
                    direction: str = "forward", pipeline=("ratr",),
                    cost: Optional[CostModel] = None,
                    dtype_bytes: int = 2) -> float:
    """Price one routing plan's step makespan — no compile, no selector grid.

    The admission-control and batch-sizing entry point
    (``launch/online.py``): a single :func:`predict_makespan_us` call at a
    fixed pipeline, memoized on the plan's count matrix, cheap enough to sit
    on the per-request serve path (the full :func:`select` grid prices every
    candidate and is reserved for refit-time re-pricing). Same units and
    same undershoot caveat as :func:`predict_makespan_us` — gate thresholds
    (SLOs) must be expressed against this predictor, not wall clock.
    """
    cost = cost if cost is not None else CostModel(l2=False)
    if cost.l2:
        cost = dataclasses.replace(cost, l2=False)
    cfg = ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                         d_model=d_model, d_ff=d_ff, dtype_bytes=dtype_bytes,
                         gmm_split_mode="source_aligned", plan=plan)
    return _plan_us(cfg, direction, tuple(pipeline), cost)


# ---------------------------------------------------------------------------
# Multi-fragment selection — fused-vs-per-layer (cross-layer fusion) and
# fused-vs-per-stage (pipeline-parallel fusion). Both reuse the per-layer
# selector verbatim for the intra-fragment terms and only price what fusion
# changes: how fragments are *joined*.
# ---------------------------------------------------------------------------

def _boundary_remap_us(up_cfg: ScheduleConfig, dn_cfg: ScheduleConfig,
                       cost: CostModel) -> float:
    """One junction's in-taskflow LayerBoundary cost: the slowest rank's
    remap stream (upstream return read + downstream send write) spread over
    its AIV pool — the same bytes the boundary tiles carry."""
    hw = cost.hw
    b_in = up_cfg.d_model * up_cfg.dtype_bytes
    b_out = dn_cfg.d_model * dn_cfg.dtype_bytes
    per = [dn_cfg.routing.send_rows(r) * (b_in + b_out)
           / (hw.aiv_gbps * 1e3) for r in range(dn_cfg.ep)]
    return (max(per) if per else 0.0) / max(1, hw.num_aiv)


def _host_bridge_us(up_cfg: ScheduleConfig, dn_cfg: ScheduleConfig,
                    cost: CostModel) -> float:
    """One junction's per-layer alternative: drain to host between layers.

    The unfused path pays a host synchronization (the launch gap between
    layer N's combine and layer N+1's dispatch — same constant the
    baseline simulator charges per collective) plus two streaming passes
    over the token activations at HBM bandwidth: the upstream
    combine-weighted gather, then the downstream dispatch scatter."""
    hw = cost.hw
    b_in = up_cfg.d_model * up_cfg.dtype_bytes
    b_out = dn_cfg.d_model * dn_cfg.dtype_bytes
    per = [2 * (up_cfg.routing.send_rows(r) * b_in
                + dn_cfg.routing.send_rows(r) * b_out)
           / (hw.hbm_gbps * 1e3) for r in range(dn_cfg.ep)]
    return hw.collective_host_us + (max(per) if per else 0.0)


def _stage_link_us(up_cfg: ScheduleConfig, dn_cfg: ScheduleConfig,
                   cost: CostModel) -> float:
    """One microbatch's StageBoundary handoff at a junction: the slowest
    rank's activation payload over the stage link — the same per-link-class
    formula :meth:`CostModel.task_us` prices a StageBoundary tile with."""
    hw = cost.hw
    row_b = dn_cfg.d_model * dn_cfg.dtype_bytes
    topo = cost.topology if cost.topology is not None else dn_cfg.topology
    if topo is not None:
        lat, bw = topo.latency_us("inter"), topo.bw_gbps("inter") * 1e3
    else:
        lat, bw = hw.hop_latency_us, hw.link_gbps * 1e3
    per = [lat + dn_cfg.routing.send_rows(r) * row_b / bw
           for r in range(dn_cfg.ep)]
    return max(per) if per else 0.0


def _stage_decomp(cfg: ScheduleConfig, direction: str,
                  cost: CostModel) -> tuple[float, float]:
    """(compute-bound, comm-bound) per-stage slot times — the two resources
    a fused steady-state cell can hide behind each other."""
    hw = cost.hw
    plan = cfg.routing
    cube = cost.rank_cube_us(cube_taskset(plan, cfg, direction))
    link, vec = _comm_vec_us(plan, cfg, direction, cost)
    if cfg.topology is not None:
        link = _comm_topo_us(plan, cfg, cost)
    comp = max((max(cube[r] / hw.num_aic, vec[r] / hw.num_aiv)
                for r in range(plan.ep)), default=0.0)
    comm = float(np.max(link)) if np.size(link) else 0.0
    return comp, comm


@dataclasses.dataclass(frozen=True)
class FusedChoice:
    """Fused-vs-per-layer verdict for a layer stack (satellite of PR 6's
    ROADMAP leftover): both sides share the per-layer selector's best
    intra-layer estimates and differ only in the junction cost — the
    in-taskflow boundary remap vs the host round-trip."""

    fuse: bool
    predicted_fused_us: float
    predicted_per_layer_us: float
    choices: tuple[AutoChoice, ...]      # per layer, layer order


@functools.lru_cache(maxsize=256)
def _select_fused(cfgs: tuple, direction: str, allow_retile: bool,
                  cost: CostModel) -> FusedChoice:
    choices = tuple(_select(c, direction, allow_retile, cost) for c in cfgs)
    intra = sum(ch.predicted_us for ch in choices)
    juncs = list(zip(cfgs[:-1], cfgs[1:]))
    if direction == "backward":          # gradients flow top layer down
        juncs = [(dn, up) for (up, dn) in juncs]
    fused = intra + sum(_boundary_remap_us(u, d, cost) for u, d in juncs)
    per_layer = intra + sum(_host_bridge_us(u, d, cost) for u, d in juncs)
    return FusedChoice(fuse=fused <= per_layer, predicted_fused_us=fused,
                       predicted_per_layer_us=per_layer, choices=choices)


def select_fused(cfgs, *, direction: str = "forward",
                 cost_model: Optional[CostModel] = None,
                 allow_retile: bool = True) -> FusedChoice:
    """Price fused-vs-per-layer for a stack of layer configs (layer order),
    so ``pipeline="auto"`` / ``fuse="auto"`` can choose per batch."""
    cost = cost_model if cost_model is not None else CostModel(l2=False)
    if cost.l2:
        cost = dataclasses.replace(cost, l2=False)
    if direction not in ("forward", "backward"):
        raise ValueError(f"unknown direction {direction!r}")
    return _select_fused(tuple(cfgs), direction, allow_retile, cost)


@dataclasses.dataclass(frozen=True)
class PPChoice:
    """PP fused-vs-per-stage verdict.

    Both estimates share the fill/drain ramp (every stage runs microbatch
    0 in sequence, boundary handoffs included) and differ in the
    steady-state slot: the per-stage reference pays the bottleneck stage's
    *serial* (intra-stage estimate + incoming handoff) per microbatch,
    while the fused schedule hides comm behind compute within a slot —
    ``max(compute, comm + handoff)`` — clamped at the per-stage slot, so
    the fused estimate is never worse by construction (overlap can only
    remove waiting, never add work; the gate asserts this stays true).
    """

    fuse: bool
    n_stages: int
    n_microbatches: int
    predicted_fused_us: float
    predicted_per_stage_us: float
    bubble_us: float                     # (S-1) x bottleneck compute slot
    choices: tuple[AutoChoice, ...]      # per stage, stage order


@functools.lru_cache(maxsize=256)
def _select_pp(cfgs: tuple, n_microbatches: int, direction: str,
               allow_retile: bool, cost: CostModel) -> PPChoice:
    S, M = len(cfgs), n_microbatches
    choices = tuple(_select(c, direction, allow_retile, cost) for c in cfgs)
    pred = [ch.predicted_us for ch in choices]
    decomp = [_stage_decomp(ch.cfg, direction, cost) for ch in choices]
    # Incoming handoff per stage in this direction's dataflow: forward
    # stage s receives from s-1, backward from s+1.
    bnd_in = [0.0] * S
    if direction == "forward":
        for s in range(1, S):
            bnd_in[s] = _stage_link_us(cfgs[s - 1], cfgs[s], cost)
    else:
        for s in range(S - 1):
            bnd_in[s] = _stage_link_us(cfgs[s + 1], cfgs[s], cost)
    fill = sum(pred) + sum(bnd_in)
    per_slot = max(pred[s] + bnd_in[s] for s in range(S))
    fused_slot = max(min(max(decomp[s][0], decomp[s][1] + bnd_in[s]),
                         pred[s] + bnd_in[s]) for s in range(S))
    per_stage = fill + (M - 1) * per_slot
    fused = fill + (M - 1) * fused_slot
    bubble = (S - 1) * max(d[0] for d in decomp)
    return PPChoice(fuse=fused <= per_stage, n_stages=S, n_microbatches=M,
                    predicted_fused_us=fused,
                    predicted_per_stage_us=per_stage,
                    bubble_us=bubble, choices=choices)


def select_pp(cfgs, n_microbatches: int, *, direction: str = "forward",
              cost_model: Optional[CostModel] = None,
              allow_retile: bool = True) -> PPChoice:
    """Price PP fused-vs-per-stage for per-stage configs (stage order).

    This is how ``pipeline="auto"`` picks the winner per plan tuple before
    committing to ``compile_pp_fused``: the per-stage intra estimates come
    from the same memoized :func:`select` grid the unfused path resolves
    with, so a fused pick never contradicts the per-stage picks it is
    built from.
    """
    if n_microbatches < 1:
        raise ValueError(f"n_microbatches must be >= 1, "
                         f"got {n_microbatches}")
    cost = cost_model if cost_model is not None else CostModel(l2=False)
    if cost.l2:
        cost = dataclasses.replace(cost, l2=False)
    if direction not in ("forward", "backward"):
        raise ValueError(f"unknown direction {direction!r}")
    return _select_pp(tuple(cfgs), int(n_microbatches), direction,
                      allow_retile, cost)


def is_auto(pipeline) -> bool:
    """True when ``pipeline`` is the literal auto-selection request."""
    return isinstance(pipeline, str) and pipeline == AUTO


def selection_cache_info():
    """Memoization stats for the selector (monitoring / benchmarks)."""
    return _select.cache_info()


def selection_cache_clear() -> None:
    _select.cache_clear()
