"""Hardware models for HyperParallel-MoE.

Two targets live side by side:

* ``AscendA3`` — the paper's evaluation platform. Used by the discrete-event
  simulator (``core/simulator.py``) to reproduce Table 3 / Figs 7-10. The
  constants come from the paper (§2.1, §5.2) and public Ascend material:
  25 AI Cores per die → 25 AIC units + 50 AIV units, a 192 MB shared L2 with
  >4x HBM read bandwidth, and profiler-reported ~67% average MAC utilisation
  for GMM under the serialized baseline.

* ``TPUv5e`` — the grading target for the roofline analysis
  (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI — constants fixed by the
  task spec).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AscendA3:
    """Per-device constants for one Ascend A3 device (paper §2.1/§5.2)."""

    num_aic: int = 25                 # AI Cube (matrix) units
    num_aiv: int = 50                 # AI Vector units
    # Cube throughput. A3-class dies deliver a few hundred TFLOP/s bf16; the
    # exact figure is not in the paper, so we calibrate the simulator against
    # the paper's measured baseline (Table 3) and keep the per-unit split.
    aic_tflops_bf16: float = 14.0     # per AIC unit → 350 TFLOP/s per die
    # Per-tile GMM efficiency by operand residency: tiles streaming inputs
    # from the shared L2 (>4× HBM read bw) keep the MXU fed better than
    # HBM-streaming tiles. This is the mechanism behind cache-guided GMM
    # interleaving's backward-pass win (§4.5).
    aic_eff_hbm: float = 0.80
    aic_eff_l2: float = 0.90
    aiv_gbps: float = 22.0            # per AIV unit effective vector GB/s
    # (calibrated against the Fig 9 serial SwiGLU+Add latency at M=32K)
    l2_bytes: int = 192 * 2**20       # shared AIC/AIV L2
    l2_read_x_hbm: float = 4.0        # L2 read bw ≥ 4x HBM (paper §2.1)
    hbm_gbps: float = 1600.0          # HBM bandwidth per device
    # Inter-device EP bandwidth. A3 SuperPod-class unified-bus interconnect;
    # calibrated so the simulated operator-by-operator baseline lands on the
    # paper's measured Table 3 numbers (see EXPERIMENTS.md §Calibration).
    link_gbps: float = 350.0
    # Measured per-task dispatch overheads (paper §6.2).
    static_dispatch_us: float = 0.1
    dynamic_dispatch_us: float = 2.36
    # Host-side collective launch + sync overhead per AllToAll phase for the
    # operator-by-operator baseline (exposed, not overlappable).
    collective_host_us: float = 120.0
    kernel_launch_us: float = 20.0    # per-kernel launch gap in the baseline


@dataclasses.dataclass(frozen=True)
class TPUv5e:
    """Roofline constants per chip (fixed by the grading spec)."""

    peak_flops_bf16: float = 197e12   # FLOP/s
    hbm_gbps: float = 819e9           # bytes/s
    ici_link_gbps: float = 50e9       # bytes/s per link
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20     # VMEM — the L2-analogue reuse buffer
    mxu_dim: int = 128                # systolic array tile edge


A3 = AscendA3()
V5E = TPUv5e()
