"""Hardware models for HyperParallel-MoE.

Two targets live side by side:

* ``AscendA3`` — the paper's evaluation platform. Used by the discrete-event
  simulator (``core/simulator.py``) to reproduce Table 3 / Figs 7-10. The
  constants come from the paper (§2.1, §5.2) and public Ascend material:
  25 AI Cores per die → 25 AIC units + 50 AIV units, a 192 MB shared L2 with
  >4x HBM read bandwidth, and profiler-reported ~67% average MAC utilisation
  for GMM under the serialized baseline.

* ``TPUv5e`` — the grading target for the roofline analysis
  (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI — constants fixed by the
  task spec).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AscendA3:
    """Per-device constants for one Ascend A3 device (paper §2.1/§5.2)."""

    num_aic: int = 25                 # AI Cube (matrix) units
    num_aiv: int = 50                 # AI Vector units
    # Cube throughput. A3-class dies deliver a few hundred TFLOP/s bf16; the
    # exact figure is not in the paper, so we calibrate the simulator against
    # the paper's measured baseline (Table 3) and keep the per-unit split.
    aic_tflops_bf16: float = 14.0     # per AIC unit → 350 TFLOP/s per die
    # Per-tile GMM efficiency by operand residency: tiles streaming inputs
    # from the shared L2 (>4× HBM read bw) keep the MXU fed better than
    # HBM-streaming tiles. This is the mechanism behind cache-guided GMM
    # interleaving's backward-pass win (§4.5).
    aic_eff_hbm: float = 0.80
    aic_eff_l2: float = 0.90
    aiv_gbps: float = 22.0            # per AIV unit effective vector GB/s
    # (calibrated against the Fig 9 serial SwiGLU+Add latency at M=32K)
    l2_bytes: int = 192 * 2**20       # shared AIC/AIV L2
    l2_read_x_hbm: float = 4.0        # L2 read bw ≥ 4x HBM (paper §2.1)
    hbm_gbps: float = 1600.0          # HBM bandwidth per device
    # Inter-device EP bandwidth. A3 SuperPod-class unified-bus interconnect;
    # calibrated so the simulated operator-by-operator baseline lands on the
    # paper's measured Table 3 numbers (see EXPERIMENTS.md §Calibration).
    link_gbps: float = 350.0
    # Measured per-task dispatch overheads (paper §6.2).
    static_dispatch_us: float = 0.1
    dynamic_dispatch_us: float = 2.36
    # Per-message link latency floor for remote put_mem_signal transfers.
    # Without it a 64-byte and a 64-KB message differ only linearly in
    # bytes, so fine-grained tile comm is mispriced as free.
    hop_latency_us: float = 0.35
    # Host-side collective launch + sync overhead per AllToAll phase for the
    # operator-by-operator baseline (exposed, not overlappable).
    collective_host_us: float = 120.0
    kernel_launch_us: float = 20.0    # per-kernel launch gap in the baseline


@dataclasses.dataclass(frozen=True)
class Topology:
    """Two-level EP cluster topology: fast intra-node links, slow uplinks.

    Ranks ``[k*ranks_per_node, (k+1)*ranks_per_node)`` form node ``k``.
    Every (src, dst) rank pair maps to one of three link classes:

    * ``"local"`` — src == dst, an HBM copy, never touches a link;
    * ``"intra"`` — same node, unified-bus/HCCS-class bandwidth;
    * ``"inter"`` — different nodes, NIC-class bandwidth with a much
      higher per-hop latency.

    The class is what the cost model, the simulator's link clocks, and
    the two-level dispatch emitter all key on — it must stay a pure
    function of the rank pair.
    """

    ranks_per_node: int = 4
    intra_gbps: float = 350.0         # matches AscendA3.link_gbps
    inter_gbps: float = 50.0          # RDMA-NIC-class uplink per rank
    intra_hop_us: float = 0.35        # per-message latency, intra-node
    inter_hop_us: float = 2.0         # per-message latency, cross-node

    def __post_init__(self) -> None:
        if self.ranks_per_node < 1:
            raise ValueError("ranks_per_node must be >= 1")
        if self.intra_gbps <= 0 or self.inter_gbps <= 0:
            raise ValueError("link bandwidths must be positive")
        if self.intra_hop_us < 0 or self.inter_hop_us < 0:
            raise ValueError("hop latencies must be non-negative")

    def node_of(self, rank: int) -> int:
        return rank // self.ranks_per_node

    def n_nodes(self, ep: int) -> int:
        if ep % self.ranks_per_node:
            raise ValueError(
                f"ep={ep} is not a multiple of ranks_per_node="
                f"{self.ranks_per_node}")
        return ep // self.ranks_per_node

    def same_node(self, a: int, b: int) -> bool:
        return self.node_of(a) == self.node_of(b)

    def link_class(self, src: int, dst: int) -> str:
        if src == dst:
            return "local"
        return "intra" if self.same_node(src, dst) else "inter"

    def bw_gbps(self, link_class: str) -> float:
        return self.intra_gbps if link_class == "intra" else self.inter_gbps

    def latency_us(self, link_class: str) -> float:
        return (self.intra_hop_us if link_class == "intra"
                else self.inter_hop_us)

    def key(self) -> tuple:
        """Hashable identity for schedule-cache keys (``core/ssc.py``)."""
        return (self.ranks_per_node, self.intra_gbps, self.inter_gbps,
                self.intra_hop_us, self.inter_hop_us)


@dataclasses.dataclass(frozen=True)
class TPUv5e:
    """Roofline constants per chip (fixed by the grading spec)."""

    peak_flops_bf16: float = 197e12   # FLOP/s
    hbm_gbps: float = 819e9           # bytes/s
    ici_link_gbps: float = 50e9       # bytes/s per link
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20     # VMEM — the L2-analogue reuse buffer
    mxu_dim: int = 128                # systolic array tile edge


A3 = AscendA3()
V5E = TPUv5e()
