"""RoutingPlan — imbalanced routing as a first-class scheduling input.

The paper's controlled Table-3 setting routes the *same* number of tokens
from every source rank to every (destination rank, local expert) pair, which
is why the seed reproduction could describe routing with one scalar
(``ScheduleConfig.rows``). Real MoE batches are skewed: per-expert load
varies per step, some (src, dst, expert) cells are empty, and hotspot
traffic concentrates on a few experts. A :class:`RoutingPlan` captures the
full per-cell row-count matrix plus the derived buffer layouts, so the whole
compile-and-execute stack (ODG extents, tile generation, dependency
derivation, executor buffers, simulator costs) can operate on genuinely
imbalanced traffic. The balanced plan is the trivial special case and
reproduces the seed's schedules exactly.

Layout conventions (shared by every layer):

* **send buffer** on source rank *s* — rows grouped by (dst rank, local
  expert), destination-major: block (d, e) starts at ``send_offset(s, d, e)``
  and holds ``count(s, d, e)`` rows.
* **recv buffer** on destination rank *d* — rows grouped by (local expert,
  src rank), expert-major so each expert's rows are contiguous for the GMM:
  block (e, s) starts at ``recv_offset(d, e, s)``.

Plans are immutable and hashable (SSC-cache friendly); all offsets are
precomputed once per plan.
"""

from __future__ import annotations

import dataclasses
import functools
from functools import cached_property

import numpy as np


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _balanced_groups(sizes: list[int], k: int) -> list[int]:
    """Row counts of ≤ ``k`` contiguous, greedily cost-balanced cell groups.

    Partitions the ordered ``sizes`` sequence into at most ``k`` nonempty
    contiguous groups, closing a group once it reaches its fair share of the
    rows still ungrouped (or once only one cell per remaining group is
    left). Cells are never split, so every group boundary is a legal cut.
    """
    k = max(1, min(k, len(sizes)))
    total = sum(sizes)
    groups: list[int] = []
    acc = 0
    done = 0
    for i, c in enumerate(sizes):
        acc += c
        cells_left = len(sizes) - i - 1
        groups_left = k - len(groups) - 1
        if groups_left == 0:
            continue
        target = (total - done) / (groups_left + 1)
        if acc >= target or cells_left <= groups_left:
            groups.append(acc)
            done += acc
            acc = 0
    if acc:
        groups.append(acc)
    return groups


def _source_aligned_chunks(cells: list[int], m_split: int) -> list[int]:
    """Row counts of ≤ ``m_split`` single-trigger-safe chunks of one expert
    block whose nonzero source cells have ``cells`` rows (src order).

    With ``m_split`` ≤ the cell count, cells are greedily *grouped* into
    row-balanced chunks (boundaries only on cell edges). With a larger
    budget, cells are *refined*: each cell gets a piece budget proportional
    to its size (extra pieces go to the cell with the currently largest
    piece) and is cut evenly within itself. A chunk is therefore either a
    union of whole cells or strictly inside one cell — in both cases every
    dispatch cell feeds exactly one consumer event group.
    """
    k = max(1, m_split)
    if k <= len(cells):
        return _balanced_groups(cells, k)
    # Refinement budget: every cell gets one piece, and the k - n spare
    # pieces go one at a time to the cell with the largest current piece —
    # sum(pieces) never exceeds k, so the tile budget holds exactly.
    pieces = [1] * len(cells)
    spare = k - len(cells)
    while spare > 0:
        splittable = [i for i in range(len(cells)) if pieces[i] < cells[i]]
        if not splittable:
            break
        i = max(splittable, key=lambda i: cells[i] / pieces[i])
        pieces[i] += 1
        spare -= 1
    chunks: list[int] = []
    for c, p in zip(cells, pieces):
        piece = _ceil_div(c, p)
        lo = 0
        while lo < c:
            hi = min(lo + piece, c)
            chunks.append(hi - lo)
            lo = hi
    return chunks


def _node_atom_chunks(atoms: list[list[int]], m_split: int) -> list[int]:
    """Row counts of ≤ ``m_split`` chunks over node-grouped atoms.

    Each atom is the ordered cell list of either one same-node source cell
    or one remote node's aggregated cells (the write range of a single
    inter-node message). Grouping treats atoms as indivisible; refinement
    hands each oversized atom a proportional piece budget and recurses into
    :func:`_source_aligned_chunks` over *its* cells — so every chunk is a
    union of whole atoms, a union of whole cells inside one atom, or
    strictly inside one cell. All three keep both the aggregated-message
    producer and the per-cell combine consumers on single-event boundaries.
    """
    sizes = [sum(a) for a in atoms]
    k = max(1, m_split)
    if k <= len(atoms):
        return _balanced_groups(sizes, k)
    pieces = [1] * len(atoms)
    spare = k - len(atoms)
    while spare > 0:
        splittable = [i for i in range(len(atoms)) if pieces[i] < sizes[i]]
        if not splittable:
            break
        i = max(splittable, key=lambda i: sizes[i] / pieces[i])
        pieces[i] += 1
        spare -= 1
    chunks: list[int] = []
    for a, p in zip(atoms, pieces):
        if p <= 1:
            chunks.append(sum(a))
        else:
            chunks.extend(_source_aligned_chunks(a, p))
    return chunks


@dataclasses.dataclass(frozen=True)
class RoutingPlan:
    """Per-(src rank, dst rank, local expert) routed-row counts."""

    # counts[src][dst][local_expert] — nested tuples so the plan is hashable.
    counts: tuple

    # -- construction -------------------------------------------------------
    @classmethod
    def from_counts(cls, counts) -> "RoutingPlan":
        arr = np.asarray(counts, dtype=np.int64)
        if arr.ndim != 3 or arr.shape[0] != arr.shape[1]:
            raise ValueError(
                f"counts must be [ep, ep, e_loc], got shape {arr.shape}")
        if (arr < 0).any():
            raise ValueError("routed-row counts must be non-negative")
        return cls(counts=tuple(
            tuple(tuple(int(x) for x in dst) for dst in src) for src in arr))

    @classmethod
    def balanced(cls, ep: int, e_loc: int, rows: int) -> "RoutingPlan":
        """The paper's controlled setting: every cell carries ``rows``."""
        return balanced_plan(ep, e_loc, rows)

    # -- basic geometry -----------------------------------------------------
    @property
    def ep(self) -> int:
        return len(self.counts)

    @property
    def e_loc(self) -> int:
        return len(self.counts[0][0])

    @cached_property
    def _c(self) -> np.ndarray:
        return np.asarray(self.counts, dtype=np.int64)

    @cached_property
    def _send_off(self) -> np.ndarray:
        """[src, dst, e] start row within the source send buffer."""
        flat = self._c.reshape(self.ep, -1)
        off = np.zeros_like(flat)
        off[:, 1:] = np.cumsum(flat, axis=1)[:, :-1]
        out = off.reshape(self._c.shape)
        # Plans are shared (lru-cached balanced plan); a consumer writing
        # into the exposed table would corrupt every later compile.
        out.setflags(write=False)
        return out

    @cached_property
    def _recv_off(self) -> np.ndarray:
        """[dst, e, src] start row within the destination recv buffer."""
        per_dst = np.ascontiguousarray(np.transpose(self._c, (1, 2, 0)))
        flat = per_dst.reshape(self.ep, -1)
        off = np.zeros_like(flat)
        off[:, 1:] = np.cumsum(flat, axis=1)[:, :-1]
        out = off.reshape(per_dst.shape)
        out.setflags(write=False)
        return out

    # -- row accounting -----------------------------------------------------
    def count(self, src: int, dst: int, e: int) -> int:
        return int(self._c[src, dst, e])

    def send_rows(self, src: int) -> int:
        """Total rows in ``src``'s send (and return) buffer."""
        return int(self._c[src].sum())

    def recv_rows(self, dst: int) -> int:
        """Total rows in ``dst``'s dispatch-receive buffer."""
        return int(self._c[:, dst].sum())

    def expert_rows(self, rank: int, e: int) -> int:
        """Rows local expert ``e`` on ``rank`` processes (all sources)."""
        return int(self._c[:, rank, e].sum())

    def expert_offset(self, rank: int, e: int) -> int:
        """Start row of expert ``e``'s contiguous block in the recv buffer."""
        return int(self._recv_off[rank, e, 0])

    def send_offset(self, src: int, dst: int, e: int) -> int:
        return int(self._send_off[src, dst, e])

    def recv_offset(self, dst: int, e: int, src: int) -> int:
        return int(self._recv_off[dst, e, src])

    @property
    def send_offsets(self) -> np.ndarray:
        """Full [src, dst, e] start-row table (for vectorized consumers)."""
        return self._send_off

    @property
    def recv_offsets(self) -> np.ndarray:
        """Full [dst, e, src] start-row table (for vectorized consumers)."""
        return self._recv_off

    # -- cell enumeration (zero cells are skipped everywhere) ---------------
    def send_cells(self, src: int) -> list[tuple[int, int, int]]:
        """Nonzero (dst, e, count), destination-major = send-buffer order."""
        return [(d, e, int(self._c[src, d, e]))
                for d in range(self.ep) for e in range(self.e_loc)
                if self._c[src, d, e] > 0]

    def combine_cells(self, rank: int) -> list[tuple[int, int, int]]:
        """Nonzero (src, e, count) returned by ``rank``, source-major."""
        return [(s, e, int(self._c[s, rank, e]))
                for s in range(self.ep) for e in range(self.e_loc)
                if self._c[s, rank, e] > 0]

    def recv_layout_cells(self, rank: int) -> list[tuple[int, int, int]]:
        """Nonzero (e, src, count) in recv-buffer (expert-major) order."""
        return [(e, s, int(self._c[s, rank, e]))
                for e in range(self.e_loc) for s in range(self.ep)
                if self._c[s, rank, e] > 0]

    def n_send_cells(self, src: int) -> int:
        return int((self._c[src] > 0).sum())

    def n_combine_cells(self, rank: int) -> int:
        return int((self._c[:, rank] > 0).sum())

    # -- tile generation ----------------------------------------------------
    def _tile_atoms(self, rank: int, e: int, atom_nodes: int,
                    agg_rows: float | None = None) -> list[list[int]]:
        """Nested row atoms for expert ``e`` under two-level dispatch.

        With hierarchical dispatch the producer of the recv rows from a
        *remote node* is one aggregated inter-node put covering every
        source rank of that node, so tile boundaries may not fall across
        its span unless they stay inside it: each *aggregated* remote-node
        group contributes one atom carrying its per-source cell list,
        while same-node sources — and remote cells whose group stays on
        the direct path (see :func:`aggregate_group`) — keep single-cell
        atoms, their producers being per-cell flat puts. The
        src-ascending recv layout makes both kinds contiguous.
        """
        atoms: list[list[int]] = []
        my_node = rank // atom_nodes
        s = 0
        while s < self.ep:
            node = s // atom_nodes
            if node == my_node:
                c = int(self._c[s, rank, e])
                if c:
                    atoms.append([c])
                s += 1
            else:
                hi = (node + 1) * atom_nodes
                cells = [int(self._c[t, rank, e]) for t in range(s, hi)
                         if self._c[t, rank, e] > 0]
                if aggregate_group(cells, agg_rows):
                    atoms.append(cells)
                else:
                    atoms.extend([c] for c in cells)
                s = hi
        return atoms

    def gmm_tiles(self, rank: int, m_split: int = 1,
                  mode: str = "even",
                  atom_nodes: int | None = None,
                  agg_rows: float | None = None,
                  ) -> list[tuple[int, int, int, int]]:
        """(e, m, lo, hi) recv-buffer row ranges for GMM/vector tiles.

        ``mode="even"`` cuts each nonzero expert block into at most
        ``m_split`` chunks of ``ceil(rows / m_split)`` rows; the last chunk
        is ragged, so no rows are ever dropped. Empty experts produce no
        tiles. For the balanced plan with ``m_split | rows`` this reduces to
        the seed's even grid — but on an arbitrary imbalanced plan the even
        boundaries straddle dispatch-cell boundaries and the scheduler
        rejects the schedule (single-trigger violation).

        ``mode="source_aligned"`` respects the source-cell structure of the
        src-major recv layout: with ``m_split`` at or below the number of
        nonzero cells, cells are greedily grouped into ≤ ``m_split``
        row-balanced chunks whose boundaries lie only on source-cell edges
        — every tile is a union of whole dispatch cells. With a larger
        budget, oversized cells are additionally refined by even cuts
        *strictly inside* one cell (budget apportioned by cell size, still
        ≤ ``m_split`` tiles total). Either way each producer cell overlaps
        exactly the consumer tiles of a single event group, so the
        single-trigger invariant holds for *any* plan, however skewed — a
        hotspot cell carrying most of a rank's tokens gets fine-grained
        tiles instead of one monolithic chain.
        """
        if mode not in ("even", "source_aligned"):
            raise ValueError(f"unknown gmm split mode {mode!r}")
        if atom_nodes is not None and mode != "source_aligned":
            raise ValueError(
                "node-grouped tiling atoms require mode='source_aligned'")
        tiles: list[tuple[int, int, int, int]] = []
        for e in range(self.e_loc):
            rows = self.expert_rows(rank, e)
            if rows == 0:
                continue
            base = self.expert_offset(rank, e)
            if mode == "even":
                chunk = _ceil_div(rows, max(1, m_split))
                lo, m = 0, 0
                while lo < rows:
                    hi = min(lo + chunk, rows)
                    tiles.append((e, m, base + lo, base + hi))
                    lo, m = hi, m + 1
                continue
            if atom_nodes is None:
                cells = [int(self._c[s, rank, e]) for s in range(self.ep)
                         if self._c[s, rank, e] > 0]
                chunks = _source_aligned_chunks(cells, m_split)
            else:
                chunks = _node_atom_chunks(
                    self._tile_atoms(rank, e, atom_nodes, agg_rows), m_split)
            lo = 0
            for m, group_rows in enumerate(chunks):
                tiles.append((e, m, base + lo, base + lo + group_rows))
                lo += group_rows
        return tiles

    def n_gmm_tiles(self, rank: int, m_split: int = 1,
                    mode: str = "even", atom_nodes: int | None = None,
                    agg_rows: float | None = None) -> int:
        return len(self.gmm_tiles(rank, m_split, mode, atom_nodes, agg_rows))

    # -- skew diagnostics ---------------------------------------------------
    @property
    def total_rows(self) -> int:
        return int(self._c.sum())

    def is_balanced(self) -> bool:
        return bool((self._c == self._c.flat[0]).all())

    def expert_imbalance(self) -> float:
        """max / mean load over all (rank, expert) slots (1.0 = balanced)."""
        loads = self._c.sum(axis=0).reshape(-1).astype(np.float64)
        mean = loads.mean()
        return float(loads.max() / mean) if mean > 0 else 1.0

    def rank_imbalance(self) -> float:
        """max / mean recv rows over ranks (straggler potential)."""
        loads = self._c.sum(axis=(0, 2)).astype(np.float64)
        mean = loads.mean()
        return float(loads.max() / mean) if mean > 0 else 1.0


def aggregate_group(cells: list[int], agg_rows: float | None) -> bool:
    """Should a remote-node (dst, expert) group take the aggregated path?

    ``cells`` are the group's nonzero per-source row counts; ``agg_rows``
    is the row count whose inter-node transfer time equals one inter-node
    hop latency (``inter_hop_us * inter_gbps / row_bytes``), or None for
    aggregate-everything.

    Aggregation saves ``(len(cells) - 1)`` per-message hop latencies on the
    inter-node NIC but costs pipelining: the destination's GMM tiles wait
    for the *whole* aggregated message where flat dispatch streams
    per-cell. So aggregate exactly when the latency saved covers the
    serialization exposed — total rows within ``(n_cells - 1) * agg_rows``
    — and never for singleton groups, where the extra intra-node hop buys
    nothing. Latency-bound sparse traffic aggregates; byte-bound hot cells
    stay on the direct per-cell path and keep fine-grained overlap.
    """
    if len(cells) < 2:
        return False
    if agg_rows is None:
        return True
    return sum(cells) <= (len(cells) - 1) * agg_rows


class HierDispatch:
    """Two-level dispatch geometry for one (plan, node_size) pair.

    Maps the flat per-cell dispatch onto DeepEP-style hierarchical
    transfers. Tokens from source node *A* bound for a remote (dst rank
    ``d``, expert ``e``) are first gathered — per source cell, over the
    fast intra-node links — into a staging buffer on a *leader* rank of
    node *A*, then take the slow inter-node hop as **one** aggregated
    message per (leader, d, e) group.

    Aggregation is selective: only groups where :func:`aggregate_group`
    says the hop-latency amortization beats the lost per-cell pipelining
    (under the ``agg_rows`` threshold the cost model derives from the
    topology) are staged; everything else keeps the flat direct path.

    Leadership is spread over the node by hashing the (d, e) group:
    ``leader(A, d, e) = A*R + (d*e_loc + e) % R`` — so a node's
    inter-node egress is balanced across its R ranks instead of
    serialising through one NIC.

    Boundary contract (what makes the tasks legal for the scheduler's
    single-trigger event machinery):

    * every gather task copies exactly one dispatch cell, so each gather
      is consumed by exactly one inter-node group task;
    * the staging buffer on a leader is laid out (d, e)-major with the
      node's sources ascending inside a group — so every group is one
      contiguous input range;
    * the recv buffer is (e, src)-major, so a group's landing zone
      (expert ``e``, sources of node A) is one contiguous output range —
      bit-identical rows to what flat per-cell dispatch would deliver;
    * GMM tiles treat each aggregated group's rows as one indivisible
      atom (``RoutingPlan._tile_atoms``), so no tile boundary splits an
      aggregated message's write range.
    """

    def __init__(self, plan: RoutingPlan, node_size: int,
                 agg_rows: float | None = None):
        if node_size < 1 or plan.ep % node_size:
            raise ValueError(
                f"node_size={node_size} must divide ep={plan.ep}")
        self.plan = plan
        self.node_size = node_size
        self.n_nodes = plan.ep // node_size
        self.agg_rows = agg_rows
        self._layouts: dict[int, tuple] = {}

    def aggregated(self, src_node: int, d: int, e: int) -> bool:
        """Does (src_node → dst ``d``, expert ``e``) take the staged path?"""
        if src_node == d // self.node_size:
            return False
        p, R = self.plan, self.node_size
        cells = [p.count(s, d, e) for s in range(src_node * R,
                                                 (src_node + 1) * R)
                 if p.count(s, d, e) > 0]
        return aggregate_group(cells, self.agg_rows)

    # -- node arithmetic ----------------------------------------------------
    def node_of(self, rank: int) -> int:
        return rank // self.node_size

    def same_node(self, a: int, b: int) -> bool:
        return a // self.node_size == b // self.node_size

    def leader(self, src_node: int, d: int, e: int) -> int:
        return (src_node * self.node_size
                + (d * self.plan.e_loc + e) % self.node_size)

    # -- per-leader staging layout ------------------------------------------
    def _layout(self, leader: int) -> tuple:
        cached = self._layouts.get(leader)
        if cached is not None:
            return cached
        p, R = self.plan, self.node_size
        node = leader // R
        s_lo, s_hi = node * R, (node + 1) * R
        groups: list[tuple[int, int, tuple[tuple[int, int], ...], int]] = []
        group_off: dict[tuple[int, int], int] = {}
        cell_off: dict[tuple[int, int, int], int] = {}
        lo = 0
        for d in range(p.ep):
            if d // R == node:
                continue
            for e in range(p.e_loc):
                if self.leader(node, d, e) != leader:
                    continue
                srcs = tuple((s, p.count(s, d, e)) for s in range(s_lo, s_hi)
                             if p.count(s, d, e) > 0)
                if not aggregate_group([c for _, c in srcs], self.agg_rows):
                    continue
                group_off[(d, e)] = lo
                run = lo
                for s, c in srcs:
                    cell_off[(d, e, s)] = run
                    run += c
                groups.append((d, e, srcs, run - lo))
                lo = run
        out = (tuple(groups), group_off, cell_off, lo)
        self._layouts[leader] = out
        return out

    def stage_groups(self, leader: int):
        """Ordered (d, e, ((src, count), ...), total_rows) groups homed at
        ``leader`` — the staging-buffer layout, (d, e)-major."""
        return self._layout(leader)[0]

    def n_stage_groups(self, leader: int) -> int:
        return len(self._layout(leader)[0])

    def group_offset(self, leader: int, d: int, e: int) -> int:
        """Staging-buffer start row of the (d, e) group."""
        return self._layout(leader)[1][(d, e)]

    def cell_offset(self, leader: int, d: int, e: int, s: int) -> int:
        """Staging-buffer start row of source ``s``'s cell in group (d, e)."""
        return self._layout(leader)[2][(d, e, s)]

    def stage_rows(self, leader: int) -> int:
        """Total staging-buffer rows homed at ``leader``."""
        return self._layout(leader)[3]

    def recv_node_span(self, d: int, e: int, src_node: int) -> tuple[int, int]:
        """(lo, rows): the contiguous recv-buffer landing zone on ``d`` for
        expert ``e`` rows from every source rank of ``src_node``."""
        p, R = self.plan, self.node_size
        lo = p.recv_offset(d, e, src_node * R)
        rows = int(sum(p.count(s, d, e)
                       for s in range(src_node * R, (src_node + 1) * R)))
        return lo, rows


@functools.lru_cache(maxsize=256)
def balanced_plan(ep: int, e_loc: int, rows: int) -> RoutingPlan:
    """Cached trivial plan — ``ScheduleConfig.routing`` hits this per task."""
    return RoutingPlan.from_counts(np.full((ep, ep, e_loc), rows,
                                           dtype=np.int64))


# ---------------------------------------------------------------------------
# Plan generators for tests and benchmarks.
# ---------------------------------------------------------------------------

def skewed_plan(ep: int, e_loc: int, rows: int,
                alpha: float = 1.0) -> RoutingPlan:
    """Deterministic Zipf-like skew over global experts.

    Every source rank still emits ``ep * e_loc * rows`` rows total (token
    count is conserved); expert ``g`` receives a share ∝ ``(g+1)^-alpha``.
    ``alpha=0`` is the balanced plan; larger alpha concentrates load.
    Shares are apportioned by largest remainder so totals are exact.
    """
    n_slots = ep * e_loc
    total = n_slots * rows
    w = np.arange(1, n_slots + 1, dtype=np.float64) ** (-alpha)
    w /= w.sum()
    ideal = w * total
    base = np.floor(ideal).astype(np.int64)
    rem = total - int(base.sum())
    order = np.argsort(-(ideal - base))
    base[order[:rem]] += 1
    counts = np.broadcast_to(base.reshape(ep, e_loc),
                             (ep, ep, e_loc)).copy()
    return RoutingPlan.from_counts(counts)


def hotspot_plan(ep: int, e_loc: int, rows: int,
                 background: int = 0) -> RoutingPlan:
    """Hot (rank 0, expert 0) cell; token count per source is conserved.

    ``background=0`` (default) is the degenerate hotspot: every source sends
    *all* of its ``ep * e_loc * rows`` tokens to (rank 0, expert 0).
    ``background > 0`` keeps roughly that many rows in every other cell —
    source rank *s* keeps ``background + s`` (deterministically varied so
    the plan is *not* per-source-uniform): the realistic hot-expert profile
    where all ranks still receive traffic but rank 0 dominates, and where
    even chunk boundaries straddle source cells — ``gmm_m_split > 1`` then
    requires source-aligned tiling.
    """
    total = ep * e_loc * rows
    if background and (background + ep - 1) * (ep * e_loc - 1) > total:
        raise ValueError("background traffic exceeds per-source token count")
    counts = np.zeros((ep, ep, e_loc), dtype=np.int64)
    for s in range(ep):
        if background:
            counts[s, :, :] = background + s
        counts[s, 0, 0] = total - counts[s].sum() + counts[s, 0, 0]
    return RoutingPlan.from_counts(counts)


def node_limited_plan(ep: int, e_loc: int, rows: int,
                      node_size: int = 4, m_nodes: int = 1,
                      leak: float = 0.05) -> RoutingPlan:
    """Node-limited routing: each token's experts confined to ≤ M nodes.

    Source rank ``s`` routes a ``1 - leak`` share of its ``ep*e_loc*rows``
    token budget uniformly over the experts of its ``m_nodes`` *allowed*
    nodes (its own node plus the next ``m_nodes - 1`` on the node ring,
    the Pangu-Ultra-MoE node-limited profile) and spreads the remaining
    ``leak`` share thinly over every other slot — many tiny cross-node
    cells, the traffic shape where per-message latency dominates and
    hierarchical aggregation pays off most. Shares are apportioned by
    largest remainder, so per-source totals are exact.
    """
    if node_size < 1 or ep % node_size:
        raise ValueError(f"node_size={node_size} must divide ep={ep}")
    if not 0.0 <= leak < 1.0:
        raise ValueError(f"leak must be in [0, 1), got {leak}")
    n_nodes = ep // node_size
    m = max(1, min(m_nodes, n_nodes))
    total = ep * e_loc * rows
    counts = np.zeros((ep, ep, e_loc), dtype=np.int64)
    for s in range(ep):
        home = s // node_size
        allowed = {(home + j) % n_nodes for j in range(m)}
        in_slots = len(allowed) * node_size * e_loc
        out_slots = ep * e_loc - in_slots
        w = np.empty(ep * e_loc, dtype=np.float64)
        for d in range(ep):
            if d // node_size in allowed:
                wd = (1.0 - leak) / in_slots if out_slots else 1.0 / in_slots
            else:
                wd = leak / out_slots
            w[d * e_loc:(d + 1) * e_loc] = wd
        ideal = (w / w.sum()) * total
        base = np.floor(ideal).astype(np.int64)
        rem = total - int(base.sum())
        order = np.argsort(-(ideal - base), kind="stable")
        base[order[:rem]] += 1
        counts[s] = base.reshape(ep, e_loc)
    return RoutingPlan.from_counts(counts)


def random_plan(ep: int, e_loc: int, max_rows: int,
                rng: np.random.Generator,
                p_zero: float = 0.3) -> RoutingPlan:
    """Sparse random plan: each cell is 0 w.p. ``p_zero``, else U[1, max]."""
    counts = rng.integers(1, max_rows + 1, size=(ep, ep, e_loc))
    counts = np.where(rng.random((ep, ep, e_loc)) < p_zero, 0, counts)
    if counts.sum() == 0:           # keep at least one routed row
        counts[0, 0, 0] = max_rows
    return RoutingPlan.from_counts(counts)
