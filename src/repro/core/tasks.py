"""Tile Task Descriptors (TDs) and per-operator FillConfigs (§4.2, Table 1).

A TD is the basic runtime-consumed unit. ``FillConfig`` functions transform
an operator's legal tile tasks (count decided by split propagation) into
runtime-consumable TDs: tile row ranges, queue type, comm endpoints, and the
read/write sets used by the static scheduler for dependency derivation.

Read/write sets use the canonical *(tensor, rank, row range)* addressing of
``odg.TensorRef`` — an interval-overlap between a writer and a reader is a
true data dependency. Cross-rank communication tasks are sender-side tasks
(the AIV worker that issues ``put_mem_signal``) whose *writes* land on the
destination rank, mirroring one-sided remote-write semantics.

All tile extents are *plan-driven*: offsets and row counts come from the
config's :class:`~repro.core.routing.RoutingPlan`, so cells of an imbalanced
plan produce variable-extent tiles with exact read/write ranges, empty cells
produce no tasks at all, and non-divisible row counts produce a ragged last
tile instead of silently dropping remainder rows. The balanced plan emits
byte-identical TDs to the seed's fixed-grid arithmetic.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .odg import ODG, OperatorNode, ScheduleConfig, CTQ, VTQ

# Sentinel event id meaning "no event" (paper uses uint32 fields).
NO_EVENT = 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class Range:
    """A contiguous row range of (tensor, rank)."""

    tensor: str
    rank: int
    lo: int
    hi: int

    @property
    def rows(self) -> int:
        return self.hi - self.lo

    def overlaps(self, other: "Range") -> bool:
        return (self.tensor == other.tensor and self.rank == other.rank
                and self.lo < other.hi and other.lo < self.hi)


@dataclasses.dataclass
class TaskDescriptor:
    """Table 1 of the paper, plus the scheduler-facing read/write sets."""

    # --- Table 1 fields ---------------------------------------------------
    task_type: str               # GMM | SwiGLU | SwiGLUGrad | put_mem_signal…
    queue_type: str              # CTQ or VTQ
    dependent_event: int = NO_EVENT
    trigger_event: int = NO_EVENT
    inputs: list[Range] = dataclasses.field(default_factory=list)
    outputs: list[Range] = dataclasses.field(default_factory=list)
    task_index: int = 0
    task_split_num: int = 1
    task_split_value: int = 0    # rows per tile, used to derive tile ranges
    tiling_data_position: int = 0
    # --- framework metadata ------------------------------------------------
    op_name: str = ""
    op_type: str = ""
    rank: int = 0                # executing rank (sender side for comm)
    meta: dict = dataclasses.field(default_factory=dict)
    # Threshold the dependent event counter must reach (paper §4.3).
    dependent_threshold: int = 0
    # Globally unique id assigned by the scheduler.
    tid: int = -1

    # Cost model hooks (filled by FillConfig; consumed by the simulator).
    flops: float = 0.0
    read_bytes: float = 0.0
    write_bytes: float = 0.0
    comm_bytes: float = 0.0
    src_rank: int = -1
    dst_rank: int = -1


# ---------------------------------------------------------------------------
# FillConfig registry
# ---------------------------------------------------------------------------

_FILL_REGISTRY: dict[str, "callable"] = {}


def fill_config(op_type: str):
    def deco(fn):
        _FILL_REGISTRY[op_type] = fn
        return fn
    return deco


def fill_tasks(g: ODG, op: OperatorNode) -> list[TaskDescriptor]:
    fn = _FILL_REGISTRY.get(op.op_type)
    if fn is None:
        raise KeyError(f"no FillConfig registered for op_type={op.op_type}")
    tds = fn(g.cfg, op)
    # Ragged tiling may emit fewer tiles than propagation requested (e.g.
    # rows < task_num); sync the operator so task_num always matches the
    # emitted tile set.
    op.task_num = len(tds)
    for i, td in enumerate(tds):
        td.op_name = op.name
        td.op_type = op.op_type
        td.rank = op.rank
        td.task_index = i
        td.task_split_num = len(tds)
    return tds


def _db(cfg: ScheduleConfig) -> int:
    return cfg.dtype_bytes


# -- Dispatch / Combine: put_mem_signal communication tasks ------------------

@fill_config("dispatch")
def _fill_dispatch(cfg: ScheduleConfig, op: OperatorNode) -> list[TaskDescriptor]:
    """One put_mem_signal per nonzero (dst rank, local expert) plan cell.

    Source layout groups rows by (dst, expert); destination layout groups by
    (expert, src) so that each expert's rows are contiguous for the GMM.
    """
    plan = cfg.routing
    r = op.rank
    src_t, dst_t = op.inputs[0], op.outputs[0]
    row_b = src_t.row_bytes
    base_src = src_t.name.split("@")[0]
    base_dst = dst_t.name.split("@")[0]
    cells = plan.send_cells(r)               # (dst, e, count), dst-major
    if not cells:
        return []
    hier = cfg.hier
    base_stg = base_dst + "_stg"
    # Dispatch is a partitioning origin (split_inputs=None), so it never
    # falls back to one unsplit task: always one exact TD per nonzero cell.
    tds = []
    for (d, e, c) in cells:
        s_lo = plan.send_offset(r, d, e)
        if (hier is not None and not hier.same_node(r, d)
                and hier.aggregated(hier.node_of(r), d, e)):
            # Two-level dispatch, stage 1: gather this cell into the
            # (dst, expert) group's staging slot on the node leader —
            # an intra-node hop.
            leader = hier.leader(hier.node_of(r), d, e)
            g_lo = hier.cell_offset(leader, d, e, r)
            tds.append(TaskDescriptor(
                task_type="put_mem_signal", queue_type=VTQ,
                inputs=[Range(base_src, r, s_lo, s_lo + c)],
                outputs=[Range(base_stg, leader, g_lo, g_lo + c)],
                task_split_value=c,
                comm_bytes=c * row_b, src_rank=r, dst_rank=leader,
                read_bytes=c * row_b, write_bytes=c * row_b,
                meta={"expert": e, "dst": d, "comm_kind": "dispatch",
                      "stage": "gather", "dst_node": hier.node_of(d)}))
            continue
        d_lo = plan.recv_offset(d, e, r)
        tds.append(TaskDescriptor(
            task_type="put_mem_signal", queue_type=VTQ,
            inputs=[Range(base_src, r, s_lo, s_lo + c)],
            outputs=[Range(base_dst, d, d_lo, d_lo + c)],
            task_split_value=c,
            comm_bytes=c * row_b, src_rank=r, dst_rank=d,
            read_bytes=c * row_b, write_bytes=c * row_b,
            meta={"expert": e, "dst": d, "comm_kind": "dispatch"}))
    return tds


@fill_config("dispatch_xnode")
def _fill_dispatch_xnode(cfg: ScheduleConfig,
                         op: OperatorNode) -> list[TaskDescriptor]:
    """Two-level dispatch, stage 2: one aggregated inter-node put per
    (dst rank, expert) group staged at this node-leader rank.

    The staging buffer is (d, e)-major with sources ascending inside a
    group, and the destination recv buffer is (expert, src)-major — so one
    contiguous staging range lands in one contiguous recv range, row-for-row
    identical to what flat per-cell dispatch would have delivered.
    """
    from repro.parallel.compression import int8_wire_bytes

    hier = cfg.hier
    leader = op.rank
    stg_t, dst_t = op.inputs[0], op.outputs[0]
    row_b = stg_t.row_bytes
    base_stg = stg_t.name.split("@")[0]
    base_dst = dst_t.name.split("@")[0]
    src_node = hier.node_of(leader)
    tds = []
    for (d, e, _srcs, total) in hier.stage_groups(leader):
        g_lo = hier.group_offset(leader, d, e)
        d_lo, rows = hier.recv_node_span(d, e, src_node)
        assert rows == total
        nbytes = total * row_b
        comm = nbytes
        meta = {"expert": e, "dst": d, "comm_kind": "dispatch",
                "stage": "xnode", "dst_node": hier.node_of(d)}
        if cfg.xnode_compress == "int8":
            comm = int8_wire_bytes(nbytes, cfg.dtype_bytes)
            meta["compress"] = "int8"
        tds.append(TaskDescriptor(
            task_type="put_mem_signal", queue_type=VTQ,
            inputs=[Range(base_stg, leader, g_lo, g_lo + total)],
            outputs=[Range(base_dst, d, d_lo, d_lo + total)],
            task_split_value=total,
            comm_bytes=comm, src_rank=leader, dst_rank=d,
            read_bytes=nbytes, write_bytes=nbytes,
            meta=meta))
    return tds


@fill_config("combine")
def _fill_combine(cfg: ScheduleConfig, op: OperatorNode) -> list[TaskDescriptor]:
    """One put_mem_signal per nonzero (source rank, local expert) cell."""
    plan = cfg.routing
    r = op.rank
    src_t, ret_t = op.inputs[0], op.outputs[0]
    row_b = src_t.row_bytes
    base_src = src_t.name.split("@")[0]
    base_ret = ret_t.name.split("@")[0]
    cells = plan.combine_cells(r)            # (src, e, count), src-major
    if not cells:
        return []
    if op.task_num == 1 and len(cells) > 1:
        # Fallback: outputs ordered to match the (e, src)-major input layout
        # so a sequential block copy is numerically correct.
        outs = [Range(base_ret, s, plan.send_offset(s, r, e),
                      plan.send_offset(s, r, e) + c)
                for (e, s, c) in plan.recv_layout_cells(r)]
        total = plan.recv_rows(r)
        return [TaskDescriptor(
            task_type="put_mem_signal", queue_type=VTQ,
            inputs=[Range(base_src, r, 0, total)],
            outputs=outs,
            task_split_value=total,
            comm_bytes=total * row_b, src_rank=r, dst_rank=-1,
            read_bytes=total * row_b, write_bytes=total * row_b,
            meta={"fallback": True, "comm_kind": "combine"})]
    tds = []
    for (s, e, c) in cells:
        y_lo = plan.recv_offset(r, e, s)     # expert-major on this rank
        ret_lo = plan.send_offset(s, r, e)   # (dst=r, expert) on source s
        tds.append(TaskDescriptor(
            task_type="put_mem_signal", queue_type=VTQ,
            inputs=[Range(base_src, r, y_lo, y_lo + c)],
            outputs=[Range(base_ret, s, ret_lo, ret_lo + c)],
            task_split_value=c,
            comm_bytes=c * row_b, src_rank=r, dst_rank=s,
            read_bytes=c * row_b, write_bytes=c * row_b,
            meta={"expert": e, "dst": s, "comm_kind": "combine"}))
    return tds


# -- GMM: expert-block tiles (full-K reduction) ------------------------------

def _gmm_tiles(cfg: ScheduleConfig, op: OperatorNode,
               task_type: str) -> list[TaskDescriptor]:
    plan = cfg.routing
    r = op.rank
    in_t, w_t = op.inputs[0], op.inputs[1]
    out_t = op.outputs[0]
    base_in = in_t.name.split("@")[0]
    base_w = w_t.name.split("@")[0]
    base_out = out_t.name.split("@")[0]
    in_row_b, out_row_b = in_t.row_bytes, out_t.row_bytes

    if op.task_num == 1:
        if in_t.rows == 0:
            return []
        k = in_row_b // _db(cfg)
        n = out_row_b // _db(cfg)
        return [TaskDescriptor(
            task_type=task_type, queue_type=CTQ,
            inputs=[Range(base_in, r, 0, in_t.rows),
                    Range(base_w, r, 0, w_t.rows)],
            outputs=[Range(base_out, r, 0, out_t.rows)],
            task_split_value=in_t.rows,
            flops=2.0 * in_t.rows * k * n,
            read_bytes=in_t.rows * in_row_b + w_t.rows * w_t.row_bytes,
            write_bytes=out_t.rows * out_row_b,
            meta={"fallback": True, **op.meta})]

    tds = []
    # Ragged expert-block tiles: ≤ gmm_m_split chunks per nonzero expert
    # (even or source-aligned boundaries per cfg.gmm_split_mode), last chunk
    # ragged — every routed row is covered exactly once.
    for (e, m, lo, hi) in plan.gmm_tiles(r, cfg.gmm_m_split,
                                         cfg.gmm_split_mode,
                                         cfg.tile_atom_nodes,
                                         cfg.tile_agg_rows):
        chunk = hi - lo
        k = in_row_b // _db(cfg)
        n = out_row_b // (_db(cfg) if task_type != "GMMWGrad" else 4)
        if task_type == "GMMWGrad":
            # dW[e] = act[e]^T @ grad[e]; "rows" of the weight tensor are
            # expert blocks; all m-chunks of expert e accumulate into it.
            out_rng = Range(base_out, r, e, e + 1)
            flops = 2.0 * chunk * k * (op.inputs[1].row_bytes // _db(cfg))
            reads = [Range(base_in, r, lo, hi),
                     Range(op.inputs[1].name.split("@")[0], r, lo, hi)]
            wbytes = out_t.row_bytes
        else:
            out_rng = Range(base_out, r, lo, hi)
            flops = 2.0 * chunk * k * n
            reads = [Range(base_in, r, lo, hi),
                     Range(base_w, r, e, e + 1)]
            wbytes = chunk * out_row_b
        tds.append(TaskDescriptor(
            task_type=task_type, queue_type=CTQ,
            inputs=reads, outputs=[out_rng],
            task_split_value=chunk,
            flops=flops,
            read_bytes=chunk * in_row_b + w_t.row_bytes,
            write_bytes=wbytes,
            meta={"expert": e, "m": m, **op.meta}))
    return tds


@fill_config("gmm")
def _fill_gmm(cfg: ScheduleConfig, op: OperatorNode) -> list[TaskDescriptor]:
    return _gmm_tiles(cfg, op, "GMM")


@fill_config("gmm_wgrad")
def _fill_gmm_wgrad(cfg: ScheduleConfig, op: OperatorNode) -> list[TaskDescriptor]:
    return _gmm_tiles(cfg, op, "GMMWGrad")


# -- Vector elementwise ops aligned to GMM row partitions --------------------

def _rowwise_tiles(cfg: ScheduleConfig, op: OperatorNode,
                   task_type: str) -> list[TaskDescriptor]:
    r = op.rank
    in_t = op.inputs[0]
    out_t = op.outputs[0]
    base_in = in_t.name.split("@")[0]
    base_out = out_t.name.split("@")[0]
    extra = [t for t in op.inputs[1:]]

    if op.task_num == 1:
        if in_t.rows == 0:
            return []
        reads = [Range(base_in, r, 0, in_t.rows)] + [
            Range(t.name.split("@")[0], r, 0, t.rows) for t in extra]
        return [TaskDescriptor(
            task_type=task_type, queue_type=VTQ,
            inputs=reads,
            outputs=[Range(base_out, r, 0, out_t.rows)],
            task_split_value=in_t.rows,
            read_bytes=sum(t.nbytes for t in op.inputs),
            write_bytes=out_t.nbytes,
            meta={"fallback": True})]

    if op.meta.get("plan_tiling") == "expert":
        # MoE-graph vector ops tile exactly like the GMMs they feed/follow —
        # plan-driven expert blocks with ragged m-chunks, so tile boundaries
        # stay aligned and the single-trigger invariant holds under skew.
        ranges = [(lo, hi, {"expert": e, "m": m})
                  for (e, m, lo, hi)
                  in cfg.routing.gmm_tiles(r, cfg.gmm_m_split,
                                           cfg.gmm_split_mode,
                                           cfg.tile_atom_nodes,
                                           cfg.tile_agg_rows)]
    else:
        # Generic even row split with a ragged last tile (no row dropped).
        chunk = -(-in_t.rows // op.task_num)
        bounds = []
        lo = 0
        while lo < in_t.rows:
            bounds.append((lo, min(lo + chunk, in_t.rows)))
            lo = bounds[-1][1]
        n = len(bounds)           # actual tile count (≤ requested)
        ranges = [(lo, hi, {"expert": i // max(1, n // cfg.e_loc)})
                  for i, (lo, hi) in enumerate(bounds)]
    tds = []
    for (lo, hi, meta) in ranges:
        chunk = hi - lo
        reads = [Range(base_in, r, lo, hi)] + [
            Range(t.name.split("@")[0], r, lo, hi) for t in extra]
        tds.append(TaskDescriptor(
            task_type=task_type, queue_type=VTQ,
            inputs=reads,
            outputs=[Range(base_out, r, lo, hi)],
            task_split_value=chunk,
            read_bytes=chunk * in_t.row_bytes
            + sum(chunk * t.row_bytes for t in extra),
            write_bytes=chunk * out_t.row_bytes,
            meta=meta))
    return tds


@fill_config("swiglu")
def _fill_swiglu(cfg: ScheduleConfig, op: OperatorNode) -> list[TaskDescriptor]:
    return _rowwise_tiles(cfg, op, "SwiGLU")


@fill_config("swiglu_grad")
def _fill_swiglu_grad(cfg: ScheduleConfig, op: OperatorNode) -> list[TaskDescriptor]:
    return _rowwise_tiles(cfg, op, "SwiGLUGrad")


# Generic elementwise ops used by the §6 microbenchmarks.
@fill_config("elementwise")
def _fill_elementwise(cfg: ScheduleConfig, op: OperatorNode) -> list[TaskDescriptor]:
    return _rowwise_tiles(cfg, op, op.meta.get("task_type", "Elementwise"))
