"""AdamW with cosine schedule, global-norm clipping and grad accumulation.

Self-contained (no optax in this environment). State is a pytree of the same
structure as params — m/v in fp32 — so the checkpoint layer and sharding
rules apply uniformly. ``grad_transform`` hooks (e.g. cross-pod gradient
compression) run before the moment update.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, oc.warmup_steps)
    prog = (step - oc.warmup_steps) / jnp.maximum(
        1.0, oc.total_steps - oc.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


def cast_params(params, dtype=jnp.bfloat16):
    """Compute-precision copy of the parameter tree (float leaves only)."""
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating)
        else p, params)


def init_opt_state(params):
    """m/v moments + fp32 master weights (params at the step boundary are
    the bf16 compute copies; masters only appear in the update math)."""
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "master": jax.tree.map(
                lambda p: p.astype(jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state, oc: OptConfig,
                  grad_transform: Optional[Callable] = None):
    """One AdamW step on the fp32 masters; returns the refreshed compute
    (bf16) params. Returns (new_params, new_state, metrics)."""
    if grad_transform is not None:
        grads = grad_transform(grads)
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    step = state["step"] + 1
    lr = schedule(oc, step)
    b1, b2 = oc.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    # Separate maps (not one map returning tuples): param trees may contain
    # tuple nodes (hybrid 'super' stacks), so tuple leaves are ambiguous.
    def new_m_fn(g, m):
        return b1 * m + (1 - b1) * g.astype(jnp.float32)

    def new_v_fn(g, v):
        g = g.astype(jnp.float32)
        return b2 * v + (1 - b2) * g * g

    new_m = jax.tree.map(new_m_fn, grads, state["m"])
    new_v = jax.tree.map(new_v_fn, grads, state["v"])

    def upd(master, m, v):
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
        if master.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * master
        return master - lr * delta

    new_master = jax.tree.map(upd, state["master"], new_m, new_v)
    new_params = jax.tree.map(
        lambda p, mst: mst.astype(p.dtype), params, new_master)
    return new_params, {"m": new_m, "v": new_v, "master": new_master,
                        "step": step}, {"grad_norm": gnorm, "lr": lr}


def accumulate_grads(loss_and_grad_fn, params, microbatches):
    """Microbatch gradient accumulation via lax.scan (fixed microbatch dim).

    ``microbatches``: pytree with leading [n_micro, ...] dims.
    """
    def body(acc, mb):
        loss, grads = loss_and_grad_fn(params, mb)
        acc_g, acc_l = acc
        return (jax.tree.map(jnp.add, acc_g, grads), acc_l + loss), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g, l), _ = jax.lax.scan(body, (zero, 0.0), microbatches)  # noqa: E741
    n = jax.tree.leaves(microbatches)[0].shape[0]
    return l / n, jax.tree.map(lambda x: x / n, g)
