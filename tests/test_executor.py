"""Numerical executor vs monolithic references — the correctness backbone."""

import numpy as np
import pytest

from repro.core import executor as ex
from repro.core.odg import (ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.scheduler import compile_schedule

CFG = ScheduleConfig(ep=3, e_loc=2, rows=4, d_model=24, d_ff=12,
                     gmm_m_split=3)


def _forward_state(cfg, seed=0):
    x_src, w1, w2 = ex.make_inputs(cfg, seed)
    st = ex.ExecutorState(cfg)
    ex.load_forward_state(cfg, st, x_src, w1, w2)
    return x_src, w1, w2, st


@pytest.mark.parametrize("ratr", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_forward_matches_reference(ratr, seed):
    s = compile_schedule(build_moe_ffn_forward(CFG), ratr=ratr)
    x_src, w1, w2, st = _forward_state(CFG)
    ex.execute(s, st, rng=np.random.default_rng(seed))
    ref = ex.reference_forward(CFG, x_src, w1, w2)
    for name in ("x_recv", "h", "g", "y", "y_ret"):
        got = np.stack([st.get(name, r) for r in range(CFG.ep)])
        np.testing.assert_allclose(got, ref[name], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("interleave", [False, True])
def test_backward_matches_vjp(interleave):
    s = compile_schedule(build_moe_ffn_backward(CFG), ratr=True,
                         gmm_interleave=interleave)
    x_src, w1, w2, _ = _forward_state(CFG)
    fwd = ex.reference_forward(CFG, x_src, w1, w2)
    dy = np.random.default_rng(7).standard_normal(
        fwd["y_ret"].shape).astype(np.float32)
    st = ex.ExecutorState(CFG)
    ex.load_backward_state(CFG, st, fwd, w1, w2, dy)
    ex.execute(s, st, rng=np.random.default_rng(3))
    dx_ref, dw1_ref, dw2_ref = ex.reference_backward(CFG, x_src, w1, w2, dy)
    np.testing.assert_allclose(
        np.stack([st.get("dx_ret", r) for r in range(CFG.ep)]), dx_ref,
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.stack([st.get("dW1", r) for r in range(CFG.ep)]), dw1_ref,
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.stack([st.get("dW2", r) for r in range(CFG.ep)]), dw2_ref,
        rtol=1e-4, atol=1e-4)


def test_order_independence():
    """Different legal event-driven orders give bit-identical results."""
    outs = []
    for seed in range(4):
        s = compile_schedule(build_moe_ffn_forward(CFG), ratr=bool(seed % 2))
        x_src, w1, w2, st = _forward_state(CFG)
        ex.execute(s, st, rng=np.random.default_rng(seed))
        outs.append(np.stack([st.get("y_ret", r) for r in range(CFG.ep)]))
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_swiglu_manual_grad_matches_jax():
    import jax
    import jax.numpy as jnp
    h = np.random.default_rng(0).standard_normal((6, 8)).astype(np.float32)
    dg = np.random.default_rng(1).standard_normal((6, 4)).astype(np.float32)

    def f(h):
        a, b = h[..., :4], h[..., 4:]
        return jax.nn.silu(a) * b

    _, vjp = jax.vjp(f, jnp.asarray(h))
    want = np.asarray(vjp(jnp.asarray(dg))[0])
    got = ex.swiglu_grad_np(dg, h)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
