"""Checkpointing, optimizer, compression, and data pipeline tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CK
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.optim import adamw
from repro.parallel import compression as comp

KEY = jax.random.PRNGKey(0)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,)) * 2,
                       "t": (jnp.zeros((2, 2)), jnp.full((3,), 7.0))}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    CK.save(str(tmp_path), 7, tree)
    d = CK.latest_step_dir(str(tmp_path))
    restored, manifest = CK.restore(d, tree)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_incomplete_ignored(tmp_path):
    tree = _tree()
    CK.save(str(tmp_path), 1, tree)
    # simulate a crashed save: dir without _COMPLETE
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "latest").write_text("step_00000002")
    d = CK.latest_step_dir(str(tmp_path))
    assert d.endswith("step_00000001")


def test_checkpoint_checksum_detects_corruption(tmp_path):
    tree = _tree()
    d = CK.save(str(tmp_path), 3, tree)
    shard = os.path.join(d, "shard_00000.npz")
    data = dict(np.load(shard))
    first = sorted(data)[0]
    data[first] = data[first] + 1
    np.savez(shard, **data)
    with pytest.raises(IOError, match="checksum"):
        CK.restore(d, tree)


def test_checkpoint_gc(tmp_path):
    for s in (1, 2, 3, 4, 5):
        CK.save(str(tmp_path), s, {"x": jnp.ones(3)})
    CK.gc_old(str(tmp_path), keep=2)
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert sorted(dirs) == ["step_00000004", "step_00000005"]


def test_adamw_master_update():
    params = adamw.cast_params({"w": jnp.ones((4, 4))}, jnp.bfloat16)
    state = adamw.init_opt_state(params)
    oc = adamw.OptConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    g = {"w": jnp.full((4, 4), 0.5, jnp.bfloat16)}
    p2, s2, m = adamw.apply_updates(params, g, state, oc)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["master"]["w"].dtype == jnp.float32
    assert float(s2["master"]["w"][0, 0]) < 1.0     # moved against grad
    assert float(m["grad_norm"]) > 0


def test_grad_clip():
    g = {"w": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5


def test_accumulate_grads_matches_full_batch():
    w = jnp.array([[1.0, 2.0], [3.0, 4.0]])
    xs = jax.random.normal(KEY, (8, 2))

    def loss(w, batch):
        return jnp.mean((batch @ w) ** 2)

    full = jax.grad(loss)(w, xs)
    mb = xs.reshape(4, 2, 2)
    _, acc = adamw.accumulate_grads(
        lambda p, b: jax.value_and_grad(loss)(p, b), w, mb)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                               rtol=1e-5, atol=1e-6)


def test_int8_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64,)) * 0.01)
    err = comp.int8_ef_init({"g": g_true})
    acc_with = np.zeros(64)
    err_state = err
    for _ in range(50):
        deq, err_state = comp.int8_ef_compress({"g": g_true}, err_state)
        acc_with += np.asarray(deq["g"])
    # with error feedback the accumulated average converges to the truth
    np.testing.assert_allclose(acc_with / 50, np.asarray(g_true),
                               atol=2e-4)


def test_data_determinism_and_sharding():
    dc = DataConfig(vocab=101, seq_len=16, global_batch=8)
    s = SyntheticStream(dc)
    a = s.global_batch_np(3)
    b = s.global_batch_np(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.global_batch_np(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    full0 = s._tokens(3, 0, 1)[0]
    np.testing.assert_array_equal(a["tokens"][0], full0[:-1])
    np.testing.assert_array_equal(a["labels"][0], full0[1:])
    # row-ranges compose: rows 2..5 match the global batch slice
    np.testing.assert_array_equal(s._tokens(3, 2, 5), s._tokens(3, 0, 8)[2:5])
    assert a["tokens"].min() >= 1 and a["tokens"].max() < dc.vocab


def test_straggler_watchdog():
    """Slow steps trip the EWMA watchdog in the FT loop."""
    import time as _time
    import dataclasses as _dc
    from repro.configs import get_smoke_config
    from repro.ft.runner import FTConfig, train_loop
    from repro.models import model as M
    import jax as _jax

    cfg = _dc.replace(get_smoke_config("olmo-1b"), n_layers=1)
    params = M.init_params(cfg, _jax.random.PRNGKey(0))

    calls = {"n": 0}

    def fake_step(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] == 6:
            _time.sleep(0.5)          # injected straggler
        return params, opt_state, {"loss": jnp.float32(1.0),
                                   "grad_norm": jnp.float32(0.1)}

    class _S:
        def sharded_batch(self, step, mesh, sharding):
            return {}

    import tempfile
    with tempfile.TemporaryDirectory() as d:
        run = train_loop(step_fn=fake_step, params=params, opt_state={},
                         stream=_S(), mesh=None, batch_sharding=None,
                         n_steps=10,
                         ft=FTConfig(ckpt_dir=d, ckpt_every=100,
                                     straggler_factor=5.0))
    assert any(s[0] == 5 for s in run.stragglers), run.stragglers
