"""Property tests for cross-node compression (parallel/compression.py).

The int8 error-feedback transform is what both the optimizer's cross-pod
grad path and the hierarchical dispatch's inter-node hop rely on; these
properties pin the contracts the rest of the stack assumes: the residual
is carried exactly, compression error stays bounded over many steps
(error feedback prevents accumulation), and the wire-byte accounting the
cost model prices matches the payload shrinkage.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from _proptest import given, settings, st

from repro.parallel import compression as comp


def _grad_arrays(shape_seed: int, scale: float, n: int) -> list[np.ndarray]:
    rng = np.random.default_rng(shape_seed)
    return [rng.normal(0.0, scale, size=(4, 6)).astype(np.float32)
            for _ in range(n)]


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.sampled_from([1e-3, 0.1, 1.0, 30.0]))
def test_int8_ef_residual_carried_exactly(seed, scale):
    """One step: err == g + e_in - deq, elementwise (fp32 bookkeeping)."""
    (g,) = _grad_arrays(seed, scale, 1)
    params = {"w": jnp.asarray(g)}
    e0 = comp.int8_ef_init(params)
    deq, err = comp.int8_ef_compress({"w": jnp.asarray(g)}, e0)
    want = (g.astype(np.float32) + np.asarray(e0["w"])
            - np.asarray(deq["w"], dtype=np.float32))
    np.testing.assert_allclose(np.asarray(err["w"]), want, rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.sampled_from([0.1, 1.0, 10.0]),
       st.integers(min_value=2, max_value=8))
def test_int8_ef_error_bounded_over_steps(seed, scale, steps):
    """Error feedback keeps the carried residual bounded by one quantization
    step of the *augmented* signal — it never accumulates across steps."""
    grads = _grad_arrays(seed, scale, steps)
    params = {"w": jnp.zeros_like(jnp.asarray(grads[0]))}
    e = comp.int8_ef_init(params)
    for g in grads:
        deq, e = comp.int8_ef_compress({"w": jnp.asarray(g)}, e)
        g32 = np.abs(g.astype(np.float32)).max() + np.abs(
            np.asarray(e["w"])).max()
        # One symmetric-int8 step of the augmented signal's amax scale.
        bound = max(g32, 1e-12) / 127.0 + 1e-6
        assert float(np.abs(np.asarray(e["w"])).max()) <= bound


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_int8_ef_sum_preserved(seed):
    """Over K steps, sum(deq) + final residual == sum(g): nothing routed
    through the compressor is ever lost, only delayed."""
    grads = _grad_arrays(seed, 1.0, 6)
    params = {"w": jnp.zeros_like(jnp.asarray(grads[0]))}
    e = comp.int8_ef_init(params)
    total = np.zeros_like(grads[0], dtype=np.float32)
    for g in grads:
        deq, e = comp.int8_ef_compress({"w": jnp.asarray(g)}, e)
        total += np.asarray(deq["w"], dtype=np.float32)
    want = np.sum([g.astype(np.float32) for g in grads], axis=0)
    np.testing.assert_allclose(total + np.asarray(e["w"]), want,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.sampled_from([1e-4, 1.0, 100.0]))
def test_int8_roundtrip_np_error_one_step(seed, scale):
    """The numpy model of the inter-node hop: elementwise error within one
    quantization step of the message's amax scale, zeros exact."""
    (x,) = _grad_arrays(seed, scale, 1)
    x = x.astype(np.float32)
    y = comp.int8_roundtrip_np(x)
    step = np.abs(x).max() / 127.0
    assert np.abs(y - x).max() <= step * (0.5 + 1e-6) + 1e-12
    z = np.zeros((3, 3), dtype=np.float32)
    assert (comp.int8_roundtrip_np(z) == z).all()


def test_int8_roundtrip_preserves_dtype():
    x16 = np.linspace(-2, 2, 32, dtype=np.float16)
    assert comp.int8_roundtrip_np(x16).dtype == np.float16


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=4096),
       st.sampled_from([2, 4]))
def test_int8_wire_bytes_shrinks_payload(rows, db):
    """Wire bytes = one int8 per element + the fixed scale header; for any
    payload past a few elements this undercuts the raw dtype bytes by
    ~db x, which is exactly what the cost model prices on the slow link."""
    nbytes = rows * 64 * db                  # rows x 64-element rows
    wire = comp.int8_wire_bytes(nbytes, db)
    assert wire == nbytes // db + comp.INT8_SCALE_BYTES
    if nbytes >= 4 * comp.INT8_SCALE_BYTES:
        assert wire < nbytes


def test_bf16_roundtrip_halves_bytes_and_bounds_error():
    """bf16 cast: half the wire bytes of fp32, relative error <= 2^-8."""
    rng = np.random.default_rng(7)
    g = rng.normal(0, 3.0, size=(16, 16)).astype(np.float32)
    out = comp.bf16_compress({"g": jnp.asarray(g)})
    y = np.asarray(out["g"], dtype=np.float32)
    assert jnp.asarray(g).astype(jnp.bfloat16).nbytes == g.nbytes // 2
    rel = np.abs(y - g) / np.maximum(np.abs(g), 1e-12)
    assert rel.max() <= 2.0 ** -8 + 1e-6


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
