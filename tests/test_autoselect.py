"""Cost-model-guided pipeline auto-selection: selector properties + the
sweep-table regression gate.

Two layers of defense:

* Properties (hypothesis when installed, the seeded ``_proptest`` fallback
  otherwise) over random skewed / sparse / hotspot / diagonal RoutingPlans:
  the selector never returns a spec it prices worse than the empty
  pipeline, equal plans resolve deterministically, and an ``"auto"`` SSC
  key equals its resolved spec's key (cache-hit parity).
* A fixture-sized ``--sched-sweep`` run asserted end-to-end through the
  simulator: scenario and pipeline names are locked (registry drift fails
  loudly), ``critical_rank_first`` still wins the hotspot scenario, the
  ``auto`` row lands within tolerance of the per-scenario best fixed
  pipeline everywhere, and strictly beats the fixed ``"all"`` pipeline on
  the hotspot.
"""

import numpy as np
import pytest

from _proptest import given, settings, st

from repro.core import executor as ex
from repro.core.autoselect import (auto_pipeline, plan_features,
                                   predict_makespan_us, select)
from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.passes import SCHED_PIPELINES, Pipeline, pipeline_arg
from repro.core.routing import (RoutingPlan, hotspot_plan, random_plan,
                                skewed_plan)
from repro.core.scheduler import compile_schedule, validate_schedule
from repro.core.ssc import SSCCache

# Tolerance of the sweep gate: auto must land within this factor of the
# best fixed pipeline on every (scenario, direction) — the acceptance bar.
SWEEP_TOL = 1.05

directions = st.sampled_from(["forward", "backward"])


def _diagonal_plan(ep: int, e_loc: int, rows: int) -> RoutingPlan:
    """Every source keeps its tokens local — zero cross-rank cells."""
    counts = np.zeros((ep, ep, e_loc), dtype=np.int64)
    for s in range(ep):
        counts[s, s, :] = rows
    return RoutingPlan.from_counts(counts)


def _random_case(seed: int, kind: str):
    rng = np.random.default_rng(seed)
    ep, e_loc = int(rng.integers(2, 5)), int(rng.integers(1, 4))
    if kind == "skewed":
        plan = skewed_plan(ep, e_loc, int(rng.integers(1, 9)),
                           float(rng.uniform(0, 2.5)))
    elif kind == "sparse":
        plan = random_plan(ep, e_loc, 7, rng, p_zero=0.4)
    elif kind == "diagonal":
        plan = _diagonal_plan(ep, e_loc, int(rng.integers(1, 9)))
    else:
        plan = hotspot_plan(ep, e_loc, int(rng.integers(2, 9)))
    m_split = int(rng.choice([1, 2, 4]))
    cfg = ScheduleConfig(ep=ep, e_loc=e_loc, rows=0, d_model=16, d_ff=8,
                         gmm_m_split=m_split,
                         gmm_split_mode="source_aligned", plan=plan)
    return plan, cfg


# ---------------------------------------------------------------------------
# Selector properties.
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["skewed", "sparse", "hotspot", "diagonal"]),
       directions)
def test_auto_never_worse_than_empty_pipeline(seed, kind, direction):
    """The pick's predicted makespan never exceeds the empty pipeline's at
    the caller's tiling — 'naive' is always in the candidate set, so a
    pruning bug that loses it (or a pricing bug that inflates the pick)
    fails here."""
    plan, cfg = _random_case(seed, kind)
    choice = select(plan, cfg, direction=direction)
    naive_us = predict_makespan_us(cfg, direction, ())
    assert choice.predicted_us <= naive_us + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["skewed", "sparse", "hotspot", "diagonal"]),
       directions)
def test_auto_is_deterministic_for_equal_plans(seed, kind, direction):
    """Equal plans (fresh objects, equal counts) resolve identically — an
    SSC-cache invariant: per-batch auto selection must not fragment keys."""
    plan, cfg = _random_case(seed, kind)
    pipe1, cfg1 = auto_pipeline(plan, cfg, direction=direction)
    # A fresh, structurally equal plan in a fresh, structurally equal cfg.
    plan2 = RoutingPlan.from_counts(np.asarray(plan.counts))
    cfg2 = ScheduleConfig(ep=cfg.ep, e_loc=cfg.e_loc, rows=0,
                          d_model=cfg.d_model, d_ff=cfg.d_ff,
                          gmm_m_split=cfg.gmm_m_split,
                          gmm_split_mode=cfg.gmm_split_mode, plan=plan2)
    pipe2, cfg2r = auto_pipeline(plan2, cfg2, direction=direction)
    assert pipe1 == pipe2
    assert cfg1 == cfg2r


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["skewed", "sparse", "hotspot", "diagonal"]),
       directions)
def test_ssc_key_parity_for_auto(seed, kind, direction):
    """``SSCCache.key(cfg, dir, pipeline="auto")`` equals the key of its
    resolved (pipeline, config) — an auto request and the equivalent
    explicit request share one cache entry."""
    plan, cfg = _random_case(seed, kind)
    pipe, rcfg = auto_pipeline(plan, cfg, direction=direction)
    k_auto = SSCCache.key(cfg, direction, pipeline="auto")
    k_resolved = SSCCache.key(rcfg, direction, pipeline=pipe)
    assert k_auto == k_resolved
    # And the resolved key never contains the literal request string.
    assert "auto" not in repr(k_auto)


def test_auto_requests_share_one_cache_entry():
    plan = hotspot_plan(4, 2, 8)
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=32, d_ff=16,
                         gmm_m_split=4, gmm_split_mode="source_aligned",
                         plan=plan)
    pipe, rcfg = auto_pipeline(plan, cfg, direction="forward")
    cache = SSCCache()
    cache.get_or_compile(cfg, "forward", pipeline="auto")
    cache.get_or_compile(rcfg, "forward", pipeline=pipe)
    cache.get_or_compile(cfg, "forward", pipeline="auto")
    assert cache.misses == 1 and cache.hits == 2


def test_compile_schedule_auto_resolves_and_pins_tiling():
    """``compile_schedule(pipeline="auto")`` resolves through the selector
    but never re-tiles (the ODG's task set is already built); the resolved
    spec — not "auto" — lands in ``Schedule.opts``."""
    plan = hotspot_plan(4, 2, 8)
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=32, d_ff=16,
                         gmm_m_split=4, gmm_split_mode="source_aligned",
                         plan=plan)
    s = compile_schedule(build_moe_ffn_forward(cfg), pipeline="auto")
    validate_schedule(s)
    names = Pipeline.from_spec(s.opts["pipeline"]).names()
    assert "auto" not in names
    registered = {n for spec in SCHED_PIPELINES.values() for n in spec}
    assert set(names) <= registered
    # Tiling pinned: same task count as an explicit compile at cfg.
    s_explicit = compile_schedule(build_moe_ffn_forward(cfg))
    assert s.n_tasks == s_explicit.n_tasks


def test_auto_schedule_executes_bit_correct():
    """An auto-resolved (possibly re-tiled) schedule from the cache still
    matches the monolithic reference — what the dropless path relies on."""
    plan = hotspot_plan(4, 2, 8, background=2)
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=16, d_ff=8,
                         gmm_m_split=4, gmm_split_mode="source_aligned",
                         plan=plan)
    sched = SSCCache().get_or_compile(cfg, "forward", pipeline="auto")
    x_src, w1, w2 = ex.make_inputs_plan(cfg, 5)
    state = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, state, x_src, w1, w2)
    ex.execute(sched, state, rng=np.random.default_rng(5))
    ref = ex.reference_forward_plan(cfg, x_src, w1, w2)
    for r in range(cfg.ep):
        if plan.send_rows(r):
            np.testing.assert_allclose(state.get("y_ret", r),
                                       ref["y_ret"][r], rtol=1e-5, atol=1e-5)


def test_selection_is_fast_and_memoized():
    """Selection stays O(ms) — it must not eat the compile-time win."""
    import time
    from repro.core.autoselect import selection_cache_clear
    plan = skewed_plan(8, 8, 128, 1.0)
    cfg = ScheduleConfig(ep=8, e_loc=8, rows=0, d_model=2048, d_ff=512,
                         gmm_m_split=64, gmm_split_mode="source_aligned",
                         plan=plan)
    selection_cache_clear()
    t0 = time.perf_counter()
    select(plan, cfg, direction="forward")
    cold_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    select(plan, cfg, direction="forward")
    warm_ms = (time.perf_counter() - t0) * 1e3
    assert cold_ms < 250.0, f"cold selection took {cold_ms:.1f}ms"
    assert warm_ms < cold_ms and warm_ms < 5.0


def test_pipeline_arg_mapping():
    assert pipeline_arg("auto") == "auto"
    assert pipeline_arg("ratr+crit") == SCHED_PIPELINES["ratr+crit"]
    assert pipeline_arg("ratr,gmm_interleave") == ("ratr", "gmm_interleave")
    with pytest.raises(KeyError, match="unknown schedule pass"):
        pipeline_arg("definitely_not_a_pass")


def test_dropless_config_carries_auto_through():
    """The dropless path hands ``"auto"`` to the SSC cache verbatim (per
    batch-plan, per direction) instead of exploding it into characters."""
    from repro.launch.dropless import DroplessConfig
    dc = DroplessConfig(pipeline="auto")
    assert dc.pipeline_spec() == "auto"
    assert DroplessConfig().pipeline_spec() == ["ratr", "gmm_interleave"]


def test_plan_features_profiles():
    hot = plan_features(hotspot_plan(8, 2, 16))
    assert hot.hotspot and hot.conc > 0.9 and hot.skew > 4
    bal = plan_features(RoutingPlan.balanced(4, 2, 8))
    assert bal.balanced and not bal.hotspot and bal.sparsity == 0.0
    sk = plan_features(skewed_plan(4, 2, 8, 1.5))
    assert not sk.balanced and sk.expert_skew > 1.25


# ---------------------------------------------------------------------------
# Sweep-table regression gate (fixture-sized --sched-sweep, simulated).
# ---------------------------------------------------------------------------

FIXTURE_SWEEP = dict(ep=8, e_loc=2, rows=256, d_model=1024, d_ff=512,
                     gmm_m_split=64)


@pytest.fixture(scope="module")
def sweep_rows():
    from repro.launch.schedsweep import sched_sweep
    return sched_sweep(quiet=True, **FIXTURE_SWEEP)


def _table(rows):
    out = {}
    for r in rows:
        out[(r["plan"], r["direction"], r["pipeline"])] = r
    return out


def test_sweep_names_locked(sweep_rows):
    """Scenario and pipeline names are the public sweep contract — silent
    registry drift (a renamed pass, a dropped scenario) fails loudly."""
    assert set(SCHED_PIPELINES) == {"naive", "ratr", "ratr+gmm_il",
                                    "ratr+crit", "all"}
    scenarios = {r["plan"] for r in sweep_rows}
    assert scenarios == {"balanced", "skewed", "hotspot", "hotspot_bg"}
    pipelines = {r["pipeline"] for r in sweep_rows}
    assert pipelines == set(SCHED_PIPELINES) | {"auto"}
    for (plan, direction) in {(r["plan"], r["direction"])
                              for r in sweep_rows}:
        present = {r["pipeline"] for r in sweep_rows
                   if (r["plan"], r["direction"]) == (plan, direction)}
        assert present == pipelines, f"missing rows in {plan}/{direction}"


def test_crit_first_still_wins_hotspot(sweep_rows):
    """The straggler-aware pass keeps its headline win: best fixed pipeline
    on the concentrated-hotspot forward scenario, strictly ahead of every
    crit-less pipeline."""
    t = _table(sweep_rows)
    crit = t[("hotspot", "forward", "ratr+crit")]["makespan_us"]
    for tag in SCHED_PIPELINES:
        other = t[("hotspot", "forward", tag)]["makespan_us"]
        assert crit <= other + 1e-9, f"{tag} beats ratr+crit on hotspot"
        if "critical_rank_first" not in SCHED_PIPELINES[tag]:
            assert crit < other, f"no win over crit-less {tag}"


def test_auto_within_tolerance_of_best_fixed(sweep_rows):
    """The acceptance bar: on every (scenario, direction) the auto row's
    simulated makespan is within SWEEP_TOL of the best fixed pipeline."""
    t = _table(sweep_rows)
    for (plan, direction) in {(r["plan"], r["direction"])
                              for r in sweep_rows}:
        best_fixed = min(t[(plan, direction, tag)]["makespan_us"]
                         for tag in SCHED_PIPELINES)
        auto = t[(plan, direction, "auto")]["makespan_us"]
        assert auto <= best_fixed * SWEEP_TOL, (
            f"auto {auto:.1f}us vs best fixed {best_fixed:.1f}us on "
            f"{plan}/{direction} "
            f"(resolved: {t[(plan, direction, 'auto')]['resolved']})")


def test_auto_strictly_beats_all_on_hotspot(sweep_rows):
    """Auto must out-schedule the fixed kitchen-sink pipeline somewhere —
    the hotspot, where the selector's budget grid and its crit/interleave
    conflict pricing both pay off."""
    t = _table(sweep_rows)
    wins = [d for d in ("forward", "backward")
            if t[("hotspot", d, "auto")]["makespan_us"]
            < t[("hotspot", d, "all")]["makespan_us"]]
    assert wins, "auto never strictly beats 'all' on the hotspot scenario"


def test_auto_rows_record_resolution(sweep_rows):
    """Every auto row carries its resolved spec + compile-time prediction —
    the sweep table doubles as the selector's provenance log."""
    for r in sweep_rows:
        if r["pipeline"] != "auto":
            continue
        assert r["resolved"], r
        assert "auto" not in Pipeline.from_spec(r["resolved_spec"]).names()
        assert r["predicted_us"] >= 0.0
        # The budget grid only ever refines tiling, never coarsens it.
        assert r["resolved_m_split"] >= FIXTURE_SWEEP["gmm_m_split"]
