"""Self-tuning SLO-aware serving: online refitting, hot-swap bit-parity,
admission control, and replay-driven sizing.

The online loop's contracts, in rough order of importance:

* Hot swaps are **bit-transparent**: the bucket spec only changes how plan
  cells pad, and padding rows are inert in the executor — so the same
  inputs produce bit-identical outputs under any spec, including across a
  forced mid-stream swap (executor-level and through the full serving
  stack).
* Refit/swap decisions are **pure functions of the observation window** —
  two tuners fed the same counts agree exactly.
* **Hysteresis** damps ladder thrash on oscillating traffic; greedy
  (hysteresis=0) swaps at least as often as a margined tuner.
* Swaps **re-key, never flush** the SSC cache.
* The admission gate shed-reports (never silently drops), bounds active
  tokens by the sized batch, and keeps predicted p99 under the SLO while
  the unbounded baseline exceeds it — predictor-priced on both sides, so
  the comparison is apples-to-apples.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.buckets import BucketSpec
from repro.core.ssc import SSCCache
from repro.launch.online import (AdmissionConfig, OnlineConfig, OnlineMoE,
                                 OnlineTuner, population_plan,
                                 replay_admission, size_capacity_factor,
                                 size_slots)
from repro.launch.replay import synth_trace
from repro.models.moe import MoEConfig, init_moe, routed_counts

from _proptest import given, settings, st

EP, E_LOC, K = 4, 2, 2
MC = MoEConfig(n_experts=EP * E_LOC, top_k=K, d_expert=16)


def _counts(profile, steps, t_loc=32, seed=0):
    return [routed_counts(ti, MC, EP) for ti in
            synth_trace(profile, steps, ep=EP, e_loc=E_LOC, t_loc=t_loc,
                        top_k=K, seed=seed)]


# ---------------------------------------------------------------------------
# Population derivation + sizing.
# ---------------------------------------------------------------------------


def test_population_plan_mean_union_and_rescale():
    pop = _counts("zipf", 8)
    plan = population_plan(pop)
    c = np.asarray(plan.counts)
    mean = np.mean(np.stack(pop), axis=0)
    np.testing.assert_array_equal(c, np.ceil(mean).astype(np.int64))
    # union sparsity: a cell is zero iff no batch ever touched it
    touched = np.stack(pop).sum(axis=0) > 0
    assert ((c > 0) == touched).all()
    # rescale targets the requested row count (ceil keeps it >=)
    small = population_plan(pop, total_rows=EP * K)
    assert EP * K <= small.total_rows <= EP * K + c.size
    with pytest.raises(ValueError):
        population_plan([])
    with pytest.raises(ValueError):
        population_plan([np.zeros((EP, EP, E_LOC), np.int64)])


def test_size_slots_monotone_and_capacity_factor():
    pop = _counts("bursty", 24)
    tight = size_slots(pop, MC, EP, 0.005, d_model=32, d_ff=16)
    loose = size_slots(pop, MC, EP, 0.02, d_model=32, d_ff=16)
    assert EP <= tight <= loose            # bigger SLO, bigger budget
    assert tight % EP == 0 and loose % EP == 0
    cf = size_capacity_factor(pop)
    assert cf > 1.0                        # bursty traffic is skewed
    assert size_capacity_factor(pop, headroom=2.0) > cf


# ---------------------------------------------------------------------------
# Refit determinism + hysteresis.
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50), st.sampled_from([0.0, 0.1, 0.3]))
def test_refit_decisions_deterministic(seed, hyst):
    rng = np.random.default_rng(seed)
    window = []
    for i in range(3):
        prof = ["uniform", "zipf", "hotspot"][int(rng.integers(3))]
        window += _counts(prof, 8, t_loc=int(rng.integers(16, 48)),
                          seed=seed + i)
    specs = [[], []]
    tuners = [OnlineTuner(oc=OnlineConfig(hysteresis=hyst))
              for _ in range(2)]
    for t, out in zip(tuners, specs):
        for c in window:
            out.append(t.observe(c).key())
    assert specs[0] == specs[1]
    assert ([e["step"] for e in tuners[0].swaps]
            == [e["step"] for e in tuners[1].swaps])
    assert tuners[0].summary() == tuners[1].summary()


def test_hysteresis_damps_ladder_thrash():
    # Oscillating uniform <-> hotspot traffic: each 8-step block flips the
    # window's fit. A greedy tuner chases it; margins damp it.
    blocks = []
    for i in range(8):
        blocks += _counts("uniform" if i % 2 == 0 else "hotspot", 8,
                          seed=i)
    swaps = {}
    for hyst in (0.0, 0.3):
        t = OnlineTuner(initial="geometric:8",
                        oc=OnlineConfig(hysteresis=hyst))
        for c in blocks:
            t.observe(c)
        swaps[hyst] = len(t.swaps)
        assert t.refits == len(blocks) // 8
    assert swaps[0.0] >= 2                 # greedy: the ladder thrashes
    assert swaps[0.3] <= 1                 # margined: it settles
    assert swaps[0.3] < swaps[0.0]


def test_swap_requires_margin_and_records_evidence():
    t = OnlineTuner(initial="geometric:8",
                    oc=OnlineConfig(hysteresis=0.1))
    for c in _counts("hotspot", 16, seed=3):
        t.observe(c)
    if t.swaps:                             # refit won: evidence attached
        ev = t.swaps[0]
        assert ev["cand_cost"] < (1 - 0.1) * ev["inc_cost"]
        assert ev["from"] == "geometric:8"
    # forced swaps are evidence-free but still recorded
    t.swap_to("linear:4", forced=True)
    assert t.swaps[-1]["forced"] and t.spec == BucketSpec.linear(4)


# ---------------------------------------------------------------------------
# SSC re-key (never flush) across swaps.
# ---------------------------------------------------------------------------


def test_swap_rekeys_ssc_without_flushing():
    d = 16
    params = init_moe(jax.random.PRNGKey(0), d, MC)
    cache = SSCCache(max_entries=64)
    from repro.launch.dropless import DroplessConfig
    tuner = OnlineTuner(initial="geometric:8",
                        oc=OnlineConfig(refit_every=10_000))
    om = OnlineMoE(DroplessConfig(ep=2, bucket="geometric:8",
                                  pipeline=("ratr",)),
                   tuner, cache=cache)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, d), jnp.float32)
    om.impl(params, x, MC).block_until_ready()
    before = cache.info()["entries"]
    assert before > 0
    om.swap_to("linear:4")
    ev = tuner.swaps[-1]["rekey"]
    assert ev["entries"] == before          # nothing evicted
    assert ev["active"] == 0                # new policy starts cold
    assert ev["stale"] == before
    om.impl(params, x, MC).block_until_ready()
    info = cache.info()
    assert info["entries"] > before         # old blobs + new policy's
    assert info["active_bucket"] is not None


# ---------------------------------------------------------------------------
# Hot-swap bit-parity: executor level, then through the serving stack.
# ---------------------------------------------------------------------------


def test_hot_swap_bit_parity_executor():
    from repro.launch.dropless import DroplessConfig
    d = 16
    params = init_moe(jax.random.PRNGKey(0), d, MC)
    xs = [jax.random.normal(jax.random.PRNGKey(i), (1, 16, d), jnp.float32)
          for i in range(1, 4)]
    frozen = OnlineConfig(refit_every=10_000)   # isolate forced swaps

    def run(specs):
        tuner = OnlineTuner(initial=specs[0], oc=frozen)
        om = OnlineMoE(DroplessConfig(ep=2, bucket=specs[0],
                                      pipeline=("ratr",)),
                       tuner, cache=SSCCache(max_entries=64))
        ys = []
        for i, x in enumerate(xs):
            if i < len(specs) and i > 0:
                om.swap_to(specs[i])
            ys.append(np.asarray(om.impl(params, x, MC)))
        return ys

    base = run(["geometric:8"])
    other = run(["linear:4"])
    swapped = run(["geometric:8", "linear:4", "exact"])
    for a, b, c in zip(base, other, swapped):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_hot_swap_bit_parity_through_serving_stack():
    # Full continuous-batching decode on an MoE arch: a forced mid-serve
    # ladder swap must not perturb a single served token.
    from repro.configs import get_smoke_config
    from repro.launch.dropless import DroplessConfig
    from repro.launch.serve import ContinuousBatcher
    from repro.models import model as M

    cfg = dataclasses.replace(get_smoke_config("dbrx-132b"),
                              dtype="float32", n_layers=2)
    mc = cfg.moe
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, 12) for i in range(4)}
    max_new = 4

    def serve(swap_at):
        tuner = OnlineTuner(initial="geometric:8",
                            oc=OnlineConfig(refit_every=10_000),
                            d_model=cfg.d_model, d_ff=mc.d_expert)
        om = OnlineMoE(DroplessConfig(ep=2, bucket=tuner.spec,
                                      pipeline=("ratr",)),
                       tuner, cache=SSCCache(max_entries=64))
        b = ContinuousBatcher(cfg, params, n_slots=2,
                              max_len=12 + max_new + 1, moe_impl=om.impl)
        pending, finished, steps = list(prompts), [], 0
        while pending or b.active.any() or b.instant_done:
            while pending and b.admit(pending[0], prompts[pending[0]],
                                      max_new):
                pending.pop(0)
            finished += b.step()
            steps += 1
            if steps == swap_at:
                om.swap_to("linear:4")
            assert steps < 200
        assert sorted(finished) == sorted(prompts)
        return b.generated, tuner

    gen_plain, _ = serve(swap_at=None)
    gen_swapped, tuner = serve(swap_at=2)
    assert [e for e in tuner.swaps if e.get("forced")]
    assert gen_plain == gen_swapped


# ---------------------------------------------------------------------------
# Admission control with load shedding (the bursty chaos case).
# ---------------------------------------------------------------------------


def test_admission_sheds_reported_and_meets_slo():
    trace = synth_trace("bursty", 48, ep=EP, e_loc=E_LOC, t_loc=32,
                        top_k=K, seed=0)
    pop = [routed_counts(ti, MC, EP) for ti in trace]
    slo = 0.01
    n = size_slots(pop, MC, EP, slo, d_model=32, d_ff=16)
    base = replay_admission(trace, MC, EP, d_model=32, d_ff=16)
    gated = replay_admission(
        trace, MC, EP, d_model=32, d_ff=16, n_slots=n,
        admission=AdmissionConfig(slo_us=slo, max_queue=160))
    offered = sum(np.asarray(t).reshape(-1, K).shape[0] for t in trace)
    # nothing silently dropped: every offered token is accounted for
    assert gated["served"] + gated["shed"] + gated["deferred"] == offered
    assert gated["shed"] > 0
    assert gated["max_active"] <= n
    # predicted p99 under SLO with shedding; unbounded baseline over it
    assert gated["p99_us"] <= slo < base["p99_us"]
    assert gated["slo_miss_rate"] == 0.0
    assert base["served"] == offered and base["shed"] == 0


def test_admission_unbounded_wait_without_shedding():
    trace = synth_trace("bursty", 24, ep=EP, e_loc=E_LOC, t_loc=32,
                        top_k=K, seed=1)
    gated = replay_admission(
        trace, MC, EP, d_model=32, d_ff=16, n_slots=EP,
        admission=AdmissionConfig(slo_us=0.005, max_queue=8, shed=False))
    assert gated["shed"] == 0               # shedding off: queue grows
    assert gated["deferred"] > 8
    with pytest.raises(ValueError):
        AdmissionConfig(slo_us=0.0)
    with pytest.raises(ValueError):
        replay_admission(trace, MC, EP,
                         admission=AdmissionConfig(slo_us=1.0))


# ---------------------------------------------------------------------------
# Online policy inside the replay harness.
# ---------------------------------------------------------------------------


def test_online_policy_replays_deterministically():
    from repro.launch.replay import replay_trace, resolve_policies
    trace = (synth_trace("zipf", 16, ep=EP, e_loc=E_LOC, t_loc=24,
                         top_k=K, seed=0)
             + synth_trace("zipf", 16, ep=EP, e_loc=E_LOC, t_loc=48,
                           top_k=K, seed=2))
    fit = synth_trace("zipf", 8, ep=EP, e_loc=E_LOC, t_loc=24, top_k=K,
                      seed=1)

    def run():
        pols = resolve_policies(["fitted:4", "online:4"], fit, MC, EP)
        # online warm-starts from the very ladder fitted:4 deploys
        assert pols["online:4"].spec.key() == pols["fitted:4"].key()
        rows = {r["policy"]: r for r in replay_trace(
            trace, MC, EP, policies=pols, d_model=32, d_ff=16,
            simulate=False)}
        return rows

    r1, r2 = run(), run()
    assert r1["online:4"]["hit_rate"] == r2["online:4"]["hit_rate"]
    assert r1["online:4"]["swaps"] == r2["online:4"]["swaps"]
    assert "swaps" not in r1["fitted:4"]
    assert r1["online:4"]["refits"] > 0
