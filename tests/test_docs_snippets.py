"""README quickstart snippets execute verbatim.

Every fenced ```python block in README.md that opens with the
`# PYTHONPATH=src python - <<'EOF'` header is a runnable quickstart; this
test extracts each one and runs it exactly as its header says — a fresh
``python`` process with ``PYTHONPATH=src`` (snippets touch the
process-level SSC cache, so in-process ``exec`` would leak state into
other tests). The snippets carry their own asserts — e.g. the PP
quickstart asserts fused beats the stage-barrier reference and that
``select_pp`` never predicts fused worse. A drifting API breaks the docs
*and* the build, not just the docs.
"""

import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
README = REPO / "README.md"
HEADER = "# PYTHONPATH=src python - <<'EOF'"
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _snippets():
    blocks = FENCE_RE.findall(README.read_text())
    out = []
    for b in blocks:
        if b.startswith(HEADER):
            body = b[len(HEADER):].strip("\n")
            body = body.removesuffix("EOF").rstrip("\n")
            name = "anon"
            m = re.search(r"^(?:from|import)\s+([\w.]+)", body, re.M)
            if m:
                name = m.group(1).split(".")[-1]
            out.append(pytest.param(body, id=name))
    return out


SNIPPETS = _snippets()


def test_readme_has_runnable_snippets():
    assert len(SNIPPETS) >= 3          # fused block, PP quickstart, topology
    joined = "\n".join(p.values[0] for p in SNIPPETS)
    assert "compile_pp_fused" in joined    # the PP quickstart is present


@pytest.mark.parametrize("body", SNIPPETS)
def test_readme_snippet_executes_verbatim(body):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-"], input=body, text=True,
                          capture_output=True, cwd=str(REPO), env=env,
                          timeout=300)
    assert proc.returncode == 0, (
        f"README snippet failed:\n{proc.stdout}\n{proc.stderr}")
