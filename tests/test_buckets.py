"""BucketSpec: plan-quantization policies and their contracts.

The properties every policy must keep (``core/buckets.py`` docstring):
coverage (bucketed plans have room for the exact rows), sparsity
preservation, idempotence, monotonicity; coarser specs never lower the
cache hit rate on a fixed trace; ``linear(rows)`` is SSC-key-identical to
the legacy ``bucket_rows`` int; padding rows are inert (executor-verified
against ``moe_grouped``); ``fit_ladder`` learns valid, padding-bounded
ladders from plan populations; and the spec rides the SSC key /
``Schedule.opts`` / the blob.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _proptest import given, settings, st

from repro.core.buckets import (BucketSpec, coarsens, fit_ladder,
                                normalize_bucket)
from repro.core.odg import ScheduleConfig
from repro.core.routing import random_plan
from repro.core.ssc import SSCCache, ssc_to_schedule
from repro.launch.dropless import DroplessConfig, DroplessMoE
from repro.models.moe import (MoEConfig, bucket_counts, init_moe,
                              moe_grouped, plan_from_routing)

KEY = jax.random.PRNGKey(0)

POLICIES = [
    BucketSpec.exact(),
    BucketSpec.linear(4),
    BucketSpec.linear(16),
    BucketSpec.geometric(4),
    BucketSpec.geometric(8, growth=1.5),
    BucketSpec.ladder([4, 9, 17]),
]


# ---------------------------------------------------------------------------
# Quantization invariants, for every policy.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, len(POLICIES) - 1), st.integers(0, 2 ** 31 - 1))
def test_quantize_invariants(pol_idx, seed):
    spec = POLICIES[pol_idx]
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 200, size=(3, 3, 2))
    c[rng.random(c.shape) < 0.3] = 0
    q = spec.quantize(c)
    assert (q >= c).all(), "coverage: counts round up"
    assert ((q == 0) == (c == 0)).all(), "sparsity preserved"
    assert (spec.quantize(q) == q).all(), "idempotent"
    flat = np.sort(c.reshape(-1))
    qf = spec.quantize(flat)
    assert (np.diff(qf) >= 0).all(), "monotone"


def test_ladder_overflow_rounds_to_top_edge_multiples():
    spec = BucketSpec.ladder([4, 16])
    c = np.array([1, 4, 5, 16, 17, 31, 32, 33, 100])
    np.testing.assert_array_equal(
        spec.quantize(c), [4, 4, 16, 16, 32, 32, 32, 48, 112])


def test_parse_key_roundtrip_and_errors():
    for text in ("16", "exact", "linear:16", "geometric:8",
                 "geometric:8x1.5", "ladder:4,8,32"):
        spec = BucketSpec.parse(text)
        assert BucketSpec.from_any(spec.key()) == spec
        assert BucketSpec.from_any(spec.spec()) == spec
        assert BucketSpec.parse(str(spec)) == spec
    assert BucketSpec.from_any(None) == BucketSpec.exact()
    assert BucketSpec.from_any(16) == BucketSpec.linear(16)
    with pytest.raises(ValueError):
        BucketSpec.parse("wavelet:3")
    with pytest.raises(ValueError):
        BucketSpec.geometric(4, growth=1.0)
    with pytest.raises(ValueError):
        BucketSpec.ladder([])
    with pytest.raises(TypeError):
        BucketSpec.from_any(3.5)
    assert normalize_bucket(BucketSpec.linear(8), 99) == BucketSpec.linear(8)
    assert normalize_bucket(None, 8) == BucketSpec.linear(8)


# ---------------------------------------------------------------------------
# The legacy bucket_rows int shim is key-identical to linear(rows).
# ---------------------------------------------------------------------------

def test_linear_spec_key_identical_to_legacy_int():
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    rng = np.random.default_rng(0)
    ti = rng.integers(0, 8, size=(64, 2))
    legacy = plan_from_routing(ti, mc, 4, capacity=None, bucket_rows=16)
    spec = plan_from_routing(ti, mc, 4, capacity=None,
                             bucket=BucketSpec.linear(16))
    assert legacy.plan.counts == spec.plan.counts

    c = np.asarray(legacy.plan.counts)
    np.testing.assert_array_equal(bucket_counts(c, 16),
                                  bucket_counts(c, BucketSpec.linear(16)))

    # DroplessConfig: deprecated int field and explicit spec → one SSC key.
    dcs = [DroplessConfig(ep=4, bucket_rows=16),
           DroplessConfig(ep=4, bucket=BucketSpec.linear(16)),
           DroplessConfig(ep=4, bucket="linear:16"),
           DroplessConfig(ep=4, bucket=16)]
    assert len({dc.bucket_spec() for dc in dcs}) == 1
    cfgs = [ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=16, d_ff=8,
                           plan=legacy.plan, bucket=dc.bucket_spec().key())
            for dc in dcs]
    keys = {SSCCache.key(cfg, "forward", pipeline=["ratr"])
            for cfg in cfgs}
    assert len(keys) == 1


def test_schedule_config_normalizes_bucket_forms():
    plan = random_plan(2, 2, 8, np.random.default_rng(0))
    variants = [16, "linear:16", BucketSpec.linear(16), ("linear", 16),
                ["linear", 16]]
    cfgs = [ScheduleConfig(ep=2, e_loc=2, rows=0, d_model=16, d_ff=8,
                           plan=plan, bucket=b) for b in variants]
    assert all(cfg.bucket == ("linear", 16) for cfg in cfgs)
    assert len({hash(cfg) for cfg in cfgs}) == 1
    # distinct policies with identical counts must not alias
    other = dataclasses.replace(cfgs[0], bucket=("geometric", 16, 2.0))
    assert SSCCache.key(cfgs[0], "forward", pipeline=["ratr"]) \
        != SSCCache.key(other, "forward", pipeline=["ratr"])


# ---------------------------------------------------------------------------
# Every policy's bucketed plan covers the exact plan cell-wise.
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, len(POLICIES) - 1), st.integers(0, 2 ** 31 - 1))
def test_bucketed_plan_covers_exact(pol_idx, seed):
    spec = POLICIES[pol_idx]
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    rng = np.random.default_rng(seed)
    ti = rng.integers(0, 8, size=(32, 2))
    exact = plan_from_routing(ti, mc, 4, capacity=None)
    bucketed = plan_from_routing(ti, mc, 4, capacity=None, bucket=spec)
    ce, cb = np.asarray(exact.plan.counts), np.asarray(bucketed.plan.counts)
    assert (cb >= ce).all()
    assert ((cb == 0) == (ce == 0)).all()
    assert (bucketed.send_row >= 0).all()     # dropless: nothing dropped
    # BucketSpec.apply agrees with the bridge path
    assert spec.apply(exact.plan).counts == bucketed.plan.counts


# ---------------------------------------------------------------------------
# Padding rows are inert: executor results match the grouped reference
# under geometric and ladder buckets, forward and backward.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bucket", [BucketSpec.geometric(4),
                                    BucketSpec.ladder([3, 10, 24])])
def test_dropless_impl_matches_grouped_under_policies(bucket):
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8, capacity_factor=8.0)
    d = 16
    params = init_moe(KEY, d, mc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    dm = DroplessMoE(DroplessConfig(ep=4, bucket=bucket),
                     cache=SSCCache(max_entries=8))
    want = moe_grouped(params, x, mc, cap=10_000)
    y = jax.jit(lambda p, x: dm.impl(p, x, mc))(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    g1 = jax.grad(lambda p: jnp.sum(dm.impl(p, x, mc) ** 2))(params)
    g2 = jax.grad(lambda p: jnp.sum(
        moe_grouped(p, x, mc, cap=10_000) ** 2))(params)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-3, atol=1e-4, err_msg=k)
    # pad accounting flowed into the cache
    info = dm.cache.info()
    assert info["padded_rows"] >= info["exact_rows"] > 0
    assert dm.step_stats()["pad_ratio"] >= 1.0


# ---------------------------------------------------------------------------
# Coarser specs never lower the cache hit rate on a fixed trace.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fine,coarse", [
    (BucketSpec.linear(4), BucketSpec.linear(8)),
    (BucketSpec.linear(8), BucketSpec.linear(32)),
    (BucketSpec.linear(8), BucketSpec.geometric(8)),
    (BucketSpec.exact(), BucketSpec.geometric(4)),
])
def test_coarser_spec_never_lowers_hit_rate(fine, coarse):
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    rng = np.random.default_rng(0)
    trace = [rng.integers(0, 8, size=(64, 2)) for _ in range(16)]
    all_counts = []
    keys = {fine: set(), coarse: set()}
    misses = {fine: 0, coarse: 0}
    for ti in trace:
        for spec in (fine, coarse):
            plan = plan_from_routing(ti, mc, 4, capacity=None,
                                     bucket=spec).plan
            if plan.counts not in keys[spec]:
                misses[spec] += 1
                keys[spec].add(plan.counts)
        all_counts.extend(np.asarray(
            plan_from_routing(ti, mc, 4, capacity=None).plan.counts
        ).reshape(-1).tolist())
    # precondition: coarse's buckets are unions of fine's on this trace —
    # which is exactly what makes the hit-rate claim a theorem, not luck
    assert coarsens(coarse, fine, all_counts)
    assert misses[coarse] <= misses[fine]


# ---------------------------------------------------------------------------
# fit_ladder: valid ladders, padding bounds, flip-risk pricing.
# ---------------------------------------------------------------------------

def _population(seed=0, n=12):
    rng = np.random.default_rng(seed)
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    return [plan_from_routing(rng.integers(0, 8, size=(64, 2)), mc, 4,
                              capacity=None).plan for _ in range(n)]


def test_fit_ladder_shape_and_padding():
    plans = _population()
    counts = np.stack([np.asarray(p.counts) for p in plans])
    top = int(counts.max())
    for budget in (1, 2, 4):
        spec = fit_ladder(plans, budget, split_penalty=0.0)
        assert spec.policy == "ladder"
        assert 1 <= len(spec.edges) <= budget
        assert spec.edges[-1] == top            # max always covered
        assert all(e in np.unique(counts[counts > 0]) for e in spec.edges)
    # exhaustive budget + no flip pricing → zero padding on the population
    n_distinct = len(np.unique(counts[counts > 0]))
    exact_fit = fit_ladder(plans, n_distinct, split_penalty=0.0)
    assert exact_fit.pad_ratio(counts) == 1.0
    # a padding-optimal fit never pads more than the budget-1 single rung
    one = fit_ladder(plans, 1, split_penalty=0.0)
    four = fit_ladder(plans, 4, split_penalty=0.0)
    assert four.pad_ratio(counts) <= one.pad_ratio(counts)
    with pytest.raises(ValueError):
        fit_ladder(plans, 0)
    with pytest.raises(ValueError):
        fit_ladder(plans, 4, split_penalty=-1.0)
    with pytest.raises(ValueError):
        fit_ladder([np.zeros((2, 2, 2), np.int64)], 2)


def test_fit_ladder_split_penalty_buys_stability():
    """Raising split_penalty must not increase the number of distinct keys
    the fitted ladder produces on its own population (boundaries leave
    high-traffic cell ranges first)."""
    plans = _population()

    def distinct_keys(spec):
        return len({spec.apply(p).counts for p in plans})

    k_sharp = distinct_keys(fit_ladder(plans, 4, split_penalty=0.0))
    k_stable = distinct_keys(fit_ladder(plans, 4, split_penalty=4.0))
    assert k_stable <= k_sharp


# ---------------------------------------------------------------------------
# The spec rides Schedule.opts and the serialized blob.
# ---------------------------------------------------------------------------

def test_blob_records_bucket_provenance():
    spec = BucketSpec.geometric(4)
    mc = MoEConfig(n_experts=4, top_k=1, d_expert=8)
    ti = np.repeat(np.arange(4), 8)[:, None]
    plan = plan_from_routing(ti, mc, 2, capacity=None, bucket=spec).plan
    cfg = ScheduleConfig(ep=2, e_loc=2, rows=0, d_model=16, d_ff=8,
                         plan=plan, bucket=spec.key())
    cache = SSCCache(max_entries=4)
    sched = cache.get_or_compile(cfg, "forward", pipeline=["ratr"])
    assert sched.opts["bucket"] == ["geometric", 4, 2.0]
    blob_key = cache.key(cfg, "forward", pipeline=["ratr"])
    rt = ssc_to_schedule(cache._cache[blob_key])
    assert rt.opts["bucket"] == ["geometric", 4, 2.0]
    assert BucketSpec.from_any(rt.opts["bucket"]) == spec
    # unbucketed compiles don't grow an opts key
    cfg0 = dataclasses.replace(cfg, bucket=None)
    sched0 = cache.get_or_compile(cfg0, "forward", pipeline=["ratr"])
    assert "bucket" not in sched0.opts


def test_ssc_record_rows_counters():
    cache = SSCCache(max_entries=4)
    assert cache.info()["pad_ratio"] == 1.0
    cache.record_rows(100, 150)
    assert cache.info()["pad_ratio"] == pytest.approx(1.5)
    st1 = cache.step_stats()
    assert st1["pad_ratio"] == pytest.approx(1.5)
    st2 = cache.step_stats()           # no rows recorded since → neutral
    assert st2["pad_ratio"] == 1.0
    with pytest.raises(ValueError):
        cache.record_rows(10, 9)


# ---------------------------------------------------------------------------
# Ragged EP: bucketed ring caps cover the exact plan's caps.
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, len(POLICIES) - 1), st.integers(0, 2 ** 31 - 1))
def test_bucketed_ring_caps_cover_exact(pol_idx, seed):
    from repro.parallel.ep import ring_chunk_caps
    spec = POLICIES[pol_idx]
    plan = random_plan(4, 2, 40, np.random.default_rng(seed))
    capped = spec.apply(plan)
    exact_caps = ring_chunk_caps(plan, 4)
    buck_caps = ring_chunk_caps(capped, 4)
    assert all(b >= e for b, e in zip(buck_caps, exact_caps))
    # all-padding steps stay skipped (zero caps preserved)
    assert all((b == 0) == (e == 0) for b, e in zip(buck_caps, exact_caps))


def test_make_moe_ep_bucket_requires_plan():
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.ep import EPConfig, make_moe_ep
    mesh = make_test_mesh(data=1, model=1)
    with pytest.raises(ValueError, match="plan"):
        make_moe_ep(mesh, EPConfig(), bucket="geometric:8")
