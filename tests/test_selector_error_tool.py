"""tools/selector_error.py: JSONL aggregation, metrics, and CI gates.

The tool consumes ``schedsweep --selector-report --report-out`` rows and
reports ordering metrics (argmin match, regret, pairwise accuracy). A tiny
synthetic report with known ordering pins the arithmetic; an end-to-end
case runs a real (small) selector report through the aggregator.
"""

import importlib.util
import json
import sys
from pathlib import Path

import pytest

_TOOL = Path(__file__).resolve().parents[1] / "tools" / "selector_error.py"
_spec = importlib.util.spec_from_file_location("selector_error", _TOOL)
selector_error = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(selector_error)


def _row(plan, cand, pred, sim, picked, sim_best, regret=None):
    return {"plan": plan, "direction": "forward", "candidate": cand,
            "predicted_us": pred, "simulated_us": sim, "picked": picked,
            "sim_best": sim_best, "regret": regret,
            "ep": 4, "e_loc": 8, "rows": 32, "d_model": 64, "d_ff": 32,
            "gmm_m_split": 8}


def _write(tmp_path, rows, name="r.jsonl"):
    p = tmp_path / name
    p.write_text("".join(json.dumps(r) + "\n" for r in rows))
    return str(p)


def test_aggregate_known_ordering(tmp_path):
    rows = [
        # scenario A: pick == sim_best, predictions order correctly
        _row("a", "x", 10.0, 12.0, True, True, 0.0),
        _row("a", "y", 20.0, 24.0, False, False),
        # scenario B: pick != sim_best (5% regret), one inverted pair
        _row("b", "x", 10.0, 21.0, True, False, 0.05),
        _row("b", "y", 20.0, 20.0, False, True),
    ]
    m = selector_error.aggregate(selector_error.load_rows(
        [_write(tmp_path, rows)]))
    assert m["rows"] == 4 and m["scenarios"] == 2
    assert m["argmin_match_rate"] == pytest.approx(0.5)
    assert m["mean_regret"] == pytest.approx(0.025)
    assert m["max_regret"] == pytest.approx(0.05)
    assert m["pairwise_ordering_accuracy"] == pytest.approx(0.5)
    assert m["underprediction_ratio_median"] == pytest.approx(1.2)


def test_main_gates_and_json(tmp_path, capsys):
    rows = [_row("a", "x", 10.0, 12.0, True, True, 0.0),
            _row("a", "y", 20.0, 24.0, False, False)]
    path = _write(tmp_path, rows)
    out = str(tmp_path / "m.json")
    assert selector_error.main([path, "--json", out,
                                "--min-argmin-rate", "0.5",
                                "--max-mean-regret", "0.1"]) == 0
    assert json.loads(Path(out).read_text())["argmin_match_rate"] == 1.0
    # failing gate returns non-zero and names the metric
    assert selector_error.main([path, "--min-argmin-rate", "1.5"]) == 1
    assert "argmin_match_rate" in capsys.readouterr().err


def test_bad_inputs(tmp_path):
    with pytest.raises(FileNotFoundError):
        selector_error.load_rows([str(tmp_path / "missing.jsonl")])
    bad = tmp_path / "bad.jsonl"
    bad.write_text("{not json\n")
    with pytest.raises(ValueError, match="bad JSONL"):
        selector_error.load_rows([str(bad)])


def test_end_to_end_with_real_report(tmp_path):
    from repro.launch.schedsweep import selector_report

    out = str(tmp_path / "report.jsonl")
    rows = selector_report(ep=2, e_loc=4, rows=16, d_model=64, d_ff=32,
                           report_out=out, quiet=True)
    assert rows
    m = selector_error.aggregate(selector_error.load_rows([out]))
    assert m["rows"] == len(rows)
    assert m["scenarios"] > 0
    assert 0.0 <= m["argmin_match_rate"] <= 1.0
    assert m["mean_regret"] is not None and m["mean_regret"] >= 0.0
