"""Topology-aware hierarchical EP: geometry, pricing, execution, caching.

The contract under test, end to end:

* :class:`~repro.core.hardware.Topology` classifies every (src, dst) pair
  into local / intra-node / inter-node link classes;
* two-level dispatch (``dispatch_mode="hier"``) compiles to ordinary tile
  tasks that execute **bit-identical** to flat dispatch (exact with
  compression off, within one quantization step with int8);
* the cost model prices each put on its link class, the simulator
  accounts busy time per class, and auto-selection never picks a
  candidate predicted worse than the best flat one;
* the SSC cache never aliases schedules compiled under different cluster
  shapes or dispatch modes.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import autoselect
from repro.core import executor as ex
from repro.core.costmodel import CostModel
from repro.core.hardware import AscendA3, Topology
from repro.core.odg import (ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.passes import SCHED_PIPELINES, registered_passes
from repro.core.routing import (HierDispatch, RoutingPlan, aggregate_group,
                                balanced_plan, hotspot_plan,
                                node_limited_plan, random_plan, skewed_plan)
from repro.core.scheduler import compile_schedule, validate_schedule
from repro.core.simulator import simulate_unified
from repro.core.ssc import SSCCache
from repro.core.tasks import TaskDescriptor
from repro.parallel.ep import ring_chunk_caps

TOPO = Topology(ranks_per_node=4)


def _plan_grid():
    rng = np.random.default_rng(5)
    return [
        ("zipf", skewed_plan(8, 4, 12, 1.6)),
        ("hotspot", hotspot_plan(8, 4, 12, background=2)),
        ("node_limited", node_limited_plan(8, 4, 12, node_size=4)),
        ("sparse", random_plan(8, 4, 9, rng, p_zero=0.6)),
        ("balanced", balanced_plan(8, 4, 8)),
    ]


def _cfg(plan, d_model=64, **kw):
    return ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                          d_model=d_model, d_ff=d_model // 2, plan=plan,
                          gmm_split_mode="source_aligned", topology=TOPO,
                          **kw)


# ---------------------------------------------------------------------------
# Topology basics
# ---------------------------------------------------------------------------

def test_topology_link_classes():
    t = Topology(ranks_per_node=4)
    assert t.link_class(1, 1) == "local"
    assert t.link_class(0, 3) == "intra"
    assert t.link_class(3, 4) == "inter"
    assert t.node_of(7) == 1 and t.node_of(3) == 0
    assert t.n_nodes(8) == 2
    assert t.bw_gbps("intra") > t.bw_gbps("inter")
    assert t.latency_us("inter") > t.latency_us("intra")


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(ranks_per_node=0)
    with pytest.raises(ValueError):
        Topology(ranks_per_node=4, inter_gbps=-1.0)
    with pytest.raises(ValueError):
        Topology(ranks_per_node=3).n_nodes(8)
    with pytest.raises(ValueError):
        # config-level guard: ep must be a multiple of ranks_per_node
        ScheduleConfig(ep=6, e_loc=2, rows=4, d_model=8, d_ff=4,
                       topology=Topology(ranks_per_node=4))


def test_topology_key_is_identity():
    a = Topology(ranks_per_node=4)
    b = Topology(ranks_per_node=4)
    c = Topology(ranks_per_node=4, inter_gbps=25.0)
    assert a.key() == b.key() and a.key() != c.key()


# ---------------------------------------------------------------------------
# Selective aggregation geometry
# ---------------------------------------------------------------------------

def test_aggregate_group_rule():
    # Singletons never aggregate: the extra hop buys no latency back.
    assert not aggregate_group([100], None)
    assert not aggregate_group([], 10.0)
    # No threshold = aggregate every multi-cell group.
    assert aggregate_group([1, 1], None)
    # Latency-bound groups aggregate, byte-bound groups stay direct:
    # total rows <= (n_cells - 1) * agg_rows.
    assert aggregate_group([5, 5, 5], 10.0)       # 15 <= 20
    assert not aggregate_group([50, 5, 5], 10.0)  # 60 > 20


def test_hier_layout_contiguous_and_conserving():
    plan = skewed_plan(8, 4, 12, 1.6)
    hier = HierDispatch(plan, 4)          # no threshold: aggregate all >= 2
    staged = 0
    for leader in range(8):
        run = 0
        for (d, e, srcs, total) in hier.stage_groups(leader):
            assert hier.leader(hier.node_of(leader), d, e) == leader
            assert hier.group_offset(leader, d, e) == run
            off = run
            for s, c in srcs:
                assert hier.cell_offset(leader, d, e, s) == off
                off += c
            assert off - run == total
            run = off
            lo, rows = hier.recv_node_span(d, e, hier.node_of(leader))
            assert rows == total
            staged += total
        assert hier.stage_rows(leader) == run
    # Every aggregated cross-node row is staged exactly once.
    want = sum(int(plan.count(s, d, e))
               for s in range(8) for d in range(8) for e in range(4)
               if s // 4 != d // 4
               and hier.aggregated(s // 4, d, e))
    assert staged == want


def test_hier_threshold_moves_groups_to_direct_path():
    plan = hotspot_plan(8, 4, 12, background=2)
    all_agg = HierDispatch(plan, 4)
    thresholded = HierDispatch(plan, 4, agg_rows=6.0)
    n_all = sum(all_agg.n_stage_groups(r) for r in range(8))
    n_thr = sum(thresholded.n_stage_groups(r) for r in range(8))
    assert 0 < n_thr < n_all          # the hot cell's group went direct
    assert not thresholded.aggregated(1, 0, 0)


# ---------------------------------------------------------------------------
# node_limited_plan scenario
# ---------------------------------------------------------------------------

def test_node_limited_plan_conserves_and_confines():
    plan = node_limited_plan(8, 4, 16, node_size=4, m_nodes=1, leak=0.05)
    c = np.asarray(plan.counts, dtype=np.int64)
    per_src = c.sum(axis=(1, 2))
    assert (per_src == 8 * 4 * 16).all()      # exact conservation per source
    for s in range(8):
        home = s // 4
        allowed = c[s, home * 4:(home + 1) * 4, :].sum()
        assert allowed >= 0.9 * per_src[s]    # >= 1 - leak goes to home node


# ---------------------------------------------------------------------------
# Cost model per-link-class pricing
# ---------------------------------------------------------------------------

def _put(nbytes, src, dst):
    return TaskDescriptor(task_type="put_mem_signal", queue_type="VTQ",
                          comm_bytes=nbytes, src_rank=src, dst_rank=dst,
                          rank=src)


def test_costmodel_prices_link_classes():
    cm = CostModel(hw=AscendA3(), topology=TOPO, l2=False)
    n = 1 << 20
    local = cm.task_us(_put(n, 2, 2))
    intra = cm.task_us(_put(n, 0, 2))
    inter = cm.task_us(_put(n, 0, 5))
    assert local < intra < inter
    assert cm.link_class_of(_put(n, 0, 5)) == "inter"
    assert cm.link_class_of(_put(n, 0, 2)) == "intra"
    # Latency floor: a tiny inter-node message is never cheaper than the
    # per-hop latency; a local copy has no such floor.
    assert cm.task_us(_put(16, 0, 5)) >= TOPO.inter_hop_us
    assert cm.task_us(_put(16, 2, 2)) < TOPO.intra_hop_us


def test_costmodel_flat_link_latency_floor():
    cm = CostModel(hw=AscendA3(), l2=False)       # no topology: one "link"
    assert cm.task_us(_put(16, 0, 5)) >= cm.hw.hop_latency_us
    assert cm.link_class_of(_put(16, 0, 5)) == "link"


# ---------------------------------------------------------------------------
# Compilation + executor parity, forward and backward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,plan", _plan_grid())
@pytest.mark.parametrize("m_split", [1, 6])
def test_hier_compiles_and_validates(name, plan, m_split):
    for build in (build_moe_ffn_forward, build_moe_ffn_backward):
        s = compile_schedule(
            build(_cfg(plan, gmm_m_split=m_split, dispatch_mode="hier")),
            pipeline=["ratr", "gmm_interleave", "critical_rank_first",
                      "hier_dispatch"])
        validate_schedule(s)


@pytest.mark.parametrize("name,plan", _plan_grid())
@pytest.mark.parametrize("m_split", [1, 6])
def test_hier_forward_parity_with_flat(name, plan, m_split):
    """Hier recv buffers are bit-identical to flat for every tiling; the
    end-to-end output is bit-identical at m_split=1 (identical GMM tiles)
    and allclose beyond (BLAS blocking differs with tile shapes)."""
    flat_cfg = _cfg(plan, gmm_m_split=m_split)
    hier_cfg = _cfg(plan, gmm_m_split=m_split, dispatch_mode="hier")
    x, w1, w2 = ex.make_inputs_plan(flat_cfg, 7)
    out = {}
    for tag, cfg in (("flat", flat_cfg), ("hier", hier_cfg)):
        s = compile_schedule(build_moe_ffn_forward(cfg),
                             pipeline=["ratr", "hier_dispatch"])
        st = ex.ExecutorState(cfg)
        ex.load_forward_state_plan(cfg, st, x, w1, w2)
        ex.execute(s, st, rng=np.random.default_rng(3))
        out[tag] = st
    for r in range(plan.ep):
        if plan.recv_rows(r):
            np.testing.assert_array_equal(out["flat"].get("x_recv", r),
                                          out["hier"].get("x_recv", r))
        if plan.send_rows(r):
            a = out["flat"].get("y_ret", r)
            b = out["hier"].get("y_ret", r)
            if m_split == 1:
                np.testing.assert_array_equal(a, b)
            else:
                np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name,plan", _plan_grid()[:3])
def test_hier_backward_parity_with_flat(name, plan):
    flat_cfg = _cfg(plan, gmm_m_split=1)
    hier_cfg = _cfg(plan, gmm_m_split=1, dispatch_mode="hier")
    x, w1, w2 = ex.make_inputs_plan(flat_cfg, 11)
    fwd = ex.reference_forward_plan(flat_cfg, x, w1, w2)
    rng = np.random.default_rng(13)
    dy = [rng.standard_normal(fwd["y_ret"][r].shape).astype(np.float32)
          for r in range(plan.ep)]
    out = {}
    for tag, cfg in (("flat", flat_cfg), ("hier", hier_cfg)):
        s = compile_schedule(build_moe_ffn_backward(cfg),
                             pipeline=["ratr", "hier_dispatch"])
        st = ex.ExecutorState(cfg)
        ex.load_backward_state_plan(cfg, st, fwd, w1, w2, dy)
        ex.execute(s, st, rng=np.random.default_rng(1))
        out[tag] = st
    for r in range(plan.ep):
        if plan.recv_rows(r):
            np.testing.assert_array_equal(out["flat"].get("dy_recv", r),
                                          out["hier"].get("dy_recv", r))
            np.testing.assert_array_equal(out["flat"].get("dW1", r),
                                          out["hier"].get("dW1", r))
        if plan.send_rows(r):
            np.testing.assert_array_equal(out["flat"].get("dx_ret", r),
                                          out["hier"].get("dx_ret", r))


def test_hier_int8_parity_within_quantization():
    plan = skewed_plan(8, 4, 12, 1.6)
    flat_cfg = _cfg(plan, gmm_m_split=1)
    comp_cfg = _cfg(plan, gmm_m_split=1, dispatch_mode="hier",
                    xnode_compress="int8")
    x, w1, w2 = ex.make_inputs_plan(flat_cfg, 7)
    out = {}
    for tag, cfg in (("flat", flat_cfg), ("int8", comp_cfg)):
        s = compile_schedule(build_moe_ffn_forward(cfg),
                             pipeline=["ratr", "hier_dispatch"])
        st = ex.ExecutorState(cfg)
        ex.load_forward_state_plan(cfg, st, x, w1, w2)
        ex.execute(s, st, rng=np.random.default_rng(3))
        out[tag] = st
    saw_delta = False
    for r in range(plan.ep):
        if not plan.recv_rows(r):
            continue
        a = out["flat"].get("x_recv", r)
        b = out["int8"].get("x_recv", r)
        # Per-message symmetric int8: error within half a quantization step
        # of each message's amax; one global bound of the whole buffer's
        # amax covers every message.
        step = np.abs(a).max() / 127.0
        np.testing.assert_allclose(b, a, rtol=0, atol=step * 0.5 + 1e-7)
        saw_delta |= not np.array_equal(a, b)
    assert saw_delta          # compression actually touched the inter hop


# ---------------------------------------------------------------------------
# Simulator per-link-class accounting
# ---------------------------------------------------------------------------

def test_simulator_link_class_accounting():
    plan = skewed_plan(8, 4, 12, 1.6)
    hw = AscendA3()
    cost = CostModel(hw=hw, topology=TOPO)
    s = compile_schedule(build_moe_ffn_forward(_cfg(plan)), ratr=True)
    r = simulate_unified(s, hw, cost=cost)
    assert set(r.link_us) == {"local", "intra", "inter"}
    assert r.link_us["inter"] > 0 and r.link_us["intra"] > 0
    # Without a topology the same schedule accounts on the flat classes.
    r0 = simulate_unified(
        compile_schedule(build_moe_ffn_forward(
            dataclasses.replace(_cfg(plan), topology=None)), ratr=True),
        hw)
    assert set(r0.link_us) == {"local", "link"}


def test_hier_reduces_inter_node_busy():
    plan = node_limited_plan(8, 4, 16, node_size=4)
    hw = AscendA3()
    cost = CostModel(hw=hw, topology=TOPO)
    flat = simulate_unified(compile_schedule(
        build_moe_ffn_forward(_cfg(plan)),
        pipeline=["ratr", "hier_dispatch"]), hw, cost=cost)
    hier = simulate_unified(compile_schedule(
        build_moe_ffn_forward(_cfg(plan, dispatch_mode="hier")),
        pipeline=["ratr", "hier_dispatch"]), hw, cost=cost)
    assert hier.link_us["inter"] < flat.link_us["inter"]


# ---------------------------------------------------------------------------
# Passes: hier_dispatch registration + flat no-op; node-aware RATR
# ---------------------------------------------------------------------------

def test_hier_dispatch_pass_registered_not_in_pipelines():
    assert "hier_dispatch" in registered_passes()
    # Locked contract: selection variants ride config changes, not new
    # pipeline names.
    assert set(SCHED_PIPELINES) == {"naive", "ratr", "ratr+gmm_il",
                                    "ratr+crit", "all"}


def test_hier_dispatch_pass_noop_on_flat():
    plan = skewed_plan(8, 4, 12, 1.6)
    base = compile_schedule(build_moe_ffn_forward(_cfg(plan)),
                            pipeline=["ratr"])
    passed = compile_schedule(build_moe_ffn_forward(_cfg(plan)),
                              pipeline=["ratr", "hier_dispatch"])
    assert base.queues == passed.queues


def test_node_aware_ratr_orders_nodes_first():
    plan = balanced_plan(8, 2, 4)
    s = compile_schedule(build_moe_ffn_forward(_cfg(plan)),
                         pipeline=["ratr"])
    # Rank 0's dispatch block must visit every remote-node destination
    # before wrapping back to its own node (ranks 1..3 come after 4..7).
    q = s.queues[(0, "VTQ")]
    dsts = [s.tasks[t].dst_rank for t in q
            if s.tasks[t].task_type == "put_mem_signal"
            and s.tasks[t].meta.get("comm_kind") == "dispatch"
            and s.tasks[t].dst_rank >= 0]
    remote = [d for d in dsts if d != 0]
    first_other_node = [d >= 4 for d in remote]
    assert all(first_other_node[:sum(first_other_node)])  # inter block first


# ---------------------------------------------------------------------------
# Auto-selection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,plan", _plan_grid()[:3])
@pytest.mark.parametrize("direction", ["forward", "backward"])
def test_autoselect_never_worse_than_flat(name, plan, direction):
    choice = autoselect.select(None, _cfg(plan, d_model=1024),
                               direction=direction)
    flat_best = min(s.predicted_us for s in choice.scores
                    if s.cfg.dispatch_mode == "flat")
    assert choice.predicted_us <= flat_best
    assert any(s.cfg.dispatch_mode == "hier" for s in choice.scores)


def test_autoselect_no_hier_without_topology():
    plan = skewed_plan(8, 4, 12, 1.6)
    cfg = dataclasses.replace(_cfg(plan), topology=None)
    choice = autoselect.select(None, cfg)
    assert all(s.cfg.dispatch_mode == "flat" for s in choice.scores)


def test_autoselect_hier_choice_compiles():
    plan = node_limited_plan(8, 4, 16, node_size=4)
    choice = autoselect.select(None, _cfg(plan, d_model=1024))
    s = compile_schedule(
        (build_moe_ffn_forward if True else None)(choice.cfg),
        pipeline=choice.pipeline)
    validate_schedule(s)


# ---------------------------------------------------------------------------
# SSC cache keying
# ---------------------------------------------------------------------------

def test_ssc_key_separates_topology_and_dispatch_mode():
    plan = skewed_plan(8, 4, 12, 1.6)
    base = _cfg(plan)
    keys = {
        SSCCache.key(base, "forward", pipeline=["ratr"]),
        SSCCache.key(dataclasses.replace(base, topology=None), "forward",
                     pipeline=["ratr"]),
        SSCCache.key(dataclasses.replace(base, dispatch_mode="hier"),
                     "forward", pipeline=["ratr"]),
        SSCCache.key(dataclasses.replace(base, dispatch_mode="hier",
                                         xnode_compress="int8"),
                     "forward", pipeline=["ratr"]),
        SSCCache.key(dataclasses.replace(
            base, topology=Topology(ranks_per_node=2)), "forward",
            pipeline=["ratr"]),
    }
    assert len(keys) == 5


def test_ssc_roundtrip_hier_schedule():
    plan = node_limited_plan(8, 4, 12, node_size=4)
    cache = SSCCache()
    cfg = _cfg(plan, dispatch_mode="hier")
    s1 = cache.get_or_compile(cfg, "forward",
                              pipeline=["ratr", "hier_dispatch"])
    s2 = cache.get_or_compile(cfg, "forward",
                              pipeline=["ratr", "hier_dispatch"])
    assert cache.hits >= 1
    assert s1.queues == s2.queues
    validate_schedule(s2)


# ---------------------------------------------------------------------------
# Ring caps per link class
# ---------------------------------------------------------------------------

def test_ring_caps_per_link_class_bucketing():
    plan = random_plan(8, 2, 9, np.random.default_rng(3), p_zero=0.3)
    exact = ring_chunk_caps(plan, 8)
    caps = ring_chunk_caps(plan, 8, topology=TOPO, bucket=4,
                           inter_bucket=32)
    for k in range(8):
        inter = any(not TOPO.same_node(s, (s + k) % 8) for s in range(8))
        assert caps[k] >= exact[k]            # never undercounts
        if exact[k] == 0:
            assert caps[k] == 0               # step skipping survives
        elif inter:
            assert caps[k] % 32 == 0
        else:
            assert caps[k] % 4 == 0
    # Single-node topology: every step quantizes on the intra ladder.
    one_node = Topology(ranks_per_node=8)
    caps1 = ring_chunk_caps(plan, 8, topology=one_node, bucket=4,
                            inter_bucket=32)
    assert all(c % 4 == 0 for c in caps1 if c)
    with pytest.raises(ValueError):
        ring_chunk_caps(plan, 8, inter_bucket=32)


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
