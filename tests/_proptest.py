"""Property-test front end: real hypothesis when installed, a minimal
deterministic fallback otherwise.

The seed gap this closes: ``tests/test_properties.py`` silently skipped
whenever ``hypothesis`` was missing, so the property suite never ran in a
bare-container tier-1 run. Importing ``given`` / ``settings`` / ``st`` from
here keeps the tests byte-identical under real hypothesis (CI installs it —
see ``requirements-dev.txt``) while a ~100-line shim executes the same
properties with seeded random sampling when it is absent. The shim is *not*
hypothesis — no shrinking, no coverage-guided generation, no database — but
it draws from the same strategy space deterministically (CRC-seeded per
test), so the invariants are genuinely exercised in every environment.

Supported strategy subset (what the repo's properties use):
``just`` / ``booleans`` / ``integers`` / ``floats`` / ``sampled_from`` /
``tuples`` / ``lists`` / ``builds``, plus ``.filter`` and ``.map``.
"""

from __future__ import annotations

try:                                     # pragma: no cover - env-dependent
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import zlib

    _DEFAULT_EXAMPLES = 50
    _MAX_REJECTS = 1000

    class _Strategy:
        """A draw-from-seeded-rng generator with filter/map combinators."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

        def filter(self, pred):
            def draw(rng):
                for _ in range(_MAX_REJECTS):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError(
                    "proptest fallback: filter rejected "
                    f"{_MAX_REJECTS} consecutive examples")
            return _Strategy(draw)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _St:
        """The ``strategies`` namespace subset the fallback provides."""

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strats))

        @staticmethod
        def lists(elem, *, min_size=0, max_size=8, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                out = []
                for _ in range(_MAX_REJECTS):
                    if len(out) >= n:
                        break
                    v = elem.example(rng)
                    if unique and v in out:
                        continue
                    out.append(v)
                if len(out) < min_size:
                    # Real hypothesis raises Unsatisfiable here; failing
                    # loudly keeps the two environments equivalent instead
                    # of silently violating the property's precondition.
                    raise ValueError(
                        f"proptest fallback: could not draw {min_size} "
                        f"unique list elements (got {len(out)})")
                return out
            return _Strategy(draw)

        @staticmethod
        def builds(target, **kw_strats):
            return _Strategy(lambda rng: target(
                **{k: s.example(rng) for k, s in kw_strats.items()}))

    st = _St()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
                 **_kw):
        """Record ``max_examples`` on the (already ``given``-wrapped) test."""
        def deco(fn):
            fn._proptest_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        """Run the test body over deterministically drawn examples.

        Seeding is by CRC of the test's qualified name — stable across
        processes and runs (unlike ``hash``, which is salted) — so a
        failure reproduces; the failing example is attached to the raised
        error since the shim cannot shrink.
        """
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_proptest_max_examples",
                            _DEFAULT_EXAMPLES)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = random.Random(seed)
                for i in range(n):
                    drawn = [s.example(rng) for s in strats]
                    try:
                        fn(*args, *drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"proptest fallback example {i + 1}/{n} "
                            f"failed: args={drawn!r}") from e
            # The drawn parameters are filled here, not by pytest — hide
            # them so the collector doesn't go hunting for fixtures.
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper
        return deco
