"""Pipeline-parallel schedule fusion: StageBoundary legality, 1F1B
interleave, stage-barrier reference, pricing, caching, selection.

The PP-fusion contract (``core/fusion.py``): a pipeline stage is a
fragment whose boundary carries *activations*. ``compile_pp_fused`` must

1. stay acyclic and deadlock-free for any tuple of real per-stage plans,
   any stage count and any microbatch count — proved by
   ``validate_schedule`` plus an event-driven simulation per example, in
   both plain and ``stage_barrier`` (fair per-stage reference) modes;
2. execute bit-identically to per-stage sequential execution with the
   stage handoff applied on the host between cells, fwd and bwd;
3. price StageBoundary tiles on the stage link class (``inter`` under a
   topology), expose ``pp_bubble_us``, and key SSC blobs on
   (stages, microbatches, boundary kind) so shapes never alias;
4. feed ``select_pp``, whose fused estimate is never worse than the
   per-stage reference by construction.
"""

import numpy as np
import pytest

from repro.core import fusion as fu
from repro.core import executor as ex
from repro.core.autoselect import select_fused, select_pp
from repro.core.costmodel import CostModel
from repro.core.hardware import Topology
from repro.core.odg import ScheduleConfig
from repro.core.routing import hotspot_plan, random_plan, skewed_plan
from repro.core.scheduler import validate_schedule
from repro.core.simulator import simulate_unified
from repro.core.ssc import SSCCache, schedule_to_ssc

from tests._proptest import given, settings, st

EP = 3
D = 8


def _cfg(plan, topology=None):
    return ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                          d_model=D, d_ff=4, plan=plan, topology=topology)


def _plan_of(kind, seed):
    rng = np.random.default_rng(seed)
    if kind == "skewed":
        return skewed_plan(EP, 2, 6, 1.0 + (seed % 3) * 0.5)
    if kind == "sparse":
        return random_plan(EP, 2, 7, rng, p_zero=0.5)
    return hotspot_plan(EP, 2, 4, background=seed % 3)


KINDS = ("skewed", "sparse", "hotspot")


def _stage_matrices(plans, rng):
    """One remap matrix per junction (between stage s and s+1) per rank:
    rows of stage s+1's send layout from rows of stage s's."""
    return [{r: rng.standard_normal(
                (plans[s + 1].send_rows(r), plans[s].send_rows(r)))
                .astype(np.float32)
             for r in range(EP)}
            for s in range(len(plans) - 1)]


def _pp_boundary_fns(fs, mats, transpose=False):
    """boundary_fns for a PP-fused schedule: physical junction
    ``m*(S-1) + s`` sits between stages s and s+1 of microbatch m, for
    forward and backward alike (``transpose`` flips the remap for bwd)."""
    pp = fs.opts["pp"]
    S, M = pp["n_stages"], pp["n_microbatches"]
    fns = {}
    for m in range(M):
        for s in range(S - 1):
            j = m * (S - 1) + s
            for r in range(EP):
                A = mats[s][r].T if transpose else mats[s][r]

                def fn(data, lo, hi, A=A):
                    if data is None:
                        data = np.zeros((A.shape[1], D), np.float32)
                    return (A @ data)[lo:hi]
                fns[(j, r)] = fn
    return fns


@settings(max_examples=10, deadline=None)
@given(st.lists(st.sampled_from(KINDS), min_size=2, max_size=3),
       st.integers(min_value=1, max_value=3),
       st.integers(min_value=0, max_value=10_000))
def test_pp_fused_acyclic_deadlock_free_bit_identical(kinds, M, seed):
    S = len(kinds)
    plans = [_plan_of(k, seed + i) for i, k in enumerate(kinds)]
    cfgs = [_cfg(p) for p in plans]
    rng = np.random.default_rng(seed)
    mats = _stage_matrices(plans, rng)

    # ---- forward: legality + simulation + bit-exact execution ----------
    fs = fu.compile_pp_fused(cfgs, M, direction="forward",
                             pipeline=("ratr",))
    validate_schedule(fs)               # acyclic, single-trigger, complete
    assert fs.opts["pp"] == {"n_stages": S, "n_microbatches": M,
                             "order": [[s, m] for (s, m)
                                       in fu.pp_cell_order(S, M, "forward")]}
    res = simulate_unified(fs)          # deadlock-free: every task retires
    resb = simulate_unified(fs, stage_barrier=True)
    assert res.makespan_us > 0 and resb.makespan_us > 0
    assert set(res.stage_span_us) == {(s, m) for s in range(S)
                                      for m in range(M)}
    with pytest.raises(ValueError):
        simulate_unified(fs, stage_barrier=True, fragment_barrier=True)

    ws = [ex.make_inputs_plan(c, (seed + 13 * i) % 97)
          for i, c in enumerate(cfgs)]
    x_srcs = [[rng.standard_normal((plans[0].send_rows(r), D))
               .astype(np.float32) for r in range(EP)] for _ in range(M)]
    refs = []                            # refs[m][s]
    for m in range(M):
        cur, per_m = x_srcs[m], []
        for s in range(S):
            per_m.append(ex.reference_forward_plan(cfgs[s], cur,
                                                   ws[s][1], ws[s][2]))
            if s < S - 1:
                cur = [mats[s][r] @ per_m[s]["y_ret"][r] for r in range(EP)]
        refs.append(per_m)

    stf = ex.ExecutorState(cfgs[0],
                           fragment_cfgs=fu.pp_fragment_cfgs(fs, cfgs))
    fu.load_pp_forward_state(fs, cfgs, stf, x_srcs,
                             [w[1] for w in ws], [w[2] for w in ws])
    stf.boundary_fns = _pp_boundary_fns(fs, mats)
    ex.execute(fs, stf, rng=np.random.default_rng(seed))
    for m in range(M):
        for s in range(S):
            for r in range(EP):
                if plans[s].send_rows(r):
                    np.testing.assert_array_equal(
                        stf.get(f"y_ret#S{s}M{m}", r), refs[m][s]["y_ret"][r])

    # ---- backward: reversed wave order, transposed stage handoff -------
    fb = fu.compile_pp_fused(cfgs, M, direction="backward",
                             pipeline=("ratr", "gmm_interleave"))
    validate_schedule(fb)
    simulate_unified(fb)
    simulate_unified(fb, stage_barrier=True)
    assert fb.opts["pp"]["order"][0] == [S - 1, 0]     # top stage first

    dys = [[rng.standard_normal(refs[m][S - 1]["y_ret"][r].shape)
            .astype(np.float32) for r in range(EP)] for m in range(M)]
    brefs = []                           # brefs[m][s] = (dx, dw1, dw2)
    for m in range(M):
        per_m = [None] * S
        dy = dys[m]
        for s in range(S - 1, -1, -1):
            per_m[s] = ex.reference_backward_plan(cfgs[s], refs[m][s],
                                                  ws[s][1], ws[s][2], dy)
            if s > 0:
                dy = [mats[s - 1][r].T @ per_m[s][0][r] for r in range(EP)]
        brefs.append(per_m)

    stb = ex.ExecutorState(cfgs[-1],
                           fragment_cfgs=fu.pp_fragment_cfgs(fb, cfgs))
    fu.load_pp_backward_state(fb, cfgs, stb, dys, refs,
                              [w[1] for w in ws], [w[2] for w in ws])
    stb.boundary_fns = _pp_boundary_fns(fb, mats, transpose=True)
    ex.execute(fb, stb, rng=np.random.default_rng(seed + 1))
    for m in range(M):
        for s in range(S):
            dx, dw1, dw2 = brefs[m][s]
            lab = f"S{s}M{m}"
            for r in range(EP):
                if plans[s].send_rows(r):
                    np.testing.assert_array_equal(
                        stb.get(f"dx_ret#{lab}", r), dx[r])
                if plans[s].recv_rows(r):
                    np.testing.assert_array_equal(
                        stb.get(f"dW1#{lab}", r), dw1[r])
                    np.testing.assert_array_equal(
                        stb.get(f"dW2#{lab}", r), dw2[r])


def test_stage_boundary_tasks_carry_activation_payload():
    """StageBoundary tiles are per-rank p2p with non-zero comm_bytes,
    stamped with cell metadata and priced on the stage link class."""
    topo = Topology(ranks_per_node=3)
    plans = [skewed_plan(EP, 2, 6, 1.5), hotspot_plan(EP, 2, 4)]
    cfgs = [_cfg(p, topology=topo) for p in plans]
    fs = fu.compile_pp_fused(cfgs, 2, direction="forward")
    cost = CostModel(topology=topo)
    bnd = [fs.tasks[t] for f in fs.fragments for t in f.boundary_tids]
    assert bnd
    for td in bnd:
        assert td.task_type == "StageBoundary"
        assert td.meta["comm_kind"] == "stage"
        assert {"pp_stage", "pp_microbatch", "boundary"} <= set(td.meta)
        assert td.comm_bytes > 0
        assert td.src_rank == td.dst_rank == td.rank
        assert cost.link_class_of(td) == "inter"
        assert cost.task_us(td) > 0
    # boundary rows cover each downstream cell's send layout exactly
    per_cell = {}
    for td in bnd:
        key = (td.meta["pp_stage"], td.meta["pp_microbatch"], td.rank)
        per_cell.setdefault(key, []).append(
            (td.outputs[0].lo, td.outputs[0].hi))
    for (s, _, r), spans in per_cell.items():
        spans.sort()
        assert spans[0][0] == 0 and spans[-1][1] == plans[s].send_rows(r)
        for (_, b), (c, _) in zip(spans, spans[1:]):
            assert b == c
    # without a topology the flat stage link prices the payload instead
    flat = CostModel()
    assert all(flat.link_class_of(td) == "link" for td in bnd)
    assert all(flat.task_us(td) > 0 for td in bnd)
    # the cost model sees a non-trivial pipeline ramp for S >= 2
    assert cost.pp_bubble_us(fs) > 0
    single = fu.compile_pp_fused([cfgs[0]], 2, n_stages=1)
    assert cost.pp_bubble_us(single) == 0.0


def test_pp_cell_order_is_1f1b_wave_order():
    assert fu.pp_cell_order(2, 3, "forward") == [
        (0, 0), (0, 1), (1, 0), (0, 2), (1, 1), (1, 2)]
    assert fu.pp_cell_order(2, 3, "backward") == [
        (1, 0), (1, 1), (0, 0), (1, 2), (0, 1), (0, 2)]
    for direction in ("forward", "backward"):
        order = fu.pp_cell_order(3, 4, direction)
        assert sorted(order) == [(s, m) for s in range(3) for m in range(4)]
        # microbatches of one stage stay in order
        for s in range(3):
            ms = [m for (s_, m) in order if s_ == s]
            assert ms == sorted(ms)


def test_pp_ssc_keys_separate_shapes_and_kinds():
    """Same stage plans at different (stages, microbatches) — or vs layer
    fusion — never alias in the SSC cache."""
    plan = skewed_plan(EP, 2, 6, 1.5)
    cfg = _cfg(plan)
    cache = SSCCache(max_entries=16)
    a = cache.get_or_compile_pp_fused([cfg, cfg], 1, "forward")
    b = cache.get_or_compile_pp_fused([cfg, cfg], 2, "forward")
    c = cache.get_or_compile_pp_fused([cfg, cfg, cfg], 1, "forward")
    d = cache.get_or_compile_fused([cfg, cfg], "forward")
    assert cache.misses == 4 and cache.hits == 0
    assert len({len(s.tasks) for s in (a, b, c)}) == 3
    # layer fusion bridges with LayerBoundary, PP fusion with StageBoundary
    assert any(t.task_type == "StageBoundary" for t in a.tasks)
    assert not any(t.task_type == "LayerBoundary" for t in a.tasks)
    assert any(t.task_type == "LayerBoundary" for t in d.tasks)
    # hits round-trip byte-identically
    a2 = cache.get_or_compile_pp_fused([cfg, cfg], 1, "forward")
    assert cache.hits == 1
    assert schedule_to_ssc(a2) == schedule_to_ssc(a)
    # and the blob equals a fresh compile (deterministic end to end)
    fresh = fu.compile_pp_fused([cfg, cfg], 1, direction="forward")
    assert schedule_to_ssc(fresh) == schedule_to_ssc(a)


def test_select_pp_never_predicts_fused_worse():
    for kinds in (("skewed", "skewed"), ("skewed", "hotspot"),
                  ("hotspot", "sparse", "skewed")):
        plans = [_plan_of(k, 5 + i) for i, k in enumerate(kinds)]
        cfgs = [_cfg(p) for p in plans]
        for M in (1, 2, 4):
            for direction in ("forward", "backward"):
                ch = select_pp(cfgs, M, direction=direction)
                assert ch.n_stages == len(cfgs)
                assert ch.n_microbatches == M
                assert (ch.predicted_fused_us
                        <= ch.predicted_per_stage_us + 1e-9)
                assert ch.fuse
                assert ch.bubble_us >= 0
                assert len(ch.choices) == len(cfgs)
    with pytest.raises(ValueError):
        select_pp(cfgs, 0)
    with pytest.raises(ValueError):
        select_pp(cfgs, 2, direction="sideways")


def test_select_fused_prices_host_bridge_alternative():
    plans = [_plan_of("skewed", 3), _plan_of("hotspot", 4)]
    cfgs = [_cfg(p) for p in plans]
    for direction in ("forward", "backward"):
        ch = select_fused(cfgs, direction=direction)
        assert ch.fuse == (ch.predicted_fused_us
                           <= ch.predicted_per_layer_us)
        assert ch.predicted_fused_us > 0
        assert ch.predicted_per_layer_us > 0
        assert len(ch.choices) == 2
    # at these sizes the host round-trip constant dominates the remap
    assert select_fused(cfgs).fuse
    with pytest.raises(ValueError):
        select_fused(cfgs, direction="sideways")
