"""Core scheduler unit tests: Algorithm 1, TD generation, events, SSC."""

import numpy as np
import pytest

from repro.core.odg import (ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.reorder import ratr_order
from repro.core.scheduler import (ScheduleError, compile_schedule,
                                  execution_order, validate_schedule)
from repro.core.split import propagate_splits, split_report
from repro.core.ssc import SSCCache, schedule_to_ssc, ssc_to_schedule
from repro.core.tasks import NO_EVENT

CFG = ScheduleConfig(ep=4, e_loc=2, rows=8, d_model=32, d_ff=16)


def test_split_propagation_counts():
    g = build_moe_ffn_forward(CFG)
    propagate_splits(g)
    rep = dict(split_report(g))
    assert rep["Dispatch@0"] == CFG.ep * CFG.e_loc
    assert rep["GMM1@0"] == CFG.e_loc * CFG.gmm_m_split
    assert rep["SwiGLU@0"] == CFG.e_loc * CFG.gmm_m_split
    assert rep["Combine@0"] == CFG.ep * CFG.e_loc


def test_split_propagation_labels():
    g = build_moe_ffn_forward(CFG)
    propagate_splits(g)
    # Dispatch output (recv buffer) is row-partitioned → GMM can split.
    assert g.tensors["x_recv@0"].split_dim == 0
    assert g.tensors["h@1"].split_dim == 0


def test_split_fallback_on_missing_labels():
    """An op whose required input label is absent gets one unsplit task."""
    from repro.core.odg import ODG, OperatorNode, SplitSpec, VECTOR
    cfg = CFG
    g = ODG(cfg, "forward")
    x = g.tensor("x@0", 64, 8, external=True)  # external: never labelled
    y = g.tensor("y@0", 64, 8)
    g.add_op(OperatorNode(
        name="EW@0", op_type="swiglu", resource=VECTOR, rank=0,
        inputs=[x], outputs=[y],
        split_spec=SplitSpec(split_inputs=((0, 0),),
                             split_output_dims=(0,),
                             task_num_fn=lambda c, op: 8)))
    propagate_splits(g)
    assert g.ops[0].task_num == 1          # fallback (Algorithm 1 line 12)


def test_dispatch_gmm_event_threshold():
    g = build_moe_ffn_forward(CFG)
    s = compile_schedule(g)
    # A GMM1 tile must wait for all ep source ranks' dispatch tiles.
    gmm1 = [t for t in s.tasks if t.op_name == "GMM1@0"]
    for td in gmm1:
        assert td.dependent_event != NO_EVENT
        assert td.dependent_threshold == CFG.ep


def test_shared_event_multiple_waiters():
    """Combine tasks of one expert share the GMM2 tile's event (§4.3)."""
    g = build_moe_ffn_forward(CFG)
    s = compile_schedule(g)
    comb = [t for t in s.tasks if t.op_name == "Combine@0"
            and t.meta.get("expert") == 0]
    events = {t.dependent_event for t in comb}
    assert len(events) == 1
    assert s.events[events.pop()].threshold == 1


def test_single_trigger_violation_detected():
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=9, d_model=32, d_ff=16,
                         gmm_m_split=3)  # 9*4=36 rows / 3 = 12: straddles
    with pytest.raises(ScheduleError, match="single-trigger"):
        compile_schedule(build_moe_ffn_forward(cfg))


def test_nested_finer_gmm_split_is_legal():
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=8, d_model=32, d_ff=16,
                         gmm_m_split=8)  # chunks nest inside dispatch tiles
    s = compile_schedule(build_moe_ffn_forward(cfg))
    validate_schedule(s)


def test_ratr_ring_order():
    assert ratr_order(0, 4) == [1, 2, 3, 0]
    assert ratr_order(2, 4) == [3, 0, 1, 2]


def test_ratr_no_destination_hotspot():
    """At every ring step the destination set is a permutation of ranks."""
    g = build_moe_ffn_forward(CFG)
    s = compile_schedule(g, ratr=True)
    per_rank_dsts = {}
    for r in range(CFG.ep):
        dsts = []
        for tid in s.queue(r, "VTQ"):
            td = s.tasks[tid]
            if td.op_name.startswith("Dispatch") and td.dst_rank >= 0:
                if td.dst_rank not in dsts:
                    dsts.append(td.dst_rank)
        per_rank_dsts[r] = dsts
    for step in range(CFG.ep):
        step_dsts = {per_rank_dsts[r][step] for r in range(CFG.ep)}
        assert step_dsts == set(range(CFG.ep)), f"hotspot at step {step}"


def test_gmm_interleave_alternates_branches():
    g = build_moe_ffn_backward(CFG)
    s = compile_schedule(g, gmm_interleave=True)
    ctq = [s.tasks[t].op_name.split("@")[0] for t in s.queue(0, "CTQ")]
    head = ctq[:4]
    assert head == ["GMM_act_grad", "GMM_w2_grad",
                    "GMM_act_grad", "GMM_w2_grad"]


def test_reorderings_stay_legal():
    for direction, builder in (("f", build_moe_ffn_forward),
                               ("b", build_moe_ffn_backward)):
        s = compile_schedule(builder(CFG), ratr=True, gmm_interleave=True)
        validate_schedule(s)
        order = execution_order(s)
        assert sorted(order) == list(range(s.n_tasks))


def test_ssc_roundtrip():
    s = compile_schedule(build_moe_ffn_forward(CFG), ratr=True)
    s2 = ssc_to_schedule(schedule_to_ssc(s))
    assert s2.n_tasks == s.n_tasks
    assert s2.queues == s.queues
    assert {e.eid: e.threshold for e in s2.events.values()} == \
        {e.eid: e.threshold for e in s.events.values()}
    for a, b in zip(s.tasks, s2.tasks):
        assert a.inputs == b.inputs and a.outputs == b.outputs
        assert a.dependent_event == b.dependent_event


def test_ssc_cache_reuse():
    cache = SSCCache()
    cache.get_or_compile(CFG, "forward", ratr=True)
    cache.get_or_compile(CFG, "forward", ratr=True)
    assert cache.hits == 1 and cache.misses == 1
    cache.get_or_compile(CFG, "backward", ratr=True)
    assert cache.misses == 2
