import os
import sys

# Tests see the real device count (the dry-run alone forces 512 devices).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
