import os
import sys

# Tests see the real device count (the dry-run alone forces 512 devices).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Serving jits the whole decode step *around* the dropless pure_callback
# executor; under async CPU dispatch the callback's device-to-host operand
# transfer can deadlock against the in-flight executable. The knob only
# binds at CPU-client creation, so it must be set before any test touches
# jax — hence here and not in the serving module's test.
import jax

jax.config.update("jax_cpu_enable_async_dispatch", False)
