"""Model substrate tests: per-arch smokes + layer-level oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, cells, skip_reason
from repro.models import model as M
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    if cfg.family == "audio":
        return {"features": jax.random.normal(KEY, (B, S, cfg.feat_in)),
                "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(KEY, (B, cfg.n_patches, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one grad step, shapes + finiteness."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits = M.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = float(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)) ** 0.5)
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_arch_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, KEY)
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    last, cache = M.prefill(cfg, params, batch, max_len=40)
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        lg, cache = M.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "qwen2-1.5b"])
def test_decode_consistency_with_forward(arch):
    """Teacher-forced decode must reproduce the parallel forward logits.

    fp32 compute: this asserts *path* equivalence (prefill+decode vs the
    parallel forward), not bf16 rounding behaviour."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = M.init_params(cfg, KEY)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    full = M.forward(cfg, params, {"tokens": toks})

    pre = 8
    last, cache = M.prefill(cfg, params, {"tokens": toks[:, :pre]},
                            max_len=S)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full[:, pre - 1], np.float32),
                               rtol=2e-2, atol=2e-2)
    for t in range(pre, S):
        lg, cache = M.decode_step(cfg, params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full[:, t], np.float32), rtol=2e-2, atol=2e-2)


def test_blockwise_attention_vs_naive():
    B, S, H, hd = 2, 64, 4, 16
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, H, hd))
    v = jax.random.normal(k3, (B, S, H, hd))
    got = L.blockwise_attention(q, k, v, causal=True, q_offset=0, block=16)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_blockwise_attention_sliding_window():
    B, S, H, hd, W = 1, 64, 2, 8, 16
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    got = L.blockwise_attention(q, k, v, causal=True, q_offset=0,
                                sliding_window=W, block=16)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = (qi >= kj) & (qi - kj < W)
    s = jnp.where(mask[None, None], s, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gqa_repeat_equivalence():
    """GQA with K<H equals full MHA with repeated KV heads."""
    B, S, H, K, hd = 1, 32, 4, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    got = L.blockwise_attention(q, k, v, causal=True, q_offset=0, block=8)
    want = L.blockwise_attention(q, jnp.repeat(k, 2, 2),
                                 jnp.repeat(v, 2, 2), causal=True,
                                 q_offset=0, block=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_ssd_chunked_vs_sequential():
    from repro.models.ssm import _ssd_chunked, ssd_reference
    b, Lseq, H, P, N = 2, 32, 3, 4, 8
    rng = jax.random.split(KEY, 4)
    x = jax.random.normal(rng[0], (b, Lseq, H, P))
    dt = jax.nn.softplus(jax.random.normal(rng[1], (b, Lseq, H)))
    A = -jnp.exp(jax.random.normal(rng[2], (H,)) * 0.3)
    B_ = jax.random.normal(rng[3], (b, Lseq, N))
    C_ = jax.random.normal(rng[0], (b, Lseq, N))
    D = jnp.ones((H,))
    got, _ = _ssd_chunked(x, dt, A, B_, C_, D, chunk=8)
    want = ssd_reference(x, dt, A, B_, C_, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rglru_scan_vs_sequential():
    from repro.models.rglru import init_rglru, rglru_reference, _rglru_core
    p = init_rglru(KEY, 16, 24)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 20, 24))
    got, h_last = _rglru_core(x, p)
    want = rglru_reference(x, p)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last),
                               np.asarray(want[:, -1]), rtol=1e-4, atol=1e-4)


def test_param_count_sane():
    # Full configs match their nameplate sizes (±20% — vocab/rounding).
    expect = {"olmo-1b": 1.3e9, "llama3_2-3b": 3.4e9, "qwen2-1_5b": 1.6e9,
              "gemma-2b": 2.6e9, "mamba2-1_3b": 1.3e9, "dbrx-132b": 132e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_shape_skip_rules():
    assert skip_reason(get_config("olmo-1b"), "long_500k")
    assert not skip_reason(get_config("mamba2-1.3b"), "long_500k")
    assert skip_reason(get_config("hubert-xlarge"), "decode_32k")
    assert len(cells(get_config("hubert-xlarge"))) == 2
    total = sum(len(cells(get_config(a))) for a in ARCHS)
    assert total == 31  # 40 assigned minus 9 mandated skips (DESIGN.md §4)
