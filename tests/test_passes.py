"""Pass-pipeline compiler tests: spec round-trips, back-compat shims,
source-aligned skew tiling, critical-rank-first, and the bounded SSC cache."""

import numpy as np
import pytest

from repro.core import executor as ex
from repro.core.odg import (ODG, OperatorNode, ScheduleConfig, SplitSpec,
                            VECTOR, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.passes import (Pipeline, PassSpec, pipeline_from_flags,
                               registered_passes, resolve_pipeline)
from repro.core.routing import (RoutingPlan, hotspot_plan, random_plan,
                                skewed_plan)
from repro.core.scheduler import (ScheduleError, compile_schedule,
                                  execution_order, validate_schedule)
from repro.core.simulator import simulate_unified
from repro.core.ssc import SSCCache, schedule_to_ssc, ssc_to_schedule

CFG = ScheduleConfig(ep=4, e_loc=2, rows=8, d_model=32, d_ff=16)

BUILDERS = {"forward": build_moe_ffn_forward,
            "backward": build_moe_ffn_backward}


# ---------------------------------------------------------------------------
# Pipeline spec plumbing + legacy-flag equivalence.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("direction", ["forward", "backward"])
@pytest.mark.parametrize("flags,names", [
    ({"ratr": True}, ["ratr"]),
    ({"gmm_interleave": True}, ["gmm_interleave"]),
    ({"chain_interleave": True}, ["chain_interleave"]),
    ({"ratr": True, "gmm_interleave": True}, ["ratr", "gmm_interleave"]),
    ({"ratr": True, "gmm_interleave": True, "chain_interleave": True},
     ["ratr", "gmm_interleave", "chain_interleave"]),
])
def test_flags_compile_byte_identical_to_pipeline(direction, flags, names):
    builder = BUILDERS[direction]
    blob_flags = schedule_to_ssc(compile_schedule(builder(CFG), **flags))
    blob_pipe = schedule_to_ssc(compile_schedule(builder(CFG),
                                                 pipeline=names))
    assert blob_flags == blob_pipe


def test_pipeline_and_flags_mutually_exclusive():
    with pytest.raises(ValueError, match="not both"):
        compile_schedule(build_moe_ffn_forward(CFG),
                         pipeline=["ratr"], ratr=True)


def test_unknown_pass_rejected():
    with pytest.raises(KeyError, match="unknown schedule pass"):
        Pipeline.of("definitely_not_a_pass")


def test_builtin_passes_registered():
    assert set(registered_passes()) >= {"ratr", "gmm_interleave",
                                        "chain_interleave",
                                        "critical_rank_first"}


def test_ssc_roundtrip_preserves_pipeline_and_queues():
    pipe = Pipeline.of("ratr", ["critical_rank_first", {"threshold": 1.5}])
    s = compile_schedule(build_moe_ffn_forward(CFG), pipeline=pipe)
    s2 = ssc_to_schedule(schedule_to_ssc(s))
    assert Pipeline.from_spec(s2.opts["pipeline"]) == pipe
    assert s2.queues == s.queues
    for a, b in zip(s.tasks, s2.tasks):
        assert a.inputs == b.inputs and a.outputs == b.outputs
        assert a.dependent_event == b.dependent_event
        assert a.trigger_event == b.trigger_event


def test_pass_params_travel_through_spec():
    spec = PassSpec.of("chain_interleave", lag=7)
    assert spec.spec() == ["chain_interleave", {"lag": 7}]
    pipe = Pipeline.from_spec([spec.spec()])
    assert pipe.passes[0] == spec
    assert pipe.key() == (("chain_interleave", (("lag", 7),)),)


def test_resolve_pipeline_normalizes():
    assert resolve_pipeline(ratr=True) == Pipeline.of("ratr")
    assert resolve_pipeline(["ratr"]) == pipeline_from_flags(ratr=True)
    assert not resolve_pipeline()            # empty pipeline is falsy


# ---------------------------------------------------------------------------
# Source-aligned sub-splitting (skew-aware tiling).
# ---------------------------------------------------------------------------

def _nonuniform_plan():
    # Per-source-varying cells: even chunk boundaries straddle cells.
    return hotspot_plan(4, 2, 8, background=2)


def test_even_split_rejects_nonuniform_plan():
    plan = _nonuniform_plan()
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=32, d_ff=16,
                         gmm_m_split=4, plan=plan)
    with pytest.raises(ScheduleError, match="single-trigger"):
        compile_schedule(build_moe_ffn_forward(cfg))


@pytest.mark.parametrize("direction", ["forward", "backward"])
@pytest.mark.parametrize("m_split", [2, 4, 16])
def test_source_aligned_compiles_nonuniform_plan(direction, m_split):
    plan = _nonuniform_plan()
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=32, d_ff=16,
                         gmm_m_split=m_split,
                         gmm_split_mode="source_aligned", plan=plan)
    s = compile_schedule(BUILDERS[direction](cfg),
                         pipeline=["ratr", "critical_rank_first"])
    validate_schedule(s)
    order = execution_order(s)
    assert sorted(order) == list(range(s.n_tasks))


def test_source_aligned_tiles_cover_and_respect_cells():
    plan = _nonuniform_plan()
    for rank in range(plan.ep):
        for m_split in (1, 2, 3, 4, 7, 64):
            tiles = plan.gmm_tiles(rank, m_split, "source_aligned")
            for e in range(plan.e_loc):
                rows = plan.expert_rows(rank, e)
                mine = [(lo, hi) for (te, m, lo, hi) in tiles if te == e]
                if rows == 0:
                    assert not mine
                    continue
                # Exact cover of the expert block, in order, no overlap.
                base = plan.expert_offset(rank, e)
                assert mine[0][0] == base and mine[-1][1] == base + rows
                for (a, b) in zip(mine, mine[1:]):
                    assert a[1] == b[0]
                assert len(mine) <= max(1, m_split)
                # Each tile is a union of whole cells or inside one cell.
                edges = [plan.recv_offset(rank, e, s) for s in range(plan.ep)
                         if plan.count(s, rank, e) > 0]
                edges.append(base + rows)
                for lo, hi in mine:
                    inside = [c for c in edges if lo < c < hi]
                    if inside:       # spans cell edges → must sit on edges
                        assert lo in edges and hi in edges


def test_source_aligned_reduces_to_grouping_for_small_budget():
    """m_split ≤ cell count: boundaries only on cell edges (pure grouping)."""
    plan = _nonuniform_plan()
    rank = 0
    cell_edges = {plan.recv_offset(rank, e, s)
                  for e in range(plan.e_loc) for s in range(plan.ep)
                  if plan.count(s, rank, e) > 0}
    cell_edges |= {plan.expert_offset(rank, e) + plan.expert_rows(rank, e)
                   for e in range(plan.e_loc)}
    for (e, m, lo, hi) in plan.gmm_tiles(rank, 3, "source_aligned"):
        assert lo in cell_edges and hi in cell_edges


@pytest.mark.parametrize("m_split", [3, 16])
def test_source_aligned_executor_matches_reference(m_split):
    plan = _nonuniform_plan()
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=8, d_ff=4,
                         gmm_m_split=m_split,
                         gmm_split_mode="source_aligned", plan=plan)
    s = compile_schedule(build_moe_ffn_forward(cfg),
                         pipeline=["ratr", "critical_rank_first"])
    x_src, w1, w2 = ex.make_inputs_plan(cfg, 3)
    st = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
    ex.execute(s, st, rng=np.random.default_rng(m_split))
    ref = ex.reference_forward_plan(cfg, x_src, w1, w2)
    for r in range(cfg.ep):
        if plan.send_rows(r):
            np.testing.assert_allclose(st.get("y_ret", r), ref["y_ret"][r],
                                       rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Critical-rank-first.
# ---------------------------------------------------------------------------

def test_critical_rank_first_reduces_hotspot_makespan():
    plan = hotspot_plan(8, 8, 128)
    cfg = ScheduleConfig(ep=8, e_loc=8, rows=0, d_model=2048, d_ff=512,
                         gmm_m_split=64, gmm_split_mode="source_aligned",
                         plan=plan)
    base = simulate_unified(compile_schedule(build_moe_ffn_forward(cfg),
                                             pipeline=["ratr"]))
    crit = simulate_unified(compile_schedule(
        build_moe_ffn_forward(cfg),
        pipeline=["ratr", "critical_rank_first"]))
    assert crit.makespan_us < base.makespan_us * 0.99


def test_critical_rank_first_noop_on_balanced_plan():
    s1 = compile_schedule(build_moe_ffn_forward(CFG), pipeline=["ratr"])
    s2 = compile_schedule(build_moe_ffn_forward(CFG),
                          pipeline=["ratr", "critical_rank_first"])
    assert s1.queues == s2.queues


def test_critical_rank_first_hoists_feeding_comm():
    plan = skewed_plan(4, 2, 8, 2.0)       # rank 0 heaviest
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=64, d_ff=32,
                         plan=plan)
    s = compile_schedule(build_moe_ffn_forward(cfg),
                         pipeline=["ratr", "critical_rank_first"])
    from repro.core.costmodel import CostModel
    _, crit = CostModel(l2=False).critical_rank(s)
    for r in range(cfg.ep):
        dsts = [s.tasks[t].dst_rank for t in s.queue(r, "VTQ")
                if s.tasks[t].op_name.startswith("Dispatch")
                and s.tasks[t].dst_rank >= 0]
        to_crit = [i for i, d in enumerate(dsts) if d == crit]
        # All critical-destined sends precede every other destination.
        assert to_crit == list(range(len(to_crit)))


# ---------------------------------------------------------------------------
# Every registered pass keeps arbitrary skewed schedules legal.
# ---------------------------------------------------------------------------

def _plan_grid():
    rng = np.random.default_rng(7)
    return [skewed_plan(3, 2, 6, 1.5),
            random_plan(3, 2, 7, rng, p_zero=0.5),
            hotspot_plan(3, 2, 4),
            hotspot_plan(3, 2, 8, background=2)]


@pytest.mark.parametrize("direction", ["forward", "backward"])
def test_registered_passes_keep_schedules_valid(direction):
    for plan in _plan_grid():
        cfg = ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                             d_model=16, d_ff=8, gmm_m_split=4,
                             gmm_split_mode="source_aligned", plan=plan)
        for name in registered_passes():
            s = compile_schedule(BUILDERS[direction](cfg), pipeline=[name])
            validate_schedule(s)
        s = compile_schedule(BUILDERS[direction](cfg),
                             pipeline=list(registered_passes()))
        validate_schedule(s)
        assert sorted(execution_order(s)) == list(range(s.n_tasks))


# ---------------------------------------------------------------------------
# Bounded SSC cache.
# ---------------------------------------------------------------------------

def test_ssc_cache_flags_and_pipeline_share_entry():
    cache = SSCCache()
    cache.get_or_compile(CFG, "forward", ratr=True)
    cache.get_or_compile(CFG, "forward", pipeline=["ratr"])
    cache.get_or_compile(CFG, "forward", pipeline=Pipeline.of("ratr"))
    assert cache.misses == 1 and cache.hits == 2


def test_ssc_cache_lru_eviction_and_info():
    cache = SSCCache(max_entries=2)
    cfgs = [ScheduleConfig(ep=2, e_loc=1, rows=r, d_model=8, d_ff=4)
            for r in (1, 2, 3)]
    cache.get_or_compile(cfgs[0], "forward")
    cache.get_or_compile(cfgs[1], "forward")
    cache.get_or_compile(cfgs[0], "forward")     # refresh 0 → 1 is LRU
    cache.get_or_compile(cfgs[2], "forward")     # evicts 1
    assert cache.evictions == 1
    cache.get_or_compile(cfgs[0], "forward")     # still cached
    assert cache.hits == 2
    cache.get_or_compile(cfgs[1], "forward")     # recompiles
    assert cache.misses == 4
    info = cache.info()
    assert info["entries"] == 2 and info["max_entries"] == 2
    assert info["evictions"] == 2 and info["bytes"] > 0


def test_ssc_cache_key_includes_split_mode():
    plan = _nonuniform_plan()
    cfg_sa = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=32, d_ff=16,
                            gmm_m_split=2,
                            gmm_split_mode="source_aligned", plan=plan)
    cfg_even = ScheduleConfig(ep=4, e_loc=2, rows=0, d_model=32, d_ff=16,
                              gmm_m_split=2, plan=plan)
    assert SSCCache.key(cfg_sa, "forward") != SSCCache.key(cfg_even,
                                                           "forward")


# ---------------------------------------------------------------------------
# Simulator rank-cap regression (satellite).
# ---------------------------------------------------------------------------

def test_simulator_serialized_dispatch_beyond_rank_1024():
    """The per-rank scheduler clock must not cap the rank id space."""
    cfg = ScheduleConfig(ep=1, e_loc=1, rows=16, d_model=8, d_ff=4)
    g = ODG(cfg, "forward")
    h = g.tensor("h@1500", 16, 32, external=True)
    y = g.tensor("y@1500", 16, 32)
    g.add_op(OperatorNode(
        name="SwiGLU@1500", op_type="swiglu", resource=VECTOR, rank=1500,
        inputs=[h], outputs=[y],
        split_spec=SplitSpec(split_inputs=None, split_output_dims=(0,),
                             task_num_fn=lambda c, op: 4)))
    s = compile_schedule(g)
    res = simulate_unified(s, serialize_dispatch=True)
    assert res.makespan_us > 0
