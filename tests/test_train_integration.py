"""End-to-end training integration: loss decreases, FT restart, simulator."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.hardware import AscendA3
from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.ft.runner import FTConfig, train_loop
from repro.models import model as M
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _setup(cfg):
    params = adamw.cast_params(M.init_params(cfg, KEY), cfg.compute_dtype)
    opt_state = adamw.init_opt_state(params)
    oc = adamw.OptConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                         weight_decay=0.0)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        p2, s2, m = adamw.apply_updates(params, grads, opt_state, oc)
        m["loss"] = loss
        return p2, s2, m

    return params, opt_state, step


class _Stream:
    def __init__(self, dc):
        self.s = SyntheticStream(dc)

    def sharded_batch(self, step, mesh, sharding):
        b = self.s.global_batch_np(step)
        return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases():
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"), n_layers=2)
    params, opt_state, step = _setup(cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    stream = SyntheticStream(dc)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v)
                 for k, v in stream.global_batch_np(i % 4).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_ft_checkpoint_restart_determinism(tmp_path):
    """Crash mid-run → resume gives the same final state as uninterrupted."""
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), n_layers=1)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    stream = _Stream(dc)
    ft_a = FTConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5)
    ft_b = FTConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5)

    # uninterrupted run
    params, opt_state, step = _setup(cfg)
    run_a = train_loop(step_fn=step, params=params, opt_state=opt_state,
                       stream=stream, mesh=None, batch_sharding=None,
                       n_steps=12, ft=ft_a)

    # crashing run: dies at step 8, then resumes from the step-5 checkpoint
    params, opt_state, step = _setup(cfg)

    def bomb(s):
        if s == 8 and not os.environ.get("_RESUMED"):
            os.environ["_RESUMED"] = "1"
            raise RuntimeError("injected node failure")

    with pytest.raises(RuntimeError, match="injected"):
        train_loop(step_fn=step, params=params, opt_state=opt_state,
                   stream=stream, mesh=None, batch_sharding=None,
                   n_steps=12, ft=ft_b, inject_fault=bomb)
    params2, opt_state2, step2 = _setup(cfg)
    run_b = train_loop(step_fn=step2, params=params2, opt_state=opt_state2,
                       stream=stream, mesh=None, batch_sharding=None,
                       n_steps=12, ft=ft_b)
    os.environ.pop("_RESUMED", None)
    assert run_b.resumed_from == 5
    for a, b in zip(jax.tree.leaves(run_a.params),
                    jax.tree.leaves(run_b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_elastic_restore_structure(tmp_path):
    """Checkpoints restore into a differently-jitted context (logical)."""
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"), n_layers=1)
    params = M.init_params(cfg, KEY)
    from repro.checkpoint import ckpt as CK
    CK.save(str(tmp_path), 1, params)
    restored, _ = CK.restore(CK.latest_step_dir(str(tmp_path)), params)
    assert jax.tree_util.tree_structure(restored) == \
        jax.tree_util.tree_structure(params)


def test_simulator_unified_beats_baseline():
    cfg = ScheduleConfig(ep=8, e_loc=8, rows=1024, d_model=7168, d_ff=1024,
                         gmm_m_split=1)
    s_base = compile_schedule(build_moe_ffn_forward(cfg))
    cfg_opt = ScheduleConfig(ep=8, e_loc=8, rows=1024, d_model=7168,
                             d_ff=1024, gmm_m_split=32)
    s_opt = compile_schedule(build_moe_ffn_forward(cfg_opt), ratr=True)
    hw = AscendA3()
    b = simulate_baseline(s_base, hw)
    u = simulate_unified(s_opt, hw)
    assert u.makespan_us < b.makespan_us
    assert u.mac_ratio > b.mac_ratio
    assert u.exposed_comm_us < b.exposed_comm_us


def test_simulator_ratr_helps_ingress_balance():
    cfg = ScheduleConfig(ep=8, e_loc=8, rows=1024, d_model=7168, d_ff=1024,
                         gmm_m_split=8)
    hw = AscendA3()
    naive = simulate_unified(
        compile_schedule(build_moe_ffn_forward(cfg)), hw)
    ratr = simulate_unified(
        compile_schedule(build_moe_ffn_forward(cfg), ratr=True), hw)
    assert ratr.makespan_us <= naive.makespan_us * 1.02
