"""Restart semantics under fault injection.

Three contracts of the FT driver, each exercised the hard way:

* **bounded loss of work** — a crash at *every* step ``k`` resumes from
  the newest checkpoint at or below ``k`` and loses at most
  ``ckpt_every - 1`` steps; the resumed trajectory is bit-identical to a
  never-failed run (counter-based data order + deterministic step).
* **history spans the crash** — ``metrics_log``/``stragglers`` ride the
  checkpoint manifest, so a resumed run's merged log contains the
  pre-crash entries instead of silently restarting from empty.
* **checkpoint atomicity** — ``ckpt.save`` SIGKILLed between *any* two
  file operations (via the ``set_file_fault_hook`` seam) never leaves a
  state ``latest_step_dir`` would resolve to a partial checkpoint.
"""

import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ft.runner import FTConfig, train_loop

CKPT_EVERY = 3
N_STEPS = 7


class _Stream:
    def sharded_batch(self, step, mesh, sharding):
        return jnp.float32(step + 1)


def _fake_step(params, opt_state, batch):
    w = params["w"] - 0.01 * batch
    return ({"w": w}, opt_state,
            {"loss": jnp.sum(w * w), "grad_norm": jnp.float32(0.1)})


def _bomb_at(k):
    armed = {"on": True}

    def inject(step):
        if armed["on"] and step == k:
            armed["on"] = False
            raise RuntimeError(f"injected fault at step {k}")
    return inject


def _run(tmp, n_steps=N_STEPS, inject=None, step_fn=_fake_step, **ftkw):
    ft = FTConfig(ckpt_dir=str(tmp), ckpt_every=CKPT_EVERY, **ftkw)
    return train_loop(step_fn=step_fn, params={"w": jnp.float32(1.0)},
                      opt_state=None, stream=_Stream(), mesh=None,
                      batch_sharding=None, n_steps=n_steps, ft=ft,
                      inject_fault=inject, log_every=1)


@pytest.mark.parametrize("k", range(1, N_STEPS))
def test_kill_at_every_step_bounded_loss_and_bit_identity(k, tmp_path):
    baseline = _run(tmp_path / "base")

    with pytest.raises(RuntimeError, match="injected"):
        _run(tmp_path / "ft", inject=_bomb_at(k))
    resumed = _run(tmp_path / "ft")

    # Recovery point: newest checkpoint at or below the fault step —
    # never more than ckpt_every - 1 steps of work lost.
    expect = (k // CKPT_EVERY) * CKPT_EVERY if k >= CKPT_EVERY else None
    assert resumed.resumed_from == expect
    assert k - (expect or 0) <= CKPT_EVERY - 1
    assert resumed.step == N_STEPS

    # The merged log spans the crash and is bit-identical to never-failed.
    assert [m["step"] for m in resumed.metrics_log] == \
        [m["step"] for m in baseline.metrics_log] == list(range(1, N_STEPS + 1))
    for a, b in zip(resumed.metrics_log, baseline.metrics_log):
        assert a["loss"] == b["loss"] and a["grad_norm"] == b["grad_norm"]
    assert np.array_equal(np.asarray(resumed.params["w"]),
                          np.asarray(baseline.params["w"]))


def test_straggler_log_survives_crash(tmp_path):
    # A 0.4 s stall at step 1 (pre-crash, pre-checkpoint) must still be in
    # the resumed run's straggler log: it rides the step-3 manifest.
    def slow_step(params, opt_state, batch):
        if float(batch) == 2.0:           # step 1's batch
            time.sleep(0.4)
        return _fake_step(params, opt_state, batch)

    with pytest.raises(RuntimeError, match="injected"):
        _run(tmp_path, inject=_bomb_at(5), step_fn=slow_step,
             straggler_factor=1.5)
    resumed = _run(tmp_path, step_fn=slow_step, straggler_factor=1.5)
    assert resumed.resumed_from == 3
    assert any(s[0] == 1 for s in resumed.stragglers), resumed.stragglers
    # And the in-manifest history matches what the run reports.
    assert [m["step"] for m in resumed.metrics_log] == list(range(1, 8))


_ATOMICITY_CHILD = r"""
import os, shutil, signal, sys

sys.path.insert(0, sys.argv[2])
import numpy as np
from repro.checkpoint import ckpt as CK

d = sys.argv[1]
tree = {"w": np.arange(8, dtype=np.float32)}
CK.save(d, 1, tree)
base = CK.latest_step_dir(d)
assert base.endswith("step_00000001"), base

N = 0
while True:
    N += 1
    assert N < 20, "fault hook never let save() finish"
    pid = os.fork()
    if pid == 0:
        # Grandchild: SIGKILL ourselves immediately before file op N.
        count = {"n": 0}
        def hook(op):
            count["n"] += 1
            if count["n"] == N:
                os.kill(os.getpid(), signal.SIGKILL)
        CK.set_file_fault_hook(hook)
        CK.save(d, 2, {"w": np.arange(8, dtype=np.float32) * 2})
        os._exit(0)
    _, status = os.waitpid(pid, 0)
    resolved = CK.latest_step_dir(d)
    # The resolved checkpoint is never partial: sentinel present and a
    # CRC-verified restore succeeds, no matter where the writer died.
    assert resolved is not None, N
    assert os.path.exists(os.path.join(resolved, "_COMPLETE")), (N, resolved)
    CK.restore(resolved, tree)
    if os.WIFEXITED(status) and os.WEXITSTATUS(status) == 0:
        # save() ran to completion: every kill point was exercised.
        assert resolved.endswith("step_00000002"), resolved
        break
    assert resolved == base, (N, resolved)
    for name in os.listdir(d):     # reset partial state for the next N
        if name.startswith("step_00000002"):
            shutil.rmtree(os.path.join(d, name))
print("OK", N)
"""


def test_checkpoint_atomicity_under_sigkill(tmp_path):
    """SIGKILL the checkpoint writer before every file op in turn;
    ``latest_step_dir`` must never resolve to a partial checkpoint."""
    script = tmp_path / "atomicity_child.py"
    script.write_text(_ATOMICITY_CHILD)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    r = subprocess.run(
        [sys.executable, str(script), str(tmp_path / "ck"), src],
        capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout, r.stdout


def test_e2e_kill_scenario(tmp_path):
    import ftharness
    checks = ftharness.run_kill("uniform", str(tmp_path))
    assert all(checks.values()), checks
