"""Deterministic fault-injection harness for the elastic FT stack.

Drives a tiny dropless MoE training loop (CPU, seconds) through the three
failure modes production clusters actually see, and reports machine-checkable
invariants for each:

* **kill** — the run dies at step *k* and resumes from the newest complete
  checkpoint: recovery loses at most ``ckpt_every - 1`` steps,
  ``resumed_from`` is exact, and because data order is counter-based the
  merged post-resume loss trajectory is *bit-identical* to a never-failed
  run (the manifest-persisted ``metrics_log`` spans the crash).
* **slow** — one rank reports 3× step times; the per-rank EWMA the loop
  accumulates feeds ``CostModel(rank_bias=)``: the slow rank becomes the
  compile-time critical rank and ``autoselect`` picks a pipeline containing
  ``critical_rank_first``.
* **rescale** — the run dies, then resumes on a mesh shrunk by one rank:
  persisted live plans come back remapped (``core.elastic.remap_plan``)
  cell-identical to plans built natively on the small mesh, the shared
  ``SSCCache`` shows re-keyed (never evicted) entries, and the rescaled
  dropless impl's outputs are bit-identical to a fresh native small-mesh
  impl's.

Every scenario runs under two routing profiles: ``uniform`` (the raw
router) and ``hotspot`` (router biased so expert 0 dominates — the
concentrated profile where remap invariants are easiest to get wrong).

CLI (the CI ``chaos`` job):

    PYTHONPATH=src python tests/ftharness.py \\
        --kinds kill,slow,rescale --profiles uniform,hotspot

One JSON line per (kind, profile) cell; exit 1 if any check fails.
``tests/test_elastic.py`` and ``tests/test_ft_restart.py`` drive the same
scenario functions as pytest cases.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir, "src")))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro.core import autoselect                           # noqa: E402
from repro.core.elastic import (check_remap, remap_plan,    # noqa: E402
                                surviving_ranks)
from repro.core.odg import ScheduleConfig                   # noqa: E402
from repro.core.routing import balanced_plan                # noqa: E402
from repro.core.ssc import SSCCache                         # noqa: E402
from repro.ft.runner import (ElasticContext, FTConfig,      # noqa: E402
                             train_loop)
from repro.launch.dropless import (DroplessConfig,          # noqa: E402
                                   DroplessMoE)
from repro.models.moe import (MoEConfig, init_moe,          # noqa: E402
                              plan_from_routing, router_topk)

# Fixture scale: e_total = 6 divides both the 3-rank mesh (e_loc = 2) and
# the post-loss 2-rank mesh (e_loc = 3), so a one-rank shrink is legal.
D_MODEL = 8
T_LOC = 8
EP = 3
MC = MoEConfig(n_experts=6, top_k=2, d_expert=4)

PROFILES = ("uniform", "hotspot")
KINDS = ("kill", "slow", "rescale")


def make_params(profile: str, seed: int = 0) -> dict:
    params = dict(init_moe(jax.random.PRNGKey(seed), D_MODEL, MC))
    if profile == "hotspot":
        # Bias the router so expert 0 wins every token's top-1 slot — the
        # concentrated (rank 0, expert 0) profile.
        params["router"] = params["router"].at[:, 0].add(4.0)
    elif profile != "uniform":
        raise ValueError(f"unknown profile {profile!r}; choices: {PROFILES}")
    return params


def rank_shard(rank: int, step: int) -> np.ndarray:
    """Rank ``rank``'s tokens for ``step`` — a pure function of (rank,
    step), so a surviving rank's data is unchanged by who else is alive."""
    rng = np.random.default_rng([1234, rank, step])
    return rng.standard_normal((T_LOC, D_MODEL)).astype(np.float32)


class ShardStream:
    """Counter-based stream that concatenates the live ranks' shards."""

    def __init__(self, ranks):
        self.ranks = tuple(int(r) for r in ranks)

    def sharded_batch(self, step, mesh, sharding):
        x = np.concatenate([rank_shard(r, step) for r in self.ranks])
        return {"x": jnp.asarray(x)}


def make_dm(ep: int = EP, cache: SSCCache = None) -> DroplessMoE:
    return DroplessMoE(DroplessConfig(ep=ep, bucket_rows=4),
                       cache=cache if cache is not None else SSCCache(64))


def make_step(dm: DroplessMoE, slow_rank: int = None,
              slow_factor: float = 1.0, lr: float = 0.05):
    """SGD step through the dropless impl — bitwise deterministic, with a
    fabricated per-rank timing vector (the watchdog input a real cluster
    measures; fabrication keeps the slow-rank scenario deterministic)."""

    def step(params, opt_state, batch):
        x = batch["x"][None]                         # [1, T, d]

        def loss_fn(p):
            y = dm.impl(p, x, MC)
            return jnp.mean(y * y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
        rank_t = np.full(dm.dc.ep, 100.0)
        if slow_rank is not None and 0 <= slow_rank < dm.dc.ep:
            rank_t[slow_rank] *= slow_factor
        return params2, opt_state, {"loss": loss, "grad_norm": gn,
                                    "rank_time_us": rank_t}

    return step


def _loop(dm, params, stream, ckpt_dir, n_steps, *, ckpt_every=3,
          inject_fault=None, elastic=None, slow_rank=None, slow_factor=1.0):
    return train_loop(
        step_fn=make_step(dm, slow_rank=slow_rank, slow_factor=slow_factor),
        params=params, opt_state=None, stream=stream, mesh=None,
        batch_sharding=None, n_steps=n_steps,
        ft=FTConfig(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every),
        inject_fault=inject_fault, log_every=1, elastic=elastic)


def _bomb_at(k: int):
    armed = {"on": True}

    def bomb(step):
        if step == k and armed["on"]:
            armed["on"] = False
            raise RuntimeError(f"injected kill at step {k}")

    return bomb


def _trajectory(run) -> list:
    return [(m["step"], m["loss"], m["grad_norm"]) for m in run.metrics_log]


# ---------------------------------------------------------------------------
# Scenarios. Each returns {check_name: bool-ish}; all truthy = pass.
# ---------------------------------------------------------------------------

def run_kill(profile: str, tmp: str, k: int = 4, ckpt_every: int = 3,
             n_steps: int = 6) -> dict:
    """Kill at step ``k``, resume, compare against a never-failed twin."""
    stream = ShardStream(range(EP))
    base = _loop(make_dm(), make_params(profile), stream,
                 os.path.join(tmp, "base"), n_steps, ckpt_every=ckpt_every)

    crash_dir = os.path.join(tmp, "crash")
    try:
        _loop(make_dm(), make_params(profile), stream, crash_dir, n_steps,
              ckpt_every=ckpt_every, inject_fault=_bomb_at(k))
        crashed = False
    except RuntimeError:
        crashed = True
    run = _loop(make_dm(), make_params(profile), stream, crash_dir, n_steps,
                ckpt_every=ckpt_every)

    expect_resume = (k // ckpt_every) * ckpt_every if k >= ckpt_every \
        else None
    lost = k - (expect_resume or 0)
    return {
        "crashed": crashed,
        "resumed_from_correct": run.resumed_from == expect_resume,
        "bounded_loss_of_work": 0 <= lost <= ckpt_every - 1,
        "log_spans_crash": [m["step"] for m in run.metrics_log]
        == list(range(1, n_steps + 1)),
        "trajectory_bit_identical": _trajectory(run) == _trajectory(base),
        "params_bit_identical": all(
            np.array_equal(a, b) for a, b in
            zip(jax.tree.leaves(base.params), jax.tree.leaves(run.params))),
    }


def run_slow(profile: str, tmp: str, slow_rank: int = 2,
             factor: float = 3.0, n_steps: int = 4) -> dict:
    """A 3× slow rank becomes the compile-time critical rank."""
    run = _loop(make_dm(), make_params(profile), ShardStream(range(EP)),
                os.path.join(tmp, "slow"), n_steps, ckpt_every=10,
                slow_rank=slow_rank, slow_factor=factor)
    cm = run.cost_model()
    plan = balanced_plan(EP, MC.e_total // EP, T_LOC)
    cfg = ScheduleConfig(ep=EP, e_loc=MC.e_total // EP, rows=T_LOC,
                         d_model=D_MODEL, d_ff=MC.d_expert, plan=plan)
    ratio, crit = cm.critical_rank(
        autoselect.cube_taskset(plan, cfg, "forward"))
    choice = autoselect.select(plan, cfg, cm)
    names = [n for n, _ in choice.pipeline.key()]
    return {
        "bias_recorded": cm.rank_bias is not None
        and len(cm.rank_bias) == EP,
        "slow_rank_max_bias": cm.rank_bias is not None
        and max(range(EP), key=lambda r: cm.rank_bias[r]) == slow_rank,
        "critical_rank_is_slow_rank": crit == slow_rank,
        "straggler_fires": ratio > 1.05,
        "autoselect_picks_crit": "critical_rank_first" in names,
    }


def run_rescale(profile: str, tmp: str, dead=(2,), k: int = 4,
                ckpt_every: int = 2, n_steps: int = 8) -> dict:
    """Kill mid-run, resume on a mesh shrunk by one rank."""
    cache = SSCCache(64)
    dm = make_dm(EP, cache)
    params = make_params(profile)

    # The live plan the big-mesh run registers (step-0 routing).
    x0 = np.concatenate([rank_shard(r, 0) for r in range(EP)])
    ti0 = np.asarray(router_topk(params["router"], x0, MC)[1])
    ti0 = ti0.reshape(EP, T_LOC, MC.top_k)
    live_plan = plan_from_routing(ti0, MC, EP, capacity=None).plan

    ckpt_dir = os.path.join(tmp, "rescale")
    try:
        _loop(dm, params, ShardStream(range(EP)), ckpt_dir, n_steps,
              ckpt_every=ckpt_every, inject_fault=_bomb_at(k),
              elastic=ElasticContext(ep=EP, cache=cache,
                                     plans={"step0": live_plan}))
        crashed = False
    except RuntimeError:
        crashed = True

    survivors = surviving_ranks(EP, dead)
    new_ep = len(survivors)
    dm2 = dm.rescale(dead_ranks=dead)            # shares + re-keys the cache
    elastic = ElasticContext(ep=new_ep, cache=cache, dead_ranks=tuple(dead))
    run = _loop(dm2, make_params(profile), ShardStream(survivors), ckpt_dir,
                n_steps, ckpt_every=ckpt_every, elastic=elastic)

    # Remapped plan vs the plan built natively on the small mesh from the
    # survivors' own token→expert assignments.
    remapped = elastic.plans.get("step0")
    native = plan_from_routing(ti0[list(survivors)], MC, new_ep,
                               capacity=None).plan
    # Rescaled impl vs a fresh native small-mesh impl, same inputs: the
    # executor is per-row deterministic, so outputs must be bit-identical.
    x_small = np.concatenate([rank_shard(r, 0) for r in survivors])[None]
    y_rescaled = np.asarray(dm2.impl(run.params, jnp.asarray(x_small), MC))
    y_native = np.asarray(make_dm(new_ep).impl(
        run.params, jnp.asarray(x_small), MC))
    info = cache.info()

    expect_resume = (k // ckpt_every) * ckpt_every if k >= ckpt_every \
        else None
    return {
        "crashed": crashed,
        "resumed_from_correct": run.resumed_from == expect_resume,
        "rescale_event_recorded": len(run.elastic_events) == 1
        and run.elastic_events[0]["from_ep"] == EP
        and run.elastic_events[0]["to_ep"] == new_ep,
        "plan_remapped": remapped is not None
        and remapped.ep == new_ep,
        "remap_matches_native_plan": remapped is not None
        and remapped.counts == native.counts,
        "remap_invariants": remapped is not None
        and check_remap(live_plan, remapped, survivors)["ok"],
        "impl_bit_identical_to_native": np.array_equal(y_rescaled, y_native),
        "cache_rekeyed_not_flushed": info["rekeyed"] >= 1
        and info["active_ep"] == new_ep and info["evictions"] == 0
        and info["by_ep"].get(EP, 0) > 0 and info["by_ep"].get(new_ep, 0) > 0,
        "run_completed": run.step == n_steps,
    }


_SCENARIOS = {"kill": run_kill, "slow": run_slow, "rescale": run_rescale}


def run_scenario(kind: str, profile: str, tmp: str) -> dict:
    return _SCENARIOS[kind](profile, tmp)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kinds", default=",".join(KINDS),
                    help=f"comma-separated scenario kinds ({','.join(KINDS)})")
    ap.add_argument("--profiles", default=",".join(PROFILES),
                    help="comma-separated routing profiles "
                         f"({','.join(PROFILES)})")
    args = ap.parse_args(argv)
    kinds = [k.strip() for k in args.kinds.split(",") if k.strip()]
    profiles = [p.strip() for p in args.profiles.split(",") if p.strip()]
    unknown = [k for k in kinds if k not in _SCENARIOS]
    if unknown:
        ap.error(f"unknown kinds {unknown}; choices: {sorted(_SCENARIOS)}")

    failures = 0
    with tempfile.TemporaryDirectory() as td:
        for kind in kinds:
            for profile in profiles:
                checks = run_scenario(
                    kind, profile, os.path.join(td, f"{kind}_{profile}"))
                ok = all(bool(v) for v in checks.values())
                failures += not ok
                print(json.dumps({"scenario": kind, "profile": profile,
                                  "ok": ok, "checks": checks}))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
