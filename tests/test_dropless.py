"""Dropless data-dependent training step: plan bucketing, SSC cache reuse,
loss parity against the fixed-capacity path, and the ragged EP ring.

The dropless path (``repro.launch.dropless``) compiles a schedule from each
batch's actual router output and trains *through* it (custom-vjp executor
callbacks). These tests pin its three contracts: (1) bucketed plan keys make
jittered routing cache-hit without changing results, (2) ``train_step`` under
``DroplessConfig`` matches the fixed-capacity step bit-for-bit when capacity
drops nothing, (3) the plan-sized EP ring moves/skips exactly the rows the
plan names.
"""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.ssc import SSCCache
from repro.core.odg import ScheduleConfig
from repro.models.moe import (MoEConfig, bucket_counts, init_moe,
                              moe_grouped, plan_from_routing)
from repro.launch.dropless import DroplessConfig, DroplessMoE

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Bucketing semantics.
# ---------------------------------------------------------------------------

def test_bucket_counts_quantizes_up_preserving_zeros():
    c = np.array([[[0, 1], [4, 5]], [[8, 9], [0, 16]]])
    b = bucket_counts(c, 4)
    np.testing.assert_array_equal(
        b, [[[0, 4], [4, 8]], [[8, 12], [0, 16]]])
    np.testing.assert_array_equal(bucket_counts(c, 1), c)


def test_bucketed_plan_rows_cover_exact_plan():
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    rng = np.random.default_rng(0)
    ti = rng.integers(0, 8, size=(64, 2))
    exact = plan_from_routing(ti, mc, 4, capacity=None)
    bucketed = plan_from_routing(ti, mc, 4, capacity=None, bucket_rows=8)
    ce = np.asarray(exact.plan.counts)
    cb = np.asarray(bucketed.plan.counts)
    assert (cb >= ce).all() and ((cb == 0) == (ce == 0)).all()
    assert (bucketed.send_row >= 0).all()          # dropless: nothing dropped
    assert cb.sum() % 8 == 0 or (cb == 0).any()


# ---------------------------------------------------------------------------
# Cache hit/miss under repeated vs jittered routing.
# ---------------------------------------------------------------------------

def _fetch(cache, plan, direction="forward"):
    cfg = ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0, d_model=16,
                         d_ff=8, plan=plan)
    cache.get_or_compile(cfg, direction, pipeline=["ratr"])


def test_cache_hits_repeated_and_bucketed_jitter():
    mc = MoEConfig(n_experts=4, top_k=1, d_expert=8)
    # base: each global expert gets 4 of rank 0's tokens and 4 of rank 1's;
    # jittered: one token moved between experts (counts 3/5 — same bucket-8
    # key as 4/4, different exact key).
    base = np.repeat(np.arange(4), 4)[:, None]
    base = np.concatenate([base, base], axis=0)          # [32, 1], ep=2
    jit_ = base.copy()
    jit_[0, 0] = 1

    exact = SSCCache(max_entries=8)
    for ti in (base, base, jit_):
        _fetch(exact, plan_from_routing(ti, mc, 2, capacity=None).plan)
    assert (exact.hits, exact.misses) == (1, 2)   # repeat hits, jitter misses

    bucketed = SSCCache(max_entries=8)
    for ti in (base, base, jit_):
        _fetch(bucketed, plan_from_routing(ti, mc, 2, capacity=None,
                                           bucket_rows=8).plan)
    assert (bucketed.hits, bucketed.misses) == (2, 1)    # jitter hits too

    stats = bucketed.step_stats()
    assert stats["hits"] == 2 and stats["misses"] == 1
    assert bucketed.step_stats() == {"hits": 0, "misses": 0,
                                     "evictions": 0, "entries": 1,
                                     "pad_ratio": 1.0}


# ---------------------------------------------------------------------------
# Bucketed-key collisions compute correct results for *both* colliding
# routings (padding rows provably inert).
# ---------------------------------------------------------------------------

def test_bucketed_key_collision_correctness():
    mc = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=8.0)
    d = 16
    params = init_moe(KEY, d, mc)
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d), jnp.float32)
    x2 = jax.random.normal(jax.random.PRNGKey(2), (1, 32, d), jnp.float32)
    cache = SSCCache(max_entries=8)
    dm = DroplessMoE(DroplessConfig(ep=2, bucket_rows=64), cache=cache)

    from repro.models.moe import router_topk
    tis = [np.asarray(router_topk(params["router"],
                                  np.asarray(x).reshape(32, d), mc)[1])
           for x in (x1, x2)]
    p1, p2 = [plan_from_routing(ti, mc, 2, capacity=None,
                                bucket_rows=64).plan for ti in tis]
    assert not np.array_equal(*[np.asarray(plan_from_routing(
        ti, mc, 2, capacity=None).plan.counts) for ti in tis])
    assert p1.counts == p2.counts          # distinct routings, one cache key

    y1 = dm.impl(params, x1, mc)
    assert cache.misses == 1 and cache.hits == 0
    y2 = dm.impl(params, x2, mc)
    assert cache.misses == 1 and cache.hits == 1   # collision reused the SSC
    for x, y in ((x1, y1), (x2, y2)):
        want = moe_grouped(params, x, mc, cap=10_000)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# The dropless fragment vs the grouped reference (fwd + grads).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bucket", [1, 8])
def test_dropless_impl_matches_grouped(bucket):
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8, capacity_factor=8.0)
    d = 16
    params = init_moe(KEY, d, mc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    dm = DroplessMoE(DroplessConfig(ep=4, bucket_rows=bucket),
                     cache=SSCCache(max_entries=8))
    want = moe_grouped(params, x, mc, cap=10_000)
    y = jax.jit(lambda p, x: dm.impl(p, x, mc))(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    g1 = jax.grad(lambda p: jnp.sum(dm.impl(p, x, mc) ** 2))(params)
    g2 = jax.grad(lambda p: jnp.sum(
        moe_grouped(p, x, mc, cap=10_000) ** 2))(params)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-3, atol=1e-4, err_msg=k)


# ---------------------------------------------------------------------------
# End-to-end: train_step through compiled schedules == fixed-capacity step.
# ---------------------------------------------------------------------------

def test_train_step_loss_parity_and_cache_reuse():
    from repro.configs import get_smoke_config
    from repro.launch import steps as St
    from repro.launch.mesh import make_test_mesh, mesh_context
    from repro.optim import adamw
    from repro.models import model as M

    cfg = get_smoke_config("granite-moe-3b-a800m")
    cfg = dataclasses.replace(
        cfg, n_layers=1, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mesh = make_test_mesh(data=1, model=1)
    params = M.init_params(cfg, KEY)
    opt_state = adamw.init_opt_state(params)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % 50,
             "labels": jnp.ones((2, 16), jnp.int32)}
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)

    fixed = St.make_steps(cfg, mesh, opt=oc, mode="zero1")
    drop = St.make_steps(cfg, mesh, opt=oc, mode="zero1",
                         dropless=DroplessConfig(ep=2, bucket_rows=4))
    assert drop.dropless is not None and fixed.dropless is None
    with mesh_context(mesh):
        p1, _, m1 = fixed.train_step(params, opt_state, batch)
        p2, o2, m2 = drop.train_step(params, opt_state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-5)
        # first step compiled fwd+bwd; identical routing next step is
        # fully cache-served and says so in its metrics
        assert m2["ssc_misses"] == 2 and m2["ssc_entries"] == 2
        _, _, m3 = drop.train_step(p2, o2, batch)
        assert m3["ssc_misses"] == 0 and m3["ssc_hits"] >= 2


# ---------------------------------------------------------------------------
# Ragged EP ring: plan-sized chunk caps.
# ---------------------------------------------------------------------------

def test_ring_chunk_caps():
    from repro.core.routing import RoutingPlan
    from repro.parallel.ep import ring_chunk_caps
    plan = RoutingPlan.from_counts(
        [[[3, 0], [0, 0], [1, 2]],
         [[0, 1], [2, 0], [0, 0]],
         [[4, 0], [0, 0], [0, 5]]])
    caps = ring_chunk_caps(plan, 3)
    c = np.asarray(plan.counts)
    for k in range(3):
        assert caps[k] == max(c[s, (s + k) % 3].max() for s in range(3))
    # purely rank-local routing → every nonlocal ring step is all-padding
    diag = np.zeros((3, 3, 2), np.int64)
    for s in range(3):
        diag[s, s] = (7, 3)
    assert ring_chunk_caps(RoutingPlan.from_counts(diag), 3) == (7, 0, 0)
    with pytest.raises(ValueError):
        ring_chunk_caps(plan, 4)


_RAGGED_EP_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.parallel.ep import (EPConfig, make_moe_ep, plan_from_dispatch,
                               _pair_capacity, ring_chunk_caps)
from repro.models.moe import MoEConfig, init_moe, moe_dense_ref, router_topk

mesh = make_test_mesh(data=1, model=4)
ep = 4
mc = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=8.0)
params = init_moe(jax.random.PRNGKey(0), 32, mc)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)
ref = moe_dense_ref(params, x, mc, cap=1000)

# replicate per-rank routing host-side (x is sequence-sharded over `model`)
B, S, d = x.shape
t_loc = B * (S // ep)
x_sh = np.transpose(np.asarray(x).reshape(B, ep, S // ep, d),
                    (1, 0, 2, 3)).reshape(ep, t_loc, d)
top_i = np.stack([np.asarray(router_topk(params["router"],
                                         jnp.asarray(x_sh[r]), mc)[1])
                  for r in range(ep)])
C = _pair_capacity(t_loc, mc, ep, 16.0)
plan = plan_from_dispatch(top_i, mc, ep, C)

full = make_moe_ep(mesh, EPConfig(capacity_factor=16.0))
ragged = make_moe_ep(mesh, EPConfig(capacity_factor=16.0), plan=plan)
# bucketed plan: caps only ever round up, so results must be identical
ragged_b = make_moe_ep(mesh, EPConfig(capacity_factor=16.0), plan=plan,
                       bucket="geometric:8")
with jax.set_mesh(mesh):
    y_full = jax.jit(lambda p, x: full(p, x, mc))(params, x)
    y_ragged = jax.jit(lambda p, x: ragged(p, x, mc))(params, x)
    y_ragged_b = jax.jit(lambda p, x: ragged_b(p, x, mc))(params, x)
    g = jax.jit(jax.grad(lambda p, x: jnp.sum(ragged(p, x, mc) ** 2)))(
        params, x)
    g_ref = jax.grad(lambda p, x: jnp.sum(
        moe_dense_ref(p, x, mc, cap=1000) ** 2))(params, x)
np.testing.assert_allclose(np.asarray(y_full), np.asarray(ref),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(y_ragged), np.asarray(y_full),
                           rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(np.asarray(y_ragged_b), np.asarray(y_full),
                           rtol=1e-6, atol=1e-6)
print("RAGGED_BUCKET_OK")
for k in g:
    np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                               rtol=1e-3, atol=1e-3)
print("RAGGED_EP_OK")

# purely rank-local routing: every nonlocal ring step must be skipped
W = np.zeros((32, 8), np.float32)
for gexp in range(8):
    W[gexp, gexp] = 10.0
params_diag = dict(params, router=jnp.asarray(W))
xd = np.zeros((B, S, 32), np.float32)
rng = np.random.default_rng(0)
for s in range(S):
    r = s // (S // ep)
    xd[:, s, 2 * r] = 1.0 + 0.1 * rng.standard_normal(B)
    xd[:, s, 2 * r + 1] = 0.9
    xd[:, s, 8:] = 0.05 * rng.standard_normal((B, 24))
xd = jnp.asarray(xd)
xd_sh = np.transpose(np.asarray(xd).reshape(B, ep, S // ep, 32),
                     (1, 0, 2, 3)).reshape(ep, t_loc, 32)
top_i_d = np.stack([np.asarray(router_topk(params_diag["router"],
                                           jnp.asarray(xd_sh[r]), mc)[1])
                    for r in range(ep)])
plan_d = plan_from_dispatch(top_i_d, mc, ep, C)
assert ring_chunk_caps(plan_d, ep)[1:] == (0,) * (ep - 1)
ragged_d = make_moe_ep(mesh, EPConfig(capacity_factor=16.0), plan=plan_d)
with jax.set_mesh(mesh):
    y_f = jax.jit(lambda p, x: full(p, x, mc))(params_diag, xd)
    y_r = jax.jit(lambda p, x: ragged_d(p, x, mc))(params_diag, xd)
    hlo = jax.jit(lambda p, x: ragged_d(p, x, mc)).lower(
        params_diag, xd).compile().as_text()
np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_f),
                           rtol=1e-6, atol=1e-6)
assert "collective-permute" not in hlo, "all-padding steps must be skipped"
print("RAGGED_SKIP_OK")
"""


def test_ragged_ep_subprocess():
    if not hasattr(jax, "set_mesh") or not hasattr(jax, "shard_map"):
        pytest.skip("shard_map/set_mesh EP path needs jax >= 0.5")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _RAGGED_EP_SUBPROCESS],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=600)
    assert "RAGGED_EP_OK" in out.stdout, out.stderr[-2000:]
    assert "RAGGED_BUCKET_OK" in out.stdout, out.stderr[-2000:]
    assert "RAGGED_SKIP_OK" in out.stdout, out.stderr[-2000:]
