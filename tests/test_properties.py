"""Property tests on the scheduling system's invariants.

Runs under real hypothesis when installed (CI does — requirements-dev.txt);
otherwise ``tests/_proptest.py`` executes the same properties with seeded
random sampling, so this suite is tier-1 everywhere instead of silently
skipping (the seed gap ROADMAP flagged).
"""

import numpy as np

from _proptest import given, settings, st

from repro.core import executor as ex
from repro.core.odg import (ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.scheduler import (Schedule, compile_schedule,
                                  execution_order, validate_schedule)

# Legal configs: gmm_m_split must divide ep (grouped) or be a multiple of it
# with per-src nesting (rows % (m/ep) == 0 handled by rows choice).
cfgs = st.builds(
    ScheduleConfig,
    ep=st.sampled_from([2, 3, 4]),
    e_loc=st.sampled_from([1, 2, 3]),
    rows=st.sampled_from([4, 8]),
    d_model=st.just(16),
    d_ff=st.just(8),
    dtype_bytes=st.just(2),
    gmm_m_split=st.sampled_from([1, 2, 4]),
).filter(lambda c: (c.ep % c.gmm_m_split == 0)
         or (c.gmm_m_split % c.ep == 0
             and (c.ep * c.rows) % c.gmm_m_split == 0))

directions = st.sampled_from(["forward", "backward"])
flags = st.tuples(st.booleans(), st.booleans())


def _build(cfg, direction):
    return (build_moe_ffn_forward(cfg) if direction == "forward"
            else build_moe_ffn_backward(cfg))


@settings(max_examples=40, deadline=None)
@given(cfgs, directions, flags)
def test_schedules_deadlock_free(cfg, direction, fl):
    ratr, il = fl
    s = compile_schedule(_build(cfg, direction), ratr=ratr,
                         gmm_interleave=il)
    validate_schedule(s)
    order = execution_order(s)
    assert sorted(order) == list(range(s.n_tasks))


@settings(max_examples=30, deadline=None)
@given(cfgs, directions)
def test_write_coverage_no_overlap(cfg, direction):
    """Non-external tensors are written exactly once, fully covered."""
    s = compile_schedule(_build(cfg, direction))
    g = _build(cfg, direction)
    from repro.core.split import propagate_splits
    propagate_splits(g)
    rows_written: dict = {}
    for td in s.tasks:
        for w in td.outputs:
            key = (w.tensor, w.rank)
            cover = rows_written.setdefault(key, np.zeros(1 << 20, bool))
            # weight-gradient "rows" accumulate (expert blocks) — skip those
            if td.task_type == "GMMWGrad":
                continue
            assert not cover[w.lo:w.hi].any(), \
                f"overlapping write on {key} [{w.lo},{w.hi})"
            cover[w.lo:w.hi] = True
    for (name, rank), cover in rows_written.items():
        base = name.split("@")[0]
        matches = [t for n, t in g.tensors.items()
                   if n.split("@")[0] == base and not t.external]
        if not matches or base in ("dW1", "dW2"):
            continue
        rows = matches[0].rows
        assert cover[:rows].all(), f"{name}@{rank} rows not fully written"


@settings(max_examples=15, deadline=None)
@given(cfgs, st.integers(0, 100))
def test_executor_order_invariance(cfg, seed):
    s = compile_schedule(build_moe_ffn_forward(cfg))
    x_src, w1, w2 = ex.make_inputs(cfg, 0)
    st_ = ex.ExecutorState(cfg)
    ex.load_forward_state(cfg, st_, x_src, w1, w2)
    ex.execute(s, st_, rng=np.random.default_rng(seed))
    ref = ex.reference_forward(cfg, x_src, w1, w2)
    got = np.stack([st_.get("y_ret", r) for r in range(cfg.ep)])
    np.testing.assert_allclose(got, ref["y_ret"], rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(0, 15))
def test_ratr_is_permutation(ep, rank):
    from repro.core.reorder import ratr_order
    rank = rank % ep
    order = ratr_order(rank, ep)
    assert sorted(order) == list(range(ep))
    assert order[0] == (rank + 1) % ep


# ---------------------------------------------------------------------------
# Pass pipeline: every registered pass keeps arbitrary imbalanced plans legal.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["skewed", "sparse",
                                                "hotspot"]),
       st.sampled_from([1, 2, 3, 16]), directions,
       st.lists(st.sampled_from(["ratr", "gmm_interleave",
                                 "chain_interleave",
                                 "critical_rank_first"]),
                unique=True, max_size=4))
def test_passes_keep_random_plans_valid(seed, kind, m_split, direction,
                                        pipeline):
    from repro.core.routing import hotspot_plan, random_plan, skewed_plan
    rng = np.random.default_rng(seed)
    ep, e_loc = int(rng.integers(2, 5)), int(rng.integers(1, 4))
    if kind == "skewed":
        plan = skewed_plan(ep, e_loc, int(rng.integers(1, 9)),
                           float(rng.uniform(0, 2.5)))
    elif kind == "sparse":
        plan = random_plan(ep, e_loc, 7, rng, p_zero=0.4)
    else:
        rows = int(rng.integers(2, 9))
        bg = int(rng.integers(0, 2))
        if (bg + ep - 1) * (ep * e_loc - 1) > ep * e_loc * rows:
            bg = 0               # background must fit the per-source budget
        plan = hotspot_plan(ep, e_loc, rows, background=bg)
    cfg = ScheduleConfig(ep=ep, e_loc=e_loc, rows=0, d_model=16, d_ff=8,
                         gmm_m_split=m_split,
                         gmm_split_mode="source_aligned", plan=plan)
    s = compile_schedule(_build(cfg, direction), pipeline=pipeline)
    validate_schedule(s)
    order = execution_order(s)
    assert sorted(order) == list(range(s.n_tasks))
