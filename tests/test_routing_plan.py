"""RoutingPlan unit tests: offsets, ragged tiles, balanced equivalence."""

import numpy as np
import pytest

from repro.core.odg import (ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.routing import (RoutingPlan, balanced_plan, hotspot_plan,
                                random_plan, skewed_plan)
from repro.core.scheduler import compile_schedule
from repro.core.split import propagate_splits, split_report
from repro.core.ssc import SSCCache
from repro.core import executor as ex


def test_plan_offsets_round_trip():
    plan = RoutingPlan.from_counts([[[3, 0], [1, 2]],
                                    [[0, 5], [2, 0]]])
    assert plan.ep == 2 and plan.e_loc == 2
    # send buffer on src 0: (d0,e0)=3, (d0,e1)=0, (d1,e0)=1, (d1,e1)=2
    assert plan.send_rows(0) == 6
    assert plan.send_offset(0, 1, 0) == 3
    assert plan.send_offset(0, 1, 1) == 4
    # recv buffer on dst 0: e0 gets 3 (src0) + 0 (src1); e1 gets 0 + 5
    assert plan.recv_rows(0) == 8
    assert plan.expert_rows(0, 0) == 3
    assert plan.expert_rows(0, 1) == 5
    assert plan.recv_offset(0, 1, 1) == 3
    assert plan.n_send_cells(0) == 3
    assert plan.n_combine_cells(0) == 2   # cells (s=0,e=0) and (s=1,e=1)


def test_plan_validation():
    with pytest.raises(ValueError):
        RoutingPlan.from_counts(np.ones((2, 3, 1)))
    with pytest.raises(ValueError):
        RoutingPlan.from_counts(-np.ones((2, 2, 1)))
    with pytest.raises(ValueError):
        ScheduleConfig(ep=3, e_loc=1, rows=0, d_model=8, d_ff=4,
                       plan=balanced_plan(2, 1, 4))


def test_plan_hashable_and_cached():
    a = balanced_plan(4, 2, 8)
    b = RoutingPlan.balanced(4, 2, 8)
    assert a is b                       # lru-cached trivial plan
    assert hash(a) == hash(RoutingPlan.from_counts(np.full((4, 4, 2), 8)))


def test_gmm_tiles_ragged_last_chunk():
    """Non-divisible expert rows emit a ragged last tile — no rows dropped."""
    plan = balanced_plan(1, 1, 10)
    tiles = plan.gmm_tiles(0, 3)        # 10 rows into ≤3 chunks of ceil=4
    assert tiles == [(0, 0, 0, 4), (0, 1, 4, 8), (0, 2, 8, 10)]
    # skewed: expert 0 has 7 rows, expert 1 has 2 (fewer rows than m_split)
    plan = RoutingPlan.from_counts([[[7, 2]]])
    tiles = plan.gmm_tiles(0, 4)
    covered = []
    for (e, m, lo, hi) in tiles:
        assert hi > lo
        covered.extend(range(lo, hi))
    assert covered == list(range(9))    # every row exactly once, in order


def test_balanced_plan_reproduces_scalar_rows_schedule():
    """The trivial plan must compile to the seed's exact taskflow."""
    scalar = ScheduleConfig(ep=4, e_loc=2, rows=8, d_model=32, d_ff=16)
    planned = ScheduleConfig(ep=4, e_loc=2, rows=8, d_model=32, d_ff=16,
                             plan=balanced_plan(4, 2, 8))
    for builder in (build_moe_ffn_forward, build_moe_ffn_backward):
        s1 = compile_schedule(builder(scalar), ratr=True)
        s2 = compile_schedule(builder(planned), ratr=True)
        assert s1.n_tasks == s2.n_tasks
        assert s1.queues == s2.queues
        for a, b in zip(s1.tasks, s2.tasks):
            assert a.inputs == b.inputs and a.outputs == b.outputs
            assert a.dependent_event == b.dependent_event
            assert a.dependent_threshold == b.dependent_threshold


def test_balanced_closed_form_ranges():
    """Balanced dispatch TDs match the seed's fixed-grid arithmetic."""
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=8, d_model=32, d_ff=16)
    s = compile_schedule(build_moe_ffn_forward(cfg))
    R = cfg.rows
    for td in s.tasks:
        if td.op_name != "Dispatch@1":
            continue
        d, e = td.meta["dst"], td.meta["expert"]
        assert td.inputs[0].lo == (d * cfg.e_loc + e) * R
        assert td.outputs[0].lo == (e * cfg.ep + 1) * R
        assert td.outputs[0].rows == R


def test_task_counts_skip_empty_cells():
    counts = np.zeros((2, 2, 2), dtype=np.int64)
    counts[0, 0, 0] = 5            # src 0 → (rank 0, expert 0) only
    counts[1, 0, 1] = 3            # src 1 → (rank 0, expert 1) only
    cfg = ScheduleConfig(ep=2, e_loc=2, rows=0, d_model=8, d_ff=4,
                         plan=RoutingPlan.from_counts(counts))
    g = build_moe_ffn_forward(cfg)
    propagate_splits(g)
    rep = dict(split_report(g))
    assert rep["Dispatch@0"] == 1 and rep["Dispatch@1"] == 1
    s = compile_schedule(g)
    # rank 1 receives nothing → none of its compute/return ops emit tasks
    for name in ("GMM1@1", "SwiGLU@1", "GMM2@1", "Combine@1"):
        assert not any(td.op_name == name for td in s.tasks)
    assert all(td.inputs[0].rows > 0 for td in s.tasks)


def test_gmm_msplit_ragged_regression():
    """Seed regression: ``chunk = rpe // m_split`` silently dropped the
    remainder rows of every expert (10 rows / m_split=3 → three 3-row tiles,
    row 9 never computed). Ragged tiles must cover every row and the
    executor must match the reference exactly."""
    # Rank 0's single expert gets all 10 rows from src 0, so the three
    # ragged m-chunks nest inside one dispatch tile (single-trigger legal).
    plan = RoutingPlan.from_counts([[[10], [3]],
                                    [[0], [4]]])
    cfg = ScheduleConfig(ep=2, e_loc=1, rows=0, d_model=8, d_ff=4,
                         gmm_m_split=3, plan=plan)
    s = compile_schedule(build_moe_ffn_forward(cfg))
    gmm1 = [t for t in s.tasks if t.op_name == "GMM1@0"]
    assert [t.outputs[0].rows for t in gmm1] == [4, 4, 2]
    x_src, w1, w2 = ex.make_inputs_plan(cfg, 3)
    st = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
    ex.execute(s, st, rng=np.random.default_rng(0))
    ref = ex.reference_forward_plan(cfg, x_src, w1, w2)
    # with the seed's floor-division tiling, row 9 of h/g/y stayed zero
    assert np.abs(st.get("y", 0)[9]).sum() > 0
    # m-chunked matmuls differ from the reference's one-matmul-per-expert
    # by float addition order, so exactness (asserted elsewhere at
    # gmm_m_split=1) relaxes to tight allclose here.
    for r in range(cfg.ep):
        np.testing.assert_allclose(st.get("y_ret", r), ref["y_ret"][r],
                                   rtol=1e-5, atol=1e-6)


def test_rowwise_ragged_regression():
    """Generic elementwise tiling covers non-divisible rows (seed dropped
    ``rows % n`` trailing rows)."""
    from repro.core.odg import ODG, OperatorNode, SplitSpec, VECTOR
    cfg = ScheduleConfig(ep=1, e_loc=1, rows=10, d_model=4, d_ff=4)
    g = ODG(cfg, "forward")
    h = g.tensor("h@0", 10, 16, external=True)
    mid = g.tensor("g@0", 10, 8)
    out = g.tensor("out@0", 10, 8)
    g.add_op(OperatorNode(
        name="SwiGLU@0", op_type="swiglu", resource=VECTOR, rank=0,
        inputs=[h], outputs=[mid],
        split_spec=SplitSpec(split_inputs=None, split_output_dims=(0,),
                             task_num_fn=lambda c, op: 3)))
    g.add_op(OperatorNode(
        name="Add@0", op_type="elementwise", resource=VECTOR, rank=0,
        inputs=[mid], outputs=[out],
        split_spec=SplitSpec(split_inputs=((0, 0),), split_output_dims=(0,),
                             task_num_fn=lambda c, op: 3),
        meta={"task_type": "Add"}))
    s = compile_schedule(g)
    for op_name in ("SwiGLU@0", "Add@0"):
        tds = [t for t in s.tasks if t.op_name == op_name]
        covered = sorted((t.outputs[0].lo, t.outputs[0].hi) for t in tds)
        assert covered[0][0] == 0 and covered[-1][1] == 10
        for (a, b) in zip(covered, covered[1:]):
            assert a[1] == b[0]


def test_ssc_cache_keys_on_plan():
    cache = SSCCache()
    plan_a = skewed_plan(2, 2, 4, 1.0)
    plan_b = skewed_plan(2, 2, 4, 2.0)
    cfg_a = ScheduleConfig(ep=2, e_loc=2, rows=0, d_model=8, d_ff=4,
                           plan=plan_a)
    cfg_b = ScheduleConfig(ep=2, e_loc=2, rows=0, d_model=8, d_ff=4,
                           plan=plan_b)
    cache.get_or_compile(cfg_a, "forward")
    cache.get_or_compile(cfg_b, "forward")   # different plan → miss
    cache.get_or_compile(cfg_a, "forward")   # same plan → hit
    assert cache.misses == 2 and cache.hits == 1


def test_plan_skew_metrics():
    assert balanced_plan(4, 2, 8).is_balanced()
    assert balanced_plan(4, 2, 8).expert_imbalance() == pytest.approx(1.0)
    hot = hotspot_plan(4, 2, 8)
    assert not hot.is_balanced()
    assert hot.expert_imbalance() == pytest.approx(4 * 2)
    assert hot.rank_imbalance() == pytest.approx(4)
    rnd = random_plan(3, 2, 9, np.random.default_rng(0))
    assert rnd.total_rows == sum(rnd.send_rows(s) for s in range(3))
    assert rnd.total_rows == sum(rnd.recv_rows(r) for r in range(3))
