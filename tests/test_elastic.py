"""Elastic plan remapping: invariants, executor parity, cache re-keying,
and the observed-time straggler feedback loop.

``core/elastic.py`` claims a remapped plan is *the* plan a shrunken mesh
would have built natively — these tests pin that cell-for-cell and
bit-for-bit (executor outputs), property-test the invariants over the full
plan-generator zoo × random dead-rank sets, and check the two integration
seams: ``SSCCache.rekey_for_mesh`` (re-key, never flush) and
``CostModel(rank_bias=)`` → ``autoselect`` (a measured-slow rank becomes
the compile-time critical rank).
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from _proptest import given, settings, st
from repro.core import autoselect
from repro.core import executor as ex
from repro.core.buckets import BucketSpec
from repro.core.costmodel import CostModel
from repro.core.elastic import (BIAS_CEIL, BIAS_FLOOR, check_remap,
                                observed_cost_model, rank_bias_from_times,
                                rechunk_expert_array, remap_plan,
                                surviving_ranks)
from repro.core.odg import (CTQ, ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.routing import (RoutingPlan, balanced_plan, hotspot_plan,
                                node_limited_plan, random_plan, skewed_plan)
from repro.core.scheduler import compile_schedule
from repro.core.ssc import SSCCache
from repro.core.tasks import TaskDescriptor
from repro.ft.runner import ElasticContext, FTConfig, RunState, train_loop


# ---------------------------------------------------------------------------
# remap_plan properties over the plan-generator zoo × random dead sets.
# e_total = 4 * 3 = 12 divides every survivor count 1..4, so any dead set
# is legal.
# ---------------------------------------------------------------------------

def _make_plan(kind: str, seed: int) -> RoutingPlan:
    if kind == "skewed":
        return skewed_plan(4, 3, 8 + seed % 5, alpha=0.5 + (seed % 4) * 0.5)
    if kind == "hotspot":
        return hotspot_plan(4, 3, 4 + seed % 4, background=seed % 3)
    if kind == "node_limited":
        return node_limited_plan(4, 3, 4 + seed % 4, node_size=2,
                                 m_nodes=1 + seed % 2)
    return random_plan(4, 3, 12, np.random.default_rng(seed), p_zero=0.3)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(["skewed", "hotspot", "node_limited", "random"]),
       st.integers(0, 10 ** 6),
       st.lists(st.integers(0, 3), min_size=1, max_size=3, unique=True))
def test_remap_invariants_property(kind, seed, dead):
    plan = _make_plan(kind, seed)
    survivors = surviving_ranks(4, dead)
    new = remap_plan(plan, dead_ranks=dead)
    assert new.ep == len(survivors)
    assert new.ep * new.e_loc == 12          # experts conserved
    report = check_remap(plan, new, survivors)
    assert report["ok"], report
    # Idempotence: remapping with nothing dead is the identity, and
    # re-chunking onto the same mesh size changes nothing.
    assert remap_plan(new, dead_ranks=[]).counts == new.counts
    assert remap_plan(new, new_ep=new.ep).counts == new.counts
    # Total rows equal the survivors' send rows — no cell addresses a
    # dead rank.
    assert new.total_rows == sum(plan.send_rows(r) for r in survivors)


def test_remap_argument_validation():
    plan = balanced_plan(4, 3, 2)
    with pytest.raises(ValueError, match="exactly one"):
        remap_plan(plan)
    with pytest.raises(ValueError, match="exactly one"):
        remap_plan(plan, dead_ranks=[0], new_ep=2)
    with pytest.raises(ValueError, match="outside mesh"):
        remap_plan(plan, dead_ranks=[4])
    with pytest.raises(ValueError, match="nothing to remap"):
        remap_plan(plan, dead_ranks=[0, 1, 2, 3])
    # 12 experts cannot land on 5 ranks.
    with pytest.raises(ValueError, match="valid mesh sizes"):
        remap_plan(plan, new_ep=5)


def test_remap_growth_roundtrip():
    """Shrink then grow back: the original cells return (fresh sources
    join empty, so the dead rank's rows are gone — but the survivors'
    cells land back in their original (src, dst, expert) slots)."""
    plan = skewed_plan(4, 3, 6, alpha=1.0)
    small = remap_plan(plan, dead_ranks=[3])
    back = remap_plan(small, new_ep=4)
    c_old = np.asarray(plan.counts)[:3]
    c_back = np.asarray(back.counts)
    np.testing.assert_array_equal(c_back[:3], c_old)
    assert c_back[3].sum() == 0


def test_rechunk_expert_array_forms():
    w = np.arange(12 * 5 * 7, dtype=np.float32).reshape(12, 5, 7)
    per_rank = w.reshape(4, 3, 5, 7)
    out_a = rechunk_expert_array(w, 2)
    # ep=4 divides new_ep=2, so the per-rank form needs e_total= to
    # disambiguate; new_ep=3 resolves on its own.
    out_b = rechunk_expert_array(per_rank, 2, e_total=12)
    assert out_a.shape == out_b.shape == (2, 6, 5, 7)
    np.testing.assert_array_equal(out_a, out_b)
    np.testing.assert_array_equal(out_a.reshape(12, 5, 7), w)
    np.testing.assert_array_equal(rechunk_expert_array(per_rank, 3),
                                  rechunk_expert_array(w, 3))
    with pytest.raises(ValueError, match="re-chunk"):
        rechunk_expert_array(w, 7)


# ---------------------------------------------------------------------------
# Executor parity: the remapped plan executes bit-for-bit like the old
# mesh (surviving rows) and like a fresh native small-mesh compile.
# ---------------------------------------------------------------------------

def _small_cfg(plan):
    return ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                          d_model=8, d_ff=4, plan=plan)


@pytest.mark.parametrize("kind,dead", [
    ("skewed", [1]), ("hotspot", [0]), ("random", [0, 2]),
    ("node_limited", [3]),
])
def test_remap_executor_forward_backward_parity(kind, dead):
    plan = _make_plan(kind, seed=7)
    survivors = surviving_ranks(4, dead)
    new = remap_plan(plan, dead_ranks=dead)

    old_cfg = _small_cfg(plan)
    new_cfg = _small_cfg(new)
    x_src, w1, w2 = ex.make_inputs_plan(old_cfg, 3)
    # Survivors keep their send buffers verbatim; expert weights re-chunk
    # by pure reshape (global expert order preserved).
    x_small = [x_src[r] for r in survivors]
    w1_small = rechunk_expert_array(w1, new.ep, e_total=12)
    w2_small = rechunk_expert_array(w2, new.ep, e_total=12)

    fwd_old = ex.reference_forward_plan(old_cfg, x_src, w1, w2)
    s = compile_schedule(build_moe_ffn_forward(new_cfg), ratr=True)
    st_f = ex.ExecutorState(new_cfg)
    ex.load_forward_state_plan(new_cfg, st_f, x_small, w1_small, w2_small)
    ex.execute(s, st_f, rng=np.random.default_rng(0))
    for i, r in enumerate(survivors):
        if new.send_rows(i):
            # Bit-identical to the old mesh's per-source combined output.
            np.testing.assert_array_equal(st_f.get("y_ret", i),
                                          fwd_old["y_ret"][r])

    # Backward through the real executor vs the fresh small-mesh reference.
    fwd_small = ex.reference_forward_plan(new_cfg, x_small, w1_small,
                                          w2_small)
    rng = np.random.default_rng(11)
    dy = [rng.standard_normal(fwd_small["y_ret"][i].shape).astype(np.float32)
          for i in range(new.ep)]
    sb = compile_schedule(build_moe_ffn_backward(new_cfg), ratr=True,
                          gmm_interleave=True)
    st_b = ex.ExecutorState(new_cfg)
    ex.load_backward_state_plan(new_cfg, st_b, fwd_small, w1_small,
                                w2_small, dy)
    ex.execute(sb, st_b, rng=np.random.default_rng(1))
    dx_ref, dw1_ref, dw2_ref = ex.reference_backward_plan(
        new_cfg, fwd_small, w1_small, w2_small, dy)
    for i in range(new.ep):
        if new.send_rows(i):
            np.testing.assert_array_equal(st_b.get("dx_ret", i), dx_ref[i])
        if new.recv_rows(i):
            np.testing.assert_array_equal(st_b.get("dW1", i), dw1_ref[i])
            np.testing.assert_array_equal(st_b.get("dW2", i), dw2_ref[i])


# ---------------------------------------------------------------------------
# BucketSpec mesh tagging.
# ---------------------------------------------------------------------------

def test_bucketspec_ep_tagging():
    b = BucketSpec.linear(16)
    assert b.key() == ("linear", 16)          # untagged = pre-tag bytes
    t = b.for_mesh(4)
    assert t.key() == ("linear", 16, ("ep", 4))
    assert str(t) == "linear:16@ep4"
    assert BucketSpec.parse("linear:16@ep4") == t
    assert BucketSpec.from_any(t.key()) == t
    assert BucketSpec.from_any(t.spec()) == t
    assert t.for_mesh(None) == b
    assert t.for_mesh(4) is t
    g = BucketSpec.geometric(8, 1.5).for_mesh(2)
    assert BucketSpec.from_any(g.spec()) == g
    assert str(BucketSpec.parse(str(g))) == str(g)
    with pytest.raises(ValueError, match="@epN"):
        BucketSpec.parse("linear:16@4")
    with pytest.raises(ValueError, match="ep tag"):
        BucketSpec.linear(4).for_mesh(0)
    # Quantization is tag-independent.
    c = np.array([1, 7, 16, 17])
    np.testing.assert_array_equal(b.quantize(c), t.quantize(c))


# ---------------------------------------------------------------------------
# SSCCache: ep-tagged keys and rekey_for_mesh.
# ---------------------------------------------------------------------------

def _sched_cfg(plan, bucket=None):
    return ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0, d_model=8,
                          d_ff=4, plan=plan, bucket=bucket)


def test_cache_key_tags_bucket_with_mesh():
    plan4 = balanced_plan(4, 3, 4)
    k = SSCCache.key(_sched_cfg(plan4, bucket=16), "forward",
                     pipeline=["ratr"])
    assert k[8] == ("linear", 16, ("ep", 4))
    # Bucket-less keys are unchanged.
    k0 = SSCCache.key(_sched_cfg(plan4), "forward", pipeline=["ratr"])
    assert k0[8] is None


def test_rekey_for_mesh_rekeys_not_flushes():
    cache = SSCCache(max_entries=8)
    plan4 = skewed_plan(4, 3, 4, alpha=1.0)
    plan3 = remap_plan(plan4, dead_ranks=[3])
    cache.get_or_compile(_sched_cfg(plan4, 16), "forward", pipeline=["ratr"])
    cache.get_or_compile(_sched_cfg(plan3, 16), "forward", pipeline=["ratr"])
    assert cache.info()["by_ep"] == {3: 1, 4: 1}

    out = cache.rekey_for_mesh(3)
    assert out == {"entries": 2, "active": 1, "stale": 1, "retagged": 0}
    info = cache.info()
    assert info["rekeyed"] == 1 and info["active_ep"] == 3
    assert info["evictions"] == 0 and info["entries"] == 2
    # Post-rekey, both mesh populations still hit.
    cache.get_or_compile(_sched_cfg(plan3, 16), "forward", pipeline=["ratr"])
    cache.get_or_compile(_sched_cfg(plan4, 16), "forward", pipeline=["ratr"])
    assert cache.hits == 2 and cache.misses == 2
    # Stale-mesh entries bear LRU pressure: with room for one more entry,
    # inserting two fresh ep=3 plans evicts the boosted-last ep=4 entry
    # only after the cache is truly full.
    small = SSCCache(max_entries=2)
    small.get_or_compile(_sched_cfg(plan4, 16), "forward", pipeline=["ratr"])
    small.get_or_compile(_sched_cfg(plan3, 16), "forward", pipeline=["ratr"])
    small.rekey_for_mesh(3)
    plan3b = remap_plan(skewed_plan(4, 3, 5, alpha=1.0), dead_ranks=[3])
    small.get_or_compile(_sched_cfg(plan3b, 16), "forward",
                         pipeline=["ratr"])
    assert small.evictions == 1
    assert small.info()["by_ep"] == {3: 2}   # the ep=4 entry was the victim


def test_rekey_retags_legacy_untagged_keys():
    cache = SSCCache(max_entries=8)
    plan4 = balanced_plan(4, 3, 4)
    k = SSCCache.key(_sched_cfg(plan4, 16), "forward", pipeline=["ratr"])
    legacy = k[:8] + (("linear", 16),) + k[9:]    # pre-tag key format
    cache._insert(legacy, b"blob", fragments=1)
    out = cache.rekey_for_mesh(4)
    assert out["retagged"] == 1
    assert list(cache._cache) == [k]              # now the canonical key


# ---------------------------------------------------------------------------
# Observed-time feedback: rank_bias → critical rank → autoselect.
# ---------------------------------------------------------------------------

def test_rank_bias_normalization_and_clipping():
    bias = rank_bias_from_times([100.0, 100.0, 100.0])
    assert bias == (1.0, 1.0, 1.0)
    bias = rank_bias_from_times([100.0, 100.0, 400.0])
    assert abs(sum(bias) / 3 - 1.0) < 0.5         # mean-normalized pre-clip
    assert max(bias) == bias[2]
    huge = rank_bias_from_times([1.0] * 9 + [1e9])
    assert max(huge) == BIAS_CEIL and min(huge) == BIAS_FLOOR
    assert rank_bias_from_times([0.0, 0.0]) == (1.0, 1.0)
    with pytest.raises(ValueError, match="empty"):
        rank_bias_from_times([])
    with pytest.raises(ValueError, match="negative"):
        rank_bias_from_times([1.0, -1.0])


def test_cost_model_bias_prices_tasks_and_stays_hashable():
    cm = observed_cost_model([300.0, 100.0, 100.0, 100.0])
    base = CostModel(l2=False)
    td = TaskDescriptor(task_type="GMM", queue_type=CTQ, rank=0, flops=1e9)
    td1 = dataclasses.replace(td, rank=1)
    assert cm.task_us(td) / cm.task_us(td1) == pytest.approx(
        cm.rank_bias[0] / cm.rank_bias[1])
    # Unbiased ranks (and out-of-range ranks) price exactly as the base.
    assert cm._task_us_unbiased(td) == base.task_us(td)
    assert cm.task_us(dataclasses.replace(td, rank=7)) == base.task_us(td)
    assert observed_cost_model(None, base) is base
    hash(cm)                                      # lru_cache memo key


def test_slow_rank_becomes_critical_and_autoselect_reacts():
    plan = balanced_plan(4, 3, 16)
    cfg = ScheduleConfig(ep=4, e_loc=3, rows=16, d_model=64, d_ff=128,
                         plan=plan)
    view = autoselect.cube_taskset(plan, cfg, "forward")
    # Unbiased: balanced plan, no straggler, no crit pipeline priced.
    ratio0, _ = CostModel(l2=False).critical_rank(view)
    assert ratio0 == pytest.approx(1.0)
    # 3× slow rank 2: it becomes the compile-time critical rank and the
    # selector picks a pipeline containing critical_rank_first.
    cm = observed_cost_model([100.0, 100.0, 300.0, 100.0])
    ratio, crit = cm.critical_rank(view)
    assert crit == 2 and ratio > 1.05
    choice = autoselect.select(plan, cfg, cm)
    names = [n for n, _ in choice.pipeline.key()]
    assert "critical_rank_first" in names, choice.tag


# ---------------------------------------------------------------------------
# ElasticContext: rescale-on-restore through train_loop (cheap fake step).
# ---------------------------------------------------------------------------

class _Stream:
    def sharded_batch(self, step, mesh, sharding):
        return jnp.float32(step + 1)


def _fake_step(ep):
    def step(params, opt_state, batch):
        w = params["w"] - 0.01 * batch
        return ({"w": w}, opt_state,
                {"loss": jnp.sum(w * w), "grad_norm": jnp.float32(0.1),
                 "rank_time_us": np.r_[np.full(ep - 1, 100.0), 300.0]})
    return step


def test_train_loop_elastic_rescale_on_restore(tmp_path):
    plan = skewed_plan(3, 2, 8, alpha=1.0)
    cache = SSCCache(8)
    cache.get_or_compile(_sched_cfg(plan, 4), "forward", pipeline=["ratr"])
    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    params = {"w": jnp.float32(1.0)}

    run3 = train_loop(step_fn=_fake_step(3), params=params, opt_state=None,
                      stream=_Stream(), mesh=None, batch_sharding=None,
                      n_steps=4, ft=ft, log_every=1,
                      elastic=ElasticContext(ep=3, cache=cache,
                                             plans={"live": plan}))
    assert run3.rank_time_ewma is not None and len(run3.rank_time_ewma) == 3

    # Resume on 2 ranks: rank 1 died.
    elastic = ElasticContext(ep=2, cache=cache, dead_ranks=(1,))
    run2 = train_loop(step_fn=_fake_step(2), params=params, opt_state=None,
                      stream=_Stream(), mesh=None, batch_sharding=None,
                      n_steps=6, ft=ft, log_every=1, elastic=elastic)
    assert run2.resumed_from == 4 and run2.step == 6
    # The persisted plan came back remapped = native on the small mesh.
    remapped = elastic.plans["live"]
    assert remapped.counts == remap_plan(plan, dead_ranks=[1]).counts
    assert check_remap(plan, remapped, (0, 2))["ok"]
    (event,) = run2.elastic_events
    assert event["from_ep"] == 3 and event["to_ep"] == 2
    assert event["survivors"] == [0, 2] and event["cache"]["entries"] == 1
    assert cache.info()["active_ep"] == 2 and cache.evictions == 0
    # The EWMA restricted to survivors: old rank 2 (slow) is now rank 1.
    cm = run2.cost_model()
    assert cm.rank_bias is not None and len(cm.rank_bias) == 2
    # Merged history spans the crash boundary.
    assert [m["step"] for m in run2.metrics_log] == list(range(1, 7))
    # Growth: resuming back on 3 ranks re-chunks the other way (the new
    # source joins with zero rows; 6 experts spread back to e_loc=2).
    elastic3 = ElasticContext(ep=3, cache=cache)
    run4 = train_loop(step_fn=_fake_step(3), params=params, opt_state=None,
                      stream=_Stream(), mesh=None, batch_sharding=None,
                      n_steps=8, ft=ft, log_every=1, elastic=elastic3)
    assert run4.elastic_events[0]["to_ep"] == 3
    grown = elastic3.plans["live"]
    assert grown.ep == 3 and grown.total_rows == remapped.total_rows


def test_runstate_cost_model_without_observations():
    rs = RunState(step=0, params=None, opt_state=None, metrics_log=[],
                  stragglers=[])
    assert rs.cost_model().rank_bias is None


def test_dead_ranks_mismatch_raises(tmp_path):
    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    params = {"w": jnp.float32(1.0)}
    train_loop(step_fn=_fake_step(3), params=params, opt_state=None,
               stream=_Stream(), mesh=None, batch_sharding=None, n_steps=2,
               ft=ft, elastic=ElasticContext(ep=3))
    with pytest.raises(ValueError, match="survivors"):
        train_loop(step_fn=_fake_step(2), params=params, opt_state=None,
                   stream=_Stream(), mesh=None, batch_sharding=None,
                   n_steps=4, ft=ft,
                   elastic=ElasticContext(ep=2, dead_ranks=(0, 1)))


# ---------------------------------------------------------------------------
# End-to-end acceptance: the harness scenarios (dropless run killed
# mid-training, resumed on a shrunken mesh; injected 3× slow rank).
# ---------------------------------------------------------------------------

def test_e2e_rescale_scenario(tmp_path):
    import ftharness
    checks = ftharness.run_rescale("uniform", str(tmp_path))
    assert all(checks.values()), checks


def test_e2e_slow_rank_scenario(tmp_path):
    import ftharness
    checks = ftharness.run_slow("hotspot", str(tmp_path))
    assert all(checks.values()), checks
