"""Imbalanced-plan execution: adversarial orders + the moe_grouped bridge.

The acceptance bar for the RoutingPlan refactor: a schedule compiled from
*real* (imbalanced) router output must execute bit-for-bit equal to the
grouped-MoE reference, forward and backward, under randomized event-driven
order — and the executor's per-rank buffers must be sized strictly from the
schedule, never guessed from same-named peers.
"""

import numpy as np
import pytest

from repro.core import executor as ex
from repro.core.odg import (ScheduleConfig, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.routing import (RoutingPlan, hotspot_plan, random_plan,
                                skewed_plan)
from repro.core.scheduler import compile_schedule, validate_schedule
from repro.models.moe import (MoEConfig, bridge_combine, bridge_dispatch,
                              capacity, init_moe, moe_grouped,
                              plan_from_routing, router_topk)


def _plan_grid():
    rng = np.random.default_rng(42)
    return [
        ("skewed", skewed_plan(3, 2, 6, 1.5)),
        ("sparse", random_plan(3, 2, 7, rng, p_zero=0.5)),
        ("hotspot", hotspot_plan(3, 2, 4)),
        ("one_empty_src", RoutingPlan.from_counts(
            [[[0, 0], [0, 0], [0, 0]],
             [[5, 1], [0, 2], [3, 0]],
             [[2, 0], [4, 4], [0, 1]]])),
    ]


def _cfg(plan):
    return ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                          d_model=8, d_ff=4, plan=plan)


@pytest.mark.parametrize("name,plan", _plan_grid())
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_imbalanced_forward_adversarial_order(name, plan, seed):
    cfg = _cfg(plan)
    s = compile_schedule(build_moe_ffn_forward(cfg), ratr=True)
    validate_schedule(s)
    x_src, w1, w2 = ex.make_inputs_plan(cfg, 7)
    st = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
    ex.execute(s, st, rng=np.random.default_rng(seed))
    ref = ex.reference_forward_plan(cfg, x_src, w1, w2)
    for r in range(cfg.ep):
        if plan.send_rows(r):
            np.testing.assert_array_equal(st.get("y_ret", r),
                                          ref["y_ret"][r])
        if plan.recv_rows(r):
            np.testing.assert_array_equal(st.get("x_recv", r),
                                          ref["x_recv"][r])


@pytest.mark.parametrize("name,plan", _plan_grid())
@pytest.mark.parametrize("seed", [0, 3])
def test_imbalanced_backward_adversarial_order(name, plan, seed):
    cfg = _cfg(plan)
    s = compile_schedule(build_moe_ffn_backward(cfg), ratr=True,
                         gmm_interleave=True)
    validate_schedule(s)
    x_src, w1, w2 = ex.make_inputs_plan(cfg, 11)
    fwd = ex.reference_forward_plan(cfg, x_src, w1, w2)
    rng = np.random.default_rng(seed + 100)
    dy = [rng.standard_normal(fwd["y_ret"][r].shape).astype(np.float32)
          for r in range(cfg.ep)]
    st = ex.ExecutorState(cfg)
    ex.load_backward_state_plan(cfg, st, fwd, w1, w2, dy)
    ex.execute(s, st, rng=np.random.default_rng(seed))
    dx_ref, dw1_ref, dw2_ref = ex.reference_backward_plan(
        cfg, fwd, w1, w2, dy)
    for r in range(cfg.ep):
        if plan.send_rows(r):
            np.testing.assert_array_equal(st.get("dx_ret", r), dx_ref[r])
        if plan.recv_rows(r):
            np.testing.assert_array_equal(st.get("dW1", r), dw1_ref[r])
            np.testing.assert_array_equal(st.get("dW2", r), dw2_ref[r])
        else:
            assert not dw1_ref[r].any() and not dw2_ref[r].any()
    # independent autodiff oracle
    dx_j, dw1_j, dw2_j = ex.reference_backward_plan_jax(
        cfg, x_src, w1, w2, dy)
    for r in range(cfg.ep):
        if plan.send_rows(r):
            np.testing.assert_allclose(dx_ref[r], dx_j[r],
                                       rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw1_ref, dw1_j, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw2_ref, dw2_j, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name,plan", _plan_grid())
def test_imbalanced_order_independence(name, plan):
    """Different legal adversarial orders give bit-identical results."""
    cfg = _cfg(plan)
    outs = []
    for seed in range(3):
        s = compile_schedule(build_moe_ffn_forward(cfg),
                             ratr=bool(seed % 2))
        x_src, w1, w2 = ex.make_inputs_plan(cfg, 5)
        st = ex.ExecutorState(cfg)
        ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
        ex.execute(s, st, rng=np.random.default_rng(seed))
        outs.append([st.get("y_ret", r) for r in range(cfg.ep)
                     if plan.send_rows(r)])
    for o in outs[1:]:
        for a, b in zip(outs[0], o):
            np.testing.assert_array_equal(a, b)


def test_buffers_sized_from_rows_map():
    """Regression for the `_rows_hint` peer-guessing bug: with per-rank row
    counts differing, every lazily-created buffer must get exactly the
    extent recorded in the schedule's write set."""
    plan = RoutingPlan.from_counts(
        [[[9, 1], [2, 0]], [[0, 3], [1, 1]]])   # recv: rank0=13, rank1=4
    cfg = _cfg(plan)
    s = compile_schedule(build_moe_ffn_forward(cfg))
    x_src, w1, w2 = ex.make_inputs_plan(cfg, 0)
    st = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
    ex.execute(s, st, rng=np.random.default_rng(1))
    assert st.get("x_recv", 0).shape[0] == 13
    assert st.get("x_recv", 1).shape[0] == 4
    for (tname, rank), rows in st.rows_map.items():
        if (tname, rank) in st.buffers and tname != "dW1":
            assert st.buffers[(tname, rank)].shape[0] == rows, (tname, rank)


# ---------------------------------------------------------------------------
# The bridge: real router output → compiled schedule ≡ moe_grouped.
# ---------------------------------------------------------------------------

def _routed_case(seed=0, ep=4, t_loc=8, d=16, f=8, top_k=2):
    import jax
    mc = MoEConfig(n_experts=ep * 2, top_k=top_k, d_expert=f)
    T = ep * t_loc
    params = init_moe(jax.random.PRNGKey(seed), d, mc)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                     (1, T, d)), dtype=np.float32)
    top_p, top_i = router_topk(params["router"], x.reshape(T, d), mc)
    return mc, params, x, np.asarray(top_p), np.asarray(top_i)


@pytest.mark.parametrize("seed", [0, 1])
def test_bridge_schedule_matches_moe_grouped(seed):
    """Compile from real (imbalanced) router output; execute under a random
    event-driven order; combine; compare against the grouped reference."""
    ep, t_loc, d, f = 4, 8, 16, 8
    mc, params, x, top_p, top_i = _routed_case(seed, ep, t_loc, d, f)
    T = ep * t_loc
    C = capacity(T, mc)
    bridge = plan_from_routing(top_i, mc, ep, capacity=C)
    plan = bridge.plan
    assert not plan.is_balanced()          # real routing is skewed

    cfg = ScheduleConfig(ep=ep, e_loc=mc.e_total // ep, rows=0,
                         d_model=d, d_ff=f, plan=plan)
    s = compile_schedule(build_moe_ffn_forward(cfg), ratr=True)
    validate_schedule(s)

    x_src = bridge_dispatch(bridge, x.reshape(ep, t_loc, d))
    w1 = np.asarray(params["w_in"]).reshape(ep, cfg.e_loc, d, 2 * f)
    w2 = np.asarray(params["w_down"]).reshape(ep, cfg.e_loc, f, d)
    st = ex.ExecutorState(cfg)
    ex.load_forward_state_plan(cfg, st, x_src, w1, w2)
    ex.execute(s, st, rng=np.random.default_rng(seed))

    y_ret = [st.get("y_ret", r) if plan.send_rows(r)
             else np.zeros((0, d), np.float32) for r in range(ep)]
    y = bridge_combine(bridge, y_ret, top_p)

    want = np.asarray(moe_grouped(params, x, mc, cap=C)).reshape(
        ep, t_loc, d)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-5)

    # and bit-for-bit against the ragged numpy grouped reference
    ref = ex.reference_forward_plan(cfg, x_src, w1, w2)
    for r in range(ep):
        if plan.send_rows(r):
            np.testing.assert_array_equal(st.get("y_ret", r),
                                          ref["y_ret"][r])


def test_bridge_backward_matches_moe_grouped_vjp():
    """Executor weight grads on a bridged plan == jax.vjp(moe_grouped)."""
    import jax
    import jax.numpy as jnp
    ep, t_loc, d, f = 4, 8, 16, 8
    mc, params, x, top_p, top_i = _routed_case(3, ep, t_loc, d, f)
    T = ep * t_loc
    C = capacity(T, mc)
    bridge = plan_from_routing(top_i, mc, ep, capacity=C)
    plan = bridge.plan
    cfg = ScheduleConfig(ep=ep, e_loc=mc.e_total // ep, rows=0,
                         d_model=d, d_ff=f, plan=plan)

    x_src = bridge_dispatch(bridge, x.reshape(ep, t_loc, d))
    w1 = np.asarray(params["w_in"]).reshape(ep, cfg.e_loc, d, 2 * f)
    w2 = np.asarray(params["w_down"]).reshape(ep, cfg.e_loc, f, d)
    fwd = ex.reference_forward_plan(cfg, x_src, w1, w2)

    # Token-space cotangent; chain through the (fixed) combine weights to
    # get the per-row cotangent entering the schedulable fragment.
    g_y = np.asarray(jax.random.normal(jax.random.PRNGKey(9),
                                       (ep, t_loc, d)), dtype=np.float32)
    dy = [np.zeros((plan.send_rows(s), d), np.float32) for s in range(ep)]
    for s_rank in range(ep):
        for t in range(t_loc):
            for j in range(mc.top_k):
                row = bridge.send_row[s_rank, t, j]
                if row >= 0:
                    dy[s_rank][row] += top_p[s_rank * t_loc + t, j] \
                        * g_y[s_rank, t]

    sb = compile_schedule(build_moe_ffn_backward(cfg), ratr=True,
                          gmm_interleave=True)
    st = ex.ExecutorState(cfg)
    ex.load_backward_state_plan(cfg, st, fwd, w1, w2, dy)
    ex.execute(sb, st, rng=np.random.default_rng(2))

    def f_params(w_in, w_down):
        return moe_grouped({**params, "w_in": w_in, "w_down": w_down},
                           jnp.asarray(x), mc, cap=C)

    _, vjp = jax.vjp(f_params, params["w_in"], params["w_down"])
    dw_in, dw_down = vjp(jnp.asarray(g_y.reshape(1, T, d)))
    dw_in = np.asarray(dw_in).reshape(ep, cfg.e_loc, d, 2 * f)
    dw_down = np.asarray(dw_down).reshape(ep, cfg.e_loc, f, d)
    for r in range(ep):
        got1 = (st.get("dW1", r) if plan.recv_rows(r)
                else np.zeros_like(dw_in[r]))
        got2 = (st.get("dW2", r) if plan.recv_rows(r)
                else np.zeros_like(dw_down[r]))
        np.testing.assert_allclose(got1, dw_in[r], rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(got2, dw_down[r], rtol=1e-3, atol=1e-4)


def test_bridge_dropless_counts():
    """Dropless bridge conserves every (token, choice) pair."""
    mc, params, x, top_p, top_i = _routed_case(5)
    bridge = plan_from_routing(top_i, mc, 4, capacity=None)
    assert bridge.plan.total_rows == top_i.size
    assert (bridge.send_row >= 0).all()


def test_ep_pair_capacity_plan():
    """parallel.ep.plan_from_dispatch mirrors _dispatch_buffers' slots."""
    from repro.parallel.ep import plan_from_dispatch
    mc, params, x, top_p, top_i = _routed_case(7)
    ep, t_loc = 4, 8
    ti = top_i.reshape(ep, t_loc, mc.top_k)
    C = 3
    plan = plan_from_dispatch(ti, mc, ep, C)
    for s_rank in range(ep):
        hist = np.bincount(ti[s_rank].reshape(-1), minlength=mc.e_total)
        want = np.minimum(hist, C).reshape(ep, mc.e_total // ep)
        got = np.array([[plan.count(s_rank, d_, e_)
                         for e_ in range(mc.e_total // ep)]
                        for d_ in range(ep)])
        np.testing.assert_array_equal(got, want)
