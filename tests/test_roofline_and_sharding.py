"""Unit tests: HLO collective parser, roofline math, sharding rules."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.hardware import V5E
from repro.parallel.roofline import (Roofline, _shape_bytes,
                                     parse_collectives)

HLO = """
ENTRY %main {
  %ag = bf16[16,4096,2048]{2,1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[1024,512]{1,0} all-reduce(%y), to_apply=%add
  %rs = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) reduce-scatter(%a, %b)
  %a2a = bf16[4,64,64]{2,1,0} all-to-all(%c), dimensions={0}
  %cps = bf16[2,256]{1,0} collective-permute-start(%d)
  %cpd = bf16[2,256]{1,0} collective-permute-done(%cps)
  %not = bf16[9,9]{1,0} add(%e, %f)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096,2048]") == 16 * 4096 * 2048 * 2
    assert _shape_bytes("f32[1024,512]") == 1024 * 512 * 4
    assert _shape_bytes("(bf16[8,128], bf16[8,128])") == 2 * 8 * 128 * 2


def test_parse_collectives():
    st = parse_collectives(HLO)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "all-to-all": 1,
                         "collective-permute": 1}
    expect = (16 * 4096 * 2048 * 2 + 1024 * 512 * 4 + 2 * 8 * 128 * 2
              + 4 * 64 * 64 * 2 + 2 * 256 * 2)
    assert st.total_bytes == expect
    # -done must not double count; non-collectives ignored.


def test_roofline_terms_and_bottleneck():
    rf = Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                  flops_per_device=197e12, bytes_per_device=819e9 * 2,
                  collective_bytes=50e9 * 0.5,
                  model_flops_global=197e12 * 256 * 0.5,
                  arg_bytes=0, temp_bytes=0, coll_counts={})
    assert abs(rf.t_compute - 1.0) < 1e-9
    assert abs(rf.t_memory - 2.0) < 1e-9
    assert abs(rf.t_collective - 0.5) < 1e-9
    assert rf.bottleneck == "memory"
    assert abs(rf.roofline_frac - 0.25) < 1e-9  # useful 0.5s / bound 2.0s


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 4}


def _rules(mode="tp_sp", arch="olmo-1b"):
    from repro.parallel.sharding import ShardingRules
    return ShardingRules(get_config(arch), _FakeMesh(), mode=mode)


def test_param_specs_tp_sp():
    r = _rules()

    class K:
        key = "w_in"
    # mlp w_in [d, 2f]: output dim over model
    assert r.param_spec((K(),), (2048, 16384)) == P(None, "model")


def test_param_specs_zero1_replicated():
    r = _rules(mode="zero1")

    class K:  # fake path key
        key = "wq"
    assert r.param_spec((K(),), (2048, 2048)) == P(None, None)


def test_opt_state_sharded_in_zero1():
    r = _rules(mode="zero1")

    class K:
        key = "w_in"
    spec = r.opt_state_spec((K(),), (2048, 16384))
    flat = [a for part in spec if part
            for a in (part if isinstance(part, tuple) else (part,))]
    assert flat, "opt state must be sharded in zero1"


def test_ep_dp_experts_sharded():
    r = _rules(mode="ep_dp", arch="granite-moe-3b-a800m")

    class K:
        key = "w_in"
    assert r.param_spec((K(),), (48, 1536, 1024)) == P("model", None, None)


def test_batch_axes_by_mode():
    r1 = _rules(mode="tp_sp")
    assert r1._batch_axis(256) == ("data",)
    r2 = _rules(mode="zero1")
    assert r2._batch_axis(256) == ("data", "model")
    assert r2._batch_axis(1) is None


def test_divisibility_fallback():
    r = _rules()
    # dim not divisible by model axis (4) → replicated
    assert r.param_spec((), (2048, 1023)) == P(None, None)
