"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gmm import gmm
from repro.kernels.gmm_swiglu import gmm_swiglu
from repro.kernels.swiglu_add import (swiglu_add_interleaved,
                                      swiglu_add_serial)

SHAPES_GMM = [
    (1, 128, 64, 128),
    (4, 256, 192, 256),
    (3, 64, 96, 160),      # non-128-multiple N
    (8, 512, 128, 64),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("E,C,K,N", SHAPES_GMM)
def test_gmm_matches_oracle(E, C, K, N, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (E, C, K), dtype)
    w = jax.random.normal(k2, (E, K, N), dtype) * 0.1
    got = gmm(x, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref.gmm_ref(x, w), np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("E,C,K,F", [(2, 128, 64, 128), (4, 192, 96, 64),
                                     (1, 256, 128, 384)])
def test_gmm_swiglu_fused(E, C, K, F, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (E, C, K), dtype)
    w = jax.random.normal(k2, (E, K, 2 * F), dtype) * 0.1
    got = gmm_swiglu(x, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref.gmm_swiglu_ref(x, w), np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("M", [256, 1024, 4096])
@pytest.mark.parametrize("mode", ["serial", "interleaved"])
def test_swiglu_add_modes(M, mode, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    h = jax.random.normal(k1, (M, 4096), dtype)
    y = jax.random.normal(k2, (M, 2048), dtype)
    fn = swiglu_add_serial if mode == "serial" else swiglu_add_interleaved
    got = fn(h, y, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref.swiglu_add_ref(h, y), np.float32), **_tol(dtype))


def test_moe_expert_ffn_drop_in():
    """The fused-kernel path is a drop-in gmm_fn for moe_grouped."""
    from repro.models.moe import MoEConfig, init_moe, moe_grouped
    mc = MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(3), 64, mc)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 64), jnp.float32)

    def gmm_fn(disp, w_in, w_down, act):
        return ops.moe_expert_ffn(disp, w_in.astype(disp.dtype),
                                  w_down.astype(disp.dtype), act)

    base = moe_grouped(params, x, mc, cap=64)
    fused = moe_grouped(params, x, mc, cap=64, gmm_fn=gmm_fn)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_vmem_budget_guard():
    x = jnp.zeros((1, 128, 60000), jnp.float32)
    w = jnp.zeros((1, 60000, 512), jnp.float32)
    with pytest.raises(AssertionError, match="VMEM"):
        gmm(x, w, bm=128, bn=512, interpret=True)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("E,C,K,F", [(2, 128, 64, 128), (3, 64, 96, 64)])
def test_gmm_swiglu_custom_vjp(E, C, K, F, dtype):
    """Pallas backward kernels == jax.vjp of the jnp oracle."""
    from repro.kernels.gmm_swiglu_bwd import gmm_swiglu_trainable
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(k1, (E, C, K), dtype)
    w = jax.random.normal(k2, (E, K, 2 * F), dtype) * 0.1
    dout = jax.random.normal(k3, (E, C, F), dtype)

    out, vjp = jax.vjp(lambda x, w: gmm_swiglu_trainable(x, w, True), x, w)
    dx, dw = vjp(dout)
    out_ref, vjp_ref = jax.vjp(ref.gmm_swiglu_ref, x, w)
    dx_ref, dw_ref = vjp_ref(dout)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=1e-4, atol=1e-4)


def test_gmm_swiglu_vjp_bf16_vs_fp32_oracle():
    """bf16 kernel grads vs the fp32 oracle: the Pallas backward must be at
    least as accurate as the all-bf16 jnp path (its accumulators are f32)."""
    from repro.kernels.gmm_swiglu_bwd import gmm_swiglu_trainable
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(6), 3)
    x = jax.random.normal(k1, (2, 64, 32), jnp.bfloat16)
    w = jax.random.normal(k2, (2, 32, 128), jnp.bfloat16) * 0.1
    dout = jax.random.normal(k3, (2, 64, 64), jnp.bfloat16)
    _, vjp = jax.vjp(lambda x, w: gmm_swiglu_trainable(x, w, True), x, w)
    dx, dw = vjp(dout)
    # fp32 oracle on the same (bf16-rounded) values
    _, vjp32 = jax.vjp(ref.gmm_swiglu_ref, x.astype(jnp.float32),
                       w.astype(jnp.float32))
    dx32, dw32 = vjp32(dout.astype(jnp.float32))
    _, vjp_bf = jax.vjp(ref.gmm_swiglu_ref, x, w)
    dx_bf, dw_bf = vjp_bf(dout)

    def err(a, b):
        return float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))

    assert err(dx, dx32) <= err(dx_bf, dx32) + 0.05
    assert err(dw, dw32) <= err(dw_bf, dw32) + 0.05
    np.testing.assert_allclose(np.asarray(dx, np.float32),
                               np.asarray(dx32), rtol=5e-2, atol=5e-2)
