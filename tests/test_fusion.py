"""Cross-layer schedule fusion: multi-fragment taskflow legality and parity.

The fusion contract (``core/fusion.py``): stitching K per-layer schedules
into one ``FusedSchedule`` must (1) stay acyclic and deadlock-free for *any*
pair of real plans — proved by ``validate_schedule`` plus an event-driven
simulation per example, (2) execute bit-identically to sequential per-layer
execution with the boundary remap applied on the host between layers, fwd
and bwd, and (3) round-trip through the SSC blob with fragments intact.
The property test drives (1)+(2) over random skewed/sparse/hotspot plan
pairs; deterministic tests pin the SSC/cache surface, the per-fragment cost
diagnostics, the simulator's phase breakdown, and the fused dropless block.
"""

import numpy as np
import pytest

from repro.core import executor as ex
from repro.core import fusion as fu
from repro.core.costmodel import CostModel
from repro.core.odg import ScheduleConfig
from repro.core.routing import hotspot_plan, random_plan, skewed_plan
from repro.core.scheduler import validate_schedule
from repro.core.simulator import simulate_unified
from repro.core.ssc import SSCCache, schedule_to_ssc, ssc_to_schedule

from tests._proptest import given, settings, st

EP = 3
D = 8


def _cfg(plan):
    return ScheduleConfig(ep=plan.ep, e_loc=plan.e_loc, rows=0,
                          d_model=D, d_ff=4, plan=plan)


def _plan_of(kind, seed):
    rng = np.random.default_rng(seed)
    if kind == "skewed":
        return skewed_plan(EP, 2, 6, 1.0 + (seed % 3) * 0.5)
    if kind == "sparse":
        return random_plan(EP, 2, 7, rng, p_zero=0.5)
    return hotspot_plan(EP, 2, 4, background=seed % 3)


def _matrix_boundary(M, transpose=False):
    """Per-rank boundary fns applying a fixed matrix remap (or its
    transpose) — the test stand-in for the combine∘dispatch token remap."""
    def make(r):
        A = M[r].T if transpose else M[r]

        def fn(data, lo, hi, A=A):
            if data is None:
                data = np.zeros((A.shape[1], D), np.float32)
            return (A @ data)[lo:hi]
        return fn
    return {(0, r): make(r) for r in M}


KINDS = ("skewed", "sparse", "hotspot")


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(KINDS), st.sampled_from(KINDS),
       st.integers(min_value=0, max_value=10_000))
def test_fused_pair_acyclic_deadlock_free_bit_identical(kind0, kind1, seed):
    plan0, plan1 = _plan_of(kind0, seed), _plan_of(kind1, seed + 1)
    cfg0, cfg1 = _cfg(plan0), _cfg(plan1)
    rng = np.random.default_rng(seed)
    M = {r: rng.standard_normal(
            (plan1.send_rows(r), plan0.send_rows(r))).astype(np.float32)
         for r in range(EP)}

    # ---- forward: legality + simulation + bit-exact execution ----------
    fs = fu.compile_fused([cfg0, cfg1], "forward", pipeline=("ratr",))
    validate_schedule(fs)               # acyclic, single-trigger, complete
    res = simulate_unified(fs)          # deadlock-free: every task retires
    assert res.makespan_us > 0
    assert set(res.fragment_makespan_us) == {0, 1}

    x_src, w10, w20 = ex.make_inputs_plan(cfg0, seed % 97)
    _, w11, w21 = ex.make_inputs_plan(cfg1, (seed + 13) % 97)
    ref0 = ex.reference_forward_plan(cfg0, x_src, w10, w20)
    x_src1 = [M[r] @ ref0["y_ret"][r] for r in range(EP)]
    ref1 = ex.reference_forward_plan(cfg1, x_src1, w11, w21)

    stf = ex.ExecutorState(cfg0, fragment_cfgs=[cfg0, cfg1])
    fu.load_fused_forward_state(fs, [cfg0, cfg1], stf, x_src,
                                [w10, w11], [w20, w21])
    stf.boundary_fns = _matrix_boundary(M)
    ex.execute(fs, stf, rng=np.random.default_rng(seed))
    for r in range(EP):
        if plan0.send_rows(r):
            np.testing.assert_array_equal(stf.get("y_ret#L0", r),
                                          ref0["y_ret"][r])
        if plan1.send_rows(r):
            np.testing.assert_array_equal(stf.get("y_ret#L1", r),
                                          ref1["y_ret"][r])

    # ---- backward: reversed execution order, transposed boundary -------
    fb = fu.compile_fused([cfg0, cfg1], "backward",
                          pipeline=("ratr", "gmm_interleave"))
    validate_schedule(fb)
    resb = simulate_unified(fb)
    assert set(resb.fragment_makespan_us) == {0, 1}
    assert [f.label for f in fb.fragments] == ["L1", "L0"]

    dy1 = [rng.standard_normal(ref1["y_ret"][r].shape).astype(np.float32)
           for r in range(EP)]
    dx1, dw11_ref, dw21_ref = ex.reference_backward_plan(
        cfg1, ref1, w11, w21, dy1)
    dy0 = [M[r].T @ dx1[r] for r in range(EP)]
    dx0, dw10_ref, dw20_ref = ex.reference_backward_plan(
        cfg0, ref0, w10, w20, dy0)

    stb = ex.ExecutorState(cfg1, fragment_cfgs=[cfg1, cfg0])
    fu.load_fused_backward_state(fb, [cfg1, cfg0], stb, dy1,
                                 [ref1, ref0], [w11, w10], [w21, w20])
    stb.boundary_fns = _matrix_boundary(M, transpose=True)
    ex.execute(fb, stb, rng=np.random.default_rng(seed + 1))
    for r in range(EP):
        if plan1.send_rows(r):
            np.testing.assert_array_equal(stb.get("dx_ret#L1", r), dx1[r])
        if plan0.send_rows(r):
            np.testing.assert_array_equal(stb.get("dx_ret#L0", r), dx0[r])
        if plan0.recv_rows(r):
            np.testing.assert_array_equal(stb.get("dW1#L0", r), dw10_ref[r])
            np.testing.assert_array_equal(stb.get("dW2#L0", r), dw20_ref[r])
        if plan1.recv_rows(r):
            np.testing.assert_array_equal(stb.get("dW1#L1", r), dw11_ref[r])
            np.testing.assert_array_equal(stb.get("dW2#L1", r), dw21_ref[r])


def test_identity_boundary_fallback():
    """With equal plans and no boundary_fns, the executor's identity
    fallback slices the upstream buffer — fused == chained layers."""
    plan = skewed_plan(EP, 2, 6, 1.5)
    cfg = _cfg(plan)
    fs = fu.compile_fused([cfg, cfg], "forward")
    x_src, w1, w2 = ex.make_inputs_plan(cfg, 3)
    ref0 = ex.reference_forward_plan(cfg, x_src, w1, w2)
    ref1 = ex.reference_forward_plan(cfg, ref0["y_ret"], w1, w2)
    stf = ex.ExecutorState(cfg, fragment_cfgs=[cfg, cfg])
    fu.load_fused_forward_state(fs, [cfg, cfg], stf, x_src,
                                [w1, w1], [w2, w2])
    ex.execute(fs, stf, rng=np.random.default_rng(0))
    for r in range(EP):
        if plan.send_rows(r):
            np.testing.assert_array_equal(stf.get("y_ret#L1", r),
                                          ref1["y_ret"][r])


def test_boundary_tiles_cover_send_layout_in_whole_cells():
    plan0 = hotspot_plan(EP, 2, 4, background=1)
    plan1 = skewed_plan(EP, 2, 6, 2.0)
    fs = fu.compile_fused([_cfg(plan0), _cfg(plan1)], "forward")
    frag1 = fs.fragments[1]
    assert frag1.boundary_tids
    by_rank = {}
    for tid in frag1.boundary_tids:
        td = fs.tasks[tid]
        assert td.task_type == "LayerBoundary"
        assert td.meta == {"fragment": 1, "boundary": 0,
                           "comm_kind": "boundary"}
        by_rank.setdefault(td.rank, []).append(
            (td.outputs[0].lo, td.outputs[0].hi))
    for r, spans in by_rank.items():
        spans.sort()
        assert len(spans) <= fu.DEFAULT_BOUNDARY_SPLIT
        assert spans[0][0] == 0 and spans[-1][1] == plan1.send_rows(r)
        for (a, b), (c, _) in zip(spans, spans[1:]):
            assert b == c                      # contiguous, gap-free
        # whole-cell grouping: every tile edge is a cell edge
        edges = {0}
        off = 0
        for (_, _, cnt) in plan1.send_cells(r):
            off += cnt
            edges.add(off)
        assert all(lo in edges and hi in edges for lo, hi in spans)


def test_fused_ssc_roundtrip_and_cache_info():
    plan0 = skewed_plan(EP, 2, 6, 1.5)
    plan1 = hotspot_plan(EP, 2, 4)
    cfg0, cfg1 = _cfg(plan0), _cfg(plan1)
    cache = SSCCache(max_entries=8)
    fs = cache.get_or_compile_fused([cfg0, cfg1], "forward",
                                    pipeline=("ratr",))
    assert isinstance(fs, fu.FusedSchedule)
    assert [f.label for f in fs.fragments] == ["L0", "L1"]
    assert (cache.hits, cache.misses) == (0, 1)
    # blob round-trip keeps the fragment table
    back = ssc_to_schedule(schedule_to_ssc(fs))
    assert isinstance(back, fu.FusedSchedule)
    assert back.fragments == fs.fragments
    assert len(back.tasks) == len(fs.tasks)
    # repeat fetch hits; per-entry info reports bytes and fragment count
    cache.get_or_compile_fused([cfg0, cfg1], "forward", pipeline=("ratr",))
    assert (cache.hits, cache.misses) == (1, 1)
    info = cache.info()
    assert len(info["per_entry"]) == 1
    assert info["per_entry"][0]["fragments"] == 2
    assert info["per_entry"][0]["bytes"] > 0
    # an unfused entry coexists and reports fragments=1
    cache.get_or_compile(cfg0, "forward", pipeline=("ratr",))
    assert sorted(e["fragments"] for e in cache.info()["per_entry"]) == [1, 2]


def test_fragment_critical_ranks_are_per_fragment():
    plan_hot = hotspot_plan(EP, 2, 4)          # all cube work on rank 0
    plan_flat = skewed_plan(EP, 2, 6, 0.0)     # balanced
    fs = fu.compile_fused([_cfg(plan_hot), _cfg(plan_flat)], "forward")
    crits = CostModel(l2=False).fragment_critical_ranks(fs)
    assert set(crits) == {0, 1}
    ratio_hot, crit_hot = crits[0]
    ratio_flat, _ = crits[1]
    assert crit_hot == 0 and ratio_hot > 1.5
    assert ratio_flat == pytest.approx(1.0)


def test_simulator_phase_breakdown():
    plan = skewed_plan(EP, 2, 6, 1.0)
    cfg = _cfg(plan)
    # single fragment: no boundary phase, one fragment span == makespan
    s = fu.compile_fused([cfg], "forward")
    r1 = simulate_unified(s)
    assert "boundary" not in r1.phase_us
    assert set(r1.fragment_makespan_us) == {0}
    assert 0 < r1.dispatch_to_combine_us <= r1.makespan_us + 1e-9
    # two fragments: boundary phase shows up, spans overlap-or-abut
    fs = fu.compile_fused([cfg, cfg], "forward")
    r2 = simulate_unified(fs)
    assert r2.phase_us["boundary"] > 0
    assert {"dispatch", "combine"} <= set(r2.phase_us)
    assert 0 < r2.dispatch_to_combine_us <= r2.makespan_us + 1e-9
    assert set(r2.fragment_makespan_us) == {0, 1}


def test_fused_dropless_block_matches_sequential_twin():
    """One fused two-layer dropless step == two sequential per-layer steps,
    bit for bit, forward and backward (jax.grad through the custom vjp)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.dropless import DroplessConfig, FusedDroplessMoE
    from repro.models.moe import MoEConfig, init_moe

    mc = MoEConfig(n_experts=6, top_k=2, d_expert=8, capacity_factor=8.0)
    d = 16
    p0 = init_moe(jax.random.PRNGKey(0), d, mc)
    p1 = init_moe(jax.random.PRNGKey(7), d, mc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d), jnp.float32)

    dc = DroplessConfig(ep=3, bucket_rows=4)
    fused = FusedDroplessMoE(dc, cache=SSCCache(max_entries=8), fuse=True)
    seq = FusedDroplessMoE(dc, cache=SSCCache(max_entries=8), fuse=False)

    yf = fused.impl([p0, p1], x, mc)
    ys = seq.impl([p0, p1], x, mc)
    assert np.isfinite(np.asarray(yf)).all() and np.asarray(yf).any()
    np.testing.assert_array_equal(np.asarray(yf), np.asarray(ys))

    gf = jax.grad(lambda ps: jnp.sum(fused.impl(ps, x, mc) ** 2))((p0, p1))
    gs = jax.grad(lambda ps: jnp.sum(seq.impl(ps, x, mc) ** 2))((p0, p1))
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the fused handle compiled multi-fragment blobs, the twin per-layer ones
    assert all(e["fragments"] == 2 for e in fused.cache.info()["per_entry"])
    assert all(e["fragments"] == 1 for e in seq.cache.info()["per_entry"])


def test_fused_dropless_block_k3_matches_sequential_twin():
    """K=3 fused dropless block == three sequential per-layer steps, bit
    for bit, forward and backward (jax.grad through the custom vjp)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.dropless import DroplessConfig, FusedDroplessMoE
    from repro.models.moe import MoEConfig, init_moe

    mc = MoEConfig(n_experts=6, top_k=2, d_expert=8, capacity_factor=8.0)
    d = 16
    ps = [init_moe(jax.random.PRNGKey(s), d, mc) for s in (0, 7, 11)]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d), jnp.float32)

    dc = DroplessConfig(ep=3, bucket_rows=4)
    fused = FusedDroplessMoE(dc, cache=SSCCache(max_entries=8), fuse=True)
    seq = FusedDroplessMoE(dc, cache=SSCCache(max_entries=8), fuse=False)

    yf = fused.impl(ps, x, mc)
    ys = seq.impl(ps, x, mc)
    assert np.isfinite(np.asarray(yf)).all() and np.asarray(yf).any()
    np.testing.assert_array_equal(np.asarray(yf), np.asarray(ys))

    gf = jax.grad(lambda q: jnp.sum(fused.impl(q, x, mc) ** 2))(tuple(ps))
    gs = jax.grad(lambda q: jnp.sum(seq.impl(q, x, mc) ** 2))(tuple(ps))
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # fused blobs hold three fragments, sequential twins one each
    assert all(e["fragments"] == 3 for e in fused.cache.info()["per_entry"])
    assert all(e["fragments"] == 1 for e in seq.cache.info()["per_entry"])


def test_fused_dropless_auto_matches_forced_choice():
    """fuse="auto" routes through select_fused and stays bit-identical to
    whichever forced path the selector predicts cheaper."""
    import jax
    import jax.numpy as jnp
    from repro.launch.dropless import DroplessConfig, FusedDroplessMoE
    from repro.models.moe import MoEConfig, init_moe

    mc = MoEConfig(n_experts=6, top_k=2, d_expert=8, capacity_factor=8.0)
    d = 16
    p0 = init_moe(jax.random.PRNGKey(0), d, mc)
    p1 = init_moe(jax.random.PRNGKey(7), d, mc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d), jnp.float32)

    dc = DroplessConfig(ep=3, bucket_rows=4)
    auto = FusedDroplessMoE(dc, cache=SSCCache(max_entries=8), fuse="auto")
    fused = FusedDroplessMoE(dc, cache=SSCCache(max_entries=8), fuse=True)
    seq = FusedDroplessMoE(dc, cache=SSCCache(max_entries=8), fuse=False)

    ya = np.asarray(auto.impl([p0, p1], x, mc))
    yf = np.asarray(fused.impl([p0, p1], x, mc))
    ys = np.asarray(seq.impl([p0, p1], x, mc))
    np.testing.assert_array_equal(yf, ys)     # twins agree regardless
    np.testing.assert_array_equal(ya, yf)     # auto == both, trivially

    ga = jax.grad(lambda q: jnp.sum(auto.impl(q, x, mc) ** 2))((p0, p1))
    gf = jax.grad(lambda q: jnp.sum(fused.impl(q, x, mc) ** 2))((p0, p1))
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gf)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    with pytest.raises(ValueError, match="auto"):
        FusedDroplessMoE(dc, fuse="sometimes")
