"""One timing formula for simulator and compile-time passes (CostModel)."""

import dataclasses

import pytest

from repro.core.costmodel import CostModel
from repro.core.hardware import AscendA3
from repro.core.odg import (CTQ, VTQ, ScheduleConfig, build_moe_ffn_forward)
from repro.core.routing import skewed_plan
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_unified
from repro.core.tasks import TaskDescriptor


def _comm_td(nbytes, src, dst):
    return TaskDescriptor(task_type="put_mem_signal", queue_type=VTQ,
                          comm_bytes=nbytes, src_rank=src, dst_rank=dst)


def test_comm_cost_local_vs_remote():
    cm = CostModel()
    hw = cm.hw
    local = cm.task_us(_comm_td(1 << 20, 0, 0))
    remote = cm.task_us(_comm_td(1 << 20, 0, 1))
    assert local == pytest.approx((1 << 20) / (hw.hbm_gbps * 1e3))
    assert remote == pytest.approx(
        hw.hop_latency_us + (1 << 20) / (hw.link_gbps * 1e3))
    assert local < remote
    # The latency floor: a tiny remote message is not free, a local copy
    # pays no hop latency.
    assert cm.task_us(_comm_td(64, 0, 1)) >= hw.hop_latency_us
    assert cm.task_us(_comm_td(64, 0, 0)) < hw.hop_latency_us


def test_cube_cost_l2_residency_band():
    cm = CostModel()
    td = TaskDescriptor(task_type="GMM", queue_type=CTQ, flops=1e9)
    cold = cm.task_us(td, 0.0)
    hot = cm.task_us(td, 1.0)
    hw = cm.hw
    assert cold == pytest.approx(
        1e9 / (hw.aic_tflops_bf16 * 1e12 * hw.aic_eff_hbm) * 1e6)
    assert hot == pytest.approx(
        1e9 / (hw.aic_tflops_bf16 * 1e12 * hw.aic_eff_l2) * 1e6)
    assert hot < cold


def test_vector_cost_and_l2_off():
    cm = CostModel()
    td = TaskDescriptor(task_type="SwiGLU", queue_type=VTQ,
                        read_bytes=4e6, write_bytes=2e6)
    hw = cm.hw
    assert cm.task_us(td, 0.0) == pytest.approx(
        (4e6 + 2e6) / (hw.aiv_gbps * 1e3))
    assert cm.task_us(td, 1.0) < cm.task_us(td, 0.0)
    # l2=False ignores the supplied hit fraction entirely.
    off = CostModel(l2=False)
    assert off.task_us(td, 1.0) == off.task_us(td, 0.0)


def test_simulator_busy_time_equals_cost_model_sum():
    """With L2 effects neutralized the simulator's busy accounting must equal
    the cost model's task sum exactly — proof there is a single timing
    formula, not two drifting copies."""
    hw = dataclasses.replace(AscendA3(), aic_eff_l2=AscendA3().aic_eff_hbm,
                             l2_read_x_hbm=1.0)
    cfg = ScheduleConfig(ep=4, e_loc=2, rows=8, d_model=64, d_ff=32,
                         gmm_m_split=2)
    s = compile_schedule(build_moe_ffn_forward(cfg), pipeline=["ratr"])
    res = simulate_unified(s, hw)
    cm = CostModel(hw=hw, l2=False)
    want = {}
    for td in s.tasks:
        key = (td.rank, td.queue_type)
        want[key] = want.get(key, 0.0) + cm.task_us(td)
    assert set(res.busy_us) == set(want)
    for key in want:
        assert res.busy_us[key] == pytest.approx(want[key], rel=1e-9)


def test_compile_time_critical_rank_matches_simulator():
    plan = skewed_plan(4, 4, 64, 1.5)
    cfg = ScheduleConfig(ep=4, e_loc=4, rows=0, d_model=256, d_ff=128,
                         plan=plan)
    s = compile_schedule(build_moe_ffn_forward(cfg), pipeline=["ratr"])
    ratio, crit = CostModel(l2=False).critical_rank(s)
    res = simulate_unified(s)
    assert crit == res.critical_rank
    assert ratio == pytest.approx(res.straggler_ratio, rel=0.15)


def test_rank_cube_us_counts_starved_ranks():
    """Ranks the plan starves of work still appear (and drag the mean)."""
    import numpy as np
    from repro.core.routing import RoutingPlan
    counts = np.zeros((3, 3, 2), dtype=np.int64)
    counts[:, 0, 0] = 5                  # ranks 1,2 receive nothing
    plan = RoutingPlan.from_counts(counts)
    cfg = ScheduleConfig(ep=3, e_loc=2, rows=0, d_model=16, d_ff=8,
                         plan=plan)
    s = compile_schedule(build_moe_ffn_forward(cfg))
    loads = CostModel(l2=False).rank_cube_us(s)
    assert set(loads) == {0, 1, 2}
    assert loads[1] == 0.0 and loads[2] == 0.0
    ratio, crit = CostModel(l2=False).critical_rank(s)
    assert crit == 0 and ratio == pytest.approx(3.0)
