"""MoE layer tests + multi-device EP equivalence (subprocess: the EP test
needs forced host devices, which must not leak into this process)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (MoEConfig, capacity, init_moe, make_dispatch,
                              moe_dense_ref, moe_grouped, router_topk)

KEY = jax.random.PRNGKey(0)
MC = MoEConfig(n_experts=6, top_k=2, d_expert=16, capacity_factor=8.0,
               n_padding_experts=2)


def test_router_masks_padding_and_normalizes():
    params = init_moe(KEY, 32, MC)
    x = jax.random.normal(KEY, (64, 32))
    p, i = router_topk(params["router"], x, MC)
    assert int(i.max()) < MC.n_experts          # padding never selected
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)


def test_grouped_equals_dense_ref():
    params = init_moe(KEY, 32, MC)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    a = moe_dense_ref(params, x, MC, cap=512)
    b = moe_grouped(params, x, MC, cap=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drop_consistency():
    """With a tiny capacity, both paths drop the same tokens."""
    params = init_moe(KEY, 32, MC)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 40, 32))
    a = moe_dense_ref(params, x, MC, cap=4)
    b = moe_grouped(params, x, MC, cap=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_dispatch_slots_unique_per_expert():
    p = jnp.ones((16, 2)) / 2
    i = jnp.stack([jnp.arange(16) % 4, (jnp.arange(16) + 1) % 4], 1)
    w, ii, slot = make_dispatch(p, i, 16, 4, 100)
    pairs = set()
    for t in range(16):
        for k in range(2):
            key = (int(ii[t, k]), int(slot[t, k]))
            assert key not in pairs, "slot collision"
            pairs.add(key)


def test_capacity_rounding():
    mc = MoEConfig(n_experts=8, top_k=2, d_expert=8, capacity_factor=1.0)
    assert capacity(100, mc, ep=4) % 4 == 0


_EP_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.parallel.ep import EPConfig, make_moe_ep
from repro.models.moe import MoEConfig, init_moe, moe_dense_ref

mesh = make_test_mesh(data=2, model=4)
mc = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=8.0)
params = init_moe(jax.random.PRNGKey(0), 32, mc)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)
ref = moe_dense_ref(params, x, mc, cap=1000)
for mode in ("baseline", "hyperparallel"):
    impl = make_moe_ep(mesh, EPConfig(mode=mode, capacity_factor=16.0))
    with jax.set_mesh(mesh):
        y = jax.jit(lambda p, x: impl(p, x, mc))(params, x)
        g = jax.jit(jax.grad(lambda p, x: jnp.sum(impl(p, x, mc)**2)))(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-4)
    gr = jax.grad(lambda p, x: jnp.sum(moe_dense_ref(p, x, mc, cap=1000)**2))(params, x)
    for k in g:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(gr[k]),
                                   rtol=1e-3, atol=1e-3)
print("EP_SUBPROCESS_OK")

# --- Pallas fused kernels inside the EP shard (production TPU path) ------
impl_pl = make_moe_ep(mesh, EPConfig(mode="hyperparallel",
                                     capacity_factor=16.0, use_pallas=True))
with jax.set_mesh(mesh):
    y_pl = jax.jit(lambda p, x: impl_pl(p, x, mc))(params, x)
np.testing.assert_allclose(np.asarray(y_pl), np.asarray(ref),
                           rtol=1e-4, atol=1e-4)
print("PALLAS_EP_OK")

# --- flash-decoding equivalence on a seq-sharded cache -------------------
from repro.parallel.flash_decode import make_flash_decode
B, S, H, K, hd = 4, 32, 4, 2, 16
ks = jax.random.split(jax.random.PRNGKey(7), 5)
q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
kc = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
vc = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
nk = jax.random.normal(ks[3], (B, 1, K, hd), jnp.float32)
nv = jax.random.normal(ks[4], (B, 1, K, hd), jnp.float32)
clen = 17
from repro.models.layers import decode_attention
kc_ref = kc.at[:, clen].set(nk[:, 0])
vc_ref = vc.at[:, clen].set(nv[:, 0])
want = decode_attention(q, kc_ref, vc_ref, jnp.int32(clen + 1))
fd = make_flash_decode(mesh, "model")
with jax.set_mesh(mesh):
    o, kc2, vc2 = jax.jit(lambda *a: fd(*a))(q, kc, vc, nk, nv, clen)
np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref), rtol=1e-6, atol=1e-6)
print("FLASH_DECODE_OK")
"""


def test_ep_modes_multidevice_subprocess():
    if not hasattr(jax, "set_mesh") or not hasattr(jax, "shard_map"):
        pytest.skip("shard_map/set_mesh EP path needs jax >= 0.5")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _EP_SUBPROCESS],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=600)
    assert "EP_SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]
    assert "PALLAS_EP_OK" in out.stdout, out.stderr[-2000:]
    assert "FLASH_DECODE_OK" in out.stdout, out.stderr[-2000:]


def test_load_balance_loss_minimized_at_uniform():
    from repro.models.moe import load_balance_loss
    d, E = 16, 8
    mc2 = MoEConfig(n_experts=E, top_k=2, d_expert=8)
    x = jax.random.normal(KEY, (512, d))
    # collapsed router (all tokens to expert 0) vs near-uniform router
    r_collapsed = jnp.zeros((d, E)).at[:, 0].set(5.0)
    r_uniform = jnp.zeros((d, E))
    aux_c, z_c = load_balance_loss(r_collapsed, x, mc2)
    aux_u, z_u = load_balance_loss(r_uniform, x, mc2)
    assert float(aux_c) > float(aux_u)
    assert abs(float(aux_u) - 1.0) < 0.2      # ≈1 at uniform
    assert float(z_c) > float(z_u) >= 0.0


def test_load_balance_loss_masks_padding():
    from repro.models.moe import load_balance_loss
    mc2 = MoEConfig(n_experts=6, top_k=2, d_expert=8, n_padding_experts=2)
    x = jax.random.normal(KEY, (128, 16))
    r = jax.random.normal(jax.random.PRNGKey(3), (16, mc2.e_total))
    aux, _ = load_balance_loss(r, x, mc2)
    assert np.isfinite(float(aux))
