"""Decode-trace replay harness: trace synthesis, the recorded-trace JSONL
format, per-policy replay metrics, and the bounded-retrace acceptance
(exact plans retrace nearly every batch; laddered plans stay within their
rung budget on stationary traffic)."""

import json

import numpy as np
import pytest

from repro.core.buckets import BucketSpec, fit_ladder
from repro.launch.replay import (PROFILES, exact_plans, load_trace_jsonl,
                                 main as replay_main, replay_trace,
                                 resolve_policies, save_trace_jsonl,
                                 synth_trace)
from repro.models.moe import MoEConfig

EP, E_LOC, T_LOC, K = 4, 2, 24, 2
MC = MoEConfig(n_experts=EP * E_LOC, top_k=K, d_expert=16)


def _trace(profile="uniform", steps=12, seed=0, **kw):
    return synth_trace(profile, steps, ep=EP, e_loc=E_LOC, t_loc=T_LOC,
                       top_k=K, seed=seed, **kw)


def test_synth_trace_shapes_and_determinism():
    for profile in PROFILES:
        tr = _trace(profile)
        assert len(tr) == 12
        for ti in tr:
            assert ti.ndim == 2 and ti.shape[1] == K
            assert ti.shape[0] % EP == 0 and ti.shape[0] >= EP
            assert ti.min() >= 0 and ti.max() < EP * E_LOC
        tr2 = _trace(profile)
        assert all(np.array_equal(a, b) for a, b in zip(tr, tr2))
    # bursty actually varies the batch size; stationary profiles don't
    sizes = {ti.shape[0] for ti in _trace("bursty", steps=24)}
    assert len(sizes) > 1
    assert len({ti.shape[0] for ti in _trace("uniform")}) == 1
    # successive batches are correlated: churn only moves a fraction
    tr = _trace("uniform", churn=0.1)
    frac_changed = np.mean(tr[0] != tr[1])
    assert frac_changed < 0.5
    with pytest.raises(ValueError):
        _trace("lumpy")


def test_trace_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = _trace("bursty")
    save_trace_jsonl(path, tr)
    back = load_trace_jsonl(path)
    assert len(back) == len(tr)
    assert all(np.array_equal(a, b) for a, b in zip(tr, back))
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(ValueError):
        load_trace_jsonl(str(empty))


def test_resolve_policies_fitted_and_named():
    fit = _trace("zipf", seed=1)
    pol = resolve_policies(["exact", "linear:16", "fitted:3", "fitted:3x0"],
                           fit, MC, EP)
    assert pol["exact"].is_exact
    assert pol["linear:16"] == BucketSpec.linear(16)
    assert pol["fitted:3"].policy == "ladder"
    assert 1 <= len(pol["fitted:3"].edges) <= 3
    # explicit split_penalty=0 reproduces the pure padding-minimal fit
    assert pol["fitted:3x0"] == fit_ladder(exact_plans(fit, MC, EP), 3,
                                           split_penalty=0.0)
    with pytest.raises(ValueError):
        resolve_policies(["", " "], fit, MC, EP)


def test_replay_rows_and_bounded_retraces():
    steps = 12
    trace = _trace("uniform", steps=steps)
    fitted = fit_ladder(exact_plans(_trace("uniform", steps=steps, seed=1),
                                    MC, EP), 4, split_penalty=1.0)
    rows = {r["policy"]: r for r in replay_trace(
        trace, MC, EP,
        {"exact": BucketSpec.exact(), "fitted": fitted},
        d_model=32, d_ff=16, simulate=True)}
    for r in rows.values():
        for key in ("hit_rate", "recompile_rate", "pad_ratio",
                    "ep_retraces", "p50_us", "p99_us", "fetch_us_mean"):
            assert key in r, key
        assert r["steps"] == steps
    exact, fit_row = rows["exact"], rows["fitted"]
    # exact plans: nearly every churned batch is a fresh jit trace (ring
    # caps are per-distance maxima, so tiny batches can repeat a cap tuple
    # even when the full plan differs — hence "nearly")
    assert exact["ep_retraces"] >= 0.75 * steps
    assert exact["recompile_rate"] == 1.0
    assert exact["pad_ratio"] == pytest.approx(1.0)
    # bucketed: bounded by the ladder (+1 tolerance for the cold start)
    assert fit_row["ep_retraces"] <= len(fitted.edges) + 1
    assert fit_row["hit_rate"] >= exact["hit_rate"]
    assert fit_row["pad_ratio"] > 1.0
    # simulated latency is inflated by padding, not deflated
    assert fit_row["p50_us"] >= exact["p50_us"]


def test_replay_without_simulator_skips_latency():
    rows = replay_trace(_trace(steps=4), MC, EP,
                        {"linear:8": BucketSpec.linear(8)},
                        d_model=32, d_ff=16, simulate=False)
    assert "p50_us" not in rows[0]


def test_replay_cli_end_to_end(tmp_path):
    trace_path = str(tmp_path / "t.jsonl")
    report_path = str(tmp_path / "r.jsonl")
    rows = replay_main([
        "--profile", "zipf", "--steps", "6", "--ep", "2", "--experts", "4",
        "--t-loc", "16", "--d-model", "32", "--d-ff", "16",
        "--policies", "exact,linear:8,fitted:3", "--no-sim",
        "--trace-out", trace_path, "--report-out", report_path])
    assert {r["policy"] for r in rows} == {"exact", "linear:8", "fitted:3"}
    with open(report_path) as f:
        parsed = [json.loads(line) for line in f if line.strip()]
    assert len(parsed) == 3
    # recorded trace replays identically through --trace-in
    rows2 = replay_main([
        "--trace-in", trace_path, "--ep", "2", "--experts", "4",
        "--d-model", "32", "--d-ff", "16",
        "--policies", "linear:8", "--no-sim"])
    lin = next(r for r in rows if r["policy"] == "linear:8")
    assert rows2[0]["hit_rate"] == lin["hit_rate"]
    assert rows2[0]["pad_ratio"] == pytest.approx(lin["pad_ratio"])


def test_arrival_timestamps_roundtrip_and_backward_compat(tmp_path):
    from repro.launch.replay import synth_arrival_us
    tr = _trace("bursty", steps=10)
    arr = synth_arrival_us(tr, mean_gap_us=100.0, seed=3)
    assert len(arr) == len(tr)
    assert (np.diff(arr) >= 0).all()        # monotone non-decreasing
    np.testing.assert_array_equal(arr, synth_arrival_us(tr,
                                                        mean_gap_us=100.0,
                                                        seed=3))
    path = str(tmp_path / "timed.jsonl")
    save_trace_jsonl(path, tr, arrival_us=arr)
    # legacy loader: plain step list, timestamps transparently ignored
    plain = load_trace_jsonl(path)
    assert all(np.array_equal(a, b) for a, b in zip(tr, plain))
    back, arr2 = load_trace_jsonl(path, with_arrivals=True)
    assert all(np.array_equal(a, b) for a, b in zip(tr, back))
    np.testing.assert_allclose(arr2, arr)
    # legacy file (no t_us): arrivals come back as None
    legacy = str(tmp_path / "legacy.jsonl")
    save_trace_jsonl(legacy, tr)
    back, none_arr = load_trace_jsonl(legacy, with_arrivals=True)
    assert none_arr is None and len(back) == len(tr)
    with pytest.raises(ValueError):
        save_trace_jsonl(path, tr, arrival_us=arr[:-1])


def test_replay_arrivals_feed_response_latency_metrics():
    from repro.launch.replay import synth_arrival_us
    trace = _trace("bursty", steps=8)
    arr = synth_arrival_us(trace, mean_gap_us=5.0, seed=0)
    rows = replay_trace(trace, MC, EP,
                        {"linear:8": BucketSpec.linear(8)},
                        d_model=32, d_ff=16, simulate=True,
                        arrival_us=arr, slo_us=50.0)
    r = rows[0]
    for key in ("p50_resp_us", "p99_resp_us", "slo_miss_rate"):
        assert key in r, key
    # queueing: response time is never below raw step latency
    assert r["p99_resp_us"] >= r["p99_us"]
    assert 0.0 <= r["slo_miss_rate"] <= 1.0
