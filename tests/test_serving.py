"""Continuous-batching serving driver: slot reuse must not perturb outputs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import ContinuousBatcher
from repro.models import model as M


def _isolated_generate(cfg, params, prompt, max_new):
    last, cache = M.prefill(cfg, params,
                            {"tokens": jnp.asarray(prompt[None, :],
                                                   jnp.int32)},
                            max_len=len(prompt) + max_new + 1)
    tok = int(jnp.argmax(last[0]))
    out = [tok]
    t = jnp.asarray([[tok]], jnp.int32)
    for _ in range(max_new - 1):
        lg, cache = M.decode_step(cfg, params, t, cache)
        tok = int(jnp.argmax(lg[0, -1]))
        out.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    return out


def test_continuous_batching_matches_isolated():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              dtype="float32", n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, 12) for i in range(5)}
    max_new = 6

    b = ContinuousBatcher(cfg, params, n_slots=2,
                          max_len=12 + max_new + 1)
    pending = list(prompts)
    finished = []
    while pending or b.active.any():
        while pending and b.admit(pending[0], prompts[pending[0]], max_new):
            pending.pop(0)
        finished += b.step()
    assert sorted(finished) == sorted(prompts)

    for rid, prompt in prompts.items():
        want = _isolated_generate(cfg, params, prompt, max_new)
        assert b.generated[rid] == want, (
            rid, b.generated[rid], want)
