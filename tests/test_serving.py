"""Continuous-batching serving driver: slot reuse must not perturb outputs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import ContinuousBatcher
from repro.models import model as M


def _isolated_generate(cfg, params, prompt, max_new):
    last, cache = M.prefill(cfg, params,
                            {"tokens": jnp.asarray(prompt[None, :],
                                                   jnp.int32)},
                            max_len=len(prompt) + max_new + 1)
    tok = int(jnp.argmax(last[0]))
    out = [tok]
    t = jnp.asarray([[tok]], jnp.int32)
    for _ in range(max_new - 1):
        lg, cache = M.decode_step(cfg, params, t, cache)
        tok = int(jnp.argmax(lg[0, -1]))
        out.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    return out


def test_continuous_batching_matches_isolated():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              dtype="float32", n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, 12) for i in range(5)}
    max_new = 6

    b = ContinuousBatcher(cfg, params, n_slots=2,
                          max_len=12 + max_new + 1)
    pending = list(prompts)
    finished = []
    while pending or b.active.any():
        while pending and b.admit(pending[0], prompts[pending[0]], max_new):
            pending.pop(0)
        finished += b.step()
    assert sorted(finished) == sorted(prompts)

    for rid, prompt in prompts.items():
        want = _isolated_generate(cfg, params, prompt, max_new)
        assert b.generated[rid] == want, (
            rid, b.generated[rid], want)


def test_admit_when_full_and_finish_then_refill():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              dtype="float32", n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = {i: rng.integers(0, cfg.vocab, 10) for i in range(3)}
    b = ContinuousBatcher(cfg, params, n_slots=2, max_len=10 + 3 + 1)

    assert b.admit(0, prompts[0], 2) and b.admit(1, prompts[1], 2)
    assert not b.admit(2, prompts[2], 2)        # full: admit refuses
    assert b.offer(2, prompts[2], 2) == "defer"  # ungated offer defers
    assert b.deferred == 1 and 2 not in b.generated

    done = b.step()                              # both finish together
    assert sorted(done) == [0, 1]
    assert not b.active.any()

    # immediate refill lands in a clean slot: the refilled request decodes
    # exactly like an isolated run (scatter overwrote every cache leaf)
    assert b.admit(2, prompts[2], 3)
    out = []
    while b.active.any():
        out += b.step()
    assert out == [2]
    assert b.generated[2] == _isolated_generate(cfg, params, prompts[2], 3)


def test_scatter_slot_leaf_shape_dispatch():
    # n_slots == prompt cache depth exercises the [L, B, ...] vs [B, ...]
    # collision the batch-1 marker dispatch exists for.
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              dtype="float32", n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(cfg, params, n_slots=2, max_len=16)
    prompt = np.arange(8) % cfg.vocab
    _, cache1 = b._prefill1(b.params,
                            jnp.asarray(prompt[None, :], jnp.int32))
    before = jax.tree.map(lambda a: np.asarray(a).copy(), b.cache)
    b._scatter_slot(1, cache1)

    def check(path_c, c1, before_leaf, after_leaf):
        after = np.asarray(after_leaf)
        b4 = np.asarray(before_leaf)
        c1 = np.asarray(c1)
        if b4.ndim == 0:
            return
        if b4.ndim == c1.ndim + 1:           # per-slot len [L, B]
            np.testing.assert_array_equal(after[:, 0], b4[:, 0])
            np.testing.assert_array_equal(after[:, 1], c1)
        elif c1.ndim >= 2 and c1.shape[1] == 1 \
                and b4.shape[0] == c1.shape[0]:   # stacked [L, B, ...]
            np.testing.assert_array_equal(after[:, 0], b4[:, 0])
            np.testing.assert_array_equal(after[:, 1], c1[:, 0])
        else:                                 # unstacked [B, ...]
            np.testing.assert_array_equal(after[0], b4[0])
            np.testing.assert_array_equal(after[1], c1[0])

    jax.tree.map(lambda b4, c1, af: check(None, c1, b4, af),
                 before, cache1, b.cache)


def test_zero_budget_request_generates_exactly_one_token():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"),
                              dtype="float32", n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 10)
    b = ContinuousBatcher(cfg, params, n_slots=1, max_len=16)
    assert b.admit(7, prompt, 1)
    assert not b.active.any()               # no slot occupied
    assert b.step() == [7]                  # drained as finished
    assert b.generated[7] == _isolated_generate(cfg, params, prompt, 1)
    # max_new=1 admits even when every slot is busy (prefill-only)
    assert b.admit(8, prompt, 2)
    assert b.admit(9, prompt, 1)
    done = []
    while b.active.any() or b.instant_done:
        done += b.step()
    assert sorted(done) == [8, 9]
    assert len(b.generated[9]) == 1
