"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import sys

from repro.core.odg import (ODG, OperatorNode, ScheduleConfig, SplitSpec,
                            VECTOR, build_moe_ffn_backward,
                            build_moe_ffn_forward)
from repro.core.scheduler import compile_schedule

CSV_HEADER = "name,us_per_call,derived"


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}")


def phase_summary(sim) -> str:
    """Space-separated per-phase busy-time breakdown of a ``SimResult``.

    Pairs with the ``dispatch_to_combine_us`` span to show *where* the
    busy time between the first dispatch and the last combine goes
    (comma-free, so it fits a single CSV ``derived`` cell).
    """
    order = ("dispatch", "gmm", "vector", "combine", "boundary")
    parts = [f"{ph}={sim.phase_us[ph]:.1f}us"
             for ph in order if ph in sim.phase_us]
    parts += [f"{ph}={us:.1f}us" for ph, us in sorted(sim.phase_us.items())
              if ph not in order]
    return " ".join(parts)


def paper_module_config(ep: int, *, m_split_mult: int = 4) -> ScheduleConfig:
    """The §5.2 DeepSeek-style MoE-FFN module, per-device effective shapes.

    seq 4096 × microbatch 2 = 8192 tokens/rank, top-8, 8 local experts,
    hidden 7168, expert intermediate 2048 (→1024 per device under TP2).
    """
    e_loc = 8
    rows = 8192 * 8 // (ep * e_loc)
    return ScheduleConfig(ep=ep, e_loc=e_loc, rows=rows, d_model=7168,
                          d_ff=1024, gmm_m_split=ep * m_split_mult)


def opt_pipeline(direction: str) -> list:
    """The paper's §4.5 optimization set as a schedule-pass pipeline."""
    return (["ratr", "gmm_interleave"] if direction == "backward"
            else ["ratr"])


def compiled_pair(ep: int, direction: str):
    cfg = paper_module_config(ep)
    builder = (build_moe_ffn_forward if direction == "forward"
               else build_moe_ffn_backward)
    base = compile_schedule(builder(paper_module_config(ep, m_split_mult=1)))
    opt = compile_schedule(builder(cfg), pipeline=opt_pipeline(direction))
    return base, opt


def build_swiglu_add_odg(M: int, n_tiles: int, width_in: int = 4096,
                         width_out: int = 2048) -> ODG:
    """§6 microbenchmark workload: SwiGLU → Add over [M, width] rows."""
    cfg = ScheduleConfig(ep=1, e_loc=1, rows=M, d_model=width_in // 2,
                         d_ff=width_out, gmm_m_split=n_tiles)
    g = ODG(cfg, "forward")
    h = g.tensor("h@0", M, width_in * 2, external=True)
    y = g.tensor("y@0", M, width_out * 2, external=True)
    mid = g.tensor("g@0", M, width_out * 2)
    out = g.tensor("out@0", M, width_out * 2)

    n_fn = (lambda c, op: n_tiles)
    g.add_op(OperatorNode(
        name="SwiGLU@0", op_type="swiglu", resource=VECTOR, rank=0,
        inputs=[h], outputs=[mid],
        split_spec=SplitSpec(split_inputs=None, split_output_dims=(0,),
                             task_num_fn=n_fn)))
    g.add_op(OperatorNode(
        name="Add@0", op_type="elementwise", resource=VECTOR, rank=0,
        inputs=[mid, y], outputs=[out],
        split_spec=SplitSpec(split_inputs=((0, 0),), split_output_dims=(0,),
                             task_num_fn=n_fn),
        meta={"task_type": "Add"}))
    g.validate_acyclic()
    return g
