"""Figure 9 — SwiGLU+Add under serial vs tile-interleaved execution.

Three artifacts:
1. Simulator latency + L2 hit rate on the taskized workload (reproduces the
   paper's 1.23× at M=32K and the serial-vs-interleaved hit-rate gap).
2. The actual Pallas kernels (serial = two pallas_calls through HBM,
   interleaved = fused tile program) validated against the jnp oracle and
   *timed on this host* — wall numbers are CPU-interpret and only the ratio
   direction is meaningful off-TPU.
3. TPU roofline bytes: the fused kernel saves 2·M·F bytes of HBM traffic.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import AscendA3, V5E
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified
from repro.kernels import ops, ref

from .common import build_swiglu_add_odg, emit

PAPER = {32768: (723.29, 588.38, 0.0520, 0.2544)}  # serial_us, int_us, hits


def run(hw: AscendA3 = AscendA3()) -> None:
    for M in (8192, 16384, 32768):
        n_tiles = M // 128          # fine AIV tiles (pool-width granularity)
        g = build_swiglu_add_odg(M, n_tiles)
        sched = compile_schedule(g)
        ser = simulate_baseline(sched, hw)
        g2 = build_swiglu_add_odg(M, n_tiles)
        inter = simulate_unified(
            compile_schedule(g2, pipeline=["chain_interleave"]), hw)
        derived = (f"interleaved={inter.makespan_us:.1f}us "
                   f"speedup={ser.makespan_us / inter.makespan_us:.2f}x "
                   f"l2_hit_serial={ser.l2_hit_rate:.3f} "
                   f"l2_hit_inter={inter.l2_hit_rate:.3f}")
        if M in PAPER:
            pb, pi, hs, hi = PAPER[M]
            derived += (f" paper:{pb:.0f}->{pi:.0f}us "
                        f"hits {hs:.3f}->{hi:.3f}")
        emit(f"swiglu_add_M{M}_serial_sim", ser.makespan_us, derived)

    # Kernel-level: correctness + HBM-traffic roofline of fused vs serial.
    M, F = 4096, 2048
    h = jax.random.normal(jax.random.PRNGKey(0), (M, 2 * F), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (M, F), jnp.float32)
    want = ref.swiglu_add_ref(h, y)
    for mode in ("serial", "interleaved"):
        got = ops.swiglu_add(h, y, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        jax.block_until_ready(ops.swiglu_add(h, y, mode=mode))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(ops.swiglu_add(h, y, mode=mode))
        us = (time.perf_counter() - t0) / 3 * 1e6
        # TPU v5e HBM-bound roofline: serial round-trips the intermediate.
        dbytes = h.dtype.itemsize
        traffic = (M * 2 * F + M * F + M * F) * dbytes  # read h, read y, write
        if mode == "serial":
            traffic += 2 * M * F * dbytes               # intermediate out+in
        tpu_us = traffic / V5E.hbm_gbps * 1e6
        emit(f"swiglu_add_kernel_{mode}", us,
             f"allclose=ok tpu_roofline={tpu_us:.1f}us")


if __name__ == "__main__":
    run()
