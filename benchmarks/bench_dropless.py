"""Dropless schedule reuse — recompile rate & fetch latency under jitter.

The dropless training step compiles a schedule for each batch's *actual*
routing (``plan_from_routing(capacity=None)``) and fetches it from the
plan-keyed ``SSCCache``. Real traffic jitters batch to batch, so exact plan
keys almost never repeat — every step recompiles. Shape bucketing
(``bucket_rows``: per-cell counts quantize up to a bucket multiple) maps
jittered batches onto stable keys at the cost of zero-padded rows.

This benchmark replays ``STEPS`` independently-sampled batches from three
traffic profiles (uniform, Zipf-skewed, hotspot) through the exact and the
bucketed cache path and reports, per (profile, mode):

* ``us_per_call`` — mean wall time of plan build + forward & backward
  schedule fetch-or-compile (the per-step scheduling cost of the dropless
  path);
* ``recompile_rate`` — fraction of schedule requests that compiled instead
  of hitting the cache (1.0 = every step pays full compilation);
* ``pad_overhead`` — bucketed plan rows / routed rows (the price of
  bucketing, 1.0 for exact plans).

Acceptance: on jittered traffic the bucketed hit rate must beat the exact
hit rate on every profile — asserted at the bottom, so CI catches a
bucketing regression.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.odg import ScheduleConfig
from repro.core.ssc import SSCCache
from repro.models.moe import MoEConfig, plan_from_routing

from .common import emit

EP, E_LOC, T_LOC, TOP_K = 4, 2, 64, 2
D_MODEL, D_FF = 64, 32
STEPS = 24
# Bucket ≳ mean cell count + a few σ of its jitter, so a cell's count
# almost always lands in the same bucket batch-to-batch (16 is below the
# jitter scale here and buys nothing; 32 trades ~2x padded rows for a
# ~0.9 hit rate).
BUCKET = 32
PIPELINE = ["ratr", "gmm_interleave"]

MC = MoEConfig(n_experts=EP * E_LOC, top_k=TOP_K, d_expert=D_FF)


def _profile_probs(name: str) -> np.ndarray:
    e = EP * E_LOC
    if name == "uniform":
        p = np.ones(e)
    elif name == "zipf":
        p = np.arange(1, e + 1, dtype=np.float64) ** -1.2
    elif name == "hotspot":
        p = np.full(e, 0.4 / (e - 1))
        p[0] = 0.6
    else:
        raise ValueError(name)
    return p / p.sum()


def _sample_top_i(rng: np.random.Generator, probs: np.ndarray) -> np.ndarray:
    """[T, k] distinct expert choices per token (Gumbel top-k)."""
    T = EP * T_LOC
    g = rng.gumbel(size=(T, probs.shape[0]))
    pert = np.log(probs)[None, :] + g
    return np.argsort(-pert, axis=1)[:, :TOP_K]


def _replay(profile: str, bucket_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    probs = _profile_probs(profile)
    cache = SSCCache(max_entries=4 * STEPS)
    fetch_s, pad = [], []
    for _ in range(STEPS):
        top_i = _sample_top_i(rng, probs)
        t0 = time.perf_counter()
        bridge = plan_from_routing(top_i, MC, EP, capacity=None,
                                   bucket_rows=bucket_rows)
        cfg = ScheduleConfig(ep=EP, e_loc=E_LOC, rows=0, d_model=D_MODEL,
                             d_ff=D_FF, gmm_split_mode="source_aligned",
                             plan=bridge.plan)
        cache.get_or_compile(cfg, "forward", pipeline=PIPELINE)
        cache.get_or_compile(cfg, "backward", pipeline=PIPELINE)
        fetch_s.append(time.perf_counter() - t0)
        pad.append(bridge.plan.total_rows / top_i.size)
    info = cache.info()
    total = info["hits"] + info["misses"]
    return {
        "us": 1e6 * float(np.mean(fetch_s)),
        "us_max": 1e6 * float(np.max(fetch_s)),
        "recompile_rate": info["misses"] / total,
        "hit_rate": info["hits"] / total,
        "pad_overhead": float(np.mean(pad)),
        "entries": info["entries"],
    }


def run() -> None:
    results = {}
    for profile in ("uniform", "zipf", "hotspot"):
        for mode, bucket in (("exact", 1), ("bucketed", BUCKET)):
            r = _replay(profile, bucket)
            results[(profile, mode)] = r
            emit(f"dropless_{profile}_{mode}", r["us"],
                 f"recompile_rate={r['recompile_rate']:.2f} "
                 f"hit_rate={r['hit_rate']:.2f} "
                 f"pad_overhead={r['pad_overhead']:.2f}x "
                 f"entries={r['entries']} max_fetch={r['us_max']:.0f}us")
    for profile in ("uniform", "zipf", "hotspot"):
        exact = results[(profile, "exact")]
        bucketed = results[(profile, "bucketed")]
        assert bucketed["hit_rate"] > exact["hit_rate"], (
            f"{profile}: bucketing must raise the cache hit rate "
            f"({bucketed['hit_rate']:.2f} vs {exact['hit_rate']:.2f})")


if __name__ == "__main__":
    run()
