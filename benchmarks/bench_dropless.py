"""Dropless schedule reuse — recompile rate & padded rows per bucket policy.

The dropless training step compiles a schedule for each batch's *actual*
routing (``plan_from_routing(capacity=None)``) and fetches it from the
plan-keyed ``SSCCache``. Real traffic churns batch to batch (continuous
batching swaps a fraction of slots per decode/train step), so exact plan
keys almost never repeat — every step recompiles. Shape bucketing
(``repro.core.buckets.BucketSpec``) maps churned batches onto stable keys
at the cost of zero-padded rows, and *which* policy decides the trade:

* ``linear:16`` — the legacy ``bucket_rows`` behaviour. Its rung
  boundaries (16, 32, 48, …) sit wherever the traffic happens to put its
  cell-count mass; a cell distribution straddling a boundary forks the key
  every few steps while every small cell still pays full-bucket padding.
* ``geometric:8`` — power-of-two-style rungs: proportional jitter
  absorption, cheap on cold cells, but its low rungs (8, 16) cut through
  mid-sized cell distributions just like linear's.
* ``fitted`` — a per-profile ladder learned by
  ``repro.core.buckets.fit_ladder`` on a *held-out* trace (different
  seed): edges go to the gaps between observed per-cell count ranges, so
  cells stop hopping rungs, with the rung budget and split-penalty
  controlling the padding/reuse frontier.

This benchmark replays ``STEPS`` churned decode-shaped batches from three
traffic profiles (uniform, Zipf, hotspot — the hotspot sized so the hot
cell straddles linear's 64 boundary, the failure mode fixed ladders cannot
dodge) through each policy's cache path, forward and backward schedules,
and reports ``us_per_call`` (plan build + both fetch-or-compiles),
``recompile_rate`` / ``hit_rate``, and ``pad_overhead`` (bucketed rows /
routed rows).

Acceptance (asserted at the bottom, so CI catches a regression):

* bucketing must beat exact keys' hit rate on every profile (the original
  dropless gate), and
* on every profile the **fitted ladder matches or beats linear:16's hit
  rate at a strictly lower padded-row ratio** — the BucketSpec tentpole's
  headline claim.
"""

from __future__ import annotations

import numpy as np

from repro.core.buckets import BucketSpec, fit_ladder
from repro.launch.replay import exact_plans, replay_trace, synth_trace
from repro.models.moe import MoEConfig

from .common import emit

EP, E_LOC, T_LOC, TOP_K = 4, 2, 72, 2
D_MODEL, D_FF = 64, 32
STEPS = 24
# Slot turnover per step: the fraction of token choices re-routed between
# successive batches (continuous batching keeps the rest decoding).
CHURN = 0.08
PIPELINE = ["ratr", "gmm_interleave"]
# Per-profile fit constants (rung budget, split penalty), chosen where the
# fitted ladder dominates linear:16 on this deterministic traffic — the
# regression gate locks them the way tests/test_autoselect.py locks the
# sweep table.
FIT = {"uniform": (3, 1.0), "zipf": (4, 0.25), "hotspot": (3, 0.5)}

MC = MoEConfig(n_experts=EP * E_LOC, top_k=TOP_K, d_expert=D_FF)


def _trace(profile: str, seed: int):
    return synth_trace(profile, STEPS, ep=EP, e_loc=E_LOC, t_loc=T_LOC,
                       top_k=TOP_K, seed=seed, churn=CHURN)


def _policies(profile: str) -> dict[str, BucketSpec]:
    budget, lam = FIT[profile]
    fitted = fit_ladder(exact_plans(_trace(profile, seed=1), MC, EP),
                        budget, split_penalty=lam)
    return {
        "exact": BucketSpec.exact(),
        "linear16": BucketSpec.linear(16),
        "geometric8": BucketSpec.geometric(8),
        "fitted": fitted,
    }


def run() -> None:
    results: dict[tuple[str, str], dict] = {}
    for profile in ("uniform", "zipf", "hotspot"):
        policies = _policies(profile)
        rows = replay_trace(_trace(profile, seed=0), MC, EP, policies,
                            d_model=D_MODEL, d_ff=D_FF, pipeline=PIPELINE,
                            directions=("forward", "backward"),
                            simulate=False, max_entries=4 * STEPS)
        for r in rows:
            results[(profile, r["policy"])] = r
            emit(f"dropless_{profile}_{r['policy']}", r["fetch_us_mean"],
                 f"recompile_rate={r['recompile_rate']:.2f} "
                 f"hit_rate={r['hit_rate']:.2f} "
                 f"pad_overhead={r['pad_ratio']:.2f}x "
                 f"spec={r['spec']}")

    for profile in ("uniform", "zipf", "hotspot"):
        exact = results[(profile, "exact")]
        lin = results[(profile, "linear16")]
        fitted = results[(profile, "fitted")]
        best_bucketed = max(lin["hit_rate"], fitted["hit_rate"],
                            results[(profile, "geometric8")]["hit_rate"])
        assert best_bucketed > exact["hit_rate"], (
            f"{profile}: bucketing must raise the cache hit rate "
            f"({best_bucketed:.2f} vs {exact['hit_rate']:.2f})")
        assert fitted["hit_rate"] >= lin["hit_rate"] \
            and fitted["pad_ratio"] < lin["pad_ratio"], (
            f"{profile}: fitted ladder must match/beat linear:16's hit "
            f"rate at strictly lower padding (fitted "
            f"hit={fitted['hit_rate']:.2f} pad={fitted['pad_ratio']:.2f} "
            f"vs linear hit={lin['hit_rate']:.2f} "
            f"pad={lin['pad_ratio']:.2f})")


if __name__ == "__main__":
    run()
