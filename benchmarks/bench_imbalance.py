"""Routing-skew sweep — what load imbalance costs each execution mode.

Sweeps a Zipf-like skew factor over global experts (token count held
constant), compiles the forward taskflow from the resulting RoutingPlan,
and runs it through both simulators. Surfaces the skew-induced straggler
(max/mean per-rank cube busy time) and exposed communication that the
unified single-launch runtime can still hide but the operator-by-operator
baseline cannot.
"""

from __future__ import annotations

from repro.core.hardware import AscendA3
from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.routing import hotspot_plan, skewed_plan
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified

from .common import emit

EP, E_LOC, ROWS = 4, 4, 512
D_MODEL, D_FF = 2048, 512


def _cases():
    for alpha in (0.0, 0.5, 1.0, 2.0):
        yield f"alpha{alpha:g}", skewed_plan(EP, E_LOC, ROWS, alpha)
    yield "hotspot", hotspot_plan(EP, E_LOC, ROWS)


def run(hw: AscendA3 = AscendA3()) -> None:
    for name, plan in _cases():
        # All generated plans are per-source-uniform (every source sends the
        # same count to a given expert), so gmm_m_split=EP cuts each expert
        # block exactly at source-cell boundaries — fine-grained tiles that
        # keep the single-trigger invariant under skew.
        cfg = ScheduleConfig(ep=EP, e_loc=E_LOC, rows=0, d_model=D_MODEL,
                             d_ff=D_FF, gmm_m_split=EP, plan=plan)
        sched = compile_schedule(build_moe_ffn_forward(cfg), ratr=True)
        uni = simulate_unified(sched, hw)
        base = simulate_baseline(sched, hw)
        emit(f"imbalance_{name}_unified", uni.makespan_us,
             f"straggler={uni.straggler_ratio:.2f}x "
             f"mac={uni.mac_ratio:.3f} "
             f"exposed={uni.exposed_comm_us:.1f}us "
             f"plan_skew={plan.expert_imbalance():.2f}x")
        emit(f"imbalance_{name}_baseline", base.makespan_us,
             f"straggler={base.straggler_ratio:.2f}x "
             f"speedup={base.makespan_us / max(1e-9, uni.makespan_us):.2f}x")


if __name__ == "__main__":
    run()
