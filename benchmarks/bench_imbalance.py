"""Routing-skew sweep — what load imbalance costs each execution mode.

Sweeps a Zipf-like skew factor over global experts (token count held
constant) plus two hotspot profiles, compiles the forward taskflow from the
resulting RoutingPlan under source-aligned sub-splitting
(``gmm_split_mode="source_aligned"`` — legal for arbitrary imbalanced
plans, unlike the even grid), and runs it through both simulators.

Two comparisons per scenario:

* unified (pipeline ``ratr``) vs the operator-by-operator baseline — the
  overlap win the single-launch runtime keeps under skew;
* pipeline ``ratr`` vs ``ratr + critical_rank_first`` — what the
  straggler-aware pass recovers at compile time (largest on concentrated
  hotspots, where it pipelines the critical rank's starved GMM chain).
"""

from __future__ import annotations

from repro.core.hardware import AscendA3
from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.routing import hotspot_plan, skewed_plan
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified

from .common import emit, phase_summary

EP, E_LOC, ROWS = 8, 8, 128
D_MODEL, D_FF = 2048, 512
M_SPLIT = 64


def _cases():
    for alpha in (0.0, 0.5, 1.0, 2.0):
        yield f"alpha{alpha:g}", skewed_plan(EP, E_LOC, ROWS, alpha)
    yield "hotspot", hotspot_plan(EP, E_LOC, ROWS)
    yield "hotspot_bg", hotspot_plan(EP, E_LOC, ROWS, background=16)


def run(hw: AscendA3 = AscendA3()) -> None:
    for name, plan in _cases():
        # Source-aligned sub-splitting places chunk boundaries on source-cell
        # edges (refining inside oversized cells), so arbitrary skewed /
        # hotspot plans get fine-grained tiles without violating the
        # single-trigger invariant — the even grid only compiles here for
        # per-src-uniform plans.
        cfg = ScheduleConfig(ep=EP, e_loc=E_LOC, rows=0, d_model=D_MODEL,
                             d_ff=D_FF, gmm_m_split=M_SPLIT,
                             gmm_split_mode="source_aligned", plan=plan)
        sched = compile_schedule(build_moe_ffn_forward(cfg),
                                 pipeline=["ratr"])
        crit_sched = compile_schedule(
            build_moe_ffn_forward(cfg),
            pipeline=["ratr", "critical_rank_first"])
        uni = simulate_unified(sched, hw)
        crit = simulate_unified(crit_sched, hw)
        base = simulate_baseline(sched, hw)
        emit(f"imbalance_{name}_unified", uni.makespan_us,
             f"straggler={uni.straggler_ratio:.2f}x "
             f"mac={uni.mac_ratio:.3f} "
             f"exposed={uni.exposed_comm_us:.1f}us "
             f"plan_skew={plan.expert_imbalance():.2f}x")
        emit(f"imbalance_{name}_d2c", uni.dispatch_to_combine_us,
             phase_summary(uni))
        emit(f"imbalance_{name}_crit_first", crit.makespan_us,
             f"reduction={(uni.makespan_us - crit.makespan_us) / max(1e-9, uni.makespan_us) * 100:+.2f}% "
             f"vs_ratr={uni.makespan_us:.1f}us")
        emit(f"imbalance_{name}_baseline", base.makespan_us,
             f"straggler={base.straggler_ratio:.2f}x "
             f"speedup={base.makespan_us / max(1e-9, uni.makespan_us):.2f}x")


if __name__ == "__main__":
    run()
