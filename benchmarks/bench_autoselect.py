"""Selector overhead smoke — auto-selection must not eat the compile win.

``bench_sched_overhead`` shows the paper's static-vs-dynamic dispatch gap;
this is the same question one level up: cost-model-guided pipeline
selection (``core/autoselect.py``) happens on the compile path of every
*new* plan the dropless trainer sees, so its latency has to stay orders of
magnitude under schedule compilation (~1s on dense ep=8 plans) and its
memoized hit has to be effectively free (bucketed batch plans repeat).

Asserts a hard per-plan budget on the cold selection and a sub-millisecond
memoized path; emits one CSV row per routing profile with the resolved
pick, so CI also notices a selector that silently starts resolving
everything to ``naive``.
"""

from __future__ import annotations

import time

from repro.core.autoselect import (select, selection_cache_clear,
                                   selection_cache_info)
from repro.core.odg import ScheduleConfig
from repro.core.routing import hotspot_plan, random_plan, skewed_plan

from .common import emit

import numpy as np

EP, E_LOC, ROWS = 8, 8, 128
D_MODEL, D_FF = 2048, 512
M_SPLIT = 64
COLD_BUDGET_MS = 100.0      # per (plan, direction); compile is ~10x this
WARM_BUDGET_MS = 1.0        # memoized per-batch path


def _profiles():
    rng = np.random.default_rng(0)
    yield "balanced", None
    yield "zipf1", skewed_plan(EP, E_LOC, ROWS, 1.0)
    yield "zipf2", skewed_plan(EP, E_LOC, ROWS, 2.0)
    yield "hotspot", hotspot_plan(EP, E_LOC, ROWS)
    yield "hotspot_bg", hotspot_plan(EP, E_LOC, ROWS, background=16)
    yield "sparse", random_plan(EP, E_LOC, ROWS // 4, rng, p_zero=0.5)


def run() -> None:
    worst_cold = worst_warm = 0.0
    for name, plan in _profiles():
        cfg = ScheduleConfig(ep=EP, e_loc=E_LOC, rows=ROWS, d_model=D_MODEL,
                             d_ff=D_FF, gmm_m_split=M_SPLIT,
                             gmm_split_mode="source_aligned", plan=plan)
        for direction in ("forward", "backward"):
            selection_cache_clear()
            t0 = time.perf_counter()
            choice = select(cfg.routing, cfg, direction=direction)
            cold_ms = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            select(cfg.routing, cfg, direction=direction)
            warm_ms = (time.perf_counter() - t0) * 1e3
            worst_cold = max(worst_cold, cold_ms)
            worst_warm = max(worst_warm, warm_ms)
            emit(f"autoselect_{name}_{direction[:3]}", cold_ms * 1e3,
                 f"warm={warm_ms * 1e3:.1f}us pick={choice.tag} "
                 f"candidates={len(choice.scores)} "
                 f"predicted={choice.predicted_us:.1f}us")
    info = selection_cache_info()
    assert worst_cold < COLD_BUDGET_MS, (
        f"cold selection {worst_cold:.1f}ms blows the {COLD_BUDGET_MS}ms "
        f"budget — selection is eating the compile-time win")
    assert worst_warm < WARM_BUDGET_MS, (
        f"memoized selection {worst_warm:.2f}ms — the per-batch dropless "
        f"path would feel this")
    emit("autoselect_worst_cold", worst_cold * 1e3,
         f"budget={COLD_BUDGET_MS}ms warm_worst={worst_warm:.3f}ms "
         f"cache={info.hits}h/{info.misses}m")


if __name__ == "__main__":
    run()
