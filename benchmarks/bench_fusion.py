"""Cross-layer schedule fusion — fused vs back-to-back fragment makespan.

Compiles a two-layer fused forward taskflow (layer 0's combine bridged
into layer 1's dispatch through per-rank LayerBoundary tiles) for three
routing-skew scenarios and simulates it twice with identical tasks and
costs:

* **fused** — the cross-fragment dependency edges as compiled: layer 1
  work at rank *r* starts as soon as *r*'s boundary inputs (the combines
  into *r*) land, overlapping layer 0's combine tail with layer 1's
  dispatch ramp;
* **sequential** — the same taskflow under ``fragment_barrier=True``:
  fragment 1 may not start until fragment 0 fully drains. This is the
  back-to-back per-layer reference — both sides price the inter-layer
  token remap identically, so the delta is purely the overlap the fused
  schedule unlocks.

The dispatch-to-combine makespan win is gated: fusion must strictly beat
the barrier on at least two of the three scenarios, otherwise the run
fails (CI regression gate for the fusion passes).

Per-layer standalone d2c (which gets the inter-layer remap for free —
the host-bridge execution model) is emitted as context, not gated.
"""

from __future__ import annotations

from repro.core.fusion import compile_fused
from repro.core.hardware import AscendA3
from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.routing import hotspot_plan, skewed_plan
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_unified

from .common import emit

EP, E_LOC, ROWS = 8, 8, 128
D_MODEL, D_FF = 2048, 512
M_SPLIT = 64
PIPELINE = ["ratr", "critical_rank_first"]
WINS_REQUIRED = 2


def _cases():
    yield "uniform", skewed_plan(EP, E_LOC, ROWS, 0.0)
    yield "zipf", skewed_plan(EP, E_LOC, ROWS, 1.2)
    yield "hotspot", hotspot_plan(EP, E_LOC, ROWS, background=16)


def _cfg(plan) -> ScheduleConfig:
    return ScheduleConfig(ep=EP, e_loc=E_LOC, rows=0, d_model=D_MODEL,
                          d_ff=D_FF, gmm_m_split=M_SPLIT,
                          gmm_split_mode="source_aligned", plan=plan)


def run(hw: AscendA3 = AscendA3()) -> None:
    wins = 0
    for name, plan in _cases():
        cfg = _cfg(plan)
        fused = compile_fused([cfg, cfg], "forward", pipeline=PIPELINE)
        fsim = simulate_unified(fused, hw)
        ssim = simulate_unified(fused, hw, fragment_barrier=True)
        solo = simulate_unified(
            compile_schedule(build_moe_ffn_forward(cfg), pipeline=PIPELINE),
            hw)
        f_d2c, s_d2c = (fsim.dispatch_to_combine_us,
                        ssim.dispatch_to_combine_us)
        win_pct = (s_d2c - f_d2c) / max(1e-9, s_d2c) * 100
        won = f_d2c < s_d2c
        wins += won
        emit(f"fusion_{name}_fused", f_d2c,
             f"win={win_pct:+.2f}% frag0="
             f"{fsim.fragment_makespan_us.get(0, 0.0):.1f}us frag1="
             f"{fsim.fragment_makespan_us.get(1, 0.0):.1f}us "
             f"boundary_busy={fsim.phase_us.get('boundary', 0.0):.1f}us")
        emit(f"fusion_{name}_sequential", s_d2c,
             f"barrier=fragment plan_skew={plan.expert_imbalance():.2f}x")
        emit(f"fusion_{name}_per_layer_x2", 2 * solo.dispatch_to_combine_us,
             "context=host-bridge remap (unpriced boundary)")
    emit("fusion_scenario_wins", float(wins), f"required>={WINS_REQUIRED}of3")
    if wins < WINS_REQUIRED:
        raise RuntimeError(
            f"fused schedule beat the fragment-barrier reference on only "
            f"{wins}/3 scenarios (need >= {WINS_REQUIRED})")


if __name__ == "__main__":
    run()
