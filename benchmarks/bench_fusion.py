"""Cross-layer schedule fusion — fused vs back-to-back fragment makespan.

Compiles a two-layer fused forward taskflow (layer 0's combine bridged
into layer 1's dispatch through per-rank LayerBoundary tiles) for three
routing-skew scenarios and simulates it twice with identical tasks and
costs:

* **fused** — the cross-fragment dependency edges as compiled: layer 1
  work at rank *r* starts as soon as *r*'s boundary inputs (the combines
  into *r*) land, overlapping layer 0's combine tail with layer 1's
  dispatch ramp;
* **sequential** — the same taskflow under ``fragment_barrier=True``:
  fragment 1 may not start until fragment 0 fully drains. This is the
  back-to-back per-layer reference — both sides price the inter-layer
  token remap identically, so the delta is purely the overlap the fused
  schedule unlocks.

The dispatch-to-combine makespan win is gated: fusion must strictly beat
the barrier on at least two of the three scenarios, otherwise the run
fails (CI regression gate for the fusion passes).

Per-layer standalone d2c (which gets the inter-layer remap for free —
the host-bridge execution model) is emitted as context, not gated.

The PP section compiles the same three skew scenarios as a 1F1B-
interleaved pipeline (``compile_pp_fused``, ep=8, pp ∈ {2, 4}, per-device
shape ratios matching a Megatron tp2pp4ep4 slice) and simulates fused vs
``stage_barrier=True`` — the fair per-stage reference where cell (s, m)
waits for both (s-1, m) and (s, m-1) to fully drain. Gated the same way:
fused must strictly beat the barrier on dispatch-to-combine or makespan
on at least two of three scenarios per pipeline depth, and ``select_pp``
must never predict fused worse than per-stage.
"""

from __future__ import annotations

from repro.core.autoselect import select_pp
from repro.core.fusion import compile_fused, compile_pp_fused
from repro.core.hardware import AscendA3
from repro.core.odg import ScheduleConfig, build_moe_ffn_forward
from repro.core.routing import hotspot_plan, skewed_plan
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_unified

from .common import emit

EP, E_LOC, ROWS = 8, 8, 128
D_MODEL, D_FF = 2048, 512
M_SPLIT = 64
PIPELINE = ["ratr", "critical_rank_first"]
WINS_REQUIRED = 2

# PP scenario: per-device slice of a Megatron tp2pp4ep4 run — d_model and
# d_ff/tp in their ~1.75 ratio (14336 / 4096 / 2tp), modest rows and
# m_split so the S x M cell grid stays simulation-sized.
PP_D_MODEL, PP_D_FF = 1024, 1792
PP_ROWS, PP_M_SPLIT = 64, 32
PP_MICROBATCHES = 4
PP_STAGES = (2, 4)


def _cases():
    yield "uniform", skewed_plan(EP, E_LOC, ROWS, 0.0)
    yield "zipf", skewed_plan(EP, E_LOC, ROWS, 1.2)
    yield "hotspot", hotspot_plan(EP, E_LOC, ROWS, background=16)


def _cfg(plan) -> ScheduleConfig:
    return ScheduleConfig(ep=EP, e_loc=E_LOC, rows=0, d_model=D_MODEL,
                          d_ff=D_FF, gmm_m_split=M_SPLIT,
                          gmm_split_mode="source_aligned", plan=plan)


def _pp_cfg(plan) -> ScheduleConfig:
    return ScheduleConfig(ep=EP, e_loc=E_LOC, rows=0, d_model=PP_D_MODEL,
                          d_ff=PP_D_FF, gmm_m_split=PP_M_SPLIT,
                          gmm_split_mode="source_aligned", plan=plan)


def _pp_cases():
    yield "uniform", skewed_plan(EP, E_LOC, PP_ROWS, 0.0)
    yield "zipf", skewed_plan(EP, E_LOC, PP_ROWS, 1.2)
    yield "hotspot", hotspot_plan(EP, E_LOC, PP_ROWS, background=8)


def run_pp(hw: AscendA3 = AscendA3()) -> None:
    for S in PP_STAGES:
        wins = 0
        for name, plan in _pp_cases():
            cfg = _pp_cfg(plan)
            fs = compile_pp_fused([cfg] * S, PP_MICROBATCHES,
                                  pipeline=PIPELINE)
            fsim = simulate_unified(fs, hw)
            ssim = simulate_unified(fs, hw, stage_barrier=True)
            won = (fsim.dispatch_to_combine_us < ssim.dispatch_to_combine_us
                   or fsim.makespan_us < ssim.makespan_us)
            wins += won
            win_pct = ((ssim.makespan_us - fsim.makespan_us)
                       / max(1e-9, ssim.makespan_us) * 100)
            emit(f"pp{S}_{name}_fused", fsim.makespan_us,
                 f"win={win_pct:+.2f}% d2c={fsim.dispatch_to_combine_us:.1f}"
                 f"us cells={S}x{PP_MICROBATCHES} "
                 f"stage_comm={fsim.phase_us.get('stage', 0.0):.1f}us")
            emit(f"pp{S}_{name}_stage_barrier", ssim.makespan_us,
                 f"barrier=stage d2c={ssim.dispatch_to_combine_us:.1f}us "
                 f"plan_skew={plan.expert_imbalance():.2f}x")
            ch = select_pp([cfg] * S, PP_MICROBATCHES)
            if ch.predicted_fused_us > ch.predicted_per_stage_us + 1e-9:
                raise RuntimeError(
                    f"select_pp predicted fused worse than per-stage at "
                    f"pp={S} scenario={name}: {ch.predicted_fused_us:.1f}us"
                    f" > {ch.predicted_per_stage_us:.1f}us")
            emit(f"pp{S}_{name}_selector_fused_pred", ch.predicted_fused_us,
                 f"per_stage_pred={ch.predicted_per_stage_us:.1f}us "
                 f"bubble={ch.bubble_us:.1f}us fuse={ch.fuse}")
        emit(f"pp{S}_scenario_wins", float(wins),
             f"required>={WINS_REQUIRED}of3")
        if wins < WINS_REQUIRED:
            raise RuntimeError(
                f"PP-fused schedule beat the stage-barrier reference on "
                f"only {wins}/3 scenarios at pp={S} "
                f"(need >= {WINS_REQUIRED})")


def run(hw: AscendA3 = AscendA3()) -> None:
    wins = 0
    for name, plan in _cases():
        cfg = _cfg(plan)
        fused = compile_fused([cfg, cfg], "forward", pipeline=PIPELINE)
        fsim = simulate_unified(fused, hw)
        ssim = simulate_unified(fused, hw, fragment_barrier=True)
        solo = simulate_unified(
            compile_schedule(build_moe_ffn_forward(cfg), pipeline=PIPELINE),
            hw)
        f_d2c, s_d2c = (fsim.dispatch_to_combine_us,
                        ssim.dispatch_to_combine_us)
        win_pct = (s_d2c - f_d2c) / max(1e-9, s_d2c) * 100
        won = f_d2c < s_d2c
        wins += won
        emit(f"fusion_{name}_fused", f_d2c,
             f"win={win_pct:+.2f}% frag0="
             f"{fsim.fragment_makespan_us.get(0, 0.0):.1f}us frag1="
             f"{fsim.fragment_makespan_us.get(1, 0.0):.1f}us "
             f"boundary_busy={fsim.phase_us.get('boundary', 0.0):.1f}us")
        emit(f"fusion_{name}_sequential", s_d2c,
             f"barrier=fragment plan_skew={plan.expert_imbalance():.2f}x")
        emit(f"fusion_{name}_per_layer_x2", 2 * solo.dispatch_to_combine_us,
             "context=host-bridge remap (unpriced boundary)")
    emit("fusion_scenario_wins", float(wins), f"required>={WINS_REQUIRED}of3")
    if wins < WINS_REQUIRED:
        raise RuntimeError(
            f"fused schedule beat the fragment-barrier reference on only "
            f"{wins}/3 scenarios (need >= {WINS_REQUIRED})")
    run_pp(hw)


if __name__ == "__main__":
    run()
