"""Decode-trace replay smoke — bounded ragged-EP retraces under bucketing.

Drives ``repro.launch.replay`` end-to-end at CI scale: churned decode
traces (stationary ``uniform`` plus the batch-size-bursting ``bursty``
profile) replayed through plan compilation, the SSC cache, and the
simulator, per bucket policy. The asserted contract is the ragged-EP
story: chunk caps are static jit constants, so an **exact** plan retraces
``make_moe_ep(plan=...)`` on nearly every batch, while a bucketed plan's
caps collapse onto the policy's rungs — on a stationary profile the
fitted ladder's distinct cap tuples stay within its rung count (+1 for
the cold start), and even under batch-size bursts the retrace count stays
far below step count.
"""

from __future__ import annotations

from repro.core.buckets import BucketSpec, fit_ladder
from repro.launch.replay import exact_plans, replay_trace, synth_trace
from repro.models.moe import MoEConfig

from .common import emit

EP, E_LOC, T_LOC, TOP_K, STEPS = 4, 2, 48, 2, 20
D_MODEL, D_FF = 64, 32

MC = MoEConfig(n_experts=EP * E_LOC, top_k=TOP_K, d_expert=D_FF)


def _trace(profile: str, seed: int):
    return synth_trace(profile, STEPS, ep=EP, e_loc=E_LOC, t_loc=T_LOC,
                       top_k=TOP_K, seed=seed)


def run() -> None:
    for profile in ("uniform", "bursty"):
        fitted = fit_ladder(exact_plans(_trace(profile, 1), MC, EP),
                            4, split_penalty=1.0)
        policies = {"exact": BucketSpec.exact(),
                    "linear16": BucketSpec.linear(16),
                    "fitted": fitted}
        rows = {r["policy"]: r for r in replay_trace(
            _trace(profile, 0), MC, EP, policies, d_model=D_MODEL,
            d_ff=D_FF, simulate=True)}
        for name, r in rows.items():
            emit(f"replay_{profile}_{name}", r["fetch_us_mean"],
                 f"hit_rate={r['hit_rate']:.2f} "
                 f"pad={r['pad_ratio']:.2f}x "
                 f"retraces={r['ep_retraces']}/{r['steps']} "
                 f"p50={r['p50_us']:.1f}us p99={r['p99_us']:.1f}us "
                 f"spec={r['spec']}")

        exact, fit_row = rows["exact"], rows["fitted"]
        assert exact["ep_retraces"] >= 0.9 * STEPS, (
            f"{profile}: exact plans should retrace nearly every batch "
            f"({exact['ep_retraces']}/{STEPS})")
        assert fit_row["ep_retraces"] < exact["ep_retraces"] / 2, (
            f"{profile}: bucketed retraces must be bounded "
            f"({fit_row['ep_retraces']} vs {exact['ep_retraces']})")
        if profile != "bursty":        # bursts legitimately resize caps
            n_rungs = len(fitted.edges)
            assert fit_row["ep_retraces"] <= n_rungs + 1, (
                f"{profile}: stationary-profile retraces must stay within "
                f"the ladder ({fit_row['ep_retraces']} > {n_rungs} + 1)")


if __name__ == "__main__":
    run()
