"""Decode-trace replay smoke — bounded ragged-EP retraces under bucketing,
plus the online-tuning and admission-control regression gates.

Drives ``repro.launch.replay`` end-to-end at CI scale: churned decode
traces (stationary ``uniform`` plus the batch-size-bursting ``bursty``
profile) replayed through plan compilation, the SSC cache, and the
simulator, per bucket policy. The asserted contract is the ragged-EP
story: chunk caps are static jit constants, so an **exact** plan retraces
``make_moe_ep(plan=...)`` on nearly every batch, while a bucketed plan's
caps collapse onto the policy's rungs — on a stationary profile the
fitted ladder's distinct cap tuples stay within its rung count (+1 for
the cold start), and even under batch-size bursts the retrace count stays
far below step count.

Two serving gates ride on top (``launch/online.py``):

* **Online vs offline under churn** — traffic whose volume doubles
  mid-trace (t_loc 48 → 96). The offline ``fitted`` ladder was sized for
  the pre-churn regime; the warm-started online tuner must match or beat
  its hit rate on at least 2 of 3 profiles, keep mean pad no worse than
  ``linear:16``, and hold simulated p99 step latency within 10% of the
  offline policy's.
* **Admission under burst** — predictor-priced token-level serving of the
  ``bursty`` profile: with the gate armed (SLO at half the unbounded p99),
  shed must be nonzero and *reported*, active tokens bounded by the sized
  batch, and p99 at or under the SLO — strictly below the unbounded
  baseline's.
"""

from __future__ import annotations

import numpy as np

from repro.core.buckets import BucketSpec, fit_ladder
from repro.launch.online import AdmissionConfig, replay_admission, size_slots
from repro.launch.replay import (exact_plans, replay_trace,
                                 resolve_policies, synth_trace)
from repro.models.moe import MoEConfig, routed_counts

from .common import emit

EP, E_LOC, T_LOC, TOP_K, STEPS = 4, 2, 48, 2, 20
D_MODEL, D_FF = 64, 32

MC = MoEConfig(n_experts=EP * E_LOC, top_k=TOP_K, d_expert=D_FF)


def _trace(profile: str, seed: int):
    return synth_trace(profile, STEPS, ep=EP, e_loc=E_LOC, t_loc=T_LOC,
                       top_k=TOP_K, seed=seed)


def run() -> None:
    for profile in ("uniform", "bursty"):
        fitted = fit_ladder(exact_plans(_trace(profile, 1), MC, EP),
                            4, split_penalty=1.0)
        policies = {"exact": BucketSpec.exact(),
                    "linear16": BucketSpec.linear(16),
                    "fitted": fitted}
        rows = {r["policy"]: r for r in replay_trace(
            _trace(profile, 0), MC, EP, policies, d_model=D_MODEL,
            d_ff=D_FF, simulate=True)}
        for name, r in rows.items():
            emit(f"replay_{profile}_{name}", r["fetch_us_mean"],
                 f"hit_rate={r['hit_rate']:.2f} "
                 f"pad={r['pad_ratio']:.2f}x "
                 f"retraces={r['ep_retraces']}/{r['steps']} "
                 f"p50={r['p50_us']:.1f}us p99={r['p99_us']:.1f}us "
                 f"spec={r['spec']}")

        exact, fit_row = rows["exact"], rows["fitted"]
        assert exact["ep_retraces"] >= 0.9 * STEPS, (
            f"{profile}: exact plans should retrace nearly every batch "
            f"({exact['ep_retraces']}/{STEPS})")
        assert fit_row["ep_retraces"] < exact["ep_retraces"] / 2, (
            f"{profile}: bucketed retraces must be bounded "
            f"({fit_row['ep_retraces']} vs {exact['ep_retraces']})")
        if profile != "bursty":        # bursts legitimately resize caps
            n_rungs = len(fitted.edges)
            assert fit_row["ep_retraces"] <= n_rungs + 1, (
                f"{profile}: stationary-profile retraces must stay within "
                f"the ladder ({fit_row['ep_retraces']} > {n_rungs} + 1)")

    run_online_gate()
    run_admission_gate()


def run_online_gate() -> None:
    """Online refitting must pay for itself when traffic churns.

    The replayed trace doubles its per-rank token volume mid-stream
    (t_loc 48 → 96) while the offline fit only ever saw the pre-churn
    regime — the deploy-then-drift scenario online tuning exists for.
    ``online:6`` warm-starts from the *identical* ladder ``fitted:6``
    deploys (resolve_policies guarantees this), so any hit-rate delta is
    attributable to refitting alone.
    """
    wins, pad_onl, pad_l16 = 0, [], []
    for profile in ("zipf", "hotspot", "bursty"):
        pre = synth_trace(profile, 32, ep=EP, e_loc=E_LOC, t_loc=T_LOC,
                          top_k=TOP_K, seed=0)
        post = synth_trace(profile, 64, ep=EP, e_loc=E_LOC, t_loc=2 * T_LOC,
                           top_k=TOP_K, seed=2)
        fit = synth_trace(profile, 32, ep=EP, e_loc=E_LOC, t_loc=T_LOC,
                          top_k=TOP_K, seed=1)
        pols = resolve_policies(["linear:16", "fitted:6", "online:6"],
                                fit, MC, EP)
        rows = {r["policy"]: r for r in replay_trace(
            pre + post, MC, EP, pols, d_model=D_MODEL, d_ff=D_FF,
            simulate=True)}
        onl, fit_row, l16 = (rows["online:6"], rows["fitted:6"],
                             rows["linear:16"])
        emit(f"replay_churn_{profile}_online", onl["fetch_us_mean"],
             f"hit={onl['hit_rate']:.2f} (fitted={fit_row['hit_rate']:.2f}) "
             f"pad={onl['pad_ratio']:.2f}x (lin16={l16['pad_ratio']:.2f}x) "
             f"swaps={onl['swaps']} refits={onl['refits']} "
             f"p99={onl['p99_us']:.1f}us (fitted={fit_row['p99_us']:.1f}us)")
        wins += onl["hit_rate"] >= fit_row["hit_rate"]
        pad_onl.append(onl["pad_ratio"])
        pad_l16.append(l16["pad_ratio"])
        assert onl["p99_us"] <= 1.10 * fit_row["p99_us"], (
            f"{profile}: online p99 {onl['p99_us']:.2f}us regressed >10% "
            f"over fitted {fit_row['p99_us']:.2f}us")
    assert wins >= 2, (
        f"online matched/beat the offline fit on only {wins}/3 churned "
        f"profiles")
    assert float(np.mean(pad_onl)) <= float(np.mean(pad_l16)), (
        f"online mean pad {np.mean(pad_onl):.3f}x exceeds the static "
        f"linear:16 ladder's {np.mean(pad_l16):.3f}x")


def run_admission_gate() -> None:
    """Admission control must buy its p99 with *reported* shed, not magic.

    Bursty traffic, SLO pinned at half the unbounded baseline's p99 and a
    batch budget sized from the same trace (``size_slots``). The gate must
    (a) meet the SLO where the baseline misses it, strictly improving p99,
    (b) never exceed the sized budget, and (c) account for every offered
    token as served, shed, or still queued — shedding is visible load
    management, never silent drop.
    """
    trace = synth_trace("bursty", 48, ep=EP, e_loc=E_LOC, t_loc=32,
                        top_k=TOP_K, seed=0)
    base = replay_admission(trace, MC, EP, d_model=D_MODEL, d_ff=D_FF)
    slo = 0.5 * base["p99_us"]
    pop = [routed_counts(ti, MC, EP) for ti in trace]
    n = size_slots(pop, MC, EP, slo, d_model=D_MODEL, d_ff=D_FF)
    gated = replay_admission(
        trace, MC, EP, d_model=D_MODEL, d_ff=D_FF, n_slots=n,
        admission=AdmissionConfig(slo_us=slo, max_queue=160))
    emit("replay_admission_gated", gated["p99_us"],
         f"slo={slo:.2f}us n_slots={n} shed={gated['shed']} "
         f"served={gated['served']} deferred={gated['deferred']} "
         f"max_active={gated['max_active']} base_p99={base['p99_us']:.2f}us "
         f"miss={gated['slo_miss_rate']:.2f}")
    offered = sum(np.asarray(t).reshape(-1, np.asarray(t).shape[-1]).shape[0]
                  for t in trace)
    assert gated["served"] + gated["shed"] + gated["deferred"] == offered, (
        "token accounting leak: served+shed+deferred != offered")
    assert gated["shed"] > 0, "bursty load at half-p99 SLO must shed"
    assert gated["max_active"] <= n, (
        f"gate exceeded sized budget: {gated['max_active']} > {n}")
    assert gated["p99_us"] <= slo < base["p99_us"], (
        f"gated p99 {gated['p99_us']:.2f}us vs slo {slo:.2f}us vs "
        f"baseline {base['p99_us']:.2f}us")


if __name__ == "__main__":
    run()
