"""Elastic rescale path latency — remap, re-key, and biased selection.

A rank loss puts three operations on the recovery critical path before
the first post-rescale step can compile: ``remap_plan`` (per live plan),
``SSCCache.rekey_for_mesh`` (once, over the resident population), and an
``autoselect`` pass under the observed-time-biased cost model. All three
are host-side bookkeeping — they must stay orders of magnitude under a
single schedule compile (~1 s at dense ep=8), or "elastic" restart is
elastic in name only. Emits per-op latency plus the remap fan of a
realistic resident population, and asserts hard budgets so CI catches a
remap that silently goes quadratic.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.autoselect import select, selection_cache_clear
from repro.core.elastic import check_remap, observed_cost_model, remap_plan
from repro.core.odg import ScheduleConfig
from repro.core.routing import (balanced_plan, hotspot_plan, random_plan,
                                skewed_plan)
from repro.core.ssc import SSCCache

from .common import emit

EP, E_LOC, ROWS = 8, 8, 128
D_MODEL, D_FF = 2048, 512
REMAP_BUDGET_MS = 5.0       # per plan; compile is ~200x this
REKEY_BUDGET_MS = 20.0      # once per rescale, whole resident population


def _population(n: int):
    rng = np.random.default_rng(7)
    plans = []
    for i in range(n):
        kind = i % 3
        if kind == 0:
            plans.append(skewed_plan(EP, E_LOC, ROWS, 1.0 + 0.1 * i))
        elif kind == 1:
            plans.append(hotspot_plan(EP, E_LOC, ROWS, background=i))
        else:
            plans.append(random_plan(EP, E_LOC, ROWS, rng, p_zero=0.3))
    return plans


def run() -> None:
    plans = _population(24)

    # 64 experts re-chunk onto any power-of-two mesh; losing a node of 4
    # ranks (8 -> 4) is the realistic shrink.
    dead = list(range(EP // 2, EP))
    t0 = time.perf_counter()
    remapped = [remap_plan(p, dead_ranks=dead) for p in plans]
    dt = time.perf_counter() - t0
    per_plan_ms = dt / len(plans) * 1e3
    assert per_plan_ms < REMAP_BUDGET_MS, per_plan_ms
    emit("elastic_remap_plan", per_plan_ms * 1e3,
         f"plans={len(plans)} ep={EP}->{EP // 2} budget={REMAP_BUDGET_MS}ms")

    t0 = time.perf_counter()
    ok = all(check_remap(p, q, tuple(range(EP // 2)))["ok"]
             for p, q in zip(plans, remapped))
    dt = time.perf_counter() - t0
    assert ok
    emit("elastic_check_remap", dt / len(plans) * 1e6,
         f"all_ok={ok}")

    # Re-key a resident cache population (no compiles timed — populate
    # with tiny plans so the rekey cost dominates the scenario).
    cache = SSCCache(max_entries=64)
    for i, p in enumerate(_population(12)):
        small = remap_plan(p, new_ep=4)
        cfg = ScheduleConfig(ep=4, e_loc=small.e_loc, rows=0, d_model=64,
                             d_ff=32, plan=small, bucket=4)
        cache.get_or_compile(cfg, "forward", pipeline=["ratr"])
    t0 = time.perf_counter()
    out = cache.rekey_for_mesh(2)
    dt_ms = (time.perf_counter() - t0) * 1e3
    assert dt_ms < REKEY_BUDGET_MS, dt_ms
    emit("elastic_rekey_for_mesh", dt_ms * 1e3,
         f"entries={out['entries']} active={out['active']} "
         f"evictions={cache.evictions}")

    # Biased selection: the straggler feedback loop prices every candidate
    # under rank_bias — same budget class as the unbiased selector.
    selection_cache_clear()
    # Balanced plan: the only skew is the observed bias, so the pick
    # doubling as a sanity signal — critical_rank_first should fire.
    plan = balanced_plan(EP, E_LOC, ROWS)
    cfg = ScheduleConfig(ep=EP, e_loc=E_LOC, rows=ROWS, d_model=D_MODEL,
                         d_ff=D_FF, plan=plan)
    times = [100.0] * EP
    times[3] = 300.0
    cm = observed_cost_model(times)
    t0 = time.perf_counter()
    choice = select(plan, cfg, cm)
    dt_ms = (time.perf_counter() - t0) * 1e3
    names = [n for n, _ in choice.pipeline.key()]
    emit("elastic_biased_select", dt_ms * 1e3,
         f"pick={choice.tag} crit_pass={'critical_rank_first' in names}")
