"""Figure 8 — end-to-end training-step latency under sampled natural routing.

The full step includes unchanged attention/dense compute and framework
overhead; the paper reports 1.08–1.09× end-to-end from the ~1.5× module
gain. We model the step as

    step = other + Σ_layers D2C(moe_ffn) × λ

with the *unchanged fraction* calibrated from the paper's Fig 3 profile
(MoE-FFN ≈ 24% of the step on the critical path) and λ a routing-imbalance
factor sampled from a Zipf-flavoured expert distribution (natural routing
makes the slowest rank the pacer). D2C latencies come from the simulator on
the real schedules — not from the paper's numbers.
"""

from __future__ import annotations

import numpy as np

from repro.core.hardware import AscendA3
from repro.core.odg import build_moe_ffn_backward, build_moe_ffn_forward
from repro.core.scheduler import compile_schedule
from repro.core.simulator import simulate_baseline, simulate_unified

from .common import emit, opt_pipeline, paper_module_config

MOE_FRACTION = 0.24       # MoE-FFN share of the step critical path (Fig 3)
PAPER_E2E = {4: 1.08, 8: 1.09, 16: 1.08}


def routing_imbalance(ep: int, e_loc: int, top_k: int = 8,
                      seed: int = 0, n_samples: int = 64) -> float:
    """E[max_rank load / mean load] under Zipf-ish natural routing."""
    rng = np.random.default_rng(seed)
    E = ep * e_loc
    lams = []
    for _ in range(n_samples):
        # aux-loss-balanced natural routing: mild log-normal popularity
        popularity = np.exp(rng.normal(0.0, 0.35, size=E))
        p = popularity / popularity.sum()
        tokens = rng.multinomial(8192 * top_k, p)
        per_rank = tokens.reshape(ep, e_loc).sum(1)
        lams.append(per_rank.max() / per_rank.mean())
    return float(np.mean(lams))


def run(hw: AscendA3 = AscendA3()) -> None:
    for ep in (4, 8, 16):
        lam = routing_imbalance(ep, 8)
        tot_b, tot_u = 0.0, 0.0
        for direction in ("forward", "backward"):
            builder = (build_moe_ffn_forward if direction == "forward"
                       else build_moe_ffn_backward)
            s_base = compile_schedule(
                builder(paper_module_config(ep, m_split_mult=1)))
            s_opt = compile_schedule(
                builder(paper_module_config(ep, m_split_mult=4)),
                pipeline=opt_pipeline(direction))
            tot_b += simulate_baseline(s_base, hw).makespan_us
            tot_u += simulate_unified(s_opt, hw).makespan_us
        # step = other + moe·λ, with moe fraction of the *baseline* step.
        step_base = tot_b * lam / MOE_FRACTION
        other = step_base - tot_b * lam
        step_opt = other + tot_u * lam
        emit(f"train_step_ep{ep}_baseline", step_base,
             f"lambda={lam:.2f}")
        emit(f"train_step_ep{ep}_hyperparallel", step_opt,
             f"e2e_speedup={step_base / step_opt:.3f}x "
             f"paper={PAPER_E2E[ep]:.2f}x")


if __name__ == "__main__":
    run()
