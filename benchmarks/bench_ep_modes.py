"""EP execution-mode comparison on *our* TPU system (not the simulator).

Lowers the paper-style MoE block through the real shard_map EP paths on an
8-device (forced-host) CPU mesh in a subprocess and reports, from the
optimized HLO: collective op mix, per-device collective bytes, and wall
time — demonstrating baseline AllToAll vs the RATR chunked-ppermute ring
produce identical numerics with different collective schedules.
"""

from __future__ import annotations

import os
import subprocess
import sys

from .common import emit

_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs.deepseek_moe_paper import smoke_config
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.parallel.ep import EPConfig, make_moe_ep
from repro.parallel.roofline import parse_collectives

mesh = make_test_mesh(2, 4)
cfg = smoke_config()
params = M.init_params(cfg, jax.random.PRNGKey(0))
moe_params = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model), jnp.float32)
results = {}
for mode in ("baseline", "hyperparallel"):
    impl = make_moe_ep(mesh, EPConfig(mode=mode, capacity_factor=8.0))
    with jax.set_mesh(mesh):
        compiled = jax.jit(lambda p, x: impl(p, x, cfg.moe)).lower(moe_params, x).compile()
        y = compiled(moe_params, x); jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(compiled(moe_params, x))
        us = (time.perf_counter() - t0) / 5 * 1e6
    colls = parse_collectives(compiled.as_text())
    results[mode] = np.asarray(y)
    print(f"ep_mode_{mode},{us:.2f},collectives={colls.counts}"
          f" bytes={colls.total_bytes}")
np.testing.assert_allclose(results["baseline"], results["hyperparallel"],
                           rtol=2e-4, atol=2e-4)
print("ep_modes_numerics,0.00,baseline==hyperparallel allclose ok")
"""


def run() -> None:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUB],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=900)
    ok = False
    for line in out.stdout.splitlines():
        if line.startswith(("ep_mode", "ep_modes")):
            print(line)
            ok = True
    if not ok:
        emit("ep_modes_failed", 0.0, out.stderr.strip()[-200:])


if __name__ == "__main__":
    run()
